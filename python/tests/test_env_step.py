"""L2 env_step semantics: the fully-jitted Empty-8x8 environment."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model

L, R, F = 0, 1, 2  # left, right, forward


def reset(b=1):
    return model.env_reset(b)


def step(state, actions):
    pos, d, t, done, _obs = state[:5] if len(state) == 5 else state
    out = model.env_step(pos, d, t, done, jnp.asarray(actions, dtype=jnp.int32))
    return out  # (pos, dir, t, done, obs, reward, discount, is_first)


class TestEnvStep:
    def test_reset_state(self):
        pos, d, t, done, obs = reset(3)
        np.testing.assert_array_equal(np.asarray(pos), [[1, 1]] * 3)
        assert np.all(np.asarray(d) == 0)
        assert np.all(np.asarray(t) == 0)
        assert obs.shape == (3, model.OBS_DIM)
        # mission-free Empty: the token block tail stays all-zero
        assert np.all(np.asarray(obs)[:, model.GRID_OBS_DIM :] == 0)

    def test_forward_moves_east(self):
        state = reset(1)
        out = step(state, [F])
        np.testing.assert_array_equal(np.asarray(out[0]), [[1, 2]])
        assert float(out[5][0]) == 0.0  # reward
        assert float(out[6][0]) == 1.0  # discount

    def test_turns_change_direction_not_position(self):
        state = reset(1)
        out = step(state, [R])
        assert int(out[1][0]) == 1  # south
        np.testing.assert_array_equal(np.asarray(out[0]), [[1, 1]])
        out = model.env_step(out[0], out[1], out[2], out[3], jnp.array([L], dtype=jnp.int32))
        assert int(out[1][0]) == 0

    def test_wall_blocks(self):
        pos, d, t, done, _ = reset(1)
        # face north (3) at (1,1): forward into the wall
        d = jnp.array([3], dtype=jnp.int32)
        out = model.env_step(pos, d, t, done, jnp.array([F], dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(out[0]), [[1, 1]])

    def test_goal_terminates_with_reward_then_autoresets(self):
        # script: 5x forward (to col 6), right, 5x forward (to row 6)
        state = reset(1)
        pos, d, t, done, _ = state
        script = [F] * 5 + [R] + [F] * 5
        reward = discount = None
        for a in script:
            out = model.env_step(pos, d, t, done, jnp.array([a], dtype=jnp.int32))
            pos, d, t, done = out[0], out[1], out[2], out[3]
            reward, discount = float(out[5][0]), float(out[6][0])
        np.testing.assert_array_equal(np.asarray(pos), [[6, 6]])
        assert reward == 1.0
        assert discount == 0.0
        assert int(done[0]) == 1
        # autoreset on the next call, whatever the action
        out = model.env_step(pos, d, t, done, jnp.array([F], dtype=jnp.int32))
        np.testing.assert_array_equal(np.asarray(out[0]), [[1, 1]])
        assert int(out[7][0]) == 1  # is_first
        assert float(out[5][0]) == 0.0
        assert int(out[3][0]) == 0

    def test_timeout_truncates_with_discount_one(self):
        pos, d, t, done, _ = reset(1)
        t = jnp.array([model.MAX_STEPS - 1], dtype=jnp.int32)
        out = model.env_step(pos, d, t, done, jnp.array([L], dtype=jnp.int32))
        assert int(out[3][0]) == 1  # done (truncated)
        assert float(out[6][0]) == 1.0  # discount preserved

    def test_obs_matches_kernel_of_state(self):
        from compile.kernels import obs as obs_kernel

        pos, d, t, done, o = reset(2)
        out = model.env_step(pos, d, t, done, jnp.array([F, R], dtype=jnp.int32))
        grid = jnp.broadcast_to(model._static_grid()[None], (2, 8, 8, 3))
        expect = obs_kernel.obs_first_person_batched(grid, out[0], out[1]).reshape(
            2, model.GRID_OBS_DIM
        )
        np.testing.assert_array_equal(
            np.asarray(out[4])[:, : model.GRID_OBS_DIM], np.asarray(expect)
        )
        assert np.all(np.asarray(out[4])[:, model.GRID_OBS_DIM :] == 0)

    @settings(max_examples=30, deadline=None)
    @given(actions=st.lists(st.integers(0, 6), min_size=1, max_size=40), b=st.integers(1, 3))
    def test_invariants_under_random_actions(self, actions, b):
        pos, d, t, done, _ = reset(b)
        for a in actions:
            out = model.env_step(
                pos, d, t, done, jnp.full((b,), a, dtype=jnp.int32)
            )
            pos, d, t, done = out[0], out[1], out[2], out[3]
            p = np.asarray(pos)
            assert (p >= 1).all() and (p <= 6).all(), "agent left the room"
            assert ((np.asarray(d) >= 0) & (np.asarray(d) < 4)).all()
            r = np.asarray(out[5])
            assert np.isin(r, [0.0, 1.0]).all()
