"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Includes hypothesis sweeps over positions, directions, grid contents and
batch sizes — the core correctness signal for the AOT path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import mlp, obs, ref


def random_grid(rng, h=8, w=8):
    g = rng.integers(0, 10, size=(h, w, 3), dtype=np.int32)
    return jnp.asarray(g)


class TestObsKernel:
    def test_matches_ref_single(self):
        rng = np.random.default_rng(0)
        grid = random_grid(rng)
        pos = jnp.array([[3, 4]], dtype=jnp.int32)
        d = jnp.array([1], dtype=jnp.int32)
        got = obs.obs_first_person_batched(grid[None], pos, d)
        want = ref.obs_first_person(grid, pos[0], d[0])
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want))

    @pytest.mark.parametrize("direction", [0, 1, 2, 3])
    def test_all_directions(self, direction):
        rng = np.random.default_rng(direction)
        grid = random_grid(rng)
        pos = jnp.array([[4, 2]], dtype=jnp.int32)
        d = jnp.array([direction], dtype=jnp.int32)
        got = obs.obs_first_person_batched(grid[None], pos, d)[0]
        want = ref.obs_first_person(grid, pos[0], d[0])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_agent_cell_is_bottom_center(self):
        # The agent's own cell must land at view (6, 3).
        rng = np.random.default_rng(3)
        grid = random_grid(rng)
        for d in range(4):
            got = obs.obs_first_person_batched(
                grid[None], jnp.array([[4, 4]], dtype=jnp.int32), jnp.array([d], dtype=jnp.int32)
            )[0]
            np.testing.assert_array_equal(np.asarray(got[6, 3]), np.asarray(grid[4, 4]))

    def test_out_of_bounds_is_unseen(self):
        rng = np.random.default_rng(4)
        grid = random_grid(rng)
        # facing west from (1,1): most of the view is out of bounds
        got = obs.obs_first_person_batched(
            grid[None], jnp.array([[1, 1]], dtype=jnp.int32), jnp.array([2], dtype=jnp.int32)
        )[0]
        got = np.asarray(got)
        # far row of the view (6 cells west of col 1) is fully OOB
        np.testing.assert_array_equal(got[0], np.zeros((7, 3), dtype=np.int32))

    @settings(max_examples=60, deadline=None)
    @given(
        r=st.integers(1, 6),
        c=st.integers(1, 6),
        d=st.integers(0, 3),
        seed=st.integers(0, 2**31 - 1),
        batch=st.integers(1, 4),
    )
    def test_hypothesis_sweep(self, r, c, d, seed, batch):
        rng = np.random.default_rng(seed)
        grids = jnp.stack([random_grid(rng) for _ in range(batch)])
        pos = jnp.tile(jnp.array([[r, c]], dtype=jnp.int32), (batch, 1))
        dirs = jnp.full((batch,), d, dtype=jnp.int32)
        got = obs.obs_first_person_batched(grids, pos, dirs)
        for i in range(batch):
            want = ref.obs_first_person(grids[i], pos[i], dirs[i])
            np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


class TestDenseKernel:
    @pytest.mark.parametrize("activation", ["tanh", "relu", "linear"])
    def test_matches_ref(self, activation):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(5, 11)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(4, 11)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(4,)).astype(np.float32))
        got = mlp.dense(x, w, b, activation=activation)
        want = ref.dense(x, w, b, activation=activation)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    @settings(max_examples=40, deadline=None)
    @given(
        bsz=st.integers(1, 16),
        nin=st.integers(1, 64),
        nout=st.integers(1, 32),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, bsz, nin, nout, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(bsz, nin)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(nout, nin)).astype(np.float32))
        b = jnp.asarray(rng.normal(size=(nout,)).astype(np.float32))
        got = mlp.dense(x, w, b, activation="tanh")
        want = ref.dense(x, w, b, activation="tanh")
        assert got.shape == (bsz, nout)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)

    def test_gradients_flow_through_kernel(self):
        # jax.grad must differentiate through the pallas_call (needed by
        # ppo_update).
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(3, 5)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(2, 5)).astype(np.float32))
        b = jnp.zeros(2, dtype=jnp.float32)

        def loss(w):
            return (mlp.dense(x, w, b, activation="tanh") ** 2).sum()

        g = jax.grad(loss)(w)

        def loss_ref(w):
            return (ref.dense(x, w, b, activation="tanh") ** 2).sum()

        g_ref = jax.grad(loss_ref)(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-4, atol=1e-5)
