"""L2 model correctness: parameter packing, PPO forward/update semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def init_params(seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(scale=0.05, size=(model.N_PARAMS,)).astype(np.float32))


class TestPacking:
    def test_param_count_matches_rust_convention(self):
        d = model.OBS_DIM
        assert d == 147 + 16  # grid features ++ mission tokens
        actor = d * 64 + 64 + 64 * 64 + 64 + 64 * 7 + 7
        critic = d * 64 + 64 + 64 * 64 + 64 + 64 + 1
        assert model.N_PARAMS == actor + critic

    def test_unpack_shapes(self):
        d = model.OBS_DIM
        actor, critic = model.unpack(init_params())
        assert [w.shape for w, _ in actor] == [(64, d), (64, 64), (7, 64)]
        assert [w.shape for w, _ in critic] == [(64, d), (64, 64), (1, 64)]
        assert all(b.shape == (w.shape[0],) for w, b in actor + critic)

    def test_unpack_roundtrip_offsets(self):
        # first weight of layer 2 of the actor sits right after W1,b1
        p = jnp.arange(model.N_PARAMS, dtype=jnp.float32)
        actor, _ = model.unpack(p)
        w2 = actor[1][0]
        assert float(w2[0, 0]) == model.OBS_DIM * 64 + 64


class TestPpoFwd:
    def test_shapes_and_determinism(self):
        p = init_params()
        obs = jnp.zeros((4, model.OBS_DIM), dtype=jnp.int32)
        logits, values = model.ppo_fwd(p, obs)
        assert logits.shape == (4, 7)
        assert values.shape == (4,)
        l2, v2 = model.ppo_fwd(p, obs)
        np.testing.assert_array_equal(np.asarray(logits), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(values), np.asarray(v2))

    def test_obs_affects_output(self):
        p = init_params()
        a = jnp.zeros((1, model.OBS_DIM), dtype=jnp.int32)
        b = jnp.full((1, model.OBS_DIM), 5, dtype=jnp.int32)
        la, _ = model.ppo_fwd(p, a)
        lb, _ = model.ppo_fwd(p, b)
        assert not np.allclose(np.asarray(la), np.asarray(lb))


class TestPpoUpdate:
    def _batch(self, mb=32, seed=0):
        rng = np.random.default_rng(seed)
        obs = jnp.asarray(rng.integers(0, 10, size=(mb, model.OBS_DIM), dtype=np.int32))
        actions = jnp.asarray(rng.integers(0, 7, size=(mb,), dtype=np.int32))
        adv = jnp.asarray(rng.normal(size=(mb,)).astype(np.float32))
        targets = jnp.asarray(rng.normal(size=(mb,)).astype(np.float32))
        return obs, actions, adv, targets

    def test_update_changes_params_and_reports_entropy(self):
        p = init_params()
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        obs, actions, adv, targets = self._batch()
        logits, _ = model.ppo_fwd(p, obs)
        logp_all = jax.nn.log_softmax(logits)
        old_logp = jnp.take_along_axis(logp_all, actions[:, None], axis=1)[:, 0]
        p2, m2, v2, pg, vl, ent = model.ppo_update(
            p, m, v, jnp.int32(1), obs, actions, old_logp, adv, targets
        )
        assert not np.allclose(np.asarray(p), np.asarray(p2))
        assert np.asarray(m2).any() and np.asarray(v2).any()
        # near-uniform init over 7 actions
        assert 1.0 < float(ent) < 2.0
        assert float(vl) > 0.0
        assert np.isfinite(float(pg))

    def test_repeated_updates_reduce_value_loss(self):
        p = init_params(1)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        obs, actions, adv, targets = self._batch(mb=64, seed=1)
        adv = jnp.zeros_like(adv)  # isolate the value head
        logits, _ = model.ppo_fwd(p, obs)
        old_logp = jnp.take_along_axis(
            jax.nn.log_softmax(logits), actions[:, None], axis=1
        )[:, 0]
        first_vl, last_vl = None, None
        for t in range(1, 121):
            p, m, v, _, vl, _ = model.ppo_update(
                p, m, v, jnp.int32(t), obs, actions, old_logp, adv, targets
            )
            if t == 1:
                first_vl = float(vl)
            last_vl = float(vl)
        assert last_vl < first_vl * 0.9, f"value loss {first_vl} -> {last_vl}"

    def test_adam_step_size_bounded_by_lr_and_clip(self):
        p = init_params(2)
        m = jnp.zeros_like(p)
        v = jnp.zeros_like(p)
        obs, actions, adv, targets = self._batch(mb=16, seed=2)
        logits, _ = model.ppo_fwd(p, obs)
        old_logp = jnp.take_along_axis(
            jax.nn.log_softmax(logits), actions[:, None], axis=1
        )[:, 0]
        p2, *_ = model.ppo_update(
            p, m, v, jnp.int32(1), obs, actions, old_logp, adv, targets
        )
        # Adam's first bias-corrected step is at most ~lr per coordinate.
        max_delta = float(jnp.abs(p2 - p).max())
        assert max_delta <= model.LR * 1.5
