"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for the Rust
runtime.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Artifacts (all under ``artifacts/``):

    model.hlo.txt                 Makefile stamp (= ppo_fwd at B=1)
    ppo_fwd_b{1,16}.hlo.txt       actor-critic forward
    ppo_update_b256.hlo.txt       fused PPO minibatch update
    env_step_empty8_b{1,16,1024}.hlo.txt   batched Empty-8x8 step
    obs_fp_b16.hlo.txt            standalone L1 observation kernel

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import obs


def to_hlo_text(fn, args):
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is load-bearing: the default printer elides big
    # literals as `constant({...})`, which the xla_extension 0.5.1 text
    # parser silently accepts and fills with a placeholder pattern —
    # corrupting any module that embeds, e.g., the static grid. (This, not
    # gather parsing, was the root cause of the index-looking observations
    # during bring-up; see EXPERIMENTS.md §Debug-log.)
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def write(path, text):
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def obs_kernel_entry(grid, pos, direction):
    return (obs.obs_first_person_batched(grid, pos, direction, h=8, w=8),)


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--fwd-sizes", default="1,16", help="comma-separated ppo_fwd batch sizes"
    )
    parser.add_argument(
        "--update-sizes", default="256", help="comma-separated ppo_update minibatch sizes"
    )
    parser.add_argument(
        "--env-sizes", default="1,16,1024", help="comma-separated env_step batch sizes"
    )
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for b in [int(x) for x in args.fwd_sizes.split(",") if x]:
        text = to_hlo_text(model.ppo_fwd, model.ppo_fwd_args(b))
        write(os.path.join(args.out_dir, f"ppo_fwd_b{b}.hlo.txt"), text)
        if b == 1:
            write(os.path.join(args.out_dir, "model.hlo.txt"), text)

    for mb in [int(x) for x in args.update_sizes.split(",") if x]:
        text = to_hlo_text(model.ppo_update, model.ppo_update_args(mb))
        write(os.path.join(args.out_dir, f"ppo_update_b{mb}.hlo.txt"), text)

    for b in [int(x) for x in args.env_sizes.split(",") if x]:
        text = to_hlo_text(model.env_step, model.env_step_args(b))
        write(os.path.join(args.out_dir, f"env_step_empty8_b{b}.hlo.txt"), text)

    # standalone L1 kernel artifact
    b = 16
    kernel_args = (
        jax.ShapeDtypeStruct((b, 8, 8, 3), jnp.int32),
        jax.ShapeDtypeStruct((b, 2), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
    )
    write(
        os.path.join(args.out_dir, f"obs_fp_b{b}.hlo.txt"),
        to_hlo_text(obs_kernel_entry, kernel_args),
    )


if __name__ == "__main__":
    main()
