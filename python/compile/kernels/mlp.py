"""Pallas kernels: fused dense layer (matmul + bias + activation) with a
custom VJP whose backward pass is also Pallas.

The PPO actor-critic is three dense layers; fusing matmul, bias add and the
nonlinearity into one kernel keeps the (B x OUT) intermediate in VMEM
instead of round-tripping HBM between XLA ops. ``pallas_call`` does not
support reverse-mode autodiff by itself, so ``dense`` carries a
``jax.custom_vjp``: the forward kernel saves the activated output, and the
backward pass computes dX/dW/db with a Pallas matmul kernel — so both
halves of ``jax.grad(ppo_update)`` lower through Layer 1.

TPU adaptation: weights are stored (OUT, IN) row-major — the Rust packing
convention — and the kernels compute ``x @ W^T`` with MXU-friendly operand
layouts; for the paper-scale shapes (B <= 2048, IN <= model.OBS_DIM = 163
— the 7x7x3 view plus the MISSION_TOKENS goal slab — OUT <= 64, f32)
a single block per operand fits VMEM (<= 1.2 MiB), so no inner grid is
needed. interpret=True throughout: CPU PJRT cannot execute Mosaic
custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w.T, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "tanh":
        y = jnp.tanh(y)
    elif activation == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _dense_impl(x, w, b, activation):
    bsz = x.shape[0]
    out = w.shape[0]
    kernel = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((bsz, out), jnp.float32),
        interpret=True,
    )(x, w, b)


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def matmul(a, b):
    """Plain Pallas matmul, used by the dense backward pass."""
    m, _ = a.shape
    _, n = b.shape
    return pl.pallas_call(
        _matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="tanh"):
    """Fused dense layer via Pallas, differentiable.

    x: f32[B, IN]; w: f32[OUT, IN]; b: f32[OUT] -> f32[B, OUT].
    activation: "tanh" | "relu" | "linear".
    """
    return _dense_impl(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    y = _dense_impl(x, w, b, activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    # activation derivative expressed through the saved output
    if activation == "tanh":
        dz = dy * (1.0 - y * y)
    elif activation == "relu":
        dz = dy * (y > 0.0).astype(dy.dtype)
    elif activation == "linear":
        dz = dy
    else:  # pragma: no cover - guarded by forward
        raise ValueError(f"unknown activation {activation}")
    dx = matmul(dz, w)  # [B,OUT] @ [OUT,IN] -> [B,IN]
    dw = matmul(dz.T, x)  # [OUT,B] @ [B,IN] -> [OUT,IN]
    db = dz.sum(axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
