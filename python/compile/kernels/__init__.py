"""Layer-1 Pallas kernels (build-time only).

Two kernels cover the paper's compute hot-spots:

* :mod:`.obs` — batched first-person observation extraction (the per-step
  gather that dominates a grid-world env step).
* :mod:`.mlp` — fused dense layer (matmul + bias + activation) used by the
  PPO actor-critic.

Both are lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so the interpret path is the correctness (and
portability) target; TPU-tiling choices are documented in DESIGN.md §Perf.
:mod:`.ref` holds the pure-jnp oracles every kernel is pytest-checked
against.
"""

from . import mlp, obs, ref  # noqa: F401
