"""Pallas kernel: batched first-person observation extraction.

The per-step hot-spot of a vectorised grid-world is the egocentric gather —
for every environment, crop a 7x7 window around the agent, rotate it into
the facing frame and mask out-of-bounds cells. On GPU the original NAVIX
does this with a vmapped gather; here it is a Pallas kernel with one grid
program per environment so the window extraction stays in VMEM.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the grid slab for one env
(8x8x3 i32 = 768 B) and the 7x7x3 output sit comfortably in VMEM; the
BlockSpec maps one environment per program instance, so HBM traffic is one
slab in, one window out — the same schedule a CUDA implementation would
express with one threadblock per env.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import VIEW


def _obs_kernel(grid_ref, pos_ref, dir_ref, o_ref, *, h, w):
    b = grid_ref.shape[0]
    grid = grid_ref[...]  # [B, H, W, 3]
    pos = pos_ref[...]  # [B, 2]
    d = dir_ref[...]  # [B]

    vr = jax.lax.broadcasted_iota(jnp.int32, (VIEW, VIEW), 0)[None]
    vc = jax.lax.broadcasted_iota(jnp.int32, (VIEW, VIEW), 1)[None]
    fo = (VIEW - 1) - vr  # [1,7,7]
    ro = vc - VIEW // 2

    # Direction vectors without gathers: dir 0=E,1=S,2=W,3=N.
    fr = jnp.where(d == 1, 1, jnp.where(d == 3, -1, 0))[:, None, None]
    fc = jnp.where(d == 0, 1, jnp.where(d == 2, -1, 0))[:, None, None]
    # rightward = clockwise next direction
    rr = fc
    rc = -fr

    wr = pos[:, 0, None, None] + fr * fo + rr * ro  # [B,7,7]
    wc = pos[:, 1, None, None] + fc * fo + rc * ro
    inb = (wr >= 0) & (wr < h) & (wc >= 0) & (wc < w)
    wr_c = jnp.clip(wr, 0, h - 1)
    wc_c = jnp.clip(wc, 0, w - 1)
    flat = grid.reshape(b, h * w, 3)
    # One-hot contraction instead of a gather: gathers are slow on the TPU
    # vector unit, while a (49 x HW) @ (HW x 3) one-hot batch-matmul maps
    # onto the MXU — and it sidesteps HLO-text round-trip bugs in the pinned
    # xla_extension 0.5.1 (see DESIGN.md §AOT-notes).
    idx = (wr_c * w + wc_c).reshape(b, VIEW * VIEW)  # [B,49]
    hw_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, h * w), 2)
    onehot = (idx[:, :, None] == hw_iota).astype(jnp.int32)  # [B,49,HW]
    vals = jnp.matmul(onehot, flat).reshape(b, VIEW, VIEW, 3)
    o_ref[...] = jnp.where(inb[:, :, :, None], vals, 0)


@functools.partial(jax.jit, static_argnames=("h", "w"))
def obs_first_person_batched(grid, pos, direction, *, h=8, w=8):
    """Batched first-person observation via the Pallas kernel.

    grid: i32[B, H, W, 3] symbolic grids (player excluded);
    pos: i32[B, 2]; direction: i32[B].
    Returns i32[B, 7, 7, 3].

    The whole batch is one kernel invocation (no pallas grid axis): the
    pinned xla_extension 0.5.1 mis-executes the while-loop lowering that
    interpret-mode `grid=(B,)` produces after an HLO-text round-trip, and a
    single invocation is also what the CPU backend wants. On real TPU the
    BlockSpec would tile the batch axis to bound VMEM (one 8x8x3 i32 slab is
    768 B, so ~1024 envs/block fit comfortably) — see DESIGN.md §Perf.
    """
    b = grid.shape[0]
    kernel = functools.partial(_obs_kernel, h=h, w=w)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((b, VIEW, VIEW, 3), jnp.int32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(grid, pos, direction)
