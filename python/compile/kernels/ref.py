"""Pure-jnp correctness oracles for the Pallas kernels.

These mirror the Rust engine's semantics exactly (see
``rust/src/systems/observations.rs``) and are the ground truth for the
pytest suite; the Pallas kernels must match them bit-for-bit.
"""

import jax.numpy as jnp

VIEW = 7
# MiniGrid direction vectors (dr, dc), dir 0 = east.
DIR_VEC = jnp.array([[0, 1], [1, 0], [0, -1], [-1, 0]], dtype=jnp.int32)


def first_person_coords(pos, direction):
    """World coordinates for each of the 7x7 egocentric view cells.

    pos: i32[2] (row, col); direction: i32[] in {0,1,2,3}.
    Returns (wr, wc): i32[7,7] world rows/cols (may be out of bounds).
    """
    vr = jnp.arange(VIEW, dtype=jnp.int32)[:, None]  # view row, 0 = far
    vc = jnp.arange(VIEW, dtype=jnp.int32)[None, :]
    fo = (VIEW - 1) - vr  # forward offset
    ro = vc - VIEW // 2  # rightward offset
    f = DIR_VEC[direction]  # (dr, dc) facing
    r = DIR_VEC[(direction + 1) % 4]  # rightward = clockwise next
    wr = pos[0] + f[0] * fo + r[0] * ro
    wc = pos[1] + f[1] * fo + r[1] * ro
    return wr, wc


def obs_first_person(grid, pos, direction):
    """First-person symbolic observation for open-room grids.

    grid: i32[H, W, 3] symbolic encoding *without* the player.
    Out-of-bounds view cells are unseen (0,0,0). Matches the Rust engine on
    environments without interior occluders (Empty family): with no interior
    walls, MiniGrid's visibility propagation lights every in-bounds cell.
    """
    h, w = grid.shape[0], grid.shape[1]
    wr, wc = first_person_coords(pos, direction)
    inb = (wr >= 0) & (wr < h) & (wc >= 0) & (wc < w)
    wr_c = jnp.clip(wr, 0, h - 1)
    wc_c = jnp.clip(wc, 0, w - 1)
    flat = grid.reshape(h * w, 3)
    vals = jnp.take(flat, wr_c * w + wc_c, axis=0)
    return jnp.where(inb[:, :, None], vals, 0).astype(jnp.int32)


def dense(x, w, b, activation="tanh"):
    """Reference dense layer: ``act(x @ w.T + b)``.

    x: f32[B, IN]; w: f32[OUT, IN] (row-major out×in, the Rust packing
    convention); b: f32[OUT].
    """
    y = x @ w.T + b[None, :]
    if activation == "tanh":
        return jnp.tanh(y)
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "linear":
        return y
    raise ValueError(f"unknown activation {activation}")
