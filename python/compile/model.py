"""Layer-2 JAX model: the fully-jitted NAVIX path.

Three computations, each lowered to a single HLO module by ``aot.py``:

* :func:`env_step` — the batched Empty-8x8 environment step (paper §3.2.2's
  "jit the whole loop" mode): intervention, reward, termination, timeout
  truncation and autoreset, with observations produced by the Layer-1
  Pallas kernel (:mod:`compile.kernels.obs`).
* :func:`ppo_fwd` — the PPO actor-critic forward over a flat parameter
  vector (Layer-1 fused dense kernels).
* :func:`ppo_update` — one *fused* PPO minibatch update: clipped-surrogate
  loss, ``jax.grad``, global-norm clipping and Adam, in one module, so the
  Rust coordinator trains with two executable calls per step and Python is
  never on the request path.

Parameter packing (shared bit-for-bit with
``rust/src/runtime/artifacts.rs::packing``): actor layers then critic
layers, each ``W (out x in, row-major) ++ b(out)``; dims actor
[OBS_DIM, 64, 64, 7], critic [OBS_DIM, 64, 64, 1], where
OBS_DIM = GRID_OBS_DIM (147) + MISSION_TOKENS (16) — the policy sees the
grid features concatenated with the tokenised mission block, so the XLA
path is goal-conditioned like the native trainers.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import mlp, obs

# --- fixed sizes (Empty-8x8, symbolic first-person 7x7x3) ---------------
H = W = 8
VIEW = 7
GRID_OBS_DIM = VIEW * VIEW * 3  # 147
# Tokenised mission block width — mirror of
# rust/src/core/mission.rs::MISSION_TOKENS (2 header + 2 clauses x 7).
MISSION_TOKENS = 16
# Policy input width: grid features ++ mission tokens. Every artifact is
# compiled against this derived constant, never a hard-coded 147.
OBS_DIM = GRID_OBS_DIM + MISSION_TOKENS
HIDDEN = 64
N_ACTIONS = 7
MAX_STEPS = 4 * H * W  # 256, the MiniGrid timeout for Empty-8x8
GOAL = (H - 2, W - 2)
START = (1, 1)

ACTOR_DIMS = (OBS_DIM, HIDDEN, HIDDEN, N_ACTIONS)
CRITIC_DIMS = (OBS_DIM, HIDDEN, HIDDEN, 1)

# --- PPO constants baked into the update artifact ------------------------
LR = 2.5e-4
CLIP_EPS = 0.2
VF_COEF = 0.5
ENT_COEF = 0.01
MAX_GRAD_NORM = 0.5
ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def param_count(dims):
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


N_PARAMS = param_count(ACTOR_DIMS) + param_count(CRITIC_DIMS)


def unpack(params):
    """Split the flat vector into per-layer (W, b) lists for both heads."""
    layers = []
    off = 0
    for dims in (ACTOR_DIMS, CRITIC_DIMS):
        net = []
        for nin, nout in zip(dims[:-1], dims[1:]):
            w = params[off : off + nin * nout].reshape(nout, nin)
            off += nin * nout
            b = params[off : off + nout]
            off += nout
            net.append((w, b))
        layers.append(net)
    return layers[0], layers[1]


# =========================================================================
# env_step: batched Empty-8x8 (intervention + reward/termination + autoreset)
# =========================================================================

def _static_grid():
    """Symbolic grid of Empty-8x8 without the player: walls, floor, goal."""
    import numpy as np

    g = np.zeros((H, W, 3), dtype=np.int32)
    g[:, :, 0] = 1  # empty
    g[0, :, 0] = 2
    g[-1, :, 0] = 2
    g[:, 0, 0] = 2
    g[:, -1, 0] = 2
    g[0, :, 1] = 5
    g[-1, :, 1] = 5
    g[:, 0, 1] = 5
    g[:, -1, 1] = 5  # grey walls
    g[GOAL[0], GOAL[1], 0] = 8  # goal tag
    g[GOAL[0], GOAL[1], 1] = 1  # green
    return jnp.asarray(g)


def _dir_vec(d):
    """Direction vectors without table gathers (see kernels/obs.py on why
    the AOT path avoids gather): dir 0=E,1=S,2=W,3=N -> (dr, dc)."""
    dr = jnp.where(d == 1, 1, jnp.where(d == 3, -1, 0))
    dc = jnp.where(d == 0, 1, jnp.where(d == 2, -1, 0))
    return dr, dc


def env_step(pos, direction, t, done_prev, action):
    """One batched Empty-8x8 step with autoreset.

    pos: i32[B,2]; direction: i32[B]; t: i32[B];
    done_prev: i32[B] (1 if the previous timestep ended the episode);
    action: i32[B] in [0,7).

    Returns (pos', dir', t', done', obs i32[B, OBS_DIM], reward f32[B],
    discount f32[B], is_first i32[B]). The obs rows are policy-width:
    grid features followed by the mission token block (all-zero for the
    mission-free Empty family), matching ``ObsBatch::copy_policy_row``.
    """
    b = pos.shape[0]

    # --- intervention (left/right/forward; other actions are no-ops in
    # Empty: nothing to pick up, drop, toggle).
    turn_left = action == 0
    turn_right = action == 1
    fwd = action == 2
    new_dir = jnp.where(
        turn_left, (direction + 3) % 4, jnp.where(turn_right, (direction + 1) % 4, direction)
    )
    dr, dc = _dir_vec(new_dir)
    fr = pos[:, 0] + dr * fwd.astype(jnp.int32)
    fc = pos[:, 1] + dc * fwd.astype(jnp.int32)
    # walkable: any interior cell (Empty has no interior obstacles)
    walkable = (fr >= 1) & (fr < H - 1) & (fc >= 1) & (fc < W - 1)
    nr = jnp.where(walkable, fr, pos[:, 0])
    nc = jnp.where(walkable, fc, pos[:, 1])

    new_t = t + 1
    goal = (nr == GOAL[0]) & (nc == GOAL[1])
    terminated = goal
    truncated = (~terminated) & (new_t >= MAX_STEPS)
    is_last = terminated | truncated

    reward = jnp.where(terminated, 1.0, 0.0).astype(jnp.float32)
    discount = jnp.where(terminated, 0.0, 1.0).astype(jnp.float32)

    # --- autoreset: if the *previous* step was terminal, this call resets
    # instead (paper's branch-free timestep protocol).
    resetting = done_prev.astype(bool)
    out_r = jnp.where(resetting, START[0], nr)
    out_c = jnp.where(resetting, START[1], nc)
    out_dir = jnp.where(resetting, 0, new_dir)
    out_t = jnp.where(resetting, 0, new_t)
    out_reward = jnp.where(resetting, 0.0, reward)
    out_discount = jnp.where(resetting, 1.0, discount)
    out_done = jnp.where(resetting, 0, is_last.astype(jnp.int32))
    is_first = resetting.astype(jnp.int32)

    # --- observation via the Layer-1 Pallas kernel, padded to policy
    # width with the (all-zero) mission token block.
    grid = jnp.broadcast_to(_static_grid()[None], (b, H, W, 3))
    o = obs.obs_first_person_batched(
        grid, jnp.stack([out_r, out_c], axis=1), out_dir, h=H, w=W
    ).reshape(b, GRID_OBS_DIM)
    o = jnp.concatenate([o, jnp.zeros((b, MISSION_TOKENS), dtype=o.dtype)], axis=1)

    return (
        jnp.stack([out_r, out_c], axis=1),
        out_dir,
        out_t,
        out_done,
        o,
        out_reward,
        out_discount,
        is_first,
    )


def env_reset(b):
    """Initial batched state (fixed start, like MiniGrid Empty)."""
    pos = jnp.tile(jnp.array([START], dtype=jnp.int32), (b, 1))
    direction = jnp.zeros(b, dtype=jnp.int32)
    t = jnp.zeros(b, dtype=jnp.int32)
    done = jnp.zeros(b, dtype=jnp.int32)
    grid = jnp.broadcast_to(_static_grid()[None], (b, H, W, 3))
    o = obs.obs_first_person_batched(grid, pos, direction, h=H, w=W).reshape(b, GRID_OBS_DIM)
    o = jnp.concatenate([o, jnp.zeros((b, MISSION_TOKENS), dtype=o.dtype)], axis=1)
    return pos, direction, t, done, o


# =========================================================================
# PPO actor-critic
# =========================================================================

def _net(layers, x, activation="tanh"):
    for i, (w, b) in enumerate(layers):
        act = activation if i + 1 < len(layers) else "linear"
        x = mlp.dense(x, w, b, activation=act)
    return x


def ppo_fwd(params, obs_i32):
    """Policy forward. params: f32[N_PARAMS]; obs: i32[B, OBS_DIM].

    Returns (logits f32[B, 7], values f32[B]).
    """
    x = obs_i32.astype(jnp.float32) / 10.0
    actor, critic = unpack(params)
    logits = _net(actor, x)
    values = _net(critic, x)[:, 0]
    return logits, values


def _ppo_loss(params, obs_i32, actions, old_logp, adv, targets):
    logits, values = ppo_fwd(params, obs_i32)
    logp_all = jax.nn.log_softmax(logits)
    probs = jax.nn.softmax(logits)
    # one-hot select, not take_along_axis: the pinned xla_extension 0.5.1
    # mis-parses call-wrapped gathers from HLO text (see kernels/obs.py)
    onehot = jax.nn.one_hot(actions, N_ACTIONS, dtype=logp_all.dtype)
    logp = (logp_all * onehot).sum(axis=1)
    ratio = jnp.exp(logp - old_logp)
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS)
    pg_loss = -jnp.minimum(ratio * adv, clipped * adv).mean()
    v_loss = 0.5 * ((values - targets) ** 2).mean()
    entropy = -(probs * logp_all).sum(axis=1).mean()
    loss = pg_loss + VF_COEF * v_loss - ENT_COEF * entropy
    return loss, (pg_loss, v_loss, entropy)


def ppo_update(params, m, v, t, obs_i32, actions, old_logp, adv, targets):
    """One fused PPO minibatch update (grad + clip + Adam).

    params/m/v: f32[N_PARAMS]; t: i32[] (Adam step, 1-based);
    obs: i32[MB, OBS_DIM]; actions: i32[MB]; old_logp/adv/targets: f32[MB].

    Returns (params', m', v', pg_loss, v_loss, entropy).
    """
    grad_fn = jax.grad(_ppo_loss, has_aux=True)
    grads, (pg_loss, v_loss, entropy) = grad_fn(
        params, obs_i32, actions, old_logp, adv, targets
    )
    # global-norm clip
    norm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, MAX_GRAD_NORM / jnp.maximum(norm, 1e-12))
    grads = grads * scale
    # Adam
    tf = t.astype(jnp.float32)
    new_m = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    new_v = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = new_m / (1.0 - ADAM_B1**tf)
    vhat = new_v / (1.0 - ADAM_B2**tf)
    new_params = params - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new_params, new_m, new_v, pg_loss, v_loss, entropy


# --- shape builders used by aot.py ---------------------------------------

def env_step_args(b):
    i32 = jnp.int32
    return (
        jax.ShapeDtypeStruct((b, 2), i32),
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((b,), i32),
        jax.ShapeDtypeStruct((b,), i32),
    )


def ppo_fwd_args(b):
    return (
        jax.ShapeDtypeStruct((N_PARAMS,), jnp.float32),
        jax.ShapeDtypeStruct((b, OBS_DIM), jnp.int32),
    )


def ppo_update_args(mb):
    f32, i32 = jnp.float32, jnp.int32
    return (
        jax.ShapeDtypeStruct((N_PARAMS,), f32),
        jax.ShapeDtypeStruct((N_PARAMS,), f32),
        jax.ShapeDtypeStruct((N_PARAMS,), f32),
        jax.ShapeDtypeStruct((), i32),
        jax.ShapeDtypeStruct((mb, OBS_DIM), i32),
        jax.ShapeDtypeStruct((mb,), i32),
        jax.ShapeDtypeStruct((mb,), f32),
        jax.ShapeDtypeStruct((mb,), f32),
        jax.ShapeDtypeStruct((mb,), f32),
    )
