//! Cross-engine parity: the batched SoA engine (NAVIX analog) and the
//! scalar OO baseline (MiniGrid analog) must produce identical episodes
//! for the same episode key and actions — the "drop-in replacement"
//! property the paper claims for NAVIX vs. MiniGrid (§3.2.1), enforced
//! here between our two engines so every speed comparison is
//! apples-to-apples.

use navix::baseline::{MiniGridEnv, SyncVectorEnv};
use navix::batch::BatchedEnv;
use navix::core::actions::Action;
use navix::core::timestep::StepType;
use navix::rng::{Key, Rng};

/// Deterministic-dynamics envs (the Dynamic-Obstacles family consumes the
/// per-env RNG stream differently across engines, so it is excluded from
/// exact trajectory parity and covered by invariant tests instead).
const PARITY_ENVS: [&str; 17] = [
    // BabyAI-style goal-conditioned families (typed Mission subsystem)
    "Navix-GoToObj-8x8-N3-v0",
    "Navix-PutNext-6x6-N2-v0",
    "Navix-Empty-5x5-v0",
    "Navix-Empty-8x8-v0",
    "Navix-Empty-Random-6x6",
    "Navix-DoorKey-5x5-v0",
    "Navix-DoorKey-Random-8x8",
    "Navix-LavaGapS5-v0",
    "Navix-SimpleCrossingS9N2-v0",
    "Navix-DistShift1-v0",
    "Navix-GoToDoor-5x5-v0",
    // RoomGrid / procedural-layout families
    "Navix-MultiRoom-N4-S5-v0",
    "Navix-Unlock-v0",
    "Navix-UnlockPickup-v0",
    "Navix-BlockedUnlockPickup-v0",
    "Navix-LockedRoom-v0",
    "Navix-Fetch-8x8-N3-v0",
];

#[test]
fn engines_agree_step_for_step_on_first_episode() {
    for id in PARITY_ENVS {
        let cfg = navix::make(id).unwrap();
        let mut fast = BatchedEnv::new(cfg.clone(), 1, Key::new(33));
        // BatchedEnv derives env 0's first episode key as
        // key.fold_in(global index = 0).fold_in(episode count = 1) — the
        // shard-invariant RNG contract; pin the baseline to it.
        let ep_key = Key::new(33).fold_in(0).fold_in(1);
        let mut slow = MiniGridEnv::new_with_episode_key(cfg, ep_key);

        // Reset observations must match exactly.
        assert_eq!(
            slow.gen_obs(),
            fast.obs.env_i32(1, 0),
            "{id}: reset observations diverged"
        );

        let mut rng = Rng::new(77);
        for step in 0..300 {
            let a = rng.below(7) as u8;
            fast.step(&[a]);
            if fast.timestep.step_type[0] == StepType::First {
                break; // autoreset: episode keys diverge beyond this point
            }
            let r = slow.step(Action::from_u8(a));
            assert_eq!(r.reward, fast.timestep.reward[0], "{id} step {step}: reward");
            assert_eq!(
                r.terminated || r.truncated,
                fast.timestep.step_type[0].is_last(),
                "{id} step {step}: episode end"
            );
            assert_eq!(
                r.obs,
                fast.obs.env_i32(1, 0),
                "{id} step {step}: observation diverged"
            );
            if r.terminated || r.truncated {
                break;
            }
        }
    }
}

#[test]
fn engines_agree_on_scripted_doorkey_solution() {
    // A full task solution (turn, fetch key, unlock, traverse, reach goal)
    // must earn the same rewards on both engines.
    let cfg = navix::make("Navix-DoorKey-5x5-v0").unwrap();
    let script = [
        Action::Right,
        Action::Forward,
        Action::Pickup,
        Action::Left,
        Action::Toggle,
        Action::Forward,
        Action::Forward,
        Action::Right,
        Action::Forward,
    ];
    let mut fast = BatchedEnv::new(cfg.clone(), 1, Key::new(5));
    let ep_key = Key::new(5).fold_in(0).fold_in(1);
    let mut slow = MiniGridEnv::new_with_episode_key(cfg, ep_key);
    for (i, &a) in script.iter().enumerate() {
        fast.step(&[a as u8]);
        let r = slow.step(a);
        assert_eq!(r.reward, fast.timestep.reward[0], "step {i}");
        assert_eq!(
            r.terminated,
            fast.timestep.step_type[0] == StepType::Terminated,
            "step {i}"
        );
    }
    assert_eq!(fast.timestep.step_type[0], StepType::Terminated);
    assert_eq!(fast.timestep.reward[0], 1.0);
}

#[test]
fn baseline_sync_vector_and_batched_have_same_obs_shape() {
    let cfg = navix::make("Navix-Empty-8x8-v0").unwrap();
    let mut venv = SyncVectorEnv::new(cfg.clone(), 4, Key::new(0));
    let obs = venv.reset();
    let fast = BatchedEnv::new(cfg, 4, Key::new(0));
    assert_eq!(obs[0].len(), fast.obs.stride(4));
}
