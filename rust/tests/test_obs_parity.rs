//! Overlay-grid vs naive-scan oracle parity, registry-wide.
//!
//! The packed cell-code overlay (PR 3) changes the *cost* of the
//! observation/step hot path, never its semantics. This suite pins that
//! bitwise over all 57 registry ids:
//!
//! 1. **State parity** — at every visited state, every spatial query
//!    (`door_at`/`key_at`/`ball_at`/`box_at`, `walkable`, `opaque`,
//!    `occupied_by_entity`, `free_for_placement`) and the per-cell encoding
//!    agree with their `*_scan` oracles on every cell. Since the stepper
//!    itself is built from these predicates, this also pins trajectory
//!    equivalence with the pre-overlay engine.
//! 2. **Observation parity** — the overlay writers produce bytes identical
//!    to the scan writers for all applicable i32 kinds over 2 episodes ×
//!    64 envs per id (and for the rgb kinds on the families that exercise
//!    doors, pickups and moving obstacles).
//! 3. **Dirty tiles** — the batched engine's incremental rgb buffer equals
//!    a from-scratch render at every step of rollouts featuring door
//!    toggles, pickups/drops and obstacle moves, autoresets included.

use navix::batch::{BatchedEnv, ObsData};
use navix::core::grid::Pos;
use navix::core::mission::MISSION_DIM;
use navix::core::state::EnvSlot;
use navix::rng::{Key, Rng};
use navix::systems::observations::{self, scan, ObsKind, ObsPath, ObsSpec};
use navix::systems::sprites::SpriteSheet;

const BATCH: usize = 64;
const EPISODES: u32 = 2;
/// Timeout clamp: keeps 2 random-walk episodes per id bounded (see the
/// registry conformance sweep for the same pattern).
const TIMEOUT_CAP: u32 = 80;

const I32_KINDS: [ObsKind; 4] = [
    ObsKind::Symbolic,
    ObsKind::SymbolicFirstPerson,
    ObsKind::Categorical,
    ObsKind::CategoricalFirstPerson,
];

/// Families whose dynamics exercise every rgb-relevant mutation: DoorKey
/// (door toggles + key pickup), Dynamic-Obstacles (obstacle moves), Fetch
/// (pickup/drop + wrong pickups), LockedRoom (many doors), GoToDoor
/// (border doors), BlockedUnlockPickup (ball drop + box pickup).
const RGB_IDS: [&str; 6] = [
    "Navix-DoorKey-8x8-v0",
    "Navix-Dynamic-Obstacles-6x6",
    "Navix-Fetch-5x5-N2-v0",
    "Navix-LockedRoom-v0",
    "Navix-GoToDoor-5x5-v0",
    "Navix-BlockedUnlockPickup-v0",
];

/// Every query and the cell encoding vs. the scan oracle, every cell.
fn assert_state_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let player = s.player();
    for r in 0..s.h as i32 {
        for c in 0..s.w as i32 {
            let p = Pos::new(r, c);
            let ctx = |what: &str| format!("{id} step {step} env {i} {what} at {p:?}");
            assert_eq!(
                observations::encode_cell(s, p, true),
                scan::encode_cell(s, p, true),
                "{}",
                ctx("encode_cell")
            );
            assert_eq!(s.door_at(p), s.door_at_scan(p), "{}", ctx("door_at"));
            assert_eq!(s.key_at(p), s.key_at_scan(p), "{}", ctx("key_at"));
            assert_eq!(s.ball_at(p), s.ball_at_scan(p), "{}", ctx("ball_at"));
            assert_eq!(s.box_at(p), s.box_at_scan(p), "{}", ctx("box_at"));
            assert_eq!(s.walkable(p), s.walkable_scan(p), "{}", ctx("walkable"));
            assert_eq!(s.opaque(p), s.opaque_scan(p), "{}", ctx("opaque"));
            assert_eq!(
                s.occupied_by_entity(p),
                s.occupied_by_entity_scan(p),
                "{}",
                ctx("occupied_by_entity")
            );
            assert_eq!(
                s.free_for_placement(p, player),
                s.free_for_placement_scan(p, player),
                "{}",
                ctx("free_for_placement")
            );
        }
    }
}

/// Overlay vs scan output for every applicable i32 kind, one env slot —
/// including the mission feature channel (typed encoder vs bit-level
/// oracle).
fn assert_i32_obs_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let spec = ObsSpec::new(ObsKind::SymbolicFirstPerson);
    let mut mission_fast = [0i32; MISSION_DIM];
    let mut mission_naive = [7i32; MISSION_DIM];
    spec.write_mission_path(ObsPath::Overlay, s, &mut mission_fast);
    spec.write_mission_path(ObsPath::NaiveScan, s, &mut mission_naive);
    assert_eq!(
        mission_fast, mission_naive,
        "{id} step {step} env {i}: mission features diverged from the bit-level oracle"
    );
    assert!(
        mission_fast.iter().all(|&x| x == 0 || x == 1),
        "{id} step {step} env {i}: mission features must be 0/1"
    );
    for kind in I32_KINDS {
        let spec = ObsSpec::new(kind);
        let n = spec.len(s.h, s.w);
        let mut fast = vec![0i32; n];
        let mut naive = vec![0i32; n];
        spec.write_i32_path(ObsPath::Overlay, s, &mut fast);
        spec.write_i32_path(ObsPath::NaiveScan, s, &mut naive);
        assert_eq!(
            fast,
            naive,
            "{id} step {step} env {i}: {} diverged from the scan oracle",
            kind.name()
        );
    }
}

/// Drive `id` through 2 episodes × `b` envs of random actions, calling
/// `check` on a rotating env slot every step and on every slot every 16th.
fn rollout_checking(id: &str, b: usize, check: impl Fn(&str, usize, usize, &EnvSlot<'_>)) {
    let mut cfg = navix::make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
    cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
    let max_steps = cfg.max_steps as usize;
    let mut env = BatchedEnv::new(cfg, b, Key::new(2027));
    for i in 0..b {
        check(id, 0, i, &env.state.slot(i));
    }
    let mut episodes = vec![0u32; b];
    let mut rng = Rng::new(17);
    // [B × A] action matrix: one row per agent (A=1 for classic ids).
    let n_agents = env.a;
    let mut actions = vec![0u8; env.policy_rows()];
    let step_budget = (EPISODES as usize + 1) * (max_steps + 2);
    let mut steps = 0;
    while episodes.iter().any(|&e| e < EPISODES) && steps < step_budget {
        for a in actions.iter_mut() {
            *a = rng.below(7) as u8;
        }
        env.step(&actions);
        steps += 1;
        check(id, steps, steps % b, &env.state.slot(steps % b));
        if steps % 16 == 0 {
            for i in 0..b {
                check(id, steps, i, &env.state.slot(i));
            }
        }
        for i in 0..b {
            // Episodes end per slot; agent 0's row carries the step type.
            if env.timestep.step_type[i * n_agents].is_last() {
                episodes[i] += 1;
            }
        }
    }
}

#[test]
fn every_id_state_queries_match_scan_oracle() {
    for id in navix::list_envs() {
        rollout_checking(id, 8, assert_state_parity);
    }
}

#[test]
fn every_id_i32_observations_match_scan_oracle() {
    for id in navix::list_envs() {
        rollout_checking(id, BATCH, assert_i32_obs_parity);
    }
}

/// Overlay vs scan output for both rgb kinds, one env slot.
fn assert_rgb_obs_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let sheet = SpriteSheet::shared();
    for kind in [ObsKind::Rgb, ObsKind::RgbFirstPerson] {
        let spec = ObsSpec::new(kind);
        let n = spec.len(s.h, s.w);
        let mut fast = vec![0u8; n];
        let mut naive = vec![0u8; n];
        spec.write_u8_path(ObsPath::Overlay, s, &sheet, &mut fast);
        spec.write_u8_path(ObsPath::NaiveScan, s, &sheet, &mut naive);
        assert_eq!(
            fast,
            naive,
            "{id} step {step} env {i}: {} diverged from the scan oracle",
            kind.name()
        );
    }
}

#[test]
fn rgb_observations_match_scan_oracle() {
    for id in RGB_IDS {
        rollout_checking(id, 4, assert_rgb_obs_parity);
    }
}

#[test]
fn batched_engine_dirty_tiles_match_from_scratch_renders() {
    // Random rollouts over the door/pickup/obstacle families with the
    // engine's Rgb observation: the incrementally-maintained buffer must
    // equal a from-scratch scan render after every step (door toggles,
    // pickups, drops, obstacle moves and autoresets included).
    let sheet = SpriteSheet::shared();
    for id in RGB_IDS {
        let b = 4;
        let mut cfg = navix::make(id).unwrap();
        cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
        let stride = ObsSpec::new(ObsKind::Rgb).len(cfg.h, cfg.w);
        let mut env = BatchedEnv::new(cfg.with_observation(ObsKind::Rgb), b, Key::new(99));
        let mut scratch = vec![0u8; stride];
        let mut rng = Rng::new(5);
        let mut actions = vec![0u8; b];
        for step in 0..120 {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
            for i in 0..b {
                scan::rgb(&env.state.slot(i), &sheet, &mut scratch);
                match &env.obs.data {
                    ObsData::U8(v) => {
                        assert_eq!(
                            &v[i * stride..(i + 1) * stride],
                            &scratch[..],
                            "{id} step {step} env {i}: dirty-tile buffer diverged"
                        );
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}
