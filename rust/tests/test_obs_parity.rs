//! Overlay-grid vs naive-scan oracle parity, registry-wide.
//!
//! The packed cell-code overlay (PR 3) changes the *cost* of the
//! observation/step hot path, never its semantics. This suite pins that
//! bitwise over every registry id:
//!
//! 1. **State parity** — at every visited state, every spatial query
//!    (`door_at`/`key_at`/`ball_at`/`box_at`, `walkable`, `opaque`,
//!    `occupied_by_entity`, `free_for_placement`) and the per-cell encoding
//!    agree with their `*_scan` oracles on every cell. Since the stepper
//!    itself is built from these predicates, this also pins trajectory
//!    equivalence with the pre-overlay engine.
//! 2. **Observation parity** — the overlay writers produce bytes identical
//!    to the scan writers for all applicable i32 kinds over 2 episodes ×
//!    64 envs per id (and for the rgb kinds on the families that exercise
//!    doors, pickups and moving obstacles).
//! 3. **Dirty tiles** — the batched engine's incremental rgb buffer equals
//!    a from-scratch render at every step of rollouts featuring door
//!    toggles, pickups/drops and obstacle moves, autoresets included.
//! 4. **Kernel paths** — the SIMD featurisers are swept under every forced
//!    [`KernelPath`] (scalar / sse2 / avx2; unsupported paths skip with a
//!    notice) and pinned bitwise against both the scalar overlay path and
//!    the scan oracles: registry-wide, on hand-built odd-shaped grids
//!    whose cell count is not a lane multiple (tail handling), and through
//!    the batched engine end to end — first-person frames and the mission
//!    block included.

use navix::batch::{BatchedEnv, ObsData};
use navix::core::components::{Color, Direction, DoorState};
use navix::core::entities::{CellType, Tag};
use navix::core::grid::Pos;
use navix::core::mission::{Mission, MISSION_TOKENS};
use navix::core::state::{BatchedState, Caps, EnvSlot};
use navix::rng::{Key, Rng};
use navix::simd::KernelPath;
use navix::systems::observations::{self, scan, ObsKind, ObsPath, ObsRoute, ObsSpec};
use navix::systems::sprites::SpriteSheet;

const BATCH: usize = 64;
const EPISODES: u32 = 2;
/// Timeout clamp: keeps 2 random-walk episodes per id bounded (see the
/// registry conformance sweep for the same pattern).
const TIMEOUT_CAP: u32 = 80;

const I32_KINDS: [ObsKind; 4] = [
    ObsKind::Symbolic,
    ObsKind::SymbolicFirstPerson,
    ObsKind::Categorical,
    ObsKind::CategoricalFirstPerson,
];

/// Families whose dynamics exercise every rgb-relevant mutation: DoorKey
/// (door toggles + key pickup), Dynamic-Obstacles (obstacle moves), Fetch
/// (pickup/drop + wrong pickups), LockedRoom (many doors), GoToDoor
/// (border doors), BlockedUnlockPickup (ball drop + box pickup).
const RGB_IDS: [&str; 6] = [
    "Navix-DoorKey-8x8-v0",
    "Navix-Dynamic-Obstacles-6x6",
    "Navix-Fetch-5x5-N2-v0",
    "Navix-LockedRoom-v0",
    "Navix-GoToDoor-5x5-v0",
    "Navix-BlockedUnlockPickup-v0",
];

/// Every query and the cell encoding vs. the scan oracle, every cell.
fn assert_state_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let player = s.player();
    for r in 0..s.h as i32 {
        for c in 0..s.w as i32 {
            let p = Pos::new(r, c);
            let ctx = |what: &str| format!("{id} step {step} env {i} {what} at {p:?}");
            assert_eq!(
                observations::encode_cell(s, p, true),
                scan::encode_cell(s, p, true),
                "{}",
                ctx("encode_cell")
            );
            assert_eq!(s.door_at(p), s.door_at_scan(p), "{}", ctx("door_at"));
            assert_eq!(s.key_at(p), s.key_at_scan(p), "{}", ctx("key_at"));
            assert_eq!(s.ball_at(p), s.ball_at_scan(p), "{}", ctx("ball_at"));
            assert_eq!(s.box_at(p), s.box_at_scan(p), "{}", ctx("box_at"));
            assert_eq!(s.walkable(p), s.walkable_scan(p), "{}", ctx("walkable"));
            assert_eq!(s.opaque(p), s.opaque_scan(p), "{}", ctx("opaque"));
            assert_eq!(
                s.occupied_by_entity(p),
                s.occupied_by_entity_scan(p),
                "{}",
                ctx("occupied_by_entity")
            );
            assert_eq!(
                s.free_for_placement(p, player),
                s.free_for_placement_scan(p, player),
                "{}",
                ctx("free_for_placement")
            );
        }
    }
}

/// Overlay vs scan output for every applicable i32 kind, one env slot —
/// including the mission feature channel (typed encoder vs bit-level
/// oracle).
fn assert_i32_obs_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let spec = ObsSpec::new(ObsKind::SymbolicFirstPerson);
    let mut mission_fast = [0i32; MISSION_TOKENS];
    let mut mission_naive = [7i32; MISSION_TOKENS];
    spec.write_mission_path(ObsPath::Overlay, s, &mut mission_fast);
    spec.write_mission_path(ObsPath::NaiveScan, s, &mut mission_naive);
    assert_eq!(
        mission_fast, mission_naive,
        "{id} step {step} env {i}: mission features diverged from the bit-level oracle"
    );
    assert!(
        mission_fast.iter().all(|&x| (0..=6).contains(&x)),
        "{id} step {step} env {i}: mission tokens must stay in the small-integer vocabulary"
    );
    for kind in I32_KINDS {
        let spec = ObsSpec::new(kind);
        let n = spec.len(s.h, s.w);
        let mut fast = vec![0i32; n];
        let mut naive = vec![0i32; n];
        spec.write_i32_path(ObsPath::Overlay, s, &mut fast);
        spec.write_i32_path(ObsPath::NaiveScan, s, &mut naive);
        assert_eq!(
            fast,
            naive,
            "{id} step {step} env {i}: {} diverged from the scan oracle",
            kind.name()
        );
    }
}

/// Drive `id` through 2 episodes × `b` envs of random actions, calling
/// `check` on a rotating env slot every step and on every slot every 16th.
fn rollout_checking(id: &str, b: usize, check: impl Fn(&str, usize, usize, &EnvSlot<'_>)) {
    let mut cfg = navix::make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
    cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
    let max_steps = cfg.max_steps as usize;
    let mut env = BatchedEnv::new(cfg, b, Key::new(2027));
    for i in 0..b {
        check(id, 0, i, &env.state.slot(i));
    }
    let mut episodes = vec![0u32; b];
    let mut rng = Rng::new(17);
    // [B × A] action matrix: one row per agent (A=1 for classic ids).
    let n_agents = env.a;
    let mut actions = vec![0u8; env.policy_rows()];
    let step_budget = (EPISODES as usize + 1) * (max_steps + 2);
    let mut steps = 0;
    while episodes.iter().any(|&e| e < EPISODES) && steps < step_budget {
        for a in actions.iter_mut() {
            *a = rng.below(7) as u8;
        }
        env.step(&actions);
        steps += 1;
        check(id, steps, steps % b, &env.state.slot(steps % b));
        if steps % 16 == 0 {
            for i in 0..b {
                check(id, steps, i, &env.state.slot(i));
            }
        }
        for i in 0..b {
            // Episodes end per slot; agent 0's row carries the step type.
            if env.timestep.step_type[i * n_agents].is_last() {
                episodes[i] += 1;
            }
        }
    }
}

#[test]
fn every_id_state_queries_match_scan_oracle() {
    for id in navix::list_envs() {
        rollout_checking(id, 8, assert_state_parity);
    }
}

#[test]
fn every_id_i32_observations_match_scan_oracle() {
    for id in navix::list_envs() {
        rollout_checking(id, BATCH, assert_i32_obs_parity);
    }
}

/// Overlay vs scan output for both rgb kinds, one env slot.
fn assert_rgb_obs_parity(id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let sheet = SpriteSheet::shared();
    for kind in [ObsKind::Rgb, ObsKind::RgbFirstPerson] {
        let spec = ObsSpec::new(kind);
        let n = spec.len(s.h, s.w);
        let mut fast = vec![0u8; n];
        let mut naive = vec![0u8; n];
        spec.write_u8_path(ObsPath::Overlay, s, &sheet, &mut fast);
        spec.write_u8_path(ObsPath::NaiveScan, s, &sheet, &mut naive);
        assert_eq!(
            fast,
            naive,
            "{id} step {step} env {i}: {} diverged from the scan oracle",
            kind.name()
        );
    }
}

#[test]
fn rgb_observations_match_scan_oracle() {
    for id in RGB_IDS {
        rollout_checking(id, 4, assert_rgb_obs_parity);
    }
}

/// One forced kernel path vs the scalar overlay path vs the scan oracle:
/// every applicable i32 kind plus the mission block, one env slot. Both
/// comparisons are bitwise — the vector featurisers never change what is
/// written, only how many cells move per iteration.
fn assert_forced_path_parity(kp: KernelPath, id: &str, step: usize, i: usize, s: &EnvSlot<'_>) {
    let forced = ObsRoute::Overlay(kp);
    let scalar = ObsRoute::Overlay(KernelPath::Scalar);
    let spec = ObsSpec::new(ObsKind::SymbolicFirstPerson);
    let mut m_forced = [0i32; MISSION_TOKENS];
    let mut m_scalar = [7i32; MISSION_TOKENS];
    spec.write_mission_route(forced, s, &mut m_forced);
    spec.write_mission_route(scalar, s, &mut m_scalar);
    assert_eq!(
        m_forced,
        m_scalar,
        "{id} step {step} env {i}: mission features diverged on {}",
        kp.name()
    );
    for kind in I32_KINDS {
        let spec = ObsSpec::new(kind);
        let n = spec.len(s.h, s.w);
        let mut got = vec![0i32; n];
        let mut want_scalar = vec![0i32; n];
        let mut want_scan = vec![0i32; n];
        spec.write_i32_route(forced, s, &mut got);
        spec.write_i32_route(scalar, s, &mut want_scalar);
        spec.write_i32_route(ObsRoute::Scan, s, &mut want_scan);
        assert_eq!(
            got,
            want_scalar,
            "{id} step {step} env {i}: {} diverged from the scalar path on {}",
            kind.name(),
            kp.name()
        );
        assert_eq!(
            got,
            want_scan,
            "{id} step {step} env {i}: {} diverged from the scan oracle on {}",
            kind.name(),
            kp.name()
        );
    }
}

#[test]
fn forced_kernel_paths_match_the_oracles_across_the_registry() {
    for kp in KernelPath::ALL {
        if !kp.supported() {
            println!("skipping kernel path {}: not supported by this CPU", kp.name());
            continue;
        }
        for id in navix::list_envs() {
            rollout_checking(id, 4, |id, step, i, s| {
                assert_forced_path_parity(kp, id, step, i, s)
            });
        }
    }
}

/// Hand-built grids whose cell count is not a multiple of any vector
/// width — 9, 25, 42, 63 and 65 cells, plus 64 as the exact-fit control —
/// so every kernel's scalar tail is exercised on every entity kind.
#[test]
fn odd_shape_grids_sweep_every_kernel_tail() {
    const SHAPES: [(usize, usize); 6] = [(3, 3), (5, 5), (6, 7), (7, 9), (8, 8), (5, 13)];
    for (h, w) in SHAPES {
        let caps = Caps { doors: 1, keys: 1, balls: 1, boxes: 1 };
        let mut st = BatchedState::new(1, h, w, caps);
        {
            let mut s = st.slot_mut(0);
            s.fill_room();
            s.set_cell(Pos::new(h as i32 - 2, w as i32 - 2), CellType::Goal, Color::Green);
            s.place_player(Pos::new(1, 1), Direction::East);
            if h >= 5 && w >= 5 {
                // Distinct interior cells for every entity kind, so each
                // cell-code branch crosses the vector/tail boundary at
                // least once across the shape sweep.
                s.set_cell(Pos::new(1, w as i32 - 2), CellType::Lava, Color::Red);
                s.add_door(Pos::new(2, 1), Color::Red, DoorState::Closed);
                s.add_key(Pos::new(2, 2), Color::Yellow);
                s.add_ball(Pos::new(3, 1), Color::Blue);
                s.add_box(Pos::new(2, w as i32 - 2), Color::Purple);
                s.set_mission(Mission::go_to(Tag::DOOR, Color::Red));
            }
        }
        let s = st.slot(0);
        let id = format!("hand-built-{h}x{w}");
        for kp in KernelPath::ALL {
            if kp.supported() {
                assert_forced_path_parity(kp, &id, 0, 0, &s);
            }
        }
    }
}

/// The forced kernel paths through the batched engine end to end: engines
/// differing only in `set_obs_route` must publish identical obs and
/// mission buffers at every step of a shared random rollout, autoresets
/// included.
#[test]
fn batched_engine_obs_identical_across_forced_kernel_paths() {
    let ids = ["Navix-DoorKey-8x8-v0", "Navix-Dynamic-Obstacles-6x6", "Navix-GoToObj-8x8-N3-v0"];
    for id in ids {
        for kind in [ObsKind::Symbolic, ObsKind::Categorical] {
            let b = 8;
            let mut cfg = navix::make(id).unwrap().with_observation(kind);
            cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
            let make = |route: ObsRoute| {
                let mut env = BatchedEnv::new(cfg.clone(), b, Key::new(31));
                env.set_obs_route(route);
                env
            };
            let mut oracle = make(ObsRoute::Scan);
            let mut engines: Vec<(KernelPath, BatchedEnv)> = KernelPath::ALL
                .into_iter()
                .filter(|kp| kp.supported())
                .map(|kp| (kp, make(ObsRoute::Overlay(kp))))
                .collect();
            let mut rng = Rng::new(23);
            let mut actions = vec![0u8; oracle.policy_rows()];
            for step in 0..60 {
                for a in actions.iter_mut() {
                    *a = rng.below(7) as u8;
                }
                oracle.step(&actions);
                let want = match &oracle.obs.data {
                    ObsData::I32(v) => v.clone(),
                    _ => unreachable!(),
                };
                for (kp, env) in engines.iter_mut() {
                    env.step(&actions);
                    let got = match &env.obs.data {
                        ObsData::I32(v) => v,
                        _ => unreachable!(),
                    };
                    assert_eq!(
                        got,
                        &want,
                        "{id} {} step {step}: engine obs diverged on {}",
                        kind.name(),
                        kp.name()
                    );
                    assert_eq!(
                        env.obs.mission,
                        oracle.obs.mission,
                        "{id} {} step {step}: engine mission diverged on {}",
                        kind.name(),
                        kp.name()
                    );
                }
            }
        }
    }
}

#[test]
fn batched_engine_dirty_tiles_match_from_scratch_renders() {
    // Random rollouts over the door/pickup/obstacle families with the
    // engine's Rgb observation: the incrementally-maintained buffer must
    // equal a from-scratch scan render after every step (door toggles,
    // pickups, drops, obstacle moves and autoresets included).
    let sheet = SpriteSheet::shared();
    for id in RGB_IDS {
        let b = 4;
        let mut cfg = navix::make(id).unwrap();
        cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
        let stride = ObsSpec::new(ObsKind::Rgb).len(cfg.h, cfg.w);
        let mut env = BatchedEnv::new(cfg.with_observation(ObsKind::Rgb), b, Key::new(99));
        let mut scratch = vec![0u8; stride];
        let mut rng = Rng::new(5);
        let mut actions = vec![0u8; b];
        for step in 0..120 {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
            for i in 0..b {
                scan::rgb(&env.state.slot(i), &sheet, &mut scratch);
                match &env.obs.data {
                    ObsData::U8(v) => {
                        assert_eq!(
                            &v[i * stride..(i + 1) * stride],
                            &scratch[..],
                            "{id} step {step} env {i}: dirty-tile buffer diverged"
                        );
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}
