//! Checkpoint/restore pins.
//!
//! 1. **Registry-wide bitwise round-trip**: for every registry id and
//!    agent count A ∈ {1, 2}, stepping after `save_checkpoint` →
//!    `restore_checkpoint` reproduces the original continuation bit for
//!    bit (timesteps, observations, mission features), and every slot's
//!    [`SlotSnapshot`] survives the byte codec exactly.
//! 2. **Cross-engine portability**: a checkpoint taken mid-episode on the
//!    single-threaded engine resumes bitwise-identically on the sharded
//!    and pipelined engines (slots are global; topology is irrelevant).
//! 3. **PPO checkpoint/resume**: saving engine + agent + tracker mid-run
//!    and resuming on a fresh engine reproduces the exact training curve
//!    on all three engines.

use navix::agents::{Ppo, PpoConfig, ReturnTracker};
use navix::batch::{BatchStepper, BatchedEnv, ObsBatch, ObsData, PipelinedEnv, ShardedEnv};
use navix::core::snapshot::SlotSnapshot;
use navix::envs::registry::{list_envs, make};
use navix::rng::{Key, Rng};

fn random_actions(rng: &mut Rng, rows: usize) -> Vec<u8> {
    (0..rows).map(|_| rng.below(7) as u8).collect()
}

fn assert_obs_equal(ctx: &str, a: &ObsBatch, b: &ObsBatch) {
    assert_eq!(a.mission, b.mission, "{ctx}: mission features diverged");
    match (&a.data, &b.data) {
        (ObsData::I32(x), ObsData::I32(y)) => assert_eq!(x, y, "{ctx}: i32 obs diverged"),
        (ObsData::U8(x), ObsData::U8(y)) => assert_eq!(x, y, "{ctx}: u8 obs diverged"),
        _ => panic!("{ctx}: obs dtypes diverged"),
    }
}

#[test]
fn snapshot_round_trip_is_bitwise_for_every_registry_env() {
    const B: usize = 4;
    for id in list_envs() {
        for agents in [1usize, 2] {
            let ctx = format!("{id} A={agents}");
            let cfg = make(id).unwrap().with_agents(agents);
            let rows = B * agents;
            let mut env = BatchedEnv::new(cfg, B, Key::new(11));
            let mut rng = Rng::new(0xC0FFEE ^ agents as u64);
            for _ in 0..12 {
                env.step(&random_actions(&mut rng, rows));
            }

            // Per-slot byte codec: capture → bytes → parse is identity.
            for i in 0..B {
                let snap = SlotSnapshot::capture(&env.state, i);
                let back = SlotSnapshot::from_bytes(&snap.to_bytes())
                    .unwrap_or_else(|e| panic!("{ctx} slot {i}: codec rejected bytes: {e}"));
                assert_eq!(snap, back, "{ctx} slot {i}: byte codec not bitwise");
            }

            let ck = env.save_checkpoint();
            // Record the true continuation…
            let plan: Vec<Vec<u8>> =
                (0..10).map(|_| random_actions(&mut rng, rows)).collect();
            let mut expect = Vec::new();
            for actions in &plan {
                env.step(actions);
                expect.push((env.timestep.clone(), env.obs.clone()));
            }
            // …then rewind and replay it.
            env.restore_checkpoint(&ck);
            for (t, actions) in plan.iter().enumerate() {
                env.step(actions);
                let (ts, obs) = &expect[t];
                assert_eq!(&env.timestep.t, &ts.t, "{ctx} step {t}: t diverged");
                assert_eq!(&env.timestep.reward, &ts.reward, "{ctx} step {t}: reward");
                assert_eq!(&env.timestep.discount, &ts.discount, "{ctx} step {t}: discount");
                assert_eq!(
                    &env.timestep.step_type, &ts.step_type,
                    "{ctx} step {t}: step_type"
                );
                assert_eq!(
                    &env.timestep.episodic_return, &ts.episodic_return,
                    "{ctx} step {t}: episodic_return"
                );
                assert_obs_equal(&format!("{ctx} step {t}"), &env.obs, obs);
            }
        }
    }
}

#[test]
fn checkpoint_is_portable_across_engines() {
    let cfg = make("Navix-DoorKey-Random-8x8").unwrap();
    let mut src = BatchedEnv::new(cfg.clone(), 8, Key::new(4));
    let mut rng = Rng::new(99);
    // 37 steps: safely mid-episode in several slots.
    for _ in 0..37 {
        src.step(&random_actions(&mut rng, 8));
    }
    let ck = src.save_checkpoint();
    let plan: Vec<Vec<u8>> = (0..30).map(|_| random_actions(&mut rng, 8)).collect();
    let mut expect = Vec::new();
    for actions in &plan {
        src.step(actions);
        expect.push((src.timestep.clone(), src.obs.clone()));
    }

    let sharded = Box::new(ShardedEnv::new(cfg.clone(), 8, 3, 2, Key::new(4)));
    let pipelined =
        Box::new(PipelinedEnv::over_batched(BatchedEnv::new(cfg, 8, Key::new(4))));
    for (name, mut env) in
        [("sharded", sharded as Box<dyn BatchStepper>), ("pipelined", pipelined)]
    {
        env.restore_checkpoint(&ck);
        for (t, actions) in plan.iter().enumerate() {
            env.step(actions);
            let (ts, obs) = &expect[t];
            assert_eq!(&env.timestep().reward, &ts.reward, "{name} step {t}: reward");
            assert_eq!(
                &env.timestep().step_type, &ts.step_type,
                "{name} step {t}: step_type"
            );
            assert_eq!(&env.timestep().t, &ts.t, "{name} step {t}: t");
            assert_obs_equal(&format!("{name} step {t}"), env.obs(), obs);
        }
    }
}

/// Train a few PPO iterations, checkpoint (engine + agent + tracker),
/// train on, then restore into a fresh engine and assert the continuation
/// reproduces the same curve bit for bit.
fn ppo_resume_reproduces_curve(make_engine: &dyn Fn() -> Box<dyn BatchStepper>) {
    let d = navix::agents::OBS_DIM;
    let pcfg = PpoConfig { rollout_len: 8, minibatches: 2, epochs: 2, ..Default::default() };
    let mut env = make_engine();
    let b = env.policy_rows();
    let mut ppo = Ppo::new(pcfg, d, 7, 13);
    let mut ro = navix::agents::ppo::Rollout::new(8, b, d);
    let mut tracker = ReturnTracker::new(16);
    for _ in 0..2 {
        ppo.collect_rollout(&mut *env, &mut ro, &mut tracker);
        ppo.update(&ro);
    }

    let engine_ck = env.save_checkpoint();
    let agent_ck = ppo.save_state();
    let tracker_ck = tracker.clone();

    let mut curve_a = Vec::new();
    for _ in 0..3 {
        ppo.collect_rollout(&mut *env, &mut ro, &mut tracker);
        let m = ppo.update(&ro);
        curve_a.push((tracker.mean(), m));
    }
    let params_a = (ppo.actor.params.clone(), ppo.critic.params.clone());

    let mut env = make_engine();
    env.restore_checkpoint(&engine_ck);
    ppo.restore_state(&agent_ck);
    let mut tracker = tracker_ck;
    let mut ro = navix::agents::ppo::Rollout::new(8, b, d);
    let mut curve_b = Vec::new();
    for _ in 0..3 {
        ppo.collect_rollout(&mut *env, &mut ro, &mut tracker);
        let m = ppo.update(&ro);
        curve_b.push((tracker.mean(), m));
    }
    assert_eq!(curve_a, curve_b, "resumed curve must be bit-identical");
    assert_eq!(params_a.0, ppo.actor.params, "actor params must match after resume");
    assert_eq!(params_a.1, ppo.critic.params, "critic params must match after resume");
}

#[test]
fn ppo_checkpoint_resume_is_exact_on_the_batched_engine() {
    let cfg = make("Navix-Empty-Random-6x6").unwrap();
    ppo_resume_reproduces_curve(&move || {
        Box::new(BatchedEnv::new(cfg.clone(), 6, Key::new(2)))
    });
}

#[test]
fn ppo_checkpoint_resume_is_exact_on_the_sharded_engine() {
    let cfg = make("Navix-Empty-Random-6x6").unwrap();
    ppo_resume_reproduces_curve(&move || {
        Box::new(ShardedEnv::new(cfg.clone(), 6, 3, 2, Key::new(2)))
    });
}

#[test]
fn ppo_checkpoint_resume_is_exact_on_the_pipelined_engine() {
    let cfg = make("Navix-Empty-Random-6x6").unwrap();
    ppo_resume_reproduces_curve(&move || {
        Box::new(PipelinedEnv::over_batched(BatchedEnv::new(cfg.clone(), 6, Key::new(2))))
    });
}
