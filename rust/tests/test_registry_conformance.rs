//! Registry-wide conformance sweep: every registered environment id must
//! reset and step a 64-env batch through two full episodes without a single
//! panic, produce observations inside the spec's bounds, generate
//! BFS-solvable layouts wherever a goal exists, and step bitwise-identically
//! under sharded execution (`--shards 3`) — so a new env family cannot land
//! unregistered, panicking, unsolvable, or shard-variant.
//!
//! The sweep runs in CI as a dedicated debug-build job; keep per-id work
//! bounded (episodes are clamped via the timeout below).

use navix::batch::{BatchedEnv, ObsBatch, ObsData, ShardedEnv};
use navix::envs::solvability::{goal_pos, reachable};
use navix::rng::{Key, Rng};

const BATCH: usize = 64;
const EPISODES: u32 = 2;
/// Timeout clamp for the sweep: truncation still ends episodes, so two
/// episodes complete within `2 * (TIMEOUT_CAP + 1)` steps even for the
/// multi-thousand-step families (LockedRoom's T is 3610).
const TIMEOUT_CAP: u32 = 250;

/// Assert every observation value is inside the symbolic spec's bounds:
/// channel 0 is a MiniGrid object tag (0..=10), channel 1 a colour (0..=5),
/// channel 2 a door state or agent direction (0..=3).
fn check_obs_bounds(id: &str, obs: &ObsBatch, b: usize, step: usize) {
    // The mission channel is the tokenised grammar block: every token is a
    // small enum index (verb/kind/colour codes are shifted by one so 0 can
    // mean "absent"), bounded by the token vocabulary.
    for (k, &x) in obs.mission.iter().enumerate() {
        assert!(
            (0..=6).contains(&x),
            "{id} step {step}: mission[{k}] = {x} outside the token vocabulary 0..=6"
        );
    }
    match &obs.data {
        ObsData::I32(v) => {
            assert_eq!(v.len() % (b * 3), 0, "{id}: obs not channel-triplets");
            for (k, &x) in v.iter().enumerate() {
                let (lo, hi) = match k % 3 {
                    0 => (0, 10), // tag
                    1 => (0, 5),  // colour
                    _ => (0, 3),  // state / direction
                };
                assert!(
                    (lo..=hi).contains(&x),
                    "{id} step {step}: obs[{k}] = {x} outside channel bounds {lo}..={hi}"
                );
            }
        }
        ObsData::U8(_) => {} // u8 is bounded by construction
    }
}

#[test]
fn every_registered_id_runs_two_episodes_with_bounded_obs() {
    for id in navix::list_envs() {
        let mut cfg = navix::make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
        cfg.max_steps = cfg.max_steps.min(TIMEOUT_CAP);
        let max_steps = cfg.max_steps as usize;
        let mut env = BatchedEnv::new(cfg, BATCH, Key::new(2026));
        check_obs_bounds(id, &env.obs, BATCH, 0);

        let mut episodes = vec![0u32; BATCH];
        let mut rng = Rng::new(13);
        // [B × A] action matrix: one row per agent (A=1 for classic ids,
        // A=2 for the Navix-MA-* families).
        let n_agents = env.a;
        let mut actions = vec![0u8; env.policy_rows()];
        let step_budget = (EPISODES as usize + 1) * (max_steps + 2);
        let mut steps = 0;
        while episodes.iter().any(|&e| e < EPISODES) && steps < step_budget {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
            steps += 1;
            // Sampled every 16th step: bounds violations are structural
            // (encoding bugs), not transient, and the sweep runs in debug.
            if steps % 16 == 0 {
                check_obs_bounds(id, &env.obs, BATCH, steps);
            }
            for i in 0..BATCH {
                // Episodes end per slot; agent 0's row carries the step type.
                if env.timestep.step_type[i * n_agents].is_last() {
                    episodes[i] += 1;
                }
            }
        }
        assert!(
            episodes.iter().all(|&e| e >= EPISODES),
            "{id}: not every env finished {EPISODES} episodes within {steps} steps"
        );
    }
}

#[test]
fn every_layout_with_a_goal_is_bfs_solvable() {
    for id in navix::list_envs() {
        let cfg = navix::make(id).unwrap();
        for seed in 0..5u64 {
            let env = BatchedEnv::new(cfg.clone(), 2, Key::new(1000 + seed));
            for i in 0..2 {
                if let Some(goal) = goal_pos(&env.state, i) {
                    assert!(
                        reachable(&env.state, i, goal, true),
                        "{id} seed {seed} env {i}: goal at {goal:?} is not reachable \
                         even through doors"
                    );
                }
            }
        }
    }
}

#[test]
fn no_family_leaves_then_clause_2_unreachable() {
    // Registry-wide guard for the 2-clause (`Then`) grammar: the entity the
    // second clause names must be reachable from the reset state with doors
    // treated as passable — clause 1's completion can only *open* doors, so
    // a clause-2 target unreachable even through doors is unwinnable by
    // construction. Generators avoid this by geometry or by rejecting the
    // draw (deterministic episode-key retry inside `BatchedEnv::new`),
    // never by panicking. Outer-wall door targets sit in wall cells BFS
    // cannot enter, so a target also counts as reachable when any
    // 4-adjacent cell is (the agent toggles doors from an adjacent cell).
    use navix::core::components::Direction;
    use navix::core::grid::Pos;
    use navix::core::mission::MissionClause;
    use navix::core::state::AgentView;
    for id in navix::list_envs() {
        let cfg = navix::make(id).unwrap();
        for seed in 0..4u64 {
            let env = BatchedEnv::new(cfg.clone(), 2, Key::new(500 + seed));
            for i in 0..2 {
                let s = env.state.slot(i);
                let spec = s.mission_spec();
                if spec.len() < 2 {
                    continue;
                }
                let clause = spec.clause(1).expect("2-clause spec has a second clause");
                let (h, w) = (s.h, s.w);
                let targets: Vec<Pos> = match clause {
                    MissionClause::Open { color } => (0..s.door_pos.len())
                        .filter(|&d| s.door_pos[d] >= 0 && s.door_color[d] == color as u8)
                        .map(|d| Pos::decode(s.door_pos[d], w))
                        .collect(),
                    MissionClause::GoTo { kind, color }
                    | MissionClause::PickUp { kind, color }
                    | MissionClause::PutNext { kind, color, .. } => {
                        use navix::core::entities::Tag;
                        let (pos, col): (&[i32], &[u8]) = match kind {
                            Tag::KEY => (s.key_pos, s.key_color),
                            Tag::BALL => (s.ball_pos, s.ball_color),
                            Tag::BOX => (s.box_pos, s.box_color),
                            _ => panic!("{id} seed {seed}: clause-2 kind {kind} has no entity table"),
                        };
                        (0..pos.len())
                            .filter(|&k| pos[k] >= 0 && col[k] == color as u8)
                            .map(|k| Pos::decode(pos[k], w))
                            .collect()
                    }
                };
                assert!(
                    !targets.is_empty(),
                    "{id} seed {seed} env {i}: clause 2 ({clause:?}) names no placed entity"
                );
                let ok = targets.iter().any(|&p| {
                    reachable(&env.state, i, p, true)
                        || Direction::ALL.iter().any(|&d| {
                            let q = p.step(d);
                            q.in_bounds(h, w) && reachable(&env.state, i, q, true)
                        })
                });
                assert!(
                    ok,
                    "{id} seed {seed} env {i}: clause-2 target {clause:?} unreachable \
                     even through doors"
                );
            }
        }
    }
}

#[test]
fn every_id_is_bitwise_shard_invariant() {
    // 200 steps of shared random actions: BatchedEnv and ShardedEnv{S=3}
    // must agree on every reward, step type, clock and observation buffer —
    // the acceptance gate for new layout generators (their RNG draws must
    // be a pure function of the episode key, never of the shard).
    const B: usize = 9;
    const STEPS: usize = 200;
    for id in navix::list_envs() {
        let cfg = navix::make(id).unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(77));
        let mut sharded = ShardedEnv::new(cfg, B, 3, 2, Key::new(77));
        let rows = single.policy_rows(); // B·A agent-rows per step
        let mut rng = Rng::new(3);
        for step in 1..=STEPS {
            let actions: Vec<u8> = (0..rows).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            assert_eq!(
                single.timestep.reward, sharded.timestep.reward,
                "{id} step {step}: rewards diverged under sharding"
            );
            assert_eq!(
                single.timestep.step_type, sharded.timestep.step_type,
                "{id} step {step}: step types diverged under sharding"
            );
            assert_eq!(
                single.timestep.t, sharded.timestep.t,
                "{id} step {step}: episode clocks diverged under sharding"
            );
            match (&single.obs.data, &sharded.obs.data) {
                (ObsData::I32(a), ObsData::I32(b)) => {
                    assert_eq!(a, b, "{id} step {step}: observations diverged under sharding")
                }
                (ObsData::U8(a), ObsData::U8(b)) => {
                    assert_eq!(a, b, "{id} step {step}: observations diverged under sharding")
                }
                _ => panic!("{id} step {step}: observation dtypes diverged"),
            }
            assert_eq!(
                single.obs.mission, sharded.obs.mission,
                "{id} step {step}: mission features diverged under sharding"
            );
        }
    }
}
