//! Sharded-execution determinism: `ShardedEnv` must be **bit-identical** to
//! the single-threaded `BatchedEnv` for any shard count — observations,
//! rewards, terminations, autoresets, episodic returns — because every
//! per-env RNG stream is a pure function of (root key, global env index,
//! per-env episode count), never of the worker or shard that steps the env.
//!
//! The matrix below drives 200 steps of shared random actions through three
//! registry families (fixed-layout, per-episode-random-layout, and
//! stochastic-dynamics) at shard counts {1, 2, 7}, comparing against the
//! single-threaded engine after every step. 7 does not divide the batch, so
//! uneven contiguous shards are covered too.

use navix::batch::{BatchedEnv, ObsBatch, ObsData, ShardedEnv};
use navix::rng::{Key, Rng};

const STEPS: usize = 200;
const BATCH: usize = 24;
const SHARD_COUNTS: [usize; 3] = [1, 2, 7];

/// Envs chosen to exercise distinct code paths: fixed layouts, per-episode
/// random layouts (reset keys matter), and stochastic ball dynamics
/// (in-episode slot RNG matters). All three terminate often enough under
/// random actions that autoreset (per-env episode counters) is covered.
const ENVS: [&str; 3] =
    ["Navix-Empty-8x8-v0", "Navix-DoorKey-Random-8x8", "Navix-Dynamic-Obstacles-6x6"];

fn assert_obs_equal(id: &str, step: usize, single: &ObsBatch, sharded: &ObsBatch) {
    assert_eq!(
        single.mission, sharded.mission,
        "{id} step {step}: mission features diverged"
    );
    match (&single.data, &sharded.data) {
        (ObsData::I32(a), ObsData::I32(b)) => {
            assert_eq!(a, b, "{id} step {step}: i32 observations diverged");
        }
        (ObsData::U8(a), ObsData::U8(b)) => {
            assert_eq!(a, b, "{id} step {step}: u8 observations diverged");
        }
        _ => panic!("{id} step {step}: observation dtypes diverged"),
    }
}

#[test]
fn sharded_env_is_bit_identical_to_batched_env() {
    for id in ENVS {
        let cfg = navix::make(id).unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), BATCH, Key::new(2024));
        let mut sharded: Vec<ShardedEnv> = SHARD_COUNTS
            .iter()
            .map(|&s| ShardedEnv::new(cfg.clone(), BATCH, s, 2, Key::new(2024)))
            .collect();

        // Reset state must already agree (construction resets).
        for sh in &sharded {
            assert_obs_equal(id, 0, &single.obs, &sh.obs);
        }

        let mut rng = Rng::new(7);
        let mut terminals = 0u32;
        for step in 1..=STEPS {
            let actions: Vec<u8> = (0..BATCH).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            for sh in sharded.iter_mut() {
                sh.step(&actions);
                let s = sh.num_shards;
                assert_eq!(
                    single.timestep.reward, sh.timestep.reward,
                    "{id} step {step} (S={s}): rewards diverged"
                );
                assert_eq!(
                    single.timestep.step_type, sh.timestep.step_type,
                    "{id} step {step} (S={s}): terminations diverged"
                );
                assert_eq!(
                    single.timestep.discount, sh.timestep.discount,
                    "{id} step {step} (S={s}): discounts diverged"
                );
                assert_eq!(
                    single.timestep.episodic_return, sh.timestep.episodic_return,
                    "{id} step {step} (S={s}): episodic returns diverged"
                );
                assert_eq!(
                    single.timestep.t, sh.timestep.t,
                    "{id} step {step} (S={s}): episode clocks diverged"
                );
                assert_obs_equal(id, step, &single.obs, &sh.obs);
            }
            terminals += single.timestep.step_type.iter().filter(|t| t.is_last()).count() as u32;
        }
        assert!(
            terminals > 0,
            "{id}: the walk never ended an episode — autoreset paths untested"
        );
    }
}

#[test]
fn sharded_rollout_random_draws_the_batched_action_stream() {
    // rollout_random must consume the identical central action stream, so
    // end states after a rollout agree between engines.
    let cfg = navix::make("Navix-Empty-Random-6x6").unwrap();
    let mut single = BatchedEnv::new(cfg.clone(), 12, Key::new(5));
    let mut sharded = ShardedEnv::new(cfg, 12, 3, 2, Key::new(5));
    assert_eq!(single.rollout_random(100, 99), sharded.rollout_random(100, 99));
    assert_eq!(single.timestep.reward, sharded.timestep.reward);
    assert_eq!(single.timestep.step_type, sharded.timestep.step_type);
    for i in 0..12 {
        assert_eq!(single.obs.env_i32(12, i), sharded.obs.env_i32(12, i));
    }
}
