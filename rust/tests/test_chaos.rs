//! Chaos-harness pins: deterministic injected faults driven through every
//! engine, with the acceptance scenario front and center — an injected
//! worker panic inside a `ShardedEnv` with uneven shards must neither
//! deadlock nor poison the pool; the faulting slot is quarantined and
//! restored while every other slot stays bitwise identical to a
//! fault-free twin run.

use navix::batch::{BatchStepper, BatchedEnv, FaultPolicy, PipelinedEnv, ShardedEnv};
use navix::bench_harness::chaos::ChaosInjector;
use navix::envs::registry::make;
use navix::rng::{Key, Rng};

const ID: &str = "Navix-Empty-Random-6x6";

fn random_actions(rng: &mut Rng, rows: usize) -> Vec<u8> {
    (0..rows).map(|_| rng.below(7) as u8).collect()
}

/// Compare every non-faulted slot of `chaotic` against the fault-free
/// `clean` twin — bitwise, at the current step.
fn assert_others_match(
    step: usize,
    faulted: &[usize],
    b: usize,
    clean_ts: &navix::core::timestep::BatchedTimestep,
    clean_obs: &navix::batch::ObsBatch,
    chaos_ts: &navix::core::timestep::BatchedTimestep,
    chaos_obs: &navix::batch::ObsBatch,
) {
    for i in 0..b {
        if faulted.contains(&i) {
            continue;
        }
        assert_eq!(
            clean_ts.reward[i], chaos_ts.reward[i],
            "step {step} slot {i}: reward diverged"
        );
        assert_eq!(
            clean_ts.step_type[i], chaos_ts.step_type[i],
            "step {step} slot {i}: step_type diverged"
        );
        assert_eq!(clean_ts.t[i], chaos_ts.t[i], "step {step} slot {i}: t diverged");
        assert_eq!(
            clean_obs.env_i32(b, i),
            chaos_obs.env_i32(b, i),
            "step {step} slot {i}: obs diverged"
        );
    }
}

#[test]
fn sharded_quarantine_neither_deadlocks_nor_poisons() {
    // The acceptance scenario: B=10 over S=3 *uneven* shards (3/3/4), an
    // injected panic in global slot 4 (shard 1) at step 6.
    let cfg = make(ID).unwrap();
    let mut clean = ShardedEnv::new(cfg.clone(), 10, 3, 2, Key::new(5));
    let mut chaotic = ShardedEnv::new(cfg, 10, 3, 2, Key::new(5));
    chaotic.supervise(FaultPolicy::QuarantineSlot);
    chaotic.arm_chaos(ChaosInjector::parse("panic@4:6").unwrap());

    let mut rng = Rng::new(1);
    for step in 1..=20 {
        let actions = random_actions(&mut rng, 10);
        clean.step(&actions);
        chaotic.step(&actions); // must return — no deadlock, no poison panic
        assert_others_match(
            step,
            &[4],
            10,
            &clean.timestep,
            &clean.obs,
            &chaotic.timestep,
            &chaotic.obs,
        );
        if step == 6 {
            // The quarantined slot: action masked, reward zeroed, latch up.
            assert_eq!(chaotic.timestep.action[4], -1, "quarantined action must be masked");
            assert_eq!(chaotic.timestep.reward[4], 0.0, "quarantined reward must be zero");
            assert!(
                chaotic.with_shard(1, |e| e.state.events[1].slot_quarantined),
                "slot_quarantined latch must be up on the faulting slot's row"
            );
        }
        if step > 6 {
            // Restored and stepping again: the slot keeps making progress.
            assert!(
                !chaotic.with_shard(1, |e| e.state.events[1].slot_quarantined),
                "latch must clear on the next clean step"
            );
        }
    }
    let log = chaotic.fault_log();
    assert_eq!(log.len(), 1, "exactly one fault: {log:?}");
    assert!(log[0].is_chaos());
    assert_eq!(log[0].slot, Some(4));
    assert_eq!(log[0].step, 6);
    let stats = ShardedEnv::fault_stats(&chaotic);
    assert_eq!(stats.injected, 1);
    assert_eq!(stats.recovered, 1);
}

#[test]
fn sharded_fused_window_survives_quarantine() {
    // Same scenario through the fused step_n path: the fault fires inside
    // a worker's K-step window.
    let cfg = make(ID).unwrap();
    let mut clean = ShardedEnv::new(cfg.clone(), 10, 3, 2, Key::new(5));
    let mut chaotic = ShardedEnv::new(cfg, 10, 3, 2, Key::new(5));
    chaotic.supervise(FaultPolicy::QuarantineSlot);
    chaotic.arm_chaos(ChaosInjector::parse("panic@4:6").unwrap());

    let mut rng = Rng::new(1);
    let plan: Vec<u8> = (0..12 * 10).map(|_| rng.below(7) as u8).collect();
    let mut traj_clean = navix::batch::TrajectorySlice::new(navix::batch::ObsCapture::Final);
    let mut traj_chaos = navix::batch::TrajectorySlice::new(navix::batch::ObsCapture::Final);
    clean.step_n(navix::batch::ActionPlan::Fixed(&plan), 12, &mut traj_clean);
    chaotic.step_n(navix::batch::ActionPlan::Fixed(&plan), 12, &mut traj_chaos);
    for t in 0..12 {
        for i in 0..10 {
            if i == 4 {
                continue;
            }
            assert_eq!(
                traj_clean.reward_row(t)[i],
                traj_chaos.reward_row(t)[i],
                "window step {t} slot {i}: reward diverged"
            );
            assert_eq!(
                traj_clean.step_type_row(t)[i],
                traj_chaos.step_type_row(t)[i],
                "window step {t} slot {i}: step_type diverged"
            );
        }
    }
    assert_eq!(traj_chaos.reward_row(5)[4], 0.0, "fault step reward must be zeroed");
    assert_eq!(ShardedEnv::fault_stats(&chaotic).recovered, 1);
}

#[test]
fn sharded_propagate_surfaces_a_diagnosable_engine_fault() {
    // Without quarantine the caller must still get a structured panic —
    // naming the shard and the chaos payload — instead of a hang on a
    // done-count that never arrives.
    let cfg = make(ID).unwrap();
    let mut env = ShardedEnv::new(cfg, 10, 3, 2, Key::new(5));
    env.arm_chaos(ChaosInjector::parse("panic@4:2").unwrap());
    let mut rng = Rng::new(1);
    let a1 = random_actions(&mut rng, 10);
    env.step(&a1);
    let a2 = random_actions(&mut rng, 10);
    let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| env.step(&a2)))
        .expect_err("the injected fault must surface");
    let msg = err
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| err.downcast_ref::<String>().cloned())
        .unwrap_or_default();
    assert!(
        msg.contains("shard 1") && msg.contains("chaos:"),
        "fault must name the shard and carry the chaos payload, got: {msg:?}"
    );
    assert!(
        env.fault_log().iter().any(|f| f.shard == Some(1) && f.is_chaos()),
        "the fault must be on record"
    );
    drop(env); // the pool must still shut down cleanly
}

#[test]
fn sharded_restart_worker_reaps_repairs_and_respawns() {
    // One worker per shard; the panic kills shard 1's worker outright. The
    // epoch watchdog must reap it, repair the torn slot from its pre-step
    // snapshot, re-step it (the one-shot spec is spent, so the repair is
    // clean), and respawn — after which EVERY slot matches the fault-free
    // twin bitwise.
    let cfg = make(ID).unwrap();
    let mut clean = ShardedEnv::new(cfg.clone(), 10, 3, 3, Key::new(5));
    let mut chaotic = ShardedEnv::new(cfg, 10, 3, 3, Key::new(5));
    chaotic.supervise(FaultPolicy::RestartWorker);
    chaotic.arm_chaos(ChaosInjector::parse("poisonrng@4:6").unwrap());

    let mut rng = Rng::new(1);
    for step in 1..=15 {
        let actions = random_actions(&mut rng, 10);
        clean.step(&actions);
        chaotic.step(&actions);
        // Repair re-executes the interrupted step, so even the faulting
        // slot must match (PoisonRng scrambled the slot RNG before dying —
        // the snapshot restore must have repaired it).
        assert_others_match(
            step,
            &[],
            10,
            &clean.timestep,
            &clean.obs,
            &chaotic.timestep,
            &chaotic.obs,
        );
    }
    let stats = ShardedEnv::fault_stats(&chaotic);
    assert_eq!(stats.injected, 1);
    assert!(stats.recovered >= 1, "the worker restart must count as a recovery");
    assert!(
        chaotic.fault_log().iter().any(|f| f.payload.contains("chaos:")),
        "the dead worker's payload must be on record"
    );
}

#[test]
fn batched_quarantines_bad_actions_and_poisoned_rng() {
    let cfg = make(ID).unwrap();
    let mut clean = BatchedEnv::new(cfg.clone(), 6, Key::new(9));
    let mut chaotic = BatchedEnv::new(cfg, 6, Key::new(9));
    chaotic.supervise(FaultPolicy::QuarantineSlot);
    chaotic.arm_chaos(ChaosInjector::parse("badaction@2:3;poisonrng@5:7").unwrap());

    let mut rng = Rng::new(2);
    for step in 1..=12 {
        let actions = random_actions(&mut rng, 6);
        clean.step(&actions);
        chaotic.step(&actions);
        assert_others_match(
            step,
            &[2, 5],
            6,
            &clean.timestep,
            &clean.obs,
            &chaotic.timestep,
            &chaotic.obs,
        );
    }
    let log = chaotic.fault_log();
    assert_eq!(log.len(), 2, "both specs must fire: {log:?}");
    assert!(log.iter().all(|f| f.is_chaos()));
    assert!(
        log[0].payload.contains("out-of-range action"),
        "bad action must be validated, got: {}",
        log[0].payload
    );
    let stats = chaotic.fault_stats();
    assert_eq!(stats.injected, 2);
    assert_eq!(stats.recovered, 2);
}

#[test]
fn pipelined_quarantine_round_trips_through_the_stepper_thread() {
    let cfg = make(ID).unwrap();
    let mut clean = BatchedEnv::new(cfg.clone(), 6, Key::new(9));
    let mut inner = BatchedEnv::new(cfg, 6, Key::new(9));
    inner.arm_chaos(ChaosInjector::parse("panic@3:5").unwrap());
    let mut piped = PipelinedEnv::over_batched(inner);
    piped.supervise(FaultPolicy::QuarantineSlot);

    let mut rng = Rng::new(2);
    for step in 1..=12 {
        let actions = random_actions(&mut rng, 6);
        clean.step(&actions);
        piped.step(&actions);
        assert_others_match(
            step,
            &[3],
            6,
            &clean.timestep,
            &clean.obs,
            piped.timestep(),
            piped.obs(),
        );
    }
    let log = piped.fault_log();
    assert_eq!(log.len(), 1, "{log:?}");
    assert_eq!(log[0].slot, Some(3));
    assert_eq!(PipelinedEnv::fault_stats(&mut piped).recovered, 1);
}

#[test]
fn chaos_env_hook_matches_the_environment() {
    // This test never calls set_var — the variable is process-global and
    // would race the parallel tests above. Unarmed (the tier-1 run) it
    // pins silence; the CI chaos job re-runs it alone with NAVIX_CHAOS
    // exported to exercise the hook end to end.
    match std::env::var("NAVIX_CHAOS") {
        Err(_) => assert!(ChaosInjector::from_env().is_none(), "hook must stay silent"),
        Ok(raw) => {
            let inj = ChaosInjector::from_env().expect("NAVIX_CHAOS is set — it must parse");
            assert!(!inj.specs().is_empty(), "NAVIX_CHAOS={raw:?} armed no specs");
            // Every BatchedEnv constructor checks the hook, so a fresh
            // engine self-arms; under quarantine the injected faults are
            // survivable and on record.
            let cfg = make(ID).unwrap();
            let mut env = BatchedEnv::new(cfg, 8, Key::new(1));
            env.supervise(FaultPolicy::QuarantineSlot);
            let mut rng = Rng::new(3);
            for _ in 0..32 {
                env.step(&random_actions(&mut rng, 8));
            }
            let stats = env.fault_stats();
            assert!(stats.injected >= 1, "the env hook must have armed the engine");
            assert_eq!(stats.injected, stats.recovered, "every injected fault recovers");
        }
    }
}
