//! Runtime integration tests: load every AOT artifact, execute it via PJRT
//! and cross-check against the native Rust implementations. These tests are
//! the proof that the three layers compose: L1 Pallas kernels and the L2
//! JAX model produce the same numbers as the L3 engine.
//!
//! Skipped gracefully when the artifacts are absent (build them with
//! `make artifacts`, which writes to `rust/artifacts/`), and likewise when
//! PJRT itself is unavailable — the offline workspace links the stub `xla`
//! crate (vendor/xla), whose client constructor fails fast; swap it for
//! real bindings to activate these tests.

use navix::batch::BatchedEnv;
use navix::nn::{Activation, Mlp};
use navix::rng::{Key, Rng};
use navix::runtime::artifacts::{packing, ArtifactSet};
use navix::runtime::client::{f32_literal, i32_literal, i32_scalar, to_f32_vec, to_i32_vec};
use navix::runtime::Runtime;

/// Both environment dependencies, or a graceful skip: the AOT artifacts
/// (`make artifacts`) and a working PJRT runtime (real `xla` bindings).
fn runtime_and_artifacts() -> Option<(Runtime, ArtifactSet)> {
    let set = match ArtifactSet::discover() {
        Ok(s) if s.sanity().is_ok() => s,
        _ => {
            eprintln!("SKIP: artifacts not built (run `make artifacts`)");
            return None;
        }
    };
    match Runtime::cpu() {
        Ok(rt) => Some((rt, set)),
        Err(e) => {
            eprintln!("SKIP: PJRT runtime unavailable ({e:#})");
            None
        }
    }
}

#[test]
fn sanity_module_loads_and_runs() {
    let Some((rt, set)) = runtime_and_artifacts() else { return };
    assert!(rt.device_count() >= 1);
    let exe = rt.load_hlo(set.sanity().unwrap()).unwrap();
    // model.hlo.txt = ppo_fwd at B=1
    let params = packing::init_params(0);
    let obs = vec![0i32; packing::OBS_DIM];
    let out = exe
        .run(&[
            f32_literal(&params, &[params.len() as i64]).unwrap(),
            i32_literal(&obs, &[1, packing::OBS_DIM as i64]).unwrap(),
        ])
        .unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(to_f32_vec(&out[0]).unwrap().len(), 7);
}

/// The decisive packing test: the XLA actor-critic forward must match the
/// native Rust MLP bit-for-bit (same flat params, same layout, same math).
#[test]
fn xla_forward_matches_native_mlp() {
    let Some((rt, set)) = runtime_and_artifacts() else { return };
    let exe = rt.load_hlo(set.ppo_fwd(16).unwrap()).unwrap();

    let params = packing::init_params(3);
    // random plausible observations
    let mut rng = Rng::new(5);
    let obs: Vec<i32> = (0..16 * packing::OBS_DIM).map(|_| rng.below(11) as i32).collect();
    let out = exe
        .run(&[
            f32_literal(&params, &[params.len() as i64]).unwrap(),
            i32_literal(&obs, &[16, packing::OBS_DIM as i64]).unwrap(),
        ])
        .unwrap();
    let logits = to_f32_vec(&out[0]).unwrap();
    let values = to_f32_vec(&out[1]).unwrap();

    // native: unpack the same flat params into actor/critic MLPs
    let actor_n: usize = packing::ACTOR_DIMS.windows(2).map(|w| w[0] * w[1] + w[1]).sum();
    let mut actor = Mlp::new(&packing::ACTOR_DIMS, Activation::Tanh, &mut Rng::new(0));
    actor.params.copy_from_slice(&params[..actor_n]);
    let mut critic = Mlp::new(&packing::CRITIC_DIMS, Activation::Tanh, &mut Rng::new(0));
    critic.params.copy_from_slice(&params[actor_n..]);

    for i in 0..16 {
        let d = packing::OBS_DIM;
        let x: Vec<f32> = obs[i * d..(i + 1) * d].iter().map(|&v| v as f32 / 10.0).collect();
        let native_logits = actor.infer(&x);
        let native_value = critic.infer(&x)[0];
        for a in 0..7 {
            let diff = (logits[i * 7 + a] - native_logits[a]).abs();
            assert!(diff < 1e-4, "env {i} logit {a}: xla {} vs native {}", logits[i * 7 + a], native_logits[a]);
        }
        assert!(
            (values[i] - native_value).abs() < 1e-4,
            "env {i} value: xla {} vs native {}",
            values[i],
            native_value
        );
    }
}

/// The L1 kernel must agree with the L3 observation system on Empty-8x8.
#[test]
fn obs_kernel_matches_rust_observations() {
    let Some((rt, set)) = runtime_and_artifacts() else { return };
    let exe = rt.load_hlo(set.obs_kernel(16).unwrap()).unwrap();

    // Drive the Rust engine to 16 diverse states.
    let cfg = navix::make("Navix-Empty-8x8-v0").unwrap();
    let mut env = BatchedEnv::new(cfg.clone(), 16, Key::new(1));
    let mut rng = Rng::new(2);
    for _ in 0..20 {
        let actions: Vec<u8> = (0..16).map(|_| rng.below(3) as u8).collect();
        env.step(&actions);
    }

    // Build the kernel inputs from the Rust state: symbolic grid w/o player.
    let mut grid = vec![0i32; 16 * 8 * 8 * 3];
    let mut pos = vec![0i32; 16 * 2];
    let mut dir = vec![0i32; 16];
    for i in 0..16 {
        let s = env.state.slot(i);
        for r in 0..8 {
            for c in 0..8 {
                let (t, col, st) = navix::systems::observations::encode_cell(
                    &s,
                    navix::core::grid::Pos::new(r, c),
                    false,
                );
                let at = ((i * 8 + r as usize) * 8 + c as usize) * 3;
                grid[at] = t;
                grid[at + 1] = col;
                grid[at + 2] = st;
            }
        }
        let p = s.player();
        pos[i * 2] = p.r;
        pos[i * 2 + 1] = p.c;
        dir[i] = s.player_dir[0];
    }
    let out = exe
        .run(&[
            i32_literal(&grid, &[16, 8, 8, 3]).unwrap(),
            i32_literal(&pos, &[16, 2]).unwrap(),
            i32_literal(&dir, &[16]).unwrap(),
        ])
        .unwrap();
    let kernel_obs = to_i32_vec(&out[0]).unwrap();

    // Rust engine's own first-person obs (with full occlusion machinery).
    for i in 0..16 {
        let rust_obs = env.obs.env_i32(16, i);
        let g = packing::GRID_OBS_DIM;
        let k = &kernel_obs[i * g..(i + 1) * g];
        assert_eq!(rust_obs, k, "env {i}: L1 kernel disagrees with L3 observation system");
    }
}

/// Trajectory-level parity: the fully-jitted L2 env step must reproduce the
/// L3 engine step-for-step on Empty-8x8 (positions, rewards, discounts,
/// observations, autoreset) across hundreds of random actions.
#[test]
fn xla_env_step_matches_rust_engine_trajectory() {
    let Some((rt, set)) = runtime_and_artifacts() else { return };
    let exe = rt.load_hlo(set.env_step(16).unwrap()).unwrap();

    let cfg = navix::make("Navix-Empty-8x8-v0").unwrap();
    let mut env = BatchedEnv::new(cfg, 16, Key::new(0));

    // XLA state: pos, dir, t, done (matches env_reset in model.py)
    let mut pos: Vec<i32> = (0..16).flat_map(|_| [1, 1]).collect();
    let mut dirv = vec![0i32; 16];
    let mut tv = vec![0i32; 16];
    let mut done = vec![0i32; 16];

    let mut rng = Rng::new(11);
    for step in 0..400 {
        let actions: Vec<u8> = (0..16).map(|_| rng.below(7) as u8).collect();
        let actions_i32: Vec<i32> = actions.iter().map(|&a| a as i32).collect();

        let out = exe
            .run(&[
                i32_literal(&pos, &[16, 2]).unwrap(),
                i32_literal(&dirv, &[16]).unwrap(),
                i32_literal(&tv, &[16]).unwrap(),
                i32_literal(&done, &[16]).unwrap(),
                i32_literal(&actions_i32, &[16]).unwrap(),
            ])
            .unwrap();
        pos = to_i32_vec(&out[0]).unwrap();
        dirv = to_i32_vec(&out[1]).unwrap();
        tv = to_i32_vec(&out[2]).unwrap();
        done = to_i32_vec(&out[3]).unwrap();
        let obs = to_i32_vec(&out[4]).unwrap();
        let reward = to_f32_vec(&out[5]).unwrap();
        let discount = to_f32_vec(&out[6]).unwrap();

        env.step(&actions);

        for i in 0..16 {
            let s = env.state.slot(i);
            let p = s.player();
            assert_eq!(
                (pos[i * 2], pos[i * 2 + 1]),
                (p.r, p.c),
                "step {step} env {i}: position diverged"
            );
            assert_eq!(dirv[i], s.player_dir[0], "step {step} env {i}: direction diverged");
            assert_eq!(reward[i], env.timestep.reward[i], "step {step} env {i}: reward");
            assert_eq!(
                discount[i], env.timestep.discount[i],
                "step {step} env {i}: discount"
            );
            assert_eq!(tv[i] as u32, env.timestep.t[i], "step {step} env {i}: t");
            // policy-width rows: grid prefix matches the engine, the
            // mission token tail stays zero (Empty is mission-free).
            let d = packing::OBS_DIM;
            let g = packing::GRID_OBS_DIM;
            assert_eq!(
                &obs[i * d..i * d + g],
                env.obs.env_i32(16, i),
                "step {step} env {i}: observation diverged"
            );
            assert!(
                obs[i * d + g..(i + 1) * d].iter().all(|&x| x == 0),
                "step {step} env {i}: mission block must stay zero"
            );
        }
    }
}

/// Fused PPO update executes and improves its own value loss.
#[test]
fn xla_ppo_update_reduces_value_loss() {
    let Some((rt, set)) = runtime_and_artifacts() else { return };
    let fwd = rt.load_hlo(set.ppo_fwd(16).unwrap()).unwrap();
    let upd = rt.load_hlo(set.ppo_update(256).unwrap()).unwrap();

    let mut params = packing::init_params(7);
    let n = params.len();
    let mut m = vec![0.0f32; n];
    let mut v = vec![0.0f32; n];
    let mut rng = Rng::new(8);
    let obs: Vec<i32> = (0..256 * packing::OBS_DIM).map(|_| rng.below(11) as i32).collect();
    let actions: Vec<i32> = (0..256).map(|_| rng.below(7) as i32).collect();
    let adv = vec![0.0f32; 256]; // isolate the value head
    let targets: Vec<f32> = (0..256).map(|_| rng.uniform_f32()).collect();

    // old_logp from the fwd artifact (first 16 rows repeated is fine for a
    // math test — use fwd on chunks of 16)
    let mut old_logp = vec![0.0f32; 256];
    for chunk in 0..16 {
        let o = &obs[chunk * 16 * packing::OBS_DIM..(chunk + 1) * 16 * packing::OBS_DIM];
        let out = fwd
            .run(&[
                f32_literal(&params, &[n as i64]).unwrap(),
                i32_literal(o, &[16, packing::OBS_DIM as i64]).unwrap(),
            ])
            .unwrap();
        let logits = to_f32_vec(&out[0]).unwrap();
        for i in 0..16 {
            let l = &logits[i * 7..(i + 1) * 7];
            let mx = l.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = l.iter().map(|x| (x - mx).exp()).sum();
            let a = actions[chunk * 16 + i] as usize;
            old_logp[chunk * 16 + i] = l[a] - mx - z.ln();
        }
    }

    let mut first = None;
    let mut last = 0.0;
    for t in 1..=60i32 {
        let out = upd
            .run(&[
                f32_literal(&params, &[n as i64]).unwrap(),
                f32_literal(&m, &[n as i64]).unwrap(),
                f32_literal(&v, &[n as i64]).unwrap(),
                i32_scalar(t),
                i32_literal(&obs, &[256, packing::OBS_DIM as i64]).unwrap(),
                i32_literal(&actions, &[256]).unwrap(),
                f32_literal(&old_logp, &[256]).unwrap(),
                f32_literal(&adv, &[256]).unwrap(),
                f32_literal(&targets, &[256]).unwrap(),
            ])
            .unwrap();
        params = to_f32_vec(&out[0]).unwrap();
        m = to_f32_vec(&out[1]).unwrap();
        v = to_f32_vec(&out[2]).unwrap();
        let v_loss = to_f32_vec(&out[4]).unwrap()[0];
        if first.is_none() {
            first = Some(v_loss);
        }
        last = v_loss;
    }
    let first = first.unwrap();
    assert!(
        last < first * 0.9,
        "fused update failed to reduce value loss: {first} -> {last}"
    );
}
