//! Training parity: the batched-GEMM + double-buffered-pipeline trainer
//! must reproduce the serial per-sample trainer **exactly** for a fixed
//! seed — bitwise for integer fields (actions, boundaries), exact f32
//! equality for every float tensor (the batch kernels preserve summation
//! order; see `rust/src/nn/mlp.rs`).
//!
//! Layers pinned here:
//! * `Mlp::forward_batch` vs row-by-row `Mlp::forward` (unit pin);
//! * `Ppo::collect_rollout` (batched) and `Ppo::collect_rollout_pipelined`
//!   (batched + overlapped stepping on a sharded engine) vs
//!   `Ppo::collect_rollout_serial` — all rollout tensors;
//! * `Ppo::update` (minibatch GEMMs) vs `Ppo::update_serial` — `PpoMetrics`
//!   and the post-update parameters, across multiple iterations so drift
//!   anywhere compounds into a failure.

use navix::agents::ppo::{Ppo, PpoConfig, Rollout};
use navix::agents::{ReturnTracker, OBS_DIM};
use navix::batch::{BatchedEnv, PipelinedEnv, ShardedEnv};
use navix::envs::registry::make;
use navix::nn::{Activation, BatchCache, Mlp};
use navix::rng::{Key, Rng};

/// The 2×64 policy net shape at a batch size that exercises both the
/// 4-wide output tiles and the remainder path.
#[test]
fn forward_batch_matches_rowwise_forward_on_policy_shapes() {
    let mut rng = Rng::new(3);
    let mlp = Mlp::new(&[OBS_DIM, 64, 64, 7], Activation::Tanh, &mut rng);
    let bsz = 13;
    let x: Vec<f32> = (0..bsz * OBS_DIM).map(|_| rng.normal() as f32).collect();
    let mut cache = BatchCache::default();
    mlp.forward_batch(&x, bsz, &mut cache);
    for s in 0..bsz {
        let row = mlp.infer(&x[s * OBS_DIM..(s + 1) * OBS_DIM]);
        assert_eq!(&cache.out()[s * 7..(s + 1) * 7], &row[..], "sample {s}");
    }
}

fn ppo_cfg(b: usize) -> PpoConfig {
    PpoConfig {
        num_envs: b,
        rollout_len: 16,
        minibatches: 4,
        epochs: 2,
        ..PpoConfig::default()
    }
}

fn assert_rollouts_equal(a: &Rollout, b: &Rollout, ctx: &str) {
    assert_eq!(a.actions, b.actions, "{ctx}: actions");
    assert_eq!(a.boundaries, b.boundaries, "{ctx}: boundaries");
    assert_eq!(a.obs, b.obs, "{ctx}: obs");
    assert_eq!(a.logp, b.logp, "{ctx}: logp");
    assert_eq!(a.values, b.values, "{ctx}: values");
    assert_eq!(a.rewards, b.rewards, "{ctx}: rewards");
    assert_eq!(a.discounts, b.discounts, "{ctx}: discounts");
    assert_eq!(a.last_values, b.last_values, "{ctx}: last_values");
    assert_eq!(a.advantages, b.advantages, "{ctx}: advantages");
    assert_eq!(a.targets, b.targets, "{ctx}: targets");
}

/// Serial per-sample trainer (BatchedEnv) vs the pipelined batched-GEMM
/// trainer (PipelinedEnv over a 2-shard ShardedEnv): three full
/// rollout+update iterations must agree on every tensor, metric and
/// parameter.
fn pipelined_matches_serial(env_id: &str, seed: u64) {
    let cfg = make(env_id).unwrap();
    let b = 8;
    let mut env_s = BatchedEnv::new(cfg.clone(), b, Key::new(seed));
    let mut env_p =
        PipelinedEnv::new(Box::new(ShardedEnv::new(cfg, b, 2, 2, Key::new(seed))));
    let mut ppo_s = Ppo::new(ppo_cfg(b), OBS_DIM, 7, seed ^ 0x5EED);
    let mut ppo_p = Ppo::new(ppo_cfg(b), OBS_DIM, 7, seed ^ 0x5EED);
    let mut ro_s = Rollout::new(16, b, OBS_DIM);
    let mut ro_p = Rollout::new(16, b, OBS_DIM);
    let mut tr_s = ReturnTracker::new(64);
    let mut tr_p = ReturnTracker::new(64);

    for iter in 0..3 {
        let ctx = format!("{env_id} iter {iter}");
        ppo_s.collect_rollout_serial(&mut env_s, &mut ro_s, &mut tr_s);
        ppo_p.collect_rollout_pipelined(&mut env_p, &mut ro_p, &mut tr_p);
        assert_rollouts_equal(&ro_s, &ro_p, &ctx);
        assert_eq!(tr_s.episodes, tr_p.episodes, "{ctx}: episode counts");
        assert_eq!(tr_s.mean(), tr_p.mean(), "{ctx}: mean returns");

        let m_s = ppo_s.update_serial(&ro_s);
        let m_p = ppo_p.update(&ro_p);
        assert_eq!(m_s, m_p, "{ctx}: PpoMetrics");
        assert_eq!(ppo_s.actor.params, ppo_p.actor.params, "{ctx}: actor params");
        assert_eq!(ppo_s.critic.params, ppo_p.critic.params, "{ctx}: critic params");
    }
}

#[test]
fn pipelined_trainer_matches_serial_on_empty_random() {
    // Random layouts + frequent autoresets: the pipeline must hand every
    // reset observation through the swap buffers at the right step.
    pipelined_matches_serial("Navix-Empty-Random-6x6", 17);
}

#[test]
fn pipelined_trainer_matches_serial_on_doorkey() {
    // A second family with doors/keys and longer episodes.
    pipelined_matches_serial("Navix-DoorKey-6x6-v0", 23);
}

#[test]
fn pipelined_trainer_matches_serial_on_goal_conditioned_family() {
    // A mission env: the rollout obs tensors now include the mission
    // feature block, so this pins the goal-conditioning channel bitwise
    // through BatchedEnv (serial oracle) vs ShardedEnv + pipeline +
    // batched featurisation.
    pipelined_matches_serial("Navix-GoToDoor-5x5-v0", 31);
}

/// The batched (non-pipelined) path on a plain BatchedEnv is the same code
/// the default `train` loop runs — pin it against the oracle too.
#[test]
fn batched_trainer_matches_serial_on_batched_env() {
    let cfg = make("Navix-Empty-Random-6x6").unwrap();
    let b = 6;
    let mut env_s = BatchedEnv::new(cfg.clone(), b, Key::new(2));
    let mut env_b = BatchedEnv::new(cfg, b, Key::new(2));
    let mut ppo_s = Ppo::new(ppo_cfg(b), OBS_DIM, 7, 4);
    let mut ppo_b = Ppo::new(ppo_cfg(b), OBS_DIM, 7, 4);
    let mut ro_s = Rollout::new(16, b, OBS_DIM);
    let mut ro_b = Rollout::new(16, b, OBS_DIM);
    let mut tr_s = ReturnTracker::new(64);
    let mut tr_b = ReturnTracker::new(64);
    for iter in 0..2 {
        let ctx = format!("batched iter {iter}");
        ppo_s.collect_rollout_serial(&mut env_s, &mut ro_s, &mut tr_s);
        ppo_b.collect_rollout(&mut env_b, &mut ro_b, &mut tr_b);
        assert_rollouts_equal(&ro_s, &ro_b, &ctx);
        let m_s = ppo_s.update_serial(&ro_s);
        let m_b = ppo_b.update(&ro_b);
        assert_eq!(m_s, m_b, "{ctx}: PpoMetrics");
        assert_eq!(ppo_s.actor.params, ppo_b.actor.params, "{ctx}: actor params");
    }
}
