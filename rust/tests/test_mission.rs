//! Mission-visibility pins: the goal-conditioning subsystem end to end.
//!
//! 1. **State → obs** — for every mission env family the observation
//!    batch's mission channel equals the typed [`Mission`] feature render
//!    of the state, and its present flag is set at every step (autoresets
//!    included); mission-free families keep an all-zero channel.
//! 2. **Engine parity** — mission features are bitwise identical across
//!    `BatchedEnv`, `ShardedEnv{S=3}` and `PipelinedEnv` on shared random
//!    walks.
//! 3. **Learnability** — a short PPO run on GoToDoor-5x5 with the mission
//!    visible must beat the same run with the mission channel zeroed (the
//!    pre-subsystem behaviour, where the mission was write-only state and
//!    the best any policy could do was guess among four doors).

use navix::agents::ppo::{Ppo, PpoConfig};
use navix::agents::OBS_DIM;
use navix::batch::{BatchStepper, BatchedEnv, ObsBatch, PipelinedEnv, ShardedEnv};
use navix::core::mission::{Mission, MISSION_TOKENS};
use navix::core::timestep::BatchedTimestep;
use navix::rng::{Key, Rng};

/// Every registered id whose layout sets a *single-clause* mission (all 19
/// of them — `registry.rs` has a companion state-level pin; keep the two in
/// sync when adding a mission family). The mirror assertion below
/// reconstructs the expected features via `Mission::from_raw`, the lossless
/// 1-clause embedding of the packed column — which by construction drops a
/// second clause, so the sequenced/curriculum families are pinned
/// separately against the token slab
/// (`sequenced_families_stream_the_full_token_slab`).
const MISSION_IDS: [&str; 19] = [
    "Navix-GoToDoor-5x5-v0",
    "Navix-GoToDoor-6x6-v0",
    "Navix-GoToDoor-8x8-v0",
    "Navix-KeyCorridorS3R1-v0",
    "Navix-KeyCorridorS3R2-v0",
    "Navix-KeyCorridorS3R3-v0",
    "Navix-KeyCorridorS4R3-v0",
    "Navix-KeyCorridorS5R3-v0",
    "Navix-KeyCorridorS6R3-v0",
    "Navix-Fetch-5x5-N2-v0",
    "Navix-Fetch-8x8-N3-v0",
    "Navix-Unlock-v0",
    "Navix-UnlockPickup-v0",
    "Navix-BlockedUnlockPickup-v0",
    "Navix-GoToObj-6x6-N2-v0",
    "Navix-GoToObj-8x8-N2-v0",
    "Navix-GoToObj-8x8-N3-v0",
    "Navix-PutNext-6x6-N2-v0",
    "Navix-PutNext-8x8-N3-v0",
];

#[test]
fn mission_channel_mirrors_state_and_is_present_for_every_mission_env() {
    const B: usize = 4;
    for id in MISSION_IDS {
        let mut env = BatchedEnv::new(navix::make(id).unwrap(), B, Key::new(11));
        let mut rng = Rng::new(23);
        let mut actions = vec![0u8; B];
        let mut expect = [0i32; MISSION_TOKENS];
        for step in 0..60 {
            for i in 0..B {
                Mission::from_raw(env.state.mission[i]).write_features(&mut expect);
                assert_eq!(
                    env.obs.mission_row(B, i),
                    &expect[..],
                    "{id} step {step} env {i}: obs mission must mirror the state"
                );
                assert_eq!(
                    env.obs.mission_row(B, i)[0],
                    1,
                    "{id} step {step} env {i}: mission env must expose a nonzero mission vector"
                );
            }
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
        }
    }
}

#[test]
fn mission_free_families_keep_an_all_zero_channel() {
    for id in ["Navix-Empty-8x8-v0", "Navix-DoorKey-6x6-v0", "Navix-LavaGapS5-v0"] {
        let mut env = BatchedEnv::new(navix::make(id).unwrap(), 3, Key::new(5));
        env.rollout_random(40, 9);
        assert!(
            env.obs.mission.iter().all(|&x| x == 0),
            "{id}: goal-only env must not fabricate mission features"
        );
    }
}

#[test]
fn mission_features_are_bitwise_identical_across_all_three_engines() {
    const B: usize = 6;
    const STEPS: usize = 80;
    for id in [
        "Navix-GoToDoor-5x5-v0",
        "Navix-Fetch-5x5-N2-v0",
        "Navix-GoToObj-8x8-N3-v0",
        "Navix-PutNext-6x6-N2-v0",
        "Navix-KeyCorridorS3R2-v0",
    ] {
        let cfg = navix::make(id).unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(3));
        let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(3));
        let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(3)));
        assert_eq!(single.obs.mission, sharded.obs.mission, "{id}: reset mission (sharded)");
        assert_eq!(single.obs.mission, piped.obs().mission, "{id}: reset mission (pipelined)");
        let mut rng = Rng::new(7);
        for step in 0..STEPS {
            let actions: Vec<u8> = (0..B).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            BatchStepper::step(&mut piped, &actions);
            assert_eq!(
                single.obs.mission,
                sharded.obs.mission,
                "{id} step {step}: mission diverged under sharding"
            );
            assert_eq!(
                single.obs.mission,
                piped.obs().mission,
                "{id} step {step}: mission diverged under pipelining"
            );
        }
    }
}

#[test]
fn sequenced_families_stream_the_full_token_slab() {
    // The 2-clause families' pin: the observation mission channel must be
    // the state's token slab verbatim (both clauses + latches), not the
    // 1-clause embedding of the packed column. Checked through autoresets
    // and mid-episode clause advances alike.
    use navix::core::state::AgentView;
    const B: usize = 4;
    for id in [
        "Navix-SeqUnlockPickup-v0",
        "Navix-OpenDoorsOrder-6x6-v0",
        "Navix-Curriculum-RoomGrid-v0",
    ] {
        let mut env = BatchedEnv::new(navix::make(id).unwrap(), B, Key::new(31));
        let mut rng = Rng::new(17);
        let mut actions = vec![0u8; B];
        for step in 0..80 {
            for i in 0..B {
                let s = env.state.slot(i);
                assert_eq!(
                    env.obs.mission_row(B, i),
                    s.mission_tokens_row(),
                    "{id} step {step} env {i}: obs must stream the token slab"
                );
                assert_eq!(
                    env.obs.mission_row(B, i)[0] as usize,
                    s.mission_spec().len(),
                    "{id} step {step} env {i}: token 0 is the clause count"
                );
            }
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
        }
    }
}

#[test]
fn sequenced_families_are_engine_parity_clean_at_one_and_two_agents() {
    // Cross-engine parity for the new families at S=3 shards, for both the
    // classic single-agent shape and the widened A=2 agent axis.
    const B: usize = 6;
    const STEPS: usize = 60;
    for id in ["Navix-SeqUnlockPickup-v0", "Navix-OpenDoorsOrder-6x6-v0"] {
        for a in [1usize, 2] {
            let cfg = navix::make(id).unwrap().with_agents(a);
            let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(13));
            let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(13));
            let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(13)));
            let rows = single.policy_rows();
            let mut rng = Rng::new(29);
            for step in 0..STEPS {
                let actions: Vec<u8> = (0..rows).map(|_| rng.below(7) as u8).collect();
                single.step(&actions);
                sharded.step(&actions);
                BatchStepper::step(&mut piped, &actions);
                assert_eq!(
                    single.obs.mission, sharded.obs.mission,
                    "{id} A={a} step {step}: mission diverged under sharding"
                );
                assert_eq!(
                    single.obs.mission,
                    piped.obs().mission,
                    "{id} A={a} step {step}: mission diverged under pipelining"
                );
                assert_eq!(
                    single.timestep.reward, sharded.timestep.reward,
                    "{id} A={a} step {step}: rewards diverged under sharding"
                );
            }
        }
    }
}

#[test]
fn curriculum_is_bitwise_shard_invariant_across_difficulties() {
    // The curriculum acceptance gate: for the mixed schedule and ≥3 pinned
    // difficulty levels, all three engines agree bitwise on observations,
    // mission tokens, rewards and step types — i.e. the per-slot difficulty
    // draw and the rejection-retry loop are pure functions of the episode
    // key, never of the shard split or pipeline phase.
    use navix::batch::ObsData;
    const B: usize = 6;
    const STEPS: usize = 100;
    for id in [
        "Navix-Curriculum-RoomGrid-v0",
        "Navix-Curriculum-RoomGrid-L0-v0",
        "Navix-Curriculum-RoomGrid-L2-v0",
        "Navix-Curriculum-RoomGrid-L3-v0",
    ] {
        let cfg = navix::make(id).unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(41));
        let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(41));
        let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(41)));
        let mut rng = Rng::new(43);
        for step in 0..STEPS {
            let actions: Vec<u8> = (0..B).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            BatchStepper::step(&mut piped, &actions);
            for (engine, obs, ts) in [
                ("sharded", &sharded.obs, &sharded.timestep),
                ("pipelined", piped.obs(), piped.timestep()),
            ] {
                match (&single.obs.data, &obs.data) {
                    (ObsData::I32(x), ObsData::I32(y)) => {
                        assert_eq!(x, y, "{id} step {step}: obs diverged under {engine}")
                    }
                    (ObsData::U8(x), ObsData::U8(y)) => {
                        assert_eq!(x, y, "{id} step {step}: obs diverged under {engine}")
                    }
                    _ => panic!("{id} step {step}: obs dtypes diverged under {engine}"),
                }
                assert_eq!(
                    single.obs.mission, obs.mission,
                    "{id} step {step}: mission tokens diverged under {engine}"
                );
                assert_eq!(
                    single.timestep.reward, ts.reward,
                    "{id} step {step}: rewards diverged under {engine}"
                );
                assert_eq!(
                    single.timestep.step_type, ts.step_type,
                    "{id} step {step}: step types diverged under {engine}"
                );
            }
        }
    }
}

/// A `BatchedEnv` with the mission channel forcibly zeroed — exactly what
/// every policy saw before the goal-conditioning subsystem existed.
struct MissionBlind {
    inner: BatchedEnv,
    obs: ObsBatch,
}

impl MissionBlind {
    fn new(inner: BatchedEnv) -> MissionBlind {
        let mut obs = inner.obs.clone();
        obs.mission.fill(0);
        MissionBlind { inner, obs }
    }

    fn refresh(&mut self) {
        self.obs.copy_from(&self.inner.obs);
        self.obs.mission.fill(0);
    }
}

impl BatchStepper for MissionBlind {
    fn batch_size(&self) -> usize {
        self.inner.b
    }
    fn step(&mut self, actions: &[u8]) {
        self.inner.step(actions);
        self.refresh();
    }
    fn timestep(&self) -> &BatchedTimestep {
        &self.inner.timestep
    }
    fn obs(&self) -> &ObsBatch {
        &self.obs
    }
    fn reset_all(&mut self) {
        self.inner.reset_all();
        self.refresh();
    }
}

#[test]
fn ppo_with_mission_features_beats_the_mission_blind_baseline_on_go_to_door() {
    // GoToDoor-5x5: four doors, the mission names one. A mission-blind
    // policy can at best learn "walk to some door and declare done" —
    // a ~25% success guess. Seeing the mission makes the task solvable.
    // Everything is deterministic for fixed seeds, so this is a stable pin,
    // not a stochastic benchmark.
    // Budget note: this is the heaviest test in the debug conformance job,
    // so the run is kept as small as the assertion allows — rollout_len 64
    // doubles the update cadence at identical total compute, and 80k steps
    // per run is the least that cleanly separates the two policies.
    let train = |blind: bool| -> f32 {
        let cfg = navix::make("Navix-GoToDoor-5x5-v0").unwrap();
        let pcfg = PpoConfig { num_envs: 16, rollout_len: 64, lr: 1e-3, ..Default::default() };
        let mut ppo = Ppo::new(pcfg, OBS_DIM, 7, 42);
        let env = BatchedEnv::new(cfg, 16, Key::new(7));
        let log = if blind {
            let mut env = MissionBlind::new(env);
            ppo.train(&mut env, 80_000)
        } else {
            let mut env = env;
            ppo.train(&mut env, 80_000)
        };
        log.final_return()
    };
    let aware = train(false);
    let blind = train(true);
    assert!(
        aware > blind,
        "goal-conditioned PPO ({aware:.3}) must beat the mission-blind baseline ({blind:.3})"
    );
    assert!(
        aware > 0.2,
        "goal-conditioned PPO should clearly exceed random guessing, got {aware:.3}"
    );
}

#[test]
fn ppo_reading_the_clause_tokens_beats_blind_on_a_sequenced_family() {
    // OpenDoorsOrder: two doors, the mission orders them, the reward is a
    // flat 1.0 on completing the *sequence*. A mission-blind policy can
    // still finish by hammering toggles at both doors, but it cannot know
    // which door is first — the token-reading policy can, and must come out
    // ahead on identical seeds. Deterministic for fixed seeds (same budget
    // discipline as the GoToDoor pin above; max_steps is clamped so the
    // flat terminal reward recurs often enough inside 80k steps).
    let train = |blind: bool| -> f32 {
        let mut cfg = navix::make("Navix-OpenDoorsOrder-6x6-v0").unwrap();
        cfg.max_steps = 96;
        let pcfg = PpoConfig { num_envs: 16, rollout_len: 64, lr: 1e-3, ..Default::default() };
        let mut ppo = Ppo::new(pcfg, OBS_DIM, 7, 42);
        let env = BatchedEnv::new(cfg, 16, Key::new(7));
        let log = if blind {
            let mut env = MissionBlind::new(env);
            ppo.train(&mut env, 80_000)
        } else {
            let mut env = env;
            ppo.train(&mut env, 80_000)
        };
        log.final_return()
    };
    let aware = train(false);
    let blind = train(true);
    assert!(
        aware > blind,
        "clause-token PPO ({aware:.3}) must beat the mission-blind baseline ({blind:.3}) \
         on the sequenced family"
    );
    assert!(aware > 0.0, "clause-token PPO must complete sequences, got {aware:.3}");
}
