//! Multi-agent engine pins: the `[B × A]` agent axis end to end.
//!
//! 1. **Contested cells** — two agents stepping onto the same free cell
//!    resolve in ascending agent-index order (the engine's documented
//!    tie-break): the lower index wins the cell, the loser stays put and
//!    latches the contact event pair.
//! 2. **Cross-engine parity** — for A ∈ {1, 2, 4} a shared random walk is
//!    bitwise identical across `BatchedEnv`, `ShardedEnv{S=3}` and
//!    `PipelinedEnv` (timesteps, observations, mission features). A = 1
//!    doubles as a regression pin: the agent axis must collapse exactly to
//!    the single-agent engines.
//! 3. **Fused windows** — `step_n` with a Fixed `[K × B·A]` plan equals K
//!    per-step calls on the MA families, batched and sharded.
//! 4. **MARL training** — PPO treats the B·A agent-rows as its policy
//!    batch and produces the identical learning curve through all three
//!    engines on a cooperative MA family.

use navix::agents::ppo::{Ppo, PpoConfig};
use navix::agents::OBS_DIM;
use navix::batch::{
    ActionPlan, BatchStepper, BatchedEnv, ObsCapture, ObsData, PipelinedEnv, ShardedEnv,
    TrajectorySlice,
};
use navix::core::actions::Action;
use navix::core::components::Direction;
use navix::core::grid::Pos;
use navix::rng::{Key, Rng};

#[test]
fn contested_cell_goes_to_the_lowest_agent_index() {
    let cfg = navix::make("Navix-Empty-8x8-v0").unwrap().with_agents(2);
    let mut env = BatchedEnv::new(cfg, 1, Key::new(1));
    {
        // Face both agents at the same free cell (3,3) from opposite sides.
        let mut s = env.state.slot_mut(0);
        s.place_agent(0, Pos::new(3, 2), Direction::East);
        s.place_agent(1, Pos::new(3, 4), Direction::West);
    }
    env.step(&[Action::Forward as u8, Action::Forward as u8]);
    let s = env.state.slot(0);
    assert_eq!(
        Pos::decode(s.player_pos[0], s.w),
        Pos::new(3, 3),
        "agent 0 steps first and wins the contested cell"
    );
    assert_eq!(
        Pos::decode(s.player_pos[1], s.w),
        Pos::new(3, 4),
        "agent 1 must be blocked by agent 0's new position"
    );
    // The blocked move latches the contact pair: mover → agent_contact,
    // blocker → contacted.
    assert!(s.events[1].agent_contact, "blocked mover latches agent_contact");
    assert!(s.events[0].contacted, "the agent standing on the cell latches contacted");
}

#[test]
fn agents_never_stack_after_engine_steps() {
    // Random walk on every MA family: no two agents of a slot may ever
    // occupy the same cell (the transition system's hard invariant).
    for id in ["Navix-MA-FourRooms-Race-v0", "Navix-MA-PutNext-Coop-6x6-N2-v0", "Navix-MA-Tag-8x8-v0"]
    {
        let cfg = navix::make(id).unwrap();
        let mut env = BatchedEnv::new(cfg, 4, Key::new(8));
        let a = env.a;
        let mut rng = Rng::new(19);
        let mut actions = vec![0u8; env.policy_rows()];
        for step in 0..200 {
            for x in actions.iter_mut() {
                *x = rng.below(7) as u8;
            }
            env.step(&actions);
            for i in 0..env.b {
                let col = &env.state.player_pos[i * a..(i + 1) * a];
                for j in 1..a {
                    assert!(
                        !col[..j].contains(&col[j]),
                        "{id} step {step} slot {i}: agents share a cell ({col:?})"
                    );
                }
            }
        }
    }
}

/// Bitwise parity of a shared random walk across the three engines, for a
/// given agent count. `base` must be an A-agnostic layout id.
fn assert_three_engine_parity(base: &str, n_agents: usize) {
    const B: usize = 6;
    const STEPS: usize = 120;
    let cfg = navix::make(base).unwrap().with_agents(n_agents);
    let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(9));
    let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(9));
    let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(9)));
    let rows = single.policy_rows();
    assert_eq!(rows, B * n_agents, "{base}: policy rows must be B·A");
    assert_eq!(BatchStepper::policy_rows(&sharded), rows, "{base}: sharded rows");
    assert_eq!(BatchStepper::policy_rows(&piped), rows, "{base}: pipelined rows");
    let mut rng = Rng::new(4);
    for step in 0..STEPS {
        let actions: Vec<u8> = (0..rows).map(|_| rng.below(7) as u8).collect();
        single.step(&actions);
        sharded.step(&actions);
        BatchStepper::step(&mut piped, &actions);
        let ctx = format!("{base} A={n_agents} step {step}");
        for (name, ts) in [("sharded", &sharded.timestep), ("pipelined", piped.timestep())] {
            assert_eq!(single.timestep.reward, ts.reward, "{ctx}: rewards ({name})");
            assert_eq!(single.timestep.step_type, ts.step_type, "{ctx}: step types ({name})");
            assert_eq!(single.timestep.t, ts.t, "{ctx}: episode clocks ({name})");
            assert_eq!(single.timestep.discount, ts.discount, "{ctx}: discounts ({name})");
        }
        for (name, obs) in [("sharded", &sharded.obs), ("pipelined", piped.obs())] {
            match (&single.obs.data, &obs.data) {
                (ObsData::I32(x), ObsData::I32(y)) => {
                    assert_eq!(x, y, "{ctx}: observations ({name})")
                }
                (ObsData::U8(x), ObsData::U8(y)) => {
                    assert_eq!(x, y, "{ctx}: observations ({name})")
                }
                _ => panic!("{ctx}: obs dtypes diverged ({name})"),
            }
            assert_eq!(single.obs.mission, obs.mission, "{ctx}: mission features ({name})");
        }
    }
}

#[test]
fn engines_agree_bitwise_for_one_two_and_four_agents() {
    for base in ["Navix-Empty-8x8-v0", "Navix-FourRooms-v0"] {
        for n_agents in [1, 2, 4] {
            assert_three_engine_parity(base, n_agents);
        }
    }
}

#[test]
fn ma_families_are_bitwise_identical_across_engines() {
    // The registered MA ids carry their own A, rewards and terminations
    // (team placement, pursuit contact) — walk them through the same
    // three-engine pin without an agent-count override.
    const B: usize = 5;
    const STEPS: usize = 150;
    for id in ["Navix-MA-FourRooms-Race-v0", "Navix-MA-PutNext-Coop-6x6-N2-v0", "Navix-MA-Tag-8x8-v0"]
    {
        let cfg = navix::make(id).unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), B, Key::new(27));
        let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(27));
        let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(27)));
        let rows = single.policy_rows();
        let mut rng = Rng::new(14);
        let mut saw_terminal = false;
        for step in 0..STEPS {
            let actions: Vec<u8> = (0..rows).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            BatchStepper::step(&mut piped, &actions);
            assert_eq!(
                single.timestep.reward, sharded.timestep.reward,
                "{id} step {step}: rewards (sharded)"
            );
            assert_eq!(
                single.timestep.step_type, sharded.timestep.step_type,
                "{id} step {step}: step types (sharded)"
            );
            assert_eq!(
                single.timestep.reward,
                piped.timestep().reward,
                "{id} step {step}: rewards (pipelined)"
            );
            assert_eq!(
                single.timestep.step_type,
                piped.timestep().step_type,
                "{id} step {step}: step types (pipelined)"
            );
            saw_terminal |= single.timestep.step_type.iter().any(|s| s.is_last());
        }
        // Truncation guarantees episode ends whenever the walk outlives the
        // timeout; the longer-T families may legitimately stay mid-episode.
        if single.cfg.max_steps as usize <= STEPS {
            assert!(saw_terminal, "{id}: the walk never ended an episode — dynamics look inert");
        }
    }
}

/// K per-step calls of the oracle, recording each step's rows.
fn reference_window(env: &mut BatchedEnv, plan: &[u8], k: usize) -> TrajectorySlice {
    let rows = env.policy_rows();
    let mut traj = TrajectorySlice::new(ObsCapture::All);
    traj.ensure_like(k, rows, &env.obs);
    for t in 0..k {
        env.step(&plan[t * rows..(t + 1) * rows]);
        traj.record_row(t, &env.timestep);
        traj.capture_obs_row(t, &env.obs);
    }
    traj
}

#[test]
fn fused_windows_match_stepwise_on_multi_agent_families() {
    const B: usize = 4;
    const K: usize = 16;
    for id in ["Navix-MA-FourRooms-Race-v0", "Navix-MA-Tag-8x8-v0"] {
        let cfg = navix::make(id).unwrap();
        let mut fused = BatchedEnv::new(cfg.clone(), B, Key::new(21));
        let mut sharded = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(21));
        let mut reference = BatchedEnv::new(cfg, B, Key::new(21));
        let rows = reference.policy_rows();
        let mut rng = Rng::new(6);
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        let mut straj = TrajectorySlice::new(ObsCapture::All);
        for window in 0..5 {
            let plan: Vec<u8> = (0..K * rows).map(|_| rng.below(7) as u8).collect();
            fused.step_n(ActionPlan::Fixed(&plan), K, &mut traj);
            sharded.step_n(ActionPlan::Fixed(&plan), K, &mut straj);
            let oracle = reference_window(&mut reference, &plan, K);
            let ctx = format!("{id} window {window}");
            assert_eq!(traj.t, oracle.t, "{ctx}: batched fused t");
            assert_eq!(traj.reward, oracle.reward, "{ctx}: batched fused rewards");
            assert_eq!(traj.step_type, oracle.step_type, "{ctx}: batched fused step types");
            assert_eq!(traj.action, oracle.action, "{ctx}: batched fused actions");
            assert_eq!(straj.t, oracle.t, "{ctx}: sharded fused t");
            assert_eq!(straj.reward, oracle.reward, "{ctx}: sharded fused rewards");
            assert_eq!(straj.step_type, oracle.step_type, "{ctx}: sharded fused step types");
        }
    }
}

#[test]
fn ppo_learning_curve_is_identical_through_every_engine_on_an_ma_family() {
    // The acceptance pin for MARL training: PPO sees B·A = 16 agent-rows
    // per step and the three engines feed it bitwise-identical rollouts,
    // so for one seed the whole learning curve must coincide.
    const B: usize = 8;
    const TOTAL: u64 = 4_096;
    let pcfg = || PpoConfig { num_envs: B, rollout_len: 16, ..PpoConfig::default() };
    let cfg = navix::make("Navix-MA-PutNext-Coop-6x6-N2-v0").unwrap();

    let mut env_b = BatchedEnv::new(cfg.clone(), B, Key::new(3));
    let log_b = Ppo::new(pcfg(), OBS_DIM, 7, 12).train(&mut env_b, TOTAL);

    let mut env_s = ShardedEnv::new(cfg.clone(), B, 3, 2, Key::new(3));
    let log_s = Ppo::new(pcfg(), OBS_DIM, 7, 12).train(&mut env_s, TOTAL);

    let mut env_p = PipelinedEnv::over_batched(BatchedEnv::new(cfg, B, Key::new(3)));
    let log_p = Ppo::new(pcfg(), OBS_DIM, 7, 12).train_pipelined(&mut env_p, TOTAL);

    let curve = |log: &navix::agents::TrainLog| -> Vec<f32> {
        log.curve.iter().map(|p| p.mean_return).collect()
    };
    assert!(
        curve(&log_b).iter().all(|r| r.is_finite()),
        "MA PPO produced a non-finite return"
    );
    assert!(!log_b.curve.is_empty(), "MA PPO must record at least one curve point");
    assert_eq!(curve(&log_b), curve(&log_s), "batched vs sharded MARL curves diverged");
    assert_eq!(curve(&log_b), curve(&log_p), "batched vs pipelined MARL curves diverged");
}
