//! Property-style integration tests: long random-action walks over every
//! registered environment, asserting structural invariants that must hold
//! in ANY reachable state. (proptest is not vendored offline; these tests
//! drive the same shrink-free random exploration with the crate's own
//! splittable PRNG — see DESIGN.md §Substitutions.)

use navix::batch::BatchedEnv;
use navix::core::entities::CellType;
use navix::core::grid::Pos;
use navix::core::state::AgentView;
use navix::core::timestep::StepType;
use navix::rng::{Key, Rng};

const WALK_STEPS: usize = 300;

fn check_invariants(env: &BatchedEnv, step: usize) {
    let (b, a) = (env.b, env.a);
    for i in 0..b {
        let id = &env.cfg.id;
        for j in 0..a {
            let s = env.state.agent_slot(i, j);
            let row = i * a + j;
            // agent in bounds, never inside a wall
            let p = s.player();
            assert!(p.in_bounds(s.h, s.w), "{id}@{step}: agent {j} out of bounds {p:?}");
            // A door replaces the cell it sits in (MiniGrid semantics), so
            // an agent may legitimately stand on a wall-base cell through an
            // open door (e.g. GoToDoor's border doors).
            if s.door_at(p).is_none() {
                assert_ne!(s.cell(p), CellType::Wall, "{id}@{step}: agent {j} inside a wall");
            }
            // agent never co-located with a blocking entity or another agent
            assert!(s.key_at(p).is_none(), "{id}@{step}: agent {j} on a key");
            assert!(s.box_at(p).is_none(), "{id}@{step}: agent {j} on a box");
            assert!(
                s.other_agent_at(p).is_none(),
                "{id}@{step}: agents share cell {p:?}"
            );
            if let Some(d) = s.door_at(p) {
                assert_eq!(
                    s.door_state[d], 0,
                    "{id}@{step}: agent {j} standing in a non-open door"
                );
            }
            // entity positions in bounds; no two entities share a cell
            // (slot-level property: checking it once per slot is enough)
            if j == 0 {
                let mut occupied = std::collections::HashSet::new();
                for (name, arr) in [
                    ("door", s.door_pos),
                    ("key", s.key_pos),
                    ("ball", s.ball_pos),
                    ("box", s.box_pos),
                ] {
                    for &enc in arr.iter().filter(|&&x| x >= 0) {
                        let q = Pos::decode(enc, s.w);
                        assert!(q.in_bounds(s.h, s.w), "{id}@{step}: {name} out of bounds");
                        assert!(
                            occupied.insert(enc),
                            "{id}@{step}: two entities share cell {q:?}"
                        );
                    }
                }
            }
            // time consistent with timeout: t can exceed max_steps by at most 0
            assert!(
                env.timestep.t[row] <= env.cfg.max_steps,
                "{id}@{step}: t={} beyond timeout {}",
                env.timestep.t[row],
                env.cfg.max_steps
            );
            // discount/step_type coherence
            match env.timestep.step_type[row] {
                StepType::Terminated => assert_eq!(env.timestep.discount[row], 0.0),
                StepType::Truncated => assert_eq!(env.timestep.discount[row], 1.0),
                StepType::First => {
                    assert_eq!(env.timestep.reward[row], 0.0);
                    assert_eq!(env.timestep.action[row], -1);
                }
                StepType::Mid => {}
            }
            // rewards bounded by the spec (all primitive rewards are in
            // [-1, 1] and every registered env uses at most 2 primitives)
            assert!(
                env.timestep.reward[row].abs() <= 2.0,
                "{id}@{step}: reward {} out of range",
                env.timestep.reward[row]
            );
        }
    }
}

#[test]
fn random_walk_invariants_all_envs() {
    for id in navix::envs::registry::list_envs() {
        let cfg = navix::make(id).unwrap();
        let mut env = BatchedEnv::new(cfg, 4, Key::new(7));
        let mut rng = Rng::new(13);
        // [B × A] action matrix — one row per agent (A=1 for classic envs).
        let mut actions = vec![0u8; env.policy_rows()];
        check_invariants(&env, 0);
        for step in 1..=WALK_STEPS {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
            check_invariants(&env, step);
        }
    }
}

#[test]
fn autoreset_always_follows_terminal() {
    // For every env: whenever step t is terminal, step t+1 must be First.
    for id in ["Navix-Empty-5x5-v0", "Navix-LavaGapS5-v0", "Navix-Dynamic-Obstacles-5x5"] {
        let cfg = navix::make(id).unwrap();
        let mut env = BatchedEnv::new(cfg, 2, Key::new(1));
        let mut rng = Rng::new(2);
        let mut prev_last = vec![false; 2];
        let mut actions = vec![0u8; 2];
        let mut saw_terminal = false;
        for _ in 0..2000 {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            env.step(&actions);
            for i in 0..2 {
                if prev_last[i] {
                    assert_eq!(
                        env.timestep.step_type[i],
                        StepType::First,
                        "{id}: terminal not followed by autoreset"
                    );
                }
                prev_last[i] = env.timestep.step_type[i].is_last();
                saw_terminal |= prev_last[i];
            }
        }
        assert!(saw_terminal, "{id}: random walk never ended an episode");
    }
}

#[test]
fn wall_count_is_invariant_within_episode() {
    // Base grid must never change between resets (only entities move).
    let cfg = navix::make("Navix-DoorKey-8x8-v0").unwrap();
    let mut env = BatchedEnv::new(cfg, 1, Key::new(3));
    let initial_base = env.state.base.clone();
    let mut rng = Rng::new(4);
    for _ in 0..200 {
        let a = rng.below(7) as u8;
        env.step(&[a]);
        if env.timestep.step_type[0] == StepType::First {
            break; // episode ended, base may legitimately change
        }
        assert_eq!(env.state.base, initial_base, "base grid mutated mid-episode");
    }
}

#[test]
fn same_seed_same_trajectory() {
    // Full determinism: same seed + same actions → identical rewards/obs.
    for id in ["Navix-Empty-Random-6x6", "Navix-Dynamic-Obstacles-6x6"] {
        let cfg = navix::make(id).unwrap();
        let mut e1 = BatchedEnv::new(cfg.clone(), 3, Key::new(42));
        let mut e2 = BatchedEnv::new(cfg, 3, Key::new(42));
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let actions: Vec<u8> = (0..3).map(|_| rng.below(7) as u8).collect();
            e1.step(&actions);
            e2.step(&actions);
            assert_eq!(e1.timestep.reward, e2.timestep.reward, "{id}");
            assert_eq!(e1.state.player_pos, e2.state.player_pos, "{id}");
        }
    }
}

#[test]
fn different_seeds_diverge() {
    let cfg = navix::make("Navix-Empty-Random-8x8").unwrap();
    let e1 = BatchedEnv::new(cfg.clone(), 4, Key::new(1));
    let e2 = BatchedEnv::new(cfg, 4, Key::new(2));
    assert_ne!(e1.state.player_pos, e2.state.player_pos);
}

#[test]
fn episodic_return_is_sum_of_rewards() {
    let cfg = navix::make("Navix-Dynamic-Obstacles-5x5").unwrap();
    let mut env = BatchedEnv::new(cfg, 1, Key::new(9));
    let mut rng = Rng::new(10);
    let mut acc = 0.0f32;
    for _ in 0..1000 {
        let a = rng.below(7) as u8;
        env.step(&[a]);
        match env.timestep.step_type[0] {
            StepType::First => acc = 0.0,
            _ => {
                acc += env.timestep.reward[0];
                assert!(
                    (env.timestep.episodic_return[0] - acc).abs() < 1e-5,
                    "return tracking drifted"
                );
            }
        }
    }
}
