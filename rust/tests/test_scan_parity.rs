//! Scan parity: one fused `step_n(K)` window must be **bitwise identical**
//! to K calls of `step` — observations, rewards, terminations, and the
//! in-episode RNG streams — for K ∈ {1, 5, 128} across all three engines
//! (`BatchedEnv`, `ShardedEnv` with 3 shards, `PipelinedEnv`), including
//! episode boundaries landing mid-window (both goal terminations and
//! `max_steps` truncations) and the slot-RNG-stochastic Dynamic-Obstacles
//! family. This is the contract that lets `Ppo::collect_rollout` hand a
//! whole horizon to the engine without changing a single float (the
//! learner-level pin lives in `tests/test_train_parity.rs`).

use navix::batch::{
    ActionPlan, ActionProvider, BatchStepper, BatchedEnv, ObsBatch, ObsCapture, ObsData,
    PipelinedEnv, ShardedEnv, TrajectorySlice,
};
use navix::core::timestep::BatchedTimestep;
use navix::envs::registry::make;
use navix::rng::{Key, Rng};
use navix::systems::observations::ObsKind;

const KS: [usize; 3] = [1, 5, 128];

/// Families swept: deterministic goal env with random starts, the
/// slot-RNG-stochastic obstacles family, and a mission (goal-conditioned)
/// family so the trajectory's mission channel is exercised too.
const ENV_IDS: [&str; 3] =
    ["Navix-Empty-Random-6x6", "Navix-Dynamic-Obstacles-8x8", "Navix-GoToDoor-5x5-v0"];

/// A time-major `[K × B]` random action plan — the same `(t, env)`-order
/// stream for both the fused window and the per-step reference.
fn random_plan(k: usize, b: usize, seed: u64) -> Vec<u8> {
    let mut rng = Rng::new(seed);
    (0..k * b).map(|_| rng.below(7) as u8).collect()
}

/// The K-calls-of-`step` oracle: advance `env` one step at a time,
/// recording every post-step timestep row and observation batch.
fn reference_window<E: BatchStepper + ?Sized>(
    env: &mut E,
    plan: &[u8],
    k: usize,
) -> TrajectorySlice {
    let b = env.batch_size();
    let mut traj = TrajectorySlice::new(ObsCapture::All);
    traj.ensure_like(k, b, env.obs());
    for t in 0..k {
        env.step(&plan[t * b..(t + 1) * b]);
        traj.record_row(t, env.timestep());
        traj.capture_obs_row(t, env.obs());
    }
    traj
}

/// Every field of two capture-`All` windows, compared per step so a
/// mismatch names the first diverging step.
fn assert_windows_equal(a: &TrajectorySlice, b: &TrajectorySlice, ctx: &str) {
    assert_eq!(a.k, b.k, "{ctx}: window length");
    assert_eq!(a.b, b.b, "{ctx}: batch size");
    assert_eq!(a.obs_stride, b.obs_stride, "{ctx}: obs stride");
    for t in 0..a.k {
        assert_eq!(a.reward_row(t), b.reward_row(t), "{ctx}: rewards at step {t}");
        assert_eq!(a.discount_row(t), b.discount_row(t), "{ctx}: discounts at step {t}");
        assert_eq!(a.step_type_row(t), b.step_type_row(t), "{ctx}: step types at step {t}");
        for i in 0..a.b {
            match (&a.obs, &b.obs) {
                (ObsData::I32(_), ObsData::I32(_)) => {
                    assert_eq!(a.obs_i32(t, i), b.obs_i32(t, i), "{ctx}: obs t={t} env={i}");
                }
                (ObsData::U8(_), ObsData::U8(_)) => {
                    assert_eq!(a.obs_u8(t, i), b.obs_u8(t, i), "{ctx}: obs t={t} env={i}");
                }
                _ => panic!("{ctx}: obs dtype diverged"),
            }
            assert_eq!(a.mission_row(t, i), b.mission_row(t, i), "{ctx}: mission t={t} env={i}");
        }
    }
    assert_eq!(a.t, b.t, "{ctx}: steps-since-reset");
    assert_eq!(a.action, b.action, "{ctx}: recorded actions");
    assert_eq!(a.episodic_return, b.episodic_return, "{ctx}: episodic returns");
}

/// The engines' mirrors after the window: post-window timestep + final obs.
fn assert_mirrors_equal(a: &mut dyn BatchStepper, b: &mut dyn BatchStepper, ctx: &str) {
    let (ta, tb) = (a.timestep().clone(), b.timestep().clone());
    assert_eq!(ta.t, tb.t, "{ctx}: final t");
    assert_eq!(ta.reward, tb.reward, "{ctx}: final reward");
    assert_eq!(ta.step_type, tb.step_type, "{ctx}: final step_type");
    match (&a.obs().data, &b.obs().data) {
        (ObsData::I32(x), ObsData::I32(y)) => assert_eq!(x, y, "{ctx}: final obs"),
        (ObsData::U8(x), ObsData::U8(y)) => assert_eq!(x, y, "{ctx}: final obs"),
        _ => panic!("{ctx}: obs dtype diverged"),
    }
    assert_eq!(a.obs().mission, b.obs().mission, "{ctx}: final mission");
}

#[test]
fn batched_step_n_is_bitwise_equal_to_k_steps() {
    for id in ENV_IDS {
        let cfg = make(id).unwrap();
        for k in KS {
            let b = 5;
            let mut fused = BatchedEnv::new(cfg.clone(), b, Key::new(11));
            let mut reference = BatchedEnv::new(cfg.clone(), b, Key::new(11));
            let plan = random_plan(k, b, 0xD1CE);
            let mut traj = TrajectorySlice::new(ObsCapture::All);
            fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
            let oracle = reference_window(&mut reference, &plan, k);
            let ctx = format!("{id} K={k}");
            assert_windows_equal(&traj, &oracle, &ctx);
            assert_mirrors_equal(&mut fused, &mut reference, &ctx);
            // The in-episode RNG streams (one u64 state per slot) must have
            // advanced identically — the fused path derives the exact same
            // per-step keys, not just the same visible outputs.
            assert_eq!(fused.state.rng, reference.state.rng, "{ctx}: slot RNG state");
        }
    }
}

#[test]
fn sharded_s3_one_epoch_per_window_matches_per_step_epochs() {
    for id in ENV_IDS {
        let cfg = make(id).unwrap();
        for k in KS {
            let b = 10; // 3 shards over 10 envs: sizes 4/3/3 — uneven on purpose
            let mut fused = ShardedEnv::new(cfg.clone(), b, 3, 2, Key::new(11));
            let mut reference = ShardedEnv::new(cfg.clone(), b, 3, 2, Key::new(11));
            let plan = random_plan(k, b, 0xD1CE);
            let mut traj = TrajectorySlice::new(ObsCapture::All);
            fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
            let oracle = reference_window(&mut reference, &plan, k);
            let ctx = format!("sharded {id} K={k}");
            assert_windows_equal(&traj, &oracle, &ctx);
            assert_mirrors_equal(&mut fused, &mut reference, &ctx);
            for s in 0..fused.shard_bounds().len() {
                let rng_a = fused.with_shard(s, |e| e.state.rng.clone());
                let rng_b = reference.with_shard(s, |e| e.state.rng.clone());
                assert_eq!(rng_a, rng_b, "{ctx}: shard {s} slot RNG state");
            }
        }
    }
}

#[test]
fn pipelined_window_round_trip_matches_per_step_submit_sync() {
    for id in ENV_IDS {
        let cfg = make(id).unwrap();
        for k in KS {
            let b = 6;
            let mut fused =
                PipelinedEnv::over_batched(BatchedEnv::new(cfg.clone(), b, Key::new(11)));
            let mut reference =
                PipelinedEnv::over_batched(BatchedEnv::new(cfg.clone(), b, Key::new(11)));
            let plan = random_plan(k, b, 0xD1CE);
            let mut traj = TrajectorySlice::new(ObsCapture::All);
            fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
            let oracle = reference_window(&mut reference, &plan, k);
            let ctx = format!("pipelined {id} K={k}");
            assert_windows_equal(&traj, &oracle, &ctx);
            assert_mirrors_equal(&mut fused, &mut reference, &ctx);
            // RNG-continuation probe: the stepper thread's engine state is
            // not directly visible, so step both once more — identical
            // successors prove identical hidden state.
            let probe = random_plan(1, b, 0xFACE);
            fused.step(&probe);
            reference.step(&probe);
            assert_mirrors_equal(&mut fused, &mut reference, &format!("{ctx} probe"));
        }
    }
}

#[test]
fn episode_boundaries_mid_window_stay_bitwise_identical() {
    // Truncate every episode after 6 steps: a K=128 window then contains
    // ~21 boundary rows per env, none aligned to the window edges, so the
    // fused path's autoreset + fresh-episode-key handling is exercised far
    // from the easy start-of-window case.
    let mut cfg = make("Navix-Empty-Random-6x6").unwrap();
    cfg.max_steps = 6;
    let (k, b) = (128, 4);
    let plan = random_plan(k, b, 0xB0B);
    let mut fused = BatchedEnv::new(cfg.clone(), b, Key::new(2));
    let mut reference = BatchedEnv::new(cfg.clone(), b, Key::new(2));
    let mut traj = TrajectorySlice::new(ObsCapture::All);
    fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
    let oracle = reference_window(&mut reference, &plan, k);
    // Sanity: the window genuinely contains interior boundaries.
    let interior_lasts = (1..k - 1)
        .flat_map(|t| oracle.step_type_row(t))
        .filter(|st| st.is_last())
        .count();
    assert!(interior_lasts > 10, "expected many mid-window episode ends, got {interior_lasts}");
    assert_windows_equal(&traj, &oracle, "mid-window boundaries");
    assert_eq!(fused.state.rng, reference.state.rng, "slot RNG after boundary-heavy window");

    // Same shape through the sharded engine's one-epoch-per-window path.
    let mut fused = ShardedEnv::new(cfg.clone(), b, 3, 2, Key::new(2));
    let mut traj = TrajectorySlice::new(ObsCapture::All);
    fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
    assert_windows_equal(&traj, &oracle, "mid-window boundaries (sharded)");
}

#[test]
fn final_capture_skips_interior_obs_but_lands_on_the_exact_frame() {
    // ObsCapture::Final is the throughput mode: interior observations are
    // never written. The final frame and all metadata must still match the
    // per-step oracle — including dirty-tile rgb, whose per-tile cache must
    // not be confused by the skipped blits.
    for kind in [ObsKind::SymbolicFirstPerson, ObsKind::Rgb] {
        let cfg = make("Navix-Empty-Random-6x6").unwrap().with_observation(kind);
        let (k, b) = (9, 4);
        let plan = random_plan(k, b, 0x5EED);
        let mut fused = BatchedEnv::new(cfg.clone(), b, Key::new(4));
        let mut reference = BatchedEnv::new(cfg.clone(), b, Key::new(4));
        let mut traj = TrajectorySlice::new(ObsCapture::Final);
        fused.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
        let oracle = reference_window(&mut reference, &plan, k);
        let ctx = format!("final capture {kind:?}");
        // Metadata is still recorded for every step...
        for t in 0..k {
            assert_eq!(traj.reward_row(t), oracle.reward_row(t), "{ctx}: rewards at {t}");
            assert_eq!(
                traj.step_type_row(t),
                oracle.step_type_row(t),
                "{ctx}: step types at {t}"
            );
        }
        // ...and the engine's final frame is bitwise the oracle's.
        assert_mirrors_equal(&mut fused, &mut reference, &ctx);
        assert_eq!(fused.state.rng, reference.state.rng, "{ctx}: slot RNG state");
    }
}

/// Replays a fixed `[K × B]` matrix through the provider interface,
/// verifying the pre-step snapshots the engine hands the callback.
struct Replay<'p> {
    plan: &'p [u8],
    b: usize,
    calls: usize,
    overlaps: usize,
}

impl ActionProvider for Replay<'_> {
    fn actions(&mut self, t: usize, obs: &ObsBatch, ts: &BatchedTimestep, out: &mut [u8]) {
        assert_eq!(ts.reward.len(), self.b, "provider sees the engine's timestep");
        assert_eq!(obs.mission.len() % self.b, 0, "provider sees the engine's obs batch");
        out.copy_from_slice(&self.plan[t * self.b..(t + 1) * self.b]);
        self.calls += 1;
    }

    fn overlap(&mut self, _t: usize) {
        self.overlaps += 1;
    }
}

#[test]
fn provider_plan_reproduces_the_fixed_plan_on_every_engine() {
    let cfg = make("Navix-Empty-Random-6x6").unwrap();
    let (k, b) = (17, 6);
    let plan = random_plan(k, b, 0xCAFE);
    let fixed_oracle = {
        let mut env = BatchedEnv::new(cfg.clone(), b, Key::new(6));
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        env.step_n(ActionPlan::Fixed(&plan), k, &mut traj);
        traj
    };
    let mut engines: Vec<(&str, Box<dyn BatchStepper>)> = vec![
        ("batched", Box::new(BatchedEnv::new(cfg.clone(), b, Key::new(6)))),
        ("sharded", Box::new(ShardedEnv::new(cfg.clone(), b, 3, 2, Key::new(6)))),
        (
            "pipelined",
            Box::new(PipelinedEnv::over_batched(BatchedEnv::new(cfg.clone(), b, Key::new(6)))),
        ),
    ];
    for (name, env) in engines.iter_mut() {
        let mut replay = Replay { plan: &plan, b, calls: 0, overlaps: 0 };
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        env.step_n(ActionPlan::Provider(&mut replay), k, &mut traj);
        assert_eq!(replay.calls, k, "{name}: one actions() call per step");
        assert_eq!(replay.overlaps, k, "{name}: one overlap() call per step");
        assert_windows_equal(&traj, &fixed_oracle, &format!("provider vs fixed ({name})"));
    }
}
