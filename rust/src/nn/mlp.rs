//! Multi-layer perceptron with hand-derived backprop over flat parameter
//! storage. Layout per layer: `W (out×in, row-major) ++ b (out)`.

use crate::rng::Rng;

/// Hidden-layer activation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
}

impl Activation {
    #[inline]
    fn f(self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }

    /// Derivative expressed in terms of the *activated* output `y`.
    #[inline]
    fn df_from_y(self, y: f32) -> f32 {
        match self {
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => 1.0 - y * y,
        }
    }
}

/// An MLP: `dims = [in, h1, …, out]`, linear final layer.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    pub act: Activation,
    pub params: Vec<f32>,
}

/// Per-forward activation cache for backprop (one per concurrent sample).
#[derive(Clone, Debug, Default)]
pub struct Cache {
    /// Activations per layer, `acts[0]` = input, `acts[L]` = output.
    pub acts: Vec<Vec<f32>>,
}

impl Mlp {
    /// Total parameter count for `dims`.
    pub fn param_count(dims: &[usize]) -> usize {
        dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Orthogonal-ish init: scaled He-normal weights, zero biases (matches
    /// the scale the paper's Rejax baselines use closely enough for 2×64
    /// nets).
    pub fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Mlp {
        let mut params = vec![0.0; Mlp::param_count(dims)];
        let mut off = 0;
        for w in dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / fan_in as f64).sqrt();
            for p in params[off..off + fan_in * fan_out].iter_mut() {
                *p = (rng.normal() * scale) as f32;
            }
            off += fan_in * fan_out + fan_out; // biases stay zero
        }
        Mlp { dims: dims.to_vec(), act, params }
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Forward pass; fills `cache` and returns the output activations.
    pub fn forward(&self, x: &[f32], cache: &mut Cache) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dims[0]);
        cache.acts.clear();
        cache.acts.push(x.to_vec());
        let mut off = 0;
        let mut cur = x.to_vec();
        for (li, wpair) in self.dims.windows(2).enumerate() {
            let (nin, nout) = (wpair[0], wpair[1]);
            let w = &self.params[off..off + nin * nout];
            let b = &self.params[off + nin * nout..off + nin * nout + nout];
            let mut next = vec![0.0f32; nout];
            for o in 0..nout {
                let row = &w[o * nin..(o + 1) * nin];
                let mut acc = b[o];
                for i in 0..nin {
                    acc += row[i] * cur[i];
                }
                next[o] =
                    if li + 1 < self.n_layers() { self.act.f(acc) } else { acc };
            }
            off += nin * nout + nout;
            cache.acts.push(next.clone());
            cur = next;
        }
        cur
    }

    /// Inference without caching.
    pub fn infer(&self, x: &[f32]) -> Vec<f32> {
        let mut cache = Cache::default();
        self.forward(x, &mut cache)
    }

    /// Backward pass: `grad_out` is ∂L/∂output; accumulates parameter
    /// gradients into `grads` (same layout as `params`) and returns
    /// ∂L/∂input.
    pub fn backward(&self, cache: &Cache, grad_out: &[f32], grads: &mut [f32]) -> Vec<f32> {
        debug_assert_eq!(grads.len(), self.params.len());
        let n_layers = self.n_layers();
        // Parameter offsets per layer.
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for w in self.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }

        let mut delta = grad_out.to_vec();
        for li in (0..n_layers).rev() {
            let (nin, nout) = (self.dims[li], self.dims[li + 1]);
            let input = &cache.acts[li];
            let output = &cache.acts[li + 1];
            // activation derivative (hidden layers only)
            if li + 1 < n_layers {
                for o in 0..nout {
                    delta[o] *= self.act.df_from_y(output[o]);
                }
            }
            let off = offsets[li];
            let (gw, gb) = {
                let (a, b) = grads[off..off + nin * nout + nout].split_at_mut(nin * nout);
                (a, b)
            };
            for o in 0..nout {
                let d = delta[o];
                gb[o] += d;
                let row = &mut gw[o * nin..(o + 1) * nin];
                for i in 0..nin {
                    row[i] += d * input[i];
                }
            }
            // propagate
            if li > 0 {
                let w = &self.params[off..off + nin * nout];
                let mut prev = vec![0.0f32; nin];
                for o in 0..nout {
                    let d = delta[o];
                    let row = &w[o * nin..(o + 1) * nin];
                    for i in 0..nin {
                        prev[i] += d * row[i];
                    }
                }
                delta = prev;
            } else {
                let w = &self.params[off..off + nin * nout];
                let mut prev = vec![0.0f32; nin];
                for o in 0..nout {
                    let d = delta[o];
                    let row = &w[o * nin..(o + 1) * nin];
                    for i in 0..nin {
                        prev[i] += d * row[i];
                    }
                }
                return prev;
            }
        }
        unreachable!()
    }

    /// Polyak/copy update from another network (target networks).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        debug_assert_eq!(self.params.len(), src.params.len());
        for (t, s) in self.params.iter_mut().zip(&src.params) {
            *t = (1.0 - tau) * *t + tau * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(act: Activation) {
        let mut rng = Rng::new(42);
        let dims = [5, 8, 8, 3];
        let mlp = Mlp::new(&dims, act, &mut rng);
        let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
        // Loss: L = sum(out^2)/2 so dL/dout = out.
        let mut cache = Cache::default();
        let out = mlp.forward(&x, &mut cache);
        let mut grads = vec![0.0; mlp.params.len()];
        let gin = mlp.backward(&cache, &out, &mut grads);

        let loss = |m: &Mlp, x: &[f32]| -> f64 {
            let o = m.infer(x);
            o.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
        };
        // parameter gradients (spot-check a spread of indices)
        let eps = 1e-3f32;
        for idx in (0..mlp.params.len()).step_by(17) {
            let mut mp = mlp.clone();
            mp.params[idx] += eps;
            let mut mm = mlp.clone();
            mm.params[idx] -= eps;
            let num = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps as f64);
            let ana = grads[idx] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                "param {idx}: numeric {num} vs analytic {ana} ({act:?})"
            );
        }
        // input gradient
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&mlp, &xp) - loss(&mlp, &xm)) / (2.0 * eps as f64);
            let ana = gin[i] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                "input {i}: numeric {num} vs analytic {ana} ({act:?})"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences_relu() {
        finite_diff_check(Activation::Relu);
    }

    #[test]
    fn gradients_match_finite_differences_tanh() {
        finite_diff_check(Activation::Tanh);
    }

    #[test]
    fn param_count() {
        assert_eq!(Mlp::param_count(&[147, 64, 64, 7]), 147 * 64 + 64 + 64 * 64 + 64 + 64 * 7 + 7);
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let mut rng = Rng::new(0);
        let mlp = Mlp::new(&[4, 16, 2], Activation::Relu, &mut rng);
        let a = mlp.infer(&[1.0, -1.0, 0.5, 2.0]);
        let b = mlp.infer(&[1.0, -1.0, 0.5, 2.0]);
        assert_eq!(a.len(), 2);
        assert_eq!(a, b);
    }

    #[test]
    fn soft_update_interpolates() {
        let mut rng = Rng::new(1);
        let src = Mlp::new(&[2, 3, 1], Activation::Relu, &mut rng);
        let mut dst = Mlp::new(&[2, 3, 1], Activation::Relu, &mut rng);
        let before = dst.params.clone();
        dst.soft_update_from(&src, 0.5);
        for i in 0..before.len() {
            let expect = 0.5 * before[i] + 0.5 * src.params[i];
            assert!((dst.params[i] - expect).abs() < 1e-6);
        }
        dst.soft_update_from(&src, 1.0);
        assert_eq!(dst.params, src.params);
    }
}
