//! Multi-layer perceptron with hand-derived backprop over flat parameter
//! storage. Layout per layer: `W (out×in, row-major) ++ b (out)`.
//!
//! Two execution paths share the parameters:
//!
//! * the **row path** ([`Mlp::forward`]/[`Mlp::backward`]) — one sample at a
//!   time, the original implementation, kept as the bitwise oracle;
//! * the **batch path** ([`Mlp::forward_batch`]/[`Mlp::backward_batch`]) —
//!   `[B, dim]` row-major buffers through a register-blocked GEMM
//!   microkernel with preallocated activation workspaces ([`BatchCache`]),
//!   the hot path of every trainer since PR 4.
//!
//! The batch kernels are deliberately **not** reduction-blocked: every
//! output accumulator runs its dot product over the full input dimension in
//! ascending order, and parameter-gradient accumulation loops samples in
//! ascending order, so the batch path is *bit-for-bit identical* to running
//! the row path sample by sample (pinned by tests here and by
//! `tests/test_train_parity.rs`). Blocking is over the independent axes
//! only: output tiles of 4 (register blocking — the input row is fetched
//! once per 4 dot products) and the natural sample-major sweep that keeps
//! each weight row hot across the batch.
//!
//! On top of the scalar microkernel, the batch path dispatches on a
//! [`KernelPath`] ([`BatchCache::kernel`], defaulting to the process-wide
//! [`simd::active`] probe): AVX2/SSE2 [`kernels`] vectorise the
//! *independent* axes only — output columns forward, input columns
//! backward — while every reduction keeps the scalar order, and FMA is
//! deliberately unused. Each vector lane therefore performs the exact
//! scalar `mul`→`add` sequence, so the SIMD paths remain bit-for-bit
//! identical to the row path (pinned per forced path by the tests here
//! and the CI `simd-matrix` job).

use crate::rng::Rng;
use crate::simd::{self, KernelPath};

/// Hidden-layer activation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Relu,
    Tanh,
}

impl Activation {
    #[inline]
    fn f(self, x: f32) -> f32 {
        match self {
            Activation::Relu => x.max(0.0),
            Activation::Tanh => x.tanh(),
        }
    }

    /// Derivative expressed in terms of the *activated* output `y`.
    #[inline]
    fn df_from_y(self, y: f32) -> f32 {
        match self {
            Activation::Relu => {
                if y > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Tanh => 1.0 - y * y,
        }
    }
}

/// An MLP: `dims = [in, h1, …, out]`, linear final layer.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub dims: Vec<usize>,
    pub act: Activation,
    pub params: Vec<f32>,
}

/// Per-forward activation cache for backprop (one per concurrent sample).
#[derive(Clone, Debug, Default)]
pub struct Cache {
    /// Activations per layer, `acts[0]` = input, `acts[L]` = output.
    pub acts: Vec<Vec<f32>>,
}

/// Reusable workspace for the batch path: per-layer `[B, dim]` activation
/// buffers for backprop, the transposed-weight buffers the backward pass
/// streams, and the two delta planes. All buffers are grown on first use
/// and reused across calls, so a training loop performs **zero** NN-side
/// heap allocation after the first minibatch.
#[derive(Clone, Debug)]
pub struct BatchCache {
    /// Activations per layer, `acts[l]` is `[bsz × dims[l]]` row-major.
    pub acts: Vec<Vec<f32>>,
    /// Batch size of the most recent [`Mlp::forward_batch`].
    pub bsz: usize,
    /// Per-layer transposed weights (`[in × out]`), rebuilt by
    /// [`Mlp::backward_batch`] each call (Adam mutates the weights between
    /// minibatches, so there is nothing stale to reuse — the win is the
    /// reused allocation and the contiguous `[in][out]` rows that turn the
    /// delta back-propagation into straight dot products). Scalar path
    /// only; the vector path streams the original row-major weights.
    wt: Vec<Vec<f32>>,
    /// Per-layer forward-transposed weights (`[in × out]`) for the vector
    /// forward kernel — one contiguous load per output-column block per
    /// input element. Rebuilt by [`Mlp::forward_batch`] each call, for the
    /// same reason as `wt`. Vector paths only.
    fwt: Vec<Vec<f32>>,
    /// Delta planes (`[bsz × max_dim]`), double-buffered across layers.
    d_cur: Vec<f32>,
    d_nxt: Vec<f32>,
    /// Which kernel path the batch GEMMs run, clamped to the CPU at
    /// dispatch time. Defaults to the process-wide probe
    /// ([`simd::active`]); the bitwise unit tests force specific paths
    /// here.
    pub kernel: KernelPath,
}

impl Default for BatchCache {
    fn default() -> Self {
        BatchCache {
            acts: Vec::new(),
            bsz: 0,
            wt: Vec::new(),
            fwt: Vec::new(),
            d_cur: Vec::new(),
            d_nxt: Vec::new(),
            kernel: simd::active(),
        }
    }
}

impl BatchCache {
    /// Output activations of the most recent forward: `[bsz × out_dim]`.
    pub fn out(&self) -> &[f32] {
        self.acts.last().map(|v| v.as_slice()).unwrap_or(&[])
    }
}

/// Grow-only resize for reusable workspace buffers (never shrinks, so
/// repeated calls at the usual fixed sizes are free). Shared by this
/// module's caches and the trainers' workspaces (re-exported through
/// [`crate::agents`]).
pub(crate) fn ensure<T: Clone + Default>(v: &mut Vec<T>, n: usize) {
    if v.len() < n {
        v.resize(n, T::default());
    }
}

/// The forward microkernel: `out[s][o] = act(bias[o] + Σ_i w[o][i]·x[s][i])`
/// for a `[bsz × nin]` input block. Register-blocked over the output
/// dimension (4 independent accumulators share one pass over the input
/// row); the reduction runs the full `nin` in ascending order per output,
/// which is exactly the summation order of the row path — see module docs.
fn dense_forward(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    out: &mut [f32],
    bsz: usize,
    nin: usize,
    nout: usize,
    act: Option<Activation>,
) {
    for s in 0..bsz {
        let xr = &x[s * nin..(s + 1) * nin];
        let or = &mut out[s * nout..(s + 1) * nout];
        let mut o = 0;
        while o + 4 <= nout {
            let w0 = &w[o * nin..(o + 1) * nin];
            let w1 = &w[(o + 1) * nin..(o + 2) * nin];
            let w2 = &w[(o + 2) * nin..(o + 3) * nin];
            let w3 = &w[(o + 3) * nin..(o + 4) * nin];
            let (mut a0, mut a1, mut a2, mut a3) =
                (bias[o], bias[o + 1], bias[o + 2], bias[o + 3]);
            for i in 0..nin {
                let xi = xr[i];
                a0 += w0[i] * xi;
                a1 += w1[i] * xi;
                a2 += w2[i] * xi;
                a3 += w3[i] * xi;
            }
            match act {
                Some(a) => {
                    or[o] = a.f(a0);
                    or[o + 1] = a.f(a1);
                    or[o + 2] = a.f(a2);
                    or[o + 3] = a.f(a3);
                }
                None => {
                    or[o] = a0;
                    or[o + 1] = a1;
                    or[o + 2] = a2;
                    or[o + 3] = a3;
                }
            }
            o += 4;
        }
        while o < nout {
            let row = &w[o * nin..(o + 1) * nin];
            let mut acc = bias[o];
            for i in 0..nin {
                acc += row[i] * xr[i];
            }
            or[o] = match act {
                Some(a) => a.f(acc),
                None => acc,
            };
            o += 1;
        }
    }
}

/// `wt[i][o] = w[o][i]` — exact element copies, so accumulating from
/// either layout yields bitwise-identical products.
fn transpose(w: &[f32], wt: &mut [f32], nin: usize, nout: usize) {
    for o in 0..nout {
        for i in 0..nin {
            wt[i * nout + o] = w[o * nin + i];
        }
    }
}

/// The vector GEMM kernels: f32 `std::arch` paths for the three hot loops
/// (forward accumulate, parameter-gradient accumulate, delta
/// back-propagation), dispatched by [`KernelPath`].
///
/// **Bitwise-identity contract.** Every kernel vectorises only an
/// *independent* axis — output columns in the forward pass, input columns
/// in the gradient/delta passes — while each reduction runs sequentially
/// in exactly the scalar order (ascending input index / ascending output
/// index / ascending sample index). Each lane therefore performs the same
/// `mul` → `add` sequence, on the same values, in the same order as the
/// scalar code; with FMA deliberately unused (separate `_mm*_mul_ps` +
/// `_mm*_add_ps`, each IEEE-754 correctly rounded exactly like the scalar
/// `*` and `+`), every intermediate f32 is identical, and the batch path
/// stays bit-for-bit equal to the per-sample oracle on every path.
/// Activations are applied scalar-ly after the accumulate (`f32::max` and
/// `_mm*_max_ps` disagree on ±0.0, and `tanh` has no vector form). Column
/// counts not divisible by the lane width fall through to scalar tails
/// with the same reduction order.
///
/// `unsafe` is confined to this module (the workspace denies it
/// elsewhere): the only unsafe operations are `std::arch` intrinsics and
/// raw-pointer loads/stores whose bounds are established by the
/// `+ LANES <= n` loop guards, and every `#[target_feature]` entry point
/// is reachable only after [`simd::effective`] clamps the requested path
/// to what the CPU probe found.
#[allow(unsafe_code)]
mod kernels {
    use super::Activation;
    use crate::simd::KernelPath;

    /// Forward microkernel over forward-transposed weights `wt`
    /// (`[in × out]`): `out[s][o] = act(bias[o] + Σ_i wt[i][o]·x[s][i])`.
    /// `kp` must already be clamped via [`crate::simd::effective`].
    #[allow(clippy::too_many_arguments)]
    pub fn dense_forward_vec(
        kp: KernelPath,
        x: &[f32],
        wt: &[f32],
        bias: &[f32],
        out: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
        act: Option<Activation>,
    ) {
        debug_assert!(x.len() >= bsz * nin && out.len() >= bsz * nout);
        debug_assert!(wt.len() >= nin * nout && bias.len() >= nout);
        match kp {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => unsafe {
                dense_forward_avx2(x, wt, bias, out, bsz, nin, nout, act)
            },
            #[cfg(target_arch = "x86_64")]
            KernelPath::Sse2 => unsafe {
                dense_forward_sse2(x, wt, bias, out, bsz, nin, nout, act)
            },
            // Defensive fallback (dispatchers route Scalar to the row-major
            // microkernel before calling here): the same accumulation over
            // the transposed layout — identical values, identical order.
            _ => {
                for s in 0..bsz {
                    let xr = &x[s * nin..(s + 1) * nin];
                    let or = &mut out[s * nout..(s + 1) * nout];
                    for o in 0..nout {
                        let mut acc = bias[o];
                        for i in 0..nin {
                            acc += wt[i * nout + o] * xr[i];
                        }
                        or[o] = match act {
                            Some(a) => a.f(acc),
                            None => acc,
                        };
                    }
                }
            }
        }
    }

    /// Parameter-gradient microkernel: `gb[o] += δ[s][o]` and
    /// `gw[o][i] += δ[s][o]·x[s][i]`, samples ascending. Vectorised over
    /// the input columns of each weight row — an independent axis; the
    /// per-parameter sample reduction order is unchanged.
    pub fn grad_params_vec(
        kp: KernelPath,
        delta: &[f32],
        input: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        debug_assert!(delta.len() >= bsz * nout && input.len() >= bsz * nin);
        debug_assert!(gw.len() >= nin * nout && gb.len() >= nout);
        match kp {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => unsafe { grad_params_avx2(delta, input, gw, gb, bsz, nin, nout) },
            #[cfg(target_arch = "x86_64")]
            KernelPath::Sse2 => unsafe { grad_params_sse2(delta, input, gw, gb, bsz, nin, nout) },
            _ => {
                for s in 0..bsz {
                    let dr = &delta[s * nout..(s + 1) * nout];
                    let xr = &input[s * nin..(s + 1) * nin];
                    for o in 0..nout {
                        let d = dr[o];
                        gb[o] += d;
                        let row = &mut gw[o * nin..(o + 1) * nin];
                        for i in 0..nin {
                            row[i] += d * xr[i];
                        }
                    }
                }
            }
        }
    }

    /// Delta back-propagation: `prev[s][i] = Σ_o δ[s][o]·w[o][i]` with the
    /// o-sum ascending, straight from the row-major weights (lane `i+k`
    /// reads `w[o][i+k]` contiguously). Vectorised over input columns —
    /// the independent axis.
    pub fn backprop_delta_vec(
        kp: KernelPath,
        delta: &[f32],
        w: &[f32],
        prev: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        debug_assert!(delta.len() >= bsz * nout && prev.len() >= bsz * nin);
        debug_assert!(w.len() >= nin * nout);
        match kp {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => unsafe { backprop_delta_avx2(delta, w, prev, bsz, nin, nout) },
            #[cfg(target_arch = "x86_64")]
            KernelPath::Sse2 => unsafe { backprop_delta_sse2(delta, w, prev, bsz, nin, nout) },
            _ => {
                for s in 0..bsz {
                    let dr = &delta[s * nout..(s + 1) * nout];
                    let pr = &mut prev[s * nin..(s + 1) * nin];
                    for i in 0..nin {
                        let mut acc = 0.0f32;
                        for (o, &d) in dr.iter().enumerate() {
                            acc += d * w[o * nin + i];
                        }
                        pr[i] = acc;
                    }
                }
            }
        }
    }

    /// # Safety
    /// The CPU must support avx2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn dense_forward_avx2(
        x: &[f32],
        wt: &[f32],
        bias: &[f32],
        out: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
        act: Option<Activation>,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let xr = &x[s * nin..(s + 1) * nin];
            let or = &mut out[s * nout..(s + 1) * nout];
            let mut o = 0;
            // 4 accumulator vectors (32 columns) per pass: the reduction
            // chain per lane stays sequential in i — blocking only adds
            // instruction-level parallelism across *independent* columns.
            while o + 32 <= nout {
                let bp = bias.as_ptr().add(o);
                let mut a0 = _mm256_loadu_ps(bp);
                let mut a1 = _mm256_loadu_ps(bp.add(8));
                let mut a2 = _mm256_loadu_ps(bp.add(16));
                let mut a3 = _mm256_loadu_ps(bp.add(24));
                for (i, &xi) in xr.iter().enumerate() {
                    let xv = _mm256_set1_ps(xi);
                    let wp = wt.as_ptr().add(i * nout + o);
                    a0 = _mm256_add_ps(a0, _mm256_mul_ps(_mm256_loadu_ps(wp), xv));
                    a1 = _mm256_add_ps(a1, _mm256_mul_ps(_mm256_loadu_ps(wp.add(8)), xv));
                    a2 = _mm256_add_ps(a2, _mm256_mul_ps(_mm256_loadu_ps(wp.add(16)), xv));
                    a3 = _mm256_add_ps(a3, _mm256_mul_ps(_mm256_loadu_ps(wp.add(24)), xv));
                }
                let op = or.as_mut_ptr().add(o);
                _mm256_storeu_ps(op, a0);
                _mm256_storeu_ps(op.add(8), a1);
                _mm256_storeu_ps(op.add(16), a2);
                _mm256_storeu_ps(op.add(24), a3);
                o += 32;
            }
            while o + 8 <= nout {
                let mut acc = _mm256_loadu_ps(bias.as_ptr().add(o));
                for (i, &xi) in xr.iter().enumerate() {
                    let wv = _mm256_loadu_ps(wt.as_ptr().add(i * nout + o));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(wv, _mm256_set1_ps(xi)));
                }
                _mm256_storeu_ps(or.as_mut_ptr().add(o), acc);
                o += 8;
            }
            while o < nout {
                let mut acc = bias[o];
                for (i, &xi) in xr.iter().enumerate() {
                    acc += wt[i * nout + o] * xi;
                }
                or[o] = acc;
                o += 1;
            }
            // Activation applied scalar-ly so rounding matches the row
            // path exactly (see module docs).
            if let Some(a) = act {
                for v in or.iter_mut() {
                    *v = a.f(*v);
                }
            }
        }
    }

    /// # Safety
    /// The CPU must support sse2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn dense_forward_sse2(
        x: &[f32],
        wt: &[f32],
        bias: &[f32],
        out: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
        act: Option<Activation>,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let xr = &x[s * nin..(s + 1) * nin];
            let or = &mut out[s * nout..(s + 1) * nout];
            let mut o = 0;
            while o + 16 <= nout {
                let bp = bias.as_ptr().add(o);
                let mut a0 = _mm_loadu_ps(bp);
                let mut a1 = _mm_loadu_ps(bp.add(4));
                let mut a2 = _mm_loadu_ps(bp.add(8));
                let mut a3 = _mm_loadu_ps(bp.add(12));
                for (i, &xi) in xr.iter().enumerate() {
                    let xv = _mm_set1_ps(xi);
                    let wp = wt.as_ptr().add(i * nout + o);
                    a0 = _mm_add_ps(a0, _mm_mul_ps(_mm_loadu_ps(wp), xv));
                    a1 = _mm_add_ps(a1, _mm_mul_ps(_mm_loadu_ps(wp.add(4)), xv));
                    a2 = _mm_add_ps(a2, _mm_mul_ps(_mm_loadu_ps(wp.add(8)), xv));
                    a3 = _mm_add_ps(a3, _mm_mul_ps(_mm_loadu_ps(wp.add(12)), xv));
                }
                let op = or.as_mut_ptr().add(o);
                _mm_storeu_ps(op, a0);
                _mm_storeu_ps(op.add(4), a1);
                _mm_storeu_ps(op.add(8), a2);
                _mm_storeu_ps(op.add(12), a3);
                o += 16;
            }
            while o + 4 <= nout {
                let mut acc = _mm_loadu_ps(bias.as_ptr().add(o));
                for (i, &xi) in xr.iter().enumerate() {
                    let wv = _mm_loadu_ps(wt.as_ptr().add(i * nout + o));
                    acc = _mm_add_ps(acc, _mm_mul_ps(wv, _mm_set1_ps(xi)));
                }
                _mm_storeu_ps(or.as_mut_ptr().add(o), acc);
                o += 4;
            }
            while o < nout {
                let mut acc = bias[o];
                for (i, &xi) in xr.iter().enumerate() {
                    acc += wt[i * nout + o] * xi;
                }
                or[o] = acc;
                o += 1;
            }
            if let Some(a) = act {
                for v in or.iter_mut() {
                    *v = a.f(*v);
                }
            }
        }
    }

    /// # Safety
    /// The CPU must support avx2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn grad_params_avx2(
        delta: &[f32],
        input: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let dr = &delta[s * nout..(s + 1) * nout];
            let xr = &input[s * nin..(s + 1) * nin];
            for o in 0..nout {
                let d = dr[o];
                gb[o] += d;
                let row = &mut gw[o * nin..(o + 1) * nin];
                let dv = _mm256_set1_ps(d);
                let mut i = 0;
                while i + 8 <= nin {
                    let rp = row.as_mut_ptr().add(i);
                    let xv = _mm256_loadu_ps(xr.as_ptr().add(i));
                    _mm256_storeu_ps(rp, _mm256_add_ps(_mm256_loadu_ps(rp), _mm256_mul_ps(dv, xv)));
                    i += 8;
                }
                while i < nin {
                    row[i] += d * xr[i];
                    i += 1;
                }
            }
        }
    }

    /// # Safety
    /// The CPU must support sse2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "sse2")]
    unsafe fn grad_params_sse2(
        delta: &[f32],
        input: &[f32],
        gw: &mut [f32],
        gb: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let dr = &delta[s * nout..(s + 1) * nout];
            let xr = &input[s * nin..(s + 1) * nin];
            for o in 0..nout {
                let d = dr[o];
                gb[o] += d;
                let row = &mut gw[o * nin..(o + 1) * nin];
                let dv = _mm_set1_ps(d);
                let mut i = 0;
                while i + 4 <= nin {
                    let rp = row.as_mut_ptr().add(i);
                    let xv = _mm_loadu_ps(xr.as_ptr().add(i));
                    _mm_storeu_ps(rp, _mm_add_ps(_mm_loadu_ps(rp), _mm_mul_ps(dv, xv)));
                    i += 4;
                }
                while i < nin {
                    row[i] += d * xr[i];
                    i += 1;
                }
            }
        }
    }

    /// # Safety
    /// The CPU must support avx2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn backprop_delta_avx2(
        delta: &[f32],
        w: &[f32],
        prev: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let dr = &delta[s * nout..(s + 1) * nout];
            let pr = &mut prev[s * nin..(s + 1) * nin];
            let mut i = 0;
            while i + 8 <= nin {
                let mut acc = _mm256_setzero_ps();
                for (o, &d) in dr.iter().enumerate() {
                    let wv = _mm256_loadu_ps(w.as_ptr().add(o * nin + i));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(d), wv));
                }
                _mm256_storeu_ps(pr.as_mut_ptr().add(i), acc);
                i += 8;
            }
            while i < nin {
                let mut acc = 0.0f32;
                for (o, &d) in dr.iter().enumerate() {
                    acc += d * w[o * nin + i];
                }
                pr[i] = acc;
                i += 1;
            }
        }
    }

    /// # Safety
    /// The CPU must support sse2; slice bounds as asserted by the caller.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "sse2")]
    unsafe fn backprop_delta_sse2(
        delta: &[f32],
        w: &[f32],
        prev: &mut [f32],
        bsz: usize,
        nin: usize,
        nout: usize,
    ) {
        use std::arch::x86_64::*;
        for s in 0..bsz {
            let dr = &delta[s * nout..(s + 1) * nout];
            let pr = &mut prev[s * nin..(s + 1) * nin];
            let mut i = 0;
            while i + 4 <= nin {
                let mut acc = _mm_setzero_ps();
                for (o, &d) in dr.iter().enumerate() {
                    let wv = _mm_loadu_ps(w.as_ptr().add(o * nin + i));
                    acc = _mm_add_ps(acc, _mm_mul_ps(_mm_set1_ps(d), wv));
                }
                _mm_storeu_ps(pr.as_mut_ptr().add(i), acc);
                i += 4;
            }
            while i < nin {
                let mut acc = 0.0f32;
                for (o, &d) in dr.iter().enumerate() {
                    acc += d * w[o * nin + i];
                }
                pr[i] = acc;
                i += 1;
            }
        }
    }
}

impl Mlp {
    /// Total parameter count for `dims`.
    pub fn param_count(dims: &[usize]) -> usize {
        dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Orthogonal-ish init: scaled He-normal weights, zero biases (matches
    /// the scale the paper's Rejax baselines use closely enough for 2×64
    /// nets).
    pub fn new(dims: &[usize], act: Activation, rng: &mut Rng) -> Mlp {
        let mut params = vec![0.0; Mlp::param_count(dims)];
        let mut off = 0;
        for w in dims.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / fan_in as f64).sqrt();
            for p in params[off..off + fan_in * fan_out].iter_mut() {
                *p = (rng.normal() * scale) as f32;
            }
            off += fan_in * fan_out + fan_out; // biases stay zero
        }
        Mlp { dims: dims.to_vec(), act, params }
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// Forward pass; fills `cache` and returns the output activations.
    pub fn forward(&self, x: &[f32], cache: &mut Cache) -> Vec<f32> {
        debug_assert_eq!(x.len(), self.dims[0]);
        cache.acts.clear();
        cache.acts.push(x.to_vec());
        let mut off = 0;
        let mut cur = x.to_vec();
        for (li, wpair) in self.dims.windows(2).enumerate() {
            let (nin, nout) = (wpair[0], wpair[1]);
            let w = &self.params[off..off + nin * nout];
            let b = &self.params[off + nin * nout..off + nin * nout + nout];
            let mut next = vec![0.0f32; nout];
            for o in 0..nout {
                let row = &w[o * nin..(o + 1) * nin];
                let mut acc = b[o];
                for i in 0..nin {
                    acc += row[i] * cur[i];
                }
                next[o] =
                    if li + 1 < self.n_layers() { self.act.f(acc) } else { acc };
            }
            off += nin * nout + nout;
            cache.acts.push(next.clone());
            cur = next;
        }
        cur
    }

    /// Inference without caching.
    pub fn infer(&self, x: &[f32]) -> Vec<f32> {
        let mut cache = Cache::default();
        self.forward(x, &mut cache)
    }

    /// Batched forward over a `[bsz × dims[0]]` row-major block. Fills
    /// `cache` for [`Mlp::backward_batch`]; read the `[bsz × dims[L]]`
    /// output via [`BatchCache::out`]. Bit-for-bit identical to calling
    /// [`Mlp::forward`] on each row.
    pub fn forward_batch(&self, x: &[f32], bsz: usize, cache: &mut BatchCache) {
        debug_assert_eq!(x.len(), bsz * self.dims[0]);
        let n_layers = self.n_layers();
        let kp = simd::effective(cache.kernel);
        cache.bsz = bsz;
        cache.acts.resize(self.dims.len(), Vec::new());
        for (l, &dim) in self.dims.iter().enumerate() {
            ensure(&mut cache.acts[l], bsz * dim);
        }
        cache.acts[0][..bsz * self.dims[0]].copy_from_slice(x);
        cache.fwt.resize(n_layers, Vec::new());
        let mut off = 0;
        for li in 0..n_layers {
            let (nin, nout) = (self.dims[li], self.dims[li + 1]);
            let w = &self.params[off..off + nin * nout];
            let b = &self.params[off + nin * nout..off + nin * nout + nout];
            let act = if li + 1 < n_layers { Some(self.act) } else { None };
            // Split-borrow the two activation planes around `li`.
            let (lo, hi) = cache.acts.split_at_mut(li + 1);
            let xin = &lo[li][..bsz * nin];
            let out = &mut hi[0][..bsz * nout];
            if kp == KernelPath::Scalar {
                dense_forward(xin, w, b, out, bsz, nin, nout, act);
            } else {
                // Vector path: stream forward-transposed weights so one
                // output-column block is one contiguous load per input
                // element. Same products, same ascending-i order — bitwise
                // identical (see `kernels`).
                let wt = &mut cache.fwt[li];
                ensure(wt, nin * nout);
                transpose(w, wt, nin, nout);
                kernels::dense_forward_vec(kp, xin, wt, b, out, bsz, nin, nout, act);
            }
            off += nin * nout + nout;
        }
    }

    /// Batched backward: `grad_out` is `[bsz × dims[L]]` ∂L/∂output for the
    /// forward recorded in `cache`; parameter gradients accumulate into
    /// `grads` (same flat layout as `params`). Per parameter, sample
    /// contributions are added in ascending sample order, so accumulating a
    /// whole minibatch here equals running [`Mlp::backward`] sample by
    /// sample, bit for bit. The input gradient (which no trainer consumes)
    /// is not computed — propagation stops after layer 0's parameters.
    pub fn backward_batch(&self, cache: &mut BatchCache, grad_out: &[f32], grads: &mut [f32]) {
        debug_assert_eq!(grads.len(), self.params.len());
        let n_layers = self.n_layers();
        let bsz = cache.bsz;
        debug_assert_eq!(grad_out.len(), bsz * self.dims[n_layers]);
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for w in self.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }
        let max_dim = *self.dims.iter().max().unwrap();
        let kp = simd::effective(cache.kernel);
        ensure(&mut cache.d_cur, bsz * max_dim);
        ensure(&mut cache.d_nxt, bsz * max_dim);
        cache.wt.resize(n_layers, Vec::new());
        cache.d_cur[..grad_out.len()].copy_from_slice(grad_out);

        for li in (0..n_layers).rev() {
            let (nin, nout) = (self.dims[li], self.dims[li + 1]);
            let input = &cache.acts[li];
            let output = &cache.acts[li + 1];
            // Activation derivative (hidden layers only), expressed in the
            // activated output like the row path.
            if li + 1 < n_layers {
                for (d, &y) in cache.d_cur[..bsz * nout].iter_mut().zip(&output[..bsz * nout]) {
                    *d *= self.act.df_from_y(y);
                }
            }
            let off = offsets[li];
            let (gw, gb) = {
                let (a, b) = grads[off..off + nin * nout + nout].split_at_mut(nin * nout);
                (a, b)
            };
            // Parameter gradients, sample-major: each parameter receives
            // its per-sample contributions in ascending sample order —
            // the same order a per-sample loop over Mlp::backward uses.
            // The vector kernel widens over input columns only, keeping
            // that reduction order (see `kernels`).
            if kp == KernelPath::Scalar {
                for s in 0..bsz {
                    let dr = &cache.d_cur[s * nout..(s + 1) * nout];
                    let xr = &input[s * nin..(s + 1) * nin];
                    for o in 0..nout {
                        let d = dr[o];
                        gb[o] += d;
                        let row = &mut gw[o * nin..(o + 1) * nin];
                        for i in 0..nin {
                            row[i] += d * xr[i];
                        }
                    }
                }
            } else {
                kernels::grad_params_vec(kp, &cache.d_cur, input, gw, gb, bsz, nin, nout);
            }
            if li > 0 {
                // Propagate: δ_prev[s][i] = Σ_o δ[s][o]·w[o][i] with the
                // o-sum in ascending order — identical to the row path's
                // `prev[i] += d·w[o][i]` accumulation. The scalar path
                // streams transposed weights (contiguous `[out]` rows per
                // accumulator); the vector path reads the row-major
                // weights directly, 8/4 contiguous `i` lanes at a time —
                // same products, same order, bitwise identical.
                let w = &self.params[off..off + nin * nout];
                if kp == KernelPath::Scalar {
                    let wt = &mut cache.wt[li];
                    ensure(wt, nin * nout);
                    for o in 0..nout {
                        for i in 0..nin {
                            wt[i * nout + o] = w[o * nin + i];
                        }
                    }
                    for s in 0..bsz {
                        let dr = &cache.d_cur[s * nout..(s + 1) * nout];
                        let pr = &mut cache.d_nxt[s * nin..(s + 1) * nin];
                        for i in 0..nin {
                            let wr = &wt[i * nout..(i + 1) * nout];
                            let mut acc = 0.0f32;
                            for o in 0..nout {
                                acc += dr[o] * wr[o];
                            }
                            pr[i] = acc;
                        }
                    }
                } else {
                    kernels::backprop_delta_vec(
                        kp,
                        &cache.d_cur,
                        w,
                        &mut cache.d_nxt,
                        bsz,
                        nin,
                        nout,
                    );
                }
                std::mem::swap(&mut cache.d_cur, &mut cache.d_nxt);
            }
        }
    }

    /// Backward pass: `grad_out` is ∂L/∂output; accumulates parameter
    /// gradients into `grads` (same layout as `params`) and returns
    /// ∂L/∂input.
    pub fn backward(&self, cache: &Cache, grad_out: &[f32], grads: &mut [f32]) -> Vec<f32> {
        debug_assert_eq!(grads.len(), self.params.len());
        let n_layers = self.n_layers();
        // Parameter offsets per layer.
        let mut offsets = Vec::with_capacity(n_layers);
        let mut off = 0;
        for w in self.dims.windows(2) {
            offsets.push(off);
            off += w[0] * w[1] + w[1];
        }

        let mut delta = grad_out.to_vec();
        for li in (0..n_layers).rev() {
            let (nin, nout) = (self.dims[li], self.dims[li + 1]);
            let input = &cache.acts[li];
            let output = &cache.acts[li + 1];
            // activation derivative (hidden layers only)
            if li + 1 < n_layers {
                for o in 0..nout {
                    delta[o] *= self.act.df_from_y(output[o]);
                }
            }
            let off = offsets[li];
            let (gw, gb) = {
                let (a, b) = grads[off..off + nin * nout + nout].split_at_mut(nin * nout);
                (a, b)
            };
            for o in 0..nout {
                let d = delta[o];
                gb[o] += d;
                let row = &mut gw[o * nin..(o + 1) * nin];
                for i in 0..nin {
                    row[i] += d * input[i];
                }
            }
            // propagate
            if li > 0 {
                let w = &self.params[off..off + nin * nout];
                let mut prev = vec![0.0f32; nin];
                for o in 0..nout {
                    let d = delta[o];
                    let row = &w[o * nin..(o + 1) * nin];
                    for i in 0..nin {
                        prev[i] += d * row[i];
                    }
                }
                delta = prev;
            } else {
                let w = &self.params[off..off + nin * nout];
                let mut prev = vec![0.0f32; nin];
                for o in 0..nout {
                    let d = delta[o];
                    let row = &w[o * nin..(o + 1) * nin];
                    for i in 0..nin {
                        prev[i] += d * row[i];
                    }
                }
                return prev;
            }
        }
        unreachable!()
    }

    /// Polyak/copy update from another network (target networks).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        debug_assert_eq!(self.params.len(), src.params.len());
        for (t, s) in self.params.iter_mut().zip(&src.params) {
            *t = (1.0 - tau) * *t + tau * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(act: Activation) {
        let mut rng = Rng::new(42);
        let dims = [5, 8, 8, 3];
        let mlp = Mlp::new(&dims, act, &mut rng);
        let x: Vec<f32> = (0..5).map(|_| rng.normal() as f32).collect();
        // Loss: L = sum(out^2)/2 so dL/dout = out.
        let mut cache = Cache::default();
        let out = mlp.forward(&x, &mut cache);
        let mut grads = vec![0.0; mlp.params.len()];
        let gin = mlp.backward(&cache, &out, &mut grads);

        let loss = |m: &Mlp, x: &[f32]| -> f64 {
            let o = m.infer(x);
            o.iter().map(|&v| (v as f64) * (v as f64) / 2.0).sum()
        };
        // parameter gradients (spot-check a spread of indices)
        let eps = 1e-3f32;
        for idx in (0..mlp.params.len()).step_by(17) {
            let mut mp = mlp.clone();
            mp.params[idx] += eps;
            let mut mm = mlp.clone();
            mm.params[idx] -= eps;
            let num = (loss(&mp, &x) - loss(&mm, &x)) / (2.0 * eps as f64);
            let ana = grads[idx] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                "param {idx}: numeric {num} vs analytic {ana} ({act:?})"
            );
        }
        // input gradient
        for i in 0..x.len() {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&mlp, &xp) - loss(&mlp, &xm)) / (2.0 * eps as f64);
            let ana = gin[i] as f64;
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                "input {i}: numeric {num} vs analytic {ana} ({act:?})"
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences_relu() {
        finite_diff_check(Activation::Relu);
    }

    #[test]
    fn gradients_match_finite_differences_tanh() {
        finite_diff_check(Activation::Tanh);
    }

    #[test]
    fn param_count() {
        assert_eq!(Mlp::param_count(&[147, 64, 64, 7]), 147 * 64 + 64 + 64 * 64 + 64 + 64 * 7 + 7);
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let mut rng = Rng::new(0);
        let mlp = Mlp::new(&[4, 16, 2], Activation::Relu, &mut rng);
        let a = mlp.infer(&[1.0, -1.0, 0.5, 2.0]);
        let b = mlp.infer(&[1.0, -1.0, 0.5, 2.0]);
        assert_eq!(a.len(), 2);
        assert_eq!(a, b);
    }

    #[test]
    fn forward_batch_is_bitwise_identical_to_rowwise_forward() {
        for act in [Activation::Relu, Activation::Tanh] {
            let mut rng = Rng::new(7);
            let mlp = Mlp::new(&[9, 13, 6, 5], act, &mut rng);
            let bsz = 11; // not a multiple of the 4-wide output tile
            let x: Vec<f32> = (0..bsz * 9).map(|_| rng.normal() as f32).collect();
            let mut bc = BatchCache::default();
            mlp.forward_batch(&x, bsz, &mut bc);
            for s in 0..bsz {
                let row = mlp.infer(&x[s * 9..(s + 1) * 9]);
                assert_eq!(&bc.out()[s * 5..(s + 1) * 5], &row[..], "sample {s} ({act:?})");
            }
        }
    }

    #[test]
    fn backward_batch_is_bitwise_identical_to_sample_loop() {
        for act in [Activation::Relu, Activation::Tanh] {
            let mut rng = Rng::new(23);
            let dims = [7, 10, 10, 3];
            let mlp = Mlp::new(&dims, act, &mut rng);
            let bsz = 6;
            let x: Vec<f32> = (0..bsz * 7).map(|_| rng.normal() as f32).collect();
            // Loss gradient: the outputs themselves (L = Σ out²/2).
            let mut bc = BatchCache::default();
            mlp.forward_batch(&x, bsz, &mut bc);
            let gout: Vec<f32> = bc.out()[..bsz * 3].to_vec();
            let mut batch_grads = vec![0.0f32; mlp.params.len()];
            mlp.backward_batch(&mut bc, &gout, &mut batch_grads);

            let mut row_grads = vec![0.0f32; mlp.params.len()];
            let mut cache = Cache::default();
            for s in 0..bsz {
                let out = mlp.forward(&x[s * 7..(s + 1) * 7], &mut cache);
                mlp.backward(&cache, &out, &mut row_grads);
            }
            assert_eq!(batch_grads, row_grads, "{act:?}");
        }
    }

    #[test]
    fn batch_cache_reuse_across_sizes_stays_exact() {
        // A big batch followed by a smaller one must not read stale tail
        // activations (buffers grow but never shrink).
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&[4, 8, 2], Activation::Tanh, &mut rng);
        let mut bc = BatchCache::default();
        let big: Vec<f32> = (0..12 * 4).map(|_| rng.normal() as f32).collect();
        mlp.forward_batch(&big, 12, &mut bc);
        let small = &big[..3 * 4];
        mlp.forward_batch(small, 3, &mut bc);
        for s in 0..3 {
            let row = mlp.infer(&small[s * 4..(s + 1) * 4]);
            assert_eq!(&bc.out()[s * 2..(s + 1) * 2], &row[..]);
        }
    }

    #[test]
    fn forced_kernel_paths_match_the_scalar_oracle_bitwise() {
        // Sweep every supported KernelPath (the CI simd-matrix contract):
        // forward activations and accumulated gradients must be bitwise
        // equal to the forced-scalar batch path AND the per-sample row
        // path. Dims chosen to hit every vector block and tail: on avx2,
        // nout = 37 = one 32-block + 5 scalar tail, 19 = two 8-blocks + 3
        // tail, 5 = pure tail; on sse2, 37 = two 16-blocks + one 4-block +
        // 1 tail. bsz 11 is not a multiple of anything.
        for kp in KernelPath::ALL {
            if !kp.supported() {
                println!("skipping {}: unsupported on this CPU", kp.name());
                continue;
            }
            for act in [Activation::Relu, Activation::Tanh] {
                let mut rng = Rng::new(99);
                let dims = [13, 37, 19, 5];
                let (nin, nout) = (dims[0], dims[3]);
                let mlp = Mlp::new(&dims, act, &mut rng);
                let bsz = 11;
                let x: Vec<f32> = (0..bsz * nin).map(|_| rng.normal() as f32).collect();

                let mut bc_s = BatchCache { kernel: KernelPath::Scalar, ..Default::default() };
                mlp.forward_batch(&x, bsz, &mut bc_s);
                let mut bc_v = BatchCache { kernel: kp, ..Default::default() };
                mlp.forward_batch(&x, bsz, &mut bc_v);
                let out_len = bsz * nout;
                assert_eq!(
                    &bc_s.out()[..out_len],
                    &bc_v.out()[..out_len],
                    "forward {} vs scalar ({act:?})",
                    kp.name()
                );
                for s in 0..bsz {
                    let row = mlp.infer(&x[s * nin..(s + 1) * nin]);
                    assert_eq!(
                        &bc_v.out()[s * nout..(s + 1) * nout],
                        &row[..],
                        "forward {} vs row path, sample {s} ({act:?})",
                        kp.name()
                    );
                }

                let gout: Vec<f32> = bc_v.out()[..out_len].to_vec();
                let mut g_s = vec![0.0f32; mlp.params.len()];
                mlp.backward_batch(&mut bc_s, &gout, &mut g_s);
                let mut g_v = vec![0.0f32; mlp.params.len()];
                mlp.backward_batch(&mut bc_v, &gout, &mut g_v);
                assert_eq!(g_s, g_v, "backward {} vs scalar ({act:?})", kp.name());
            }
        }
    }

    #[test]
    fn kernel_paths_survive_cache_reuse_across_shapes() {
        // One cache re-used across two different nets and batch sizes: the
        // grown fwt/delta workspaces must not leak stale state into later
        // calls on any supported path.
        for kp in KernelPath::ALL {
            if !kp.supported() {
                continue;
            }
            let mut rng = Rng::new(3);
            let big = Mlp::new(&[12, 40, 9], Activation::Tanh, &mut rng);
            let small = Mlp::new(&[6, 17, 4], Activation::Relu, &mut rng);
            let xb: Vec<f32> = (0..9 * 12).map(|_| rng.normal() as f32).collect();
            let xs: Vec<f32> = (0..5 * 6).map(|_| rng.normal() as f32).collect();
            let mut bc = BatchCache { kernel: kp, ..Default::default() };
            big.forward_batch(&xb, 9, &mut bc);
            small.forward_batch(&xs, 5, &mut bc);
            for s in 0..5 {
                let row = small.infer(&xs[s * 6..(s + 1) * 6]);
                assert_eq!(&bc.out()[s * 4..(s + 1) * 4], &row[..], "{} sample {s}", kp.name());
            }
        }
    }

    #[test]
    fn soft_update_interpolates() {
        let mut rng = Rng::new(1);
        let src = Mlp::new(&[2, 3, 1], Activation::Relu, &mut rng);
        let mut dst = Mlp::new(&[2, 3, 1], Activation::Relu, &mut rng);
        let before = dst.params.clone();
        dst.soft_update_from(&src, 0.5);
        for i in 0..before.len() {
            let expect = 0.5 * before[i] + 0.5 * src.params[i];
            assert!((dst.params[i] - expect).abs() < 1e-6);
        }
        dst.soft_update_from(&src, 1.0);
        assert_eq!(dst.params, src.params);
    }
}
