//! Adam optimizer (Kingma & Ba, 2015) over flat parameter vectors, with the
//! global-norm gradient clipping the paper's Rejax baselines tune (Table 9).

/// Adam state for one parameter vector.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub t: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(n: usize, lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: vec![0.0; n], v: vec![0.0; n] }
    }

    /// Apply one update step in place. `grads` is consumed as-is (call
    /// [`clip_global_norm`] first if clipping is configured).
    pub fn step(&mut self, params: &mut [f32], grads: &[f32]) {
        debug_assert_eq!(params.len(), grads.len());
        debug_assert_eq!(params.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i];
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / b1t;
            let vhat = self.v[i] / b2t;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

/// Scale `grads` so their global L2 norm is at most `max_norm`. Returns the
/// pre-clip norm.
pub fn clip_global_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimises_a_quadratic() {
        // minimise f(p) = (p-3)^2
        let mut p = vec![0.0f32];
        let mut opt = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (p[0] - 3.0)];
            opt.step(&mut p, &g);
        }
        assert!((p[0] - 3.0).abs() < 1e-2, "converged to {}", p[0]);
    }

    #[test]
    fn bias_correction_makes_first_step_lr_sized() {
        let mut p = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut p, &[1.0]);
        assert!((p[0] + 0.01).abs() < 1e-4, "first step should be ≈ -lr, got {}", p[0]);
    }

    #[test]
    fn clipping_preserves_direction() {
        let mut g = vec![3.0, 4.0]; // norm 5
        let norm = clip_global_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        assert!((g[0] - 0.6).abs() < 1e-6);
        assert!((g[1] - 0.8).abs() < 1e-6);
        // under the cap: untouched
        let mut g2 = vec![0.3, 0.4];
        clip_global_norm(&mut g2, 1.0);
        assert_eq!(g2, vec![0.3, 0.4]);
    }
}
