//! Neural-network substrate for the native RL baselines (paper §4.3).
//!
//! A deliberately small, dependency-free stack: flat-`Vec<f32>` parameter
//! storage, dense layers with hand-derived backprop (gradient-checked
//! against finite differences in the tests), ReLU/tanh activations and Adam.
//! All paper baselines use two hidden layers of 64 units, which this module
//! mirrors by default.
//!
//! Since PR 4 the trainers run on the **batch path** —
//! [`Mlp::forward_batch`]/[`Mlp::backward_batch`] over `[B, dim]` row-major
//! buffers through a register-blocked GEMM microkernel with reusable
//! [`BatchCache`] workspaces — which is bit-for-bit identical to the
//! per-sample path (see `mlp.rs` module docs) but amortises weight traffic
//! over the whole batch and performs no per-sample allocation.
//!
//! The *flagship* PPO path does not use this module for its update — that
//! runs through the AOT-compiled JAX/Pallas artifact via
//! [`crate::runtime`] — but the native implementation powers DQN/SAC, the
//! Fig.-7 baselines, and serves as the cross-check for the XLA path.

pub mod adam;
pub mod mlp;

pub use adam::Adam;
pub use mlp::{Activation, BatchCache, Mlp};

/// Numerically-stable softmax into `out`.
pub fn softmax(logits: &[f32], out: &mut [f32]) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0;
    for (o, &l) in out.iter_mut().zip(logits) {
        *o = (l - m).exp();
        z += *o;
    }
    for o in out.iter_mut() {
        *o /= z;
    }
}

/// log-softmax into `out`.
pub fn log_softmax(logits: &[f32], out: &mut [f32]) {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let z: f32 = logits.iter().map(|&l| (l - m).exp()).sum();
    let lz = z.ln() + m;
    for (o, &l) in out.iter_mut().zip(logits) {
        *o = l - lz;
    }
}

/// Sample from a categorical distribution given logits.
pub fn sample_categorical(logits: &[f32], rng: &mut crate::rng::Rng) -> usize {
    let mut probs = vec![0.0; logits.len()];
    softmax(logits, &mut probs);
    rng.categorical(&probs)
}

/// Argmax index.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let logits = [1.0, 2.0, 3.0];
        let mut p = [0.0; 3];
        softmax(&logits, &mut p);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn softmax_is_shift_invariant_and_stable() {
        let mut a = [0.0; 3];
        let mut b = [0.0; 3];
        softmax(&[1.0, 2.0, 3.0], &mut a);
        softmax(&[1001.0, 1002.0, 1003.0], &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn log_softmax_matches_softmax() {
        let logits = [0.3, -1.2, 2.0, 0.0];
        let mut p = [0.0; 4];
        let mut lp = [0.0; 4];
        softmax(&logits, &mut p);
        log_softmax(&logits, &mut lp);
        for (x, y) in p.iter().zip(&lp) {
            assert!((x.ln() - y).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.5]), 1);
    }

    #[test]
    fn categorical_sampling_respects_probs() {
        let mut rng = crate::rng::Rng::new(0);
        let logits = [0.0, 5.0, 0.0]; // heavily favours index 1
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[sample_categorical(&logits, &mut rng)] += 1;
        }
        assert!(counts[1] > 900, "{counts:?}");
    }
}
