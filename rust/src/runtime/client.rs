//! Thin, typed wrapper over the `xla` crate's PJRT CPU client.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client plus compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo<P: AsRef<Path>>(&self, path: P) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled executable with typed helpers.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with literal inputs; the jax artifacts are lowered with
    /// `return_tuple=True`, so the single output is a tuple which this
    /// unpacks into its elements.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given shape from a flat slice.
pub fn i32_literal(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 scalar literal (e.g. Adam's step counter input). Routed
/// through this module so callers never name the `xla` crate directly.
pub fn i32_scalar(value: i32) -> xla::Literal {
    xla::Literal::scalar(value)
}

/// Extract a literal into a Vec<f32>.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Extract a literal into a Vec<i32>.
pub fn to_i32_vec(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}

/// Extract a scalar f32.
pub fn to_f32_scalar(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
    Ok(v[0])
}
