//! Artifact discovery: locates the `artifacts/` directory produced by
//! `make artifacts` and names the executables the coordinator expects.

use anyhow::{anyhow, Result};
use std::path::PathBuf;

/// Resolve the artifacts directory: `$NAVIX_ARTIFACTS` if set, else
/// `artifacts/` relative to the current dir, else relative to the crate
/// root (so `cargo test` from anywhere finds it).
pub fn artifacts_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("NAVIX_ARTIFACTS") {
        let p = PathBuf::from(dir);
        if p.is_dir() {
            return Ok(p);
        }
        return Err(anyhow!("NAVIX_ARTIFACTS={} is not a directory", p.display()));
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        let p = PathBuf::from(cand);
        if p.is_dir() {
            return Ok(p);
        }
    }
    Err(anyhow!(
        "artifacts/ not found — run `make artifacts` (or set NAVIX_ARTIFACTS)"
    ))
}

/// The artifact files the coordinator knows how to drive.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    pub dir: PathBuf,
}

impl ArtifactSet {
    pub fn discover() -> Result<ArtifactSet> {
        Ok(ArtifactSet { dir: artifacts_dir()? })
    }

    fn existing(&self, name: &str) -> Result<PathBuf> {
        let p = self.dir.join(name);
        if p.is_file() {
            Ok(p)
        } else {
            Err(anyhow!("missing artifact {} — run `make artifacts`", p.display()))
        }
    }

    /// Batched Empty-8x8 env step (L2+L1) for batch size `b`.
    pub fn env_step(&self, b: usize) -> Result<PathBuf> {
        self.existing(&format!("env_step_empty8_b{b}.hlo.txt"))
    }

    /// Actor-critic forward pass for batch size `b`.
    pub fn ppo_fwd(&self, b: usize) -> Result<PathBuf> {
        self.existing(&format!("ppo_fwd_b{b}.hlo.txt"))
    }

    /// Fused PPO minibatch update for minibatch size `mb`.
    pub fn ppo_update(&self, mb: usize) -> Result<PathBuf> {
        self.existing(&format!("ppo_update_b{mb}.hlo.txt"))
    }

    /// Standalone first-person observation kernel (L1) for batch `b`.
    pub fn obs_kernel(&self, b: usize) -> Result<PathBuf> {
        self.existing(&format!("obs_fp_b{b}.hlo.txt"))
    }

    /// Sanity module written by the Makefile stamp.
    pub fn sanity(&self) -> Result<PathBuf> {
        self.existing("model.hlo.txt")
    }

    /// Available batch sizes for an artifact family, by filename scan.
    pub fn available_sizes(&self, prefix: &str) -> Vec<usize> {
        let mut sizes = Vec::new();
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                if let Some(rest) = name.strip_prefix(prefix) {
                    if let Some(num) = rest.strip_suffix(".hlo.txt") {
                        if let Ok(n) = num.parse() {
                            sizes.push(n);
                        }
                    }
                }
            }
        }
        sizes.sort_unstable();
        sizes
    }
}

/// PPO parameter-packing convention shared with `python/compile/model.py`:
/// actor layers then critic layers, each `W (out×in, row-major) ++ b(out)`,
/// dims actor `[OBS_DIM,64,64,7]`, critic `[OBS_DIM,64,64,1]`.
pub mod packing {
    /// Flattened symbolic first-person grid width (7×7×3), re-exported so
    /// artifact consumers can split a policy row back into grid ++ mission.
    pub const GRID_OBS_DIM: usize = crate::agents::GRID_OBS_DIM;
    /// Tokenised mission block width (see [`crate::core::mission`]).
    pub const MISSION_TOKENS: usize = crate::core::mission::MISSION_TOKENS;
    /// Policy input width the artifacts are compiled against: grid features
    /// concatenated with the mission token block. Derived from
    /// [`crate::agents::OBS_DIM`] — one constant, never a hard-coded 147 —
    /// and mirrored by `python/compile/model.py::OBS_DIM`.
    pub const OBS_DIM: usize = crate::agents::OBS_DIM;
    pub const HIDDEN: usize = 64;
    pub const N_ACTIONS: usize = 7;

    pub const ACTOR_DIMS: [usize; 4] = [OBS_DIM, HIDDEN, HIDDEN, N_ACTIONS];
    pub const CRITIC_DIMS: [usize; 4] = [OBS_DIM, HIDDEN, HIDDEN, 1];

    fn count(dims: &[usize]) -> usize {
        dims.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
    }

    /// Total flat parameter count (actor ++ critic).
    pub fn total_params() -> usize {
        count(&ACTOR_DIMS) + count(&CRITIC_DIMS)
    }

    /// He-init a flat parameter vector with the shared layout.
    pub fn init_params(seed: u64) -> Vec<f32> {
        let mut rng = crate::rng::Rng::new(seed);
        let mut params = Vec::with_capacity(total_params());
        for dims in [&ACTOR_DIMS[..], &CRITIC_DIMS[..]] {
            for w in dims.windows(2) {
                let (nin, nout) = (w[0], w[1]);
                let scale = (2.0 / nin as f64).sqrt() * 0.5;
                for _ in 0..nin * nout {
                    params.push((rng.normal() * scale) as f32);
                }
                for _ in 0..nout {
                    params.push(0.0);
                }
            }
        }
        params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_counts() {
        // grid 147 ++ mission 16 = 163-wide policy rows
        assert_eq!(packing::OBS_DIM, 163);
        assert_eq!(packing::OBS_DIM, packing::GRID_OBS_DIM + packing::MISSION_TOKENS);
        let d = packing::OBS_DIM;
        let actor = d * 64 + 64 + 64 * 64 + 64 + 64 * 7 + 7;
        let critic = d * 64 + 64 + 64 * 64 + 64 + 64 + 1;
        assert_eq!(packing::total_params(), actor + critic);
        assert_eq!(packing::init_params(0).len(), packing::total_params());
    }

    #[test]
    fn init_is_deterministic_and_nonzero() {
        let a = packing::init_params(1);
        let b = packing::init_params(1);
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x != 0.0));
        assert_ne!(packing::init_params(2), a);
    }

    #[test]
    fn artifact_set_names() {
        let set = ArtifactSet { dir: PathBuf::from("/nonexistent") };
        assert!(set.env_step(16).is_err());
        assert!(set.ppo_update(256).is_err());
    }
}
