//! PJRT runtime: loads the AOT artifacts produced by the build-time Python
//! layers (`make artifacts`) and executes them from the Rust hot path.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serialises `HloModuleProto` with
//! 64-bit instruction ids, which the pinned xla_extension 0.5.1 rejects; the
//! text parser reassigns ids and round-trips cleanly (see
//! `python/compile/aot.py`). Executables are compiled once at load and
//! cached; per-call cost is literal transfer + execution only, so Python is
//! never on the request path.

pub mod artifacts;
pub mod client;

pub use artifacts::{artifacts_dir, ArtifactSet};
pub use client::{Executable, Runtime};
