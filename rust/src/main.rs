//! `navix` — the Layer-3 launcher.
//!
//! Subcommands:
//! * `ls` — list every registered environment id (Tables 7–8).
//! * `info [--env ID]` — live ECSM inventory (paper Tables 1–6) and, with
//!   `--env`, the config of one environment.
//! * `run --env ID [--batch B] [--steps N] [--engine batched|sync|async]`
//!   — timed random-policy unroll (the §4.1 speed protocol), printing wall
//!   time and steps/s.
//! * `train --algo ppo|dqn|sac|ppo-xla --env ID [--steps N] [--seed S]
//!   [--config FILE]` — train a baseline, append to the scoreboard.
//! * `render --env ID [--seed S]` — ASCII-render a reset state (debugging).

use anyhow::{anyhow, Result};
use navix::agents::{Dqn, DqnConfig, Ppo, PpoConfig, Sac, SacConfig};
use navix::batch::{BatchStepper, BatchedEnv, PipelinedEnv, ShardedEnv};
use navix::cli::Args;
use navix::config::{Config, ExecConfig};
use navix::coordinator::scoreboard::{Entry, Scoreboard};
use navix::coordinator::{unroll_walltime_exec, Engine, XlaPpo};
use navix::core::entities::EntityKind;
use navix::rng::Key;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_str() {
        "ls" => cmd_ls(),
        "info" => cmd_info(args),
        "run" => cmd_run(args),
        "train" => cmd_train(args),
        "render" => cmd_render(args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand `{other}` (try `navix help`)")),
    }
}

fn print_help() {
    println!(
        "navix — Rust+JAX+Pallas reproduction of NAVIX (NeurIPS 2025)\n\n\
         USAGE: navix <ls|info|run|train|render> [options]\n\n\
         run   --env ID [--batch B=8] [--steps N=1000] [--seed S]\n\
               [--engine batched|sharded|sync|async] [--shards S=auto] [--threads T=auto]\n\
         train --algo ppo|dqn|sac|ppo-xla --env ID [--steps N=100000] [--seed S] [--config FILE]\n\
               [--shards S] [--threads T] [--pipeline]   (ppo: sharded rollouts and/or the\n\
               double-buffered rollout pipeline — same trajectories, overlapped stepping)\n\
         info  [--env ID]\n\
         render --env ID [--seed S]"
    );
}

fn cmd_ls() -> Result<()> {
    for id in navix::envs::registry::list_envs() {
        println!("{id}");
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    if let Some(id) = args.opt("env") {
        let cfg = navix::envs::registry::make(id)?;
        println!("id          : {}", cfg.id);
        println!("grid        : {}x{}", cfg.h, cfg.w);
        println!("max_steps   : {}", cfg.max_steps);
        println!("observation : {}", cfg.obs.kind.name());
        println!(
            "reward      : {}",
            cfg.reward.terms.iter().map(|t| t.name()).collect::<Vec<_>>().join(" + ")
        );
        println!(
            "termination : {}",
            cfg.termination.terms.iter().map(|t| t.name()).collect::<Vec<_>>().join(" | ")
        );
        println!(
            "capacities  : doors={} keys={} balls={} boxes={}",
            cfg.caps.doors, cfg.caps.keys, cfg.caps.balls, cfg.caps.boxes
        );
        return Ok(());
    }
    println!("== Entities (paper Table 2) ==");
    for e in EntityKind::ALL {
        println!("{:<8} [{}]", format!("{e:?}"), e.components().join(", "));
    }
    println!("\n== Systems (paper Table 3) ==");
    println!("Intervention  I : S x A -> S   (rust/src/systems/intervention.rs)");
    println!("Transition    P : S x A -> S   (rust/src/systems/transition.rs)");
    println!("Observation   O : S -> O       (rust/src/systems/observations.rs, 6 fns)");
    println!("Reward        R : S x A -> R   (rust/src/systems/rewards.rs)");
    println!("Termination   g : S -> B       (rust/src/systems/terminations.rs)");
    println!("\n== Environments ==");
    println!("{} registered ids (`navix ls`)", navix::envs::registry::list_envs().len());
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let env_id = args.opt("env").map(str::to_string).unwrap_or("Navix-Empty-8x8-v0".into());
    let batch = args.opt_usize("batch", 8)?;
    let steps = args.opt_usize("steps", 1000)?;
    let seed = args.opt_u64("seed", 0)?;
    let engine = match args.opt_or("engine", "batched").as_str() {
        "batched" => Engine::Batched,
        "sharded" => Engine::Sharded,
        "sync" => Engine::BaselineSync,
        "async" => Engine::BaselineAsync,
        other => return Err(anyhow!("unknown engine {other}")),
    };
    let exec = args.exec_config()?;
    // Optional observation-function override (also the perf-probe knob:
    // comparing kinds isolates the observation system's share of the step).
    if let Some(kind) = args.opt("obs") {
        use navix::systems::observations::ObsKind;
        let kind = match kind {
            "symbolic" => ObsKind::Symbolic,
            "symbolic_first_person" => ObsKind::SymbolicFirstPerson,
            "rgb" => ObsKind::Rgb,
            "rgb_first_person" => ObsKind::RgbFirstPerson,
            "categorical" => ObsKind::Categorical,
            "categorical_first_person" => ObsKind::CategoricalFirstPerson,
            other => return Err(anyhow!("unknown observation kind {other}")),
        };
        anyhow::ensure!(
            engine == Engine::Batched,
            "--obs override is only wired for the batched engine"
        );
        let cfg = navix::envs::registry::make_with(&env_id, kind)?;
        let mut env =
            navix::batch::BatchedEnv::new(cfg, batch, navix::rng::Key::new(seed));
        let start = std::time::Instant::now();
        env.rollout_random(steps, seed ^ 0xAC7);
        let secs = start.elapsed().as_secs_f64();
        println!(
            "navix-batched env={env_id} obs={} batch={batch} steps={steps}: {:.4}s ({:.0} steps/s)",
            kind.name(),
            secs,
            (batch * steps) as f64 / secs
        );
        return Ok(());
    }
    let secs = unroll_walltime_exec(engine, &env_id, batch, steps, seed, &exec)?;
    let sps = (batch * steps) as f64 / secs;
    println!(
        "{} env={env_id} batch={batch} steps={steps}: {:.4}s ({:.0} steps/s)",
        engine.name(),
        secs,
        sps
    );
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let algo = args.opt_or("algo", "ppo");
    let env_id = args.opt("env").map(str::to_string).unwrap_or("Navix-Empty-8x8-v0".into());
    let steps = args.opt_u64("steps", 100_000)?;
    let seed = args.opt_u64("seed", 0)?;
    let cfgfile = args.opt("config").map(Config::load).transpose()?.unwrap_or_default();
    let env_cfg = navix::envs::registry::make(&env_id)?;
    // Execution layer: for shards/threads the CLI wins and the config
    // file's [parallel] section fills the gaps (0 = auto). --pipeline is a
    // presence-only switch, so it can only turn the pipeline ON; a config
    // file's `pipeline = true` cannot be overridden from the CLI.
    let file_exec = ExecConfig::from_config(&cfgfile)?;
    let cli_exec = args.exec_config()?;
    let exec = ExecConfig {
        num_shards: if cli_exec.num_shards != 0 {
            cli_exec.num_shards
        } else {
            file_exec.num_shards
        },
        num_threads: if cli_exec.num_threads != 0 {
            cli_exec.num_threads
        } else {
            file_exec.num_threads
        },
        pipeline: cli_exec.pipeline || file_exec.pipeline,
    };

    // Only the native-PPO trainer consults the execution layer; don't let
    // the flags silently no-op for the other algorithms.
    if algo != "ppo" && exec != ExecConfig::default() {
        eprintln!(
            "warning: --shards/--threads/--pipeline (and [parallel]) only apply to \
             --algo ppo; {algo} runs on the single-threaded batched engine"
        );
    }

    println!("training {algo} on {env_id} for {steps} steps (seed {seed})");
    let t0 = std::time::Instant::now();
    let (final_return, episodes) = match algo.as_str() {
        "ppo" => {
            let num_envs = cfgfile.get_usize("ppo.num_envs", 16)?;
            let mut ppo = Ppo::new(
                PpoConfig {
                    num_envs,
                    lr: cfgfile.get_f32("ppo.lr", 2.5e-4)?,
                    ..PpoConfig::default()
                },
                navix::agents::OBS_DIM,
                7,
                seed,
            );
            // Same trajectories on every engine (the RNG contract), so the
            // choice is pure execution policy.
            let use_sharded = exec.num_shards != 0 || exec.num_threads != 0;
            let log = if exec.pipeline {
                let engine: Box<dyn BatchStepper + Send> = if use_sharded {
                    Box::new(ShardedEnv::new(
                        env_cfg,
                        num_envs,
                        exec.num_shards,
                        exec.num_threads,
                        Key::new(seed),
                    ))
                } else {
                    Box::new(BatchedEnv::new(env_cfg, num_envs, Key::new(seed)))
                };
                let mut penv = PipelinedEnv::new(engine);
                ppo.train_pipelined(&mut penv, steps)
            } else if use_sharded {
                let mut env = ShardedEnv::new(
                    env_cfg,
                    num_envs,
                    exec.num_shards,
                    exec.num_threads,
                    Key::new(seed),
                );
                ppo.train(&mut env, steps)
            } else {
                let mut env = BatchedEnv::new(env_cfg, num_envs, Key::new(seed));
                ppo.train(&mut env, steps)
            };
            print_curve(&log);
            (log.final_return(), log.episodes)
        }
        "ppo-xla" => {
            let num_envs = cfgfile.get_usize("ppo.num_envs", 16)?;
            let mut env = BatchedEnv::new(env_cfg, num_envs, Key::new(seed));
            let mut ppo =
                XlaPpo::new(PpoConfig { num_envs, ..PpoConfig::default() }, seed)?;
            let log = ppo.train(&mut env, steps)?;
            print_curve(&log);
            (log.final_return(), log.episodes)
        }
        "dqn" => {
            let num_envs = cfgfile.get_usize("dqn.num_envs", 16)?;
            let mut env = BatchedEnv::new(env_cfg, num_envs, Key::new(seed));
            let mut dqn = Dqn::new(DqnConfig::default(), navix::agents::OBS_DIM, 7, seed);
            let log = dqn.train(&mut env, steps);
            print_curve(&log);
            (log.final_return(), log.episodes)
        }
        "sac" => {
            let num_envs = cfgfile.get_usize("sac.num_envs", 16)?;
            let mut env = BatchedEnv::new(env_cfg, num_envs, Key::new(seed));
            let mut sac = Sac::new(SacConfig::default(), navix::agents::OBS_DIM, 7, seed);
            let log = sac.train(&mut env, steps);
            print_curve(&log);
            (log.final_return(), log.episodes)
        }
        other => return Err(anyhow!("unknown algorithm {other}")),
    };
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "done in {dt:.1}s ({:.0} steps/s): final mean return {final_return:.3} over {episodes} episodes",
        steps as f64 / dt
    );

    let mut sb = Scoreboard::load("results/scoreboard.tsv")?;
    sb.record(Entry { env_id, algo, seeds: 1, env_steps: steps, final_return });
    sb.save()?;
    Ok(())
}

fn print_curve(log: &navix::agents::TrainLog) {
    let n = log.curve.len();
    let stride = (n / 10).max(1);
    for (i, p) in log.curve.iter().enumerate() {
        if i % stride == 0 || i == n - 1 {
            println!(
                "  step {:>9}  return {:>7.3}  loss {:>9.4}",
                p.env_steps, p.mean_return, p.loss
            );
        }
    }
}

fn cmd_render(args: &Args) -> Result<()> {
    let env_id = args.opt("env").map(str::to_string).unwrap_or("Navix-Empty-8x8-v0".into());
    let seed = args.opt_u64("seed", 0)?;
    let cfg = navix::envs::registry::make(&env_id)?;
    let env = BatchedEnv::new(cfg.clone(), 1, Key::new(seed));
    let mut sym = vec![0i32; cfg.h * cfg.w * 3];
    navix::systems::observations::symbolic(&env.state.slot(0), &mut sym);
    println!("{env_id} (seed {seed}):");
    for r in 0..cfg.h {
        let mut line = String::new();
        for c in 0..cfg.w {
            let tag = sym[(r * cfg.w + c) * 3];
            let dir = sym[(r * cfg.w + c) * 3 + 2];
            line.push(match tag {
                2 => '#',
                4 => 'D',
                5 => 'k',
                6 => 'o',
                7 => 'B',
                8 => 'G',
                9 => '~',
                10 => ['>', 'v', '<', '^'][(dir.rem_euclid(4)) as usize],
                _ => '.',
            });
        }
        println!("  {line}");
    }
    Ok(())
}
