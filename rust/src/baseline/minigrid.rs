//! Object-oriented scalar MiniGrid engine (the baseline architecture).
//!
//! Faithful to MiniGrid's design: the grid is a vector of
//! `Option<Box<dyn WorldObj>>`, every rule goes through virtual dispatch,
//! and `step`/`reset` allocate fresh observation buffers — the access
//! patterns that make the original suite CPU-bound (paper §1).
//!
//! Episode *semantics* are shared with the batched engine by construction:
//! `reset` runs the same layout generators into a one-env
//! [`crate::core::state::BatchedState`] and converts it into the object
//! grid, and rewards/terminations evaluate the same event latches.

use crate::core::actions::Action;
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::{CellType, Tag};
use crate::core::events::Events;
use crate::core::grid::Pos;
use crate::core::mission::{Mission, MissionVerb};
use crate::core::state::BatchedState;
use crate::envs::{EnvConfig, LayoutError};
use crate::rng::{Key, Rng};

/// MiniGrid's `WorldObj`: one boxed trait object per occupied cell.
pub trait WorldObj {
    fn tag(&self) -> i32;
    fn color(&self) -> Color {
        Color::Grey
    }
    /// Encoded state channel (door open/closed/locked; 0 otherwise).
    fn state(&self) -> i32 {
        0
    }
    fn can_overlap(&self) -> bool {
        false
    }
    fn can_pickup(&self) -> bool {
        false
    }
    fn see_behind(&self) -> bool {
        true
    }
    /// Toggle in place; returns true if the object changed.
    fn toggle(&mut self, carrying: &Option<Box<dyn WorldObj>>) -> bool {
        let _ = carrying;
        false
    }
}

pub struct Wall;
impl WorldObj for Wall {
    fn tag(&self) -> i32 {
        Tag::WALL
    }
    fn see_behind(&self) -> bool {
        false
    }
}

pub struct Goal;
impl WorldObj for Goal {
    fn tag(&self) -> i32 {
        Tag::GOAL
    }
    fn color(&self) -> Color {
        Color::Green
    }
    fn can_overlap(&self) -> bool {
        true
    }
}

pub struct Lava;
impl WorldObj for Lava {
    fn tag(&self) -> i32 {
        Tag::LAVA
    }
    fn color(&self) -> Color {
        Color::Red
    }
    fn can_overlap(&self) -> bool {
        true
    }
}

pub struct KeyObj(pub Color);
impl WorldObj for KeyObj {
    fn tag(&self) -> i32 {
        Tag::KEY
    }
    fn color(&self) -> Color {
        self.0
    }
    fn can_pickup(&self) -> bool {
        true
    }
}

pub struct BallObj(pub Color);
impl WorldObj for BallObj {
    fn tag(&self) -> i32 {
        Tag::BALL
    }
    fn color(&self) -> Color {
        self.0
    }
    fn can_pickup(&self) -> bool {
        true
    }
}

pub struct BoxObj(pub Color);
impl WorldObj for BoxObj {
    fn tag(&self) -> i32 {
        Tag::BOX
    }
    fn color(&self) -> Color {
        self.0
    }
    fn can_pickup(&self) -> bool {
        true
    }
}

pub struct Door {
    pub color: Color,
    pub state: DoorState,
}
impl WorldObj for Door {
    fn tag(&self) -> i32 {
        Tag::DOOR
    }
    fn color(&self) -> Color {
        self.color
    }
    fn state(&self) -> i32 {
        self.state as i32
    }
    fn can_overlap(&self) -> bool {
        self.state == DoorState::Open
    }
    fn see_behind(&self) -> bool {
        self.state == DoorState::Open
    }
    fn toggle(&mut self, carrying: &Option<Box<dyn WorldObj>>) -> bool {
        match self.state {
            DoorState::Locked => {
                if let Some(obj) = carrying {
                    if obj.tag() == Tag::KEY && obj.color() == self.color {
                        self.state = DoorState::Open;
                        return true;
                    }
                }
                false
            }
            DoorState::Closed => {
                self.state = DoorState::Open;
                true
            }
            DoorState::Open => {
                self.state = DoorState::Closed;
                true
            }
        }
    }
}

/// The scalar object-oriented environment.
pub struct MiniGridEnv {
    pub cfg: EnvConfig,
    grid: Vec<Option<Box<dyn WorldObj>>>,
    agent_pos: Pos,
    agent_dir: Direction,
    carrying: Option<Box<dyn WorldObj>>,
    step_count: u32,
    mission: i32,
    rng: Rng,
    key: Key,
    episode: u64,
}

/// Step outcome (gymnasium 5-tuple, observation allocated per call like the
/// original Python API).
pub struct StepResult {
    pub obs: Vec<i32>,
    pub reward: f32,
    pub terminated: bool,
    pub truncated: bool,
}

impl MiniGridEnv {
    pub fn new(cfg: EnvConfig, key: Key) -> Self {
        let mut env = MiniGridEnv {
            grid: Vec::new(),
            agent_pos: Pos::new(1, 1),
            agent_dir: Direction::East,
            carrying: None,
            step_count: 0,
            mission: -1,
            rng: Rng::from_key(key),
            key,
            episode: 0,
            cfg,
        };
        env.reset();
        env
    }

    /// Construct with a pinned *episode* key: the first episode's layout is
    /// generated from exactly `ep_key` (instead of `key.fold_in(1)`),
    /// which lets cross-engine parity tests line this engine up with a
    /// specific [`crate::batch::BatchedEnv`] slot.
    pub fn new_with_episode_key(cfg: EnvConfig, ep_key: Key) -> Self {
        let mut env = MiniGridEnv {
            grid: Vec::new(),
            agent_pos: Pos::new(1, 1),
            agent_dir: Direction::East,
            carrying: None,
            step_count: 0,
            mission: -1,
            rng: Rng::from_key(ep_key),
            key: ep_key,
            episode: 0,
            cfg,
        };
        env.reset_with_key(ep_key);
        env
    }

    /// Reset: run the shared layout generator, then convert into the object
    /// grid (boxing every entity — the per-episode allocation storm is part
    /// of the architecture being modelled). An unplaceable layout draw is
    /// retried with successor episode keys, mirroring the batched engine's
    /// deterministic skip-the-same-keys behaviour.
    pub fn reset(&mut self) -> Vec<i32> {
        let (root, id) = (self.key, self.cfg.id.clone());
        crate::envs::retry_episode_keys(&id, root, |_| {
            self.episode += 1;
            self.try_reset_with_key(root.fold_in(self.episode))
        })
    }

    /// Reset the episode from an explicit episode key (panics on an
    /// unplaceable layout; pinned-key parity tests want exactly this key).
    pub fn reset_with_key(&mut self, ep_key: Key) -> Vec<i32> {
        self.try_reset_with_key(ep_key).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Reset the episode from an explicit episode key, surfacing layout
    /// failures as data.
    pub fn try_reset_with_key(&mut self, ep_key: Key) -> Result<Vec<i32>, LayoutError> {
        let mut st = BatchedState::new(1, self.cfg.h, self.cfg.w, self.cfg.caps);
        {
            let mut slot = st.slot_mut(0);
            self.cfg.reset_slot(&mut slot, ep_key)?;
        }
        let s = st.slot(0);
        self.grid = (0..self.cfg.h * self.cfg.w).map(|_| None).collect();
        for r in 0..self.cfg.h as i32 {
            for c in 0..self.cfg.w as i32 {
                let p = Pos::new(r, c);
                let obj: Option<Box<dyn WorldObj>> = match s.cell(p) {
                    CellType::Wall => Some(Box::new(Wall)),
                    CellType::Goal => Some(Box::new(Goal)),
                    CellType::Lava => Some(Box::new(Lava)),
                    CellType::Floor => None,
                };
                self.grid[(r as usize) * self.cfg.w + c as usize] = obj;
            }
        }
        for d in 0..s.door_pos.len() {
            if s.door_pos[d] >= 0 {
                let p = Pos::decode(s.door_pos[d], self.cfg.w);
                self.set(
                    p,
                    Some(Box::new(Door {
                        color: Color::from_u8(s.door_color[d]),
                        state: DoorState::from_u8(s.door_state[d]),
                    })),
                );
            }
        }
        for k in 0..s.key_pos.len() {
            if s.key_pos[k] >= 0 {
                let p = Pos::decode(s.key_pos[k], self.cfg.w);
                self.set(p, Some(Box::new(KeyObj(Color::from_u8(s.key_color[k])))));
            }
        }
        for b in 0..s.ball_pos.len() {
            if s.ball_pos[b] >= 0 {
                let p = Pos::decode(s.ball_pos[b], self.cfg.w);
                self.set(p, Some(Box::new(BallObj(Color::from_u8(s.ball_color[b])))));
            }
        }
        for b in 0..s.box_pos.len() {
            if s.box_pos[b] >= 0 {
                let p = Pos::decode(s.box_pos[b], self.cfg.w);
                self.set(p, Some(Box::new(BoxObj(Color::from_u8(s.box_color[b])))));
            }
        }
        self.agent_pos = s.player();
        self.agent_dir = s.dir();
        self.carrying = None;
        self.mission = s.mission[0];
        self.step_count = 0;
        self.rng = Rng::from_key(ep_key.fold_in(0xBA5E));
        Ok(self.gen_obs())
    }

    #[inline]
    fn get(&self, p: Pos) -> Option<&dyn WorldObj> {
        if !p.in_bounds(self.cfg.h, self.cfg.w) {
            return None;
        }
        self.grid[(p.r as usize) * self.cfg.w + p.c as usize].as_deref()
    }

    #[inline]
    fn set(&mut self, p: Pos, obj: Option<Box<dyn WorldObj>>) {
        self.grid[(p.r as usize) * self.cfg.w + p.c as usize] = obj;
    }

    fn take(&mut self, p: Pos) -> Option<Box<dyn WorldObj>> {
        self.grid[(p.r as usize) * self.cfg.w + p.c as usize].take()
    }

    fn in_bounds(&self, p: Pos) -> bool {
        p.in_bounds(self.cfg.h, self.cfg.w)
    }

    fn front_pos(&self) -> Pos {
        self.agent_pos.step(self.agent_dir)
    }

    /// One environment step (MiniGrid `step` control flow).
    pub fn step(&mut self, action: Action) -> StepResult {
        self.step_count += 1;
        let mut events = Events::NONE;
        let fwd = self.front_pos();

        match action {
            Action::Left => self.agent_dir = self.agent_dir.left(),
            Action::Right => self.agent_dir = self.agent_dir.right(),
            Action::Forward => {
                let (overlap, is_ball) = match self.get(fwd) {
                    None => (self.in_bounds(fwd), false),
                    Some(o) => (o.can_overlap(), o.tag() == Tag::BALL),
                };
                if is_ball {
                    events.ball_hit = true;
                } else if overlap {
                    self.agent_pos = fwd;
                }
            }
            Action::Pickup => {
                if self.carrying.is_none() {
                    let can = self.get(fwd).map(|o| o.can_pickup()).unwrap_or(false);
                    if can {
                        let obj = self.take(fwd);
                        if let Some(o) = &obj {
                            let mission = Mission::from_raw(self.mission);
                            if o.tag() == Tag::BALL && mission.is_pick_up(Tag::BALL, o.color()) {
                                events.ball_picked = true;
                            }
                            // Pickup-mission events (Fetch/UnlockPickup),
                            // mirroring the batched intervention system:
                            // only the pick-up verb fires them.
                            if mission.verb() == Some(MissionVerb::PickUp) {
                                if mission.matches(o.tag(), o.color()) {
                                    events.object_picked = true;
                                } else {
                                    events.wrong_pickup = true;
                                }
                            }
                        }
                        self.carrying = obj;
                    }
                }
            }
            Action::Drop => {
                if self.carrying.is_some() && self.in_bounds(fwd) && self.get(fwd).is_none() {
                    let obj = self.carrying.take();
                    let mission = Mission::from_raw(self.mission);
                    if let Some(o) = &obj {
                        // PutNext success: the mission's moved object lands
                        // 4-adjacent to its second object (same check as the
                        // batched intervention system).
                        if mission.verb() == Some(MissionVerb::PutNext)
                            && mission.matches(o.tag(), o.color())
                        {
                            let (nt, nc) = (mission.near_kind_tag(), mission.near_color());
                            let adjacent =
                                [(-1, 0), (1, 0), (0, -1), (0, 1)].iter().any(|&(dr, dc)| {
                                    self.get(Pos::new(fwd.r + dr, fwd.c + dc))
                                        .map(|n| n.tag() == nt && n.color() == nc)
                                        .unwrap_or(false)
                                });
                            if adjacent {
                                events.object_placed = true;
                            }
                        }
                    }
                    self.set(fwd, obj);
                }
            }
            Action::Toggle => {
                let carrying = std::mem::take(&mut self.carrying);
                if let Some(slot) =
                    self.in_bounds(fwd).then(|| (fwd.r as usize) * self.cfg.w + fwd.c as usize)
                {
                    if let Some(obj) = self.grid[slot].as_mut() {
                        let was_locked =
                            obj.tag() == Tag::DOOR && obj.state() == DoorState::Locked as i32;
                        if obj.toggle(&carrying) && was_locked {
                            events.door_unlocked = true;
                        }
                    }
                }
                self.carrying = carrying;
            }
            Action::Done => {
                if let Some(o) = self.get(fwd) {
                    let mission = Mission::from_raw(self.mission);
                    if mission.is_go_to(o.tag(), o.color()) {
                        if o.tag() == Tag::DOOR {
                            events.door_done = true;
                        } else {
                            events.object_reached = true;
                        }
                    }
                }
            }
        }

        // Dynamic obstacles (Dynamic-Obstacles family).
        if self.cfg.stochastic_balls {
            self.move_obstacles(&mut events);
        }

        // Position-coincidence events.
        if let Some(o) = self.get(self.agent_pos) {
            match o.tag() {
                Tag::GOAL => events.goal_reached = true,
                Tag::LAVA => events.lava_fall = true,
                _ => {}
            }
        }

        let reward = eval_reward(&self.cfg, &events, action, self.step_count);
        let terminated = eval_termination(&self.cfg, &events);
        let truncated = !terminated && self.step_count >= self.cfg.max_steps;

        StepResult { obs: self.gen_obs(), reward, terminated, truncated }
    }

    fn move_obstacles(&mut self, events: &mut Events) {
        let balls: Vec<Pos> = (0..self.cfg.h as i32)
            .flat_map(|r| (0..self.cfg.w as i32).map(move |c| Pos::new(r, c)))
            .filter(|&p| self.get(p).map(|o| o.tag() == Tag::BALL).unwrap_or(false))
            .collect();
        for p in balls {
            for _ in 0..8 {
                let dr = self.rng.randint(-1, 2);
                let dc = self.rng.randint(-1, 2);
                let q = Pos::new(p.r + dr, p.c + dc);
                if q == p {
                    break;
                }
                if q == self.agent_pos {
                    events.ball_hit = true;
                    break;
                }
                if self.in_bounds(q) && self.get(q).is_none() {
                    let obj = self.take(p);
                    self.set(q, obj);
                    break;
                }
            }
        }
    }

    /// Generate the first-person symbolic observation (fresh allocation per
    /// call, as in the Python original).
    pub fn gen_obs(&self) -> Vec<i32> {
        let view = self.cfg.obs.view;
        let mut obs = vec![0i32; view * view * 3];
        let mut mask = vec![false; view * view];

        // visibility propagation over the object grid
        let transparent = |vr: usize, vc: usize| -> bool {
            let p = crate::systems::observations::view_to_world(
                self.agent_pos,
                self.agent_dir,
                view,
                vr,
                vc,
            );
            if !p.in_bounds(self.cfg.h, self.cfg.w) {
                return false;
            }
            self.get(p).map(|o| o.see_behind()).unwrap_or(true)
        };
        mask[(view - 1) * view + view / 2] = true;
        for vr in (0..view).rev() {
            for vc in 0..view - 1 {
                if mask[vr * view + vc] && transparent(vr, vc) {
                    mask[vr * view + vc + 1] = true;
                    if vr > 0 {
                        mask[(vr - 1) * view + vc] = true;
                        mask[(vr - 1) * view + vc + 1] = true;
                    }
                }
            }
            for vc in (1..view).rev() {
                if mask[vr * view + vc] && transparent(vr, vc) {
                    mask[vr * view + vc - 1] = true;
                    if vr > 0 {
                        mask[(vr - 1) * view + vc] = true;
                        mask[(vr - 1) * view + vc - 1] = true;
                    }
                }
            }
        }

        for vr in 0..view {
            for vc in 0..view {
                let i = (vr * view + vc) * 3;
                if !mask[vr * view + vc] {
                    continue; // unseen = (0,0,0)
                }
                if vr == view - 1 && vc == view / 2 {
                    if let Some(o) = &self.carrying {
                        obs[i] = o.tag();
                        obs[i + 1] = o.color() as i32;
                        obs[i + 2] = o.state();
                    } else if let Some(o) = self.get(self.agent_pos) {
                        obs[i] = o.tag();
                        obs[i + 1] = o.color() as i32;
                        obs[i + 2] = o.state();
                    } else {
                        obs[i] = Tag::EMPTY;
                    }
                    continue;
                }
                let p = crate::systems::observations::view_to_world(
                    self.agent_pos,
                    self.agent_dir,
                    view,
                    vr,
                    vc,
                );
                if !p.in_bounds(self.cfg.h, self.cfg.w) {
                    continue;
                }
                match self.get(p) {
                    Some(o) => {
                        obs[i] = o.tag();
                        obs[i + 1] = o.color() as i32;
                        obs[i + 2] = o.state();
                    }
                    None => {
                        obs[i] = Tag::EMPTY;
                    }
                }
            }
        }
        obs
    }

    pub fn agent_pos(&self) -> Pos {
        self.agent_pos
    }
    pub fn agent_dir(&self) -> Direction {
        self.agent_dir
    }
    pub fn carrying_tag(&self) -> Option<i32> {
        self.carrying.as_ref().map(|o| o.tag())
    }
}

fn eval_reward(cfg: &EnvConfig, events: &Events, action: Action, t: u32) -> f32 {
    use crate::systems::rewards::RewardFn;
    cfg.reward
        .terms
        .iter()
        .map(|f| match f {
            RewardFn::OnGoalReached => events.goal_reached as i32 as f32,
            RewardFn::OnLavaFall => -(events.lava_fall as i32 as f32),
            RewardFn::OnDoorDone => events.door_done as i32 as f32,
            RewardFn::OnBallPicked => events.ball_picked as i32 as f32,
            RewardFn::OnBallHit => -(events.ball_hit as i32 as f32),
            RewardFn::OnDoorUnlocked => events.door_unlocked as i32 as f32,
            RewardFn::OnObjectPicked => events.object_picked as i32 as f32,
            RewardFn::OnObjectReached => events.object_reached as i32 as f32,
            RewardFn::OnObjectPlaced => events.object_placed as i32 as f32,
            RewardFn::Free => 0.0,
            RewardFn::ActionCost(c) => {
                if action == Action::Done {
                    0.0
                } else {
                    -c
                }
            }
            RewardFn::TimeCost(c) => -c,
            // `step_count` was incremented at the top of `step`, matching
            // upstream MiniGrid's `1 - 0.9 * step_count / max_steps`.
            RewardFn::MiniGridLegacy => {
                if events.goal_reached {
                    1.0 - 0.9 * t as f32 / cfg.max_steps.max(1) as f32
                } else {
                    0.0
                }
            }
        })
        .sum()
}

fn eval_termination(cfg: &EnvConfig, events: &Events) -> bool {
    use crate::systems::terminations::TermFn;
    cfg.termination.terms.iter().any(|f| match f {
        TermFn::OnGoalReached => events.goal_reached,
        TermFn::OnLavaFall => events.lava_fall,
        TermFn::OnDoorDone => events.door_done,
        TermFn::OnBallPicked => events.ball_picked,
        TermFn::OnBallHit => events.ball_hit,
        TermFn::OnDoorUnlocked => events.door_unlocked,
        TermFn::OnObjectPicked => events.object_picked,
        TermFn::OnWrongPickup => events.wrong_pickup,
        TermFn::OnObjectReached => events.object_reached,
        TermFn::OnObjectPlaced => events.object_placed,
        TermFn::Free => false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;

    #[test]
    fn scripted_empty_episode_matches_batched_engine() {
        // Same seed → same layout; same action script → same rewards.
        let cfg = make("Navix-Empty-5x5-v0").unwrap();
        let mut env = MiniGridEnv::new(cfg, Key::new(0));
        let script =
            [Action::Forward, Action::Forward, Action::Right, Action::Forward, Action::Forward];
        let mut last = None;
        for &a in &script {
            last = Some(env.step(a));
        }
        let last = last.unwrap();
        assert!(last.terminated);
        assert_eq!(last.reward, 1.0);
    }

    #[test]
    fn doorkey_task_completable() {
        let cfg = make("Navix-DoorKey-5x5-v0").unwrap();
        let mut env = MiniGridEnv::new(cfg, Key::new(0));
        for a in [
            Action::Right,
            Action::Forward,
            Action::Pickup,
            Action::Left,
            Action::Toggle,
            Action::Forward,
            Action::Forward,
            Action::Right,
        ] {
            let r = env.step(a);
            assert!(!r.terminated, "terminated early");
        }
        assert_eq!(env.carrying_tag(), Some(Tag::KEY));
        let r = env.step(Action::Forward);
        assert!(r.terminated);
        assert_eq!(r.reward, 1.0);
    }

    #[test]
    fn obs_matches_batched_engine_on_reset() {
        // Byte-compatibility across engines (the drop-in-replacement claim).
        for id in ["Navix-Empty-8x8-v0", "Navix-DoorKey-8x8-v0", "Navix-LavaGapS7-v0"] {
            let cfg = make(id).unwrap();
            let env = MiniGridEnv::new(cfg.clone(), Key::new(7));
            let obs_oo = env.gen_obs();

            let mut st = BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
            {
                let mut slot = st.slot_mut(0);
                // replicate MiniGridEnv::reset's episode key schedule
                cfg.reset_slot(&mut slot, Key::new(7).fold_in(1)).unwrap();
            }
            let mut obs_soa = vec![0i32; cfg.obs.len(cfg.h, cfg.w)];
            cfg.obs.write_i32(&st.slot(0), &mut obs_soa);
            assert_eq!(obs_oo, obs_soa, "{id}: engines disagree on reset obs");
        }
    }

    #[test]
    fn truncation_after_max_steps() {
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.max_steps = 2;
        let mut env = MiniGridEnv::new(cfg, Key::new(0));
        env.step(Action::Left);
        let r = env.step(Action::Left);
        assert!(r.truncated && !r.terminated);
    }

    #[test]
    fn dynamic_obstacles_never_crash_and_can_hit() {
        let cfg = make("Navix-Dynamic-Obstacles-5x5").unwrap();
        let mut env = MiniGridEnv::new(cfg, Key::new(3));
        let mut rng = Rng::new(5);
        let mut saw_hit = false;
        for _ in 0..300 {
            let a = Action::from_u8(rng.below(7) as u8);
            let r = env.step(a);
            if r.terminated && r.reward < 0.0 {
                saw_hit = true;
            }
            if r.terminated || r.truncated {
                env.reset();
            }
        }
        assert!(saw_hit, "random policy should collide at least once in 5x5");
    }
}
