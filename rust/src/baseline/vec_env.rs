//! Gymnasium-style vector-environment wrappers over the scalar baseline.
//!
//! * [`SyncVectorEnv`] — step each env in a Python-style sequential loop.
//! * [`AsyncVectorEnv`] — one worker *thread* per environment with channel
//!   IPC, the architectural analog of gymnasium's `multiprocessing`
//!   vectorisation that MiniGrid relies on (paper §4.2). Per-step
//!   synchronisation and message passing are intentionally part of the
//!   measured cost — that is the overhead the paper's Fig. 5 exposes
//!   (the original dies at 16 envs; ours degrades more gracefully but the
//!   per-env thread cost still grows linearly).
//!
//! Both wrappers autoreset like `gymnasium.vector` (terminal step returns
//! the final obs of the old episode is *not* modelled; we return the fresh
//! reset obs, matching NAVIX's autoreset convention so cross-engine
//! trajectory comparisons stay aligned).

use super::minigrid::{MiniGridEnv, StepResult};
use crate::core::actions::Action;
use crate::envs::EnvConfig;
use crate::rng::Key;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

/// Batched step outcome (one entry per env).
pub struct VecStep {
    pub obs: Vec<Vec<i32>>,
    pub reward: Vec<f32>,
    pub terminated: Vec<bool>,
    pub truncated: Vec<bool>,
}

/// Sequential vector env (gymnasium `SyncVectorEnv`).
pub struct SyncVectorEnv {
    envs: Vec<MiniGridEnv>,
    needs_reset: Vec<bool>,
}

impl SyncVectorEnv {
    pub fn new(cfg: EnvConfig, n: usize, key: Key) -> Self {
        let envs =
            (0..n).map(|i| MiniGridEnv::new(cfg.clone(), key.fold_in(i as u64))).collect();
        SyncVectorEnv { envs, needs_reset: vec![false; n] }
    }

    pub fn num_envs(&self) -> usize {
        self.envs.len()
    }

    pub fn reset(&mut self) -> Vec<Vec<i32>> {
        self.needs_reset.fill(false);
        self.envs.iter_mut().map(|e| e.reset()).collect()
    }

    pub fn step(&mut self, actions: &[u8]) -> VecStep {
        let n = self.envs.len();
        let mut out = VecStep {
            obs: Vec::with_capacity(n),
            reward: vec![0.0; n],
            terminated: vec![false; n],
            truncated: vec![false; n],
        };
        for (i, env) in self.envs.iter_mut().enumerate() {
            if self.needs_reset[i] {
                out.obs.push(env.reset());
                self.needs_reset[i] = false;
                continue;
            }
            let StepResult { obs, reward, terminated, truncated } =
                env.step(Action::from_u8(actions[i]));
            if terminated || truncated {
                self.needs_reset[i] = true;
            }
            out.obs.push(obs);
            out.reward[i] = reward;
            out.terminated[i] = terminated;
            out.truncated[i] = truncated;
        }
        out
    }
}

enum Cmd {
    Step(u8),
    Reset,
    Close,
}

struct Worker {
    cmd: Sender<Cmd>,
    res: Receiver<StepResult>,
    handle: Option<JoinHandle<()>>,
}

/// Thread-per-env vector env (gymnasium `AsyncVectorEnv` analog).
pub struct AsyncVectorEnv {
    workers: Vec<Worker>,
    needs_reset: Vec<bool>,
}

impl AsyncVectorEnv {
    pub fn new(cfg: EnvConfig, n: usize, key: Key) -> Self {
        let workers = (0..n)
            .map(|i| {
                let (cmd_tx, cmd_rx) = channel::<Cmd>();
                let (res_tx, res_rx) = channel::<StepResult>();
                let cfg = cfg.clone();
                let wkey = key.fold_in(i as u64);
                let handle = std::thread::spawn(move || {
                    let mut env = MiniGridEnv::new(cfg, wkey);
                    while let Ok(cmd) = cmd_rx.recv() {
                        match cmd {
                            Cmd::Step(a) => {
                                let r = env.step(Action::from_u8(a));
                                if res_tx.send(r).is_err() {
                                    break;
                                }
                            }
                            Cmd::Reset => {
                                let obs = env.reset();
                                let r = StepResult {
                                    obs,
                                    reward: 0.0,
                                    terminated: false,
                                    truncated: false,
                                };
                                if res_tx.send(r).is_err() {
                                    break;
                                }
                            }
                            Cmd::Close => break,
                        }
                    }
                });
                Worker { cmd: cmd_tx, res: res_rx, handle: Some(handle) }
            })
            .collect();
        AsyncVectorEnv { workers, needs_reset: vec![false; n] }
    }

    pub fn num_envs(&self) -> usize {
        self.workers.len()
    }

    pub fn reset(&mut self) -> Vec<Vec<i32>> {
        for w in &self.workers {
            w.cmd.send(Cmd::Reset).expect("worker alive");
        }
        self.needs_reset.fill(false);
        self.workers.iter().map(|w| w.res.recv().expect("worker alive").obs).collect()
    }

    /// Scatter actions, gather results (the per-step synchronisation barrier
    /// the paper's baseline pays on every step).
    pub fn step(&mut self, actions: &[u8]) -> VecStep {
        let n = self.workers.len();
        for (i, w) in self.workers.iter().enumerate() {
            let cmd =
                if self.needs_reset[i] { Cmd::Reset } else { Cmd::Step(actions[i]) };
            w.cmd.send(cmd).expect("worker alive");
        }
        let mut out = VecStep {
            obs: Vec::with_capacity(n),
            reward: vec![0.0; n],
            terminated: vec![false; n],
            truncated: vec![false; n],
        };
        for (i, w) in self.workers.iter().enumerate() {
            let r = w.res.recv().expect("worker alive");
            if self.needs_reset[i] {
                self.needs_reset[i] = false;
            } else if r.terminated || r.truncated {
                self.needs_reset[i] = true;
            }
            out.obs.push(r.obs);
            out.reward[i] = r.reward;
            out.terminated[i] = r.terminated;
            out.truncated[i] = r.truncated;
        }
        out
    }
}

impl Drop for AsyncVectorEnv {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.cmd.send(Cmd::Close);
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::rng::Rng;

    #[test]
    fn sync_vector_steps_and_autoresets() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap();
        let mut venv = SyncVectorEnv::new(cfg, 4, Key::new(0));
        let obs = venv.reset();
        assert_eq!(obs.len(), 4);
        assert_eq!(obs[0].len(), 7 * 7 * 3);
        // drive env 0 to the goal
        for a in [2u8, 2, 1, 2, 2] {
            let r = venv.step(&[a, 0, 0, 0]);
            if r.terminated[0] {
                assert_eq!(r.reward[0], 1.0);
            }
        }
        // next step autoresets env 0 without touching the others
        let r = venv.step(&[0, 0, 0, 0]);
        assert!(!r.terminated[0]);
    }

    #[test]
    fn async_vector_matches_sync_rewards() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap();
        let mut sync = SyncVectorEnv::new(cfg.clone(), 3, Key::new(9));
        let mut asyn = AsyncVectorEnv::new(cfg, 3, Key::new(9));
        sync.reset();
        asyn.reset();
        let mut rng = Rng::new(1);
        for _ in 0..60 {
            let actions: Vec<u8> = (0..3).map(|_| rng.below(7) as u8).collect();
            let rs = sync.step(&actions);
            let ra = asyn.step(&actions);
            assert_eq!(rs.reward, ra.reward);
            assert_eq!(rs.terminated, ra.terminated);
            assert_eq!(rs.obs, ra.obs);
        }
    }

    #[test]
    fn async_shuts_down_cleanly() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap();
        let mut venv = AsyncVectorEnv::new(cfg, 8, Key::new(0));
        venv.reset();
        venv.step(&[0; 8]);
        drop(venv); // must join all workers without hanging
    }
}
