//! The comparison baseline: a faithful re-creation of the *architecture* the
//! paper benchmarks against (MiniGrid + gymnasium vector envs).
//!
//! * [`minigrid`] — an object-oriented scalar engine: one heap-allocated
//!   trait object per grid cell, dynamic dispatch on every interaction,
//!   per-step observation allocation. This mirrors MiniGrid's
//!   `WorldObj`/`Grid` design (the paper's CPU-bound baseline), minus the
//!   Python interpreter.
//! * [`vec_env`] — gymnasium-style vector wrappers: `SyncVectorEnv`
//!   (sequential loop) and `AsyncVectorEnv` (one worker thread per
//!   environment with channel IPC, the analog of gymnasium's
//!   `multiprocessing` — the configuration the paper's Fig. 5 shows dying
//!   at 16 environments).
//!
//! Both engines consume the same [`crate::envs::EnvConfig`]s and layout
//! generators, so speed comparisons measure *architecture* (batched SoA vs.
//! object-per-cell + per-env worker), not differing game rules. This is the
//! substitution documented in DESIGN.md: our baseline has no Python
//! interpreter, so measured gaps are a *lower bound* on the paper's.

pub mod minigrid;
pub mod vec_env;

pub use minigrid::MiniGridEnv;
pub use vec_env::{AsyncVectorEnv, SyncVectorEnv};
