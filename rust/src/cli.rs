//! Hand-rolled command-line argument parsing (clap is not vendored in this
//! offline image). Supports the `navix <subcommand> [--flag value] [--switch]
//! [positional…]` grammar used by the launcher.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Parsed command line: subcommand, `--key value` options, bare `--switch`
/// flags and positional arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Option names that take no value (everything else with `--` expects one).
const SWITCHES: &[&str] =
    &["help", "verbose", "tune", "baseline", "xla", "quiet", "sharded", "smoke", "pipeline"];

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let mut args = Args::default();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next().unwrap();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if SWITCHES.contains(&name) {
                    args.switches.push(name.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                    args.opts.insert(name.to_string(), v);
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} {v}: not an integer")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} {v}: not an integer")),
        }
    }

    pub fn opt_f32(&self, name: &str, default: f32) -> Result<f32> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{name} {v}: not a float")),
        }
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    /// The execution-layer flags shared by the `run`/`train` launchers and
    /// the throughput drivers: `--shards S --threads T` (absent/0 = use the
    /// host's available parallelism) and `--pipeline` (double-buffered
    /// rollout pipeline). See [`crate::batch::ShardedEnv`] and
    /// [`crate::batch::PipelinedEnv`].
    pub fn exec_config(&self) -> Result<crate::config::ExecConfig> {
        Ok(crate::config::ExecConfig {
            num_shards: self.opt_usize("shards", 0)?,
            num_threads: self.opt_usize("threads", 0)?,
            pipeline: self.switch("pipeline"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_opts_and_positionals() {
        let a = parse("train --env Navix-Empty-8x8-v0 --steps 1000 --verbose extra");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.opt("env"), Some("Navix-Empty-8x8-v0"));
        assert_eq!(a.opt_usize("steps", 0).unwrap(), 1000);
        assert!(a.switch("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let a = parse("run --batch=64 --seed=3");
        assert_eq!(a.opt_usize("batch", 0).unwrap(), 64);
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 3);
    }

    #[test]
    fn missing_value_errors() {
        let r = Args::parse(vec!["run".into(), "--env".into()]);
        assert!(r.is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("info");
        assert_eq!(a.opt_or("env", "Navix-Empty-8x8-v0"), "Navix-Empty-8x8-v0");
        assert_eq!(a.opt_f32("lr", 3e-4).unwrap(), 3e-4);
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn exec_config_flags() {
        let a = parse("run --shards 4 --threads 2 --pipeline");
        let e = a.exec_config().unwrap();
        assert_eq!(e.num_shards, 4);
        assert_eq!(e.num_threads, 2);
        assert!(e.pipeline);
        let auto = parse("run").exec_config().unwrap();
        assert_eq!(auto.num_shards, 0, "absent flags mean auto");
        assert_eq!(auto.num_threads, 0);
        assert!(!auto.pipeline, "pipeline is opt-in");
    }
}
