//! Deterministic chaos injection: seeded faults fired at exact
//! (slot, step) coordinates inside the engines' supervised step path.
//!
//! The injector is armed either programmatically
//! ([`crate::batch::BatchedEnv::arm_chaos`]) or through the `NAVIX_CHAOS`
//! environment variable, which every `BatchedEnv` constructor checks — so
//! the sharded and pipelined engines inherit injection in their inner
//! engines with zero plumbing. Slots are addressed *globally* (shard
//! offsets included) and every spec fires exactly once, so the same spec
//! list produces the same fault on every engine topology.
//!
//! Grammar of `NAVIX_CHAOS` (also accepted by [`ChaosInjector::parse`]):
//!
//! ```text
//! panic@SLOT:STEP[;KIND@SLOT:STEP…]     explicit spec list
//! seed=S,n=N,slots=B,maxstep=M          N specs derived from seed S
//! ```
//!
//! Kinds: `panic` (plain injected panic), `badaction` (corrupts one agent's
//! action byte to 255 — the supervised path validates and panics),
//! `poisonrng` (scrambles the slot's in-episode RNG stream *before*
//! panicking, so recovery must actually repair state, not just resume).
//! Every injected panic message starts with `"chaos:"` — the marker
//! [`crate::batch::EngineFault::is_chaos`] counts.

use crate::rng::Rng;

/// What kind of fault to inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Panic mid-step, before the slot body runs.
    Panic,
    /// Corrupt agent 0's action byte to 255 (out of range: `Action::N` is
    /// 7); the supervised validation turns it into a structured panic.
    BadAction,
    /// Corrupt the slot's in-episode RNG stream state, then panic.
    PoisonRng,
}

impl ChaosKind {
    fn parse(s: &str) -> Result<ChaosKind, String> {
        match s {
            "panic" => Ok(ChaosKind::Panic),
            "badaction" => Ok(ChaosKind::BadAction),
            "poisonrng" => Ok(ChaosKind::PoisonRng),
            other => Err(format!(
                "NAVIX_CHAOS: unknown kind {other:?} (expected panic|badaction|poisonrng)"
            )),
        }
    }
}

/// One fault: fire `kind` in global slot `slot` at engine step `step`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChaosSpec {
    pub kind: ChaosKind,
    /// Global slot index (shard offsets included).
    pub slot: usize,
    /// Engine step counter value at which to fire (first step is 1).
    pub step: u64,
}

/// A deterministic, one-shot-per-spec fault injector.
#[derive(Clone, Debug)]
pub struct ChaosInjector {
    specs: Vec<ChaosSpec>,
    fired: Vec<bool>,
}

impl ChaosInjector {
    pub fn new(specs: Vec<ChaosSpec>) -> ChaosInjector {
        let n = specs.len();
        ChaosInjector { specs, fired: vec![false; n] }
    }

    /// Derive `n` specs from a seed: slot in `0..slots`, step in
    /// `1..=max_step`, kind cycling through all three. Engine-independent,
    /// so every topology under the same seed sees the same faults.
    pub fn seeded(seed: u64, n: usize, slots: usize, max_step: u64) -> ChaosInjector {
        assert!(slots > 0 && max_step > 0, "chaos seeded form needs slots > 0, maxstep > 0");
        let mut rng = Rng::new(seed);
        let specs = (0..n)
            .map(|i| ChaosSpec {
                kind: match i % 3 {
                    0 => ChaosKind::Panic,
                    1 => ChaosKind::BadAction,
                    _ => ChaosKind::PoisonRng,
                },
                slot: rng.below(slots as u32) as usize,
                step: 1 + rng.below(max_step as u32) as u64,
            })
            .collect();
        ChaosInjector::new(specs)
    }

    /// Parse the `NAVIX_CHAOS` grammar (module docs).
    pub fn parse(s: &str) -> Result<ChaosInjector, String> {
        let s = s.trim();
        if s.is_empty() {
            return Err("NAVIX_CHAOS is empty".to_string());
        }
        if s.contains("seed=") {
            let mut seed = None;
            let mut n = None;
            let mut slots = None;
            let mut max_step = None;
            for part in s.split(',') {
                let (k, v) = part
                    .split_once('=')
                    .ok_or_else(|| format!("NAVIX_CHAOS: bad key=value pair {part:?}"))?;
                let v: u64 = v
                    .trim()
                    .parse()
                    .map_err(|_| format!("NAVIX_CHAOS: non-numeric value in {part:?}"))?;
                match k.trim() {
                    "seed" => seed = Some(v),
                    "n" => n = Some(v as usize),
                    "slots" => slots = Some(v as usize),
                    "maxstep" => max_step = Some(v),
                    other => return Err(format!("NAVIX_CHAOS: unknown key {other:?}")),
                }
            }
            let (seed, n, slots, max_step) = (
                seed.ok_or("NAVIX_CHAOS: seeded form needs seed=")?,
                n.ok_or("NAVIX_CHAOS: seeded form needs n=")?,
                slots.ok_or("NAVIX_CHAOS: seeded form needs slots=")?,
                max_step.ok_or("NAVIX_CHAOS: seeded form needs maxstep=")?,
            );
            return Ok(ChaosInjector::seeded(seed, n, slots, max_step));
        }
        let specs = s
            .split(';')
            .filter(|e| !e.trim().is_empty())
            .map(|entry| {
                let (kind, at) = entry
                    .trim()
                    .split_once('@')
                    .ok_or_else(|| format!("NAVIX_CHAOS: entry {entry:?} missing '@'"))?;
                let (slot, step) = at
                    .split_once(':')
                    .ok_or_else(|| format!("NAVIX_CHAOS: entry {entry:?} missing ':'"))?;
                Ok(ChaosSpec {
                    kind: ChaosKind::parse(kind.trim())?,
                    slot: slot
                        .trim()
                        .parse()
                        .map_err(|_| format!("NAVIX_CHAOS: bad slot in {entry:?}"))?,
                    step: step
                        .trim()
                        .parse()
                        .map_err(|_| format!("NAVIX_CHAOS: bad step in {entry:?}"))?,
                })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(ChaosInjector::new(specs))
    }

    /// Read `NAVIX_CHAOS`; `None` when unset. A malformed value panics
    /// with the parse error — a chaos run that silently injects nothing
    /// would report a vacuous pass.
    pub fn from_env() -> Option<ChaosInjector> {
        let raw = std::env::var("NAVIX_CHAOS").ok()?;
        if raw.trim().is_empty() {
            return None;
        }
        match ChaosInjector::parse(&raw) {
            Ok(inj) => Some(inj),
            Err(e) => panic!("{e}"),
        }
    }

    /// Should a fault fire for `global_slot` at engine step `step`? Each
    /// spec fires at most once; with several matching specs the earliest
    /// unfired one wins.
    pub fn check(&mut self, global_slot: usize, step: u64) -> Option<ChaosKind> {
        for (spec, fired) in self.specs.iter().zip(self.fired.iter_mut()) {
            if !*fired && spec.slot == global_slot && spec.step == step {
                *fired = true;
                return Some(spec.kind);
            }
        }
        None
    }

    /// How many specs have fired so far.
    pub fn fired_count(&self) -> u64 {
        self.fired.iter().filter(|&&f| f).count() as u64
    }

    pub fn specs(&self) -> &[ChaosSpec] {
        &self.specs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_grammar_parses() {
        let inj = ChaosInjector::parse("panic@3:17; badaction@0:5 ;poisonrng@2:9").unwrap();
        assert_eq!(
            inj.specs(),
            &[
                ChaosSpec { kind: ChaosKind::Panic, slot: 3, step: 17 },
                ChaosSpec { kind: ChaosKind::BadAction, slot: 0, step: 5 },
                ChaosSpec { kind: ChaosKind::PoisonRng, slot: 2, step: 9 },
            ]
        );
        assert!(ChaosInjector::parse("explode@1:1").is_err());
        assert!(ChaosInjector::parse("panic@1").is_err());
        assert!(ChaosInjector::parse("seed=1,n=2").is_err(), "seeded form needs all keys");
    }

    #[test]
    fn seeded_form_is_deterministic_and_in_range() {
        let a = ChaosInjector::seeded(42, 5, 16, 100);
        let b = ChaosInjector::parse("seed=42,n=5,slots=16,maxstep=100").unwrap();
        assert_eq!(a.specs(), b.specs());
        for s in a.specs() {
            assert!(s.slot < 16);
            assert!(s.step >= 1 && s.step <= 100);
        }
        assert_ne!(
            ChaosInjector::seeded(43, 5, 16, 100).specs(),
            a.specs(),
            "different seeds must differ"
        );
    }

    #[test]
    fn specs_fire_exactly_once() {
        let mut inj = ChaosInjector::parse("panic@1:2").unwrap();
        assert_eq!(inj.check(0, 2), None);
        assert_eq!(inj.check(1, 1), None);
        assert_eq!(inj.check(1, 2), Some(ChaosKind::Panic));
        assert_eq!(inj.check(1, 2), None, "one-shot");
        assert_eq!(inj.fired_count(), 1);
    }
}
