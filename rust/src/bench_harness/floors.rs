//! Committed bench floors (`bench_floors.toml` at the repo root).
//!
//! The smoke benches gate CI on "steps/s must clear a recorded floor". The
//! floors used to live as defaults buried in each bench binary, so raising
//! one meant a code change nobody reviewed as a perf claim. They are now
//! centralised in `bench_floors.toml` — a committed, reviewable file read by
//! both the benches and the CI jobs — and resolved here with a fixed
//! precedence:
//!
//! 1. the bench's environment variable (e.g. `NAVIX_TRAIN_SMOKE_FLOOR`) —
//!    a per-run override for experiments and one-off CI reruns;
//! 2. `bench_floors.toml`, located via `NAVIX_BENCH_FLOORS=<path>` or by
//!    searching the working directory and up to two parents (cargo runs
//!    benches from `rust/`, the workflows from the repo root);
//! 3. the bench's built-in conservative default.
//!
//! Every [`Floor`] carries its `source` so a floor miss can report *which*
//! number judged it (`source: bench_floors.toml`) and the emitted
//! `BENCH_*.json` records the provenance in its `meta` object.

use crate::config::Config;

/// The file's key layout: `[<section>] smoke_floor_steps_per_s = <float>`.
const KEY: &str = "smoke_floor_steps_per_s";

/// A resolved floor: the gate value plus where it came from.
#[derive(Clone, Debug, PartialEq)]
pub struct Floor {
    /// Minimum acceptable steps/s.
    pub value: f64,
    /// Provenance label: the override env var's name, the floors file's
    /// path, or `"built-in default"`.
    pub source: String,
}

/// Resolve the floor for `section` (a `[section]` of `bench_floors.toml`)
/// with the precedence documented at module level.
pub fn resolve(section: &str, env_var: &str, default: f64) -> Floor {
    let env_val = std::env::var(env_var).ok();
    let file = locate().and_then(|path| Config::load(&path).ok().map(|cfg| (path, cfg)));
    let file_ref = file.as_ref().map(|(p, c)| (p.as_str(), c));
    resolve_from(env_val.as_deref(), file_ref, section, env_var, default)
}

/// The pure core of [`resolve`], separated so tests can exercise the
/// precedence without touching the process environment or the filesystem.
pub fn resolve_from(
    env_val: Option<&str>,
    file: Option<(&str, &Config)>,
    section: &str,
    env_var: &str,
    default: f64,
) -> Floor {
    if let Some(v) = env_val.and_then(|v| v.parse::<f64>().ok()) {
        return Floor { value: v, source: env_var.to_string() };
    }
    if let Some((path, cfg)) = file {
        if let Some(v) =
            cfg.get(&format!("{section}.{KEY}")).and_then(|v| v.parse::<f64>().ok())
        {
            return Floor { value: v, source: path.to_string() };
        }
    }
    Floor { value: default, source: "built-in default".to_string() }
}

/// Find `bench_floors.toml`: explicit `NAVIX_BENCH_FLOORS` path, else the
/// first hit walking from the working directory up two parents.
fn locate() -> Option<String> {
    if let Ok(path) = std::env::var("NAVIX_BENCH_FLOORS") {
        if !path.is_empty() {
            return Some(path);
        }
    }
    for candidate in
        ["bench_floors.toml", "../bench_floors.toml", "../../bench_floors.toml"]
    {
        if std::path::Path::new(candidate).is_file() {
            return Some(candidate.to_string());
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn floors_file() -> Config {
        Config::parse(
            "# committed floors\n[obs]\nsmoke_floor_steps_per_s = 100000\n\n\
             [train]\nsmoke_floor_steps_per_s = 8000\n",
        )
        .unwrap()
    }

    #[test]
    fn env_var_beats_file_beats_default() {
        let cfg = floors_file();
        let file = Some(("bench_floors.toml", &cfg));
        let f = resolve_from(Some("123.5"), file, "train", "NAVIX_TRAIN_SMOKE_FLOOR", 5000.0);
        assert_eq!(f, Floor { value: 123.5, source: "NAVIX_TRAIN_SMOKE_FLOOR".into() });
        let f = resolve_from(None, file, "train", "NAVIX_TRAIN_SMOKE_FLOOR", 5000.0);
        assert_eq!(f, Floor { value: 8000.0, source: "bench_floors.toml".into() });
        let f = resolve_from(None, None, "train", "NAVIX_TRAIN_SMOKE_FLOOR", 5000.0);
        assert_eq!(f, Floor { value: 5000.0, source: "built-in default".into() });
    }

    #[test]
    fn unparseable_override_and_missing_section_fall_through() {
        let cfg = floors_file();
        let file = Some(("bench_floors.toml", &cfg));
        // A garbage env override falls through to the file...
        let f = resolve_from(Some("fast"), file, "obs", "NAVIX_OBS_SMOKE_FLOOR", 1.0);
        assert_eq!(f.value, 100_000.0);
        // ...and a section the file doesn't know falls through to the default.
        let f = resolve_from(None, file, "nope", "NAVIX_NOPE_FLOOR", 42.0);
        assert_eq!(f, Floor { value: 42.0, source: "built-in default".into() });
    }

    #[test]
    fn the_committed_floors_file_parses_with_this_reader() {
        // Keep the real file honest: if someone edits bench_floors.toml into
        // a shape Config::parse rejects, this test (not a nightly bench) is
        // what fails. Skipped silently if the file is not where cargo test
        // runs (workspace layouts vary in CI).
        for path in ["bench_floors.toml", "../bench_floors.toml", "../../bench_floors.toml"] {
            if let Ok(text) = std::fs::read_to_string(path) {
                let cfg = Config::parse(&text).expect("bench_floors.toml must parse");
                for section in ["obs", "train"] {
                    let key = format!("{section}.{KEY}");
                    let v: f64 = cfg
                        .get(&key)
                        .unwrap_or_else(|| panic!("{key} missing from {path}"))
                        .parse()
                        .expect("floor must be a number");
                    assert!(v > 0.0, "{key} must be positive");
                }
                return;
            }
        }
    }
}
