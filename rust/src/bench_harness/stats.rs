//! Summary statistics matching the paper's plots: medians with 5–95
//! percentile confidence intervals across repeated runs/seeds.

/// Summary of a sample of wall times (seconds) or any scalar metric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    /// 5th percentile (lower CI bound in the paper's figures).
    pub p5: f64,
    /// 95th percentile (upper CI bound).
    pub p95: f64,
    pub std: f64,
}

impl Summary {
    pub fn from_samples(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "empty sample");
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            median: percentile(&sorted, 50.0),
            min: sorted[0],
            max: sorted[n - 1],
            p5: percentile(&sorted, 5.0),
            p95: percentile(&sorted, 95.0),
            std: var.sqrt(),
        }
    }

    /// "median [p5, p95]" with engineering units.
    pub fn fmt_secs(&self) -> String {
        format!(
            "{} [{}, {}]",
            fmt_duration(self.median),
            fmt_duration(self.p5),
            fmt_duration(self.p95)
        )
    }
}

/// Linear-interpolated percentile of a *sorted* sample.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Load-imbalance ratio of per-shard busy times: `max / mean` (1.0 =
/// perfectly balanced). Reported by the sharded benches next to steps/s —
/// the gap between the speedup and the thread count is explained by this
/// number plus the synchronisation overhead.
pub fn imbalance(busy_secs: &[f64]) -> f64 {
    if busy_secs.is_empty() {
        return 1.0;
    }
    let max = busy_secs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let mean = busy_secs.iter().sum::<f64>() / busy_secs.len() as f64;
    if mean <= 0.0 {
        1.0
    } else {
        max / mean
    }
}

/// Human-friendly seconds formatting (µs/ms/s).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_of_known_sample() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::from_samples(&xs);
        assert!((s.median - 50.5).abs() < 1e-9);
        assert!((s.p5 - 5.95).abs() < 1e-9);
        assert!((s.p95 - 95.05).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let s = Summary::from_samples(&[2.0]);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.p5, 2.0);
        assert_eq!(s.p95, 2.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.5e-4), "50.0µs");
        assert_eq!(fmt_duration(0.5), "500.00ms");
        assert_eq!(fmt_duration(2.5), "2.50s");
    }

    #[test]
    fn imbalance_ratio() {
        assert!((imbalance(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12, "balanced");
        assert!((imbalance(&[2.0, 1.0, 0.0]) - 2.0).abs() < 1e-12, "max 2 / mean 1");
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0.0, 0.0]), 1.0, "no work yet");
    }
}
