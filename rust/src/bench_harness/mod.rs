//! Benchmark harness substrate (criterion is not vendored in this offline
//! image, so we provide the subset the paper's figures need: repeated timed
//! runs, medians and the 5–95 percentile confidence intervals every NAVIX
//! plot reports).

pub mod chaos;
pub mod floors;
pub mod stats;

pub use chaos::{ChaosInjector, ChaosKind, ChaosSpec};
pub use floors::Floor;
pub use stats::Summary;

use std::time::Instant;

/// Time `f` once, returning seconds.
pub fn time_once<F: FnOnce() -> R, R>(f: F) -> (f64, R) {
    let start = Instant::now();
    let r = f();
    (start.elapsed().as_secs_f64(), r)
}

/// Run `f` for `warmup` unrecorded and `runs` recorded repetitions and
/// summarise the wall times (the paper's protocol: 5 runs, 5–95 pct CI).
pub fn bench<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs {
        let start = Instant::now();
        f();
        times.push(start.elapsed().as_secs_f64());
    }
    Summary::from_samples(&times)
}

/// A formatted results table writer: prints aligned rows to stdout and
/// mirrors them into a results file so EXPERIMENTS.md can cite raw data.
pub struct Report {
    name: String,
    rows: Vec<Vec<String>>,
    header: Vec<String>,
    meta: Vec<(String, String)>,
}

impl Report {
    pub fn new(name: &str, header: &[&str]) -> Self {
        println!("\n=== {name} ===");
        println!("{}", header.join("\t"));
        Report {
            name: name.to_string(),
            rows: Vec::new(),
            header: header.iter().map(|s| s.to_string()).collect(),
            meta: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        println!("{}", cells.join("\t"));
        self.rows.push(cells.to_vec());
    }

    /// Attach a key/value pair to the emitted JSON's `meta` object — used by
    /// the smoke benches to record the gate (`floor`, `floor_source`) next
    /// to the number it judged (`measured`), so a CI floor miss is
    /// diagnosable from the `BENCH_*.json` artifact alone.
    pub fn meta(&mut self, key: &str, value: &str) {
        self.meta.push((key.to_string(), value.to_string()));
    }
}

/// Record the SIMD dispatch decision in `report`'s meta block: the path the
/// kernels actually run (`simd_path`), what the CPU probe found
/// (`simd_detected`) and what the environment forced (`simd_requested`,
/// `"auto"` when unforced). Every `BENCH_*.json` carries these, so the
/// artifact alone names the kernel width behind its numbers — and the
/// nightly auto-vs-scalar matrix can be compared without re-deriving the
/// runner's capabilities.
pub fn simd_meta(report: &mut Report) {
    report.meta("simd_path", crate::simd::active().name());
    report.meta("simd_detected", crate::simd::detected().name());
    report.meta("simd_requested", crate::simd::requested().map_or("auto", |p| p.name()));
}

impl Report {
    /// Write the table under `results/` (best-effort): as TSV for
    /// EXPERIMENTS.md citations and as `BENCH_<name>.json` — the artifact
    /// the CI bench-smoke job uploads so the perf trajectory is recorded
    /// run over run.
    pub fn save(&self) {
        let _ = std::fs::create_dir_all("results");
        let safe = self.name.replace([' ', '/'], "_");
        let mut body = self.header.join("\t");
        body.push('\n');
        for r in &self.rows {
            body.push_str(&r.join("\t"));
            body.push('\n');
        }
        let _ = std::fs::write(format!("results/{safe}.tsv"), body);
        let _ = std::fs::write(format!("results/BENCH_{safe}.json"), self.to_json());
    }

    /// The table as a JSON document (hand-rolled: serde is not vendored in
    /// this offline image).
    pub fn to_json(&self) -> String {
        fn esc(s: &str) -> String {
            s.replace('\\', "\\\\").replace('"', "\\\"")
        }
        fn arr(cells: &[String]) -> String {
            let quoted: Vec<String> = cells.iter().map(|c| format!("\"{}\"", esc(c))).collect();
            format!("[{}]", quoted.join(","))
        }
        let rows: Vec<String> = self.rows.iter().map(|r| arr(r)).collect();
        // Every BENCH_*.json self-describes the mission encoding behind its
        // numbers: the tokenised-mission block width is part of the policy
        // input (and of the observation-path work each steps/s row timed),
        // so trend comparisons across PRs must not conflate widths.
        let baked = format!(
            "\"mission_tokens\":\"{}\"",
            crate::core::mission::MISSION_TOKENS
        );
        let meta: Vec<String> = std::iter::once(baked)
            .chain(self.meta.iter().map(|(k, v)| format!("\"{}\":\"{}\"", esc(k), esc(v))))
            .collect();
        format!(
            "{{\"name\":\"{}\",\"header\":{},\"rows\":[{}],\"meta\":{{{}}}}}\n",
            esc(&self.name),
            arr(&self.header),
            rows.join(","),
            meta.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_runs() {
        let mut n = 0;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert!(s.median >= 0.0);
        assert!(s.p95 >= s.p5);
    }

    #[test]
    fn time_once_returns_value() {
        let (dt, v) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn report_json_shape_and_escaping() {
        let mut r = Report::new("json test", &["a", "b"]);
        r.row(&["1".to_string(), "x \"quoted\"".to_string()]);
        let j = r.to_json();
        assert!(j.starts_with("{\"name\":\"json test\""));
        assert!(j.contains("\"header\":[\"a\",\"b\"]"));
        assert!(j.contains("\"rows\":[[\"1\",\"x \\\"quoted\\\"\"]]"));
        // The mission-token width is auto-stamped into every meta block.
        assert!(j.contains(&format!(
            "\"meta\":{{\"mission_tokens\":\"{}\"}}",
            crate::core::mission::MISSION_TOKENS
        )));
        assert!(j.ends_with("}\n"));
    }

    #[test]
    fn report_meta_lands_in_the_json() {
        let mut r = Report::new("meta test", &["a"]);
        r.meta("floor", "8000");
        r.meta("floor_source", "bench_floors.toml");
        let j = r.to_json();
        assert!(j.contains("\"floor\":\"8000\",\"floor_source\":\"bench_floors.toml\"}"));
    }

    #[test]
    fn simd_meta_records_the_dispatch_decision() {
        let mut r = Report::new("simd meta test", &["a"]);
        simd_meta(&mut r);
        let j = r.to_json();
        assert!(j.contains(&format!("\"simd_path\":\"{}\"", crate::simd::active().name())));
        assert!(j.contains(&format!("\"simd_detected\":\"{}\"", crate::simd::detected().name())));
        assert!(j.contains("\"simd_requested\":\""));
    }
}
