//! Splittable, counter-based pseudo-random number generation.
//!
//! The `rand` crate is not vendored in this offline environment, and — more
//! importantly — NAVIX's reproducibility story rests on JAX-style *splittable*
//! keys (`jax.random.split` / `fold_in`). This module provides a small,
//! deterministic, splittable PRNG built on the SplitMix64 finalizer, which is
//! reimplemented bit-for-bit on the Python side (`python/compile/parity.py`)
//! so trajectory-level parity tests can pin down both engines.
//!
//! Statistical quality: SplitMix64 passes BigCrush; for grid-world layout
//! sampling and ε-greedy exploration this is far beyond sufficient.

/// SplitMix64 finalizer: the core bijective mixing function.
#[inline(always)]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A splittable random key, analogous to `jax.random.PRNGKey`.
///
/// Keys are cheap (a single `u64`) and every derivation is a pure function of
/// the key, so the same seed reproduces the same environment layouts and
/// agent exploration on both the Rust and JAX sides.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Key(pub u64);

impl Key {
    /// Create a key from a seed (mirrors `jax.random.PRNGKey(seed)`).
    pub fn new(seed: u64) -> Self {
        Key(splitmix64(seed ^ 0xA076_1D64_78BD_642F))
    }

    /// Derive a child key by folding in data (mirrors `jax.random.fold_in`).
    #[inline]
    pub fn fold_in(self, data: u64) -> Key {
        Key(splitmix64(self.0 ^ splitmix64(data ^ 0x9E6C_63D0_876A_3F6B)))
    }

    /// Split into `n` independent keys (mirrors `jax.random.split`).
    pub fn split(self, n: usize) -> Vec<Key> {
        (0..n as u64).map(|i| self.fold_in(i)).collect()
    }

    /// Split into two keys (the common case).
    #[inline]
    pub fn split2(self) -> (Key, Key) {
        (self.fold_in(0), self.fold_in(1))
    }
}

/// A mutable PRNG stream seeded from a [`Key`]. Used where sequential draws
/// are more convenient than key plumbing (layout generation, baselines).
#[derive(Clone, Debug)]
pub struct Rng {
    pub state: u64,
}

impl Rng {
    pub fn from_key(key: Key) -> Self {
        Rng { state: key.0 }
    }

    pub fn new(seed: u64) -> Self {
        Rng::from_key(Key::new(seed))
    }

    /// Next raw 64 random bits (SplitMix64 sequence).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift reduction
    /// (no modulo bias for the n ≪ 2^64 values used here).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        (((self.next_u64() >> 32) * n as u64) >> 32) as u32
    }

    /// Uniform integer in `[lo, hi)` (mirrors `jax.random.randint`).
    #[inline]
    pub fn randint(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u32) as i32
    }

    /// Uniform float in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Standard normal via Box–Muller (used for NN init).
    pub fn normal(&mut self) -> f64 {
        // Guard against log(0).
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len() as u32) as usize;
        }
        let mut x = self.uniform_f32() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_deterministic() {
        assert_eq!(Key::new(0), Key::new(0));
        assert_ne!(Key::new(0), Key::new(1));
        let (a, b) = Key::new(7).split2();
        assert_ne!(a, b);
        assert_eq!(Key::new(7).split(4).len(), 4);
    }

    #[test]
    fn split_children_are_distinct() {
        let ks = Key::new(42).split(64);
        for i in 0..ks.len() {
            for j in (i + 1)..ks.len() {
                assert_ne!(ks[i], ks[j], "children {i} and {j} collide");
            }
        }
    }

    #[test]
    fn fold_in_differs_from_parent() {
        let k = Key::new(3);
        assert_ne!(k.fold_in(0), k);
        assert_ne!(k.fold_in(0), k.fold_in(1));
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(0);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn uniform_mean_is_centred() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn randint_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let x = r.randint(-3, 4);
            assert!((-3..4).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(1);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_zero_weights() {
        let mut r = Rng::new(2);
        for _ in 0..200 {
            let i = r.categorical(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}
