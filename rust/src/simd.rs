//! Runtime SIMD dispatch: one capability probe, one override knob, one
//! kernel-path enum threaded through both hot kernels.
//!
//! The paper's 200,000× speedup story is batch parallelism × device
//! parallelism × kernel width. Earlier PRs pulled the first two levers
//! (`ShardedEnv`, `PipelinedEnv`, fused `step_n` windows); this module is
//! the third: the streaming overlay featurisers
//! ([`crate::systems::observations`]) and the batched GEMM microkernel
//! ([`crate::nn::mlp`]) dispatch on a [`KernelPath`] selected here once
//! per process.
//!
//! Selection rules (each answer cached in a `OnceLock`, so the CPU probe
//! and the environment are consulted exactly once):
//!
//! 1. `NAVIX_FORCE_SCALAR=1` pins [`KernelPath::Scalar`] — the historic
//!    pure-Rust loops, which are also the bitwise oracles the parity
//!    suites pin the vector paths against.
//! 2. `NAVIX_SIMD=avx2|sse2|scalar` forces a specific path. A request the
//!    CPU cannot satisfy is clamped to the widest supported path with a
//!    warning on stderr — never a fault (the CI `simd-matrix` job probes
//!    `/proc/cpuinfo` first and skips-with-notice instead of relying on
//!    the clamp).
//! 3. Otherwise the CPU probe picks the widest supported path.
//!
//! Every dispatch site honors the process-wide selection ([`active`]) but
//! also accepts an explicit [`KernelPath`] argument, so the parity tests
//! sweep scalar vs sse2 vs avx2 *within one process* and pin them bitwise
//! identical. The vector kernels never reassociate a reduction and never
//! use FMA — see `EXPERIMENTS.md` §SIMD for why identity holds exactly.

use std::sync::OnceLock;

/// Which kernel implementation the hot loops run. Ordered by capability —
/// `Scalar < Sse2 < Avx2` — so clamping a request to the hardware is
/// [`Ord::min`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum KernelPath {
    /// The original pure-Rust loops — always available on every target,
    /// and the oracle the vector paths are pinned against.
    Scalar,
    /// 128-bit `std::arch` x86 intrinsics (4 × f32 / 4 × u32 lanes).
    Sse2,
    /// 256-bit `std::arch` x86 intrinsics (8 × f32 / 8 × u32 lanes).
    Avx2,
}

impl KernelPath {
    /// All paths, narrowest first — the order the CI matrix sweeps.
    pub const ALL: [KernelPath; 3] = [KernelPath::Scalar, KernelPath::Sse2, KernelPath::Avx2];

    /// The name used by `NAVIX_SIMD`, the bench `meta` blocks and the CI
    /// matrix.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Scalar => "scalar",
            KernelPath::Sse2 => "sse2",
            KernelPath::Avx2 => "avx2",
        }
    }

    /// Parse a `NAVIX_SIMD` value (case-insensitive).
    pub fn parse(s: &str) -> Option<KernelPath> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelPath::Scalar),
            "sse2" => Some(KernelPath::Sse2),
            "avx2" => Some(KernelPath::Avx2),
            _ => None,
        }
    }

    /// f32/u32 lanes per vector register on this path.
    pub fn lanes(self) -> usize {
        match self {
            KernelPath::Scalar => 1,
            KernelPath::Sse2 => 4,
            KernelPath::Avx2 => 8,
        }
    }

    /// Can this CPU execute this path?
    pub fn supported(self) -> bool {
        self <= detected()
    }
}

/// The widest path this CPU supports (probed once, then cached).
pub fn detected() -> KernelPath {
    static DETECTED: OnceLock<KernelPath> = OnceLock::new();
    *DETECTED.get_or_init(probe)
}

#[cfg(target_arch = "x86_64")]
fn probe() -> KernelPath {
    if std::arch::is_x86_feature_detected!("avx2") {
        KernelPath::Avx2
    } else if std::arch::is_x86_feature_detected!("sse2") {
        KernelPath::Sse2
    } else {
        KernelPath::Scalar
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn probe() -> KernelPath {
    // Non-x86 targets run the scalar loops. The dispatch sites stay valid
    // because every forced path is clamped through `effective` first.
    KernelPath::Scalar
}

/// The forced path, if any: `NAVIX_FORCE_SCALAR` beats `NAVIX_SIMD`, both
/// read once. `None` means auto-detect; an unrecognised `NAVIX_SIMD` value
/// warns on stderr and auto-detects rather than faulting.
pub fn requested() -> Option<KernelPath> {
    static REQUESTED: OnceLock<Option<KernelPath>> = OnceLock::new();
    *REQUESTED.get_or_init(|| {
        if std::env::var("NAVIX_FORCE_SCALAR").is_ok_and(|v| !v.is_empty() && v != "0") {
            return Some(KernelPath::Scalar);
        }
        match std::env::var("NAVIX_SIMD") {
            Ok(v) if !v.is_empty() => {
                let parsed = KernelPath::parse(&v);
                if parsed.is_none() {
                    eprintln!("NAVIX_SIMD={v}: unknown path (scalar|sse2|avx2); auto-detecting");
                }
                parsed
            }
            _ => None,
        }
    })
}

/// The process-wide selection: the override clamped to the hardware, else
/// the probe. Every dispatch site that is not handed an explicit path
/// runs this answer.
pub fn active() -> KernelPath {
    static ACTIVE: OnceLock<KernelPath> = OnceLock::new();
    *ACTIVE.get_or_init(|| match requested() {
        Some(req) => {
            let eff = effective(req);
            if eff != req {
                eprintln!(
                    "NAVIX_SIMD requests {} but this CPU tops out at {} — running {}",
                    req.name(),
                    detected().name(),
                    eff.name()
                );
            }
            eff
        }
        None => detected(),
    })
}

/// Clamp `kp` to what this CPU can execute: forcing a wider path than the
/// hardware has degrades to the widest supported one instead of faulting.
/// Every kernel dispatch site routes its path argument through here, so an
/// `unsafe` `#[target_feature]` entry point is unreachable without the
/// matching CPU capability.
#[inline]
pub fn effective(kp: KernelPath) -> KernelPath {
    kp.min(detected())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capability_order_and_lanes() {
        assert!(KernelPath::Scalar < KernelPath::Sse2);
        assert!(KernelPath::Sse2 < KernelPath::Avx2);
        assert_eq!(KernelPath::Scalar.lanes(), 1);
        assert_eq!(KernelPath::Sse2.lanes(), 4);
        assert_eq!(KernelPath::Avx2.lanes(), 8);
    }

    #[test]
    fn parse_roundtrips_every_name() {
        for kp in KernelPath::ALL {
            assert_eq!(KernelPath::parse(kp.name()), Some(kp));
            assert_eq!(KernelPath::parse(&kp.name().to_uppercase()), Some(kp));
        }
        assert_eq!(KernelPath::parse("altivec"), None);
        assert_eq!(KernelPath::parse(""), None);
    }

    #[test]
    fn active_is_supported_and_stable() {
        // Whatever the probe/override picked must be runnable here, and the
        // cached answer must not change between calls.
        assert!(active().supported());
        assert_eq!(active(), active());
        assert!(KernelPath::Scalar.supported());
        #[cfg(target_arch = "x86_64")]
        assert!(KernelPath::Sse2.supported(), "sse2 is x86_64 baseline");
    }

    #[test]
    fn effective_clamps_to_hardware() {
        for kp in KernelPath::ALL {
            assert!(effective(kp).supported());
            assert!(effective(kp) <= kp);
        }
        assert_eq!(effective(KernelPath::Scalar), KernelPath::Scalar);
    }

    #[test]
    fn forced_env_is_honored_when_supported() {
        // The contract the CI simd-matrix job relies on: when NAVIX_SIMD
        // names a supported path, exactly that path runs; an unsupported
        // request clamps to the probe instead of faulting.
        match requested() {
            Some(req) if req.supported() => assert_eq!(active(), req),
            Some(_) => assert_eq!(active(), detected()),
            None => assert_eq!(active(), detected()),
        }
    }
}
