//! The batched environment engine — the Rust analog of NAVIX's
//! `jax.vmap(env.step)` (paper §3.2.2 and §4.2).
//!
//! `BatchedEnv` owns a struct-of-arrays [`BatchedState`] for `B` parallel
//! environments plus reusable observation/reward/discount buffers, and steps
//! all of them with zero per-step allocation. Autoreset follows the paper's
//! timestep design: if an environment's previous timestep was terminal, the
//! step resets it instead (returning a `First` timestep), so agent code
//! stays branch-free.
//!
//! The batching win this engine reproduces is architectural, not SIMD magic:
//! one dispatch amortised over `B` contiguous state slots vs. one Python
//! object graph per environment in the baseline ([`crate::baseline`]).
//!
//! Three execution layers compose on top of the same state: [`BatchedEnv`]
//! (single-threaded `vmap` analog), [`sharded::ShardedEnv`] (multi-core
//! `pmap` analog) and [`pipeline::PipelinedEnv`] (double-buffered rollout
//! pipeline that overlaps stepping with learner compute) — all bitwise
//! equivalent for a fixed seed.
//!
//! The observation/step hot path is **scan-free**: spatial queries and the
//! per-cell encoding read the state's packed cell-code overlay grid (one
//! `u32` per cell, kept write-through consistent — see
//! [`crate::core::state`]), and full-grid rgb uses per-env **dirty-tile
//! tracking**: the image is rendered once, then only tiles whose code
//! changed are re-blitted each step.
//!
//! ## RNG contract (what makes sharding deterministic)
//!
//! Every episode key is a pure function of `(root key, global env index,
//! per-env episode count)` — `key.fold_in(index).fold_in(count)` — and the
//! in-episode stream lives inside the env's own state slot. Nothing depends
//! on the order envs are stepped or reset, so splitting the batch into
//! contiguous shards ([`sharded::ShardedEnv`], the `pmap` analog) is
//! bit-identical to the single-threaded engine for any shard count.

pub mod pipeline;
pub mod sharded;

pub use pipeline::PipelinedEnv;
pub use sharded::ShardedEnv;

use std::sync::Arc;

use crate::core::actions::Action;
use crate::core::mission::MISSION_DIM;
use crate::core::state::{cellcode, BatchedState};
use crate::core::timestep::{BatchedTimestep, StepType};
use crate::envs::EnvConfig;
use crate::rng::Key;
use crate::systems::intervention::intervene;
use crate::systems::observations::{rgb_incremental, ObsKind, ObsPath};
use crate::systems::sprites::SpriteSheet;
use crate::systems::transition::transition;

/// Grid-observation storage for a batch (dtype depends on the obs function).
#[derive(Clone, Debug)]
pub enum ObsData {
    I32(Vec<i32>),
    U8(Vec<u8>),
}

/// Observation batch: the grid encoding (`data`, `[B × stride]`) plus the
/// fixed-width goal-conditioning channel (`mission`,
/// `[B ×`[`MISSION_DIM`]`]` i32 one-hots — all-zero for mission-free
/// families). Every engine ([`BatchedEnv`], [`ShardedEnv`],
/// [`PipelinedEnv`]) fills both on every reset/step, so the mission is part
/// of the observation contract, not a state peek.
#[derive(Clone, Debug)]
pub struct ObsBatch {
    pub data: ObsData,
    pub mission: Vec<i32>,
}

impl ObsBatch {
    /// Allocate a zeroed batch: `stride` grid elements per env (u8 for rgb
    /// kinds, i32 otherwise) plus the mission channel.
    pub fn alloc(rgb: bool, b: usize, stride: usize) -> ObsBatch {
        ObsBatch {
            data: if rgb {
                ObsData::U8(vec![0; b * stride])
            } else {
                ObsData::I32(vec![0; b * stride])
            },
            mission: vec![0; b * MISSION_DIM],
        }
    }

    /// Per-env flat grid length (the mission channel is separate).
    pub fn stride(&self, b: usize) -> usize {
        match &self.data {
            ObsData::I32(v) => v.len() / b,
            ObsData::U8(v) => v.len() / b,
        }
    }

    /// i32 grid view of env `i` (panics on rgb batches).
    pub fn env_i32(&self, b: usize, i: usize) -> &[i32] {
        match &self.data {
            ObsData::I32(v) => {
                let s = v.len() / b;
                &v[i * s..(i + 1) * s]
            }
            ObsData::U8(_) => panic!("rgb observation accessed as i32"),
        }
    }

    /// u8 grid view of env `i` (panics on symbolic batches).
    pub fn env_u8(&self, b: usize, i: usize) -> &[u8] {
        match &self.data {
            ObsData::U8(v) => {
                let s = v.len() / b;
                &v[i * s..(i + 1) * s]
            }
            ObsData::I32(_) => panic!("symbolic observation accessed as u8"),
        }
    }

    /// The whole grid batch as one contiguous `[B × stride]` i32 slice
    /// (panics on rgb batches). The batched trainers featurise this in one
    /// pass instead of `B` per-env slices.
    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            ObsData::I32(v) => v,
            ObsData::U8(_) => panic!("rgb observation accessed as i32"),
        }
    }

    /// Mission feature row of env `i`.
    pub fn mission_row(&self, b: usize, i: usize) -> &[i32] {
        let m = self.mission.len() / b;
        &self.mission[i * m..(i + 1) * m]
    }

    /// Copy env `i`'s full policy input — grid i32s followed by the mission
    /// features — into `out` (`stride + MISSION_DIM` long). The replay-based
    /// agents store exactly this row.
    pub fn copy_policy_row(&self, b: usize, i: usize, out: &mut [i32]) {
        let grid = self.env_i32(b, i);
        out[..grid.len()].copy_from_slice(grid);
        out[grid.len()..].copy_from_slice(self.mission_row(b, i));
    }

    /// Copy another batch's contents into this one (same shape/dtype); the
    /// pipelined engine publishes the back buffer with this.
    pub fn copy_from(&mut self, src: &ObsBatch) {
        match (&mut self.data, &src.data) {
            (ObsData::I32(dst), ObsData::I32(src)) => dst.copy_from_slice(src),
            (ObsData::U8(dst), ObsData::U8(src)) => dst.copy_from_slice(src),
            _ => unreachable!("observation dtype diverged between engines"),
        }
        self.mission.copy_from_slice(&src.mission);
    }
}

/// `B` parallel environments of one configuration, stepped in lockstep.
pub struct BatchedEnv {
    pub cfg: EnvConfig,
    pub b: usize,
    pub state: BatchedState,
    pub timestep: BatchedTimestep,
    pub obs: ObsBatch,
    sprites: Option<Arc<SpriteSheet>>,
    /// Which observation implementation runs (overlay by default; the scan
    /// oracle is selectable for parity tests and the obs_throughput bench).
    obs_path: ObsPath,
    /// Dirty-tile cache for full-grid rgb: per env, the render code each
    /// tile of the obs buffer currently shows (`b·h·w`; empty otherwise).
    /// `cellcode::INVALID` marks a tile as needing a blit.
    rgb_prev: Vec<u32>,
    key: Key,
    /// Global index of local env 0 (non-zero only inside a [`ShardedEnv`]).
    index_offset: usize,
    /// Per-env episode counter: episode key = key ⊕ global index ⊕ count.
    reset_counts: Vec<u64>,
}

impl BatchedEnv {
    /// Allocate and reset `b` environments.
    pub fn new(cfg: EnvConfig, b: usize, key: Key) -> Self {
        BatchedEnv::with_offset(cfg, b, key, 0)
    }

    /// Allocate `b` environments whose *global* indices start at
    /// `index_offset`. This is the constructor [`ShardedEnv`] uses: a shard
    /// covering envs `[lo, hi)` of a batch derives exactly the RNG streams
    /// the equivalent single `BatchedEnv` would, because episode keys are a
    /// pure function of `(key, index_offset + i, reset_counts[i])` — never
    /// of the worker or shard that happens to step the env.
    pub fn with_offset(cfg: EnvConfig, b: usize, key: Key, index_offset: usize) -> Self {
        let state = BatchedState::new(b, cfg.h, cfg.w, cfg.caps);
        let obs_len = cfg.obs.len(cfg.h, cfg.w);
        let obs = ObsBatch::alloc(cfg.obs.kind.is_rgb(), b, obs_len);
        // One process-wide sprite sheet: rgb engines (and every shard of a
        // ShardedEnv) share the rendered tiles instead of rebuilding them.
        let sprites = if cfg.obs.kind.is_rgb() { Some(SpriteSheet::shared()) } else { None };
        let rgb_prev = if cfg.obs.kind == ObsKind::Rgb {
            vec![cellcode::INVALID; b * cfg.h * cfg.w]
        } else {
            Vec::new()
        };
        let mut env = BatchedEnv {
            cfg,
            b,
            state,
            timestep: BatchedTimestep::first(b),
            obs,
            sprites,
            obs_path: ObsPath::Overlay,
            rgb_prev,
            key,
            index_offset,
            reset_counts: vec![0; b],
        };
        env.reset_all();
        env
    }

    /// Select the observation implementation (parity tests and the
    /// `obs_throughput` bench switch to the scan oracle here). Invalidates
    /// the rgb dirty-tile cache so the next frame is a full render.
    pub fn set_obs_path(&mut self, path: ObsPath) {
        self.obs_path = path;
        self.rgb_prev.fill(cellcode::INVALID);
        for i in 0..self.b {
            self.write_obs(i);
        }
    }

    /// Number of discrete actions.
    pub fn num_actions(&self) -> usize {
        Action::N
    }

    /// Reset env `i`'s state slot with a fresh episode key. A layout
    /// generator that cannot place an entity is retried with successor
    /// episode keys — deterministic (and therefore shard-invariant),
    /// because failure is a pure function of the key, so every engine
    /// covering this env skips exactly the same keys. The retry loop (and
    /// its env-id + root-key panic on exhaustion) is shared with the
    /// baseline engine: [`crate::envs::retry_episode_keys`].
    fn reset_slot_fresh(&mut self, i: usize) {
        let BatchedEnv { cfg, state, reset_counts, key, index_offset, .. } = self;
        let (key, offset) = (*key, *index_offset);
        crate::envs::retry_episode_keys(&cfg.id, key, |_| {
            reset_counts[i] += 1;
            let ep_key = key.fold_in((offset + i) as u64).fold_in(reset_counts[i]);
            cfg.reset_slot(&mut state.slot_mut(i), ep_key)
        });
    }

    /// Reset every environment (fresh episode keys) and write observations.
    pub fn reset_all(&mut self) {
        for i in 0..self.b {
            self.reset_slot_fresh(i);
        }
        self.timestep = BatchedTimestep::first(self.b);
        for i in 0..self.b {
            self.write_obs(i);
        }
    }

    /// Reset just env `i` (autoreset path).
    fn reset_one(&mut self, i: usize) {
        self.reset_slot_fresh(i);
        self.timestep.t[i] = 0;
        self.timestep.action[i] = -1;
        self.timestep.reward[i] = 0.0;
        self.timestep.discount[i] = 1.0;
        self.timestep.step_type[i] = StepType::First;
        self.timestep.episodic_return[i] = 0.0;
    }

    /// Step all environments with `actions` (one per env, values 0..7).
    /// Environments whose previous timestep was terminal autoreset instead.
    pub fn step(&mut self, actions: &[u8]) {
        debug_assert_eq!(actions.len(), self.b);
        for i in 0..self.b {
            if self.timestep.step_type[i].is_last() {
                self.reset_one(i);
                self.write_obs(i);
                continue;
            }
            self.step_one(i, Action::from_u8(actions[i]));
            self.write_obs(i);
        }
    }

    /// Core per-env step: intervention → transition → reward/termination →
    /// timeout truncation.
    fn step_one(&mut self, i: usize, action: Action) {
        let stochastic = self.cfg.stochastic_balls;
        let max_steps = self.cfg.max_steps;
        {
            let mut slot = self.state.slot_mut(i);
            intervene(&mut slot, action);
            transition(&mut slot, stochastic);
        }
        let slot = self.state.slot(i);
        let reward = self.cfg.reward.eval(&slot, action, max_steps);
        let terminated = self.cfg.termination.eval(&slot);
        let truncated = !terminated && slot.t >= max_steps;

        let ts = &mut self.timestep;
        ts.t[i] = slot.t;
        ts.action[i] = action as i32;
        ts.reward[i] = reward;
        ts.episodic_return[i] += reward;
        ts.discount[i] = if terminated { 0.0 } else { 1.0 };
        ts.step_type[i] = if terminated {
            StepType::Terminated
        } else if truncated {
            StepType::Truncated
        } else {
            StepType::Mid
        };
    }

    fn write_obs(&mut self, i: usize) {
        let slot = self.state.slot(i);
        let stride = self.cfg.obs.len(self.cfg.h, self.cfg.w);
        match &mut self.obs.data {
            ObsData::I32(v) => {
                let out = &mut v[i * stride..(i + 1) * stride];
                self.cfg.obs.write_i32_path(self.obs_path, &slot, out);
            }
            ObsData::U8(v) => {
                let sheet = self.sprites.as_ref().expect("sprite sheet for rgb obs");
                let out = &mut v[i * stride..(i + 1) * stride];
                if self.cfg.obs.kind == ObsKind::Rgb && self.obs_path == ObsPath::Overlay {
                    // Dirty-tile path: the obs buffer persists across steps,
                    // so only tiles whose render code changed are re-blitted
                    // (a fresh env starts all-INVALID → one full render).
                    let hw = self.cfg.h * self.cfg.w;
                    let prev = &mut self.rgb_prev[i * hw..(i + 1) * hw];
                    rgb_incremental(&slot, sheet, prev, out);
                } else {
                    self.cfg.obs.write_u8_path(self.obs_path, &slot, sheet, out);
                }
            }
        }
        // The goal-conditioning side channel rides along with every kind.
        let mrow = &mut self.obs.mission[i * MISSION_DIM..(i + 1) * MISSION_DIM];
        self.cfg.obs.write_mission_path(self.obs_path, &slot, mrow);
    }

    /// Convenience: run `steps` lockstep iterations with uniformly random
    /// actions. Returns total env-steps executed (`b × steps`). Used by the
    /// throughput benches (paper Figs. 4/5/8).
    pub fn rollout_random(&mut self, steps: usize, seed: u64) -> usize {
        let mut rng = crate::rng::Rng::new(seed);
        let mut actions = vec![0u8; self.b];
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = rng.below(Action::N as u32) as u8;
            }
            self.step(&actions);
        }
        steps * self.b
    }
}

/// Uniform interface over the batched steppers — [`BatchedEnv`] (the `vmap`
/// analog) and [`ShardedEnv`] (the `pmap` analog) — so training and
/// benchmark code is agnostic to the execution backend. Object safe: the
/// multi-agent coordinator holds `Box<dyn BatchStepper>` per agent.
pub trait BatchStepper {
    /// Number of parallel environments.
    fn batch_size(&self) -> usize;

    /// Step every environment in lockstep; terminal slots autoreset.
    fn step(&mut self, actions: &[u8]);

    /// Timestep metadata written by the most recent step/reset.
    fn timestep(&self) -> &BatchedTimestep;

    /// Observation buffers written by the most recent step/reset.
    fn obs(&self) -> &ObsBatch;

    /// Reset every environment with fresh episode keys.
    fn reset_all(&mut self);

    /// Number of discrete actions.
    fn num_actions(&self) -> usize {
        Action::N
    }
}

impl BatchStepper for BatchedEnv {
    fn batch_size(&self) -> usize {
        self.b
    }

    fn step(&mut self, actions: &[u8]) {
        BatchedEnv::step(self, actions);
    }

    fn timestep(&self) -> &BatchedTimestep {
        &self.timestep
    }

    fn obs(&self) -> &ObsBatch {
        &self.obs
    }

    fn reset_all(&mut self) {
        BatchedEnv::reset_all(self);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::systems::observations::ObsKind;

    fn env(id: &str, b: usize) -> BatchedEnv {
        BatchedEnv::new(make(id).unwrap(), b, Key::new(0))
    }

    #[test]
    fn reset_produces_first_timesteps_and_obs() {
        let e = env("Navix-Empty-8x8-v0", 4);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::First));
        assert_eq!(e.obs.stride(4), 7 * 7 * 3);
        // fixed start → all four observations identical
        let o0: Vec<i32> = e.obs.env_i32(4, 0).to_vec();
        for i in 1..4 {
            assert_eq!(e.obs.env_i32(4, i), &o0[..]);
        }
    }

    #[test]
    fn step_advances_time_and_tracks_actions() {
        let mut e = env("Navix-Empty-5x5-v0", 2);
        e.step(&[Action::Forward as u8, Action::Left as u8]);
        assert_eq!(e.timestep.t, vec![1, 1]);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::Mid));
        assert_eq!(e.timestep.action, vec![2, 0]);
    }

    #[test]
    fn scripted_goal_reach_terminates_then_autoresets() {
        // Empty-5x5: agent (1,1) E, goal (3,3): F, F, Right, F, F.
        let mut e = env("Navix-Empty-5x5-v0", 1);
        let script =
            [Action::Forward, Action::Forward, Action::Right, Action::Forward, Action::Forward];
        for &a in &script {
            e.step(&[a as u8]);
        }
        assert_eq!(e.timestep.step_type[0], StepType::Terminated);
        assert_eq!(e.timestep.reward[0], 1.0);
        assert_eq!(e.timestep.discount[0], 0.0);
        assert_eq!(e.timestep.episodic_return[0], 1.0);
        // next step autoresets regardless of the action
        e.step(&[Action::Forward as u8]);
        assert_eq!(e.timestep.step_type[0], StepType::First);
        assert_eq!(e.timestep.t[0], 0);
        assert_eq!(e.timestep.action[0], -1);
        assert_eq!(e.timestep.episodic_return[0], 0.0);
        let s = e.state.slot(0);
        assert_eq!(s.player(), crate::core::grid::Pos::new(1, 1), "fresh episode");
    }

    #[test]
    fn terminal_event_at_exact_timeout_is_termination_not_truncation() {
        // MiniGrid semantics: `terminated` is evaluated before the timeout,
        // so an episode whose terminal event fires exactly at t == T must
        // report termination (γ = 0), not truncation. Empty-5x5's scripted
        // solution takes exactly 5 steps; pin T to 5.
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.max_steps = 5;
        let mut e = BatchedEnv::new(cfg, 1, Key::new(0));
        let script =
            [Action::Forward, Action::Forward, Action::Right, Action::Forward, Action::Forward];
        for &a in &script {
            e.step(&[a as u8]);
        }
        assert_eq!(e.timestep.t[0], 5, "the goal step is exactly the timeout step");
        assert_eq!(
            e.timestep.step_type[0],
            StepType::Terminated,
            "terminal at t == T must be termination"
        );
        assert_eq!(e.timestep.discount[0], 0.0, "termination sets γ = 0");
        assert_eq!(e.timestep.reward[0], 1.0);
    }

    #[test]
    fn truncation_at_max_steps_keeps_discount() {
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.max_steps = 3;
        let mut e = BatchedEnv::new(cfg, 1, Key::new(1));
        for _ in 0..3 {
            e.step(&[Action::Left as u8]); // spin in place, never terminal
        }
        assert_eq!(e.timestep.step_type[0], StepType::Truncated);
        assert_eq!(e.timestep.discount[0], 1.0, "truncation preserves γ");
    }

    #[test]
    fn batch_envs_evolve_independently() {
        let mut e = env("Navix-Empty-Random-6x6", 8);
        let mut acts = vec![Action::Forward as u8; 8];
        acts[3] = Action::Left as u8;
        e.step(&acts);
        let mut distinct = std::collections::HashSet::new();
        for i in 0..8 {
            let s = e.state.slot(i);
            distinct.insert((s.player_pos, s.player_dir));
        }
        assert!(distinct.len() > 2, "batch collapsed to identical states");
    }

    #[test]
    fn rollout_random_executes_requested_steps() {
        let mut e = env("Navix-Empty-8x8-v0", 16);
        let n = e.rollout_random(100, 42);
        assert_eq!(n, 1600);
    }

    #[test]
    fn rgb_batch_allocates_u8() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap().with_observation(ObsKind::Rgb);
        let e = BatchedEnv::new(cfg, 2, Key::new(0));
        match &e.obs.data {
            ObsData::U8(v) => assert_eq!(v.len(), 2 * 160 * 160 * 3),
            _ => panic!("rgb must be u8"),
        }
        assert_eq!(e.obs.mission.len(), 2 * MISSION_DIM, "mission channel rides along");
    }

    #[test]
    fn mission_channel_tracks_state_and_clears_for_goal_envs() {
        use crate::core::mission::Mission;
        // Mission env: features present and equal to the state's mission.
        let e = env("Navix-GoToDoor-5x5-v0", 3);
        for i in 0..3 {
            let mut expect = [0i32; MISSION_DIM];
            Mission::from_raw(e.state.mission[i]).write_features(&mut expect);
            assert_eq!(e.obs.mission_row(3, i), &expect[..], "env {i}");
            assert_eq!(e.obs.mission_row(3, i)[0], 1, "env {i}: mission must be present");
        }
        // Goal env: the channel exists but stays all-zero.
        let e = env("Navix-Empty-5x5-v0", 2);
        assert!(e.obs.mission.iter().all(|&x| x == 0));
        // copy_policy_row concatenates grid + mission.
        let e = env("Navix-Fetch-5x5-N2-v0", 2);
        let stride = e.obs.stride(2);
        let mut row = vec![0i32; stride + MISSION_DIM];
        e.obs.copy_policy_row(2, 1, &mut row);
        assert_eq!(&row[..stride], e.obs.env_i32(2, 1));
        assert_eq!(&row[stride..], e.obs.mission_row(2, 1));
    }

    #[test]
    fn rgb_dirty_tiles_match_from_scratch_render() {
        // The incremental rgb buffer must be indistinguishable from a full
        // render at every step, including across autoresets.
        let cfg = make("Navix-Empty-5x5-v0").unwrap().with_observation(ObsKind::Rgb);
        let mut e = BatchedEnv::new(cfg, 2, Key::new(0));
        let sheet = SpriteSheet::shared();
        let mut scratch = vec![0u8; e.obs.stride(2)];
        for i in 0..2 {
            crate::systems::observations::scan::rgb(&e.state.slot(i), &sheet, &mut scratch);
            assert_eq!(e.obs.env_u8(2, i), &scratch[..], "reset frame env {i}");
        }
        for step in 0..30 {
            let a = [(step % 7) as u8, ((step + 2) % 7) as u8];
            e.step(&a);
            for i in 0..2 {
                crate::systems::observations::scan::rgb(&e.state.slot(i), &sheet, &mut scratch);
                assert_eq!(e.obs.env_u8(2, i), &scratch[..], "step {step} env {i}");
            }
        }
    }

    #[test]
    fn every_registered_env_steps_under_random_actions() {
        for id in crate::envs::registry::fig3_envs() {
            let mut e = env(id, 4);
            e.rollout_random(50, 7);
        }
    }

    #[test]
    fn offset_slices_reproduce_global_streams() {
        // The RNG contract behind ShardedEnv: a BatchedEnv covering global
        // envs [3, 6) must reproduce envs 3..6 of a 6-env batch exactly —
        // layouts, steps and autoresets included.
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut full = BatchedEnv::new(cfg.clone(), 6, Key::new(9));
        let mut part = BatchedEnv::with_offset(cfg, 3, Key::new(9), 3);
        assert_eq!(&full.state.player_pos[3..6], &part.state.player_pos[..]);
        let mut rng = crate::rng::Rng::new(4);
        for _ in 0..120 {
            let actions: Vec<u8> = (0..6).map(|_| rng.below(7) as u8).collect();
            full.step(&actions);
            part.step(&actions[3..6]);
            assert_eq!(&full.state.player_pos[3..6], &part.state.player_pos[..]);
            assert_eq!(&full.timestep.reward[3..6], &part.timestep.reward[..]);
            for i in 0..3 {
                assert_eq!(full.obs.env_i32(6, 3 + i), part.obs.env_i32(3, i));
            }
        }
    }

    #[test]
    fn episodic_return_accumulates_costs() {
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.reward = crate::systems::rewards::RewardSpec::new(vec![
            crate::systems::rewards::RewardFn::TimeCost(0.1),
        ]);
        let mut e = BatchedEnv::new(cfg, 1, Key::new(0));
        e.step(&[Action::Left as u8]);
        e.step(&[Action::Left as u8]);
        assert!((e.timestep.episodic_return[0] + 0.2).abs() < 1e-6);
    }
}
