//! The batched environment engine — the Rust analog of NAVIX's
//! `jax.vmap(env.step)` (paper §3.2.2 and §4.2).
//!
//! `BatchedEnv` owns a struct-of-arrays [`BatchedState`] for `B` parallel
//! environments plus reusable observation/reward/discount buffers, and steps
//! all of them with zero per-step allocation. Autoreset follows the paper's
//! timestep design: if an environment's previous timestep was terminal, the
//! step resets it instead (returning a `First` timestep), so agent code
//! stays branch-free.
//!
//! ## The agent axis
//!
//! Each slot holds `A = cfg.n_agents` agents (1 for every classic family).
//! The engine contract is **agent-row major**: actions come in as a flat
//! `[B × A]` matrix (slot `i`'s agents at `i·A ‥ (i+1)·A`), and every
//! per-row output — timestep metadata, observations, mission features,
//! trajectory slices — has one row per agent at the same index, so the
//! policy batch is simply `B·A` rows ([`BatchStepper::policy_rows`]).
//! Within a slot, agents act in ascending index order; walking into
//! another agent latches the contact event pair instead of moving, which
//! makes contested-cell resolution deterministic. One agent's terminal
//! event ends the episode for the whole slot (the grid resets as a unit).
//! With `A = 1` every shape and stream collapses to the classic layout
//! bit for bit.
//!
//! The batching win this engine reproduces is architectural, not SIMD magic:
//! one dispatch amortised over `B` contiguous state slots vs. one Python
//! object graph per environment in the baseline ([`crate::baseline`]).
//!
//! Three execution layers compose on top of the same state: [`BatchedEnv`]
//! (single-threaded `vmap` analog), [`sharded::ShardedEnv`] (multi-core
//! `pmap` analog) and [`pipeline::PipelinedEnv`] (double-buffered rollout
//! pipeline that overlaps stepping with learner compute) — all bitwise
//! equivalent for a fixed seed.
//!
//! The observation/step hot path is **scan-free**: spatial queries and the
//! per-cell encoding read the state's packed cell-code overlay grid (one
//! `u32` per cell, kept write-through consistent — see
//! [`crate::core::state`]), and full-grid rgb uses per-env **dirty-tile
//! tracking**: the image is rendered once, then only tiles whose code
//! changed are re-blitted each step.
//!
//! ## RNG contract (what makes sharding deterministic)
//!
//! Every episode key is a pure function of `(root key, global env index,
//! per-env episode count)` — `key.fold_in(index).fold_in(count)` — and the
//! in-episode stream lives inside the env's own state slot. Nothing depends
//! on the order envs are stepped or reset, so splitting the batch into
//! contiguous shards ([`sharded::ShardedEnv`], the `pmap` analog) is
//! bit-identical to the single-threaded engine for any shard count.
//!
//! ## Scan mode (fused K-step rollouts)
//!
//! [`BatchStepper::step_n`] is the repo's analog of NAVIX wrapping the
//! rollout loop in `jax.lax.scan`: one call executes `K` lockstep steps
//! into a time-major [`TrajectorySlice`], amortising trait-object dispatch,
//! observation-buffer traffic and (on [`ShardedEnv`]) the epoch/condvar
//! round-trip over the whole window. The same counted-key RNG contract
//! above is what makes fusion bitwise-trivial: every per-step key is
//! derived from `(root key, index, count)` up front rather than threaded
//! sequentially through the loop, so `step_n(K)` is bit-identical to `K`
//! calls of `step` (pinned by `tests/test_scan_parity.rs`). With a
//! [`ActionPlan::Fixed`] plan and [`ObsCapture::Final`], intermediate
//! observations are never materialised — safe even for dirty-tile rgb,
//! whose per-tile cache only advances on blit, so the final frame renders
//! exactly the tiles that changed since the last materialised one.

pub mod fault;
pub mod pipeline;
pub mod sharded;

pub use fault::{EngineFault, FaultPolicy, FaultStats};
pub use pipeline::PipelinedEnv;
pub use sharded::ShardedEnv;

use std::sync::Arc;

use crate::bench_harness::chaos::{ChaosInjector, ChaosKind};
use crate::core::actions::Action;
use crate::core::mission::MISSION_TOKENS;
use crate::core::snapshot::{EngineCheckpoint, SlotCheckpoint, SlotSnapshot};
use crate::core::state::{cellcode, BatchedState};
use crate::core::timestep::{BatchedTimestep, StepType};
use crate::envs::EnvConfig;
use crate::rng::Key;
use fault::{catch_fault, payload_to_string, Supervisor};
use crate::systems::intervention::intervene;
use crate::systems::observations::{rgb_incremental, ObsKind, ObsPath, ObsRoute};
use crate::systems::sprites::SpriteSheet;
use crate::systems::transition::transition;

/// Grid-observation storage for a batch (dtype depends on the obs function).
#[derive(Clone, Debug)]
pub enum ObsData {
    I32(Vec<i32>),
    U8(Vec<u8>),
}

/// Observation batch: the grid encoding (`data`, `[rows × stride]`) plus
/// the fixed-width goal-conditioning channel (`mission`,
/// `[rows ×`[`MISSION_TOKENS`]`]` i32 grammar tokens — all-zero for mission-free
/// families). `rows` is the engine's `B·A` agent-row count (`B` when
/// `A = 1`); every accessor's `b` argument is that row count. Every engine
/// ([`BatchedEnv`], [`ShardedEnv`], [`PipelinedEnv`]) fills both on every
/// reset/step, so the mission is part of the observation contract, not a
/// state peek.
#[derive(Clone, Debug)]
pub struct ObsBatch {
    pub data: ObsData,
    pub mission: Vec<i32>,
}

impl ObsBatch {
    /// Allocate a zeroed batch: `stride` grid elements per env (u8 for rgb
    /// kinds, i32 otherwise) plus the mission channel.
    pub fn alloc(rgb: bool, b: usize, stride: usize) -> ObsBatch {
        ObsBatch {
            data: if rgb {
                ObsData::U8(vec![0; b * stride])
            } else {
                ObsData::I32(vec![0; b * stride])
            },
            mission: vec![0; b * MISSION_TOKENS],
        }
    }

    /// Per-env flat grid length (the mission channel is separate).
    pub fn stride(&self, b: usize) -> usize {
        match &self.data {
            ObsData::I32(v) => v.len() / b,
            ObsData::U8(v) => v.len() / b,
        }
    }

    /// i32 grid view of env `i` (panics on rgb batches).
    pub fn env_i32(&self, b: usize, i: usize) -> &[i32] {
        match &self.data {
            ObsData::I32(v) => {
                let s = v.len() / b;
                &v[i * s..(i + 1) * s]
            }
            ObsData::U8(_) => panic!("rgb observation accessed as i32"),
        }
    }

    /// u8 grid view of env `i` (panics on symbolic batches).
    pub fn env_u8(&self, b: usize, i: usize) -> &[u8] {
        match &self.data {
            ObsData::U8(v) => {
                let s = v.len() / b;
                &v[i * s..(i + 1) * s]
            }
            ObsData::I32(_) => panic!("symbolic observation accessed as u8"),
        }
    }

    /// The whole grid batch as one contiguous `[B × stride]` i32 slice
    /// (panics on rgb batches). The batched trainers featurise this in one
    /// pass instead of `B` per-env slices.
    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            ObsData::I32(v) => v,
            ObsData::U8(_) => panic!("rgb observation accessed as i32"),
        }
    }

    /// Mission feature row of env `i`.
    pub fn mission_row(&self, b: usize, i: usize) -> &[i32] {
        let m = self.mission.len() / b;
        &self.mission[i * m..(i + 1) * m]
    }

    /// Copy env `i`'s full policy input — grid i32s followed by the mission
    /// features — into `out` (`stride + MISSION_TOKENS` long). The replay-based
    /// agents store exactly this row.
    pub fn copy_policy_row(&self, b: usize, i: usize, out: &mut [i32]) {
        let grid = self.env_i32(b, i);
        out[..grid.len()].copy_from_slice(grid);
        out[grid.len()..].copy_from_slice(self.mission_row(b, i));
    }

    /// Copy another batch's contents into this one (same shape/dtype); the
    /// pipelined engine publishes the back buffer with this.
    pub fn copy_from(&mut self, src: &ObsBatch) {
        match (&mut self.data, &src.data) {
            (ObsData::I32(dst), ObsData::I32(src)) => dst.copy_from_slice(src),
            (ObsData::U8(dst), ObsData::U8(src)) => dst.copy_from_slice(src),
            _ => unreachable!("observation dtype diverged between engines"),
        }
        self.mission.copy_from_slice(&src.mission);
    }
}

/// Which per-step observations a fused [`BatchStepper::step_n`] window
/// materialises into its [`TrajectorySlice`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObsCapture {
    /// Copy every step's post-step observation batch into the slice
    /// (`[K × B × stride]` — what the parity tests compare).
    All,
    /// Skip per-step copies: only the engine's own `obs()` buffers hold the
    /// final post-window frame. With an [`ActionPlan::Fixed`] plan the
    /// intermediate observations are never even written — the scan-mode
    /// win the `fig5_sharded` bench's `*-scan` rows measure.
    #[default]
    Final,
}

/// Supplies actions inside a fused [`BatchStepper::step_n`] window — the
/// on-policy case, where step `t`'s actions depend on step `t`'s
/// observations and cannot be precomputed into an [`ActionPlan::Fixed`]
/// matrix.
pub trait ActionProvider {
    /// Fill `out` (one action per agent-row, `[B × A]`) with step `t`'s
    /// actions given the pre-step observation batch and timestep metadata.
    fn actions(&mut self, t: usize, obs: &ObsBatch, ts: &BatchedTimestep, out: &mut [u8]);

    /// Work to run while step `t` is in flight. [`PipelinedEnv`] calls this
    /// between submit and sync so it overlaps the environment step; the
    /// synchronous engines call it immediately before stepping. Must read
    /// only step `t`'s snapshot (captured in [`ActionProvider::actions`]),
    /// never the engine's post-step state.
    fn overlap(&mut self, _t: usize) {}
}

/// The action source for one fused [`BatchStepper::step_n`] window.
pub enum ActionPlan<'a> {
    /// Precomputed time-major `[K × B·A]` action matrix (row `t` holds step
    /// `t`'s actions, one per agent-row). Enables the fully fused paths: one epoch per window
    /// on [`ShardedEnv`], one swap-buffer round-trip on [`PipelinedEnv`],
    /// and skipped intermediate observations under [`ObsCapture::Final`].
    Fixed(&'a [u8]),
    /// Actions produced per step by a policy callback (the PPO trainers).
    /// The engines still fuse the bookkeeping, but each step's
    /// observations must be materialised for the callback.
    Provider(&'a mut dyn ActionProvider),
}

/// Time-major `[K × rows]` trajectory buffer filled by one
/// [`BatchStepper::step_n`] window (`rows` = the engine's `B·A` agent-row
/// count): the post-step timestep metadata of
/// every step, plus (under [`ObsCapture::All`]) every step's observation
/// batch. Field layouts match [`crate::agents::ppo::Rollout`]'s time-major
/// tensors, so trainers copy whole windows with one `memcpy` per field.
/// Buffers grow on demand and are reused across windows.
#[derive(Clone, Debug)]
pub struct TrajectorySlice {
    /// Steps recorded by the last window.
    pub k: usize,
    /// Agent-row count of the recording engine (`B·A`; `B` when `A = 1`).
    pub b: usize,
    /// Which observations the engine materialises into `obs`/`mission`.
    pub capture: ObsCapture,
    /// `[K × B]` steps-since-reset.
    pub t: Vec<u32>,
    /// `[K × B]` actions taken (−1 on autoreset rows).
    pub action: Vec<i32>,
    /// `[K × B]` rewards.
    pub reward: Vec<f32>,
    /// `[K × B]` discounts (0 on termination).
    pub discount: Vec<f32>,
    /// `[K × B]` step classifications (terminations/truncations).
    pub step_type: Vec<StepType>,
    /// `[K × B]` accumulated episodic returns.
    pub episodic_return: Vec<f32>,
    /// `[K × B × stride]` grid observations ([`ObsCapture::All`] only).
    pub obs: ObsData,
    /// `[K × B ×`[`MISSION_TOKENS`]`]` mission rows ([`ObsCapture::All`] only).
    pub mission: Vec<i32>,
    /// Per-env flat grid length of `obs`.
    pub obs_stride: usize,
}

impl Default for TrajectorySlice {
    fn default() -> Self {
        TrajectorySlice::new(ObsCapture::Final)
    }
}

impl TrajectorySlice {
    /// An empty slice; engines shape it on first use via
    /// [`TrajectorySlice::ensure_like`].
    pub fn new(capture: ObsCapture) -> Self {
        TrajectorySlice {
            k: 0,
            b: 0,
            capture,
            t: Vec::new(),
            action: Vec::new(),
            reward: Vec::new(),
            discount: Vec::new(),
            step_type: Vec::new(),
            episodic_return: Vec::new(),
            obs: ObsData::I32(Vec::new()),
            mission: Vec::new(),
            obs_stride: 0,
        }
    }

    /// Resize every buffer for a `K × B` window whose observations have
    /// `obs`'s dtype and stride. Engines call this at the top of `step_n`;
    /// reallocation only happens when the window grows or the dtype
    /// changes.
    pub fn ensure_like(&mut self, k: usize, b: usize, obs: &ObsBatch) {
        self.k = k;
        self.b = b;
        let n = k * b;
        self.t.resize(n, 0);
        self.action.resize(n, -1);
        self.reward.resize(n, 0.0);
        self.discount.resize(n, 1.0);
        self.step_type.resize(n, StepType::First);
        self.episodic_return.resize(n, 0.0);
        self.obs_stride = obs.stride(b);
        if self.capture == ObsCapture::All {
            let len = n * self.obs_stride;
            match (&mut self.obs, &obs.data) {
                (ObsData::I32(dst), ObsData::I32(_)) => dst.resize(len, 0),
                (ObsData::U8(dst), ObsData::U8(_)) => dst.resize(len, 0),
                (slot, ObsData::I32(_)) => *slot = ObsData::I32(vec![0; len]),
                (slot, ObsData::U8(_)) => *slot = ObsData::U8(vec![0; len]),
            }
            self.mission.resize(n * MISSION_TOKENS, 0);
        }
    }

    /// Record step `t`'s post-step timestep metadata (row `t` of every
    /// metadata field, one `memcpy` each).
    pub fn record_row(&mut self, t: usize, ts: &BatchedTimestep) {
        let (lo, hi) = (t * self.b, (t + 1) * self.b);
        self.t[lo..hi].copy_from_slice(&ts.t);
        self.action[lo..hi].copy_from_slice(&ts.action);
        self.reward[lo..hi].copy_from_slice(&ts.reward);
        self.discount[lo..hi].copy_from_slice(&ts.discount);
        self.step_type[lo..hi].copy_from_slice(&ts.step_type);
        self.episodic_return[lo..hi].copy_from_slice(&ts.episodic_return);
    }

    /// Record step `t`'s post-step observation batch ([`ObsCapture::All`]).
    pub fn capture_obs_row(&mut self, t: usize, obs: &ObsBatch) {
        debug_assert_eq!(self.capture, ObsCapture::All);
        let (lo, hi) = (t * self.b * self.obs_stride, (t + 1) * self.b * self.obs_stride);
        match (&mut self.obs, &obs.data) {
            (ObsData::I32(dst), ObsData::I32(src)) => dst[lo..hi].copy_from_slice(src),
            (ObsData::U8(dst), ObsData::U8(src)) => dst[lo..hi].copy_from_slice(src),
            _ => unreachable!("trajectory obs dtype diverged from the engine"),
        }
        self.mission[t * self.b * MISSION_TOKENS..(t + 1) * self.b * MISSION_TOKENS]
            .copy_from_slice(&obs.mission);
    }

    /// Step `t`'s reward row.
    pub fn reward_row(&self, t: usize) -> &[f32] {
        &self.reward[t * self.b..(t + 1) * self.b]
    }

    /// Step `t`'s discount row.
    pub fn discount_row(&self, t: usize) -> &[f32] {
        &self.discount[t * self.b..(t + 1) * self.b]
    }

    /// Step `t`'s step-type row.
    pub fn step_type_row(&self, t: usize) -> &[StepType] {
        &self.step_type[t * self.b..(t + 1) * self.b]
    }

    /// i32 grid view of env `i` at step `t` (capture mode `All`).
    pub fn obs_i32(&self, t: usize, i: usize) -> &[i32] {
        match &self.obs {
            ObsData::I32(v) => {
                let base = (t * self.b + i) * self.obs_stride;
                &v[base..base + self.obs_stride]
            }
            ObsData::U8(_) => panic!("rgb trajectory observation accessed as i32"),
        }
    }

    /// u8 grid view of env `i` at step `t` (capture mode `All`).
    pub fn obs_u8(&self, t: usize, i: usize) -> &[u8] {
        match &self.obs {
            ObsData::U8(v) => {
                let base = (t * self.b + i) * self.obs_stride;
                &v[base..base + self.obs_stride]
            }
            ObsData::I32(_) => panic!("symbolic trajectory observation accessed as u8"),
        }
    }

    /// Mission feature row of env `i` at step `t` (capture mode `All`).
    pub fn mission_row(&self, t: usize, i: usize) -> &[i32] {
        let base = (t * self.b + i) * MISSION_TOKENS;
        &self.mission[base..base + MISSION_TOKENS]
    }
}

/// `B` parallel environments of one configuration, stepped in lockstep.
pub struct BatchedEnv {
    pub cfg: EnvConfig,
    pub b: usize,
    /// Agents per slot (`cfg.n_agents`); all per-row buffers hold `b·a`
    /// agent-rows.
    pub a: usize,
    pub state: BatchedState,
    pub timestep: BatchedTimestep,
    pub obs: ObsBatch,
    sprites: Option<Arc<SpriteSheet>>,
    /// Which observation route runs: implementation (overlay by default;
    /// the scan oracle is selectable for parity tests and the
    /// obs_throughput bench) plus, on the overlay path, the SIMD kernel —
    /// resolved once here and threaded through every writer.
    obs_route: ObsRoute,
    /// Dirty-tile cache for full-grid rgb: per agent-row, the render code
    /// each tile of the obs buffer currently shows (`b·a·h·w`; empty
    /// otherwise). `cellcode::INVALID` marks a tile as needing a blit.
    rgb_prev: Vec<u32>,
    key: Key,
    /// Global index of local env 0 (non-zero only inside a [`ShardedEnv`]).
    index_offset: usize,
    /// Per-env episode counter: episode key = key ⊕ global index ⊕ count.
    reset_counts: Vec<u64>,
    /// Engine steps taken since construction/restore (the chaos injector's
    /// clock, and the stamp the torn-slot repair ledger compares against).
    step_count: u64,
    /// Fault supervision, armed by [`BatchedEnv::supervise`]. `None` keeps
    /// the historic unguarded fast path.
    supervisor: Option<Supervisor>,
    /// Deterministic fault injector, armed by [`BatchedEnv::arm_chaos`] or
    /// the `NAVIX_CHAOS` environment variable.
    chaos: Option<ChaosInjector>,
}

impl BatchedEnv {
    /// Allocate and reset `b` environments.
    pub fn new(cfg: EnvConfig, b: usize, key: Key) -> Self {
        BatchedEnv::with_offset(cfg, b, key, 0)
    }

    /// Allocate `b` environments whose *global* indices start at
    /// `index_offset`. This is the constructor [`ShardedEnv`] uses: a shard
    /// covering envs `[lo, hi)` of a batch derives exactly the RNG streams
    /// the equivalent single `BatchedEnv` would, because episode keys are a
    /// pure function of `(key, index_offset + i, reset_counts[i])` — never
    /// of the worker or shard that happens to step the env.
    pub fn with_offset(cfg: EnvConfig, b: usize, key: Key, index_offset: usize) -> Self {
        let a = cfg.n_agents.max(1);
        let rows = b * a;
        let state = BatchedState::with_agents(b, cfg.h, cfg.w, cfg.caps, a);
        let obs_len = cfg.obs.len(cfg.h, cfg.w);
        let obs = ObsBatch::alloc(cfg.obs.kind.is_rgb(), rows, obs_len);
        // One process-wide sprite sheet: rgb engines (and every shard of a
        // ShardedEnv) share the rendered tiles instead of rebuilding them.
        let sprites = if cfg.obs.kind.is_rgb() { Some(SpriteSheet::shared()) } else { None };
        let rgb_prev = if cfg.obs.kind == ObsKind::Rgb {
            vec![cellcode::INVALID; rows * cfg.h * cfg.w]
        } else {
            Vec::new()
        };
        let mut env = BatchedEnv {
            cfg,
            b,
            a,
            state,
            timestep: BatchedTimestep::first(rows),
            obs,
            sprites,
            obs_route: ObsPath::Overlay.route(),
            rgb_prev,
            key,
            index_offset,
            reset_counts: vec![0; b],
            step_count: 0,
            supervisor: None,
            // Every constructor checks NAVIX_CHAOS, so shard/pipeline inner
            // engines inherit injection with zero plumbing (slots are
            // addressed globally via index_offset).
            chaos: ChaosInjector::from_env(),
        };
        env.reset_all();
        env
    }

    /// Select the observation implementation (parity tests and the
    /// `obs_throughput` bench switch to the scan oracle here); the SIMD
    /// kernel is resolved once via [`ObsPath::route`]. Invalidates the rgb
    /// dirty-tile cache so the next frame is a full render.
    pub fn set_obs_path(&mut self, path: ObsPath) {
        self.set_obs_route(path.route());
    }

    /// Force a fully-resolved observation route — the SIMD parity suite
    /// pins forced kernel paths through the whole engine here. Invalidates
    /// the rgb dirty-tile cache so the next frame is a full render.
    pub fn set_obs_route(&mut self, route: ObsRoute) {
        self.obs_route = route;
        self.rgb_prev.fill(cellcode::INVALID);
        for i in 0..self.b {
            self.write_obs(i);
        }
    }

    /// The resolved observation route this engine writes through.
    pub fn obs_route(&self) -> ObsRoute {
        self.obs_route
    }

    /// Number of discrete actions.
    pub fn num_actions(&self) -> usize {
        Action::N
    }

    /// Agent-row count `b·a`: the width of the action matrix and of every
    /// per-row output buffer.
    pub fn policy_rows(&self) -> usize {
        self.b * self.a
    }

    /// Reset env `i`'s state slot with a fresh episode key. A layout
    /// generator that cannot place an entity is retried with successor
    /// episode keys — deterministic (and therefore shard-invariant),
    /// because failure is a pure function of the key, so every engine
    /// covering this env skips exactly the same keys. The retry loop (and
    /// its env-id + root-key panic on exhaustion) is shared with the
    /// baseline engine: [`crate::envs::retry_episode_keys`].
    fn reset_slot_fresh(&mut self, i: usize) {
        let BatchedEnv { cfg, state, reset_counts, key, index_offset, .. } = self;
        let (key, offset) = (*key, *index_offset);
        crate::envs::retry_episode_keys(&cfg.id, key, |_| {
            reset_counts[i] += 1;
            let ep_key = key.fold_in((offset + i) as u64).fold_in(reset_counts[i]);
            cfg.reset_slot(&mut state.slot_mut(i), ep_key)
        });
    }

    /// Reset every environment (fresh episode keys) and write observations.
    pub fn reset_all(&mut self) {
        for i in 0..self.b {
            self.reset_slot_fresh(i);
        }
        self.timestep = BatchedTimestep::first(self.b * self.a);
        for i in 0..self.b {
            self.write_obs(i);
        }
    }

    /// Reset just env `i` (autoreset path): all of the slot's agent-rows.
    fn reset_one(&mut self, i: usize) {
        self.reset_slot_fresh(i);
        for r in i * self.a..(i + 1) * self.a {
            self.timestep.t[r] = 0;
            self.timestep.action[r] = -1;
            self.timestep.reward[r] = 0.0;
            self.timestep.discount[r] = 1.0;
            self.timestep.step_type[r] = StepType::First;
            self.timestep.episodic_return[r] = 0.0;
        }
    }

    /// Step all environments with `actions` (the flat `[B × A]` action
    /// matrix — one action per agent-row, values 0..7; just `[B]` when
    /// `A = 1`). Slots whose previous timestep was terminal autoreset
    /// instead.
    pub fn step(&mut self, actions: &[u8]) {
        self.step_impl(actions, true);
    }

    /// One lockstep iteration; `write_obs: false` advances the state and
    /// timestep metadata without materialising observations (the interior
    /// of a fused [`ObsCapture::Final`] window — output-only buffers, so
    /// skipping writes nobody reads is exact, including dirty-tile rgb
    /// whose cache only advances on blit).
    fn step_impl(&mut self, actions: &[u8], write_obs: bool) {
        debug_assert_eq!(actions.len(), self.b * self.a);
        self.step_count += 1;
        if self.supervisor.is_some() || self.chaos.is_some() {
            for i in 0..self.b {
                self.step_slot_guarded(i, actions, write_obs);
            }
        } else {
            for i in 0..self.b {
                self.step_slot_body(i, actions, write_obs);
            }
        }
    }

    /// The plain per-slot step: autoreset a terminal slot, step a live one,
    /// optionally materialise its observations.
    #[inline]
    fn step_slot_body(&mut self, i: usize, actions: &[u8], write_obs: bool) {
        let a = self.a;
        // All of a slot's agent-rows share one step_type, so row i·A
        // speaks for the slot.
        if self.timestep.step_type[i * a].is_last() {
            self.reset_one(i);
        } else {
            self.step_one(i, &actions[i * a..(i + 1) * a]);
        }
        if write_obs {
            self.write_obs(i);
        }
    }

    /// The guarded step body: fire any chaos fault due at this (slot, step)
    /// coordinate, validate action bytes, then run the plain slot body.
    /// Out-of-range action bytes are tolerated (wrapped mod
    /// [`Action::N`]) on the fast path; under supervision/chaos they
    /// become a structured panic instead of being silently remapped.
    fn step_slot_checked(&mut self, i: usize, actions: &[u8], write_obs: bool) {
        let a = self.a;
        let global = self.index_offset + i;
        let step = self.step_count;
        let slot_acts = &actions[i * a..(i + 1) * a];
        let mut corrupted: Option<Vec<u8>> = None;
        if let Some(kind) = self.chaos.as_mut().and_then(|c| c.check(global, step)) {
            match kind {
                ChaosKind::Panic => {
                    panic!("chaos: injected panic in slot {global} at step {step}")
                }
                ChaosKind::PoisonRng => {
                    // Scramble real state before panicking, so recovery has
                    // to repair the slot, not merely resume it.
                    self.state.rng[i] ^= 0x9E37_79B9_7F4A_7C15;
                    panic!("chaos: poisoned rng draw in slot {global} at step {step}")
                }
                ChaosKind::BadAction => {
                    let mut row = slot_acts.to_vec();
                    row[0] = 255;
                    corrupted = Some(row);
                }
            }
        }
        let acts: &[u8] = corrupted.as_deref().unwrap_or(slot_acts);
        for (j, &act) in acts.iter().enumerate() {
            if act as usize >= Action::N {
                let tag = if corrupted.is_some() { "chaos: " } else { "" };
                panic!(
                    "{tag}out-of-range action {act} for agent {j} in slot {global} \
                     at step {step} (valid: 0..{})",
                    Action::N
                );
            }
        }
        if self.timestep.step_type[i * a].is_last() {
            self.reset_one(i);
        } else {
            self.step_one(i, acts);
        }
        if write_obs {
            self.write_obs(i);
        }
    }

    /// Supervised per-slot step: take the pre-step snapshot (for policies
    /// that can roll back), run the checked body behind `catch_unwind`
    /// (unless the policy wants panics to unwind into the worker), and
    /// dispatch any caught fault to the policy handler.
    fn step_slot_guarded(&mut self, i: usize, actions: &[u8], write_obs: bool) {
        if self.supervisor.as_ref().is_some_and(Supervisor::snapshotting) {
            let ck = self.snapshot_slot(i);
            let sc = self.step_count;
            if let Some(sup) = self.supervisor.as_mut() {
                sup.pre_step[i] = Some((sc, ck));
            }
        }
        let catching = self.supervisor.as_ref().is_some_and(Supervisor::catching);
        if !catching {
            // Chaos without supervision, or RestartWorker: the panic
            // unwinds out of `step` (killing a ShardedEnv worker); the
            // snapshot + stamp ledger above is what
            // `recover_interrupted_step` repairs from.
            self.step_slot_checked(i, actions, write_obs);
            if let Some(sup) = self.supervisor.as_mut() {
                sup.stamp[i] = self.step_count;
                sup.consecutive[i] = 0;
            }
            return;
        }
        let res = {
            let this = &mut *self;
            catch_fault(move || this.step_slot_checked(i, actions, write_obs))
        };
        match res {
            Ok(()) => {
                if let Some(sup) = self.supervisor.as_mut() {
                    sup.stamp[i] = self.step_count;
                    sup.consecutive[i] = 0;
                }
            }
            Err(payload) => self.handle_slot_fault(i, payload, write_obs),
        }
    }

    /// Record a caught slot panic as an [`EngineFault`], then apply the
    /// policy: re-raise ([`FaultPolicy::Propagate`]) or quarantine.
    fn handle_slot_fault(
        &mut self,
        i: usize,
        payload: Box<dyn std::any::Any + Send>,
        write_obs: bool,
    ) {
        let fault = EngineFault {
            shard: None,
            slot: Some(self.index_offset + i),
            env_id: self.cfg.id.clone(),
            step: self.step_count,
            payload: payload_to_string(&*payload),
        };
        let sup = self.supervisor.as_mut().expect("slot faults are only caught under supervision");
        sup.faults.push(fault);
        if sup.policy == FaultPolicy::Propagate {
            std::panic::resume_unwind(payload);
        }
        self.quarantine_slot(i, payload, write_obs);
    }

    /// The quarantine ladder: on the first consecutive fault, roll the slot
    /// back to its pre-step snapshot (a no-op transition: same state, zero
    /// reward, `slot_quarantined` latched); on repeated faults — or when
    /// the interrupted episode was already terminal — replace the episode
    /// via up to `max_retries` successor-episode-key resets (the same
    /// retry path layout generation uses); re-raise when exhausted.
    fn quarantine_slot(
        &mut self,
        i: usize,
        mut payload: Box<dyn std::any::Any + Send>,
        write_obs: bool,
    ) {
        let a = self.a;
        let sc = self.step_count;
        let sup = self.supervisor.as_mut().expect("quarantine requires a supervisor");
        sup.consecutive[i] += 1;
        let max_retries = sup.max_retries;
        if sup.consecutive[i] == 1 {
            if let Some((stamp, ck)) = sup.pre_step[i].take() {
                // Only a snapshot from *this* step's pre-state is a valid
                // rollback target, and only while its episode is live — a
                // terminal pre-step must autoreset, so fall through to the
                // reset arm instead of resurrecting a finished episode.
                if stamp == sc && !ck.ts_step_type[0].is_last() {
                    self.restore_slot_impl(i, &ck, write_obs);
                    for r in i * a..(i + 1) * a {
                        // A quarantined step is a no-op transition: no
                        // action took effect and no reward accrues
                        // (episodic_return stays at the snapshot's value).
                        self.timestep.action[r] = -1;
                        self.timestep.reward[r] = 0.0;
                        self.state.events[r].slot_quarantined = true;
                    }
                    let sup = self.supervisor.as_mut().unwrap();
                    sup.recovered += 1;
                    sup.stamp[i] = sc;
                    return;
                }
            }
        }
        for _ in 0..max_retries {
            let res = {
                let this = &mut *self;
                catch_fault(move || this.reset_one(i))
            };
            match res {
                Ok(()) => {
                    for r in i * a..(i + 1) * a {
                        self.state.events[r].slot_quarantined = true;
                    }
                    if write_obs {
                        self.write_obs(i);
                    }
                    let sup = self.supervisor.as_mut().unwrap();
                    sup.recovered += 1;
                    sup.stamp[i] = sc;
                    return;
                }
                Err(p) => payload = p,
            }
        }
        std::panic::resume_unwind(payload)
    }

    /// Fused K-step window — the scan-mode core every engine builds on.
    /// Bit-identical to `k` calls of [`BatchedEnv::step`]; with a
    /// [`ActionPlan::Fixed`] plan and [`ObsCapture::Final`] the interior
    /// steps skip observation materialisation entirely.
    pub fn step_n(&mut self, mut plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        let rows = self.policy_rows();
        traj.ensure_like(k, rows, &self.obs);
        let capture_all = traj.capture == ObsCapture::All;
        let mut buf = vec![0u8; rows];
        if let ActionPlan::Fixed(actions) = &plan {
            assert_eq!(actions.len(), k * rows, "Fixed plan must be [K × B·A]");
        }
        for t in 0..k {
            match &mut plan {
                ActionPlan::Fixed(actions) => {
                    // Interior observations are dead under Final capture:
                    // the plan cannot read them and the next window starts
                    // from the state, not the frame.
                    let write = capture_all || t + 1 == k;
                    self.step_impl(&actions[t * rows..(t + 1) * rows], write);
                }
                ActionPlan::Provider(p) => {
                    p.actions(t, &self.obs, &self.timestep, &mut buf);
                    p.overlap(t);
                    self.step_impl(&buf, true);
                }
            }
            traj.record_row(t, &self.timestep);
            if capture_all {
                traj.capture_obs_row(t, &self.obs);
            }
        }
    }

    /// Core per-slot step: per-agent interventions (ascending agent order —
    /// the deterministic contested-cell rule) → one shared transition →
    /// per-agent reward rows and a slot-level termination → timeout
    /// truncation. `acts` holds the slot's `A` actions.
    fn step_one(&mut self, i: usize, acts: &[u8]) {
        let stochastic = self.cfg.stochastic_balls;
        let max_steps = self.cfg.max_steps;
        let a = self.a;
        for j in 0..a {
            let mut slot = self.state.agent_slot_mut(i, j);
            intervene(&mut slot, Action::from_u8(acts[j]));
        }
        {
            let mut slot = self.state.slot_mut(i);
            transition(&mut slot, stochastic);
        }
        // One slot-level termination: any agent's terminal event ends the
        // episode for the whole slot (the grid resets as a unit).
        let mut terminated = false;
        for j in 0..a {
            terminated = terminated || self.cfg.termination.eval(&self.state.agent_slot(i, j));
        }
        let t_now = self.state.agent_slot(i, 0).t;
        let truncated = !terminated && t_now >= max_steps;
        let step_type = if terminated {
            StepType::Terminated
        } else if truncated {
            StepType::Truncated
        } else {
            StepType::Mid
        };

        for j in 0..a {
            let action = Action::from_u8(acts[j]);
            let reward = self.cfg.reward.eval(&self.state.agent_slot(i, j), action, max_steps);
            let r = i * a + j;
            let ts = &mut self.timestep;
            ts.t[r] = t_now;
            ts.action[r] = action as i32;
            ts.reward[r] = reward;
            ts.episodic_return[r] += reward;
            ts.discount[r] = if terminated { 0.0 } else { 1.0 };
            ts.step_type[r] = step_type;
        }
    }

    fn write_obs(&mut self, i: usize) {
        let stride = self.cfg.obs.len(self.cfg.h, self.cfg.w);
        for j in 0..self.a {
            let slot = self.state.agent_slot(i, j);
            let r = i * self.a + j;
            match &mut self.obs.data {
                ObsData::I32(v) => {
                    let out = &mut v[r * stride..(r + 1) * stride];
                    self.cfg.obs.write_i32_route(self.obs_route, &slot, out);
                }
                ObsData::U8(v) => {
                    let sheet = self.sprites.as_ref().expect("sprite sheet for rgb obs");
                    let out = &mut v[r * stride..(r + 1) * stride];
                    let overlay = matches!(self.obs_route, ObsRoute::Overlay(_));
                    if self.cfg.obs.kind == ObsKind::Rgb && overlay {
                        // Dirty-tile path: the obs buffer persists across
                        // steps, so only tiles whose render code changed are
                        // re-blitted (a fresh env starts all-INVALID → one
                        // full render).
                        let hw = self.cfg.h * self.cfg.w;
                        let prev = &mut self.rgb_prev[r * hw..(r + 1) * hw];
                        rgb_incremental(&slot, sheet, prev, out);
                    } else {
                        self.cfg.obs.write_u8_route(self.obs_route, &slot, sheet, out);
                    }
                }
            }
            // The goal-conditioning side channel rides along per agent-row.
            let mrow = &mut self.obs.mission[r * MISSION_TOKENS..(r + 1) * MISSION_TOKENS];
            self.cfg.obs.write_mission_route(self.obs_route, &slot, mrow);
        }
    }

    /// Arm fault supervision with `policy`. Safe to call again to switch
    /// policies; the fault log carries over.
    pub fn supervise(&mut self, policy: FaultPolicy) {
        match self.supervisor.as_mut() {
            Some(sup) => sup.policy = policy,
            None => self.supervisor = Some(Supervisor::new(policy, self.b)),
        }
    }

    /// Arm (or replace) the deterministic chaos injector.
    pub fn arm_chaos(&mut self, injector: ChaosInjector) {
        self.chaos = Some(injector);
    }

    /// Every fault caught so far, in order.
    pub fn fault_log(&self) -> Vec<EngineFault> {
        self.supervisor.as_ref().map(|s| s.faults.clone()).unwrap_or_default()
    }

    /// Injected/recovered counters for the bench meta block.
    pub fn fault_stats(&self) -> FaultStats {
        FaultStats {
            injected: self.chaos.as_ref().map(|c| c.fired_count()).unwrap_or(0),
            recovered: self.supervisor.as_ref().map(|s| s.recovered).unwrap_or(0),
        }
    }

    /// Engine steps taken since construction (or the last checkpoint
    /// restore).
    pub fn step_count(&self) -> u64 {
        self.step_count
    }

    /// Capture slot `i`: full SoA state + reset counter + the slot's `[A]`
    /// timestep rows — everything a mid-rollout resume needs.
    pub fn snapshot_slot(&self, i: usize) -> SlotCheckpoint {
        let a = self.a;
        let rows = i * a..(i + 1) * a;
        SlotCheckpoint {
            state: SlotSnapshot::capture(&self.state, i),
            reset_count: self.reset_counts[i],
            ts_t: self.timestep.t[rows.clone()].to_vec(),
            ts_action: self.timestep.action[rows.clone()].to_vec(),
            ts_reward: self.timestep.reward[rows.clone()].to_vec(),
            ts_discount: self.timestep.discount[rows.clone()].to_vec(),
            ts_step_type: self.timestep.step_type[rows.clone()].to_vec(),
            ts_episodic_return: self.timestep.episodic_return[rows].to_vec(),
        }
    }

    /// Restore slot `i` from a checkpoint taken on the same configuration
    /// and rewrite its observations. Every other slot is untouched.
    pub fn restore_slot(&mut self, i: usize, ck: &SlotCheckpoint) {
        self.restore_slot_impl(i, ck, true);
    }

    fn restore_slot_impl(&mut self, i: usize, ck: &SlotCheckpoint, write_obs: bool) {
        let a = self.a;
        ck.state.restore(&mut self.state, i);
        self.reset_counts[i] = ck.reset_count;
        let rows = i * a..(i + 1) * a;
        self.timestep.t[rows.clone()].copy_from_slice(&ck.ts_t);
        self.timestep.action[rows.clone()].copy_from_slice(&ck.ts_action);
        self.timestep.reward[rows.clone()].copy_from_slice(&ck.ts_reward);
        self.timestep.discount[rows.clone()].copy_from_slice(&ck.ts_discount);
        self.timestep.step_type[rows.clone()].copy_from_slice(&ck.ts_step_type);
        self.timestep.episodic_return[rows].copy_from_slice(&ck.ts_episodic_return);
        // The rgb dirty-tile cache describes what the obs *buffer* shows,
        // which a state restore does not change — the next blit diffs the
        // restored state against it and repaints exactly the stale tiles.
        if write_obs {
            self.write_obs(i);
        }
    }

    /// Checkpoint the whole engine: all `B` slots, the RNG identity and
    /// the step counter.
    pub fn save_checkpoint(&self) -> EngineCheckpoint {
        EngineCheckpoint {
            b: self.b,
            a: self.a,
            root_key: self.key.0,
            step_count: self.step_count,
            slots: (0..self.b).map(|i| self.snapshot_slot(i)).collect(),
        }
    }

    /// Restore a checkpoint taken by [`BatchedEnv::save_checkpoint`] on an
    /// engine with the same shape and root key (asserted — episode keys
    /// fold the root key in, so resuming under a different key could not
    /// be bit-identical).
    pub fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        assert_eq!((ck.b, ck.a), (self.b, self.a), "checkpoint shape mismatch");
        assert_eq!(
            ck.root_key, self.key.0,
            "checkpoint was taken under a different root key"
        );
        self.step_count = ck.step_count;
        for (i, slot) in ck.slots.iter().enumerate() {
            self.restore_slot(i, slot);
        }
    }

    /// Repair after a [`FaultPolicy::RestartWorker`] panic unwound out of
    /// [`BatchedEnv::step`] mid-iteration, then finish the step. Slots
    /// stamped with the current step already completed and stay untouched;
    /// the torn slot rolls back to the pre-step snapshot its interrupted
    /// step took and re-steps (chaos specs are one-shot, so a transient
    /// fault replays cleanly — bitwise identical to the fault-free step);
    /// a slot that faults *again* is quarantined. `actions` must be the
    /// same `[B × A]` matrix the interrupted step was given.
    pub fn recover_interrupted_step(&mut self, actions: &[u8], write_obs: bool) {
        let sc = self.step_count;
        assert!(
            self.supervisor.as_ref().is_some_and(Supervisor::snapshotting),
            "recover_interrupted_step requires a snapshotting fault policy"
        );
        for i in 0..self.b {
            let sup = self.supervisor.as_ref().unwrap();
            if sup.stamp[i] == sc {
                continue;
            }
            let torn = matches!(&sup.pre_step[i], Some((stamp, _)) if *stamp == sc);
            if torn {
                let (_, ck) = self.supervisor.as_mut().unwrap().pre_step[i].take().unwrap();
                self.restore_slot_impl(i, &ck, false);
                self.supervisor.as_mut().unwrap().pre_step[i] = Some((sc, ck));
            }
            let res = {
                let this = &mut *self;
                catch_fault(move || this.step_slot_checked(i, actions, write_obs))
            };
            match res {
                Ok(()) => {
                    let sup = self.supervisor.as_mut().unwrap();
                    sup.stamp[i] = sc;
                    sup.consecutive[i] = 0;
                    if torn {
                        sup.recovered += 1;
                    }
                }
                Err(payload) => self.handle_slot_fault(i, payload, write_obs),
            }
        }
    }

    /// Convenience: run `steps` lockstep iterations with uniformly random
    /// actions. Returns total env-steps executed (`b × steps`). Used by the
    /// throughput benches (paper Figs. 4/5/8).
    pub fn rollout_random(&mut self, steps: usize, seed: u64) -> usize {
        let mut rng = crate::rng::Rng::new(seed);
        let mut actions = vec![0u8; self.b * self.a];
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = rng.below(Action::N as u32) as u8;
            }
            self.step(&actions);
        }
        steps * self.b
    }
}

/// Uniform interface over the batched steppers — [`BatchedEnv`] (the `vmap`
/// analog) and [`ShardedEnv`] (the `pmap` analog) — so training and
/// benchmark code is agnostic to the execution backend. Object safe: the
/// multi-agent coordinator holds `Box<dyn BatchStepper>` per agent.
pub trait BatchStepper {
    /// Number of parallel environments (slots).
    fn batch_size(&self) -> usize;

    /// Agents per slot (`A`; 1 unless the family is multi-agent).
    fn num_agents(&self) -> usize {
        1
    }

    /// Agent-row count `B·A`: the width of the action matrix, of every
    /// per-row output buffer, and of the policy batch the trainers see.
    fn policy_rows(&self) -> usize {
        self.batch_size() * self.num_agents()
    }

    /// Step every environment in lockstep with the flat `[B × A]` action
    /// matrix; terminal slots autoreset.
    fn step(&mut self, actions: &[u8]);

    /// Timestep metadata written by the most recent step/reset.
    fn timestep(&self) -> &BatchedTimestep;

    /// Observation buffers written by the most recent step/reset.
    fn obs(&self) -> &ObsBatch;

    /// Reset every environment with fresh episode keys.
    fn reset_all(&mut self);

    /// Fused K-step window (scan mode): execute `k` lockstep steps from
    /// `plan` in one call, recording every step's timestep metadata (and,
    /// under [`ObsCapture::All`], observations) into `traj`. Bit-identical
    /// to `k` calls of [`BatchStepper::step`] — the engines override this
    /// with fused implementations (skipped interior observations, one
    /// sync round-trip per window); this default is the per-step fallback
    /// any implementor gets for free.
    fn step_n(&mut self, mut plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        let rows = self.policy_rows();
        traj.ensure_like(k, rows, self.obs());
        let mut buf = vec![0u8; rows];
        if let ActionPlan::Fixed(actions) = &plan {
            assert_eq!(actions.len(), k * rows, "Fixed plan must be [K × B·A]");
        }
        for t in 0..k {
            match &mut plan {
                ActionPlan::Fixed(actions) => {
                    buf.copy_from_slice(&actions[t * rows..(t + 1) * rows]);
                }
                ActionPlan::Provider(p) => {
                    p.actions(t, self.obs(), self.timestep(), &mut buf);
                    p.overlap(t);
                }
            }
            self.step(&buf);
            traj.record_row(t, self.timestep());
            if traj.capture == ObsCapture::All {
                traj.capture_obs_row(t, self.obs());
            }
        }
    }

    /// Number of discrete actions.
    fn num_actions(&self) -> usize {
        Action::N
    }

    /// Checkpoint the engine: all `B` slots + RNG identity + step
    /// counters, sufficient to resume bit-identically on a fresh engine of
    /// the same configuration. `&mut self` because the pipelined engine
    /// round-trips the request through its stepper thread. Engines without
    /// snapshot support keep this default.
    fn save_checkpoint(&mut self) -> EngineCheckpoint {
        unimplemented!("this BatchStepper does not support checkpoint/restore")
    }

    /// Restore a checkpoint taken by [`BatchStepper::save_checkpoint`] on
    /// an engine of the same configuration (asserts on mismatch).
    fn restore_checkpoint(&mut self, _ck: &EngineCheckpoint) {
        unimplemented!("this BatchStepper does not support checkpoint/restore")
    }

    /// Arm fault supervision with `policy` (see [`FaultPolicy`]).
    fn supervise(&mut self, _policy: FaultPolicy) {
        unimplemented!("this BatchStepper does not support fault supervision")
    }

    /// Every fault the engine has caught so far. `&mut self` for the same
    /// round-trip reason as [`BatchStepper::save_checkpoint`].
    fn fault_log(&mut self) -> Vec<EngineFault> {
        Vec::new()
    }

    /// Injected/recovered fault counters (the `BENCH_*.json` meta block).
    fn fault_stats(&mut self) -> FaultStats {
        FaultStats::default()
    }
}

/// Fused-window variant of the engines' `rollout_random`: the **same**
/// uniform action stream (seeded `rng.below(N)` in `(t, env)` order),
/// executed through [`BatchStepper::step_n`] in windows of `window` steps
/// with observations materialised only at window tails — the scan-mode
/// throughput protocol of the `fig5_sharded` bench's `*-scan` rows.
/// Returns total env-steps executed (`b × steps`).
pub fn rollout_random_scan<E: BatchStepper + ?Sized>(
    env: &mut E,
    steps: usize,
    seed: u64,
    window: usize,
) -> usize {
    let rows = env.policy_rows();
    let window = window.max(1);
    let mut rng = crate::rng::Rng::new(seed);
    let mut plan = vec![0u8; window * rows];
    let mut traj = TrajectorySlice::new(ObsCapture::Final);
    let mut done = 0usize;
    while done < steps {
        let k = window.min(steps - done);
        for a in plan[..k * rows].iter_mut() {
            *a = rng.below(Action::N as u32) as u8;
        }
        env.step_n(ActionPlan::Fixed(&plan[..k * rows]), k, &mut traj);
        done += k;
    }
    steps * env.batch_size()
}

impl BatchStepper for BatchedEnv {
    fn batch_size(&self) -> usize {
        self.b
    }

    fn num_agents(&self) -> usize {
        self.a
    }

    fn step(&mut self, actions: &[u8]) {
        BatchedEnv::step(self, actions);
    }

    fn timestep(&self) -> &BatchedTimestep {
        &self.timestep
    }

    fn obs(&self) -> &ObsBatch {
        &self.obs
    }

    fn reset_all(&mut self) {
        BatchedEnv::reset_all(self);
    }

    fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        BatchedEnv::step_n(self, plan, k, traj);
    }

    fn save_checkpoint(&mut self) -> EngineCheckpoint {
        BatchedEnv::save_checkpoint(self)
    }

    fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        BatchedEnv::restore_checkpoint(self, ck);
    }

    fn supervise(&mut self, policy: FaultPolicy) {
        BatchedEnv::supervise(self, policy);
    }

    fn fault_log(&mut self) -> Vec<EngineFault> {
        BatchedEnv::fault_log(self)
    }

    fn fault_stats(&mut self) -> FaultStats {
        BatchedEnv::fault_stats(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::systems::observations::ObsKind;

    fn env(id: &str, b: usize) -> BatchedEnv {
        BatchedEnv::new(make(id).unwrap(), b, Key::new(0))
    }

    #[test]
    fn reset_produces_first_timesteps_and_obs() {
        let e = env("Navix-Empty-8x8-v0", 4);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::First));
        assert_eq!(e.obs.stride(4), 7 * 7 * 3);
        // fixed start → all four observations identical
        let o0: Vec<i32> = e.obs.env_i32(4, 0).to_vec();
        for i in 1..4 {
            assert_eq!(e.obs.env_i32(4, i), &o0[..]);
        }
    }

    #[test]
    fn step_advances_time_and_tracks_actions() {
        let mut e = env("Navix-Empty-5x5-v0", 2);
        e.step(&[Action::Forward as u8, Action::Left as u8]);
        assert_eq!(e.timestep.t, vec![1, 1]);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::Mid));
        assert_eq!(e.timestep.action, vec![2, 0]);
    }

    #[test]
    fn scripted_goal_reach_terminates_then_autoresets() {
        // Empty-5x5: agent (1,1) E, goal (3,3): F, F, Right, F, F.
        let mut e = env("Navix-Empty-5x5-v0", 1);
        let script =
            [Action::Forward, Action::Forward, Action::Right, Action::Forward, Action::Forward];
        for &a in &script {
            e.step(&[a as u8]);
        }
        assert_eq!(e.timestep.step_type[0], StepType::Terminated);
        assert_eq!(e.timestep.reward[0], 1.0);
        assert_eq!(e.timestep.discount[0], 0.0);
        assert_eq!(e.timestep.episodic_return[0], 1.0);
        // next step autoresets regardless of the action
        e.step(&[Action::Forward as u8]);
        assert_eq!(e.timestep.step_type[0], StepType::First);
        assert_eq!(e.timestep.t[0], 0);
        assert_eq!(e.timestep.action[0], -1);
        assert_eq!(e.timestep.episodic_return[0], 0.0);
        let s = e.state.slot(0);
        assert_eq!(s.player(), crate::core::grid::Pos::new(1, 1), "fresh episode");
    }

    #[test]
    fn terminal_event_at_exact_timeout_is_termination_not_truncation() {
        // MiniGrid semantics: `terminated` is evaluated before the timeout,
        // so an episode whose terminal event fires exactly at t == T must
        // report termination (γ = 0), not truncation. Empty-5x5's scripted
        // solution takes exactly 5 steps; pin T to 5.
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.max_steps = 5;
        let mut e = BatchedEnv::new(cfg, 1, Key::new(0));
        let script =
            [Action::Forward, Action::Forward, Action::Right, Action::Forward, Action::Forward];
        for &a in &script {
            e.step(&[a as u8]);
        }
        assert_eq!(e.timestep.t[0], 5, "the goal step is exactly the timeout step");
        assert_eq!(
            e.timestep.step_type[0],
            StepType::Terminated,
            "terminal at t == T must be termination"
        );
        assert_eq!(e.timestep.discount[0], 0.0, "termination sets γ = 0");
        assert_eq!(e.timestep.reward[0], 1.0);
    }

    #[test]
    fn truncation_at_max_steps_keeps_discount() {
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.max_steps = 3;
        let mut e = BatchedEnv::new(cfg, 1, Key::new(1));
        for _ in 0..3 {
            e.step(&[Action::Left as u8]); // spin in place, never terminal
        }
        assert_eq!(e.timestep.step_type[0], StepType::Truncated);
        assert_eq!(e.timestep.discount[0], 1.0, "truncation preserves γ");
    }

    #[test]
    fn batch_envs_evolve_independently() {
        let mut e = env("Navix-Empty-Random-6x6", 8);
        let mut acts = vec![Action::Forward as u8; 8];
        acts[3] = Action::Left as u8;
        e.step(&acts);
        use crate::core::state::AgentView;
        let mut distinct = std::collections::HashSet::new();
        for i in 0..8 {
            let s = e.state.slot(i);
            distinct.insert((s.player_pos_value(), s.player_dir_value()));
        }
        assert!(distinct.len() > 2, "batch collapsed to identical states");
    }

    #[test]
    fn rollout_random_executes_requested_steps() {
        let mut e = env("Navix-Empty-8x8-v0", 16);
        let n = e.rollout_random(100, 42);
        assert_eq!(n, 1600);
    }

    #[test]
    fn rgb_batch_allocates_u8() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap().with_observation(ObsKind::Rgb);
        let e = BatchedEnv::new(cfg, 2, Key::new(0));
        match &e.obs.data {
            ObsData::U8(v) => assert_eq!(v.len(), 2 * 160 * 160 * 3),
            _ => panic!("rgb must be u8"),
        }
        assert_eq!(e.obs.mission.len(), 2 * MISSION_TOKENS, "mission channel rides along");
    }

    #[test]
    fn mission_channel_tracks_state_and_clears_for_goal_envs() {
        use crate::core::mission::Mission;
        // Mission env: features present and equal to the state's mission.
        let e = env("Navix-GoToDoor-5x5-v0", 3);
        for i in 0..3 {
            let mut expect = [0i32; MISSION_TOKENS];
            Mission::from_raw(e.state.mission[i]).write_features(&mut expect);
            assert_eq!(e.obs.mission_row(3, i), &expect[..], "env {i}");
            assert_eq!(e.obs.mission_row(3, i)[0], 1, "env {i}: mission must be present");
        }
        // Goal env: the channel exists but stays all-zero.
        let e = env("Navix-Empty-5x5-v0", 2);
        assert!(e.obs.mission.iter().all(|&x| x == 0));
        // copy_policy_row concatenates grid + mission.
        let e = env("Navix-Fetch-5x5-N2-v0", 2);
        let stride = e.obs.stride(2);
        let mut row = vec![0i32; stride + MISSION_TOKENS];
        e.obs.copy_policy_row(2, 1, &mut row);
        assert_eq!(&row[..stride], e.obs.env_i32(2, 1));
        assert_eq!(&row[stride..], e.obs.mission_row(2, 1));
    }

    #[test]
    fn rgb_dirty_tiles_match_from_scratch_render() {
        // The incremental rgb buffer must be indistinguishable from a full
        // render at every step, including across autoresets.
        let cfg = make("Navix-Empty-5x5-v0").unwrap().with_observation(ObsKind::Rgb);
        let mut e = BatchedEnv::new(cfg, 2, Key::new(0));
        let sheet = SpriteSheet::shared();
        let mut scratch = vec![0u8; e.obs.stride(2)];
        for i in 0..2 {
            crate::systems::observations::scan::rgb(&e.state.slot(i), &sheet, &mut scratch);
            assert_eq!(e.obs.env_u8(2, i), &scratch[..], "reset frame env {i}");
        }
        for step in 0..30 {
            let a = [(step % 7) as u8, ((step + 2) % 7) as u8];
            e.step(&a);
            for i in 0..2 {
                crate::systems::observations::scan::rgb(&e.state.slot(i), &sheet, &mut scratch);
                assert_eq!(e.obs.env_u8(2, i), &scratch[..], "step {step} env {i}");
            }
        }
    }

    #[test]
    fn every_registered_env_steps_under_random_actions() {
        for id in crate::envs::registry::fig3_envs() {
            let mut e = env(id, 4);
            e.rollout_random(50, 7);
        }
    }

    #[test]
    fn offset_slices_reproduce_global_streams() {
        // The RNG contract behind ShardedEnv: a BatchedEnv covering global
        // envs [3, 6) must reproduce envs 3..6 of a 6-env batch exactly —
        // layouts, steps and autoresets included.
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut full = BatchedEnv::new(cfg.clone(), 6, Key::new(9));
        let mut part = BatchedEnv::with_offset(cfg, 3, Key::new(9), 3);
        assert_eq!(&full.state.player_pos[3..6], &part.state.player_pos[..]);
        let mut rng = crate::rng::Rng::new(4);
        for _ in 0..120 {
            let actions: Vec<u8> = (0..6).map(|_| rng.below(7) as u8).collect();
            full.step(&actions);
            part.step(&actions[3..6]);
            assert_eq!(&full.state.player_pos[3..6], &part.state.player_pos[..]);
            assert_eq!(&full.timestep.reward[3..6], &part.timestep.reward[..]);
            for i in 0..3 {
                assert_eq!(full.obs.env_i32(6, 3 + i), part.obs.env_i32(3, i));
            }
        }
    }

    #[test]
    fn step_n_matches_stepwise_and_skips_interior_obs_exactly() {
        // Unit pin of the scan-mode core (the engine sweep lives in
        // tests/test_scan_parity.rs): one Fixed window under Final capture
        // must land on the same state, timestep and final frame as the
        // per-step loop, despite never writing interior observations.
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut a = BatchedEnv::new(cfg.clone(), 5, Key::new(8));
        let mut b = BatchedEnv::new(cfg, 5, Key::new(8));
        let mut rng = crate::rng::Rng::new(2);
        let mut traj = TrajectorySlice::new(ObsCapture::Final);
        for _ in 0..4 {
            let plan: Vec<u8> = (0..9 * 5).map(|_| rng.below(7) as u8).collect();
            a.step_n(ActionPlan::Fixed(&plan), 9, &mut traj);
            for t in 0..9 {
                b.step(&plan[t * 5..(t + 1) * 5]);
                assert_eq!(traj.reward_row(t), &b.timestep.reward[..]);
                assert_eq!(traj.step_type_row(t), &b.timestep.step_type[..]);
            }
            assert_eq!(a.state.rng, b.state.rng, "in-episode RNG streams diverged");
            assert_eq!(a.timestep.t, b.timestep.t);
            for i in 0..5 {
                assert_eq!(a.obs.env_i32(5, i), b.obs.env_i32(5, i), "final frame env {i}");
            }
        }
    }

    #[test]
    fn rollout_random_scan_replays_the_rollout_random_stream() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut a = BatchedEnv::new(cfg.clone(), 4, Key::new(3));
        let mut b = BatchedEnv::new(cfg, 4, Key::new(3));
        let n = rollout_random_scan(&mut a, 50, 42, 16); // uneven tail window
        assert_eq!(n, b.rollout_random(50, 42));
        assert_eq!(a.timestep.reward, b.timestep.reward);
        assert_eq!(a.state.player_pos, b.state.player_pos);
        for i in 0..4 {
            assert_eq!(a.obs.env_i32(4, i), b.obs.env_i32(4, i));
        }
    }

    #[test]
    fn episodic_return_accumulates_costs() {
        let mut cfg = make("Navix-Empty-5x5-v0").unwrap();
        cfg.reward = crate::systems::rewards::RewardSpec::new(vec![
            crate::systems::rewards::RewardFn::TimeCost(0.1),
        ]);
        let mut e = BatchedEnv::new(cfg, 1, Key::new(0));
        e.step(&[Action::Left as u8]);
        e.step(&[Action::Left as u8]);
        assert!((e.timestep.episodic_return[0] + 0.2).abs() < 1e-6);
    }
}
