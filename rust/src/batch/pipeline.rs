//! `PipelinedEnv` — the double-buffered rollout pipeline.
//!
//! PR 3 made the env/observation hot path O(1) per cell; the remaining
//! serial cost in training was structural: the learner and the simulator
//! took strict turns (`act → step → act → …`). This module overlaps them,
//! following the Large Batch Simulation design (Shacklett et al.): a
//! dedicated stepper thread owns the execution engine (any
//! [`BatchStepper`] — the single-threaded [`BatchedEnv`] or the sharded
//! multi-core [`crate::batch::ShardedEnv`]), and the learner talks to it
//! through **two swap buffers** of gathered timesteps + observations:
//!
//! * [`PipelinedEnv::submit`] hands the step-*t* actions to the stepper
//!   thread and returns immediately — the workers advance the envs to
//!   *t + 1* in the **back** buffer;
//! * meanwhile the learner keeps reading the **front** buffer (step *t*'s
//!   observations stay valid) to run the critic, log-prob and bookkeeping
//!   half of inference;
//! * [`PipelinedEnv::sync`] blocks until the step finishes and swaps the
//!   buffers (two `Vec` pointer swaps — no copy on the learner side).
//!
//! ## Determinism
//!
//! The pipeline changes *when* work happens, never *what* is computed: the
//! actions submitted are exactly the serial loop's actions, the envs step
//! in the same order inside the owned engine, and the learner's overlapped
//! work reads a snapshot of step *t* that the stepping cannot mutate. For
//! a fixed seed the rollout tensors and training metrics are bit-for-bit
//! identical to the serial path — `tests/test_train_parity.rs` pins this
//! across env families, and [`crate::coordinator::multi_agent`] pins the
//! full training curve.

use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batch::fault::lock_recover;
use crate::batch::{
    ActionPlan, BatchStepper, BatchedEnv, EngineFault, FaultPolicy, FaultStats, ObsBatch,
    ObsCapture, TrajectorySlice,
};
use crate::core::actions::Action;
use crate::core::snapshot::EngineCheckpoint;
use crate::core::timestep::BatchedTimestep;

/// Default stall watchdog: how long [`PipelinedEnv::sync`] waits for a
/// live stepper thread before declaring it stalled. Overridable per
/// instance ([`PipelinedEnv::set_watchdog_secs`]) or process-wide via the
/// `NAVIX_PIPE_WATCHDOG_SECS` environment variable.
const DEFAULT_WATCHDOG: Duration = Duration::from_secs(120);

/// What one epoch asks the stepper thread to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cmd {
    Step,
    /// Fused window: run the shipped K-step plan through the owned
    /// engine's `step_n`; the back buffers carry the whole trajectory
    /// chunk across in one swap.
    StepN,
    ResetAll,
    /// Checkpoint the owned engine into [`PipeState::checkpoint`].
    Save,
    /// Restore the owned engine from [`PipeState::checkpoint`].
    Restore,
    /// Arm the owned engine with [`PipeState::policy`].
    Supervise,
    /// Copy the owned engine's fault log/stats into the shared state.
    TakeFaults,
}

/// State shared with the stepper thread. The back buffer lives here; the
/// front buffer lives in [`PipelinedEnv`] and is only touched by the
/// learner, so reads need no lock.
struct PipeState {
    epoch: u64,
    completed: u64,
    cmd: Cmd,
    actions: Vec<u8>,
    /// Time-major `[K × B·A]` plan of an in-flight [`Cmd::StepN`] window.
    plan: Vec<u8>,
    /// Window length of an in-flight [`Cmd::StepN`].
    chunk_len: usize,
    /// Capture mode the caller's trajectory wants.
    capture: ObsCapture,
    back_ts: BatchedTimestep,
    back_obs: ObsBatch,
    /// Back trajectory chunk: the stepper thread swaps its filled window
    /// in, the caller's sync swaps it out — whole-window hand-off with no
    /// copies on the learner side.
    back_traj: TrajectorySlice,
    /// Checkpoint hand-off cell for [`Cmd::Save`]/[`Cmd::Restore`].
    checkpoint: Option<EngineCheckpoint>,
    /// Policy shipped by a [`Cmd::Supervise`] round-trip.
    policy: FaultPolicy,
    /// Fault log copied out by the last [`Cmd::TakeFaults`] round-trip.
    fault_log: Vec<EngineFault>,
    /// Fault stats copied out by the last [`Cmd::TakeFaults`] round-trip.
    fault_stats: FaultStats,
    shutdown: bool,
}

struct Control {
    state: Mutex<PipeState>,
    start: Condvar,
    done: Condvar,
}

/// A batch stepper running on its own thread behind two swap buffers, so
/// environment stepping overlaps the learner's compute. Mirrors the
/// [`BatchStepper`] surface (`step` = `submit` + `sync`) for drop-in use;
/// the pipelined trainers call `submit`/`sync` directly to expose the
/// overlap window.
pub struct PipelinedEnv {
    b: usize,
    /// Agents per slot of the owned engine; action slices and buffer rows
    /// span `b·a` agent-rows.
    a: usize,
    front_ts: BatchedTimestep,
    front_obs: ObsBatch,
    control: Arc<Control>,
    worker: Option<JoinHandle<()>>,
    /// Epoch of the submit we have not yet synced (0 = none in flight).
    in_flight: Option<u64>,
    /// Stall watchdog: how long to wait for a live stepper thread before
    /// panicking with a "stalled at step N" diagnosis.
    watchdog: Duration,
}

impl PipelinedEnv {
    /// Move `env` onto a fresh stepper thread. The front buffer starts as
    /// a copy of the env's construction-time reset state, so `obs()` and
    /// `timestep()` are valid immediately.
    pub fn new(env: Box<dyn BatchStepper + Send>) -> Self {
        let b = env.batch_size();
        let a = env.num_agents();
        let front_ts = env.timestep().clone();
        let front_obs = env.obs().clone();
        let control = Arc::new(Control {
            state: Mutex::new(PipeState {
                epoch: 0,
                completed: 0,
                cmd: Cmd::Step,
                actions: vec![0u8; b * a],
                plan: Vec::new(),
                chunk_len: 0,
                capture: ObsCapture::Final,
                back_ts: front_ts.clone(),
                back_obs: front_obs.clone(),
                back_traj: TrajectorySlice::new(ObsCapture::Final),
                checkpoint: None,
                policy: FaultPolicy::Propagate,
                fault_log: Vec::new(),
                fault_stats: FaultStats::default(),
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let worker = {
            let control = Arc::clone(&control);
            std::thread::spawn(move || stepper_loop(env, control))
        };
        let watchdog = std::env::var("NAVIX_PIPE_WATCHDOG_SECS")
            .ok()
            .and_then(|s| s.trim().parse::<f64>().ok())
            .filter(|&s| s > 0.0)
            .map(Duration::from_secs_f64)
            .unwrap_or(DEFAULT_WATCHDOG);
        PipelinedEnv {
            b,
            a,
            front_ts,
            front_obs,
            control,
            worker: Some(worker),
            in_flight: None,
            watchdog,
        }
    }

    /// Override the stall watchdog (seconds). A sync that waits longer
    /// than this on a *live* stepper thread panics with a "stalled at
    /// step N" diagnosis instead of hanging forever.
    pub fn set_watchdog_secs(&mut self, secs: f64) {
        assert!(secs > 0.0, "watchdog must be positive");
        self.watchdog = Duration::from_secs_f64(secs);
    }

    /// Number of parallel environments.
    pub fn batch_size(&self) -> usize {
        self.b
    }

    /// Number of discrete actions.
    pub fn num_actions(&self) -> usize {
        Action::N
    }

    /// Timestep metadata of the most recent synced step (front buffer).
    pub fn timestep(&self) -> &BatchedTimestep {
        &self.front_ts
    }

    /// Observations of the most recent synced step (front buffer).
    pub fn obs(&self) -> &ObsBatch {
        &self.front_obs
    }

    /// Hand `actions` to the stepper thread and return immediately. The
    /// front buffer stays valid (and untouched) until [`Self::sync`].
    /// Panics if a step is already in flight — the pipeline is depth-1 by
    /// design (one step of lookahead keeps trajectories on-policy).
    pub fn submit(&mut self, actions: &[u8]) {
        debug_assert_eq!(actions.len(), self.b * self.a);
        assert!(self.in_flight.is_none(), "PipelinedEnv::submit with a step already in flight");
        let mut st = lock_recover(&self.control.state);
        st.actions.copy_from_slice(actions);
        st.cmd = Cmd::Step;
        st.epoch += 1;
        self.in_flight = Some(st.epoch);
        self.control.start.notify_one();
    }

    /// Block until the in-flight step finishes, then swap the buffers so
    /// the front holds the new timestep + observations. No-op if nothing
    /// is in flight. If the stepper thread died instead of completing the
    /// epoch — a panic inside `env.step` happens with the mutex released,
    /// so it cannot poison the lock and must be detected by liveness — the
    /// worker's own panic payload is reclaimed from its `JoinHandle` and
    /// re-raised here, so the caller sees the root cause (env id, failing
    /// key, …) rather than a generic "thread died" message.
    pub fn sync(&mut self) {
        let Some(epoch) = self.in_flight.take() else { return };
        let mut st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
        std::mem::swap(&mut self.front_ts, &mut st.back_ts);
        std::mem::swap(&mut self.front_obs, &mut st.back_obs);
    }

    /// Fused K-step window. An [`ActionPlan::Fixed`] plan is shipped to
    /// the stepper thread whole: one submit/notify round-trip covers all K
    /// steps, the owned engine runs its fused `step_n` (so a sharded
    /// engine underneath still gets its one-epoch-per-window path), and
    /// the swap buffers carry the entire trajectory chunk back along with
    /// the final timestep/observation frame. Provider plans keep the
    /// per-step submit → overlap → sync schedule — the provider's
    /// [`crate::batch::ActionProvider::overlap`] work runs while the step
    /// is in flight, exactly the pipelined trainers' overlap window.
    pub fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        let rows = self.b * self.a;
        match plan {
            ActionPlan::Fixed(actions) => {
                assert_eq!(actions.len(), k * rows, "Fixed plan must be [K × B·A]");
                assert!(
                    self.in_flight.is_none(),
                    "PipelinedEnv::step_n with a step already in flight"
                );
                let epoch = {
                    let mut st = lock_recover(&self.control.state);
                    st.plan.resize(k * rows, 0);
                    st.plan.copy_from_slice(actions);
                    st.chunk_len = k;
                    st.capture = traj.capture;
                    st.cmd = Cmd::StepN;
                    st.epoch += 1;
                    self.control.start.notify_one();
                    st.epoch
                };
                let mut st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
                std::mem::swap(traj, &mut st.back_traj);
                std::mem::swap(&mut self.front_ts, &mut st.back_ts);
                std::mem::swap(&mut self.front_obs, &mut st.back_obs);
            }
            ActionPlan::Provider(p) => {
                traj.ensure_like(k, rows, &self.front_obs);
                let mut buf = vec![0u8; rows];
                for t in 0..k {
                    p.actions(t, &self.front_obs, &self.front_ts, &mut buf);
                    self.submit(&buf);
                    // Overlap window: the provider's bookkeeping runs on
                    // step t's snapshot while the workers advance to t+1.
                    p.overlap(t);
                    self.sync();
                    traj.record_row(t, &self.front_ts);
                    if traj.capture == ObsCapture::All {
                        traj.capture_obs_row(t, &self.front_obs);
                    }
                }
            }
        }
    }

    /// Synchronous step: submit + sync (the [`BatchStepper`] contract).
    pub fn step(&mut self, actions: &[u8]) {
        self.submit(actions);
        self.sync();
    }

    /// Reset every environment (fresh episode keys), synchronously.
    pub fn reset_all(&mut self) {
        let epoch = self.control_cmd(Cmd::ResetAll);
        self.in_flight = Some(epoch);
        self.sync();
    }

    /// Publish a control command epoch to the stepper thread.
    fn control_cmd(&mut self, cmd: Cmd) -> u64 {
        assert!(
            self.in_flight.is_none(),
            "PipelinedEnv control command ({cmd:?}) with a step in flight"
        );
        let mut st = lock_recover(&self.control.state);
        st.cmd = cmd;
        st.epoch += 1;
        self.control.start.notify_one();
        st.epoch
    }

    /// Checkpoint the owned engine (round-trips through the stepper
    /// thread, so it can run between any two steps of a rollout).
    pub fn save_checkpoint(&mut self) -> EngineCheckpoint {
        let epoch = self.control_cmd(Cmd::Save);
        let mut st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
        st.checkpoint.take().expect("stepper thread did not produce a checkpoint")
    }

    /// Restore the owned engine from `ck` and refresh the front buffers
    /// with the restored timestep/observations.
    pub fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        lock_recover(&self.control.state).checkpoint = Some(ck.clone());
        let epoch = self.control_cmd(Cmd::Restore);
        let mut st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
        std::mem::swap(&mut self.front_ts, &mut st.back_ts);
        std::mem::swap(&mut self.front_obs, &mut st.back_obs);
    }

    /// Arm fault supervision on the owned engine.
    pub fn supervise(&mut self, policy: FaultPolicy) {
        lock_recover(&self.control.state).policy = policy;
        let epoch = self.control_cmd(Cmd::Supervise);
        let _ = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
    }

    /// The owned engine's fault log (round-trip; see [`EngineFault`]).
    pub fn fault_log(&mut self) -> Vec<EngineFault> {
        let epoch = self.control_cmd(Cmd::TakeFaults);
        let mut st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
        std::mem::take(&mut st.fault_log)
    }

    /// The owned engine's injected/recovered counters (round-trip).
    pub fn fault_stats(&mut self) -> FaultStats {
        let epoch = self.control_cmd(Cmd::TakeFaults);
        let st = wait_completed(&self.control, &mut self.worker, epoch, self.watchdog);
        st.fault_stats
    }

    /// Convenience constructor over the single-threaded engine.
    pub fn over_batched(env: BatchedEnv) -> Self {
        PipelinedEnv::new(Box::new(env))
    }
}

impl Drop for PipelinedEnv {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.control.state);
            st.shutdown = true;
            self.control.start.notify_one();
        }
        if let Some(handle) = self.worker.take() {
            let _ = handle.join();
        }
    }
}

impl BatchStepper for PipelinedEnv {
    fn batch_size(&self) -> usize {
        self.b
    }

    fn num_agents(&self) -> usize {
        self.a
    }

    fn step(&mut self, actions: &[u8]) {
        PipelinedEnv::step(self, actions);
    }

    fn timestep(&self) -> &BatchedTimestep {
        &self.front_ts
    }

    fn obs(&self) -> &ObsBatch {
        &self.front_obs
    }

    fn reset_all(&mut self) {
        PipelinedEnv::reset_all(self);
    }

    fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        PipelinedEnv::step_n(self, plan, k, traj);
    }

    fn save_checkpoint(&mut self) -> EngineCheckpoint {
        PipelinedEnv::save_checkpoint(self)
    }

    fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        PipelinedEnv::restore_checkpoint(self, ck);
    }

    fn supervise(&mut self, policy: FaultPolicy) {
        PipelinedEnv::supervise(self, policy);
    }

    fn fault_log(&mut self) -> Vec<EngineFault> {
        PipelinedEnv::fault_log(self)
    }

    fn fault_stats(&mut self) -> FaultStats {
        PipelinedEnv::fault_stats(self)
    }
}

/// Block until the stepper thread completes `epoch`, returning the state
/// guard for the buffer swaps. If the thread died instead of completing —
/// a panic inside `env.step`/`env.step_n` happens with the mutex released,
/// so it cannot poison the lock and must be detected by liveness — the
/// worker's own panic payload is reclaimed from its `JoinHandle` and
/// re-raised here, so the caller sees the root cause (env id, failing
/// key, …) rather than a generic "thread died" message. A thread that is
/// still *alive* but has not completed within `watchdog` trips a "stalled
/// at step N" panic instead of hanging the caller forever.
fn wait_completed<'c>(
    control: &'c Control,
    worker: &mut Option<JoinHandle<()>>,
    epoch: u64,
    watchdog: Duration,
) -> MutexGuard<'c, PipeState> {
    let deadline = Instant::now() + watchdog;
    let mut st = lock_recover(&control.state);
    while st.completed < epoch {
        let (next, timeout) = control
            .done
            .wait_timeout(st, Duration::from_millis(100))
            .unwrap_or_else(PoisonError::into_inner);
        st = next;
        if !timeout.timed_out() || st.completed >= epoch {
            continue;
        }
        if worker.as_ref().map_or(true, |w| w.is_finished()) {
            drop(st); // release before joining; nothing else holds it
            match worker.take().map(JoinHandle::join) {
                Some(Err(payload)) => std::panic::resume_unwind(payload),
                _ => panic!(
                    "PipelinedEnv stepper thread exited without completing \
                     epoch {epoch} (and without panicking)"
                ),
            }
        }
        if Instant::now() >= deadline {
            drop(st);
            panic!(
                "PipelinedEnv stepper thread stalled at step {epoch}: no completion \
                 within {watchdog:?} (thread alive but not progressing; raise the \
                 limit via set_watchdog_secs or NAVIX_PIPE_WATCHDOG_SECS if steps \
                 legitimately take this long)"
            );
        }
    }
    st
}

/// Stepper-thread body: wait for an epoch, copy the actions (or the whole
/// fused plan) out, step the owned engine (lock released — this is the
/// long pole that overlaps the learner), then publish the results into
/// the back buffers.
fn stepper_loop(mut env: Box<dyn BatchStepper + Send>, control: Arc<Control>) {
    let mut seen = 0u64;
    let mut actions = vec![0u8; env.policy_rows()];
    let mut plan: Vec<u8> = Vec::new();
    // Local trajectory chunk: filled while the lock is released, then
    // swapped into the back buffer whole.
    let mut traj = TrajectorySlice::new(ObsCapture::Final);
    loop {
        let (cmd, k) = {
            let mut st = lock_recover(&control.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    break;
                }
                st = control.start.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            seen = st.epoch;
            match st.cmd {
                Cmd::StepN => {
                    plan.resize(st.plan.len(), 0);
                    plan.copy_from_slice(&st.plan);
                    traj.capture = st.capture;
                    (Cmd::StepN, st.chunk_len)
                }
                Cmd::Step => {
                    actions.copy_from_slice(&st.actions);
                    (Cmd::Step, 0)
                }
                cmd => (cmd, 0),
            }
        };
        match cmd {
            Cmd::Step => env.step(&actions),
            Cmd::StepN => env.step_n(ActionPlan::Fixed(&plan), k, &mut traj),
            Cmd::ResetAll => env.reset_all(),
            // Control commands run their engine work under the lock below
            // — they are rare and cheap, and the hand-off cell lives in
            // the shared state.
            Cmd::Save | Cmd::Restore | Cmd::Supervise | Cmd::TakeFaults => {}
        }
        let mut st = lock_recover(&control.state);
        match cmd {
            Cmd::Save => st.checkpoint = Some(env.save_checkpoint()),
            Cmd::Restore => {
                let ck = st.checkpoint.take().expect("Cmd::Restore without a checkpoint");
                env.restore_checkpoint(&ck);
            }
            Cmd::Supervise => {
                let policy = st.policy;
                env.supervise(policy);
            }
            Cmd::TakeFaults => {
                st.fault_log = env.fault_log();
                st.fault_stats = env.fault_stats();
            }
            _ => {}
        }
        let ts = env.timestep();
        st.back_ts.t.copy_from_slice(&ts.t);
        st.back_ts.action.copy_from_slice(&ts.action);
        st.back_ts.reward.copy_from_slice(&ts.reward);
        st.back_ts.discount.copy_from_slice(&ts.discount);
        st.back_ts.step_type.copy_from_slice(&ts.step_type);
        st.back_ts.episodic_return.copy_from_slice(&ts.episodic_return);
        st.back_obs.copy_from(env.obs());
        if cmd == Cmd::StepN {
            std::mem::swap(&mut st.back_traj, &mut traj);
        }
        st.completed = seen;
        control.done.notify_one();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::timestep::StepType;
    use crate::envs::registry::make;
    use crate::rng::{Key, Rng};

    fn pipelined(id: &str, b: usize) -> PipelinedEnv {
        PipelinedEnv::over_batched(BatchedEnv::new(make(id).unwrap(), b, Key::new(0)))
    }

    #[test]
    fn construction_exposes_reset_state() {
        let p = pipelined("Navix-Empty-8x8-v0", 4);
        assert_eq!(p.batch_size(), 4);
        assert!(p.timestep().step_type.iter().all(|&s| s == StepType::First));
        assert!(p.obs().env_i32(4, 0).iter().any(|&x| x != 0));
    }

    #[test]
    fn matches_batched_env_bitwise_on_random_walk() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), 6, Key::new(3));
        let mut piped = PipelinedEnv::over_batched(BatchedEnv::new(cfg, 6, Key::new(3)));
        let mut rng = Rng::new(11);
        for _ in 0..150 {
            let actions: Vec<u8> = (0..6).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            piped.step(&actions);
            assert_eq!(single.timestep.reward, piped.timestep().reward);
            assert_eq!(single.timestep.step_type, piped.timestep().step_type);
            for i in 0..6 {
                assert_eq!(single.obs.env_i32(6, i), piped.obs().env_i32(6, i));
            }
        }
    }

    #[test]
    fn front_buffer_is_stable_while_a_step_is_in_flight() {
        let mut p = pipelined("Navix-Empty-5x5-v0", 2);
        let before: Vec<i32> = p.obs().env_i32(2, 0).to_vec();
        p.submit(&[Action::Forward as u8, Action::Forward as u8]);
        // The overlap window: the pre-step observations must stay intact.
        assert_eq!(p.obs().env_i32(2, 0), &before[..]);
        p.sync();
        assert_eq!(p.timestep().t, vec![1, 1]);
    }

    #[test]
    fn reset_all_round_trips() {
        let mut p = pipelined("Navix-Empty-5x5-v0", 3);
        p.step(&[0, 1, 2]);
        p.reset_all();
        assert!(p.timestep().step_type.iter().all(|&s| s == StepType::First));
        assert_eq!(p.timestep().t, vec![0, 0, 0]);
    }

    #[test]
    fn drop_joins_the_stepper_thread() {
        let p = pipelined("Navix-Empty-5x5-v0", 2);
        drop(p); // must not hang or leak the thread
    }

    #[test]
    fn fused_window_round_trips_the_trajectory_chunk() {
        // One StepN round-trip vs K submit/sync pairs: the swapped-in
        // chunk and the front buffers must match the per-step pipeline
        // exactly (the engine matrix lives in tests/test_scan_parity.rs).
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut fused = PipelinedEnv::over_batched(BatchedEnv::new(cfg.clone(), 6, Key::new(3)));
        let mut stepwise =
            PipelinedEnv::over_batched(BatchedEnv::new(cfg, 6, Key::new(3)));
        let mut rng = Rng::new(11);
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        for _ in 0..3 {
            let plan: Vec<u8> = (0..10 * 6).map(|_| rng.below(7) as u8).collect();
            fused.step_n(ActionPlan::Fixed(&plan), 10, &mut traj);
            for t in 0..10 {
                stepwise.step(&plan[t * 6..(t + 1) * 6]);
                assert_eq!(traj.reward_row(t), &stepwise.timestep().reward[..]);
                assert_eq!(traj.step_type_row(t), &stepwise.timestep().step_type[..]);
                for i in 0..6 {
                    assert_eq!(traj.obs_i32(t, i), stepwise.obs().env_i32(6, i));
                }
            }
            assert_eq!(fused.timestep().t, stepwise.timestep().t);
            for i in 0..6 {
                assert_eq!(fused.obs().env_i32(6, i), stepwise.obs().env_i32(6, i));
            }
        }
    }

    /// A stepper that dies mid-step with a distinctive payload.
    struct Exploding {
        ts: BatchedTimestep,
        obs: ObsBatch,
    }

    impl BatchStepper for Exploding {
        fn batch_size(&self) -> usize {
            1
        }
        fn step(&mut self, _actions: &[u8]) {
            panic!("layout generation failed for Navix-Exploding-v0 (root key 0xDEAD)");
        }
        fn timestep(&self) -> &BatchedTimestep {
            &self.ts
        }
        fn obs(&self) -> &ObsBatch {
            &self.obs
        }
        fn reset_all(&mut self) {}
    }

    /// A stepper whose first step blocks long enough to trip a short
    /// watchdog (the thread stays alive — the stall path, not the death
    /// path).
    struct Stalling {
        ts: BatchedTimestep,
        obs: ObsBatch,
    }

    impl BatchStepper for Stalling {
        fn batch_size(&self) -> usize {
            1
        }
        fn step(&mut self, _actions: &[u8]) {
            std::thread::sleep(Duration::from_millis(800));
        }
        fn timestep(&self) -> &BatchedTimestep {
            &self.ts
        }
        fn obs(&self) -> &ObsBatch {
            &self.obs
        }
        fn reset_all(&mut self) {}
    }

    #[test]
    fn watchdog_reports_a_stalled_stepper_thread() {
        let env = Stalling { ts: BatchedTimestep::first(1), obs: ObsBatch::alloc(false, 1, 4) };
        let mut p = PipelinedEnv::new(Box::new(env));
        p.set_watchdog_secs(0.05);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.step(&[0])))
            .expect_err("the watchdog must trip");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(
            msg.contains("stalled at step 1"),
            "watchdog must name the stalled step, got: {msg:?}"
        );
        // Drop still shuts the (slow, but alive) thread down cleanly.
    }

    #[test]
    fn checkpoint_round_trips_through_the_stepper_thread() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut p = PipelinedEnv::over_batched(BatchedEnv::new(cfg, 5, Key::new(9)));
        let mut rng = Rng::new(31);
        let step_batch = |p: &mut PipelinedEnv, rng: &mut Rng| {
            let actions: Vec<u8> = (0..5).map(|_| rng.below(7) as u8).collect();
            p.step(&actions);
        };
        for _ in 0..25 {
            step_batch(&mut p, &mut rng);
        }
        let ck = p.save_checkpoint();
        let mut replay = Rng::new(77);
        let mut seen: Vec<(Vec<f32>, Vec<i32>)> = Vec::new();
        for _ in 0..25 {
            step_batch(&mut p, &mut replay);
            seen.push((p.timestep().reward.clone(), p.obs().env_i32(5, 0).to_vec()));
        }
        p.restore_checkpoint(&ck);
        let mut replay = Rng::new(77);
        for expect in &seen {
            step_batch(&mut p, &mut replay);
            assert_eq!(&p.timestep().reward, &expect.0);
            assert_eq!(p.obs().env_i32(5, 0), &expect.1[..]);
        }
    }

    #[test]
    fn stepper_panic_payload_reaches_the_caller() {
        // The satellite fix for the generic "stepper thread died mid-step"
        // panic: the worker's own payload (env id, root key, …) must be
        // re-raised on the caller thread, not replaced.
        let env = Exploding { ts: BatchedTimestep::first(1), obs: ObsBatch::alloc(false, 1, 4) };
        let mut p = PipelinedEnv::new(Box::new(env));
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| p.step(&[0])))
            .expect_err("the worker panic must propagate");
        let msg = err
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| err.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(
            msg.contains("Navix-Exploding-v0") && msg.contains("0xDEAD"),
            "caller must see the worker's own payload, got: {msg:?}"
        );
    }
}
