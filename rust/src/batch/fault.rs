//! Fault supervision: structured panics, recovery policies, and the
//! poison-tolerant lock helper the multi-threaded engines share.
//!
//! A panic inside a step body (a layout-generator bug, a corrupted action,
//! an injected chaos fault) is caught at the slot boundary, converted into
//! an [`EngineFault`] carrying the shard/slot/env/step coordinates plus the
//! original panic payload, and handled per the configured [`FaultPolicy`]:
//!
//! - [`FaultPolicy::Propagate`] — record the fault, then re-raise the
//!   original payload. The caller still sees the real panic, but the fault
//!   log pinpoints where it happened (no more anonymous deadlocks).
//! - [`FaultPolicy::QuarantineSlot`] — roll the faulting slot back to its
//!   pre-step [`SlotCheckpoint`] (or, for repeated/terminal faults, replace
//!   the episode via the successor-episode-key reset path, bounded by
//!   [`Supervisor::max_retries`]), latch `slot_quarantined` on the slot's
//!   agent rows and zero their rewards. Every other slot steps
//!   bitwise-unchanged.
//! - [`FaultPolicy::RestartWorker`] — let the panic kill the worker thread;
//!   the engine's epoch watchdog reaps the corpse, repairs the torn slot
//!   from its pre-step snapshot, finishes the dead worker's remaining work
//!   inline and respawns a replacement.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::core::snapshot::SlotCheckpoint;

/// What to do when a step body panics. See the module docs for semantics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Record the fault, then re-raise the original panic payload.
    Propagate,
    /// Restore the faulting slot (snapshot first, then successor-key
    /// resets) and keep going; all other slots are untouched.
    QuarantineSlot,
    /// Let the panic kill the owning worker thread; the engine reaps,
    /// repairs and respawns. Only meaningful on `ShardedEnv` — the
    /// single-threaded engine treats it like snapshot-armed `Propagate`.
    RestartWorker,
}

/// A structured record of one caught panic.
#[derive(Clone, Debug)]
pub struct EngineFault {
    /// Shard that hosted the fault (`None` outside `ShardedEnv`).
    pub shard: Option<usize>,
    /// Global slot index (`None` when the panic tore down a whole worker
    /// before the slot could be identified).
    pub slot: Option<usize>,
    /// Environment id of the faulting engine.
    pub env_id: String,
    /// Engine step counter at the time of the fault.
    pub step: u64,
    /// The original panic payload, rendered to a string.
    pub payload: String,
}

impl EngineFault {
    /// Was this fault injected by the chaos harness (payload convention:
    /// every injected panic message starts with `"chaos:"`)?
    pub fn is_chaos(&self) -> bool {
        self.payload.starts_with("chaos:")
    }
}

impl std::fmt::Display for EngineFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "engine fault in {}", self.env_id)?;
        if let Some(s) = self.shard {
            write!(f, " shard {s}")?;
        }
        if let Some(i) = self.slot {
            write!(f, " slot {i}")?;
        }
        write!(f, " at step {}: {}", self.step, self.payload)
    }
}

/// Injected/recovered counters surfaced into the `BENCH_*.json` meta block
/// so the nightly trend workflow can track recovery overhead.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Faults fired by the chaos harness ([`EngineFault::is_chaos`]).
    pub injected: u64,
    /// Faults recovered without surfacing to the caller (quarantines,
    /// worker restarts).
    pub recovered: u64,
}

impl FaultStats {
    pub fn merge(&mut self, other: FaultStats) {
        self.injected += other.injected;
        self.recovered += other.recovered;
    }
}

/// Render a caught panic payload (`Box<dyn Any>`) to a string: `&str` and
/// `String` payloads verbatim, anything else a placeholder.
pub fn payload_to_string(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// A panic inside a lock scope poisons the `Mutex`; the stock
/// `lock().unwrap()` then converts every *subsequent* access into a
/// secondary `PoisonError` panic that hides the original fault. The
/// supervision layer catches the original panic at the slot boundary and
/// keeps slot state transactional via snapshots, so the data under a
/// poisoned lock is either untouched or about to be restored — recovering
/// the guard is safe and keeps the first fault the only story.
pub fn lock_recover<T: ?Sized>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Run `f`, catching an unwind and rendering its payload. The
/// `AssertUnwindSafe` is justified the same way `lock_recover` is: the
/// supervision layer restores any slot a caught panic may have torn.
pub fn catch_fault<R>(f: impl FnOnce() -> R) -> Result<R, Box<dyn Any + Send>> {
    catch_unwind(AssertUnwindSafe(f))
}

/// Per-engine supervision state: the policy, the fault log, and the
/// per-slot pre-step snapshots + bookkeeping the recovery paths use.
#[derive(Debug)]
pub struct Supervisor {
    pub policy: FaultPolicy,
    /// Bound on consecutive successor-key reset attempts while
    /// quarantining one slot (the escalation ladder: snapshot restore
    /// first, then up to `max_retries` fresh episodes, then re-raise).
    pub max_retries: u32,
    /// Every fault seen, in order.
    pub faults: Vec<EngineFault>,
    /// Faults recovered in-place (quarantine restores/resets, torn-slot
    /// repairs).
    pub recovered: u64,
    /// Pre-step checkpoint per slot, stamped with the `step_count` it was
    /// taken at (a repair must not restore a snapshot from an older step).
    pub pre_step: Vec<Option<(u64, SlotCheckpoint)>>,
    /// Last step each slot *completed* (`stamp[i] == step_count` ⇔ slot
    /// `i` finished the current step) — the torn-slot repair ledger.
    pub stamp: Vec<u64>,
    /// Consecutive faults per slot (reset to 0 by a clean step).
    pub consecutive: Vec<u32>,
}

impl Supervisor {
    pub fn new(policy: FaultPolicy, b: usize) -> Supervisor {
        Supervisor {
            policy,
            max_retries: 3,
            faults: Vec::new(),
            recovered: 0,
            pre_step: vec![None; b],
            stamp: vec![0; b],
            consecutive: vec![0; b],
        }
    }

    /// Does this policy keep pre-step snapshots? (`Propagate` re-raises,
    /// so paying the snapshot copy would buy nothing.)
    pub fn snapshotting(&self) -> bool {
        self.policy != FaultPolicy::Propagate
    }

    /// Does this policy catch panics at the slot boundary?
    /// (`RestartWorker` deliberately lets them unwind into the worker.)
    pub fn catching(&self) -> bool {
        self.policy != FaultPolicy::RestartWorker
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = std::sync::Arc::new(Mutex::new(7u32));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        assert_eq!(*lock_recover(&m), 7, "guard recovered, data intact");
    }

    #[test]
    fn payloads_render_and_chaos_faults_are_tagged() {
        let err = catch_fault(|| panic!("chaos: injected panic in slot 3")).unwrap_err();
        let fault = EngineFault {
            shard: Some(1),
            slot: Some(3),
            env_id: "Navix-Empty-5x5-v0".into(),
            step: 17,
            payload: payload_to_string(&*err),
        };
        assert!(fault.is_chaos());
        let msg = format!("{fault}");
        assert!(msg.contains("shard 1") && msg.contains("slot 3") && msg.contains("step 17"));
        let owned = catch_fault(|| panic!("{}", String::from("boom"))).unwrap_err();
        assert_eq!(payload_to_string(&*owned), "boom");
    }

    #[test]
    fn supervisor_policy_switches() {
        assert!(Supervisor::new(FaultPolicy::QuarantineSlot, 2).snapshotting());
        assert!(Supervisor::new(FaultPolicy::QuarantineSlot, 2).catching());
        assert!(!Supervisor::new(FaultPolicy::Propagate, 2).snapshotting());
        assert!(!Supervisor::new(FaultPolicy::RestartWorker, 2).catching());
        assert!(Supervisor::new(FaultPolicy::RestartWorker, 2).snapshotting());
    }
}
