//! `ShardedEnv` — the multi-core batch stepper (the `jax.pmap` analog).
//!
//! [`BatchedEnv`] amortises one dispatch over `B` contiguous state slots
//! (the paper's `vmap` analog). This module adds the *device axis* from the
//! paper's `pmap` benchmarks (§4.2) and the large-batch simulation design of
//! Shacklett et al.: the struct-of-arrays batch is split into `S`
//! **contiguous shards**, each a [`BatchedEnv`] over its global index range,
//! stepped by a **fixed pool** of worker threads.
//!
//! The pool is persistent: workers are spawned once at construction and
//! synchronise with the caller on an epoch counter + two condvars. The hot
//! path performs **no allocation and no channel traffic** — actions are
//! scattered into preallocated per-shard buffers, each worker steps its
//! shards in place, and the results are gathered into contiguous
//! timestep/observation mirrors with one `memcpy` per field per shard.
//! Per-shard busy time is accumulated for the load statistics the
//! `fig5_sharded` bench reports. Rgb shards share one process-wide
//! [`SpriteSheet`](crate::systems::sprites::SpriteSheet) (`Arc` behind a
//! `OnceLock`), so sharded rgb runs no longer pay per-shard sheet
//! construction or memory.
//!
//! ## Fault tolerance
//!
//! Every lock acquisition goes through [`lock_recover`], so a panic can
//! poison a `Mutex` without turning every later access into a secondary
//! `PoisonError` panic. Workers execute each shard command behind
//! `catch_unwind` (except under [`FaultPolicy::RestartWorker`], which
//! *wants* the panic to kill the worker): a caught panic is recorded as a
//! structured [`EngineFault`] and the epoch still completes, so
//! [`ShardedEnv::run_epoch`] re-raises a diagnosable fault instead of
//! deadlocking on a done-count that can never be reached. A worker that
//! dies anyway is detected by the epoch watchdog (`wait_timeout` +
//! `JoinHandle::is_finished`), its panic payload joined and re-raised as an
//! [`EngineFault`] — and under `RestartWorker` the dead worker's shards are
//! repaired inline (torn slots roll back to their pre-step snapshots via
//! [`BatchedEnv::recover_interrupted_step`]) and a replacement worker is
//! spawned. Under [`FaultPolicy::QuarantineSlot`] the inner engines absorb
//! faults at the slot boundary, so the pool never even sees them.
//!
//! ## Determinism
//!
//! Stepping is **bit-identical** to the single-threaded [`BatchedEnv`] for
//! any shard count: every per-env RNG stream is a pure function of
//! `(root key, global env index, per-env episode count)` — never of the
//! shard or worker that executes the env (see [`BatchedEnv::with_offset`]
//! and the module docs of [`crate::batch`]). The integration test
//! `rust/tests/test_sharded_determinism.rs` pins this for `S ∈ {1, 2, 7}`.

use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::batch::fault::{catch_fault, lock_recover, payload_to_string};
use crate::batch::{
    ActionPlan, BatchStepper, BatchedEnv, EngineFault, FaultPolicy, FaultStats, ObsBatch,
    ObsCapture, ObsData, TrajectorySlice,
};
use crate::bench_harness::chaos::ChaosInjector;
use crate::core::actions::Action;
use crate::core::mission::MISSION_TOKENS;
use crate::core::snapshot::EngineCheckpoint;
use crate::core::timestep::BatchedTimestep;
use crate::envs::EnvConfig;
use crate::rng::Key;

/// One shard: a contiguous env range plus its scatter/timing buffers.
struct Shard {
    env: BatchedEnv,
    /// Per-step action slice for this shard (scattered by the caller).
    actions: Vec<u8>,
    /// Time-major `[K × shard_b]` action plan for a fused window
    /// (scattered by the caller before a [`Cmd::StepN`] epoch).
    plan: Vec<u8>,
    /// This shard's trajectory chunk, filled in the worker during a fused
    /// window — shard state stays hot in the worker for all K steps.
    traj: TrajectorySlice,
    /// Cumulative busy wall-time spent stepping/resetting this shard.
    busy_secs: f64,
    /// Last epoch whose command finished on this shard — the repair path's
    /// ledger for telling a completed shard from one a dying worker never
    /// reached (or tore mid-command).
    done_epoch: u64,
}

/// What an epoch asks the workers to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cmd {
    Step,
    /// Fused window: run the scattered K-step plan through the shard
    /// engine's `step_n` — one epoch/condvar round-trip per K steps.
    StepN(usize),
    ResetAll,
}

struct PoolState {
    epoch: u64,
    cmd: Cmd,
    done_workers: usize,
    shutdown: bool,
    /// Active fault policy (workers read it per epoch).
    policy: FaultPolicy,
    /// Faults caught during the current epoch (drained by `run_epoch`).
    epoch_faults: Vec<EngineFault>,
    /// Every pool-level fault ever seen (worker catches + dead workers).
    fault_history: Vec<EngineFault>,
    /// Workers reaped and respawned under `RestartWorker`.
    workers_restarted: u64,
}

struct Control {
    state: Mutex<PoolState>,
    start: Condvar,
    done: Condvar,
}

/// `B` parallel environments split into `S` contiguous shards, stepped by a
/// fixed multi-core worker pool. Mirrors [`BatchedEnv`]'s public surface
/// (`timestep`, `obs`, `step`, `reset_all`, `rollout_random`) so callers
/// can switch engines without code changes (or use [`BatchStepper`]).
pub struct ShardedEnv {
    pub cfg: EnvConfig,
    pub b: usize,
    /// Agents per slot (`cfg.n_agents`); every per-row buffer and action
    /// slice spans `b·a` agent-rows, sharded as `[lo·a, hi·a)` segments.
    pub a: usize,
    pub num_shards: usize,
    pub num_threads: usize,
    /// Gathered timestep mirror (same layout as [`BatchedEnv::timestep`]).
    pub timestep: BatchedTimestep,
    /// Gathered observation mirror (same layout as [`BatchedEnv::obs`]).
    pub obs: ObsBatch,
    bounds: Vec<(usize, usize)>,
    shards: Vec<Arc<Mutex<Shard>>>,
    control: Arc<Control>,
    workers: Vec<JoinHandle<()>>,
    obs_stride: usize,
    /// Cumulative engine steps dispatched (1 per `Step` epoch, K per fused
    /// window) — what every shard engine's `step_count` should read after
    /// a completed epoch; the repair path uses it to tell "never started"
    /// from "torn mid-step".
    steps_dispatched: u64,
}

impl ShardedEnv {
    /// Allocate `b` environments split into `num_shards` contiguous shards
    /// stepped by `num_threads` persistent workers. `0` for either means
    /// "use the host's available parallelism"; both are clamped so no shard
    /// is empty and no worker is idle by construction.
    pub fn new(
        cfg: EnvConfig,
        b: usize,
        num_shards: usize,
        num_threads: usize,
        key: Key,
    ) -> Self {
        let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let num_shards = if num_shards == 0 { auto } else { num_shards }.clamp(1, b.max(1));
        let num_threads = if num_threads == 0 { auto } else { num_threads }.clamp(1, num_shards);

        let a = cfg.n_agents.max(1);
        let obs_stride = cfg.obs.len(cfg.h, cfg.w);
        let mut bounds = Vec::with_capacity(num_shards);
        let mut shards = Vec::with_capacity(num_shards);
        for s in 0..num_shards {
            let lo = s * b / num_shards;
            let hi = (s + 1) * b / num_shards;
            bounds.push((lo, hi));
            let env = BatchedEnv::with_offset(cfg.clone(), hi - lo, key, lo);
            shards.push(Arc::new(Mutex::new(Shard {
                env,
                actions: vec![0u8; (hi - lo) * a],
                plan: Vec::new(),
                traj: TrajectorySlice::new(ObsCapture::Final),
                busy_secs: 0.0,
                done_epoch: 0,
            })));
        }

        let obs = ObsBatch::alloc(cfg.obs.kind.is_rgb(), b * a, obs_stride);

        let control = Arc::new(Control {
            state: Mutex::new(PoolState {
                epoch: 0,
                cmd: Cmd::Step,
                done_workers: 0,
                shutdown: false,
                policy: FaultPolicy::Propagate,
                epoch_faults: Vec::new(),
                fault_history: Vec::new(),
                workers_restarted: 0,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });

        // Fixed shard ownership, round-robin: worker w steps shards
        // w, w+T, w+2T, … — contiguous global ranges stay cache-friendly
        // within a shard while load spreads across workers.
        let workers = (0..num_threads)
            .map(|w| {
                let mine = owned_shards(&shards, w, num_threads);
                spawn_worker(mine, Arc::clone(&control), num_threads, 0)
            })
            .collect();

        let mut env = ShardedEnv {
            cfg,
            b,
            a,
            num_shards,
            num_threads,
            timestep: BatchedTimestep::first(b * a),
            obs,
            bounds,
            shards,
            control,
            workers,
            obs_stride,
            steps_dispatched: 0,
        };
        env.gather(); // expose the construction-time reset observations
        env
    }

    /// Number of discrete actions.
    pub fn num_actions(&self) -> usize {
        Action::N
    }

    /// Step all environments with `actions` (the flat `[B × A]` action
    /// matrix — one per agent-row, values 0..7). Slots whose previous
    /// timestep was terminal autoreset instead.
    /// Bit-identical to [`BatchedEnv::step`] on the same action sequence.
    pub fn step(&mut self, actions: &[u8]) {
        let a = self.a;
        debug_assert_eq!(actions.len(), self.b * a);
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            lock_recover(shard).actions.copy_from_slice(&actions[lo * a..hi * a]);
        }
        self.steps_dispatched += 1;
        self.run_epoch(Cmd::Step);
        self.gather();
    }

    /// Reset every environment (fresh episode keys), in parallel.
    pub fn reset_all(&mut self) {
        self.run_epoch(Cmd::ResetAll);
        self.gather();
    }

    /// Fused K-step window. With an [`ActionPlan::Fixed`] plan this is the
    /// scan-mode payoff for the device axis: the whole time-major plan is
    /// scattered up front, **one** epoch/condvar round-trip covers all K
    /// steps (vs. K for the per-step path), each worker runs its shard's
    /// fused `step_n` with the shard state hot in cache, and the caller
    /// gathers the trajectory chunks afterwards. Provider plans need the
    /// full gathered observation batch before every step, so they fall
    /// back to one epoch per step (still recording into `traj`).
    /// Under [`FaultPolicy::RestartWorker`] Fixed plans also run one epoch
    /// per step — worker-death repair is step-granular, so the fused
    /// window's latency win is traded for restartability.
    /// Bit-identical to `k` calls of [`ShardedEnv::step`] either way.
    pub fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        let a = self.a;
        let rows = self.b * a;
        traj.ensure_like(k, rows, &self.obs);
        match plan {
            ActionPlan::Fixed(actions) => {
                assert_eq!(actions.len(), k * rows, "Fixed plan must be [K × B·A]");
                if lock_recover(&self.control.state).policy == FaultPolicy::RestartWorker {
                    for t in 0..k {
                        self.step(&actions[t * rows..(t + 1) * rows]);
                        traj.record_row(t, &self.timestep);
                        if traj.capture == ObsCapture::All {
                            traj.capture_obs_row(t, &self.obs);
                        }
                    }
                    return;
                }
                // Scatter: per-shard time-major plan chunks, capture mode
                // forwarded so workers allocate nothing mid-epoch.
                for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
                    let mut sh = lock_recover(shard);
                    let bs = (hi - lo) * a;
                    sh.plan.resize(k * bs, 0);
                    for t in 0..k {
                        sh.plan[t * bs..(t + 1) * bs]
                            .copy_from_slice(&actions[t * rows + lo * a..t * rows + hi * a]);
                    }
                    sh.traj.capture = traj.capture;
                }
                self.steps_dispatched += k as u64;
                self.run_epoch(Cmd::StepN(k));
                self.gather_traj(k, traj);
                self.gather();
            }
            ActionPlan::Provider(p) => {
                let mut buf = vec![0u8; rows];
                for t in 0..k {
                    p.actions(t, &self.obs, &self.timestep, &mut buf);
                    p.overlap(t);
                    self.step(&buf);
                    traj.record_row(t, &self.timestep);
                    if traj.capture == ObsCapture::All {
                        traj.capture_obs_row(t, &self.obs);
                    }
                }
            }
        }
    }

    /// Copy every shard's fused-window trajectory chunk into the global
    /// time-major slice (row segment `[t·B + lo, t·B + hi)` per shard per
    /// step — one `memcpy` per field per row segment).
    fn gather_traj(&self, k: usize, traj: &mut TrajectorySlice) {
        let a = self.a;
        let rows = self.b * a;
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            let sh = lock_recover(shard);
            let (lo, hi) = (lo * a, hi * a);
            let bs = hi - lo;
            for t in 0..k {
                let (g, s) = (t * rows, t * bs);
                traj.t[g + lo..g + hi].copy_from_slice(&sh.traj.t[s..s + bs]);
                traj.action[g + lo..g + hi].copy_from_slice(&sh.traj.action[s..s + bs]);
                traj.reward[g + lo..g + hi].copy_from_slice(&sh.traj.reward[s..s + bs]);
                traj.discount[g + lo..g + hi].copy_from_slice(&sh.traj.discount[s..s + bs]);
                traj.step_type[g + lo..g + hi]
                    .copy_from_slice(&sh.traj.step_type[s..s + bs]);
                traj.episodic_return[g + lo..g + hi]
                    .copy_from_slice(&sh.traj.episodic_return[s..s + bs]);
            }
            if traj.capture == ObsCapture::All {
                let os = self.obs_stride;
                for t in 0..k {
                    let (g, s) = (t * rows, t * bs);
                    match (&mut traj.obs, &sh.traj.obs) {
                        (ObsData::I32(dst), ObsData::I32(src)) => {
                            dst[(g + lo) * os..(g + hi) * os]
                                .copy_from_slice(&src[s * os..(s + bs) * os]);
                        }
                        (ObsData::U8(dst), ObsData::U8(src)) => {
                            dst[(g + lo) * os..(g + hi) * os]
                                .copy_from_slice(&src[s * os..(s + bs) * os]);
                        }
                        _ => unreachable!("shard trajectory obs dtype diverged"),
                    }
                    traj.mission[(g + lo) * MISSION_TOKENS..(g + hi) * MISSION_TOKENS]
                        .copy_from_slice(
                            &sh.traj.mission[s * MISSION_TOKENS..(s + bs) * MISSION_TOKENS],
                        );
                }
            }
        }
    }

    /// Convenience: run `steps` lockstep iterations with uniformly random
    /// actions — the same action stream [`BatchedEnv::rollout_random`]
    /// draws, so throughput comparisons execute identical work. Returns
    /// total env-steps (`b × steps`).
    pub fn rollout_random(&mut self, steps: usize, seed: u64) -> usize {
        let mut rng = crate::rng::Rng::new(seed);
        let mut actions = vec![0u8; self.b * self.a];
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = rng.below(Action::N as u32) as u8;
            }
            self.step(&actions);
        }
        steps * self.b
    }

    /// Cumulative per-shard busy seconds since construction (the fig5
    /// sharded bench reports max/mean as the load-imbalance ratio).
    pub fn shard_busy_secs(&self) -> Vec<f64> {
        self.shards.iter().map(|s| lock_recover(s).busy_secs).collect()
    }

    /// Global `[lo, hi)` env ranges of each shard.
    pub fn shard_bounds(&self) -> &[(usize, usize)] {
        &self.bounds
    }

    /// Inspect one shard's engine under its lock (debugging/tests).
    pub fn with_shard<R>(&self, s: usize, f: impl FnOnce(&BatchedEnv) -> R) -> R {
        let shard = lock_recover(&self.shards[s]);
        f(&shard.env)
    }

    /// Arm fault supervision: the pool records `policy`, and every shard
    /// engine is supervised with it (so faults are caught — or, under
    /// [`FaultPolicy::RestartWorker`], snapshotted for repair — at the
    /// slot boundary).
    pub fn supervise(&mut self, policy: FaultPolicy) {
        lock_recover(&self.control.state).policy = policy;
        for shard in &self.shards {
            lock_recover(shard).env.supervise(policy);
        }
    }

    /// Arm the same chaos injector on every shard engine. Specs address
    /// slots globally, so exactly the shard owning a spec's slot fires it.
    pub fn arm_chaos(&mut self, injector: ChaosInjector) {
        for shard in &self.shards {
            lock_recover(shard).env.arm_chaos(injector.clone());
        }
    }

    /// Every fault seen so far: pool-level records (worker catches, dead
    /// workers) followed by each shard engine's own log.
    pub fn fault_log(&self) -> Vec<EngineFault> {
        let mut log = lock_recover(&self.control.state).fault_history.clone();
        for shard in &self.shards {
            log.extend(lock_recover(shard).env.fault_log());
        }
        log
    }

    /// Injected/recovered counters summed over shards, plus one recovery
    /// per restarted worker.
    pub fn fault_stats(&self) -> FaultStats {
        let mut stats = FaultStats::default();
        for shard in &self.shards {
            stats.merge(lock_recover(shard).env.fault_stats());
        }
        stats.recovered += lock_recover(&self.control.state).workers_restarted;
        stats
    }

    /// Checkpoint all `B` slots (global order), the RNG identity and the
    /// step counter.
    pub fn save_checkpoint(&self) -> EngineCheckpoint {
        let mut slots = Vec::with_capacity(self.b);
        let mut root_key = 0;
        let mut step_count = 0;
        for shard in &self.shards {
            let sh = lock_recover(shard);
            let ck = sh.env.save_checkpoint();
            root_key = ck.root_key;
            step_count = ck.step_count;
            slots.extend(ck.slots);
        }
        EngineCheckpoint { b: self.b, a: self.a, root_key, step_count, slots }
    }

    /// Restore a checkpoint taken by any engine of the same configuration
    /// (shard layout does not matter — slots are global).
    pub fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        assert_eq!((ck.b, ck.a), (self.b, self.a), "checkpoint shape mismatch");
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            let mut sh = lock_recover(shard);
            let sub = EngineCheckpoint {
                b: hi - lo,
                a: ck.a,
                root_key: ck.root_key,
                step_count: ck.step_count,
                slots: ck.slots[lo..hi].to_vec(),
            };
            sh.env.restore_checkpoint(&sub);
        }
        self.steps_dispatched = ck.step_count;
        self.gather();
    }

    /// Publish one epoch of work and block until every worker finished it.
    /// The epoch counter (not the notification) is the wait condition, so
    /// wakeups can never be missed; a `wait_timeout` watchdog scans for
    /// dead workers, so a dying worker yields a diagnosable
    /// [`EngineFault`] (or, under [`FaultPolicy::RestartWorker`], an
    /// inline repair + respawn) instead of a done-count that never
    /// arrives.
    fn run_epoch(&mut self, cmd: Cmd) {
        let epoch = {
            let mut st = lock_recover(&self.control.state);
            st.cmd = cmd;
            st.done_workers = 0;
            st.epoch += 1;
            st.epoch_faults.clear();
            self.control.start.notify_all();
            st.epoch
        };
        let mut st = lock_recover(&self.control.state);
        while st.done_workers < self.num_threads {
            let (guard, timeout) = self
                .control
                .done
                .wait_timeout(st, Duration::from_millis(50))
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
            if !timeout.timed_out() {
                continue;
            }
            // Workers only exit on shutdown — a finished handle mid-epoch
            // is a corpse.
            let dead: Vec<usize> =
                (0..self.workers.len()).filter(|&w| self.workers[w].is_finished()).collect();
            if dead.is_empty() {
                continue;
            }
            let policy = st.policy;
            drop(st);
            for w in dead {
                self.reap_worker(w, epoch, cmd, policy);
            }
            st = lock_recover(&self.control.state);
        }
        // Faults the workers caught this epoch (inner supervision either
        // re-raised on purpose — Propagate — or could not absorb them):
        // surface the first one as a panic that names shard/slot/env/step.
        if !st.epoch_faults.is_empty() {
            let faults = std::mem::take(&mut st.epoch_faults);
            let first = faults[0].clone();
            st.fault_history.extend(faults);
            drop(st);
            panic!("{first}");
        }
    }

    /// A worker died mid-epoch: join it, record the fault, and — under
    /// [`FaultPolicy::RestartWorker`] — repair its unfinished shards
    /// inline, spawn a replacement and count the epoch as done on its
    /// behalf. Any other policy re-raises the fault (workers catch panics
    /// under those policies, so death means something went badly wrong).
    fn reap_worker(&mut self, w: usize, epoch: u64, cmd: Cmd, policy: FaultPolicy) {
        let replacement = spawn_worker(
            owned_shards(&self.shards, w, self.num_threads),
            Arc::clone(&self.control),
            self.num_threads,
            // The replacement must not re-execute the current epoch — the
            // repair below completes it inline.
            epoch,
        );
        let corpse = std::mem::replace(&mut self.workers[w], replacement);
        let payload_str = match corpse.join() {
            Err(payload) => payload_to_string(&*payload),
            Ok(()) => "<worker exited without panicking>".to_string(),
        };
        let fault = EngineFault {
            shard: None,
            slot: None,
            env_id: self.cfg.id.clone(),
            step: self.steps_dispatched,
            payload: payload_str,
        };
        lock_recover(&self.control.state).fault_history.push(fault.clone());
        if policy != FaultPolicy::RestartWorker {
            panic!("worker {w} died: {fault}");
        }
        for (idx, shard) in owned_shards(&self.shards, w, self.num_threads) {
            let mut sh = lock_recover(&shard);
            if sh.done_epoch == epoch {
                continue;
            }
            match cmd {
                Cmd::Step => {
                    let Shard { env, actions, .. } = &mut *sh;
                    if env.step_count() < self.steps_dispatched {
                        // The worker died before reaching this shard: run
                        // the step normally — catching, because the fault
                        // (e.g. a pending chaos spec) may live here.
                        if catch_fault(|| env.step(actions)).is_err() {
                            env.recover_interrupted_step(actions, true);
                        }
                    } else {
                        // Torn mid-step: roll the faulting slot back to its
                        // pre-step snapshot and finish the remaining slots.
                        env.recover_interrupted_step(actions, true);
                    }
                }
                Cmd::ResetAll => {
                    // Resets draw no chaos; a mid-reset death is a real
                    // layout bug, and re-running the whole shard reset
                    // lands every slot on deterministic successor keys.
                    sh.env.reset_all();
                }
                Cmd::StepN(_) => {
                    // step_n degrades Fixed plans to per-step epochs under
                    // RestartWorker, so a fused window can never be the
                    // command a restartable worker died in.
                    unreachable!("fused windows are not dispatched under RestartWorker (shard {idx})")
                }
            }
            sh.done_epoch = epoch;
        }
        let mut st = lock_recover(&self.control.state);
        st.workers_restarted += 1;
        st.done_workers += 1; // the epoch's work is done, just not by the corpse
        if st.done_workers == self.num_threads {
            self.control.done.notify_one();
        }
    }

    /// Copy every shard's timestep and observation slices into the
    /// contiguous mirrors — one `memcpy` per field per shard, no
    /// allocation.
    fn gather(&mut self) {
        let a = self.a;
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            let sh = lock_recover(shard);
            let (lo, hi) = (lo * a, hi * a);
            let ts = &sh.env.timestep;
            self.timestep.t[lo..hi].copy_from_slice(&ts.t);
            self.timestep.action[lo..hi].copy_from_slice(&ts.action);
            self.timestep.reward[lo..hi].copy_from_slice(&ts.reward);
            self.timestep.discount[lo..hi].copy_from_slice(&ts.discount);
            self.timestep.step_type[lo..hi].copy_from_slice(&ts.step_type);
            self.timestep.episodic_return[lo..hi].copy_from_slice(&ts.episodic_return);
            let s = self.obs_stride;
            match (&mut self.obs.data, &sh.env.obs.data) {
                (ObsData::I32(dst), ObsData::I32(src)) => {
                    dst[lo * s..hi * s].copy_from_slice(src);
                }
                (ObsData::U8(dst), ObsData::U8(src)) => {
                    dst[lo * s..hi * s].copy_from_slice(src);
                }
                _ => unreachable!("shard obs dtype diverged from the mirror"),
            }
            self.obs.mission[lo * MISSION_TOKENS..hi * MISSION_TOKENS]
                .copy_from_slice(&sh.env.obs.mission);
        }
    }
}

impl Drop for ShardedEnv {
    fn drop(&mut self) {
        {
            let mut st = lock_recover(&self.control.state);
            st.shutdown = true;
            self.control.start.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl BatchStepper for ShardedEnv {
    fn batch_size(&self) -> usize {
        self.b
    }

    fn num_agents(&self) -> usize {
        self.a
    }

    fn step(&mut self, actions: &[u8]) {
        ShardedEnv::step(self, actions);
    }

    fn timestep(&self) -> &BatchedTimestep {
        &self.timestep
    }

    fn obs(&self) -> &ObsBatch {
        &self.obs
    }

    fn reset_all(&mut self) {
        ShardedEnv::reset_all(self);
    }

    fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        ShardedEnv::step_n(self, plan, k, traj);
    }

    fn save_checkpoint(&mut self) -> EngineCheckpoint {
        ShardedEnv::save_checkpoint(self)
    }

    fn restore_checkpoint(&mut self, ck: &EngineCheckpoint) {
        ShardedEnv::restore_checkpoint(self, ck);
    }

    fn supervise(&mut self, policy: FaultPolicy) {
        ShardedEnv::supervise(self, policy);
    }

    fn fault_log(&mut self) -> Vec<EngineFault> {
        ShardedEnv::fault_log(self)
    }

    fn fault_stats(&mut self) -> FaultStats {
        ShardedEnv::fault_stats(self)
    }
}

/// The (global index, shard) pairs worker `w` owns under the round-robin
/// assignment — shared by construction, respawn and inline repair so the
/// three can never disagree about ownership.
fn owned_shards(
    shards: &[Arc<Mutex<Shard>>],
    w: usize,
    num_threads: usize,
) -> Vec<(usize, Arc<Mutex<Shard>>)> {
    shards
        .iter()
        .enumerate()
        .skip(w)
        .step_by(num_threads)
        .map(|(i, s)| (i, Arc::clone(s)))
        .collect()
}

fn spawn_worker(
    mine: Vec<(usize, Arc<Mutex<Shard>>)>,
    control: Arc<Control>,
    total_workers: usize,
    start_epoch: u64,
) -> JoinHandle<()> {
    std::thread::spawn(move || worker_loop(mine, control, total_workers, start_epoch))
}

/// Worker body: wait for a new epoch, execute the command over the owned
/// shards (timing each), report completion. Exits on shutdown. Unless the
/// policy is [`FaultPolicy::RestartWorker`] (which wants the panic to kill
/// the thread), each shard command runs behind `catch_unwind`: the fault
/// is recorded and the done-count still advances, so the caller gets a
/// structured panic instead of a hang.
fn worker_loop(
    mine: Vec<(usize, Arc<Mutex<Shard>>)>,
    control: Arc<Control>,
    total_workers: usize,
    start_epoch: u64,
) {
    let mut seen_epoch = start_epoch;
    loop {
        let (cmd, policy) = {
            let mut st = lock_recover(&control.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    break;
                }
                st = control.start.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            seen_epoch = st.epoch;
            (st.cmd, st.policy)
        };
        let mut caught: Vec<EngineFault> = Vec::new();
        for (idx, shard) in &mine {
            let mut sh = lock_recover(shard);
            let t0 = Instant::now();
            let run = |sh: &mut Shard| match cmd {
                Cmd::Step => {
                    let Shard { env, actions, .. } = sh;
                    env.step(actions);
                }
                Cmd::StepN(k) => {
                    // The fused window: all K steps run here with the
                    // shard's state hot, no sync until the window ends.
                    let Shard { env, plan, traj, .. } = sh;
                    env.step_n(ActionPlan::Fixed(plan), k, traj);
                }
                Cmd::ResetAll => sh.env.reset_all(),
            };
            if policy == FaultPolicy::RestartWorker {
                // No catch: a panic unwinds out of the thread (poisoning
                // the shard lock — recovered by `lock_recover`) and the
                // epoch watchdog takes over.
                run(&mut sh);
            } else if let Err(payload) = catch_fault(|| run(&mut sh)) {
                // Prefer the shard engine's own record (it knows the
                // slot); fall back to a synthesized one.
                let fault = match sh.env.fault_log().last() {
                    Some(f) if f.step == sh.env.step_count() => {
                        EngineFault { shard: Some(*idx), ..f.clone() }
                    }
                    _ => EngineFault {
                        shard: Some(*idx),
                        slot: None,
                        env_id: sh.env.cfg.id.clone(),
                        step: sh.env.step_count(),
                        payload: payload_to_string(&*payload),
                    },
                };
                caught.push(fault);
            }
            sh.done_epoch = seen_epoch;
            sh.busy_secs += t0.elapsed().as_secs_f64();
        }
        let mut st = lock_recover(&control.state);
        st.epoch_faults.append(&mut caught);
        st.done_workers += 1;
        if st.done_workers == total_workers {
            control.done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::timestep::StepType;
    use crate::envs::registry::make;
    use crate::rng::Rng;

    fn env(id: &str, b: usize, shards: usize, threads: usize) -> ShardedEnv {
        ShardedEnv::new(make(id).unwrap(), b, shards, threads, Key::new(0))
    }

    #[test]
    fn construction_resets_and_gathers_obs() {
        let e = env("Navix-Empty-8x8-v0", 8, 4, 2);
        assert_eq!(e.num_shards, 4);
        assert_eq!(e.num_threads, 2);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::First));
        // fixed start: all eight observations identical and non-trivial
        let o0: Vec<i32> = e.obs.env_i32(8, 0).to_vec();
        assert!(o0.iter().any(|&x| x != 0));
        for i in 1..8 {
            assert_eq!(e.obs.env_i32(8, i), &o0[..]);
        }
    }

    #[test]
    fn shard_counts_clamp_to_batch() {
        let e = env("Navix-Empty-5x5-v0", 3, 7, 7);
        assert_eq!(e.num_shards, 3, "no empty shards");
        assert!(e.num_threads <= 3);
        let total: usize = e.shard_bounds().iter().map(|&(lo, hi)| hi - lo).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn matches_batched_env_bitwise_on_random_walk() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), 10, Key::new(3));
        let mut sharded = ShardedEnv::new(cfg, 10, 3, 2, Key::new(3));
        let mut rng = Rng::new(11);
        for _ in 0..150 {
            let actions: Vec<u8> = (0..10).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            assert_eq!(single.timestep.reward, sharded.timestep.reward);
            assert_eq!(single.timestep.step_type, sharded.timestep.step_type);
            for i in 0..10 {
                assert_eq!(single.obs.env_i32(10, i), sharded.obs.env_i32(10, i));
            }
        }
    }

    #[test]
    fn reset_all_matches_batched_env() {
        let cfg = make("Navix-Empty-Random-8x8").unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), 6, Key::new(5));
        let mut sharded = ShardedEnv::new(cfg, 6, 2, 2, Key::new(5));
        single.reset_all();
        sharded.reset_all();
        assert_eq!(single.state.player_pos, {
            let mut pos = Vec::new();
            for s in 0..sharded.num_shards {
                sharded.with_shard(s, |e| pos.extend_from_slice(&e.state.player_pos));
            }
            pos
        });
        for i in 0..6 {
            assert_eq!(single.obs.env_i32(6, i), sharded.obs.env_i32(6, i));
        }
    }

    #[test]
    fn rollout_random_executes_requested_steps_and_times_shards() {
        let mut e = env("Navix-Empty-8x8-v0", 16, 4, 2);
        let n = e.rollout_random(50, 42);
        assert_eq!(n, 800);
        let busy = e.shard_busy_secs();
        assert_eq!(busy.len(), 4);
        assert!(busy.iter().all(|&t| t > 0.0), "workers must have timed work: {busy:?}");
    }

    #[test]
    fn drop_joins_the_pool() {
        let e = env("Navix-Empty-5x5-v0", 4, 2, 2);
        drop(e); // must not hang or leak threads
    }

    #[test]
    fn fused_window_matches_per_step_epochs() {
        // One StepN epoch vs K Step epochs: same trajectory, same gathered
        // mirrors (the engine matrix lives in tests/test_scan_parity.rs).
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut fused = ShardedEnv::new(cfg.clone(), 10, 3, 2, Key::new(3));
        let mut stepwise = ShardedEnv::new(cfg, 10, 3, 2, Key::new(3));
        let mut rng = Rng::new(11);
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        for _ in 0..3 {
            let plan: Vec<u8> = (0..12 * 10).map(|_| rng.below(7) as u8).collect();
            fused.step_n(ActionPlan::Fixed(&plan), 12, &mut traj);
            for t in 0..12 {
                stepwise.step(&plan[t * 10..(t + 1) * 10]);
                assert_eq!(traj.reward_row(t), &stepwise.timestep.reward[..]);
                assert_eq!(traj.step_type_row(t), &stepwise.timestep.step_type[..]);
                for i in 0..10 {
                    assert_eq!(traj.obs_i32(t, i), stepwise.obs.env_i32(10, i));
                    assert_eq!(traj.mission_row(t, i), stepwise.obs.mission_row(10, i));
                }
            }
            assert_eq!(fused.timestep.t, stepwise.timestep.t);
            for i in 0..10 {
                assert_eq!(fused.obs.env_i32(10, i), stepwise.obs.env_i32(10, i));
            }
        }
    }

    #[test]
    fn checkpoint_round_trips_across_shard_layouts() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut sharded = ShardedEnv::new(cfg.clone(), 9, 3, 2, Key::new(8));
        let mut rng = Rng::new(21);
        let mut actions = vec![0u8; 9];
        for _ in 0..40 {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            sharded.step(&actions);
        }
        let ck = ShardedEnv::save_checkpoint(&sharded);
        // Restore into a single-threaded engine: slots are global, shard
        // layout is irrelevant.
        let mut single = BatchedEnv::new(cfg, 9, Key::new(8));
        single.restore_checkpoint(&ck);
        for _ in 0..40 {
            for a in actions.iter_mut() {
                *a = rng.below(7) as u8;
            }
            sharded.step(&actions);
            single.step(&actions);
            assert_eq!(single.timestep.reward, sharded.timestep.reward);
            assert_eq!(single.timestep.step_type, sharded.timestep.step_type);
            for i in 0..9 {
                assert_eq!(single.obs.env_i32(9, i), sharded.obs.env_i32(9, i));
            }
        }
    }
}
