//! `ShardedEnv` — the multi-core batch stepper (the `jax.pmap` analog).
//!
//! [`BatchedEnv`] amortises one dispatch over `B` contiguous state slots
//! (the paper's `vmap` analog). This module adds the *device axis* from the
//! paper's `pmap` benchmarks (§4.2) and the large-batch simulation design of
//! Shacklett et al.: the struct-of-arrays batch is split into `S`
//! **contiguous shards**, each a [`BatchedEnv`] over its global index range,
//! stepped by a **fixed pool** of worker threads.
//!
//! The pool is persistent: workers are spawned once at construction and
//! synchronise with the caller on an epoch counter + two condvars. The hot
//! path performs **no allocation and no channel traffic** — actions are
//! scattered into preallocated per-shard buffers, each worker steps its
//! shards in place, and the results are gathered into contiguous
//! timestep/observation mirrors with one `memcpy` per field per shard.
//! Per-shard busy time is accumulated for the load statistics the
//! `fig5_sharded` bench reports. Rgb shards share one process-wide
//! [`SpriteSheet`](crate::systems::sprites::SpriteSheet) (`Arc` behind a
//! `OnceLock`), so sharded rgb runs no longer pay per-shard sheet
//! construction or memory.
//!
//! ## Determinism
//!
//! Stepping is **bit-identical** to the single-threaded [`BatchedEnv`] for
//! any shard count: every per-env RNG stream is a pure function of
//! `(root key, global env index, per-env episode count)` — never of the
//! shard or worker that executes the env (see [`BatchedEnv::with_offset`]
//! and the module docs of [`crate::batch`]). The integration test
//! `rust/tests/test_sharded_determinism.rs` pins this for `S ∈ {1, 2, 7}`.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::batch::{
    ActionPlan, BatchStepper, BatchedEnv, ObsBatch, ObsCapture, ObsData, TrajectorySlice,
};
use crate::core::actions::Action;
use crate::core::mission::MISSION_DIM;
use crate::core::timestep::BatchedTimestep;
use crate::envs::EnvConfig;
use crate::rng::Key;

/// One shard: a contiguous env range plus its scatter/timing buffers.
struct Shard {
    env: BatchedEnv,
    /// Per-step action slice for this shard (scattered by the caller).
    actions: Vec<u8>,
    /// Time-major `[K × shard_b]` action plan for a fused window
    /// (scattered by the caller before a [`Cmd::StepN`] epoch).
    plan: Vec<u8>,
    /// This shard's trajectory chunk, filled in the worker during a fused
    /// window — shard state stays hot in the worker for all K steps.
    traj: TrajectorySlice,
    /// Cumulative busy wall-time spent stepping/resetting this shard.
    busy_secs: f64,
}

/// What an epoch asks the workers to do.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cmd {
    Step,
    /// Fused window: run the scattered K-step plan through the shard
    /// engine's `step_n` — one epoch/condvar round-trip per K steps.
    StepN(usize),
    ResetAll,
}

struct PoolState {
    epoch: u64,
    cmd: Cmd,
    done_workers: usize,
    shutdown: bool,
}

struct Control {
    state: Mutex<PoolState>,
    start: Condvar,
    done: Condvar,
}

/// `B` parallel environments split into `S` contiguous shards, stepped by a
/// fixed multi-core worker pool. Mirrors [`BatchedEnv`]'s public surface
/// (`timestep`, `obs`, `step`, `reset_all`, `rollout_random`) so callers
/// can switch engines without code changes (or use [`BatchStepper`]).
pub struct ShardedEnv {
    pub cfg: EnvConfig,
    pub b: usize,
    /// Agents per slot (`cfg.n_agents`); every per-row buffer and action
    /// slice spans `b·a` agent-rows, sharded as `[lo·a, hi·a)` segments.
    pub a: usize,
    pub num_shards: usize,
    pub num_threads: usize,
    /// Gathered timestep mirror (same layout as [`BatchedEnv::timestep`]).
    pub timestep: BatchedTimestep,
    /// Gathered observation mirror (same layout as [`BatchedEnv::obs`]).
    pub obs: ObsBatch,
    bounds: Vec<(usize, usize)>,
    shards: Vec<Arc<Mutex<Shard>>>,
    control: Arc<Control>,
    workers: Vec<JoinHandle<()>>,
    obs_stride: usize,
}

impl ShardedEnv {
    /// Allocate `b` environments split into `num_shards` contiguous shards
    /// stepped by `num_threads` persistent workers. `0` for either means
    /// "use the host's available parallelism"; both are clamped so no shard
    /// is empty and no worker is idle by construction.
    pub fn new(
        cfg: EnvConfig,
        b: usize,
        num_shards: usize,
        num_threads: usize,
        key: Key,
    ) -> Self {
        let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let num_shards = if num_shards == 0 { auto } else { num_shards }.clamp(1, b.max(1));
        let num_threads = if num_threads == 0 { auto } else { num_threads }.clamp(1, num_shards);

        let a = cfg.n_agents.max(1);
        let obs_stride = cfg.obs.len(cfg.h, cfg.w);
        let mut bounds = Vec::with_capacity(num_shards);
        let mut shards = Vec::with_capacity(num_shards);
        for s in 0..num_shards {
            let lo = s * b / num_shards;
            let hi = (s + 1) * b / num_shards;
            bounds.push((lo, hi));
            let env = BatchedEnv::with_offset(cfg.clone(), hi - lo, key, lo);
            shards.push(Arc::new(Mutex::new(Shard {
                env,
                actions: vec![0u8; (hi - lo) * a],
                plan: Vec::new(),
                traj: TrajectorySlice::new(ObsCapture::Final),
                busy_secs: 0.0,
            })));
        }

        let obs = ObsBatch::alloc(cfg.obs.kind.is_rgb(), b * a, obs_stride);

        let control = Arc::new(Control {
            state: Mutex::new(PoolState {
                epoch: 0,
                cmd: Cmd::Step,
                done_workers: 0,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
        });

        // Fixed shard ownership, round-robin: worker w steps shards
        // w, w+T, w+2T, … — contiguous global ranges stay cache-friendly
        // within a shard while load spreads across workers.
        let workers = (0..num_threads)
            .map(|w| {
                let mine: Vec<Arc<Mutex<Shard>>> =
                    shards.iter().skip(w).step_by(num_threads).cloned().collect();
                let control = Arc::clone(&control);
                std::thread::spawn(move || worker_loop(mine, control, num_threads))
            })
            .collect();

        let mut env = ShardedEnv {
            cfg,
            b,
            a,
            num_shards,
            num_threads,
            timestep: BatchedTimestep::first(b * a),
            obs,
            bounds,
            shards,
            control,
            workers,
            obs_stride,
        };
        env.gather(); // expose the construction-time reset observations
        env
    }

    /// Number of discrete actions.
    pub fn num_actions(&self) -> usize {
        Action::N
    }

    /// Step all environments with `actions` (the flat `[B × A]` action
    /// matrix — one per agent-row, values 0..7). Slots whose previous
    /// timestep was terminal autoreset instead.
    /// Bit-identical to [`BatchedEnv::step`] on the same action sequence.
    pub fn step(&mut self, actions: &[u8]) {
        let a = self.a;
        debug_assert_eq!(actions.len(), self.b * a);
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            shard.lock().unwrap().actions.copy_from_slice(&actions[lo * a..hi * a]);
        }
        self.run_epoch(Cmd::Step);
        self.gather();
    }

    /// Reset every environment (fresh episode keys), in parallel.
    pub fn reset_all(&mut self) {
        self.run_epoch(Cmd::ResetAll);
        self.gather();
    }

    /// Fused K-step window. With an [`ActionPlan::Fixed`] plan this is the
    /// scan-mode payoff for the device axis: the whole time-major plan is
    /// scattered up front, **one** epoch/condvar round-trip covers all K
    /// steps (vs. K for the per-step path), each worker runs its shard's
    /// fused `step_n` with the shard state hot in cache, and the caller
    /// gathers the trajectory chunks afterwards. Provider plans need the
    /// full gathered observation batch before every step, so they fall
    /// back to one epoch per step (still recording into `traj`).
    /// Bit-identical to `k` calls of [`ShardedEnv::step`] either way.
    pub fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        let a = self.a;
        let rows = self.b * a;
        traj.ensure_like(k, rows, &self.obs);
        match plan {
            ActionPlan::Fixed(actions) => {
                assert_eq!(actions.len(), k * rows, "Fixed plan must be [K × B·A]");
                // Scatter: per-shard time-major plan chunks, capture mode
                // forwarded so workers allocate nothing mid-epoch.
                for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
                    let mut sh = shard.lock().unwrap();
                    let bs = (hi - lo) * a;
                    sh.plan.resize(k * bs, 0);
                    for t in 0..k {
                        sh.plan[t * bs..(t + 1) * bs]
                            .copy_from_slice(&actions[t * rows + lo * a..t * rows + hi * a]);
                    }
                    sh.traj.capture = traj.capture;
                }
                self.run_epoch(Cmd::StepN(k));
                self.gather_traj(k, traj);
                self.gather();
            }
            ActionPlan::Provider(p) => {
                let mut buf = vec![0u8; rows];
                for t in 0..k {
                    p.actions(t, &self.obs, &self.timestep, &mut buf);
                    p.overlap(t);
                    self.step(&buf);
                    traj.record_row(t, &self.timestep);
                    if traj.capture == ObsCapture::All {
                        traj.capture_obs_row(t, &self.obs);
                    }
                }
            }
        }
    }

    /// Copy every shard's fused-window trajectory chunk into the global
    /// time-major slice (row segment `[t·B + lo, t·B + hi)` per shard per
    /// step — one `memcpy` per field per row segment).
    fn gather_traj(&self, k: usize, traj: &mut TrajectorySlice) {
        let a = self.a;
        let rows = self.b * a;
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            let sh = shard.lock().unwrap();
            let (lo, hi) = (lo * a, hi * a);
            let bs = hi - lo;
            for t in 0..k {
                let (g, s) = (t * rows, t * bs);
                traj.t[g + lo..g + hi].copy_from_slice(&sh.traj.t[s..s + bs]);
                traj.action[g + lo..g + hi].copy_from_slice(&sh.traj.action[s..s + bs]);
                traj.reward[g + lo..g + hi].copy_from_slice(&sh.traj.reward[s..s + bs]);
                traj.discount[g + lo..g + hi].copy_from_slice(&sh.traj.discount[s..s + bs]);
                traj.step_type[g + lo..g + hi]
                    .copy_from_slice(&sh.traj.step_type[s..s + bs]);
                traj.episodic_return[g + lo..g + hi]
                    .copy_from_slice(&sh.traj.episodic_return[s..s + bs]);
            }
            if traj.capture == ObsCapture::All {
                let os = self.obs_stride;
                for t in 0..k {
                    let (g, s) = (t * rows, t * bs);
                    match (&mut traj.obs, &sh.traj.obs) {
                        (ObsData::I32(dst), ObsData::I32(src)) => {
                            dst[(g + lo) * os..(g + hi) * os]
                                .copy_from_slice(&src[s * os..(s + bs) * os]);
                        }
                        (ObsData::U8(dst), ObsData::U8(src)) => {
                            dst[(g + lo) * os..(g + hi) * os]
                                .copy_from_slice(&src[s * os..(s + bs) * os]);
                        }
                        _ => unreachable!("shard trajectory obs dtype diverged"),
                    }
                    traj.mission[(g + lo) * MISSION_DIM..(g + hi) * MISSION_DIM]
                        .copy_from_slice(
                            &sh.traj.mission[s * MISSION_DIM..(s + bs) * MISSION_DIM],
                        );
                }
            }
        }
    }

    /// Convenience: run `steps` lockstep iterations with uniformly random
    /// actions — the same action stream [`BatchedEnv::rollout_random`]
    /// draws, so throughput comparisons execute identical work. Returns
    /// total env-steps (`b × steps`).
    pub fn rollout_random(&mut self, steps: usize, seed: u64) -> usize {
        let mut rng = crate::rng::Rng::new(seed);
        let mut actions = vec![0u8; self.b * self.a];
        for _ in 0..steps {
            for a in actions.iter_mut() {
                *a = rng.below(Action::N as u32) as u8;
            }
            self.step(&actions);
        }
        steps * self.b
    }

    /// Cumulative per-shard busy seconds since construction (the fig5
    /// sharded bench reports max/mean as the load-imbalance ratio).
    pub fn shard_busy_secs(&self) -> Vec<f64> {
        self.shards.iter().map(|s| s.lock().unwrap().busy_secs).collect()
    }

    /// Global `[lo, hi)` env ranges of each shard.
    pub fn shard_bounds(&self) -> &[(usize, usize)] {
        &self.bounds
    }

    /// Inspect one shard's engine under its lock (debugging/tests).
    pub fn with_shard<R>(&self, s: usize, f: impl FnOnce(&BatchedEnv) -> R) -> R {
        let shard = self.shards[s].lock().unwrap();
        f(&shard.env)
    }

    /// Publish one epoch of work and block until every worker finished it.
    /// The epoch counter (not the notification) is the wait condition, so
    /// wakeups can never be missed.
    fn run_epoch(&self, cmd: Cmd) {
        {
            let mut st = self.control.state.lock().unwrap();
            st.cmd = cmd;
            st.done_workers = 0;
            st.epoch += 1;
            self.control.start.notify_all();
        }
        let mut st = self.control.state.lock().unwrap();
        while st.done_workers < self.num_threads {
            st = self.control.done.wait(st).unwrap();
        }
    }

    /// Copy every shard's timestep and observation slices into the
    /// contiguous mirrors — one `memcpy` per field per shard, no
    /// allocation.
    fn gather(&mut self) {
        let a = self.a;
        for (shard, &(lo, hi)) in self.shards.iter().zip(&self.bounds) {
            let sh = shard.lock().unwrap();
            let (lo, hi) = (lo * a, hi * a);
            let ts = &sh.env.timestep;
            self.timestep.t[lo..hi].copy_from_slice(&ts.t);
            self.timestep.action[lo..hi].copy_from_slice(&ts.action);
            self.timestep.reward[lo..hi].copy_from_slice(&ts.reward);
            self.timestep.discount[lo..hi].copy_from_slice(&ts.discount);
            self.timestep.step_type[lo..hi].copy_from_slice(&ts.step_type);
            self.timestep.episodic_return[lo..hi].copy_from_slice(&ts.episodic_return);
            let s = self.obs_stride;
            match (&mut self.obs.data, &sh.env.obs.data) {
                (ObsData::I32(dst), ObsData::I32(src)) => {
                    dst[lo * s..hi * s].copy_from_slice(src);
                }
                (ObsData::U8(dst), ObsData::U8(src)) => {
                    dst[lo * s..hi * s].copy_from_slice(src);
                }
                _ => unreachable!("shard obs dtype diverged from the mirror"),
            }
            self.obs.mission[lo * MISSION_DIM..hi * MISSION_DIM]
                .copy_from_slice(&sh.env.obs.mission);
        }
    }
}

impl Drop for ShardedEnv {
    fn drop(&mut self) {
        {
            let mut st = self.control.state.lock().unwrap();
            st.shutdown = true;
            self.control.start.notify_all();
        }
        for handle in self.workers.drain(..) {
            let _ = handle.join();
        }
    }
}

impl BatchStepper for ShardedEnv {
    fn batch_size(&self) -> usize {
        self.b
    }

    fn num_agents(&self) -> usize {
        self.a
    }

    fn step(&mut self, actions: &[u8]) {
        ShardedEnv::step(self, actions);
    }

    fn timestep(&self) -> &BatchedTimestep {
        &self.timestep
    }

    fn obs(&self) -> &ObsBatch {
        &self.obs
    }

    fn reset_all(&mut self) {
        ShardedEnv::reset_all(self);
    }

    fn step_n(&mut self, plan: ActionPlan<'_>, k: usize, traj: &mut TrajectorySlice) {
        ShardedEnv::step_n(self, plan, k, traj);
    }
}

/// Worker body: wait for a new epoch, execute the command over the owned
/// shards (timing each), report completion. Exits on shutdown.
fn worker_loop(mine: Vec<Arc<Mutex<Shard>>>, control: Arc<Control>, total_workers: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let cmd = {
            let mut st = control.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen_epoch {
                    break;
                }
                st = control.start.wait(st).unwrap();
            }
            seen_epoch = st.epoch;
            st.cmd
        };
        for shard in &mine {
            let mut sh = shard.lock().unwrap();
            let t0 = Instant::now();
            match cmd {
                Cmd::Step => {
                    let Shard { env, actions, .. } = &mut *sh;
                    env.step(actions);
                }
                Cmd::StepN(k) => {
                    // The fused window: all K steps run here with the
                    // shard's state hot, no sync until the window ends.
                    let Shard { env, plan, traj, .. } = &mut *sh;
                    env.step_n(ActionPlan::Fixed(plan), k, traj);
                }
                Cmd::ResetAll => sh.env.reset_all(),
            }
            sh.busy_secs += t0.elapsed().as_secs_f64();
        }
        let mut st = control.state.lock().unwrap();
        st.done_workers += 1;
        if st.done_workers == total_workers {
            control.done.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::timestep::StepType;
    use crate::envs::registry::make;
    use crate::rng::Rng;

    fn env(id: &str, b: usize, shards: usize, threads: usize) -> ShardedEnv {
        ShardedEnv::new(make(id).unwrap(), b, shards, threads, Key::new(0))
    }

    #[test]
    fn construction_resets_and_gathers_obs() {
        let e = env("Navix-Empty-8x8-v0", 8, 4, 2);
        assert_eq!(e.num_shards, 4);
        assert_eq!(e.num_threads, 2);
        assert!(e.timestep.step_type.iter().all(|&s| s == StepType::First));
        // fixed start: all eight observations identical and non-trivial
        let o0: Vec<i32> = e.obs.env_i32(8, 0).to_vec();
        assert!(o0.iter().any(|&x| x != 0));
        for i in 1..8 {
            assert_eq!(e.obs.env_i32(8, i), &o0[..]);
        }
    }

    #[test]
    fn shard_counts_clamp_to_batch() {
        let e = env("Navix-Empty-5x5-v0", 3, 7, 7);
        assert_eq!(e.num_shards, 3, "no empty shards");
        assert!(e.num_threads <= 3);
        let total: usize = e.shard_bounds().iter().map(|&(lo, hi)| hi - lo).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn matches_batched_env_bitwise_on_random_walk() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), 10, Key::new(3));
        let mut sharded = ShardedEnv::new(cfg, 10, 3, 2, Key::new(3));
        let mut rng = Rng::new(11);
        for _ in 0..150 {
            let actions: Vec<u8> = (0..10).map(|_| rng.below(7) as u8).collect();
            single.step(&actions);
            sharded.step(&actions);
            assert_eq!(single.timestep.reward, sharded.timestep.reward);
            assert_eq!(single.timestep.step_type, sharded.timestep.step_type);
            for i in 0..10 {
                assert_eq!(single.obs.env_i32(10, i), sharded.obs.env_i32(10, i));
            }
        }
    }

    #[test]
    fn reset_all_matches_batched_env() {
        let cfg = make("Navix-Empty-Random-8x8").unwrap();
        let mut single = BatchedEnv::new(cfg.clone(), 6, Key::new(5));
        let mut sharded = ShardedEnv::new(cfg, 6, 2, 2, Key::new(5));
        single.reset_all();
        sharded.reset_all();
        assert_eq!(single.state.player_pos, {
            let mut pos = Vec::new();
            for s in 0..sharded.num_shards {
                sharded.with_shard(s, |e| pos.extend_from_slice(&e.state.player_pos));
            }
            pos
        });
        for i in 0..6 {
            assert_eq!(single.obs.env_i32(6, i), sharded.obs.env_i32(6, i));
        }
    }

    #[test]
    fn rollout_random_executes_requested_steps_and_times_shards() {
        let mut e = env("Navix-Empty-8x8-v0", 16, 4, 2);
        let n = e.rollout_random(50, 42);
        assert_eq!(n, 800);
        let busy = e.shard_busy_secs();
        assert_eq!(busy.len(), 4);
        assert!(busy.iter().all(|&t| t > 0.0), "workers must have timed work: {busy:?}");
    }

    #[test]
    fn drop_joins_the_pool() {
        let e = env("Navix-Empty-5x5-v0", 4, 2, 2);
        drop(e); // must not hang or leak threads
    }

    #[test]
    fn fused_window_matches_per_step_epochs() {
        // One StepN epoch vs K Step epochs: same trajectory, same gathered
        // mirrors (the engine matrix lives in tests/test_scan_parity.rs).
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut fused = ShardedEnv::new(cfg.clone(), 10, 3, 2, Key::new(3));
        let mut stepwise = ShardedEnv::new(cfg, 10, 3, 2, Key::new(3));
        let mut rng = Rng::new(11);
        let mut traj = TrajectorySlice::new(ObsCapture::All);
        for _ in 0..3 {
            let plan: Vec<u8> = (0..12 * 10).map(|_| rng.below(7) as u8).collect();
            fused.step_n(ActionPlan::Fixed(&plan), 12, &mut traj);
            for t in 0..12 {
                stepwise.step(&plan[t * 10..(t + 1) * 10]);
                assert_eq!(traj.reward_row(t), &stepwise.timestep.reward[..]);
                assert_eq!(traj.step_type_row(t), &stepwise.timestep.step_type[..]);
                for i in 0..10 {
                    assert_eq!(traj.obs_i32(t, i), stepwise.obs.env_i32(10, i));
                    assert_eq!(traj.mission_row(t, i), stepwise.obs.mission_row(10, i));
                }
            }
            assert_eq!(fused.timestep.t, stepwise.timestep.t);
            for i in 0..10 {
                assert_eq!(fused.obs.env_i32(10, i), stepwise.obs.env_i32(10, i));
            }
        }
    }
}
