//! The reward system `R : S × A × S → ℝ` (paper Table 5).
//!
//! NAVIX deliberately departs from MiniGrid's non-Markovian time-discounted
//! reward (paper Eq. 1) and uses Markovian, event-driven rewards instead:
//! 0 everywhere and ±1 on task events. Reward functions are composable — a
//! [`RewardSpec`] is a weighted sum of primitives, which is how the paper's
//! R1/R2/R3 composites (Table 8) are expressed.
//!
//! For completeness (and for users who want to reproduce historical MiniGrid
//! curves) the original non-Markovian reward is also provided as
//! [`RewardFn::MiniGridLegacy`]; it is *not* used by any registered NAVIX
//! environment, matching the paper.

use crate::core::actions::Action;
use crate::core::state::{AgentView, EnvSlot};

/// Primitive reward functions (paper Table 5, plus the KeyCorridor pickup
/// event and the legacy MiniGrid shaping for reference).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RewardFn {
    /// +1 when Player and a Goal entity share a position.
    OnGoalReached,
    /// −1 when Player and a Lava entity share a position.
    OnLavaFall,
    /// +1 when `done` is performed in front of the mission-colour door.
    OnDoorDone,
    /// +1 when the mission-target ball is picked up (KeyCorridor).
    OnBallPicked,
    /// −1 when the player collides with a flying obstacle (Dynamic-Obstacles).
    OnBallHit,
    /// +1 when a locked door is unlocked (Unlock).
    OnDoorUnlocked,
    /// +1 when the mission-target object of any pickable kind is picked up
    /// (Fetch, UnlockPickup).
    OnObjectPicked,
    /// +1 when `done` is performed facing the go-to mission's target object
    /// (GoToObj).
    OnObjectReached,
    /// +1 when the put-next mission's object is dropped adjacent to its
    /// second object (PutNext).
    OnObjectPlaced,
    /// +1 when *any* agent in the slot placed the mission object — the
    /// cooperative PutNext team reward (every agent-row pays out).
    OnObjectPlacedTeam,
    /// +1 when the mission's final clause completed (sequenced BabyAI-style
    /// families: SeqUnlockPickup, OpenDoorsOrder, curriculum RoomGrid).
    OnMissionComplete,
    /// +1 when this agent walked into another agent (pursuit "tag" success).
    OnAgentContact,
    /// −1 when another agent walked into this one (the evader was caught).
    OnContacted,
    /// 0 everywhere.
    Free,
    /// −cost on every action except `done`.
    ActionCost(f32),
    /// −cost on every step.
    TimeCost(f32),
    /// MiniGrid's original non-Markovian `1 − 0.9·t/T` on success, where `t`
    /// is the post-step counter — the same count upstream MiniGrid uses
    /// (`step_count` is incremented before the reward is computed).
    /// Reference only; breaks the Markov property, see paper §3.2.1.
    MiniGridLegacy,
}

impl RewardFn {
    /// Evaluate on the post-intervention slot. `max_steps` is the timeout T
    /// (used only by the legacy shaping).
    pub fn eval(self, s: &EnvSlot<'_>, action: Action, max_steps: u32) -> f32 {
        let ev = s.events_value();
        match self {
            RewardFn::OnGoalReached => {
                if ev.goal_reached {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnLavaFall => {
                if ev.lava_fall {
                    -1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnDoorDone => {
                if ev.door_done {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnBallPicked => {
                if ev.ball_picked {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnBallHit => {
                if ev.ball_hit {
                    -1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnDoorUnlocked => {
                if ev.door_unlocked {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnObjectPicked => {
                if ev.object_picked {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnObjectReached => {
                if ev.object_reached {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnObjectPlaced => {
                if ev.object_placed {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnObjectPlacedTeam => {
                if s.events.iter().any(|e| e.object_placed) {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnMissionComplete => {
                if ev.mission_complete {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnAgentContact => {
                if ev.agent_contact {
                    1.0
                } else {
                    0.0
                }
            }
            RewardFn::OnContacted => {
                if ev.contacted {
                    -1.0
                } else {
                    0.0
                }
            }
            RewardFn::Free => 0.0,
            RewardFn::ActionCost(c) => {
                if action == Action::Done {
                    0.0
                } else {
                    -c
                }
            }
            RewardFn::TimeCost(c) => -c,
            RewardFn::MiniGridLegacy => {
                // `s.t` is evaluated after the transition system advanced it,
                // so it equals MiniGrid's `step_count` at reward time — no +1.
                if ev.goal_reached {
                    1.0 - 0.9 * s.t as f32 / max_steps.max(1) as f32
                } else {
                    0.0
                }
            }
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RewardFn::OnGoalReached => "on_goal_reached",
            RewardFn::OnLavaFall => "on_lava_fall",
            RewardFn::OnDoorDone => "on_door_done",
            RewardFn::OnBallPicked => "on_ball_picked",
            RewardFn::OnBallHit => "on_ball_hit",
            RewardFn::OnDoorUnlocked => "on_door_unlocked",
            RewardFn::OnObjectPicked => "on_object_picked",
            RewardFn::OnObjectReached => "on_object_reached",
            RewardFn::OnObjectPlaced => "on_object_placed",
            RewardFn::OnObjectPlacedTeam => "on_object_placed_team",
            RewardFn::OnMissionComplete => "on_mission_complete",
            RewardFn::OnAgentContact => "on_agent_contact",
            RewardFn::OnContacted => "on_contacted",
            RewardFn::Free => "free",
            RewardFn::ActionCost(_) => "action_cost",
            RewardFn::TimeCost(_) => "time_cost",
            RewardFn::MiniGridLegacy => "minigrid_legacy",
        }
    }
}

/// A composable reward: the sum of its primitives (paper Appendix C shows
/// the same composition from the Python API).
#[derive(Clone, Debug, PartialEq)]
pub struct RewardSpec {
    pub terms: Vec<RewardFn>,
}

impl RewardSpec {
    pub fn new(terms: Vec<RewardFn>) -> Self {
        RewardSpec { terms }
    }

    /// R1 (Table 8): goal achievement.
    pub fn r1() -> Self {
        RewardSpec::new(vec![RewardFn::OnGoalReached])
    }

    /// R2 (Table 8): goal achievement + lava avoidance.
    pub fn r2() -> Self {
        RewardSpec::new(vec![RewardFn::OnGoalReached, RewardFn::OnLavaFall])
    }

    /// R3 (Table 8): goal achievement + dynamic-obstacle avoidance.
    pub fn r3() -> Self {
        RewardSpec::new(vec![RewardFn::OnGoalReached, RewardFn::OnBallHit])
    }

    /// KeyCorridor: pick up the target ball.
    pub fn ball_pickup() -> Self {
        RewardSpec::new(vec![RewardFn::OnBallPicked])
    }

    /// GoToDoor: `done` in front of the mission door.
    pub fn door_done() -> Self {
        RewardSpec::new(vec![RewardFn::OnDoorDone])
    }

    /// Unlock: open the locked door.
    pub fn unlock() -> Self {
        RewardSpec::new(vec![RewardFn::OnDoorUnlocked])
    }

    /// Fetch / UnlockPickup: pick up the mission-target object.
    pub fn object_pickup() -> Self {
        RewardSpec::new(vec![RewardFn::OnObjectPicked])
    }

    /// GoToObj: `done` facing the mission object.
    pub fn object_reached() -> Self {
        RewardSpec::new(vec![RewardFn::OnObjectReached])
    }

    /// PutNext: drop the mission object adjacent to its second object.
    pub fn object_placed() -> Self {
        RewardSpec::new(vec![RewardFn::OnObjectPlaced])
    }

    /// Cooperative PutNext: every agent in the slot is paid when any one of
    /// them places the mission object.
    pub fn team_object_placed() -> Self {
        RewardSpec::new(vec![RewardFn::OnObjectPlacedTeam])
    }

    /// Sequenced missions: +1 when the final clause completes.
    pub fn mission_complete() -> Self {
        RewardSpec::new(vec![RewardFn::OnMissionComplete])
    }

    /// Pursuit–evasion: +1 for tagging another agent, −1 for being tagged,
    /// −1 for colliding with a flying obstacle.
    pub fn pursuit() -> Self {
        RewardSpec::new(vec![
            RewardFn::OnAgentContact,
            RewardFn::OnContacted,
            RewardFn::OnBallHit,
        ])
    }

    pub fn eval(&self, s: &EnvSlot<'_>, action: Action, max_steps: u32) -> f32 {
        self.terms.iter().map(|t| t.eval(s, action, max_steps)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::Direction;
    use crate::core::events::Events;
    use crate::core::grid::Pos;
    use crate::core::state::{BatchedState, Caps};

    fn slot_with_events(ev: Events) -> BatchedState {
        let mut st = BatchedState::new(1, 5, 5, Caps::default());
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        s.events[0] = ev;
        drop(s);
        st
    }

    #[test]
    fn r1_fires_only_on_goal() {
        let st = slot_with_events(Events { goal_reached: true, ..Events::NONE });
        assert_eq!(RewardSpec::r1().eval(&st.slot(0), Action::Forward, 100), 1.0);
        let st = slot_with_events(Events { lava_fall: true, ..Events::NONE });
        assert_eq!(RewardSpec::r1().eval(&st.slot(0), Action::Forward, 100), 0.0);
    }

    #[test]
    fn r2_penalises_lava() {
        let st = slot_with_events(Events { lava_fall: true, ..Events::NONE });
        assert_eq!(RewardSpec::r2().eval(&st.slot(0), Action::Forward, 100), -1.0);
    }

    #[test]
    fn r3_penalises_collision() {
        let st = slot_with_events(Events { ball_hit: true, ..Events::NONE });
        assert_eq!(RewardSpec::r3().eval(&st.slot(0), Action::Forward, 100), -1.0);
    }

    #[test]
    fn costs_accumulate() {
        let st = slot_with_events(Events::NONE);
        let spec = RewardSpec::new(vec![RewardFn::ActionCost(0.1), RewardFn::TimeCost(0.05)]);
        let r = spec.eval(&st.slot(0), Action::Forward, 100);
        assert!((r + 0.15).abs() < 1e-6);
        // done action is exempt from action cost
        let r = spec.eval(&st.slot(0), Action::Done, 100);
        assert!((r + 0.05).abs() < 1e-6);
    }

    #[test]
    fn legacy_reward_is_time_dependent_markov_reward_is_not() {
        let mut st = slot_with_events(Events { goal_reached: true, ..Events::NONE });
        {
            let mut s = st.slot_mut(0);
            *s.t = 0;
        }
        let early = RewardFn::MiniGridLegacy.eval(&st.slot(0), Action::Forward, 100);
        let markov_early = RewardSpec::r1().eval(&st.slot(0), Action::Forward, 100);
        {
            let mut s = st.slot_mut(0);
            *s.t = 50;
        }
        let late = RewardFn::MiniGridLegacy.eval(&st.slot(0), Action::Forward, 100);
        let markov_late = RewardSpec::r1().eval(&st.slot(0), Action::Forward, 100);
        assert!(early > late, "legacy reward decays with t (non-Markovian)");
        assert_eq!(markov_early, markov_late, "NAVIX reward is Markovian");
    }

    #[test]
    fn free_is_zero() {
        let st = slot_with_events(Events { goal_reached: true, ..Events::NONE });
        assert_eq!(RewardFn::Free.eval(&st.slot(0), Action::Forward, 100), 0.0);
    }

    #[test]
    fn legacy_reward_matches_minigrid_step_count() {
        // Upstream MiniGrid: step_count is incremented before the reward is
        // computed, and `_reward() = 1 - 0.9 * step_count / max_steps`. Our
        // `t` is advanced by the transition system before reward evaluation,
        // so reaching the goal on the 5th step of a T=100 episode must pay
        // exactly 1 - 0.9 * 5/100.
        let mut st = slot_with_events(Events { goal_reached: true, ..Events::NONE });
        {
            let mut s = st.slot_mut(0);
            *s.t = 5;
        }
        let r = RewardFn::MiniGridLegacy.eval(&st.slot(0), Action::Forward, 100);
        assert!((r - (1.0 - 0.9 * 5.0 / 100.0)).abs() < 1e-6, "got {r}");
    }

    #[test]
    fn go_to_obj_and_put_next_primitives() {
        let st = slot_with_events(Events { object_reached: true, ..Events::NONE });
        assert_eq!(RewardSpec::object_reached().eval(&st.slot(0), Action::Done, 100), 1.0);
        assert_eq!(RewardSpec::object_placed().eval(&st.slot(0), Action::Done, 100), 0.0);
        let st = slot_with_events(Events { object_placed: true, ..Events::NONE });
        assert_eq!(RewardSpec::object_placed().eval(&st.slot(0), Action::Drop, 100), 1.0);
        assert_eq!(RewardSpec::object_reached().eval(&st.slot(0), Action::Drop, 100), 0.0);
    }

    #[test]
    fn unlock_and_object_pickup_primitives() {
        let st = slot_with_events(Events { door_unlocked: true, ..Events::NONE });
        assert_eq!(RewardSpec::unlock().eval(&st.slot(0), Action::Toggle, 100), 1.0);
        assert_eq!(RewardSpec::object_pickup().eval(&st.slot(0), Action::Toggle, 100), 0.0);
        let st = slot_with_events(Events { object_picked: true, ..Events::NONE });
        assert_eq!(RewardSpec::object_pickup().eval(&st.slot(0), Action::Pickup, 100), 1.0);
        // wrong pickup pays nothing (Fetch: terminate with 0 reward)
        let st = slot_with_events(Events { wrong_pickup: true, ..Events::NONE });
        assert_eq!(RewardSpec::object_pickup().eval(&st.slot(0), Action::Pickup, 100), 0.0);
    }

    #[test]
    fn mission_complete_primitive() {
        let st = slot_with_events(Events { mission_complete: true, ..Events::NONE });
        assert_eq!(RewardSpec::mission_complete().eval(&st.slot(0), Action::Pickup, 100), 1.0);
        // mid-sequence progress (door_opened without completion) pays nothing
        let st = slot_with_events(Events { door_opened: true, ..Events::NONE });
        assert_eq!(RewardSpec::mission_complete().eval(&st.slot(0), Action::Toggle, 100), 0.0);
    }

    #[test]
    fn pursuit_and_team_primitives() {
        let st = slot_with_events(Events { agent_contact: true, ..Events::NONE });
        assert_eq!(RewardSpec::pursuit().eval(&st.slot(0), Action::Forward, 100), 1.0);
        let st = slot_with_events(Events { contacted: true, ..Events::NONE });
        assert_eq!(RewardSpec::pursuit().eval(&st.slot(0), Action::Forward, 100), -1.0);
        // Team reward: agent 1 placed the object, agent 0 is paid too.
        let mut st = BatchedState::with_agents(1, 5, 5, Caps::default(), 2);
        {
            let mut s = st.slot_mut(0);
            s.fill_room();
            s.place_player(Pos::new(1, 1), Direction::East);
            s.place_agent(1, Pos::new(2, 2), Direction::East);
            s.events[1] = Events { object_placed: true, ..Events::NONE };
        }
        let team = RewardSpec::team_object_placed();
        assert_eq!(team.eval(&st.agent_slot(0, 0), Action::Drop, 100), 1.0);
        assert_eq!(team.eval(&st.agent_slot(0, 1), Action::Drop, 100), 1.0);
        // The per-agent primitive pays only the agent that placed it.
        let solo = RewardSpec::object_placed();
        assert_eq!(solo.eval(&st.agent_slot(0, 0), Action::Drop, 100), 0.0);
        assert_eq!(solo.eval(&st.agent_slot(0, 1), Action::Drop, 100), 1.0);
    }
}
