//! The intervention system `I : S × A → S` (paper Appendix A): applies the
//! agent's action to the entity state with full MiniGrid semantics, and
//! latches the events that the reward/termination systems consume.

use crate::core::actions::Action;
use crate::core::components::{Color, DoorState, Pocket};
use crate::core::entities::{CellType, Tag};
use crate::core::events::Events;
use crate::core::grid::Pos;
use crate::core::mission::MissionVerb;
use crate::core::state::{AgentView, SlotMut};

/// Apply `action` to one environment slot, acting as the slot view's
/// active agent. Returns nothing; all effects are written into the slot
/// (new agent pose, entity states, event latches).
///
/// In a multi-agent slot the engine calls this once per agent in
/// ascending agent order; the step's event latches are cleared when agent
/// 0 acts and accumulate across the later agents, so a latch one agent
/// sets on another (`contacted`, `ball_hit`) survives to the end of the
/// slot's step.
pub fn intervene(s: &mut SlotMut<'_>, action: Action) {
    if s.agent == 0 {
        s.events.fill(Events::NONE);
    }
    s.last_action[s.agent] = action as i32;

    match action {
        Action::Left => {
            s.player_dir[s.agent] = s.dir().left() as i32;
        }
        Action::Right => {
            s.player_dir[s.agent] = s.dir().right() as i32;
        }
        Action::Forward => forward(s),
        Action::Pickup => pickup(s),
        Action::Drop => drop_item(s),
        Action::Toggle => toggle(s),
        Action::Done => done(s),
    }

    // Position-coincidence events (checked after any movement).
    let p = s.player();
    match s.cell(p) {
        CellType::Goal => s.events[s.agent].goal_reached = true,
        CellType::Lava => s.events[s.agent].lava_fall = true,
        _ => {}
    }

    // Clause advance: if this agent's action fired the active clause's
    // completion event, latch the clause done in the token slab and move
    // the cursor (the packed mission column follows to the next clause).
    // Completing the *final* clause latches `mission_complete` — the
    // success event sequenced families reward and terminate on. Mission
    // events only latch into the acting agent's own row, so reading the
    // row here cannot advance on another agent's completion.
    let ev = s.events[s.agent];
    let completed = match s.mission_value().verb() {
        Some(MissionVerb::GoTo) => ev.door_done || ev.object_reached,
        Some(MissionVerb::PickUp) => ev.object_picked || ev.ball_picked,
        Some(MissionVerb::Open) => ev.door_opened,
        Some(MissionVerb::PutNext) => ev.object_placed,
        None => false,
    };
    if completed && s.advance_mission_clause() {
        s.events[s.agent].mission_complete = true;
    }
}

/// `forward`: move one cell ahead if walkable. Walking into another agent
/// latches the contact pair (`agent_contact` on the mover, `contacted` on
/// the target) without moving — this *is* the deterministic contested-cell
/// rule: agents act in ascending index order, so the lower index claims a
/// cell first and later movers bounce off it. Walking into a ball latches
/// the ball-collision event (Dynamic-Obstacles failure) without moving.
fn forward(s: &mut SlotMut<'_>) {
    let front = s.front();
    if let Some(j) = s.other_agent_at(front) {
        s.events[s.agent].agent_contact = true;
        s.events[j].contacted = true;
        return;
    }
    if s.ball_at(front).is_some() {
        s.events[s.agent].ball_hit = true;
        return;
    }
    if s.walkable(front) {
        s.player_pos[s.agent] = front.encode(s.w);
    }
}

/// `pickup`: pick the pickable entity ahead into the pocket (if empty).
/// Latches the pickup-mission events: `ball_picked` (KeyCorridor),
/// `object_picked` when the item matches a pick-up mission's target of any
/// kind, and `wrong_pickup` when it does not (Fetch failure).
fn pickup(s: &mut SlotMut<'_>) {
    if !s.pocket_value().is_empty() {
        return;
    }
    let front = s.front();
    let mission = s.mission_value();
    let picked = if let Some(k) = s.key_at(front) {
        let color = Color::from_u8(s.key_color[k]);
        s.remove_key(k); // off the grid, into the pocket
        Some((Tag::KEY, color))
    } else if let Some(bl) = s.ball_at(front) {
        let color = Color::from_u8(s.ball_color[bl]);
        // KeyCorridor mission: picking the target ball is the success event.
        if mission.is_pick_up(Tag::BALL, color) {
            s.events[s.agent].ball_picked = true;
        }
        s.remove_ball(bl);
        Some((Tag::BALL, color))
    } else if let Some(bx) = s.box_at(front) {
        let color = Color::from_u8(s.box_color[bx]);
        s.remove_box(bx);
        Some((Tag::BOX, color))
    } else {
        None
    };
    if let Some((tag, color)) = picked {
        s.pocket[s.agent] = Pocket::holding(tag, color).0;
        // Pickup-mission events fire only under a pick-up verb
        // (Fetch/UnlockPickup); go-to and put-next missions are unaffected.
        if mission.verb() == Some(MissionVerb::PickUp) {
            if mission.matches(tag, color) {
                s.events[s.agent].object_picked = true;
            } else {
                s.events[s.agent].wrong_pickup = true;
            }
        }
    }
}

/// Is an entity of exactly `(tag, color)` sitting at `p`? (O(1) overlay
/// probes; doors match regardless of open/closed state.)
fn entity_matches(s: &SlotMut<'_>, p: Pos, tag: i32, color: Color) -> bool {
    match tag {
        Tag::DOOR => s.door_at(p).map(|d| s.door_color[d] == color as u8),
        Tag::KEY => s.key_at(p).map(|k| s.key_color[k] == color as u8),
        Tag::BALL => s.ball_at(p).map(|b| s.ball_color[b] == color as u8),
        Tag::BOX => s.box_at(p).map(|b| s.box_color[b] == color as u8),
        _ => None,
    }
    .unwrap_or(false)
}

/// `drop`: place the held entity into the empty floor cell ahead. Under a
/// put-next mission, dropping the target object onto a cell 4-adjacent to
/// the mission's second object latches `object_placed` (PutNext success).
fn drop_item(s: &mut SlotMut<'_>) {
    let pocket = s.pocket_value();
    if pocket.is_empty() {
        return;
    }
    let front = s.front();
    if s.cell(front) != CellType::Floor || s.occupied_by_entity(front) {
        return;
    }
    let color = pocket.color();
    let dropped = match pocket.kind_tag() {
        Tag::KEY => s.try_add_key(front, color).is_some(),
        Tag::BALL => s.try_add_ball(front, color).is_some(),
        Tag::BOX => s.try_add_box(front, color).is_some(),
        _ => false,
    };
    if dropped {
        s.pocket[s.agent] = Pocket::EMPTY.0;
        let mission = s.mission_value();
        if mission.verb() == Some(MissionVerb::PutNext)
            && mission.matches(pocket.kind_tag(), color)
        {
            let (near_tag, near_color) = (mission.near_kind_tag(), mission.near_color());
            let adjacent = [(-1, 0), (1, 0), (0, -1), (0, 1)].iter().any(|&(dr, dc)| {
                entity_matches(s, Pos::new(front.r + dr, front.c + dc), near_tag, near_color)
            });
            if adjacent {
                s.events[s.agent].object_placed = true;
            }
        }
    }
}

/// `toggle`: doors open/close; locked doors unlock only with a matching
/// key. Any transition to Open of a door matching an active open-verb
/// mission latches `door_opened` (the Open clause's completion event —
/// a progress marker, not a terminal).
fn toggle(s: &mut SlotMut<'_>) {
    let front = s.front();
    if let Some(d) = s.door_at(front) {
        let state = DoorState::from_u8(s.door_state[d]);
        let pocket = s.pocket_value();
        let color = Color::from_u8(s.door_color[d]);
        let mut opened = false;
        match state {
            DoorState::Locked => {
                let has_matching_key = !pocket.is_empty()
                    && pocket.kind_tag() == Tag::KEY
                    && pocket.color() as u8 == s.door_color[d];
                if has_matching_key {
                    s.set_door_state(d, DoorState::Open);
                    s.events[s.agent].door_unlocked = true;
                    opened = true;
                }
            }
            DoorState::Closed => {
                s.set_door_state(d, DoorState::Open);
                opened = true;
            }
            DoorState::Open => s.set_door_state(d, DoorState::Closed),
        }
        if opened && s.mission_value().is_open(color) {
            s.events[s.agent].door_opened = true;
        }
    }
}

/// `done`: under a go-to mission, declaring completion while facing the
/// target latches the success event — `door_done` for door targets
/// (GoToDoor) and `object_reached` for pickable targets (GoToObj).
fn done(s: &mut SlotMut<'_>) {
    let front = s.front();
    let mission = s.mission_value();
    if let Some(d) = s.door_at(front) {
        if mission.is_go_to(Tag::DOOR, Color::from_u8(s.door_color[d])) {
            s.events[s.agent].door_done = true;
        }
    } else if let Some(k) = s.key_at(front) {
        if mission.is_go_to(Tag::KEY, Color::from_u8(s.key_color[k])) {
            s.events[s.agent].object_reached = true;
        }
    } else if let Some(b) = s.ball_at(front) {
        if mission.is_go_to(Tag::BALL, Color::from_u8(s.ball_color[b])) {
            s.events[s.agent].object_reached = true;
        }
    } else if let Some(b) = s.box_at(front) {
        if mission.is_go_to(Tag::BOX, Color::from_u8(s.box_color[b])) {
            s.events[s.agent].object_reached = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::Direction;
    use crate::core::mission::Mission;
    use crate::core::state::{BatchedState, Caps};

    fn room() -> BatchedState {
        let mut st = BatchedState::new(1, 7, 7, Caps { doors: 2, keys: 2, balls: 2, boxes: 1 });
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(3, 3), Direction::East);
        drop(s);
        st
    }

    #[test]
    fn turns_compose() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        intervene(&mut s, Action::Left);
        assert_eq!(s.dir(), Direction::North);
        intervene(&mut s, Action::Right);
        intervene(&mut s, Action::Right);
        assert_eq!(s.dir(), Direction::South);
        assert_eq!(s.player(), Pos::new(3, 3), "turning never moves");
    }

    #[test]
    fn forward_moves_and_walls_block() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 4));
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 5));
        intervene(&mut s, Action::Forward); // wall at col 6
        assert_eq!(s.player(), Pos::new(3, 5));
    }

    #[test]
    fn goal_event_latches_on_entry() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Goal, Color::Green);
        intervene(&mut s, Action::Forward);
        assert!(s.events[0].goal_reached);
        assert!(!s.events[0].lava_fall);
    }

    #[test]
    fn lava_event_latches_on_entry() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Lava, Color::Red);
        intervene(&mut s, Action::Forward);
        assert!(s.events[0].lava_fall);
    }

    #[test]
    fn pickup_key_then_drop() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_key(Pos::new(3, 4), Color::Yellow);
        intervene(&mut s, Action::Pickup);
        assert!(s.key_pos.iter().all(|&k| k < 0));
        assert_eq!(s.pocket_value().kind_tag(), Tag::KEY);
        assert_eq!(s.pocket_value().color(), Color::Yellow);
        // pickup with full pocket is a no-op
        s.add_key(Pos::new(3, 4), Color::Red);
        intervene(&mut s, Action::Pickup);
        assert_eq!(s.pocket_value().color(), Color::Yellow);
        // drop is blocked by the occupied front cell, then succeeds on free
        intervene(&mut s, Action::Drop);
        assert!(!s.pocket_value().is_empty());
        intervene(&mut s, Action::Left); // face north, (2,3) free
        intervene(&mut s, Action::Drop);
        assert!(s.pocket_value().is_empty());
        assert!(s.key_at(Pos::new(2, 3)).is_some());
    }

    #[test]
    fn locked_door_needs_matching_key() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        let d = s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Locked);
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Locked);
        s.pocket[0] = Pocket::holding(Tag::KEY, Color::Red).0;
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Locked, "wrong colour");
        s.pocket[0] = Pocket::holding(Tag::KEY, Color::Blue).0;
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Open);
        // forward through the now-open door
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 4));
    }

    #[test]
    fn closed_door_toggles_open_and_shut() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        let d = s.add_door(Pos::new(3, 4), Color::Grey, DoorState::Closed);
        assert!(!s.walkable(Pos::new(3, 4)));
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Open);
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Closed);
    }

    #[test]
    fn walking_into_ball_latches_collision_without_moving() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Blue);
        intervene(&mut s, Action::Forward);
        assert!(s.events[0].ball_hit);
        assert_eq!(s.player(), Pos::new(3, 3));
    }

    #[test]
    fn ball_pickup_latches_mission_event() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Purple);
        s.mission.fill(Mission::pick_up(Tag::BALL, Color::Purple).raw());
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].ball_picked);
        assert_eq!(s.pocket_value().kind_tag(), Tag::BALL);
    }

    #[test]
    fn done_in_front_of_mission_door() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Green, DoorState::Closed);
        s.mission.fill(Mission::go_to(Tag::DOOR, Color::Green).raw());
        intervene(&mut s, Action::Done);
        assert!(s.events[0].door_done);
        // facing elsewhere: no event
        intervene(&mut s, Action::Left);
        intervene(&mut s, Action::Done);
        assert!(!s.events[0].door_done);
    }

    #[test]
    fn unlocking_latches_door_unlocked() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Locked);
        s.pocket[0] = Pocket::holding(Tag::KEY, Color::Blue).0;
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_unlocked);
        // re-toggling an open/closed door is not an unlock
        intervene(&mut s, Action::Toggle); // open -> closed
        assert!(!s.events[0].door_unlocked);
        intervene(&mut s, Action::Toggle); // closed -> open
        assert!(!s.events[0].door_unlocked);
    }

    #[test]
    fn pickup_mission_object_latches_object_picked() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_box(Pos::new(3, 4), Color::Green);
        s.mission.fill(Mission::pick_up(Tag::BOX, Color::Green).raw());
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].object_picked);
        assert!(!s.events[0].wrong_pickup);
    }

    #[test]
    fn pickup_non_target_latches_wrong_pickup() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Red);
        s.mission.fill(Mission::pick_up(Tag::KEY, Color::Blue).raw()); // fetch the blue key
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].wrong_pickup, "wrong object picked under a pickable mission");
        assert!(!s.events[0].object_picked);
    }

    #[test]
    fn door_missions_do_not_fire_pickup_events() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_key(Pos::new(3, 4), Color::Yellow);
        s.mission.fill(Mission::go_to(Tag::DOOR, Color::Yellow).raw()); // GoToDoor-style mission
        intervene(&mut s, Action::Pickup);
        assert!(!s.events[0].object_picked);
        assert!(!s.events[0].wrong_pickup);
    }

    #[test]
    fn done_facing_go_to_object_latches_object_reached() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Blue);
        s.mission.fill(Mission::go_to(Tag::BALL, Color::Blue).raw());
        intervene(&mut s, Action::Done);
        assert!(s.events[0].object_reached);
        assert!(!s.events[0].door_done);
        // picking the go-to target up is NOT the success event (and not a
        // wrong pickup either — those are pick-up-verb semantics)
        intervene(&mut s, Action::Pickup);
        assert!(!s.events[0].object_picked);
        assert!(!s.events[0].wrong_pickup);
        // wrong colour: no event
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Red);
        intervene(&mut s, Action::Done);
        assert!(!s.events[0].object_reached, "wrong colour must not satisfy go-to");
    }

    #[test]
    fn put_next_drop_adjacent_to_target_latches_object_placed() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_box(Pos::new(2, 4), Color::Green); // the "near" target
        s.pocket[0] = Pocket::holding(Tag::BALL, Color::Purple).0;
        s.mission.fill(Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green).raw());
        // drop at (3,4): 4-adjacent to the box at (2,4)
        intervene(&mut s, Action::Drop);
        assert!(s.events[0].object_placed);
        assert!(s.pocket_value().is_empty());
    }

    #[test]
    fn put_next_far_drop_or_wrong_object_does_not_fire() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_box(Pos::new(1, 1), Color::Green); // far away
        s.pocket[0] = Pocket::holding(Tag::BALL, Color::Purple).0;
        s.mission.fill(Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green).raw());
        intervene(&mut s, Action::Drop); // lands at (3,4), not adjacent
        assert!(!s.events[0].object_placed, "distant drop must not satisfy put-next");
        // dropping the WRONG object next to the target fires nothing
        let mut s = st.slot_mut(0);
        s.pocket[0] = Pocket::holding(Tag::KEY, Color::Yellow).0;
        s.place_player(Pos::new(2, 2), Direction::West); // drop at (2,1), adjacent to box
        intervene(&mut s, Action::Drop);
        assert!(!s.events[0].object_placed, "only the mission's moved object counts");
    }

    #[test]
    fn single_clause_completion_latches_mission_complete() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Purple);
        s.set_mission(Mission::pick_up(Tag::BALL, Color::Purple));
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].ball_picked);
        assert!(s.events[0].mission_complete, "the only clause is the final clause");
        assert_eq!(s.mission[0], -1, "completed mission clears the active clause");
    }

    #[test]
    fn open_mission_latches_door_opened() {
        use crate::core::mission::MissionVerb;
        let mut st = room();
        let mut s = st.slot_mut(0);
        let d = s.add_door(Pos::new(3, 4), Color::Red, DoorState::Closed);
        s.set_mission(Mission::open(Color::Red));
        assert_eq!(s.mission_value().verb(), Some(MissionVerb::Open));
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Open);
        assert!(s.events[0].door_opened);
        assert!(s.events[0].mission_complete);
        // Re-toggling after completion fires nothing: no active clause.
        intervene(&mut s, Action::Toggle); // open -> closed
        intervene(&mut s, Action::Toggle); // closed -> open
        assert!(!s.events[0].door_opened);
    }

    #[test]
    fn open_mission_ignores_wrong_colour_and_close() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Closed);
        s.set_mission(Mission::open(Color::Red));
        intervene(&mut s, Action::Toggle); // opens the BLUE door
        assert!(!s.events[0].door_opened, "wrong colour must not satisfy open");
        assert!(!s.events[0].mission_complete);
    }

    #[test]
    fn unlocking_an_open_mission_door_latches_both() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Locked);
        s.pocket[0] = Pocket::holding(Tag::KEY, Color::Blue).0;
        s.set_mission(Mission::open(Color::Blue));
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_unlocked);
        assert!(s.events[0].door_opened, "Locked→Open is an open too");
        assert!(s.events[0].mission_complete);
    }

    #[test]
    fn sequenced_mission_advances_clause_by_clause() {
        use crate::core::mission::{MissionClause, MissionSpec};
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Red, DoorState::Closed);
        s.add_box(Pos::new(2, 3), Color::Green);
        s.set_mission_spec(MissionSpec::then(
            MissionClause::Open { color: Color::Red },
            MissionClause::PickUp { kind: Tag::BOX, color: Color::Green },
        ));
        // Picking the clause-2 box while clause 1 is active fires nothing:
        // the active clause is Open, and PickUp events need a PickUp verb.
        intervene(&mut s, Action::Left); // face north, box at (2,3)
        intervene(&mut s, Action::Pickup);
        assert!(!s.events[0].object_picked, "clause 2 is not active yet");
        assert!(!s.events[0].mission_complete);
        // Put it back and run the sequence in order.
        intervene(&mut s, Action::Drop);
        intervene(&mut s, Action::Right); // face east again
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_opened);
        assert!(!s.events[0].mission_complete, "clause 1/2 must not complete the mission");
        assert_eq!(
            s.mission_value().raw(),
            Mission::pick_up(Tag::BOX, Color::Green).raw(),
            "the packed column advanced to clause 2"
        );
        intervene(&mut s, Action::Left);
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].object_picked);
        assert!(s.events[0].mission_complete, "clause 2/2 completes the mission");
        assert_eq!(s.mission[0], -1);
    }

    #[test]
    fn events_cleared_each_step() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Goal, Color::Green);
        intervene(&mut s, Action::Forward);
        assert!(s.events[0].goal_reached);
        intervene(&mut s, Action::Left);
        // still standing on the goal: coincidence events re-latch; but motion
        // events like ball_hit must clear.
        assert!(s.events[0].goal_reached);
        assert!(!s.events[0].ball_hit);
    }

    #[test]
    fn agents_block_and_latch_contact() {
        let mut st = BatchedState::with_agents(
            1,
            7,
            7,
            Caps { doors: 2, keys: 2, balls: 2, boxes: 1 },
            2,
        );
        {
            let mut s = st.slot_mut(0);
            s.fill_room();
            s.place_player(Pos::new(3, 3), Direction::East);
            s.place_agent(1, Pos::new(3, 4), Direction::West);
        }
        // Agent 0 walks into agent 1: mover latches agent_contact, target
        // latches contacted, and nobody moves.
        {
            let mut s = st.agent_slot_mut(0, 0);
            intervene(&mut s, Action::Forward);
            assert_eq!(s.player(), Pos::new(3, 3), "blocked by the other agent");
            assert!(s.events[0].agent_contact);
            assert!(s.events[1].contacted);
        }
        // Agent 1 then acts in the same step: the latches agent 0 set must
        // survive (only agent 0's sub-step clears the slot's events).
        {
            let mut s = st.agent_slot_mut(0, 1);
            intervene(&mut s, Action::Left);
            assert!(s.events[0].agent_contact);
            assert!(s.events[1].contacted);
        }
        // Next step: agent 0 turns away — all latches clear on its sub-step.
        {
            let mut s = st.agent_slot_mut(0, 0);
            intervene(&mut s, Action::Left);
            assert!(!s.events[0].agent_contact);
            assert!(!s.events[1].contacted);
        }
        // Agent 1 can now walk into agent 0's cell-adjacent space freely:
        // front of agent 1 (facing West) is (3,3), still occupied by agent 0.
        {
            let mut s = st.agent_slot_mut(0, 1);
            intervene(&mut s, Action::Forward);
            assert_eq!(s.player(), Pos::new(3, 4), "blocked by agent 0");
            assert!(s.events[1].agent_contact);
            assert!(s.events[0].contacted);
        }
    }
}
