//! The intervention system `I : S × A → S` (paper Appendix A): applies the
//! agent's action to the entity state with full MiniGrid semantics, and
//! latches the events that the reward/termination systems consume.

use crate::core::actions::Action;
use crate::core::components::{DoorState, Pocket};
use crate::core::entities::{CellType, Tag};
use crate::core::events::Events;
use crate::core::state::SlotMut;

/// Apply `action` to one environment slot. Returns nothing; all effects are
/// written into the slot (new player pose, entity states, event latches).
pub fn intervene(s: &mut SlotMut<'_>, action: Action) {
    *s.events = Events::NONE;
    *s.last_action = action as i32;

    match action {
        Action::Left => {
            *s.player_dir = s.dir().left() as i32;
        }
        Action::Right => {
            *s.player_dir = s.dir().right() as i32;
        }
        Action::Forward => forward(s),
        Action::Pickup => pickup(s),
        Action::Drop => drop_item(s),
        Action::Toggle => toggle(s),
        Action::Done => done(s),
    }

    // Position-coincidence events (checked after any movement).
    let p = s.player();
    match s.cell(p) {
        CellType::Goal => s.events.goal_reached = true,
        CellType::Lava => s.events.lava_fall = true,
        _ => {}
    }
}

/// `forward`: move one cell ahead if walkable. Walking into a ball latches
/// the ball-collision event (Dynamic-Obstacles failure) without moving.
fn forward(s: &mut SlotMut<'_>) {
    let front = s.front();
    if s.ball_at(front).is_some() {
        s.events.ball_hit = true;
        return;
    }
    if s.walkable(front) {
        *s.player_pos = front.encode(s.w);
    }
}

/// `pickup`: pick the pickable entity ahead into the pocket (if empty).
/// Latches the pickup-mission events: `ball_picked` (KeyCorridor),
/// `object_picked` when the item matches a pickable mission target of any
/// kind, and `wrong_pickup` when it does not (Fetch failure).
fn pickup(s: &mut SlotMut<'_>) {
    if !s.pocket_value().is_empty() {
        return;
    }
    let front = s.front();
    let picked = if let Some(k) = s.key_at(front) {
        let color = crate::core::components::Color::from_u8(s.key_color[k]);
        s.remove_key(k); // off the grid, into the pocket
        Some((Tag::KEY, color))
    } else if let Some(bl) = s.ball_at(front) {
        let color = crate::core::components::Color::from_u8(s.ball_color[bl]);
        // KeyCorridor mission: picking the target ball is the success event.
        // mission encodes the target ball colour as (Tag::BALL << 8 | color).
        if *s.mission == Pocket::holding(Tag::BALL, color).0 {
            s.events.ball_picked = true;
        }
        s.remove_ball(bl);
        Some((Tag::BALL, color))
    } else if let Some(bx) = s.box_at(front) {
        let color = crate::core::components::Color::from_u8(s.box_color[bx]);
        s.remove_box(bx);
        Some((Tag::BOX, color))
    } else {
        None
    };
    if let Some((tag, color)) = picked {
        *s.pocket = Pocket::holding(tag, color).0;
        // Pickup-mission events fire only when the mission targets a
        // pickable kind (Fetch/UnlockPickup); door missions are unaffected.
        let mission_tag = *s.mission >> 8;
        if *s.mission >= 0 && matches!(mission_tag, Tag::KEY | Tag::BALL | Tag::BOX) {
            if *s.mission == Pocket::holding(tag, color).0 {
                s.events.object_picked = true;
            } else {
                s.events.wrong_pickup = true;
            }
        }
    }
}

/// `drop`: place the held entity into the empty floor cell ahead.
fn drop_item(s: &mut SlotMut<'_>) {
    let pocket = s.pocket_value();
    if pocket.is_empty() {
        return;
    }
    let front = s.front();
    if s.cell(front) != CellType::Floor || s.occupied_by_entity(front) {
        return;
    }
    let color = pocket.color();
    let dropped = match pocket.kind_tag() {
        Tag::KEY => s.try_add_key(front, color).is_some(),
        Tag::BALL => s.try_add_ball(front, color).is_some(),
        Tag::BOX => s.try_add_box(front, color).is_some(),
        _ => false,
    };
    if dropped {
        *s.pocket = Pocket::EMPTY.0;
    }
}

/// `toggle`: doors open/close; locked doors unlock only with a matching key.
fn toggle(s: &mut SlotMut<'_>) {
    let front = s.front();
    if let Some(d) = s.door_at(front) {
        let state = DoorState::from_u8(s.door_state[d]);
        let pocket = s.pocket_value();
        match state {
            DoorState::Locked => {
                let has_matching_key = !pocket.is_empty()
                    && pocket.kind_tag() == Tag::KEY
                    && pocket.color() as u8 == s.door_color[d];
                if has_matching_key {
                    s.set_door_state(d, DoorState::Open);
                    s.events.door_unlocked = true;
                }
            }
            DoorState::Closed => s.set_door_state(d, DoorState::Open),
            DoorState::Open => s.set_door_state(d, DoorState::Closed),
        }
    }
}

/// `done`: latches the GoToDoor success event when facing a door of the
/// mission colour. mission encodes the target as (Tag::DOOR << 8 | color).
fn done(s: &mut SlotMut<'_>) {
    let front = s.front();
    if let Some(d) = s.door_at(front) {
        let target = (Tag::DOOR << 8) | s.door_color[d] as i32;
        if *s.mission == target {
            s.events.door_done = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::{Color, Direction};
    use crate::core::grid::Pos;
    use crate::core::state::{BatchedState, Caps};

    fn room() -> BatchedState {
        let mut st = BatchedState::new(1, 7, 7, Caps { doors: 2, keys: 2, balls: 2, boxes: 1 });
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(3, 3), Direction::East);
        drop(s);
        st
    }

    #[test]
    fn turns_compose() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        intervene(&mut s, Action::Left);
        assert_eq!(s.dir(), Direction::North);
        intervene(&mut s, Action::Right);
        intervene(&mut s, Action::Right);
        assert_eq!(s.dir(), Direction::South);
        assert_eq!(s.player(), Pos::new(3, 3), "turning never moves");
    }

    #[test]
    fn forward_moves_and_walls_block() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 4));
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 5));
        intervene(&mut s, Action::Forward); // wall at col 6
        assert_eq!(s.player(), Pos::new(3, 5));
    }

    #[test]
    fn goal_event_latches_on_entry() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Goal, Color::Green);
        intervene(&mut s, Action::Forward);
        assert!(s.events.goal_reached);
        assert!(!s.events.lava_fall);
    }

    #[test]
    fn lava_event_latches_on_entry() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Lava, Color::Red);
        intervene(&mut s, Action::Forward);
        assert!(s.events.lava_fall);
    }

    #[test]
    fn pickup_key_then_drop() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_key(Pos::new(3, 4), Color::Yellow);
        intervene(&mut s, Action::Pickup);
        assert!(s.key_pos.iter().all(|&k| k < 0));
        assert_eq!(s.pocket_value().kind_tag(), Tag::KEY);
        assert_eq!(s.pocket_value().color(), Color::Yellow);
        // pickup with full pocket is a no-op
        s.add_key(Pos::new(3, 4), Color::Red);
        intervene(&mut s, Action::Pickup);
        assert_eq!(s.pocket_value().color(), Color::Yellow);
        // drop is blocked by the occupied front cell, then succeeds on free
        intervene(&mut s, Action::Drop);
        assert!(!s.pocket_value().is_empty());
        intervene(&mut s, Action::Left); // face north, (2,3) free
        intervene(&mut s, Action::Drop);
        assert!(s.pocket_value().is_empty());
        assert!(s.key_at(Pos::new(2, 3)).is_some());
    }

    #[test]
    fn locked_door_needs_matching_key() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        let d = s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Locked);
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Locked);
        *s.pocket = Pocket::holding(Tag::KEY, Color::Red).0;
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Locked, "wrong colour");
        *s.pocket = Pocket::holding(Tag::KEY, Color::Blue).0;
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Open);
        // forward through the now-open door
        intervene(&mut s, Action::Forward);
        assert_eq!(s.player(), Pos::new(3, 4));
    }

    #[test]
    fn closed_door_toggles_open_and_shut() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        let d = s.add_door(Pos::new(3, 4), Color::Grey, DoorState::Closed);
        assert!(!s.walkable(Pos::new(3, 4)));
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Open);
        intervene(&mut s, Action::Toggle);
        assert_eq!(DoorState::from_u8(s.door_state[d]), DoorState::Closed);
    }

    #[test]
    fn walking_into_ball_latches_collision_without_moving() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Blue);
        intervene(&mut s, Action::Forward);
        assert!(s.events.ball_hit);
        assert_eq!(s.player(), Pos::new(3, 3));
    }

    #[test]
    fn ball_pickup_latches_mission_event() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Purple);
        *s.mission = Pocket::holding(Tag::BALL, Color::Purple).0;
        intervene(&mut s, Action::Pickup);
        assert!(s.events.ball_picked);
        assert_eq!(s.pocket_value().kind_tag(), Tag::BALL);
    }

    #[test]
    fn done_in_front_of_mission_door() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Green, DoorState::Closed);
        *s.mission = (Tag::DOOR << 8) | Color::Green as i32;
        intervene(&mut s, Action::Done);
        assert!(s.events.door_done);
        // facing elsewhere: no event
        intervene(&mut s, Action::Left);
        intervene(&mut s, Action::Done);
        assert!(!s.events.door_done);
    }

    #[test]
    fn unlocking_latches_door_unlocked() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_door(Pos::new(3, 4), Color::Blue, DoorState::Locked);
        *s.pocket = Pocket::holding(Tag::KEY, Color::Blue).0;
        intervene(&mut s, Action::Toggle);
        assert!(s.events.door_unlocked);
        // re-toggling an open/closed door is not an unlock
        intervene(&mut s, Action::Toggle); // open -> closed
        assert!(!s.events.door_unlocked);
        intervene(&mut s, Action::Toggle); // closed -> open
        assert!(!s.events.door_unlocked);
    }

    #[test]
    fn pickup_mission_object_latches_object_picked() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_box(Pos::new(3, 4), Color::Green);
        *s.mission = (Tag::BOX << 8) | Color::Green as i32;
        intervene(&mut s, Action::Pickup);
        assert!(s.events.object_picked);
        assert!(!s.events.wrong_pickup);
    }

    #[test]
    fn pickup_non_target_latches_wrong_pickup() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(3, 4), Color::Red);
        *s.mission = (Tag::KEY << 8) | Color::Blue as i32; // fetch the blue key
        intervene(&mut s, Action::Pickup);
        assert!(s.events.wrong_pickup, "wrong object picked under a pickable mission");
        assert!(!s.events.object_picked);
    }

    #[test]
    fn door_missions_do_not_fire_pickup_events() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.add_key(Pos::new(3, 4), Color::Yellow);
        *s.mission = (Tag::DOOR << 8) | Color::Yellow as i32; // GoToDoor-style mission
        intervene(&mut s, Action::Pickup);
        assert!(!s.events.object_picked);
        assert!(!s.events.wrong_pickup);
    }

    #[test]
    fn events_cleared_each_step() {
        let mut st = room();
        let mut s = st.slot_mut(0);
        s.set_cell(Pos::new(3, 4), CellType::Goal, Color::Green);
        intervene(&mut s, Action::Forward);
        assert!(s.events.goal_reached);
        intervene(&mut s, Action::Left);
        // still standing on the goal: coincidence events re-latch; but motion
        // events like ball_hit must clear.
        assert!(s.events.goal_reached);
        assert!(!s.events.ball_hit);
    }
}
