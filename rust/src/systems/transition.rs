//! The transition system `P : S × A → S` (paper Appendix A): the MDP
//! dynamics that run *after* the agent's intervention. In the MiniGrid suite
//! the only stochastic dynamic is the Dynamic-Obstacles family, where each
//! ball (a `Stochastic` entity) moves to a random adjacent free cell each
//! step; a ball moving onto the agent latches the collision event.
//!
//! The system also advances the step counter, which the batched stepper uses
//! for timeout truncation.

use crate::core::grid::Pos;
use crate::core::state::{AgentView, SlotMut};

/// Advance the MDP dynamics for one environment slot.
///
/// `stochastic_balls`: whether balls are dynamic obstacles (true for the
/// Dynamic-Obstacles family; false where balls are static pickup targets,
/// e.g. KeyCorridor).
pub fn transition(s: &mut SlotMut<'_>, stochastic_balls: bool) {
    *s.t += 1;
    if !stochastic_balls {
        return;
    }
    move_obstacles(s);
}

/// MiniGrid's DynamicObstaclesEnv moves each obstacle to a random position
/// within a ±1 neighbourhood (8-neighbourhood + stay), retrying a bounded
/// number of times; the move is skipped if no sampled cell is free.
fn move_obstacles(s: &mut SlotMut<'_>) {
    for bi in 0..s.ball_pos.len() {
        let enc = s.ball_pos[bi];
        if enc < 0 {
            continue;
        }
        let p = Pos::decode(enc, s.w);
        // Bounded rejection sampling, like MiniGrid's place_obj(..., max_tries).
        for _ in 0..8 {
            let (dr, dc) = {
                let mut rng = s.rng();
                (rng.randint(-1, 2), rng.randint(-1, 2))
            };
            let q = Pos::new(p.r + dr, p.c + dc);
            if q == p {
                break; // sampled "stay put"
            }
            if let Some(j) = s.agent_at(q) {
                // Ball ran into an agent: collision event on that agent,
                // ball stays.
                s.events[j].ball_hit = true;
                break;
            }
            if s.walkable(q) {
                s.move_ball(bi, q);
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::{Color, Direction};
    use crate::core::state::{BatchedState, Caps};

    fn room(balls: usize) -> BatchedState {
        let mut st = BatchedState::new(1, 8, 8, Caps { balls, ..Caps::default() });
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        *s.rng = 7;
        drop(s);
        st
    }

    #[test]
    fn advances_time() {
        let mut st = room(0);
        let mut s = st.slot_mut(0);
        transition(&mut s, false);
        transition(&mut s, true);
        assert_eq!(*s.t, 2);
    }

    #[test]
    fn static_balls_do_not_move() {
        let mut st = room(1);
        let mut s = st.slot_mut(0);
        let enc = {
            s.add_ball(Pos::new(4, 4), Color::Blue);
            s.ball_pos[0]
        };
        for _ in 0..10 {
            transition(&mut s, false);
        }
        assert_eq!(s.ball_pos[0], enc);
    }

    #[test]
    fn dynamic_balls_stay_on_walkable_cells() {
        let mut st = room(3);
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(4, 4), Color::Blue);
        s.add_ball(Pos::new(2, 5), Color::Blue);
        s.add_ball(Pos::new(6, 2), Color::Blue);
        for _ in 0..200 {
            transition(&mut s, true);
            for &enc in s.ball_pos.iter() {
                assert!(enc >= 0);
                let p = Pos::decode(enc, s.w);
                assert!(p.in_bounds(s.h, s.w));
                assert!(
                    s.cell(p) == crate::core::entities::CellType::Floor,
                    "ball on non-floor at {p:?}"
                );
                assert_ne!(p, s.player(), "ball may never occupy the agent cell");
            }
            // no two balls share a cell
            let mut ps: Vec<i32> = s.ball_pos.to_vec();
            ps.sort_unstable();
            ps.dedup();
            assert_eq!(ps.len(), 3);
        }
    }

    #[test]
    fn balls_do_move_eventually() {
        let mut st = room(1);
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(4, 4), Color::Blue);
        let start = s.ball_pos[0];
        let mut moved = false;
        for _ in 0..20 {
            transition(&mut s, true);
            if s.ball_pos[0] != start {
                moved = true;
                break;
            }
        }
        assert!(moved, "dynamic obstacle never moved in 20 steps");
    }

    #[test]
    fn ball_collision_with_adjacent_player_possible() {
        // Place a ball right next to the player and step many times: the
        // collision event must fire at least once (ball tries to move onto
        // the agent with positive probability).
        let mut st = room(1);
        let mut s = st.slot_mut(0);
        s.add_ball(Pos::new(1, 2), Color::Blue);
        let mut hit = false;
        for _ in 0..100 {
            s.events[0] = crate::core::events::Events::NONE;
            transition(&mut s, true);
            if s.events[0].ball_hit {
                hit = true;
                break;
            }
            // keep the ball near the player for the test's purpose
            s.move_ball(0, Pos::new(1, 2));
        }
        assert!(hit, "adjacent obstacle never collided in 100 steps");
    }
}
