//! The `HasSprite` component: procedural 32×32×3 RGB tiles (paper Table 1
//! gives sprites shape `u8[32x32x3]`).
//!
//! MiniGrid ships hand-drawn tile renderers; we reproduce them procedurally
//! (same silhouettes: grey wall block, dark floor with grid lines, green
//! goal, orange lava with waves, coloured key/ball/box/door glyphs, red
//! agent triangle oriented by direction). Tiles are pre-rendered once into a
//! [`SpriteSheet`] so the rgb observation functions are pure memcpy loops.

use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::Tag;
use std::sync::{Arc, OnceLock};

/// Tile edge length in pixels.
pub const TILE: usize = 32;
const PX: usize = TILE * TILE;

/// One rendered tile: row-major RGB.
pub type Sprite = [u8; PX * 3];

fn blank(rgb: [u8; 3]) -> Sprite {
    let mut s = [0u8; PX * 3];
    for p in 0..PX {
        s[p * 3] = rgb[0];
        s[p * 3 + 1] = rgb[1];
        s[p * 3 + 2] = rgb[2];
    }
    s
}

#[inline]
fn put(s: &mut Sprite, x: usize, y: usize, rgb: [u8; 3]) {
    let i = (y * TILE + x) * 3;
    s[i] = rgb[0];
    s[i + 1] = rgb[1];
    s[i + 2] = rgb[2];
}

fn fill_rect(s: &mut Sprite, x0: usize, y0: usize, x1: usize, y1: usize, rgb: [u8; 3]) {
    for y in y0..y1 {
        for x in x0..x1 {
            put(s, x, y, rgb);
        }
    }
}

fn floor_tile() -> Sprite {
    let mut s = blank([0, 0, 0]);
    // MiniGrid draws thin grid lines at the tile border.
    for i in 0..TILE {
        put(&mut s, i, 0, [100, 100, 100]);
        put(&mut s, 0, i, [100, 100, 100]);
    }
    s
}

fn wall_tile() -> Sprite {
    blank([100, 100, 100])
}

fn goal_tile() -> Sprite {
    blank([0, 255, 0])
}

fn lava_tile() -> Sprite {
    let mut s = blank([255, 128, 0]);
    // three dark horizontal waves
    for wave in 0..3 {
        let y0 = 6 + wave * 10;
        for x in 0..TILE {
            let dy = ((x as f32 / TILE as f32) * std::f32::consts::TAU).sin() * 2.0;
            let y = (y0 as f32 + dy) as usize;
            if y < TILE {
                put(&mut s, x, y, [0, 0, 0]);
            }
        }
    }
    s
}

fn key_tile(color: Color) -> Sprite {
    let mut s = floor_tile();
    let c = color.rgb();
    // ring
    let (cx, cy, r_out, r_in) = (14.0f32, 9.0f32, 5.0f32, 2.5f32);
    for y in 0..TILE {
        for x in 0..TILE {
            let d = ((x as f32 - cx).powi(2) + (y as f32 - cy).powi(2)).sqrt();
            if d <= r_out && d >= r_in {
                put(&mut s, x, y, c);
            }
        }
    }
    // shaft + teeth
    fill_rect(&mut s, 13, 14, 16, 26, c);
    fill_rect(&mut s, 16, 21, 20, 23, c);
    fill_rect(&mut s, 16, 24, 19, 26, c);
    s
}

fn ball_tile(color: Color) -> Sprite {
    let mut s = floor_tile();
    let c = color.rgb();
    let (cx, cy, r) = (16.0f32, 16.0f32, 10.0f32);
    for y in 0..TILE {
        for x in 0..TILE {
            if (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2) <= r * r {
                put(&mut s, x, y, c);
            }
        }
    }
    s
}

fn box_tile(color: Color) -> Sprite {
    let mut s = floor_tile();
    let c = color.rgb();
    fill_rect(&mut s, 4, 4, 28, 28, c);
    fill_rect(&mut s, 7, 7, 25, 25, [0, 0, 0]);
    fill_rect(&mut s, 4, 14, 28, 18, c); // latch band
    s
}

fn door_tile(color: Color, state: DoorState) -> Sprite {
    let c = color.rgb();
    match state {
        DoorState::Open => {
            // open door: frame only, floor visible
            let mut s = floor_tile();
            for t in 0..3 {
                for i in 0..TILE {
                    put(&mut s, i, t, c);
                    put(&mut s, i, TILE - 1 - t, c);
                    put(&mut s, t, i, c);
                    put(&mut s, TILE - 1 - t, i, c);
                }
            }
            s
        }
        DoorState::Closed | DoorState::Locked => {
            let mut s = blank([0, 0, 0]);
            fill_rect(&mut s, 1, 1, 31, 31, c);
            fill_rect(&mut s, 4, 4, 28, 28, [0, 0, 0]);
            fill_rect(&mut s, 6, 6, 26, 26, c);
            if state == DoorState::Locked {
                // keyhole
                fill_rect(&mut s, 14, 12, 18, 16, [0, 0, 0]);
                fill_rect(&mut s, 15, 16, 17, 21, [0, 0, 0]);
            } else {
                // handle
                fill_rect(&mut s, 22, 14, 26, 18, [0, 0, 0]);
            }
            s
        }
    }
}

fn agent_tile(dir: Direction) -> Sprite {
    let mut s = floor_tile();
    let c = [255, 0, 0];
    // triangle pointing along dir; define in "east" frame then rotate.
    for y in 0..TILE {
        for x in 0..TILE {
            // east-frame coordinates
            let (ex, ey) = match dir {
                Direction::East => (x as i32, y as i32),
                Direction::South => (y as i32, (TILE - 1 - x) as i32),
                Direction::West => ((TILE - 1 - x) as i32, (TILE - 1 - y) as i32),
                Direction::North => ((TILE - 1 - y) as i32, x as i32),
            };
            // triangle with apex at (26,16), base at x=6 from y=6..26
            let (ax, ay) = (26.0f32, 16.0f32);
            let (b1x, b1y) = (6.0f32, 6.0f32);
            let (b2x, b2y) = (6.0f32, 26.0f32);
            let (px, py) = (ex as f32, ey as f32);
            let sign = |x1: f32, y1: f32, x2: f32, y2: f32| -> f32 {
                (px - x2) * (y1 - y2) - (x1 - x2) * (py - y2)
            };
            let d1 = sign(ax, ay, b1x, b1y);
            let d2 = sign(b1x, b1y, b2x, b2y);
            let d3 = sign(b2x, b2y, ax, ay);
            let neg = d1 < 0.0 || d2 < 0.0 || d3 < 0.0;
            let pos = d1 > 0.0 || d2 > 0.0 || d3 > 0.0;
            if !(neg && pos) {
                put(&mut s, x, y, c);
            }
        }
    }
    s
}

fn unseen_tile() -> Sprite {
    blank([40, 40, 40])
}

/// Pre-rendered sprite registry indexed by (tag, colour, state/direction).
pub struct SpriteSheet {
    floor: Sprite,
    wall: Sprite,
    goal: Sprite,
    lava: Sprite,
    unseen: Sprite,
    keys: Vec<Sprite>,           // by colour
    balls: Vec<Sprite>,          // by colour
    boxes: Vec<Sprite>,          // by colour
    doors: Vec<Sprite>,          // by colour*3 + state
    agents: [Sprite; 4],         // by direction
}

impl SpriteSheet {
    /// The process-wide shared sheet. Tiles are immutable once rendered, so
    /// every engine — and in particular every shard of a
    /// [`ShardedEnv`](crate::batch::ShardedEnv) — clones one `Arc` instead
    /// of re-rendering its own ~140 KB sheet per shard.
    pub fn shared() -> Arc<SpriteSheet> {
        static SHEET: OnceLock<Arc<SpriteSheet>> = OnceLock::new();
        SHEET.get_or_init(|| Arc::new(SpriteSheet::new())).clone()
    }

    pub fn new() -> Self {
        let keys = Color::ALL.iter().map(|&c| key_tile(c)).collect();
        let balls = Color::ALL.iter().map(|&c| ball_tile(c)).collect();
        let boxes = Color::ALL.iter().map(|&c| box_tile(c)).collect();
        let mut doors = Vec::with_capacity(18);
        for &c in &Color::ALL {
            for st in [DoorState::Open, DoorState::Closed, DoorState::Locked] {
                doors.push(door_tile(c, st));
            }
        }
        SpriteSheet {
            floor: floor_tile(),
            wall: wall_tile(),
            goal: goal_tile(),
            lava: lava_tile(),
            unseen: unseen_tile(),
            keys,
            balls,
            boxes,
            doors,
            agents: [
                agent_tile(Direction::East),
                agent_tile(Direction::South),
                agent_tile(Direction::West),
                agent_tile(Direction::North),
            ],
        }
    }

    /// Sprite for a symbolic (tag, colour, state) triple.
    pub fn get(&self, tag: i32, color: u8, state: i32) -> &Sprite {
        let c = color as usize % 6;
        match tag {
            Tag::UNSEEN => &self.unseen,
            Tag::EMPTY | Tag::FLOOR => &self.floor,
            Tag::WALL => &self.wall,
            Tag::GOAL => &self.goal,
            Tag::LAVA => &self.lava,
            Tag::KEY => &self.keys[c],
            Tag::BALL => &self.balls[c],
            Tag::BOX => &self.boxes[c],
            Tag::DOOR => &self.doors[c * 3 + (state.clamp(0, 2) as usize)],
            Tag::AGENT => &self.agents[(state.rem_euclid(4)) as usize],
            _ => &self.unseen,
        }
    }
}

impl Default for SpriteSheet {
    fn default() -> Self {
        SpriteSheet::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sheet_builds_and_tiles_differ() {
        let sheet = SpriteSheet::new();
        assert_ne!(sheet.get(Tag::WALL, 0, 0)[..], sheet.get(Tag::EMPTY, 0, 0)[..]);
        assert_ne!(sheet.get(Tag::KEY, 0, 0)[..], sheet.get(Tag::KEY, 1, 0)[..]);
        assert_ne!(
            sheet.get(Tag::DOOR, 0, DoorState::Open as i32)[..],
            sheet.get(Tag::DOOR, 0, DoorState::Locked as i32)[..]
        );
    }

    #[test]
    fn shared_sheet_is_one_allocation() {
        let a = SpriteSheet::shared();
        let b = SpriteSheet::shared();
        assert!(Arc::ptr_eq(&a, &b), "every caller must reuse the same sheet");
    }

    #[test]
    fn agent_sprites_rotate() {
        let sheet = SpriteSheet::new();
        let east = sheet.get(Tag::AGENT, 0, 0);
        let north = sheet.get(Tag::AGENT, 0, 3);
        assert_ne!(east[..], north[..]);
    }

    #[test]
    fn goal_is_green_wall_is_grey() {
        let sheet = SpriteSheet::new();
        let g = sheet.get(Tag::GOAL, 0, 0);
        assert_eq!(&g[0..3], &[0, 255, 0]);
        let w = sheet.get(Tag::WALL, 0, 0);
        assert_eq!(&w[0..3], &[100, 100, 100]);
    }
}
