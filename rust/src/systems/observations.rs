//! The observation system `O : S → O` (paper Table 4): all six observation
//! functions, each available full-grid (MDP) or first-person (POMDP):
//!
//! | function                   | shape              | dtype |
//! |----------------------------|--------------------|-------|
//! | `symbolic`                 | `[H, W, 3]`        | i32   |
//! | `symbolic_first_person`    | `[R, R, 3]`        | i32   |
//! | `rgb`                      | `[32H, 32W, 3]`    | u8    |
//! | `rgb_first_person`         | `[32R, 32R, 3]`    | u8    |
//! | `categorical`              | `[H, W]`           | i32   |
//! | `categorical_first_person` | `[R, R]`           | i32   |
//!
//! First-person views use MiniGrid's egocentric frame (agent at the bottom
//! centre of an `R×R` window, facing "up") including the iterative
//! visibility-propagation occlusion mask, so symbolic observations are
//! byte-compatible with the original `gen_obs`.
//!
//! ## Two execution paths, one encoding
//!
//! The default path streams the state's packed **cell-code overlay grid**
//! ([`crate::core::state::cellcode`]): every cell's `(tag, colour, state)`
//! triple is a single `u32` read, so a full-grid observation is O(H·W)
//! instead of the naive O(H·W·caps) entity-table scans. The original
//! scan-based implementations are kept verbatim in [`scan`] as the
//! bitwise-parity oracle — `tests/test_obs_parity.rs` pins both paths equal
//! over the whole registry, and `benches/obs_throughput.rs` measures the
//! gap (recorded in `EXPERIMENTS.md` §Perf and `results/BENCH_obs.json`).
//!
//! For full-grid rgb the batched engine goes one step further:
//! [`rgb_incremental`] re-blits only the tiles whose render code changed
//! since the previous frame (dirty-tile rendering), turning the per-step
//! `32H × 32W` blit into a handful of tile blits.
//!
//! ## SIMD
//!
//! The overlay path's full-grid streaming loops ([`symbolic`],
//! [`categorical`]) additionally dispatch on a [`KernelPath`]: AVX2
//! unpacks 8 packed cell codes per lane-group (SSE2: 4), with the scalar
//! loop as both the universal fallback and the tail handler for
//! `H·W mod lanes ≠ 0`. All ops are integer ops, so the vector paths are
//! *bitwise* identical to the scalar loop — pinned per forced path by
//! `tests/test_obs_parity.rs` and the CI `simd-matrix` job. The resolved
//! ([`ObsPath`], [`KernelPath`]) pair is an [`ObsRoute`], computed once
//! per engine by [`ObsPath::route`] and threaded through every writer.

use crate::core::components::Direction;
use crate::core::entities::{CellType, Tag};
use crate::core::grid::Pos;
use crate::core::mission::{CLAUSE_BASE, CLAUSE_STRIDE, MISSION_TOKENS};
use crate::core::state::{cellcode, AgentView, EnvSlot};
use crate::simd::{self, KernelPath};
use crate::systems::sprites::{Sprite, SpriteSheet, TILE};

/// Default egocentric window edge (MiniGrid's `agent_view_size`).
pub const VIEW: usize = 7;

/// Which observation function an environment uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsKind {
    Symbolic,
    SymbolicFirstPerson,
    Rgb,
    RgbFirstPerson,
    Categorical,
    CategoricalFirstPerson,
}

impl ObsKind {
    pub fn name(self) -> &'static str {
        match self {
            ObsKind::Symbolic => "symbolic",
            ObsKind::SymbolicFirstPerson => "symbolic_first_person",
            ObsKind::Rgb => "rgb",
            ObsKind::RgbFirstPerson => "rgb_first_person",
            ObsKind::Categorical => "categorical",
            ObsKind::CategoricalFirstPerson => "categorical_first_person",
        }
    }

    pub fn is_rgb(self) -> bool {
        matches!(self, ObsKind::Rgb | ObsKind::RgbFirstPerson)
    }
}

/// Which implementation computes the observation: the O(1)-per-cell
/// overlay-grid path (the default) or the original naive entity-table
/// scans. The scan path is the parity oracle — it exists so tests and the
/// `obs_throughput` bench can pin and measure the overlay path against it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObsPath {
    #[default]
    Overlay,
    NaiveScan,
}

impl ObsPath {
    /// Resolve this path to a concrete [`ObsRoute`] — the single place the
    /// SIMD kernel selection enters the observation layer. The overlay path
    /// picks the process-wide [`simd::active`] kernel; the scan oracle has
    /// no kernel axis.
    pub fn route(self) -> ObsRoute {
        match self {
            ObsPath::Overlay => ObsRoute::Overlay(simd::active()),
            ObsPath::NaiveScan => ObsRoute::Scan,
        }
    }
}

/// A fully-resolved observation route: which implementation runs *and*, on
/// the overlay path, which SIMD kernel its streaming loops use. Engines
/// resolve an [`ObsPath`] once ([`ObsPath::route`]) and thread the route
/// through every writer; the parity suite constructs forced routes
/// (`ObsRoute::Overlay(KernelPath::…)`) to sweep every kernel in one
/// process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObsRoute {
    /// Overlay-grid streaming writers on the given kernel path.
    Overlay(KernelPath),
    /// The naive entity-table scan oracle (always scalar).
    Scan,
}

impl Default for ObsRoute {
    fn default() -> Self {
        ObsPath::default().route()
    }
}

/// Observation spec: function kind + egocentric window size.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ObsSpec {
    pub kind: ObsKind,
    pub view: usize,
}

impl ObsSpec {
    pub fn new(kind: ObsKind) -> Self {
        ObsSpec { kind, view: VIEW }
    }

    /// Observation shape for a grid of `h × w`.
    pub fn shape(&self, h: usize, w: usize) -> Vec<usize> {
        let r = self.view;
        match self.kind {
            ObsKind::Symbolic => vec![h, w, 3],
            ObsKind::SymbolicFirstPerson => vec![r, r, 3],
            ObsKind::Rgb => vec![TILE * h, TILE * w, 3],
            ObsKind::RgbFirstPerson => vec![TILE * r, TILE * r, 3],
            ObsKind::Categorical => vec![h, w],
            ObsKind::CategoricalFirstPerson => vec![r, r],
        }
    }

    /// Flat element count per env.
    pub fn len(&self, h: usize, w: usize) -> usize {
        self.shape(h, w).iter().product()
    }

    /// Write the observation for one env into `out` (i32 kinds, overlay
    /// path). Panics if called on an rgb kind.
    pub fn write_i32(&self, s: &EnvSlot<'_>, out: &mut [i32]) {
        self.write_i32_path(ObsPath::Overlay, s, out)
    }

    /// Write the observation for one env into `out` (u8 / rgb kinds,
    /// overlay path, full render).
    pub fn write_u8(&self, s: &EnvSlot<'_>, sheet: &SpriteSheet, out: &mut [u8]) {
        self.write_u8_path(ObsPath::Overlay, s, sheet, out)
    }

    /// Path-explicit i32 writer (tests/benches pick the scan oracle here).
    /// The kernel path is resolved once via [`ObsPath::route`].
    pub fn write_i32_path(&self, path: ObsPath, s: &EnvSlot<'_>, out: &mut [i32]) {
        self.write_i32_route(path.route(), s, out)
    }

    /// Route-explicit i32 writer — the parity suite forces specific SIMD
    /// kernels here via `ObsRoute::Overlay(KernelPath::…)`.
    pub fn write_i32_route(&self, route: ObsRoute, s: &EnvSlot<'_>, out: &mut [i32]) {
        match (route, self.kind) {
            (ObsRoute::Overlay(kp), ObsKind::Symbolic) => symbolic_kernel(kp, s, out),
            (ObsRoute::Overlay(_), ObsKind::SymbolicFirstPerson) => {
                symbolic_first_person(s, self.view, out)
            }
            (ObsRoute::Overlay(kp), ObsKind::Categorical) => categorical_kernel(kp, s, out),
            (ObsRoute::Overlay(_), ObsKind::CategoricalFirstPerson) => {
                categorical_first_person(s, self.view, out)
            }
            (ObsRoute::Scan, ObsKind::Symbolic) => scan::symbolic(s, out),
            (ObsRoute::Scan, ObsKind::SymbolicFirstPerson) => {
                scan::symbolic_first_person(s, self.view, out)
            }
            (ObsRoute::Scan, ObsKind::Categorical) => scan::categorical(s, out),
            (ObsRoute::Scan, ObsKind::CategoricalFirstPerson) => {
                scan::categorical_first_person(s, self.view, out)
            }
            _ => panic!("write_i32 called on rgb observation kind"),
        }
    }

    /// Write the tokenised mission block for one env into `out`
    /// (`MISSION_TOKENS` i32s). Every observation kind carries this side
    /// channel — it conditions the policy on the goal, it is not part of
    /// the grid encoding. Dispatches like the grid writers so the parity
    /// suite can pin the streamed slab against the bit-level scan oracle.
    pub fn write_mission_path(&self, path: ObsPath, s: &EnvSlot<'_>, out: &mut [i32]) {
        self.write_mission_route(path.route(), s, out)
    }

    /// Route-explicit mission writer. The block is `MISSION_TOKENS` i32s —
    /// a scalar-tail copy, too small to vectorise, so every kernel path
    /// runs the same encoder and only the overlay/scan axis matters.
    pub fn write_mission_route(&self, route: ObsRoute, s: &EnvSlot<'_>, out: &mut [i32]) {
        match route {
            ObsRoute::Overlay(_) => mission_features(s, out),
            ObsRoute::Scan => scan::mission_features(s, out),
        }
    }

    /// Path-explicit u8 writer (tests/benches pick the scan oracle here).
    pub fn write_u8_path(
        &self,
        path: ObsPath,
        s: &EnvSlot<'_>,
        sheet: &SpriteSheet,
        out: &mut [u8],
    ) {
        self.write_u8_route(path.route(), s, sheet, out)
    }

    /// Route-explicit u8 writer. Rgb blits are sprite copies, not unpack
    /// loops — the kernel path has no rgb axis, only overlay/scan.
    pub fn write_u8_route(
        &self,
        route: ObsRoute,
        s: &EnvSlot<'_>,
        sheet: &SpriteSheet,
        out: &mut [u8],
    ) {
        match (route, self.kind) {
            (ObsRoute::Overlay(_), ObsKind::Rgb) => rgb(s, sheet, out),
            (ObsRoute::Overlay(_), ObsKind::RgbFirstPerson) => {
                rgb_first_person(s, self.view, sheet, out)
            }
            (ObsRoute::Scan, ObsKind::Rgb) => scan::rgb(s, sheet, out),
            (ObsRoute::Scan, ObsKind::RgbFirstPerson) => {
                scan::rgb_first_person(s, self.view, sheet, out)
            }
            _ => panic!("write_u8 called on symbolic observation kind"),
        }
    }
}

/// Symbolic (tag, colour, state) encoding of the cell at `p`, optionally
/// overlaying the player (MiniGrid `encode` semantics; the agent's state
/// channel is its direction, its colour channel its agent index). Other
/// agents in the slot are always encoded — a first-person view hides the
/// viewer itself (`include_player = false`) but still sees its peers.
/// O(1): a single packed overlay read for any in-grid cell; out-of-range
/// positions fall back to the scan oracle, which this function matches bit
/// for bit (see [`scan::encode_cell`]).
#[inline]
pub fn encode_cell(s: &EnvSlot<'_>, p: Pos, include_player: bool) -> (i32, i32, i32) {
    if include_player && p == s.player() {
        return (Tag::AGENT, s.agent as i32, s.player_dir_value());
    }
    if let Some(j) = s.other_agent_at(p) {
        return (Tag::AGENT, j as i32, s.player_dir[j]);
    }
    if p.in_bounds(s.h, s.w) {
        let code = s.overlay[(p.r as usize) * s.w + p.c as usize];
        return (cellcode::tag(code), cellcode::color(code), cellcode::state(code));
    }
    scan::encode_cell(s, p, include_player)
}

/// Mission token block of one env: the active agent's serialised
/// [`crate::core::mission::MissionSpec`] streamed verbatim from the state's
/// token slab. O(MISSION_TOKENS) memcpy — the overlay path's writer.
#[inline]
pub fn mission_features(s: &EnvSlot<'_>, out: &mut [i32]) {
    debug_assert_eq!(out.len(), MISSION_TOKENS);
    out.copy_from_slice(s.mission_tokens_row());
}

/// The render code of flat cell `cell`: the packed overlay code with the
/// player overlaid (full-grid views include the agent). This is the value
/// the dirty-tile cache compares frames by.
#[inline]
pub fn render_code(s: &EnvSlot<'_>, cell: usize) -> u32 {
    if let Some(j) = s.player_pos.iter().position(|&pp| pp == cell as i32) {
        cellcode::pack(Tag::AGENT, j as u8, s.player_dir[j] as u8)
    } else {
        s.overlay[cell]
    }
}

/// `symbolic`: the canonical full-grid MiniGrid encoding, i32[H, W, 3].
/// One streaming pass over the overlay plus a single player overwrite, on
/// the process-wide SIMD path.
pub fn symbolic(s: &EnvSlot<'_>, out: &mut [i32]) {
    symbolic_kernel(simd::active(), s, out)
}

/// [`symbolic`] on an explicit kernel path: the streaming unpack runs 8
/// (avx2) / 4 (sse2) cells per lane-group, bitwise identical on every
/// path (see [`kernels`]). The per-agent player overwrite stays scalar —
/// it touches `A` cells, not `H·W`.
pub fn symbolic_kernel(kp: KernelPath, s: &EnvSlot<'_>, out: &mut [i32]) {
    debug_assert_eq!(out.len(), s.h * s.w * 3);
    kernels::unpack3(kp, s.overlay, out);
    for (j, &pp) in s.player_pos.iter().enumerate() {
        if pp >= 0 && (pp as usize) < s.overlay.len() {
            let i = pp as usize * 3;
            out[i] = Tag::AGENT;
            out[i + 1] = j as i32;
            out[i + 2] = s.player_dir[j];
        }
    }
}

/// `categorical`: entity tag per cell, i32[H, W]. One streaming pass over
/// the overlay plus a single player overwrite, on the process-wide SIMD
/// path.
pub fn categorical(s: &EnvSlot<'_>, out: &mut [i32]) {
    categorical_kernel(simd::active(), s, out)
}

/// [`categorical`] on an explicit kernel path (see [`kernels`]).
pub fn categorical_kernel(kp: KernelPath, s: &EnvSlot<'_>, out: &mut [i32]) {
    debug_assert_eq!(out.len(), s.h * s.w);
    kernels::unpack_tags(kp, s.overlay, out);
    for &pp in s.player_pos.iter() {
        if pp >= 0 && (pp as usize) < s.overlay.len() {
            out[pp as usize] = Tag::AGENT;
        }
    }
}

/// The streaming overlay-unpack kernels behind [`symbolic`] and
/// [`categorical`] — the only SIMD code in the observation layer.
///
/// Lane layout (avx2; sse2 is the same picture at half width): one
/// unaligned load pulls 8 packed cell codes, three shift+mask ops split
/// them into planar tag/colour/state vectors, and — for `symbolic` —
/// three cross-lane permutes plus two byte-blends per output vector
/// re-interleave the planes into the `[t, c, s]`-per-cell layout of the
/// observation buffer (sse2 has no byte-blend, so it re-interleaves with
/// shuffles and and/or masks). Every operation is an integer operation:
/// the vector paths are *bitwise* equal to the scalar loop by
/// construction, with no rounding argument needed (contrast the GEMM
/// kernels in `nn/mlp.rs`, where identity relies on fixed reduction order
/// and no FMA). Cell counts not divisible by the lane count fall through
/// to the scalar loop for the tail.
///
/// `unsafe` is confined to this module (the workspace denies it
/// elsewhere): the only unsafe operations are `std::arch` intrinsics and
/// raw-pointer loads/stores whose bounds are established by the
/// `cell + LANES <= n` loop guards, and every `#[target_feature]` entry
/// point is reachable only after [`simd::effective`] clamps the requested
/// path to what the CPU probe found.
#[allow(unsafe_code)]
pub mod kernels {
    use crate::core::state::cellcode;
    use crate::simd::{self, KernelPath};

    /// `out[cell] = tag(code)` for every overlay cell — the `categorical`
    /// streaming unpack.
    pub fn unpack_tags(kp: KernelPath, overlay: &[u32], out: &mut [i32]) {
        debug_assert!(out.len() >= overlay.len());
        match simd::effective(kp) {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => unsafe { unpack_tags_avx2(overlay, out) },
            #[cfg(target_arch = "x86_64")]
            KernelPath::Sse2 => unsafe { unpack_tags_sse2(overlay, out) },
            _ => unpack_tags_scalar(overlay, out),
        }
    }

    /// `out[cell*3 ..][..3] = (tag, colour, state)` for every overlay
    /// cell — the `symbolic` streaming unpack.
    pub fn unpack3(kp: KernelPath, overlay: &[u32], out: &mut [i32]) {
        debug_assert!(out.len() >= overlay.len() * 3);
        match simd::effective(kp) {
            #[cfg(target_arch = "x86_64")]
            KernelPath::Avx2 => unsafe { unpack3_avx2(overlay, out) },
            #[cfg(target_arch = "x86_64")]
            KernelPath::Sse2 => unsafe { unpack3_sse2(overlay, out) },
            _ => unpack3_scalar(overlay, out),
        }
    }

    fn unpack_tags_scalar(overlay: &[u32], out: &mut [i32]) {
        for (cell, &code) in overlay.iter().enumerate() {
            out[cell] = cellcode::tag(code);
        }
    }

    fn unpack3_scalar(overlay: &[u32], out: &mut [i32]) {
        for (cell, &code) in overlay.iter().enumerate() {
            out[cell * 3] = cellcode::tag(code);
            out[cell * 3 + 1] = cellcode::color(code);
            out[cell * 3 + 2] = cellcode::state(code);
        }
    }

    /// # Safety
    /// The CPU must support avx2 and `out.len() >= overlay.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn unpack_tags_avx2(overlay: &[u32], out: &mut [i32]) {
        use std::arch::x86_64::*;
        let n = overlay.len();
        let byte = _mm256_set1_epi32(0xFF);
        let mut cell = 0usize;
        while cell + 8 <= n {
            let v = _mm256_loadu_si256(overlay.as_ptr().add(cell) as *const __m256i);
            let t = _mm256_and_si256(v, byte);
            _mm256_storeu_si256(out.as_mut_ptr().add(cell) as *mut __m256i, t);
            cell += 8;
        }
        unpack_tags_scalar(&overlay[cell..], &mut out[cell..]);
    }

    /// # Safety
    /// The CPU must support sse2 and `out.len() >= overlay.len()`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "sse2")]
    unsafe fn unpack_tags_sse2(overlay: &[u32], out: &mut [i32]) {
        use std::arch::x86_64::*;
        let n = overlay.len();
        let byte = _mm_set1_epi32(0xFF);
        let mut cell = 0usize;
        while cell + 4 <= n {
            let v = _mm_loadu_si128(overlay.as_ptr().add(cell) as *const __m128i);
            let t = _mm_and_si128(v, byte);
            _mm_storeu_si128(out.as_mut_ptr().add(cell) as *mut __m128i, t);
            cell += 4;
        }
        unpack_tags_scalar(&overlay[cell..], &mut out[cell..]);
    }

    /// # Safety
    /// The CPU must support avx2 and `out.len() >= overlay.len() * 3`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn unpack3_avx2(overlay: &[u32], out: &mut [i32]) {
        use std::arch::x86_64::*;
        let n = overlay.len();
        let byte = _mm256_set1_epi32(0xFF);
        // 8 cells unpack to 24 i32s = 3 output vectors. The cell index
        // feeding each output lane is the same for all three planes (the
        // don't-care lanes of each permute are masked off by the blends):
        //   r0 lanes: t0 c0 s0 t1 c1 s1 t2 c2   ← cells 0 0 0 1 1 1 2 2
        //   r1 lanes: s2 t3 c3 s3 t4 c4 s4 t5   ← cells 2 3 3 3 4 4 4 5
        //   r2 lanes: c5 s5 t6 c6 s6 t7 c7 s7   ← cells 5 5 6 6 6 7 7 7
        let i0 = _mm256_setr_epi32(0, 0, 0, 1, 1, 1, 2, 2);
        let i1 = _mm256_setr_epi32(2, 3, 3, 3, 4, 4, 4, 5);
        let i2 = _mm256_setr_epi32(5, 5, 6, 6, 6, 7, 7, 7);
        // Per-output plane selectors: a lane of -1 (all bytes set) makes
        // `_mm256_blendv_epi8` take that whole lane from the colour/state
        // permute; unselected lanes keep the tag permute.
        let on = -1i32;
        let c0 = _mm256_setr_epi32(0, on, 0, 0, on, 0, 0, on);
        let s0 = _mm256_setr_epi32(0, 0, on, 0, 0, on, 0, 0);
        let c1 = _mm256_setr_epi32(0, 0, on, 0, 0, on, 0, 0);
        let s1 = _mm256_setr_epi32(on, 0, 0, on, 0, 0, on, 0);
        let c2 = _mm256_setr_epi32(on, 0, 0, on, 0, 0, on, 0);
        let s2 = _mm256_setr_epi32(0, on, 0, 0, on, 0, 0, on);
        let mut cell = 0usize;
        while cell + 8 <= n {
            let v = _mm256_loadu_si256(overlay.as_ptr().add(cell) as *const __m256i);
            let t = _mm256_and_si256(v, byte);
            let c = _mm256_and_si256(_mm256_srli_epi32(v, 8), byte);
            let s = _mm256_and_si256(_mm256_srli_epi32(v, 16), byte);
            let dst = out.as_mut_ptr().add(cell * 3);
            let r0 = _mm256_blendv_epi8(
                _mm256_blendv_epi8(
                    _mm256_permutevar8x32_epi32(t, i0),
                    _mm256_permutevar8x32_epi32(c, i0),
                    c0,
                ),
                _mm256_permutevar8x32_epi32(s, i0),
                s0,
            );
            let r1 = _mm256_blendv_epi8(
                _mm256_blendv_epi8(
                    _mm256_permutevar8x32_epi32(t, i1),
                    _mm256_permutevar8x32_epi32(c, i1),
                    c1,
                ),
                _mm256_permutevar8x32_epi32(s, i1),
                s1,
            );
            let r2 = _mm256_blendv_epi8(
                _mm256_blendv_epi8(
                    _mm256_permutevar8x32_epi32(t, i2),
                    _mm256_permutevar8x32_epi32(c, i2),
                    c2,
                ),
                _mm256_permutevar8x32_epi32(s, i2),
                s2,
            );
            _mm256_storeu_si256(dst as *mut __m256i, r0);
            _mm256_storeu_si256(dst.add(8) as *mut __m256i, r1);
            _mm256_storeu_si256(dst.add(16) as *mut __m256i, r2);
            cell += 8;
        }
        unpack3_scalar(&overlay[cell..], &mut out[cell * 3..]);
    }

    /// # Safety
    /// The CPU must support sse2 and `out.len() >= overlay.len() * 3`.
    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "sse2")]
    unsafe fn unpack3_sse2(overlay: &[u32], out: &mut [i32]) {
        use std::arch::x86_64::*;
        let n = overlay.len();
        let byte = _mm_set1_epi32(0xFF);
        // 4 cells unpack to 12 i32s = 3 output vectors:
        //   r0: t0 c0 s0 t1    r1: c1 s1 t2 c2    r2: s2 t3 c3 s3
        // sse2 lacks a byte-blend, so each output combines three shuffled
        // plane vectors with and/or masks. By symmetry every output uses
        // the same three masks with rotating plane roles: the plane in
        // lanes {0, 3}, the plane in lane {1}, the plane in lane {2}.
        let on = -1i32;
        let m03 = _mm_setr_epi32(on, 0, 0, on);
        let m1 = _mm_setr_epi32(0, on, 0, 0);
        let m2 = _mm_setr_epi32(0, 0, on, 0);
        let mut cell = 0usize;
        while cell + 4 <= n {
            let v = _mm_loadu_si128(overlay.as_ptr().add(cell) as *const __m128i);
            let t = _mm_and_si128(v, byte);
            let c = _mm_and_si128(_mm_srli_epi32(v, 8), byte);
            let s = _mm_and_si128(_mm_srli_epi32(v, 16), byte);
            let dst = out.as_mut_ptr().add(cell * 3);
            // r0 = [t0, c0, s0, t1]: t in lanes {0,3}, c in {1}, s in {2}.
            let r0 = _mm_or_si128(
                _mm_or_si128(
                    _mm_and_si128(_mm_shuffle_epi32(t, 0b01_00_00_00), m03),
                    _mm_and_si128(_mm_shuffle_epi32(c, 0b00_00_00_00), m1),
                ),
                _mm_and_si128(_mm_shuffle_epi32(s, 0b00_00_00_00), m2),
            );
            // r1 = [c1, s1, t2, c2]: c in lanes {0,3}, s in {1}, t in {2}.
            let r1 = _mm_or_si128(
                _mm_or_si128(
                    _mm_and_si128(_mm_shuffle_epi32(c, 0b10_01_01_01), m03),
                    _mm_and_si128(_mm_shuffle_epi32(s, 0b01_01_01_01), m1),
                ),
                _mm_and_si128(_mm_shuffle_epi32(t, 0b10_10_10_10), m2),
            );
            // r2 = [s2, t3, c3, s3]: s in lanes {0,3}, t in {1}, c in {2}.
            let r2 = _mm_or_si128(
                _mm_or_si128(
                    _mm_and_si128(_mm_shuffle_epi32(s, 0b11_10_10_10), m03),
                    _mm_and_si128(_mm_shuffle_epi32(t, 0b11_11_11_11), m1),
                ),
                _mm_and_si128(_mm_shuffle_epi32(c, 0b11_11_11_11), m2),
            );
            _mm_storeu_si128(dst as *mut __m128i, r0);
            _mm_storeu_si128(dst.add(4) as *mut __m128i, r1);
            _mm_storeu_si128(dst.add(8) as *mut __m128i, r2);
            cell += 4;
        }
        unpack3_scalar(&overlay[cell..], &mut out[cell * 3..]);
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // Deterministic pseudo-overlay: arbitrary u32 patterns, including
        // codes with bits above the state byte set.
        fn overlay(n: usize, seed: u32) -> Vec<u32> {
            let mut x = seed | 1;
            (0..n)
                .map(|_| {
                    x ^= x << 13;
                    x ^= x >> 17;
                    x ^= x << 5;
                    x
                })
                .collect()
        }

        #[test]
        fn every_kernel_matches_scalar_on_every_tail_length() {
            // Lengths straddling the 8/4 lane groups: 0..=17 covers every
            // tail residue for both widths, plus a longer run.
            for n in (0..=17).chain([64, 65, 127]) {
                let ov = overlay(n, 0x9E3779B9 ^ n as u32);
                let mut want3 = vec![0i32; n * 3];
                let mut want1 = vec![0i32; n];
                unpack3_scalar(&ov, &mut want3);
                unpack_tags_scalar(&ov, &mut want1);
                for kp in KernelPath::ALL {
                    if !kp.supported() {
                        continue;
                    }
                    let mut got3 = vec![-1i32; n * 3];
                    let mut got1 = vec![-1i32; n];
                    unpack3(kp, &ov, &mut got3);
                    unpack_tags(kp, &ov, &mut got1);
                    assert_eq!(got3, want3, "unpack3 {} n={n}", kp.name());
                    assert_eq!(got1, want1, "unpack_tags {} n={n}", kp.name());
                }
            }
        }
    }
}

/// Map a first-person view coordinate to a world position. The agent sits at
/// view row `R−1`, column `R/2`, facing view-"north" (decreasing view row).
#[inline]
pub fn view_to_world(player: Pos, dir: Direction, view: usize, vr: usize, vc: usize) -> Pos {
    let fo = (view - 1 - vr) as i32; // forward offset
    let ro = vc as i32 - (view / 2) as i32; // rightward offset
    let f = dir.vec();
    let r = dir.rightward().vec();
    Pos::new(player.r + f.0 * fo + r.0 * ro, player.c + f.1 * fo + r.1 * ro)
}

/// Precomputed egocentric frame: world coordinates, transparency and the
/// visibility mask for every view cell, computed once per observation.
/// (Perf: the naive formulation re-derived `view_to_world` and re-scanned
/// entity tables ~150×/env/step; hoisting them here cut the first-person
/// observation cost by ~2× — see EXPERIMENTS.md §Perf. The overlay grid
/// then made each remaining per-cell probe O(1).)
pub struct ViewFrame {
    pub wr: [i32; VIEW * VIEW],
    pub wc: [i32; VIEW * VIEW],
    pub visible: [bool; VIEW * VIEW],
}

impl ViewFrame {
    /// Build the frame: coordinates, per-cell transparency, then MiniGrid's
    /// iterative visibility propagation (`process_vis`). Overlay path.
    pub fn compute(s: &EnvSlot<'_>, view: usize) -> ViewFrame {
        Self::compute_impl(s, view, EnvSlot::opaque)
    }

    /// Scan-oracle frame: identical propagation over `opaque_scan`.
    pub fn compute_scan(s: &EnvSlot<'_>, view: usize) -> ViewFrame {
        Self::compute_impl(s, view, EnvSlot::opaque_scan)
    }

    fn compute_impl<'a>(
        s: &EnvSlot<'a>,
        view: usize,
        opaque: fn(&EnvSlot<'a>, Pos) -> bool,
    ) -> ViewFrame {
        debug_assert!(view <= VIEW);
        let mut f = ViewFrame {
            wr: [0; VIEW * VIEW],
            wc: [0; VIEW * VIEW],
            visible: [false; VIEW * VIEW],
        };
        let player = s.player();
        let dir = s.dir();
        let fv = dir.vec();
        let rv = dir.rightward().vec();
        let half = (view / 2) as i32;
        let mut transparent = [false; VIEW * VIEW];
        for vr in 0..view {
            let fo = (view - 1 - vr) as i32;
            let base_r = player.r + fv.0 * fo - rv.0 * half;
            let base_c = player.c + fv.1 * fo - rv.1 * half;
            for vc in 0..view {
                let i = vr * view + vc;
                let r = base_r + rv.0 * vc as i32;
                let c = base_c + rv.1 * vc as i32;
                f.wr[i] = r;
                f.wc[i] = c;
                let p = Pos::new(r, c);
                transparent[i] = p.in_bounds(s.h, s.w) && !opaque(s, p);
            }
        }

        let agent = (view - 1) * view + view / 2;
        f.visible[agent] = true;
        for vr in (0..view).rev() {
            // sweep left → right
            for vc in 0..view - 1 {
                let i = vr * view + vc;
                if f.visible[i] && transparent[i] {
                    f.visible[i + 1] = true;
                    if vr > 0 {
                        f.visible[i - view] = true;
                        f.visible[i - view + 1] = true;
                    }
                }
            }
            // sweep right → left
            for vc in (1..view).rev() {
                let i = vr * view + vc;
                if f.visible[i] && transparent[i] {
                    f.visible[i - 1] = true;
                    if vr > 0 {
                        f.visible[i - view] = true;
                        f.visible[i - view - 1] = true;
                    }
                }
            }
        }
        f
    }
}

/// MiniGrid's iterative visibility propagation (`process_vis`): light flows
/// from the agent cell outward through transparent cells. Returns an `R×R`
/// boolean mask in view coordinates (row-major). (Compatibility wrapper
/// around [`ViewFrame::compute`].)
pub fn visibility_mask(s: &EnvSlot<'_>, view: usize, mask: &mut [bool]) {
    debug_assert_eq!(mask.len(), view * view);
    let f = ViewFrame::compute(s, view);
    mask.copy_from_slice(&f.visible[..view * view]);
}

/// Encode one first-person view cell from a precomputed frame (the agent's
/// own cell shows the carried object, as in MiniGrid's `gen_obs`),
/// parametrised by the per-cell encoder so the overlay and scan paths share
/// the frame logic.
#[inline]
fn encode_frame_cell_with(
    s: &EnvSlot<'_>,
    f: &ViewFrame,
    view: usize,
    i: usize,
    enc: fn(&EnvSlot<'_>, Pos, bool) -> (i32, i32, i32),
) -> (i32, i32, i32) {
    if !f.visible[i] {
        return (Tag::UNSEEN, 0, 0);
    }
    if i == (view - 1) * view + view / 2 {
        let pocket = s.pocket_value();
        if !pocket.is_empty() {
            return (pocket.kind_tag(), pocket.color() as i32, 0);
        }
        return enc(s, s.player(), false);
    }
    let p = Pos::new(f.wr[i], f.wc[i]);
    if !p.in_bounds(s.h, s.w) {
        return (Tag::UNSEEN, 0, 0);
    }
    enc(s, p, false)
}

/// `symbolic_first_person`: egocentric window with occlusion, i32[R, R, 3].
pub fn symbolic_first_person(s: &EnvSlot<'_>, view: usize, out: &mut [i32]) {
    debug_assert_eq!(out.len(), view * view * 3);
    let f = ViewFrame::compute(s, view);
    for i in 0..view * view {
        let (t, col, st) = encode_frame_cell_with(s, &f, view, i, encode_cell);
        out[i * 3] = t;
        out[i * 3 + 1] = col;
        out[i * 3 + 2] = st;
    }
}

/// `categorical_first_person`: egocentric tags, i32[R, R].
pub fn categorical_first_person(s: &EnvSlot<'_>, view: usize, out: &mut [i32]) {
    debug_assert_eq!(out.len(), view * view);
    let f = ViewFrame::compute(s, view);
    for i in 0..view * view {
        out[i] = encode_frame_cell_with(s, &f, view, i, encode_cell).0;
    }
}

/// Blit a 32×32 sprite into an image of `cols` tile columns.
#[inline]
fn blit(out: &mut [u8], cols: usize, tr: usize, tc: usize, sprite: &[u8]) {
    let row_px = cols * TILE * 3;
    for y in 0..TILE {
        let dst = (tr * TILE + y) * row_px + tc * TILE * 3;
        let src = y * TILE * 3;
        out[dst..dst + TILE * 3].copy_from_slice(&sprite[src..src + TILE * 3]);
    }
}

/// Sprite for a packed render code.
#[inline]
fn sprite_for<'a>(sheet: &'a SpriteSheet, code: u32) -> &'a Sprite {
    sheet.get(cellcode::tag(code), cellcode::color(code) as u8, cellcode::state(code))
}

/// `rgb`: fully-visible image, u8[32H, 32W, 3] (from-scratch render).
pub fn rgb(s: &EnvSlot<'_>, sheet: &SpriteSheet, out: &mut [u8]) {
    debug_assert_eq!(out.len(), s.h * s.w * TILE * TILE * 3);
    for r in 0..s.h {
        for c in 0..s.w {
            let code = render_code(s, r * s.w + c);
            blit(out, s.w, r, c, sprite_for(sheet, code));
        }
    }
}

/// Dirty-tile `rgb`: re-blit only the tiles whose render code differs from
/// `prev` (the per-env cache of the codes the image in `out` currently
/// shows; seed it with [`cellcode::INVALID`] to force a full render).
/// Updates `prev` in place. After a full render at reset, a step re-blits
/// only the handful of cells that actually changed — the agent's old and
/// new cell, a toggled door, a moved obstacle — instead of all `H·W` tiles.
pub fn rgb_incremental(s: &EnvSlot<'_>, sheet: &SpriteSheet, prev: &mut [u32], out: &mut [u8]) {
    debug_assert_eq!(prev.len(), s.h * s.w);
    debug_assert_eq!(out.len(), s.h * s.w * TILE * TILE * 3);
    for r in 0..s.h {
        for c in 0..s.w {
            let cell = r * s.w + c;
            let code = render_code(s, cell);
            if prev[cell] != code {
                prev[cell] = code;
                blit(out, s.w, r, c, sprite_for(sheet, code));
            }
        }
    }
}

/// `rgb_first_person`: egocentric image with occlusion, u8[32R, 32R, 3].
pub fn rgb_first_person(s: &EnvSlot<'_>, view: usize, sheet: &SpriteSheet, out: &mut [u8]) {
    debug_assert_eq!(out.len(), view * view * TILE * TILE * 3);
    let f = ViewFrame::compute(s, view);
    for vr in 0..view {
        for vc in 0..view {
            let (t, col, st) = encode_frame_cell_with(s, &f, view, vr * view + vc, encode_cell);
            blit(out, view, vr, vc, sheet.get(t, col as u8, st));
        }
    }
}

/// The naive-scan oracle: the original O(caps)-per-cell implementations of
/// every observation function, kept verbatim so the overlay path has a
/// bitwise reference. `tests/test_obs_parity.rs` pins overlay == scan over
/// the full registry; `benches/obs_throughput.rs` measures the speedup.
pub mod scan {
    use super::*;

    /// Scan-path oracle for [`super::mission_features`]: starts from the
    /// token slab but *rebuilds the active clause's tokens from the packed
    /// mission i32* with a bit-level decode (no `Mission`/`MissionSpec`
    /// accessor involved). The overlay path is a verbatim slab copy, so
    /// overlay == scan pins the state invariant that the packed `mission`
    /// column always equals the slab's active clause — drift between the
    /// two redundant goal encodings is caught by the parity suite.
    pub fn mission_features(s: &EnvSlot<'_>, out: &mut [i32]) {
        debug_assert_eq!(out.len(), MISSION_TOKENS);
        let slab = &s.mission_tokens[s.agent * MISSION_TOKENS..(s.agent + 1) * MISSION_TOKENS];
        out.copy_from_slice(slab);
        let n = slab[0];
        if n <= 0 {
            // No mission: the block is all-zero by construction.
            out.fill(0);
            return;
        }
        let m = s.mission[s.agent];
        if m < 0 {
            // Completed mission: no active clause to rebuild — the slab
            // (with every done latch set) is the whole story.
            return;
        }
        let active = slab[1].clamp(0, n - 1);
        let base = CLAUSE_BASE + active as usize * CLAUSE_STRIDE;
        let color = m & 0xFF;
        let tag = (m >> 8) & 0xFF;
        let verb_code = (m >> 16) & 0x3;
        // token verb codes: 1 = go-to, 2 = pick-up, 3 = put-next,
        // 4 = open; packed code 0 is the kind default (doors go-to,
        // pickables pick-up).
        let verb_tok = match verb_code {
            1 => 1,
            2 => 3,
            3 => 4,
            _ => {
                if tag == Tag::DOOR {
                    1
                } else {
                    2
                }
            }
        };
        let kind_slot = |t: i32| match t {
            Tag::DOOR => 0,
            Tag::KEY => 1,
            Tag::BALL => 2,
            _ => 3,
        };
        out[base] = verb_tok;
        out[base + 1] = kind_slot(tag) + 1;
        out[base + 2] = color + 1;
        if verb_code == 2 {
            out[base + 3] = kind_slot((m >> 18) & 0x7) + 1;
            out[base + 4] = ((m >> 21) & 0x7) + 1;
        } else {
            out[base + 3] = 0;
            out[base + 4] = 0;
        }
        // An active clause is by definition not yet complete.
        out[base + 5] = 0;
    }

    /// Scan-path [`super::encode_cell`]: first-match entity-table scans
    /// (agents included — an independent walk of the position column).
    #[inline]
    pub fn encode_cell(s: &EnvSlot<'_>, p: Pos, include_player: bool) -> (i32, i32, i32) {
        if include_player && p == s.player() {
            return (Tag::AGENT, s.agent as i32, s.player_dir_value());
        }
        if p.in_bounds(s.h, s.w) {
            let enc = p.encode(s.w);
            for j in 0..s.player_pos.len() {
                if j != s.agent && s.player_pos[j] == enc {
                    return (Tag::AGENT, j as i32, s.player_dir[j]);
                }
            }
        }
        if let Some(d) = s.door_at_scan(p) {
            return (Tag::DOOR, s.door_color[d] as i32, s.door_state[d] as i32);
        }
        if let Some(k) = s.key_at_scan(p) {
            return (Tag::KEY, s.key_color[k] as i32, 0);
        }
        if let Some(b) = s.ball_at_scan(p) {
            return (Tag::BALL, s.ball_color[b] as i32, 0);
        }
        if let Some(b) = s.box_at_scan(p) {
            return (Tag::BOX, s.box_color[b] as i32, 0);
        }
        match s.cell(p) {
            CellType::Floor => (Tag::EMPTY, 0, 0),
            CellType::Wall => (Tag::WALL, s.cell_color(p) as i32, 0),
            CellType::Goal => (Tag::GOAL, 1 /* green */, 0),
            CellType::Lava => (Tag::LAVA, 0, 0),
        }
    }

    /// Scan-path [`super::symbolic`].
    pub fn symbolic(s: &EnvSlot<'_>, out: &mut [i32]) {
        debug_assert_eq!(out.len(), s.h * s.w * 3);
        let mut i = 0;
        for r in 0..s.h as i32 {
            for c in 0..s.w as i32 {
                let (t, col, st) = encode_cell(s, Pos::new(r, c), true);
                out[i] = t;
                out[i + 1] = col;
                out[i + 2] = st;
                i += 3;
            }
        }
    }

    /// Scan-path [`super::categorical`].
    pub fn categorical(s: &EnvSlot<'_>, out: &mut [i32]) {
        debug_assert_eq!(out.len(), s.h * s.w);
        let mut i = 0;
        for r in 0..s.h as i32 {
            for c in 0..s.w as i32 {
                out[i] = encode_cell(s, Pos::new(r, c), true).0;
                i += 1;
            }
        }
    }

    /// Scan-path [`super::symbolic_first_person`].
    pub fn symbolic_first_person(s: &EnvSlot<'_>, view: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), view * view * 3);
        let f = ViewFrame::compute_scan(s, view);
        for i in 0..view * view {
            let (t, col, st) = encode_frame_cell_with(s, &f, view, i, encode_cell);
            out[i * 3] = t;
            out[i * 3 + 1] = col;
            out[i * 3 + 2] = st;
        }
    }

    /// Scan-path [`super::categorical_first_person`].
    pub fn categorical_first_person(s: &EnvSlot<'_>, view: usize, out: &mut [i32]) {
        debug_assert_eq!(out.len(), view * view);
        let f = ViewFrame::compute_scan(s, view);
        for i in 0..view * view {
            out[i] = encode_frame_cell_with(s, &f, view, i, encode_cell).0;
        }
    }

    /// Scan-path [`super::rgb`] (always a full from-scratch render).
    pub fn rgb(s: &EnvSlot<'_>, sheet: &SpriteSheet, out: &mut [u8]) {
        debug_assert_eq!(out.len(), s.h * s.w * TILE * TILE * 3);
        for r in 0..s.h {
            for c in 0..s.w {
                let (t, col, st) = encode_cell(s, Pos::new(r as i32, c as i32), true);
                blit(out, s.w, r, c, sheet.get(t, col as u8, st));
            }
        }
    }

    /// Scan-path [`super::rgb_first_person`].
    pub fn rgb_first_person(s: &EnvSlot<'_>, view: usize, sheet: &SpriteSheet, out: &mut [u8]) {
        debug_assert_eq!(out.len(), view * view * TILE * TILE * 3);
        let f = ViewFrame::compute_scan(s, view);
        for vr in 0..view {
            for vc in 0..view {
                let (t, col, st) =
                    encode_frame_cell_with(s, &f, view, vr * view + vc, encode_cell);
                blit(out, view, vr, vc, sheet.get(t, col as u8, st));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::{Color, DoorState};
    use crate::core::state::{BatchedState, Caps};

    fn env() -> BatchedState {
        let mut st = BatchedState::new(1, 8, 8, Caps { doors: 1, keys: 1, balls: 1, boxes: 1 });
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(4, 2), Direction::East);
        s.set_cell(Pos::new(6, 6), CellType::Goal, Color::Green);
        drop(s);
        st
    }

    #[test]
    fn symbolic_full_encodes_agent_walls_goal() {
        let st = env();
        let s = st.slot(0);
        let mut out = vec![0i32; 8 * 8 * 3];
        symbolic(&s, &mut out);
        let at = |r: usize, c: usize| -> (i32, i32, i32) {
            let i = (r * 8 + c) * 3;
            (out[i], out[i + 1], out[i + 2])
        };
        assert_eq!(at(0, 0).0, Tag::WALL);
        assert_eq!(at(4, 2), (Tag::AGENT, 0, Direction::East as i32));
        assert_eq!(at(6, 6), (Tag::GOAL, 1, 0));
        assert_eq!(at(3, 3), (Tag::EMPTY, 0, 0));
    }

    #[test]
    fn categorical_matches_symbolic_tag_channel() {
        let st = env();
        let s = st.slot(0);
        let mut sym = vec![0i32; 8 * 8 * 3];
        let mut cat = vec![0i32; 8 * 8];
        symbolic(&s, &mut sym);
        categorical(&s, &mut cat);
        for i in 0..64 {
            assert_eq!(cat[i], sym[i * 3]);
        }
    }

    #[test]
    fn overlay_path_matches_scan_oracle() {
        // A state exercising every entity kind + the pocket.
        let mut st = env();
        {
            let mut s = st.slot_mut(0);
            s.add_door(Pos::new(4, 4), Color::Red, DoorState::Closed);
            s.add_key(Pos::new(2, 2), Color::Yellow);
            s.add_ball(Pos::new(5, 5), Color::Blue);
            s.add_box(Pos::new(2, 5), Color::Purple);
            s.set_cell(Pos::new(1, 6), CellType::Lava, Color::Red);
        }
        let s = st.slot(0);
        for p in (0..8).flat_map(|r| (0..8).map(move |c| Pos::new(r, c))) {
            assert_eq!(encode_cell(&s, p, true), scan::encode_cell(&s, p, true), "{p:?}");
            assert_eq!(encode_cell(&s, p, false), scan::encode_cell(&s, p, false), "{p:?}");
        }
        let mut fast = vec![0i32; 8 * 8 * 3];
        let mut naive = vec![0i32; 8 * 8 * 3];
        symbolic(&s, &mut fast);
        scan::symbolic(&s, &mut naive);
        assert_eq!(fast, naive);
        let mut fast_fp = vec![0i32; 7 * 7 * 3];
        let mut naive_fp = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&s, 7, &mut fast_fp);
        scan::symbolic_first_person(&s, 7, &mut naive_fp);
        assert_eq!(fast_fp, naive_fp);
        let sheet = SpriteSheet::new();
        let mut img_fast = vec![0u8; 8 * 8 * TILE * TILE * 3];
        let mut img_naive = vec![0u8; 8 * 8 * TILE * TILE * 3];
        rgb(&s, &sheet, &mut img_fast);
        scan::rgb(&s, &sheet, &mut img_naive);
        assert_eq!(img_fast, img_naive);
    }

    #[test]
    fn rgb_incremental_matches_full_render_across_mutations() {
        let mut st = env();
        let sheet = SpriteSheet::new();
        let mut prev = vec![cellcode::INVALID; 8 * 8];
        let mut inc = vec![0u8; 8 * 8 * TILE * TILE * 3];
        let mut full = vec![0u8; 8 * 8 * TILE * TILE * 3];
        let d = {
            let mut s = st.slot_mut(0);
            s.add_door(Pos::new(4, 4), Color::Red, DoorState::Closed)
        };
        // Frame 0: full render via the dirty path (all tiles invalid).
        rgb_incremental(&st.slot(0), &sheet, &mut prev, &mut inc);
        rgb(&st.slot(0), &sheet, &mut full);
        assert_eq!(inc, full);
        // Door toggle, key pickup, obstacle move, player move: each frame
        // the incremental image must equal a from-scratch render.
        {
            let mut s = st.slot_mut(0);
            s.set_door_state(d, DoorState::Open);
        }
        rgb_incremental(&st.slot(0), &sheet, &mut prev, &mut inc);
        rgb(&st.slot(0), &sheet, &mut full);
        assert_eq!(inc, full, "door toggle");
        {
            let mut s = st.slot_mut(0);
            let k = s.add_key(Pos::new(2, 2), Color::Yellow);
            s.remove_key(k); // picked up
            let b = s.add_ball(Pos::new(5, 5), Color::Blue);
            s.move_ball(b, Pos::new(5, 6));
            s.place_player(Pos::new(4, 3), Direction::North);
        }
        rgb_incremental(&st.slot(0), &sheet, &mut prev, &mut inc);
        rgb(&st.slot(0), &sheet, &mut full);
        assert_eq!(inc, full, "pickup + obstacle + player moves");
    }

    #[test]
    fn view_to_world_orientation() {
        let p = Pos::new(4, 2);
        // facing east: ahead is +col, view-right is south (+row)
        assert_eq!(view_to_world(p, Direction::East, 7, 6, 3), p);
        assert_eq!(view_to_world(p, Direction::East, 7, 5, 3), Pos::new(4, 3));
        assert_eq!(view_to_world(p, Direction::East, 7, 6, 4), Pos::new(5, 2));
        assert_eq!(view_to_world(p, Direction::East, 7, 6, 2), Pos::new(3, 2));
        // facing north: ahead is −row, view-right is east
        assert_eq!(view_to_world(p, Direction::North, 7, 5, 3), Pos::new(3, 2));
        assert_eq!(view_to_world(p, Direction::North, 7, 6, 4), Pos::new(4, 3));
    }

    #[test]
    fn first_person_agent_cell_shows_carried_item() {
        let mut st = env();
        {
            let mut s = st.slot_mut(0);
            s.pocket[0] = crate::core::components::Pocket::holding(Tag::KEY, Color::Yellow).0;
        }
        let s = st.slot(0);
        let mut out = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&s, 7, &mut out);
        let i = (6 * 7 + 3) * 3;
        assert_eq!(out[i], Tag::KEY);
        assert_eq!(out[i + 1], Color::Yellow as i32);
    }

    #[test]
    fn occlusion_hides_cells_behind_wall_lines() {
        // A full wall line one cell ahead of the agent (MiniGrid's
        // visibility propagates diagonally, so only an unbroken line fully
        // occludes — single cells leak light around their corners, exactly
        // as in the original `process_vis`).
        let mut st = env();
        {
            let mut s = st.slot_mut(0);
            for r in 1..7 {
                s.set_cell(Pos::new(r, 3), CellType::Wall, Color::Grey);
            }
        }
        let s = st.slot(0);
        let mut out = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&s, 7, &mut out);
        // the wall itself is visible…
        let wall_i = (5 * 7 + 3) * 3; // one ahead: vr=5, vc=3
        assert_eq!(out[wall_i], Tag::WALL);
        // …but everything beyond the line is unseen
        for vr in 0..5 {
            for vc in 0..7 {
                let i = (vr * 7 + vc) * 3;
                assert_eq!(out[i], Tag::UNSEEN, "view cell ({vr},{vc}) leaked past the wall");
            }
        }
    }

    #[test]
    fn closed_door_in_wall_blocks_sight_open_door_does_not() {
        // DoorKey-style geometry: a wall line with a door in it.
        let mut st = env();
        {
            let mut s = st.slot_mut(0);
            for r in 1..7 {
                s.set_cell(Pos::new(r, 3), CellType::Wall, Color::Grey);
            }
            s.set_cell(Pos::new(4, 3), CellType::Floor, Color::Grey);
            s.add_door(Pos::new(4, 3), Color::Red, DoorState::Closed);
        }
        let mut out = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&st.slot(0), 7, &mut out);
        // the door is visible, the cell behind it is not
        assert_eq!(out[(5 * 7 + 3) * 3], Tag::DOOR, "closed door visible");
        assert_eq!(out[(4 * 7 + 3) * 3], Tag::UNSEEN, "closed door occludes");
        {
            let mut s = st.slot_mut(0);
            s.set_door_state(0, DoorState::Open);
        }
        symbolic_first_person(&st.slot(0), 7, &mut out);
        assert_ne!(out[(4 * 7 + 3) * 3], Tag::UNSEEN, "open door is see-through");
    }

    #[test]
    fn out_of_bounds_view_cells_are_unseen() {
        let st = env(); // player at (4,2) facing east; view extends past walls
        let mut out = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&st.slot(0), 7, &mut out);
        // far-left column of the view (vc=0) maps 3 cells north of the
        // player... those are in-bounds here. Check a corner that maps
        // outside: vr=0 (6 ahead) from col 2 reaches col 8 => OOB.
        let i = (0 * 7 + 3) * 3;
        assert_eq!(out[i], Tag::UNSEEN);
    }

    #[test]
    fn rgb_shapes_and_content() {
        let st = env();
        let sheet = SpriteSheet::new();
        let spec = ObsSpec::new(ObsKind::Rgb);
        let mut out = vec![0u8; spec.len(8, 8)];
        spec.write_u8(&st.slot(0), &sheet, &mut out);
        // top-left pixel is wall grey
        assert_eq!(&out[0..3], &[100, 100, 100]);
        // goal tile at (6,6): sample its centre pixel
        let row_px = 8 * TILE * 3;
        let centre = (6 * TILE + 16) * row_px + (6 * TILE + 16) * 3;
        assert_eq!(&out[centre..centre + 3], &[0, 255, 0]);
    }

    #[test]
    fn rgb_first_person_renders() {
        let st = env();
        let sheet = SpriteSheet::new();
        let spec = ObsSpec::new(ObsKind::RgbFirstPerson);
        let mut out = vec![0u8; spec.len(8, 8)];
        spec.write_u8(&st.slot(0), &sheet, &mut out);
        assert_eq!(out.len(), 7 * 7 * 32 * 32 * 3);
        assert!(out.iter().any(|&p| p != 0));
    }

    #[test]
    fn mission_features_overlay_matches_scan_oracle() {
        use crate::core::components::Color;
        use crate::core::mission::{Mission, MissionClause, MissionSpec};
        let mut st = env();
        let missions = [
            Mission::NONE,
            Mission::go_to(Tag::DOOR, Color::Yellow),
            Mission::go_to(Tag::BALL, Color::Blue),
            Mission::pick_up(Tag::KEY, Color::Red),
            Mission::pick_up(Tag::BOX, Color::Grey),
            Mission::open(Color::Green),
            Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green),
        ];
        let check = |st: &BatchedState, what: &str| {
            let s = st.slot(0);
            let mut fast = [0i32; MISSION_TOKENS];
            let mut naive = [7i32; MISSION_TOKENS];
            mission_features(&s, &mut fast);
            scan::mission_features(&s, &mut naive);
            assert_eq!(fast, naive, "{what} diverged from the bit-level oracle");
            let spec = ObsSpec::new(ObsKind::SymbolicFirstPerson);
            let mut via_spec = [0i32; MISSION_TOKENS];
            spec.write_mission_path(ObsPath::Overlay, &s, &mut via_spec);
            assert_eq!(via_spec, fast);
            spec.write_mission_path(ObsPath::NaiveScan, &s, &mut via_spec);
            assert_eq!(via_spec, naive);
        };
        for m in missions {
            st.slot_mut(0).set_mission(m);
            check(&st, &format!("mission {m:?}"));
        }
        // Sequenced spec through every progress state: clause 1 active,
        // clause 2 active (after one advance), complete.
        let seq = MissionSpec::then(
            MissionClause::Open { color: Color::Red },
            MissionClause::PickUp { kind: Tag::BOX, color: Color::Green },
        );
        st.slot_mut(0).set_mission_spec(seq);
        check(&st, "sequenced spec, clause 1 active");
        assert!(!st.slot_mut(0).advance_mission_clause());
        check(&st, "sequenced spec, clause 2 active");
        assert!(st.slot_mut(0).advance_mission_clause());
        check(&st, "sequenced spec, complete");
    }

    #[test]
    fn other_agents_are_encoded_with_their_index() {
        let mut st = BatchedState::with_agents(1, 8, 8, Caps::default(), 2);
        {
            let mut s = st.slot_mut(0);
            s.fill_room();
            s.place_player(Pos::new(4, 2), Direction::East);
            s.place_agent(1, Pos::new(4, 4), Direction::North);
        }
        // Full grid: both agents visible, colour channel = agent index.
        let s = st.slot(0);
        let mut out = vec![0i32; 8 * 8 * 3];
        symbolic(&s, &mut out);
        let at = |r: usize, c: usize| -> (i32, i32, i32) {
            let i = (r * 8 + c) * 3;
            (out[i], out[i + 1], out[i + 2])
        };
        assert_eq!(at(4, 2), (Tag::AGENT, 0, Direction::East as i32));
        assert_eq!(at(4, 4), (Tag::AGENT, 1, Direction::North as i32));
        // Agent 0's first-person frame: agent 1 sits two cells ahead
        // (view row 4, col 3) and is encoded even though the frame hides
        // the viewer itself.
        let mut fp = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&s, 7, &mut fp);
        let i = (4 * 7 + 3) * 3;
        assert_eq!(fp[i], Tag::AGENT);
        assert_eq!(fp[i + 1], 1);
        // Overlay and scan paths agree on multi-agent cells.
        for p in (0..8).flat_map(|r| (0..8).map(move |c| Pos::new(r, c))) {
            assert_eq!(encode_cell(&s, p, true), scan::encode_cell(&s, p, true), "{p:?}");
            assert_eq!(encode_cell(&s, p, false), scan::encode_cell(&s, p, false), "{p:?}");
        }
        // Agent 1's own egocentric frame (from its pose) sees agent 0.
        let s1 = st.agent_slot(0, 1);
        let mut fp1 = vec![0i32; 7 * 7 * 3];
        symbolic_first_person(&s1, 7, &mut fp1);
        let saw_peer = (0..49).any(|i| fp1[i * 3] == Tag::AGENT && fp1[i * 3 + 1] == 0);
        assert!(saw_peer, "agent 1 must see agent 0 in its egocentric frame");
        // The full-grid render codes carry the agent index too.
        let c0 = render_code(&s, 4 * 8 + 2);
        let c1 = render_code(&s, 4 * 8 + 4);
        assert_eq!(cellcode::tag(c0), Tag::AGENT);
        assert_eq!(cellcode::color(c0), 0);
        assert_eq!(cellcode::tag(c1), Tag::AGENT);
        assert_eq!(cellcode::color(c1), 1);
    }

    #[test]
    fn shapes_match_table4() {
        let h = 8;
        let w = 6;
        assert_eq!(ObsSpec::new(ObsKind::Symbolic).shape(h, w), vec![8, 6, 3]);
        assert_eq!(ObsSpec::new(ObsKind::SymbolicFirstPerson).shape(h, w), vec![7, 7, 3]);
        assert_eq!(ObsSpec::new(ObsKind::Rgb).shape(h, w), vec![256, 192, 3]);
        assert_eq!(ObsSpec::new(ObsKind::RgbFirstPerson).shape(h, w), vec![224, 224, 3]);
        assert_eq!(ObsSpec::new(ObsKind::Categorical).shape(h, w), vec![8, 6]);
        assert_eq!(ObsSpec::new(ObsKind::CategoricalFirstPerson).shape(h, w), vec![7, 7]);
    }
}
