//! The termination system `γ : S × A × S → 𝔹` (paper Table 6).
//!
//! Like rewards, terminations are event-driven and composable: a
//! [`TermSpec`] is the OR of its primitives. Timeout *truncation* is handled
//! separately by the batched stepper (it is a property of the episode bound
//! T, not of the MDP), with the dm_env-style distinction: termination sets
//! γ_{t+1} = 0, truncation keeps γ_{t+1} = γ.

use crate::core::state::{AgentView, EnvSlot};

/// Primitive termination predicates (paper Table 6 + mission events).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TermFn {
    /// Terminate when Player reaches a Goal entity.
    OnGoalReached,
    /// Terminate when Player steps into Lava.
    OnLavaFall,
    /// Terminate when `done` is performed before the mission door.
    OnDoorDone,
    /// Terminate when the mission ball is picked up (KeyCorridor).
    OnBallPicked,
    /// Terminate when hit by a flying obstacle (Dynamic-Obstacles).
    OnBallHit,
    /// Terminate when a locked door is unlocked (Unlock).
    OnDoorUnlocked,
    /// Terminate when the mission-target object is picked up
    /// (Fetch, UnlockPickup).
    OnObjectPicked,
    /// Terminate when a non-target object is picked up (Fetch: any pickup
    /// ends the episode, but only the target pays).
    OnWrongPickup,
    /// Terminate when `done` is performed facing the go-to mission's target
    /// object (GoToObj).
    OnObjectReached,
    /// Terminate when the put-next mission's object lands adjacent to its
    /// second object (PutNext).
    OnObjectPlaced,
    /// Terminate when the mission's final clause completed (sequenced
    /// families — mid-sequence progress like `door_opened` does not
    /// terminate).
    OnMissionComplete,
    /// Terminate when this agent tagged another agent (pursuit–evasion).
    OnAgentContact,
    /// Terminate when this agent was tagged by another agent.
    OnContacted,
    /// Never terminate.
    Free,
}

impl TermFn {
    pub fn eval(self, s: &EnvSlot<'_>) -> bool {
        let ev = s.events_value();
        match self {
            TermFn::OnGoalReached => ev.goal_reached,
            TermFn::OnLavaFall => ev.lava_fall,
            TermFn::OnDoorDone => ev.door_done,
            TermFn::OnBallPicked => ev.ball_picked,
            TermFn::OnBallHit => ev.ball_hit,
            TermFn::OnDoorUnlocked => ev.door_unlocked,
            TermFn::OnObjectPicked => ev.object_picked,
            TermFn::OnWrongPickup => ev.wrong_pickup,
            TermFn::OnObjectReached => ev.object_reached,
            TermFn::OnObjectPlaced => ev.object_placed,
            TermFn::OnMissionComplete => ev.mission_complete,
            TermFn::OnAgentContact => ev.agent_contact,
            TermFn::OnContacted => ev.contacted,
            TermFn::Free => false,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TermFn::OnGoalReached => "on_goal_reached",
            TermFn::OnLavaFall => "on_lava_fall",
            TermFn::OnDoorDone => "on_door_done",
            TermFn::OnBallPicked => "on_ball_picked",
            TermFn::OnBallHit => "on_ball_hit",
            TermFn::OnDoorUnlocked => "on_door_unlocked",
            TermFn::OnObjectPicked => "on_object_picked",
            TermFn::OnWrongPickup => "on_wrong_pickup",
            TermFn::OnObjectReached => "on_object_reached",
            TermFn::OnObjectPlaced => "on_object_placed",
            TermFn::OnMissionComplete => "on_mission_complete",
            TermFn::OnAgentContact => "on_agent_contact",
            TermFn::OnContacted => "on_contacted",
            TermFn::Free => "free",
        }
    }
}

/// Composable termination: OR of primitives.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TermSpec {
    pub terms: Vec<TermFn>,
}

impl TermSpec {
    pub fn new(terms: Vec<TermFn>) -> Self {
        TermSpec { terms }
    }

    /// Goal only (Empty, DoorKey, FourRooms…).
    pub fn goal() -> Self {
        TermSpec::new(vec![TermFn::OnGoalReached])
    }

    /// Goal or lava (LavaGap, Crossings, DistShift — "terminate whenever the
    /// reward is non-zero", Table 8).
    pub fn goal_or_lava() -> Self {
        TermSpec::new(vec![TermFn::OnGoalReached, TermFn::OnLavaFall])
    }

    /// Goal or obstacle collision (Dynamic-Obstacles).
    pub fn goal_or_ball_hit() -> Self {
        TermSpec::new(vec![TermFn::OnGoalReached, TermFn::OnBallHit])
    }

    /// Ball pickup (KeyCorridor).
    pub fn ball_picked() -> Self {
        TermSpec::new(vec![TermFn::OnBallPicked])
    }

    /// Door done (GoToDoor).
    pub fn door_done() -> Self {
        TermSpec::new(vec![TermFn::OnDoorDone])
    }

    /// Locked door opened (Unlock).
    pub fn door_unlocked() -> Self {
        TermSpec::new(vec![TermFn::OnDoorUnlocked])
    }

    /// Mission object picked up (UnlockPickup, BlockedUnlockPickup).
    pub fn object_picked() -> Self {
        TermSpec::new(vec![TermFn::OnObjectPicked])
    }

    /// Any pickup ends the episode; only the target pays (Fetch).
    pub fn fetch() -> Self {
        TermSpec::new(vec![TermFn::OnObjectPicked, TermFn::OnWrongPickup])
    }

    /// `done` facing the mission object (GoToObj).
    pub fn object_reached() -> Self {
        TermSpec::new(vec![TermFn::OnObjectReached])
    }

    /// Mission object dropped next to its second object (PutNext).
    pub fn object_placed() -> Self {
        TermSpec::new(vec![TermFn::OnObjectPlaced])
    }

    /// Whole mission complete (sequenced families).
    pub fn mission_complete() -> Self {
        TermSpec::new(vec![TermFn::OnMissionComplete])
    }

    /// Pursuit–evasion: a tag in either direction or an obstacle collision
    /// ends the episode. (The engine ORs the spec across a slot's agents,
    /// so one agent's terminal event ends the slot for everyone.)
    pub fn pursuit() -> Self {
        TermSpec::new(vec![
            TermFn::OnAgentContact,
            TermFn::OnContacted,
            TermFn::OnBallHit,
        ])
    }

    pub fn eval(&self, s: &EnvSlot<'_>) -> bool {
        self.terms.iter().any(|t| t.eval(s))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::Direction;
    use crate::core::events::Events;
    use crate::core::grid::Pos;
    use crate::core::state::{BatchedState, Caps};

    fn with_events(ev: Events) -> BatchedState {
        let mut st = BatchedState::new(1, 5, 5, Caps::default());
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.place_player(Pos::new(1, 1), Direction::East);
        s.events[0] = ev;
        drop(s);
        st
    }

    #[test]
    fn goal_terminates() {
        let st = with_events(Events { goal_reached: true, ..Events::NONE });
        assert!(TermSpec::goal().eval(&st.slot(0)));
        assert!(TermSpec::goal_or_lava().eval(&st.slot(0)));
    }

    #[test]
    fn lava_terminates_only_composite() {
        let st = with_events(Events { lava_fall: true, ..Events::NONE });
        assert!(!TermSpec::goal().eval(&st.slot(0)));
        assert!(TermSpec::goal_or_lava().eval(&st.slot(0)));
    }

    #[test]
    fn ball_events() {
        let st = with_events(Events { ball_hit: true, ..Events::NONE });
        assert!(TermSpec::goal_or_ball_hit().eval(&st.slot(0)));
        let st = with_events(Events { ball_picked: true, ..Events::NONE });
        assert!(TermSpec::ball_picked().eval(&st.slot(0)));
    }

    #[test]
    fn unlock_and_pickup_events_terminate() {
        let st = with_events(Events { door_unlocked: true, ..Events::NONE });
        assert!(TermSpec::door_unlocked().eval(&st.slot(0)));
        assert!(!TermSpec::object_picked().eval(&st.slot(0)));
        let st = with_events(Events { object_picked: true, ..Events::NONE });
        assert!(TermSpec::object_picked().eval(&st.slot(0)));
        assert!(TermSpec::fetch().eval(&st.slot(0)));
        // Fetch ends the episode on the wrong object too
        let st = with_events(Events { wrong_pickup: true, ..Events::NONE });
        assert!(TermSpec::fetch().eval(&st.slot(0)));
        assert!(!TermSpec::object_picked().eval(&st.slot(0)));
    }

    #[test]
    fn go_to_obj_and_put_next_events_terminate() {
        let st = with_events(Events { object_reached: true, ..Events::NONE });
        assert!(TermSpec::object_reached().eval(&st.slot(0)));
        assert!(!TermSpec::object_placed().eval(&st.slot(0)));
        let st = with_events(Events { object_placed: true, ..Events::NONE });
        assert!(TermSpec::object_placed().eval(&st.slot(0)));
        assert!(!TermSpec::object_reached().eval(&st.slot(0)));
    }

    #[test]
    fn agent_contact_terminates_pursuit() {
        let st = with_events(Events { agent_contact: true, ..Events::NONE });
        assert!(TermSpec::pursuit().eval(&st.slot(0)));
        assert!(!TermSpec::goal().eval(&st.slot(0)));
        let st = with_events(Events { contacted: true, ..Events::NONE });
        assert!(TermSpec::pursuit().eval(&st.slot(0)));
        let st = with_events(Events { ball_hit: true, ..Events::NONE });
        assert!(TermSpec::pursuit().eval(&st.slot(0)));
    }

    #[test]
    fn mission_complete_terminates_but_progress_does_not() {
        let st = with_events(Events { mission_complete: true, ..Events::NONE });
        assert!(TermSpec::mission_complete().eval(&st.slot(0)));
        // mid-sequence clause completion is progress, not an outcome
        let st = with_events(Events { door_opened: true, ..Events::NONE });
        assert!(!TermSpec::mission_complete().eval(&st.slot(0)));
    }

    #[test]
    fn free_never_terminates() {
        let st = with_events(Events {
            goal_reached: true,
            lava_fall: true,
            ball_hit: true,
            ball_picked: true,
            door_done: true,
            ..Events::NONE
        });
        assert!(!TermSpec::new(vec![TermFn::Free]).eval(&st.slot(0)));
    }
}
