//! Image export: write rgb observations as PPM (P6) files — the
//! dependency-free format every image viewer and converter understands.
//! Used by `examples/render_gallery.rs` for visual validation of layouts
//! and sprites.

use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Write a row-major RGB buffer as a binary PPM.
pub fn write_ppm<P: AsRef<Path>>(path: P, width: usize, height: usize, rgb: &[u8]) -> Result<()> {
    anyhow::ensure!(
        rgb.len() == width * height * 3,
        "buffer {} != {width}x{height}x3",
        rgb.len()
    );
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write!(f, "P6\n{width} {height}\n255\n")?;
    f.write_all(rgb)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_payload() {
        let dir = std::env::temp_dir().join(format!("navix_ppm_{}", std::process::id()));
        let path = dir.join("t.ppm");
        let rgb = vec![7u8; 2 * 3 * 3];
        write_ppm(&path, 2, 3, &rgb).unwrap();
        let data = std::fs::read(&path).unwrap();
        assert!(data.starts_with(b"P6\n2 3\n255\n"));
        assert_eq!(data.len(), b"P6\n2 3\n255\n".len() + 18);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_buffer_size() {
        let r = write_ppm("/tmp/never.ppm", 4, 4, &[0u8; 3]);
        assert!(r.is_err());
    }
}
