//! Systems (paper §3.1, Appendix A): pure functions over the collective
//! entity/component state, in bijection with the RL formalism —
//!
//! * [`intervention`] — `I : S × A → S`, the agent's decision applied.
//! * [`transition`]   — `P : S × A → S`, the MDP dynamics (stochastic
//!   entities such as dynamic obstacles).
//! * [`observations`] — `O : S → O`, all six paper Table-4 observation
//!   functions (symbolic/rgb/categorical × full/first-person).
//! * [`rewards`]      — `R : S × A × S → ℝ`, Markovian, event-driven
//!   (paper Table 5).
//! * [`terminations`] — `γ : S × A × S → 𝔹`, event-driven (paper Table 6).
//! * [`sprites`]      — the HasSprite component: procedural 32×32×3 RGB
//!   tiles used by the rgb observation functions.

pub mod intervention;
pub mod render;
pub mod observations;
pub mod rewards;
pub mod sprites;
pub mod terminations;
pub mod transition;

pub use intervention::intervene;
pub use observations::{ObsKind, ObsSpec};
pub use rewards::{RewardFn, RewardSpec};
pub use terminations::{TermFn, TermSpec};
pub use transition::transition;
