//! The paper's §4.3 scoreboard: a persisted table of per-environment,
//! per-algorithm results "that new algorithms can refer to, to avoid
//! re-running baselines".
//!
//! Stored as TSV under `results/scoreboard.tsv` (no serde offline; the
//! format is trivially greppable and diffable).

use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One scoreboard entry.
#[derive(Clone, Debug, PartialEq)]
pub struct Entry {
    pub env_id: String,
    pub algo: String,
    pub seeds: u32,
    pub env_steps: u64,
    pub final_return: f32,
}

/// The scoreboard: best final return per (env, algo).
#[derive(Clone, Debug, Default)]
pub struct Scoreboard {
    entries: BTreeMap<(String, String), Entry>,
    path: Option<PathBuf>,
}

impl Scoreboard {
    pub fn new() -> Scoreboard {
        Scoreboard::default()
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Scoreboard> {
        let path = path.as_ref();
        let mut sb = Scoreboard { entries: BTreeMap::new(), path: Some(path.to_path_buf()) };
        if !path.exists() {
            return Ok(sb);
        }
        let text = std::fs::read_to_string(path).context("reading scoreboard")?;
        for (i, line) in text.lines().enumerate() {
            if i == 0 || line.trim().is_empty() {
                continue; // header
            }
            let cols: Vec<&str> = line.split('\t').collect();
            anyhow::ensure!(cols.len() == 5, "scoreboard line {}: bad column count", i + 1);
            let e = Entry {
                env_id: cols[0].to_string(),
                algo: cols[1].to_string(),
                seeds: cols[2].parse()?,
                env_steps: cols[3].parse()?,
                final_return: cols[4].parse()?,
            };
            sb.entries.insert((e.env_id.clone(), e.algo.clone()), e);
        }
        Ok(sb)
    }

    /// Record a result, keeping the better of old/new final returns.
    pub fn record(&mut self, e: Entry) {
        let key = (e.env_id.clone(), e.algo.clone());
        match self.entries.get(&key) {
            Some(old) if old.final_return >= e.final_return => {}
            _ => {
                self.entries.insert(key, e);
            }
        }
    }

    pub fn get(&self, env_id: &str, algo: &str) -> Option<&Entry> {
        self.entries.get(&(env_id.to_string(), algo.to_string()))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Entry> {
        self.entries.values()
    }

    /// Persist as TSV.
    pub fn save(&self) -> Result<()> {
        let path = self
            .path
            .clone()
            .unwrap_or_else(|| PathBuf::from("results/scoreboard.tsv"));
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut body = String::from("env_id\talgo\tseeds\tenv_steps\tfinal_return\n");
        for e in self.entries.values() {
            body.push_str(&format!(
                "{}\t{}\t{}\t{}\t{:.4}\n",
                e.env_id, e.algo, e.seeds, e.env_steps, e.final_return
            ));
        }
        std::fs::write(&path, body).context("writing scoreboard")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(env: &str, algo: &str, ret: f32) -> Entry {
        Entry {
            env_id: env.into(),
            algo: algo.into(),
            seeds: 4,
            env_steps: 100_000,
            final_return: ret,
        }
    }

    #[test]
    fn record_keeps_best() {
        let mut sb = Scoreboard::new();
        sb.record(entry("Navix-Empty-8x8-v0", "ppo", 0.5));
        sb.record(entry("Navix-Empty-8x8-v0", "ppo", 0.9));
        sb.record(entry("Navix-Empty-8x8-v0", "ppo", 0.7));
        assert_eq!(sb.get("Navix-Empty-8x8-v0", "ppo").unwrap().final_return, 0.9);
        assert_eq!(sb.len(), 1);
    }

    #[test]
    fn roundtrip_through_disk() {
        let dir = std::env::temp_dir().join(format!("navix_sb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("scoreboard.tsv");
        let mut sb = Scoreboard::load(&path).unwrap();
        sb.record(entry("Navix-Empty-8x8-v0", "ppo", 0.95));
        sb.record(entry("Navix-DoorKey-5x5-v0", "dqn", 0.8));
        sb.save().unwrap();
        let sb2 = Scoreboard::load(&path).unwrap();
        assert_eq!(sb2.len(), 2);
        assert_eq!(sb2.get("Navix-DoorKey-5x5-v0", "dqn").unwrap().final_return, 0.8);
        std::fs::remove_dir_all(&dir).ok();
    }
}
