//! Throughput workloads (paper §4.1–4.2, Figs. 1/3/4/5/8): timed unrolls of
//! random-policy interaction across engines and batch sizes.

use crate::baseline::{AsyncVectorEnv, SyncVectorEnv};
use crate::batch::{rollout_random_scan, BatchedEnv, ShardedEnv};
use crate::config::ExecConfig;
use crate::envs::registry::make;
use crate::rng::{Key, Rng};
use anyhow::{bail, Result};
use std::time::Instant;

/// Which engine executes the unroll.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// NAVIX analog: batched SoA engine, single-threaded (`vmap`).
    Batched,
    /// NAVIX analog: sharded multi-core SoA engine (`pmap`).
    Sharded,
    /// MiniGrid analog: scalar OO engine in a sequential vector wrapper.
    BaselineSync,
    /// MiniGrid analog with gymnasium-`multiprocessing`-style worker threads.
    BaselineAsync,
}

impl Engine {
    pub fn name(self) -> &'static str {
        match self {
            Engine::Batched => "navix-batched",
            Engine::Sharded => "navix-sharded",
            Engine::BaselineSync => "minigrid-sync",
            Engine::BaselineAsync => "minigrid-async",
        }
    }
}

/// [`unroll_walltime_exec`] with the default (auto) sharding config.
pub fn unroll_walltime(
    engine: Engine,
    env_id: &str,
    n_envs: usize,
    steps: usize,
    seed: u64,
) -> Result<f64> {
    unroll_walltime_exec(engine, env_id, n_envs, steps, seed, &ExecConfig::default())
}

/// Wall time (seconds) for `steps` lockstep iterations of `n_envs` parallel
/// environments of `env_id` under a uniform-random policy — the paper's
/// speed protocol ("1K steps with 8 parallel environments", §4.1). `exec`
/// configures the shard/thread counts of the [`Engine::Sharded`] mode
/// (ignored by the other engines). Construction (including worker-pool
/// spawn) is excluded from the timing for every engine.
pub fn unroll_walltime_exec(
    engine: Engine,
    env_id: &str,
    n_envs: usize,
    steps: usize,
    seed: u64,
    exec: &ExecConfig,
) -> Result<f64> {
    let cfg = make(env_id)?;
    match engine {
        Engine::Batched => {
            let mut env = BatchedEnv::new(cfg, n_envs, Key::new(seed));
            let start = Instant::now();
            env.rollout_random(steps, seed ^ 0xAC7);
            Ok(start.elapsed().as_secs_f64())
        }
        Engine::Sharded => {
            let mut env =
                ShardedEnv::new(cfg, n_envs, exec.num_shards, exec.num_threads, Key::new(seed));
            let start = Instant::now();
            env.rollout_random(steps, seed ^ 0xAC7);
            Ok(start.elapsed().as_secs_f64())
        }
        Engine::BaselineSync => {
            let mut venv = SyncVectorEnv::new(cfg, n_envs, Key::new(seed));
            venv.reset();
            let mut rng = Rng::new(seed ^ 0xAC7);
            let mut actions = vec![0u8; n_envs];
            let start = Instant::now();
            for _ in 0..steps {
                for a in actions.iter_mut() {
                    *a = rng.below(7) as u8;
                }
                venv.step(&actions);
            }
            Ok(start.elapsed().as_secs_f64())
        }
        Engine::BaselineAsync => {
            let mut venv = AsyncVectorEnv::new(cfg, n_envs, Key::new(seed));
            venv.reset();
            let mut rng = Rng::new(seed ^ 0xAC7);
            let mut actions = vec![0u8; n_envs];
            let start = Instant::now();
            for _ in 0..steps {
                for a in actions.iter_mut() {
                    *a = rng.below(7) as u8;
                }
                venv.step(&actions);
            }
            Ok(start.elapsed().as_secs_f64())
        }
    }
}

/// Scan-mode variant of [`unroll_walltime_exec`]: the same seeded random
/// action stream, executed through the engines' fused
/// [`crate::batch::BatchStepper::step_n`] path in windows of `window` steps
/// (see [`rollout_random_scan`]). Only meaningful for the NAVIX-analog
/// engines — the MiniGrid baselines have no fused path, and asking for one
/// is an error rather than a silently per-step number.
pub fn unroll_walltime_scan(
    engine: Engine,
    env_id: &str,
    n_envs: usize,
    steps: usize,
    window: usize,
    seed: u64,
    exec: &ExecConfig,
) -> Result<f64> {
    let cfg = make(env_id)?;
    match engine {
        Engine::Batched => {
            let mut env = BatchedEnv::new(cfg, n_envs, Key::new(seed));
            let start = Instant::now();
            rollout_random_scan(&mut env, steps, seed ^ 0xAC7, window);
            Ok(start.elapsed().as_secs_f64())
        }
        Engine::Sharded => {
            let mut env =
                ShardedEnv::new(cfg, n_envs, exec.num_shards, exec.num_threads, Key::new(seed));
            let start = Instant::now();
            rollout_random_scan(&mut env, steps, seed ^ 0xAC7, window);
            Ok(start.elapsed().as_secs_f64())
        }
        Engine::BaselineSync | Engine::BaselineAsync => {
            bail!("scan mode requires a fused engine; {} steps one call at a time", engine.name())
        }
    }
}

/// Steps/second from an unroll measurement.
pub fn steps_per_second(n_envs: usize, steps: usize, secs: f64) -> f64 {
    (n_envs * steps) as f64 / secs.max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_engines_complete_a_small_unroll() {
        for engine in
            [Engine::Batched, Engine::Sharded, Engine::BaselineSync, Engine::BaselineAsync]
        {
            let dt = unroll_walltime(engine, "Navix-Empty-5x5-v0", 4, 50, 0).unwrap();
            assert!(dt > 0.0, "{engine:?}");
        }
    }

    #[test]
    fn sharded_unroll_respects_explicit_exec_config() {
        let exec = ExecConfig { num_shards: 2, num_threads: 2, pipeline: false };
        let dt =
            unroll_walltime_exec(Engine::Sharded, "Navix-Empty-8x8-v0", 16, 50, 0, &exec).unwrap();
        assert!(dt > 0.0);
    }

    #[test]
    fn batched_engine_is_fastest_at_scale() {
        // The paper's core claim, scaled down: at 64 envs the batched
        // engine beats the thread-per-env baseline.
        let fast = unroll_walltime(Engine::Batched, "Navix-Empty-8x8-v0", 64, 100, 1).unwrap();
        let slow =
            unroll_walltime(Engine::BaselineAsync, "Navix-Empty-8x8-v0", 64, 100, 1).unwrap();
        assert!(
            fast < slow,
            "batched {fast}s should beat async baseline {slow}s at 64 envs"
        );
    }

    #[test]
    fn steps_per_second_math() {
        assert_eq!(steps_per_second(8, 1000, 2.0), 4000.0);
    }

    #[test]
    fn scan_unroll_runs_on_fused_engines_and_rejects_baselines() {
        let exec = ExecConfig { num_shards: 2, num_threads: 2, pipeline: false };
        for engine in [Engine::Batched, Engine::Sharded] {
            let dt =
                unroll_walltime_scan(engine, "Navix-Empty-5x5-v0", 4, 50, 16, 0, &exec).unwrap();
            assert!(dt > 0.0, "{engine:?}");
        }
        for engine in [Engine::BaselineSync, Engine::BaselineAsync] {
            assert!(
                unroll_walltime_scan(engine, "Navix-Empty-5x5-v0", 4, 50, 16, 0, &exec).is_err(),
                "{engine:?} must refuse scan mode"
            );
        }
    }
}
