//! Training/benchmark orchestration — the Layer-3 coordination logic.
//!
//! * [`trainer`] — the flagship three-layer path: rollouts on the Rust SoA
//!   engine, policy forward + fused PPO update executed as AOT-compiled
//!   JAX/Pallas artifacts via PJRT ([`crate::runtime`]).
//! * [`multi_agent`] — the paper's Fig. 6 workload: N independent PPO
//!   agents, each with its own 16-env batch, trained in one process.
//! * [`throughput`] — the Fig. 4/5/8 workloads: timed unrolls across
//!   engines and batch sizes.
//! * [`scoreboard`] — the paper's §4.3 scoreboard: persisted
//!   per-env/per-algorithm results.

pub mod multi_agent;
pub mod scoreboard;
pub mod throughput;
pub mod trainer;

pub use throughput::{unroll_walltime, unroll_walltime_exec, Engine};
pub use trainer::XlaPpo;
