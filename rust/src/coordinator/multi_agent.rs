//! Parallel multi-agent training — the paper's Fig. 6 workload: N
//! independent PPO agents, each with its own batch of 16 environments,
//! trained simultaneously on one accelerator.
//!
//! Hardware adaptation: the paper packs all agents into one GPU via a
//! leading vmap axis. Here agents are trained within one process, each over
//! its own SoA engine batch — single-threaded ([`BatchedEnv`]) by default,
//! or the sharded multi-core stepper ([`ShardedEnv`], the device axis) via
//! [`train_parallel_ppo_exec`]. Both modes produce bit-identical
//! trajectories, which preserves the experiment's structure —
//! shared-nothing agents, one process — while the absolute scaling curve
//! reflects the host (see EXPERIMENTS.md §Fig6).
//!
//! Since PR 6 every agent's rollout is *fused*: `Ppo::collect_rollout`
//! hands the whole horizon to the engine as one
//! [`crate::batch::BatchStepper::step_n`] call (EXPERIMENTS.md §"Scan
//! mode"), so this coordinator pays one dispatch per rollout per agent
//! rather than one per env step.

use crate::agents::ppo::{Ppo, PpoConfig, Rollout};
use crate::agents::{ReturnTracker, TrainLog};
use crate::batch::{BatchStepper, BatchedEnv, PipelinedEnv, ShardedEnv};
use crate::config::ExecConfig;
use crate::envs::registry::make;
use crate::rng::Key;
use anyhow::Result;
use std::time::Instant;

/// One agent's execution backend. `Pipelined` keeps its concrete type so
/// the rollout reaches `PipelinedEnv::step_n` (whose provider path overlaps
/// learner bookkeeping with env stepping); `Plain` erases the engine behind
/// [`BatchStepper`].
enum AgentEnv {
    Plain(Box<dyn BatchStepper>),
    Pipelined(PipelinedEnv),
}

impl AgentEnv {
    fn batch_size(&self) -> usize {
        match self {
            AgentEnv::Plain(e) => e.batch_size(),
            AgentEnv::Pipelined(p) => p.batch_size(),
        }
    }

    fn collect(&mut self, ppo: &mut Ppo, ro: &mut Rollout, tracker: &mut ReturnTracker) {
        match self {
            AgentEnv::Plain(e) => ppo.collect_rollout(e.as_mut(), ro, tracker),
            AgentEnv::Pipelined(p) => ppo.collect_rollout_pipelined(p, ro, tracker),
        }
    }
}

/// Result of a multi-agent run.
#[derive(Debug)]
pub struct MultiAgentResult {
    pub n_agents: usize,
    pub envs_per_agent: usize,
    pub total_env_steps: u64,
    pub wall_secs: f64,
    pub steps_per_second: f64,
    pub mean_final_return: f32,
    pub logs: Vec<TrainLog>,
}

/// [`train_parallel_ppo_exec`] on the single-threaded engine.
pub fn train_parallel_ppo(
    env_id: &str,
    n_agents: usize,
    envs_per_agent: usize,
    steps_per_agent: u64,
    seed: u64,
) -> Result<MultiAgentResult> {
    train_parallel_ppo_exec(env_id, n_agents, envs_per_agent, steps_per_agent, seed, None)
}

/// Train `n_agents` PPO agents for `steps_per_agent` env steps each on
/// `env_id` (paper: Empty-8x8, 1M steps, 16 envs/agent — scale the step
/// budget to the host). With `exec: Some(cfg)` every agent's batch steps on
/// the sharded multi-core engine ([`ShardedEnv`], the Fig.-6 device axis),
/// and `exec.pipeline` additionally runs it behind the double-buffered
/// rollout pipeline ([`PipelinedEnv`]) so env stepping overlaps learner
/// compute; `None` keeps the single-threaded [`BatchedEnv`]. Trajectories
/// are bit-identical across all three modes (see `rust/src/batch/`).
pub fn train_parallel_ppo_exec(
    env_id: &str,
    n_agents: usize,
    envs_per_agent: usize,
    steps_per_agent: u64,
    seed: u64,
    exec: Option<ExecConfig>,
) -> Result<MultiAgentResult> {
    let cfg = make(env_id)?;
    // Shared-nothing agent pool: one env batch + one learner per agent.
    let mut agents: Vec<(Ppo, AgentEnv)> = (0..n_agents)
        .map(|a| {
            let key = Key::new(seed).fold_in(a as u64);
            let env = match exec {
                Some(e) => {
                    let sharded = ShardedEnv::new(
                        cfg.clone(),
                        envs_per_agent,
                        e.num_shards,
                        e.num_threads,
                        key,
                    );
                    if e.pipeline {
                        AgentEnv::Pipelined(PipelinedEnv::new(Box::new(sharded)))
                    } else {
                        AgentEnv::Plain(Box::new(sharded))
                    }
                }
                None => AgentEnv::Plain(Box::new(BatchedEnv::new(
                    cfg.clone(),
                    envs_per_agent,
                    key,
                ))),
            };
            let pcfg = PpoConfig { num_envs: envs_per_agent, ..PpoConfig::default() };
            let ppo = Ppo::new(pcfg, crate::agents::OBS_DIM, 7, seed ^ a as u64);
            (ppo, env)
        })
        .collect();

    let start = Instant::now();
    let mut logs = Vec::with_capacity(n_agents);
    // Round-robin by rollout so all agents progress together (the paper's
    // lockstep vmap semantics), rather than agent-at-a-time.
    let steps_per_iter = (agents[0].0.cfg.rollout_len * envs_per_agent) as u64;
    let iters = steps_per_agent.div_ceil(steps_per_iter);
    let mut rollouts: Vec<crate::agents::ppo::Rollout> = agents
        .iter()
        .map(|(p, e)| {
            crate::agents::ppo::Rollout::new(
                p.cfg.rollout_len,
                e.batch_size(),
                crate::agents::OBS_DIM,
            )
        })
        .collect();
    let mut trackers: Vec<crate::agents::ReturnTracker> =
        (0..n_agents).map(|_| crate::agents::ReturnTracker::new(64)).collect();
    let mut curves: Vec<TrainLog> = (0..n_agents).map(|_| TrainLog::default()).collect();
    for it in 0..iters {
        for (a, (ppo, env)) in agents.iter_mut().enumerate() {
            env.collect(ppo, &mut rollouts[a], &mut trackers[a]);
            let m = ppo.update(&rollouts[a]);
            curves[a].curve.push(crate::agents::CurvePoint {
                env_steps: (it + 1) * steps_per_iter,
                mean_return: trackers[a].mean(),
                loss: m.pg_loss + m.v_loss,
            });
        }
    }
    let wall_secs = start.elapsed().as_secs_f64();
    for (a, mut log) in curves.into_iter().enumerate() {
        log.episodes = trackers[a].episodes;
        logs.push(log);
    }

    let total_env_steps = n_agents as u64 * iters * steps_per_iter;
    let mean_final_return =
        logs.iter().map(|l| l.final_return()).sum::<f32>() / n_agents as f32;
    Ok(MultiAgentResult {
        n_agents,
        envs_per_agent,
        total_env_steps,
        wall_secs,
        steps_per_second: total_env_steps as f64 / wall_secs.max(1e-12),
        mean_final_return,
        logs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_agents_train_independently() {
        let r = train_parallel_ppo("Navix-Empty-5x5-v0", 2, 4, 2_000, 0).unwrap();
        assert_eq!(r.n_agents, 2);
        assert_eq!(r.logs.len(), 2);
        assert!(r.total_env_steps >= 2 * 2_000);
        assert!(r.steps_per_second > 0.0);
        // different seeds → different curves
        let c0: Vec<f32> = r.logs[0].curve.iter().map(|p| p.loss).collect();
        let c1: Vec<f32> = r.logs[1].curve.iter().map(|p| p.loss).collect();
        assert_ne!(c0, c1);
    }

    #[test]
    fn sharded_mode_reproduces_single_threaded_training_exactly() {
        // Same seeds, same RNG contract → the sharded device axis must not
        // change a single loss value (learning is on the same trajectories).
        let single = train_parallel_ppo("Navix-Empty-5x5-v0", 1, 8, 1_024, 3).unwrap();
        let exec = ExecConfig { num_shards: 2, num_threads: 2, pipeline: false };
        let sharded =
            train_parallel_ppo_exec("Navix-Empty-5x5-v0", 1, 8, 1_024, 3, Some(exec)).unwrap();
        let l0: Vec<f32> = single.logs[0].curve.iter().map(|p| p.loss).collect();
        let l1: Vec<f32> = sharded.logs[0].curve.iter().map(|p| p.loss).collect();
        assert_eq!(l0, l1, "sharded training diverged from single-threaded");
        assert_eq!(single.logs[0].episodes, sharded.logs[0].episodes);
    }

    #[test]
    fn pipelined_mode_reproduces_single_threaded_training_exactly() {
        // The double-buffered pipeline reorders *when* compute happens,
        // never *what* is computed: the full training curve must be
        // bit-identical to the serial single-threaded run.
        let single = train_parallel_ppo("Navix-Empty-5x5-v0", 1, 8, 1_024, 5).unwrap();
        let exec = ExecConfig { num_shards: 2, num_threads: 2, pipeline: true };
        let piped =
            train_parallel_ppo_exec("Navix-Empty-5x5-v0", 1, 8, 1_024, 5, Some(exec)).unwrap();
        let l0: Vec<f32> = single.logs[0].curve.iter().map(|p| p.loss).collect();
        let l1: Vec<f32> = piped.logs[0].curve.iter().map(|p| p.loss).collect();
        assert_eq!(l0, l1, "pipelined training diverged from single-threaded");
        assert_eq!(single.logs[0].episodes, piped.logs[0].episodes);
        let r0: Vec<f32> = single.logs[0].curve.iter().map(|p| p.mean_return).collect();
        let r1: Vec<f32> = piped.logs[0].curve.iter().map(|p| p.mean_return).collect();
        assert_eq!(r0, r1);
    }
}
