//! XLA-fused PPO: the end-to-end three-layer path.
//!
//! Rollouts run on the Rust batched engine (L3). The actor-critic forward
//! (`ppo_fwd_b{B}`) and the entire minibatch update — forward, backward and
//! Adam, fused into one HLO module by `jax.grad` + XLA (`ppo_update_b{MB}`)
//! — execute through PJRT. The policy network's dense layers are Pallas
//! kernels (L1) lowered inside the same modules (see
//! `python/compile/kernels/mlp.py`).
//!
//! Parameters live in a flat `f32` vector with the packing convention of
//! [`crate::runtime::artifacts::packing`], shared bit-for-bit with the
//! Python side; Adam state (m, v) round-trips through the artifact as two
//! more flat vectors, so the Rust side owns *all* state and Python is never
//! on the path.

use crate::agents::gae;
use crate::agents::ppo::{PpoConfig, Rollout};
use crate::agents::{preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::batch::BatchedEnv;
use crate::nn::{log_softmax, sample_categorical};
use crate::rng::Rng;
use crate::runtime::artifacts::{packing, ArtifactSet};
use crate::runtime::client::{f32_literal, i32_literal, i32_scalar, to_f32_scalar, to_f32_vec};
use crate::runtime::{Executable, Runtime};
use anyhow::{Context, Result};

/// Update-step diagnostics mirrored from the artifact outputs.
#[derive(Clone, Copy, Debug, Default)]
pub struct XlaPpoMetrics {
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
}

/// PPO whose compute graph is the AOT JAX/Pallas artifact.
pub struct XlaPpo {
    pub cfg: PpoConfig,
    pub params: Vec<f32>,
    opt_m: Vec<f32>,
    opt_v: Vec<f32>,
    opt_t: i32,
    fwd: Executable,
    update: Executable,
    mb_size: usize,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
}

impl XlaPpo {
    /// Load artifacts for `num_envs` rollout batch and the minibatch size
    /// implied by the config, and He-init the flat parameters.
    pub fn new(cfg: PpoConfig, seed: u64) -> Result<XlaPpo> {
        let set = ArtifactSet::discover()?;
        let runtime = Runtime::cpu()?;
        let fwd = runtime
            .load_hlo(set.ppo_fwd(cfg.num_envs)?)
            .context("loading ppo_fwd artifact")?;
        let mb_size = cfg.num_envs * cfg.rollout_len / cfg.minibatches;
        let update = runtime
            .load_hlo(set.ppo_update(mb_size)?)
            .context("loading ppo_update artifact")?;
        let n = packing::total_params();
        Ok(XlaPpo {
            cfg,
            params: packing::init_params(seed),
            opt_m: vec![0.0; n],
            opt_v: vec![0.0; n],
            opt_t: 0,
            fwd,
            update,
            mb_size,
            // The artifacts are compiled against the full policy-width
            // input — grid features ++ mission token block — derived from
            // `agents::OBS_DIM` on both sides, so the XLA path is
            // goal-conditioned like the native trainers (see
            // EXPERIMENTS.md §Goal-conditioning).
            obs_dim: packing::OBS_DIM,
            n_actions: packing::N_ACTIONS,
            rng: Rng::new(seed ^ 0x9E37),
        })
    }

    /// Batched policy forward through the artifact: returns (logits, values).
    pub fn forward(&self, obs: &[i32], b: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        let p = f32_literal(&self.params, &[self.params.len() as i64])?;
        let o = i32_literal(obs, &[b as i64, self.obs_dim as i64])?;
        let out = self.fwd.run(&[p, o])?;
        anyhow::ensure!(out.len() == 2, "ppo_fwd must return (logits, values)");
        Ok((to_f32_vec(&out[0])?, to_f32_vec(&out[1])?))
    }

    /// One fused minibatch update through the artifact.
    pub fn update_minibatch(
        &mut self,
        obs: &[i32],
        actions: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        targets: &[f32],
    ) -> Result<XlaPpoMetrics> {
        let mb = self.mb_size as i64;
        self.opt_t += 1;
        let inputs = [
            f32_literal(&self.params, &[self.params.len() as i64])?,
            f32_literal(&self.opt_m, &[self.opt_m.len() as i64])?,
            f32_literal(&self.opt_v, &[self.opt_v.len() as i64])?,
            i32_scalar(self.opt_t),
            i32_literal(obs, &[mb, self.obs_dim as i64])?,
            i32_literal(actions, &[mb])?,
            f32_literal(old_logp, &[mb])?,
            f32_literal(adv, &[mb])?,
            f32_literal(targets, &[mb])?,
        ];
        let out = self.update.run(&inputs)?;
        anyhow::ensure!(out.len() == 6, "ppo_update must return 6 outputs, got {}", out.len());
        self.params = to_f32_vec(&out[0])?;
        self.opt_m = to_f32_vec(&out[1])?;
        self.opt_v = to_f32_vec(&out[2])?;
        Ok(XlaPpoMetrics {
            pg_loss: to_f32_scalar(&out[3])?,
            v_loss: to_f32_scalar(&out[4])?,
            entropy: to_f32_scalar(&out[5])?,
        })
    }

    /// Collect a rollout on the Rust engine, acting through the artifact.
    fn collect_rollout(
        &mut self,
        env: &mut BatchedEnv,
        ro: &mut Rollout,
        raw_obs: &mut [i32],
        tracker: &mut ReturnTracker,
    ) -> Result<()> {
        let (t_len, b) = (self.cfg.rollout_len, env.b);
        let d = self.obs_dim;
        let mut obs_buf = vec![0i32; b * d];
        let mut actions = vec![0u8; b];
        let mut lp = vec![0.0f32; self.n_actions];
        for t in 0..t_len {
            // Policy-width rows (grid ++ mission tokens): one raw i32
            // snapshot for the artifact inputs, one featurised block
            // straight into the rollout.
            for i in 0..b {
                env.obs.copy_policy_row(b, i, &mut obs_buf[i * d..(i + 1) * d]);
            }
            raw_obs[t * b * d..(t + 1) * b * d].copy_from_slice(&obs_buf);
            preprocess_obs(&obs_buf, &mut ro.obs[t * b * d..(t + 1) * b * d]);
            let (logits, values) = self.forward(&obs_buf, b)?;
            for i in 0..b {
                let lslice = &logits[i * self.n_actions..(i + 1) * self.n_actions];
                let a = sample_categorical(lslice, &mut self.rng);
                log_softmax(lslice, &mut lp);
                let idx = t * b + i;
                ro.actions[idx] = a as u8;
                ro.logp[idx] = lp[a];
                ro.values[idx] = values[i];
                actions[i] = a as u8;
            }
            env.step(&actions);
            for i in 0..b {
                let idx = t * b + i;
                ro.rewards[idx] = env.timestep.reward[i];
                ro.discounts[idx] = env.timestep.discount[i];
                let last = env.timestep.step_type[i].is_last();
                ro.boundaries[idx] = last;
                if last {
                    tracker.push(env.timestep.episodic_return[i]);
                }
            }
        }
        for i in 0..b {
            env.obs.copy_policy_row(b, i, &mut obs_buf[i * d..(i + 1) * d]);
        }
        let (_, values) = self.forward(&obs_buf, b)?;
        ro.last_values.copy_from_slice(&values);
        gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            self.cfg.gamma,
            self.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        if self.cfg.normalize_advantage {
            gae::normalize(&mut ro.advantages);
        }
        Ok(())
    }

    /// Full training run. Mirrors [`crate::agents::ppo::Ppo::train`] with
    /// the compute swapped for the artifacts.
    pub fn train(&mut self, env: &mut BatchedEnv, total_steps: u64) -> Result<TrainLog> {
        anyhow::ensure!(
            env.b == self.cfg.num_envs,
            "env batch {} != artifact batch {}",
            env.b,
            self.cfg.num_envs
        );
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let (t_len, b, d) = (self.cfg.rollout_len, env.b, self.obs_dim);
        let steps_per_iter = (t_len * b) as u64;
        let iters = total_steps.div_ceil(steps_per_iter);
        let mut ro = Rollout::new(t_len, b, d);
        let mut raw_obs = vec![0i32; t_len * b * d];

        let n = t_len * b;
        let mut order: Vec<usize> = (0..n).collect();
        let mut mb_obs = vec![0i32; self.mb_size * d];
        let mut mb_actions = vec![0i32; self.mb_size];
        let mut mb_logp = vec![0.0f32; self.mb_size];
        let mut mb_adv = vec![0.0f32; self.mb_size];
        let mut mb_tgt = vec![0.0f32; self.mb_size];

        for it in 0..iters {
            self.collect_rollout(env, &mut ro, &mut raw_obs, &mut tracker)?;
            let mut metrics = XlaPpoMetrics::default();
            let mut updates = 0.0f32;
            for _ in 0..self.cfg.epochs {
                self.rng.shuffle(&mut order);
                for mb in order.chunks_exact(self.mb_size) {
                    for (k, &idx) in mb.iter().enumerate() {
                        mb_obs[k * d..(k + 1) * d]
                            .copy_from_slice(&raw_obs[idx * d..(idx + 1) * d]);
                        mb_actions[k] = ro.actions[idx] as i32;
                        mb_logp[k] = ro.logp[idx];
                        mb_adv[k] = ro.advantages[idx];
                        mb_tgt[k] = ro.targets[idx];
                    }
                    let m = self.update_minibatch(
                        &mb_obs, &mb_actions, &mb_logp, &mb_adv, &mb_tgt,
                    )?;
                    metrics.pg_loss += m.pg_loss;
                    metrics.v_loss += m.v_loss;
                    metrics.entropy += m.entropy;
                    updates += 1.0;
                }
            }
            log.curve.push(CurvePoint {
                env_steps: (it + 1) * steps_per_iter,
                mean_return: tracker.mean(),
                loss: (metrics.pg_loss + metrics.v_loss) / updates.max(1.0),
            });
        }
        log.episodes = tracker.episodes;
        Ok(log)
    }
}
