//! Proximal Policy Optimization (Schulman et al., 2017) — the paper's
//! flagship baseline and the workload of its Fig. 6 scaling experiment
//! (N agents × 16 envs each).
//!
//! This is the *native* implementation (manual backprop through the
//! [`crate::nn`] substrate). The XLA-fused variant — rollouts here, update
//! as a single AOT-compiled JAX/Pallas executable — lives in
//! [`crate::coordinator::trainer`]; both share this module's rollout and
//! GAE machinery, and a cross-check test asserts they optimise the same
//! objective.

use crate::agents::{gae, preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::batch::BatchStepper;
use crate::core::actions::Action;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::{log_softmax, sample_categorical, softmax, Activation, Mlp};
use crate::rng::Rng;

/// PPO hyperparameters (defaults follow the paper's Rejax configs for
/// MiniGrid-scale tasks; every Table-9 "fitted" knob is here).
#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub num_envs: usize,
    pub rollout_len: usize,
    pub epochs: usize,
    pub minibatches: usize,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub clip_eps: f32,
    pub lr: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    pub max_grad_norm: f32,
    pub normalize_advantage: bool,
    pub activation: Activation,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            num_envs: 16,
            rollout_len: 128,
            epochs: 4,
            minibatches: 8,
            gamma: 0.99,
            gae_lambda: 0.95,
            clip_eps: 0.2,
            lr: 2.5e-4,
            vf_coef: 0.5,
            ent_coef: 0.01,
            max_grad_norm: 0.5,
            normalize_advantage: true,
            activation: Activation::Tanh,
        }
    }
}

/// Update-step diagnostics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PpoMetrics {
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
}

/// Native PPO agent: separate actor/critic MLPs (2×64 as in the paper).
pub struct Ppo {
    pub cfg: PpoConfig,
    pub actor: Mlp,
    pub critic: Mlp,
    actor_opt: Adam,
    critic_opt: Adam,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
}

/// Rollout storage (time-major `[T × B]`).
pub struct Rollout {
    pub obs: Vec<f32>,
    pub actions: Vec<u8>,
    pub logp: Vec<f32>,
    pub values: Vec<f32>,
    pub rewards: Vec<f32>,
    pub discounts: Vec<f32>,
    pub boundaries: Vec<bool>,
    pub last_values: Vec<f32>,
    pub advantages: Vec<f32>,
    pub targets: Vec<f32>,
}

impl Rollout {
    pub fn new(t: usize, b: usize, obs_dim: usize) -> Rollout {
        Rollout {
            obs: vec![0.0; t * b * obs_dim],
            actions: vec![0; t * b],
            logp: vec![0.0; t * b],
            values: vec![0.0; t * b],
            rewards: vec![0.0; t * b],
            discounts: vec![0.0; t * b],
            boundaries: vec![false; t * b],
            last_values: vec![0.0; b],
            advantages: vec![0.0; t * b],
            targets: vec![0.0; t * b],
        }
    }
}

impl Ppo {
    pub fn new(cfg: PpoConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Ppo {
        let mut rng = Rng::new(seed);
        let actor = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let critic = Mlp::new(&[obs_dim, 64, 64, 1], cfg.activation, &mut rng);
        let actor_opt = Adam::new(actor.params.len(), cfg.lr);
        let critic_opt = Adam::new(critic.params.len(), cfg.lr);
        Ppo { cfg, actor, critic, actor_opt, critic_opt, obs_dim, n_actions, rng }
    }

    /// Collect one on-policy rollout from `env` into `ro`. Generic over the
    /// execution backend: the single-threaded [`crate::batch::BatchedEnv`]
    /// or the sharded multi-core [`crate::batch::ShardedEnv`].
    pub fn collect_rollout<E: BatchStepper + ?Sized>(
        &mut self,
        env: &mut E,
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
    ) {
        let (t_len, b) = (self.cfg.rollout_len, env.batch_size());
        let mut x = vec![0.0f32; self.obs_dim];
        let mut actions = vec![0u8; b];
        for t in 0..t_len {
            for i in 0..b {
                preprocess_obs(env.obs().env_i32(b, i), &mut x);
                let logits = self.actor.infer(&x);
                let value = self.critic.infer(&x)[0];
                let a = sample_categorical(&logits, &mut self.rng);
                let mut lp = vec![0.0; self.n_actions];
                log_softmax(&logits, &mut lp);
                let idx = t * b + i;
                ro.obs[idx * self.obs_dim..(idx + 1) * self.obs_dim].copy_from_slice(&x);
                ro.actions[idx] = a as u8;
                ro.logp[idx] = lp[a];
                ro.values[idx] = value;
                actions[i] = a as u8;
            }
            env.step(&actions);
            let ts = env.timestep();
            for i in 0..b {
                let idx = t * b + i;
                ro.rewards[idx] = ts.reward[i];
                ro.discounts[idx] = ts.discount[i];
                let last = ts.step_type[i].is_last();
                ro.boundaries[idx] = last;
                if last {
                    tracker.push(ts.episodic_return[i]);
                }
            }
        }
        for i in 0..b {
            preprocess_obs(env.obs().env_i32(b, i), &mut x);
            ro.last_values[i] = self.critic.infer(&x)[0];
        }
        gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            self.cfg.gamma,
            self.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        if self.cfg.normalize_advantage {
            gae::normalize(&mut ro.advantages);
        }
    }

    /// Run the clipped-objective update epochs over the rollout.
    pub fn update(&mut self, ro: &Rollout) -> PpoMetrics {
        let n = ro.actions.len();
        let mb_size = (n / self.cfg.minibatches).max(1);
        let mut order: Vec<usize> = (0..n).collect();
        let mut metrics = PpoMetrics::default();
        let mut count = 0.0f32;

        let mut a_grads = vec![0.0f32; self.actor.params.len()];
        let mut c_grads = vec![0.0f32; self.critic.params.len()];
        let mut cache = crate::nn::mlp::Cache::default();
        let mut vcache = crate::nn::mlp::Cache::default();

        for _ in 0..self.cfg.epochs {
            self.rng.shuffle(&mut order);
            for mb in order.chunks(mb_size) {
                a_grads.fill(0.0);
                c_grads.fill(0.0);
                let scale = 1.0 / mb.len() as f32;
                for &idx in mb {
                    let x = &ro.obs[idx * self.obs_dim..(idx + 1) * self.obs_dim];
                    let a = ro.actions[idx] as usize;
                    let adv = ro.advantages[idx];
                    let old_lp = ro.logp[idx];

                    // actor
                    let logits = self.actor.forward(x, &mut cache);
                    let mut lp = vec![0.0; self.n_actions];
                    log_softmax(&logits, &mut lp);
                    let mut probs = vec![0.0; self.n_actions];
                    softmax(&logits, &mut probs);
                    let ratio = (lp[a] - old_lp).exp();
                    let clipped =
                        ratio.clamp(1.0 - self.cfg.clip_eps, 1.0 + self.cfg.clip_eps);
                    let unclipped_obj = ratio * adv;
                    let clipped_obj = clipped * adv;
                    // d(-min)/dlogp = -adv*ratio where the unclipped branch
                    // is active, 0 otherwise.
                    let pg_coef =
                        if unclipped_obj <= clipped_obj { -adv * ratio } else { 0.0 };
                    let entropy: f32 =
                        -probs.iter().zip(&lp).map(|(&p, &l)| p * l).sum::<f32>();
                    let mut dlogits = vec![0.0f32; self.n_actions];
                    for j in 0..self.n_actions {
                        let ind = if j == a { 1.0 } else { 0.0 };
                        let dlogp_a = ind - probs[j];
                        let dentropy = -probs[j] * (lp[j] + entropy);
                        dlogits[j] =
                            scale * (pg_coef * dlogp_a - self.cfg.ent_coef * dentropy);
                    }
                    self.actor.backward(&cache, &dlogits, &mut a_grads);

                    // critic
                    let v = self.critic.forward(x, &mut vcache)[0];
                    let verr = v - ro.targets[idx];
                    self.critic.backward(
                        &vcache,
                        &[scale * self.cfg.vf_coef * verr],
                        &mut c_grads,
                    );

                    metrics.pg_loss += -unclipped_obj.min(clipped_obj);
                    metrics.v_loss += 0.5 * verr * verr;
                    metrics.entropy += entropy;
                    count += 1.0;
                }
                clip_global_norm(&mut a_grads, self.cfg.max_grad_norm);
                clip_global_norm(&mut c_grads, self.cfg.max_grad_norm);
                self.actor_opt.step(&mut self.actor.params, &a_grads);
                self.critic_opt.step(&mut self.critic.params, &c_grads);
            }
        }
        metrics.pg_loss /= count;
        metrics.v_loss /= count;
        metrics.entropy /= count;
        metrics
    }

    /// Full training loop: `total_steps` environment steps on `env`.
    pub fn train<E: BatchStepper + ?Sized>(&mut self, env: &mut E, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let steps_per_iter = (self.cfg.rollout_len * env.batch_size()) as u64;
        let iters = total_steps.div_ceil(steps_per_iter);
        let mut ro = Rollout::new(self.cfg.rollout_len, env.batch_size(), self.obs_dim);
        for it in 0..iters {
            self.collect_rollout(env, &mut ro, &mut tracker);
            let m = self.update(&ro);
            log.curve.push(CurvePoint {
                env_steps: (it + 1) * steps_per_iter,
                mean_return: tracker.mean(),
                loss: m.pg_loss + m.v_loss,
            });
        }
        log.episodes = tracker.episodes;
        log
    }

    /// Greedy action for evaluation.
    pub fn act_greedy(&self, obs: &[i32]) -> Action {
        let mut x = vec![0.0f32; self.obs_dim];
        preprocess_obs(obs, &mut x);
        Action::from_u8(crate::nn::argmax(&self.actor.infer(&x)) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::BatchedEnv;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn rollout_fills_all_fields() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 4, Key::new(0));
        let mut ppo = Ppo::new(PpoConfig { rollout_len: 8, ..Default::default() }, 147, 7, 0);
        let mut ro = Rollout::new(8, 4, 147);
        let mut tracker = ReturnTracker::new(8);
        ppo.collect_rollout(&mut env, &mut ro, &mut tracker);
        assert!(ro.logp.iter().all(|&l| l <= 0.0), "log-probs must be ≤ 0");
        assert!(ro.values.iter().any(|&v| v != 0.0), "critic should output something");
    }

    #[test]
    fn update_changes_parameters_and_reports_entropy() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 4, Key::new(0));
        let mut ppo = Ppo::new(
            PpoConfig { rollout_len: 16, minibatches: 2, epochs: 2, ..Default::default() },
            147,
            7,
            0,
        );
        let mut ro = Rollout::new(16, 4, 147);
        let mut tracker = ReturnTracker::new(8);
        ppo.collect_rollout(&mut env, &mut ro, &mut tracker);
        let before = ppo.actor.params.clone();
        let m = ppo.update(&ro);
        assert_ne!(before, ppo.actor.params);
        // fresh policy over 7 actions: entropy near ln(7) ≈ 1.95
        assert!(m.entropy > 1.0 && m.entropy < 2.0, "entropy {}", m.entropy);
    }

    #[test]
    fn ppo_improves_on_empty_5x5_smoke() {
        // Short-budget smoke: after ~40k steps on Empty-5x5 (dense-enough
        // task) mean return should clearly beat the random-policy baseline.
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(1));
        let mut ppo = Ppo::new(
            PpoConfig { num_envs: 8, rollout_len: 64, lr: 1e-3, ..Default::default() },
            147,
            7,
            1,
        );
        let log = ppo.train(&mut env, 40_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.5,
            "PPO failed to learn Empty-5x5: final mean return {final_ret} over {} episodes",
            log.episodes
        );
    }
}
