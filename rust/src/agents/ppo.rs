//! Proximal Policy Optimization (Schulman et al., 2017) — the paper's
//! flagship baseline and the workload of its Fig. 6 scaling experiment
//! (N agents × 16 envs each).
//!
//! This is the *native* implementation (manual backprop through the
//! [`crate::nn`] substrate). The XLA-fused variant — rollouts here, update
//! as a single AOT-compiled JAX/Pallas executable — lives in
//! [`crate::coordinator::trainer`]; both share this module's rollout and
//! GAE machinery, and a cross-check test asserts they optimise the same
//! objective.
//!
//! ## Execution paths (PR 4 batched inference, PR 6 fused rollouts)
//!
//! The hot paths are **batch-oriented**: inference featurises the whole
//! observation batch into one contiguous `[B, obs_dim]` buffer and runs a
//! single batched actor/critic forward per env step, and [`Ppo::update`]
//! drives minibatch GEMMs through
//! [`Mlp::forward_batch`]/[`Mlp::backward_batch`] with reusable workspaces
//! (zero per-sample allocation).
//!
//! Rollout collection is **fused** (scan mode): [`Ppo::collect_rollout`]
//! hands the entire horizon to the engine as one
//! [`BatchStepper::step_n`] call, supplying actions through an
//! [`ActionProvider`] whose `overlap` hook carries the critic/log-prob/
//! bookkeeping half of inference — inside a [`PipelinedEnv`]
//! ([`Ppo::collect_rollout_pipelined`]) that work overlaps the environment
//! step, reproducing the double-buffered schedule exactly. The per-step
//! batched loop is kept as [`Ppo::collect_rollout_stepwise`], the
//! batch-level parity oracle for the fused path.
//!
//! All of this is **bit-for-bit identical** to the original per-sample
//! implementation, which is kept as [`Ppo::collect_rollout_serial`] /
//! [`Ppo::update_serial`] — the parity oracle that
//! `tests/test_train_parity.rs` pins the batched + pipelined paths
//! against (the batch kernels preserve summation order; see
//! [`crate::nn::mlp`]).

use crate::agents::{
    ensure, gae, preprocess_env_obs, preprocess_obs_batch, CurvePoint, ReturnTracker, TrainLog,
};
use crate::batch::{
    ActionPlan, ActionProvider, BatchStepper, ObsBatch, PipelinedEnv, TrajectorySlice,
};
use crate::core::actions::Action;
use crate::core::timestep::BatchedTimestep;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::mlp::BatchCache;
use crate::nn::{log_softmax, sample_categorical, softmax, Activation, Mlp};
use crate::rng::Rng;

/// PPO hyperparameters (defaults follow the paper's Rejax configs for
/// MiniGrid-scale tasks; every Table-9 "fitted" knob is here).
#[derive(Clone, Debug)]
pub struct PpoConfig {
    pub num_envs: usize,
    pub rollout_len: usize,
    pub epochs: usize,
    pub minibatches: usize,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub clip_eps: f32,
    pub lr: f32,
    pub vf_coef: f32,
    pub ent_coef: f32,
    pub max_grad_norm: f32,
    pub normalize_advantage: bool,
    pub activation: Activation,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            num_envs: 16,
            rollout_len: 128,
            epochs: 4,
            minibatches: 8,
            gamma: 0.99,
            gae_lambda: 0.95,
            clip_eps: 0.2,
            lr: 2.5e-4,
            vf_coef: 0.5,
            ent_coef: 0.01,
            max_grad_norm: 0.5,
            normalize_advantage: true,
            activation: Activation::Tanh,
        }
    }
}

/// Update-step diagnostics.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PpoMetrics {
    pub pg_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
}

/// Reusable buffers for the batched hot paths. Grown on first use; a
/// training loop performs no per-sample heap allocation after the first
/// iteration (the satellite fix for the `probs`/`logits` scratch vectors
/// the old per-sample update reallocated every sample).
#[derive(Default)]
struct Workspace {
    /// `[B × obs_dim]` acting features of the current step.
    x: Vec<f32>,
    /// `[B]` actions handed to the stepper.
    actions: Vec<u8>,
    /// `[n_actions]` log-softmax row scratch.
    lp: Vec<f32>,
    /// `[n_actions]` softmax row scratch.
    probs: Vec<f32>,
    acache: BatchCache,
    ccache: BatchCache,
    /// `[MB × obs_dim]` gathered minibatch features.
    mb_x: Vec<f32>,
    /// `[MB × n_actions]` actor output gradient.
    mb_dlogits: Vec<f32>,
    /// `[MB × 1]` critic output gradient.
    mb_dv: Vec<f32>,
    a_grads: Vec<f32>,
    c_grads: Vec<f32>,
    /// Reusable fused-rollout trajectory window (scan mode).
    traj: TrajectorySlice,
}

/// Native PPO agent: separate actor/critic MLPs (2×64 as in the paper).
pub struct Ppo {
    pub cfg: PpoConfig,
    pub actor: Mlp,
    pub critic: Mlp,
    actor_opt: Adam,
    critic_opt: Adam,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
    ws: Workspace,
}

/// Everything [`Ppo`] needs to resume training bit-identically: network
/// weights, both Adam moment vectors, and the agent RNG stream. Pair it
/// with the engine's [`crate::core::snapshot::EngineCheckpoint`] (and the
/// caller's [`ReturnTracker`], which is `Clone`) to checkpoint a training
/// run mid-rollout.
#[derive(Clone, Debug)]
pub struct PpoCheckpoint {
    pub actor: Mlp,
    pub critic: Mlp,
    pub actor_opt: Adam,
    pub critic_opt: Adam,
    pub rng: Rng,
}

/// Rollout storage (time-major `[T × B·A]` — one row per agent-row, so a
/// multi-agent engine's every agent contributes transitions; `b` below is
/// [`BatchStepper::policy_rows`]).
pub struct Rollout {
    pub obs: Vec<f32>,
    pub actions: Vec<u8>,
    pub logp: Vec<f32>,
    pub values: Vec<f32>,
    pub rewards: Vec<f32>,
    pub discounts: Vec<f32>,
    pub boundaries: Vec<bool>,
    pub last_values: Vec<f32>,
    pub advantages: Vec<f32>,
    pub targets: Vec<f32>,
}

impl Rollout {
    pub fn new(t: usize, b: usize, obs_dim: usize) -> Rollout {
        Rollout {
            obs: vec![0.0; t * b * obs_dim],
            actions: vec![0; t * b],
            logp: vec![0.0; t * b],
            values: vec![0.0; t * b],
            rewards: vec![0.0; t * b],
            discounts: vec![0.0; t * b],
            boundaries: vec![false; t * b],
            last_values: vec![0.0; b],
            advantages: vec![0.0; t * b],
            targets: vec![0.0; t * b],
        }
    }
}

/// Per-step policy evaluation plugged into the fused [`BatchStepper::step_n`]
/// loop. `actions` runs the featurise → actor forward → sample half (the part
/// the engine must wait on); `overlap` runs the critic forward + rollout
/// bookkeeping half, which reads only step *t*'s snapshot and can therefore
/// proceed while a pipelined engine steps the envs to *t + 1*.
struct FusedActing<'a> {
    ppo: &'a mut Ppo,
    ro: &'a mut Rollout,
    b: usize,
}

impl ActionProvider for FusedActing<'_> {
    fn actions(&mut self, t: usize, obs: &ObsBatch, _ts: &BatchedTimestep, out: &mut [u8]) {
        let (b, d) = (self.b, self.ppo.obs_dim);
        preprocess_obs_batch(obs, &mut self.ppo.ws.x[..b * d]);
        self.ppo.actor.forward_batch(&self.ppo.ws.x[..b * d], b, &mut self.ppo.ws.acache);
        self.ppo.sample_actions(self.ro, t * b, b);
        out.copy_from_slice(&self.ppo.ws.actions[..b]);
    }

    fn overlap(&mut self, t: usize) {
        let (b, d) = (self.b, self.ppo.obs_dim);
        self.ppo.critic.forward_batch(&self.ppo.ws.x[..b * d], b, &mut self.ppo.ws.ccache);
        self.ppo.record_step(self.ro, t * b, b);
    }
}

impl Ppo {
    pub fn new(cfg: PpoConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Ppo {
        let mut rng = Rng::new(seed);
        let actor = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let critic = Mlp::new(&[obs_dim, 64, 64, 1], cfg.activation, &mut rng);
        let actor_opt = Adam::new(actor.params.len(), cfg.lr);
        let critic_opt = Adam::new(critic.params.len(), cfg.lr);
        Ppo {
            cfg,
            actor,
            critic,
            actor_opt,
            critic_opt,
            obs_dim,
            n_actions,
            rng,
            ws: Workspace::default(),
        }
    }

    /// Sample one action per env from the `[b × n_actions]` logits in the
    /// actor cache, writing `ws.actions` and `ro.actions[base..base+b]`.
    /// Draws from `rng` in ascending env order — the exact draw sequence of
    /// the serial path's per-sample [`sample_categorical`].
    fn sample_actions(&mut self, ro: &mut Rollout, base: usize, b: usize) {
        let na = self.n_actions;
        let ws = &mut self.ws;
        let logits = ws.acache.out();
        for i in 0..b {
            let lrow = &logits[i * na..(i + 1) * na];
            softmax(lrow, &mut ws.probs[..na]);
            let a = self.rng.categorical(&ws.probs[..na]) as u8;
            ws.actions[i] = a;
            ro.actions[base + i] = a;
        }
    }

    /// The bookkeeping half of acting for one step: log-probs from the
    /// actor cache, values from the critic cache, features into the
    /// rollout. Needs nothing from the environment, so the pipelined path
    /// runs it inside the overlap window while the workers step.
    fn record_step(&mut self, ro: &mut Rollout, base: usize, b: usize) {
        let (d, na) = (self.obs_dim, self.n_actions);
        let ws = &mut self.ws;
        ro.obs[base * d..(base + b) * d].copy_from_slice(&ws.x[..b * d]);
        let logits = ws.acache.out();
        let values = ws.ccache.out();
        for i in 0..b {
            let idx = base + i;
            log_softmax(&logits[i * na..(i + 1) * na], &mut ws.lp[..na]);
            ro.logp[idx] = ws.lp[ro.actions[idx] as usize];
            ro.values[idx] = values[i];
        }
    }

    /// Record the post-step timestep metadata for one rollout row.
    fn record_timestep(
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
        ts: &crate::core::timestep::BatchedTimestep,
        base: usize,
        b: usize,
    ) {
        for i in 0..b {
            let idx = base + i;
            ro.rewards[idx] = ts.reward[i];
            ro.discounts[idx] = ts.discount[i];
            let last = ts.step_type[i].is_last();
            ro.boundaries[idx] = last;
            if last {
                tracker.push(ts.episodic_return[i]);
            }
        }
    }

    fn finish_rollout(&mut self, ro: &mut Rollout, b: usize) {
        ro.last_values[..b].copy_from_slice(&self.ws.ccache.out()[..b]);
        gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            self.cfg.gamma,
            self.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        if self.cfg.normalize_advantage {
            gae::normalize(&mut ro.advantages);
        }
    }

    fn ensure_rollout_ws(&mut self, b: usize) {
        let (d, na) = (self.obs_dim, self.n_actions);
        let ws = &mut self.ws;
        ensure(&mut ws.x, b * d);
        ensure(&mut ws.actions, b);
        ensure(&mut ws.lp, na);
        ensure(&mut ws.probs, na);
    }

    /// Collect one on-policy rollout from `env` into `ro` — **fused**: the
    /// entire horizon is one [`BatchStepper::step_n`] call, with batched
    /// inference supplied per step through a [`FusedActing`] provider (the
    /// whole `ObsBatch` featurised into one contiguous `[B, obs_dim]`
    /// buffer, a single actor + critic forward serving all envs). Rewards,
    /// discounts and episode boundaries come back as one time-major
    /// [`TrajectorySlice`] window and are copied into the rollout with one
    /// `memcpy` per field. Generic over the execution backend
    /// ([`crate::batch::BatchedEnv`], [`crate::batch::ShardedEnv`], or a
    /// [`PipelinedEnv`] — whose `step_n` overlaps the provider's critic/
    /// bookkeeping work with the environment step). Bit-identical to
    /// [`Ppo::collect_rollout_stepwise`] and
    /// [`Ppo::collect_rollout_serial`].
    pub fn collect_rollout<E: BatchStepper + ?Sized>(
        &mut self,
        env: &mut E,
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
    ) {
        let (t_len, b, d) = (self.cfg.rollout_len, env.policy_rows(), self.obs_dim);
        self.ensure_rollout_ws(b);
        // Take the workspace window out so the provider can borrow `self`
        // while the engine fills it.
        let mut traj = std::mem::take(&mut self.ws.traj);
        {
            let mut acting = FusedActing { ppo: &mut *self, ro: &mut *ro, b };
            env.step_n(ActionPlan::Provider(&mut acting), t_len, &mut traj);
        }
        // Window → rollout tensors: both are time-major [T × B].
        ro.rewards.copy_from_slice(&traj.reward);
        ro.discounts.copy_from_slice(&traj.discount);
        for idx in 0..t_len * b {
            let last = traj.step_type[idx].is_last();
            ro.boundaries[idx] = last;
            if last {
                // (t asc, env asc) — the per-step paths' push order.
                tracker.push(traj.episodic_return[idx]);
            }
        }
        self.ws.traj = traj;
        preprocess_obs_batch(env.obs(), &mut self.ws.x[..b * d]);
        self.critic.forward_batch(&self.ws.x[..b * d], b, &mut self.ws.ccache);
        self.finish_rollout(ro, b);
    }

    /// [`Ppo::collect_rollout`] on a [`PipelinedEnv`]: the fused horizon
    /// call dispatches to the pipeline's `step_n`, which submits step
    /// *t*'s actions as soon as the actor has sampled them and runs the
    /// provider's overlap hook — the critic forward + log-prob/rollout
    /// bookkeeping for step *t* — while the workers advance the
    /// environments to *t + 1*. Same trajectories, same RNG stream, same
    /// floats — only the schedule changes.
    pub fn collect_rollout_pipelined(
        &mut self,
        env: &mut PipelinedEnv,
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
    ) {
        self.collect_rollout(env, ro, tracker);
    }

    /// The pre-fusion per-step batched rollout loop, kept verbatim as the
    /// batch-level parity oracle for the fused scan path (and the
    /// scan-vs-stepwise comparison rows of the `fig6_ppo_agents` bench).
    /// One `env.step` dispatch per step; same floats, same RNG stream as
    /// [`Ppo::collect_rollout`].
    pub fn collect_rollout_stepwise<E: BatchStepper + ?Sized>(
        &mut self,
        env: &mut E,
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
    ) {
        let (t_len, b, d) = (self.cfg.rollout_len, env.policy_rows(), self.obs_dim);
        self.ensure_rollout_ws(b);
        for t in 0..t_len {
            let base = t * b;
            preprocess_obs_batch(env.obs(), &mut self.ws.x[..b * d]);
            self.actor.forward_batch(&self.ws.x[..b * d], b, &mut self.ws.acache);
            self.sample_actions(ro, base, b);
            self.critic.forward_batch(&self.ws.x[..b * d], b, &mut self.ws.ccache);
            self.record_step(ro, base, b);
            env.step(&self.ws.actions[..b]);
            Ppo::record_timestep(ro, tracker, env.timestep(), base, b);
        }
        preprocess_obs_batch(env.obs(), &mut self.ws.x[..b * d]);
        self.critic.forward_batch(&self.ws.x[..b * d], b, &mut self.ws.ccache);
        self.finish_rollout(ro, b);
    }

    /// The original per-sample rollout, kept verbatim as the parity oracle
    /// for the batched and pipelined paths (`tests/test_train_parity.rs`).
    pub fn collect_rollout_serial<E: BatchStepper + ?Sized>(
        &mut self,
        env: &mut E,
        ro: &mut Rollout,
        tracker: &mut ReturnTracker,
    ) {
        let (t_len, b) = (self.cfg.rollout_len, env.policy_rows());
        let mut x = vec![0.0f32; self.obs_dim];
        let mut actions = vec![0u8; b];
        for t in 0..t_len {
            for i in 0..b {
                preprocess_env_obs(env.obs(), b, i, &mut x);
                let logits = self.actor.infer(&x);
                let value = self.critic.infer(&x)[0];
                let a = sample_categorical(&logits, &mut self.rng);
                let mut lp = vec![0.0; self.n_actions];
                log_softmax(&logits, &mut lp);
                let idx = t * b + i;
                ro.obs[idx * self.obs_dim..(idx + 1) * self.obs_dim].copy_from_slice(&x);
                ro.actions[idx] = a as u8;
                ro.logp[idx] = lp[a];
                ro.values[idx] = value;
                actions[i] = a as u8;
            }
            env.step(&actions);
            Ppo::record_timestep(ro, tracker, env.timestep(), t * b, b);
        }
        for i in 0..b {
            preprocess_env_obs(env.obs(), b, i, &mut x);
            ro.last_values[i] = self.critic.infer(&x)[0];
        }
        gae::gae(
            &ro.rewards,
            &ro.values,
            &ro.last_values,
            &ro.discounts,
            &ro.boundaries,
            self.cfg.gamma,
            self.cfg.gae_lambda,
            &mut ro.advantages,
            &mut ro.targets,
        );
        if self.cfg.normalize_advantage {
            gae::normalize(&mut ro.advantages);
        }
    }

    /// Run the clipped-objective update epochs over the rollout with
    /// minibatch GEMMs: one batched actor forward/backward and one batched
    /// critic forward/backward per minibatch, through reusable workspaces.
    /// Bit-identical to [`Ppo::update_serial`] (same RNG stream, same
    /// per-parameter summation order — see [`crate::nn::mlp`]).
    pub fn update(&mut self, ro: &Rollout) -> PpoMetrics {
        let n = ro.actions.len();
        let (d, na) = (self.obs_dim, self.n_actions);
        let mb_size = (n / self.cfg.minibatches).max(1);
        let mut order: Vec<usize> = (0..n).collect();
        let mut metrics = PpoMetrics::default();
        let mut count = 0.0f32;

        let (alen, clen) = (self.actor.params.len(), self.critic.params.len());
        {
            let ws = &mut self.ws;
            ensure(&mut ws.a_grads, alen);
            ensure(&mut ws.c_grads, clen);
            ensure(&mut ws.mb_x, mb_size * d);
            ensure(&mut ws.mb_dlogits, mb_size * na);
            ensure(&mut ws.mb_dv, mb_size);
            ensure(&mut ws.lp, na);
            ensure(&mut ws.probs, na);
        }

        for _ in 0..self.cfg.epochs {
            self.rng.shuffle(&mut order);
            for mb in order.chunks(mb_size) {
                let m = mb.len();
                let scale = 1.0 / m as f32;
                {
                    let ws = &mut self.ws;
                    ws.a_grads[..alen].fill(0.0);
                    ws.c_grads[..clen].fill(0.0);
                    for (k, &idx) in mb.iter().enumerate() {
                        ws.mb_x[k * d..(k + 1) * d]
                            .copy_from_slice(&ro.obs[idx * d..(idx + 1) * d]);
                    }
                }

                // Actor: batched forward, per-row clipped-objective
                // gradient, one batched backward.
                self.actor.forward_batch(&self.ws.mb_x[..m * d], m, &mut self.ws.acache);
                {
                    let ws = &mut self.ws;
                    let logits = ws.acache.out();
                    for (k, &idx) in mb.iter().enumerate() {
                        let lrow = &logits[k * na..(k + 1) * na];
                        let a = ro.actions[idx] as usize;
                        let adv = ro.advantages[idx];
                        let old_lp = ro.logp[idx];
                        log_softmax(lrow, &mut ws.lp[..na]);
                        softmax(lrow, &mut ws.probs[..na]);
                        let ratio = (ws.lp[a] - old_lp).exp();
                        let clipped =
                            ratio.clamp(1.0 - self.cfg.clip_eps, 1.0 + self.cfg.clip_eps);
                        let unclipped_obj = ratio * adv;
                        let clipped_obj = clipped * adv;
                        // d(-min)/dlogp = -adv*ratio where the unclipped
                        // branch is active, 0 otherwise.
                        let pg_coef =
                            if unclipped_obj <= clipped_obj { -adv * ratio } else { 0.0 };
                        let entropy: f32 = -ws.probs[..na]
                            .iter()
                            .zip(&ws.lp[..na])
                            .map(|(&p, &l)| p * l)
                            .sum::<f32>();
                        for j in 0..na {
                            let ind = if j == a { 1.0 } else { 0.0 };
                            let dlogp_a = ind - ws.probs[j];
                            let dentropy = -ws.probs[j] * (ws.lp[j] + entropy);
                            ws.mb_dlogits[k * na + j] =
                                scale * (pg_coef * dlogp_a - self.cfg.ent_coef * dentropy);
                        }
                        metrics.pg_loss += -unclipped_obj.min(clipped_obj);
                        metrics.entropy += entropy;
                        count += 1.0;
                    }
                }
                self.actor.backward_batch(
                    &mut self.ws.acache,
                    &self.ws.mb_dlogits[..m * na],
                    &mut self.ws.a_grads,
                );

                // Critic: batched forward, per-row value error, one batched
                // backward over the `[m × 1]` output gradient.
                self.critic.forward_batch(&self.ws.mb_x[..m * d], m, &mut self.ws.ccache);
                {
                    let ws = &mut self.ws;
                    let values = ws.ccache.out();
                    for (k, &idx) in mb.iter().enumerate() {
                        let verr = values[k] - ro.targets[idx];
                        ws.mb_dv[k] = scale * self.cfg.vf_coef * verr;
                        metrics.v_loss += 0.5 * verr * verr;
                    }
                }
                self.critic.backward_batch(
                    &mut self.ws.ccache,
                    &self.ws.mb_dv[..m],
                    &mut self.ws.c_grads,
                );

                clip_global_norm(&mut self.ws.a_grads[..alen], self.cfg.max_grad_norm);
                clip_global_norm(&mut self.ws.c_grads[..clen], self.cfg.max_grad_norm);
                self.actor_opt.step(&mut self.actor.params, &self.ws.a_grads[..alen]);
                self.critic_opt.step(&mut self.critic.params, &self.ws.c_grads[..clen]);
            }
        }
        metrics.pg_loss /= count;
        metrics.v_loss /= count;
        metrics.entropy /= count;
        metrics
    }

    /// The original per-sample update, kept as the parity oracle (with the
    /// scratch vectors hoisted out of the inner loop — the old code
    /// reallocated `lp`/`probs`/`dlogits` for every sample).
    pub fn update_serial(&mut self, ro: &Rollout) -> PpoMetrics {
        let n = ro.actions.len();
        let mb_size = (n / self.cfg.minibatches).max(1);
        let mut order: Vec<usize> = (0..n).collect();
        let mut metrics = PpoMetrics::default();
        let mut count = 0.0f32;

        let mut a_grads = vec![0.0f32; self.actor.params.len()];
        let mut c_grads = vec![0.0f32; self.critic.params.len()];
        let mut cache = crate::nn::mlp::Cache::default();
        let mut vcache = crate::nn::mlp::Cache::default();
        let mut lp = vec![0.0f32; self.n_actions];
        let mut probs = vec![0.0f32; self.n_actions];
        let mut dlogits = vec![0.0f32; self.n_actions];

        for _ in 0..self.cfg.epochs {
            self.rng.shuffle(&mut order);
            for mb in order.chunks(mb_size) {
                a_grads.fill(0.0);
                c_grads.fill(0.0);
                let scale = 1.0 / mb.len() as f32;
                for &idx in mb {
                    let x = &ro.obs[idx * self.obs_dim..(idx + 1) * self.obs_dim];
                    let a = ro.actions[idx] as usize;
                    let adv = ro.advantages[idx];
                    let old_lp = ro.logp[idx];

                    // actor
                    let logits = self.actor.forward(x, &mut cache);
                    log_softmax(&logits, &mut lp);
                    softmax(&logits, &mut probs);
                    let ratio = (lp[a] - old_lp).exp();
                    let clipped =
                        ratio.clamp(1.0 - self.cfg.clip_eps, 1.0 + self.cfg.clip_eps);
                    let unclipped_obj = ratio * adv;
                    let clipped_obj = clipped * adv;
                    let pg_coef =
                        if unclipped_obj <= clipped_obj { -adv * ratio } else { 0.0 };
                    let entropy: f32 =
                        -probs.iter().zip(&lp).map(|(&p, &l)| p * l).sum::<f32>();
                    for j in 0..self.n_actions {
                        let ind = if j == a { 1.0 } else { 0.0 };
                        let dlogp_a = ind - probs[j];
                        let dentropy = -probs[j] * (lp[j] + entropy);
                        dlogits[j] =
                            scale * (pg_coef * dlogp_a - self.cfg.ent_coef * dentropy);
                    }
                    self.actor.backward(&cache, &dlogits, &mut a_grads);

                    // critic
                    let v = self.critic.forward(x, &mut vcache)[0];
                    let verr = v - ro.targets[idx];
                    self.critic.backward(
                        &vcache,
                        &[scale * self.cfg.vf_coef * verr],
                        &mut c_grads,
                    );

                    metrics.pg_loss += -unclipped_obj.min(clipped_obj);
                    metrics.v_loss += 0.5 * verr * verr;
                    metrics.entropy += entropy;
                    count += 1.0;
                }
                clip_global_norm(&mut a_grads, self.cfg.max_grad_norm);
                clip_global_norm(&mut c_grads, self.cfg.max_grad_norm);
                self.actor_opt.step(&mut self.actor.params, &a_grads);
                self.critic_opt.step(&mut self.critic.params, &c_grads);
            }
        }
        metrics.pg_loss /= count;
        metrics.v_loss /= count;
        metrics.entropy /= count;
        metrics
    }

    /// Full training loop: `total_steps` agent-steps on `env` (every
    /// agent-row of a multi-agent engine counts — the policy batch is
    /// `B·A` rows per env step).
    pub fn train<E: BatchStepper + ?Sized>(&mut self, env: &mut E, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let steps_per_iter = (self.cfg.rollout_len * env.policy_rows()) as u64;
        let iters = total_steps.div_ceil(steps_per_iter);
        let mut ro = Rollout::new(self.cfg.rollout_len, env.policy_rows(), self.obs_dim);
        for it in 0..iters {
            self.collect_rollout(env, &mut ro, &mut tracker);
            let m = self.update(&ro);
            log.curve.push(CurvePoint {
                env_steps: (it + 1) * steps_per_iter,
                mean_return: tracker.mean(),
                loss: m.pg_loss + m.v_loss,
            });
        }
        log.episodes = tracker.episodes;
        log
    }

    /// [`Ppo::train`] over the double-buffered pipeline: environment
    /// stepping overlaps the critic/bookkeeping half of inference. Same
    /// training curve as the serial path for a fixed seed.
    pub fn train_pipelined(&mut self, env: &mut PipelinedEnv, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let steps_per_iter = (self.cfg.rollout_len * env.policy_rows()) as u64;
        let iters = total_steps.div_ceil(steps_per_iter);
        let mut ro = Rollout::new(self.cfg.rollout_len, env.policy_rows(), self.obs_dim);
        for it in 0..iters {
            self.collect_rollout_pipelined(env, &mut ro, &mut tracker);
            let m = self.update(&ro);
            log.curve.push(CurvePoint {
                env_steps: (it + 1) * steps_per_iter,
                mean_return: tracker.mean(),
                loss: m.pg_loss + m.v_loss,
            });
        }
        log.episodes = tracker.episodes;
        log
    }

    /// Capture the agent's full training state (weights, optimizer
    /// moments, RNG stream). Workspaces are scratch and excluded — they
    /// are rewritten before they are read.
    pub fn save_state(&self) -> PpoCheckpoint {
        PpoCheckpoint {
            actor: self.actor.clone(),
            critic: self.critic.clone(),
            actor_opt: self.actor_opt.clone(),
            critic_opt: self.critic_opt.clone(),
            rng: self.rng.clone(),
        }
    }

    /// Restore a state captured by [`Ppo::save_state`]; subsequent
    /// rollouts and updates replay bit-identically.
    pub fn restore_state(&mut self, ck: &PpoCheckpoint) {
        self.actor = ck.actor.clone();
        self.critic = ck.critic.clone();
        self.actor_opt = ck.actor_opt.clone();
        self.critic_opt = ck.critic_opt.clone();
        self.rng = ck.rng.clone();
    }

    /// Greedy action for env `i` of an observation batch (evaluation).
    pub fn act_greedy(&self, obs: &crate::batch::ObsBatch, b: usize, i: usize) -> Action {
        let mut x = vec![0.0f32; self.obs_dim];
        preprocess_env_obs(obs, b, i, &mut x);
        Action::from_u8(crate::nn::argmax(&self.actor.infer(&x)) as u8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::BatchedEnv;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn rollout_fills_all_fields() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 4, Key::new(0));
        let d = crate::agents::OBS_DIM;
        let mut ppo = Ppo::new(PpoConfig { rollout_len: 8, ..Default::default() }, d, 7, 0);
        let mut ro = Rollout::new(8, 4, d);
        let mut tracker = ReturnTracker::new(8);
        ppo.collect_rollout(&mut env, &mut ro, &mut tracker);
        assert!(ro.logp.iter().all(|&l| l <= 0.0), "log-probs must be ≤ 0");
        assert!(ro.values.iter().any(|&v| v != 0.0), "critic should output something");
    }

    #[test]
    fn update_changes_parameters_and_reports_entropy() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 4, Key::new(0));
        let mut ppo = Ppo::new(
            PpoConfig { rollout_len: 16, minibatches: 2, epochs: 2, ..Default::default() },
            crate::agents::OBS_DIM,
            7,
            0,
        );
        let mut ro = Rollout::new(16, 4, crate::agents::OBS_DIM);
        let mut tracker = ReturnTracker::new(8);
        ppo.collect_rollout(&mut env, &mut ro, &mut tracker);
        let before = ppo.actor.params.clone();
        let m = ppo.update(&ro);
        assert_ne!(before, ppo.actor.params);
        // fresh policy over 7 actions: entropy near ln(7) ≈ 1.95
        assert!(m.entropy > 1.0 && m.entropy < 2.0, "entropy {}", m.entropy);
    }

    #[test]
    fn batched_rollout_and_update_match_the_serial_oracle() {
        // The unit-level pin (the integration sweep across env families
        // lives in tests/test_train_parity.rs): same seed → the batched
        // path reproduces the per-sample path exactly.
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let pcfg =
            PpoConfig { rollout_len: 12, minibatches: 3, epochs: 2, ..Default::default() };
        let mut env_a = BatchedEnv::new(cfg.clone(), 4, Key::new(5));
        let mut env_b = BatchedEnv::new(cfg, 4, Key::new(5));
        let d = crate::agents::OBS_DIM;
        let mut ppo_a = Ppo::new(pcfg.clone(), d, 7, 9);
        let mut ppo_b = Ppo::new(pcfg, d, 7, 9);
        let mut ro_a = Rollout::new(12, 4, d);
        let mut ro_b = Rollout::new(12, 4, d);
        let mut tr_a = ReturnTracker::new(8);
        let mut tr_b = ReturnTracker::new(8);
        for _ in 0..2 {
            ppo_a.collect_rollout_serial(&mut env_a, &mut ro_a, &mut tr_a);
            ppo_b.collect_rollout(&mut env_b, &mut ro_b, &mut tr_b);
            assert_eq!(ro_a.obs, ro_b.obs);
            assert_eq!(ro_a.actions, ro_b.actions);
            assert_eq!(ro_a.logp, ro_b.logp);
            assert_eq!(ro_a.values, ro_b.values);
            assert_eq!(ro_a.advantages, ro_b.advantages);
            let m_a = ppo_a.update_serial(&ro_a);
            let m_b = ppo_b.update(&ro_b);
            assert_eq!(m_a, m_b);
            assert_eq!(ppo_a.actor.params, ppo_b.actor.params);
            assert_eq!(ppo_a.critic.params, ppo_b.critic.params);
        }
    }

    #[test]
    fn fused_rollout_matches_the_stepwise_oracle() {
        // Scan-mode pin: one `step_n` call per horizon (the fused
        // `collect_rollout`) reproduces the per-step batched loop exactly —
        // every rollout tensor, the tracker stream, and the updated params.
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let pcfg =
            PpoConfig { rollout_len: 10, minibatches: 2, epochs: 2, ..Default::default() };
        let mut env_a = BatchedEnv::new(cfg.clone(), 5, Key::new(7));
        let mut env_b = BatchedEnv::new(cfg, 5, Key::new(7));
        let d = crate::agents::OBS_DIM;
        let mut ppo_a = Ppo::new(pcfg.clone(), d, 7, 3);
        let mut ppo_b = Ppo::new(pcfg, d, 7, 3);
        let mut ro_a = Rollout::new(10, 5, d);
        let mut ro_b = Rollout::new(10, 5, d);
        let mut tr_a = ReturnTracker::new(8);
        let mut tr_b = ReturnTracker::new(8);
        for _ in 0..3 {
            ppo_a.collect_rollout_stepwise(&mut env_a, &mut ro_a, &mut tr_a);
            ppo_b.collect_rollout(&mut env_b, &mut ro_b, &mut tr_b);
            assert_eq!(ro_a.obs, ro_b.obs);
            assert_eq!(ro_a.actions, ro_b.actions);
            assert_eq!(ro_a.logp, ro_b.logp);
            assert_eq!(ro_a.values, ro_b.values);
            assert_eq!(ro_a.rewards, ro_b.rewards);
            assert_eq!(ro_a.discounts, ro_b.discounts);
            assert_eq!(ro_a.boundaries, ro_b.boundaries);
            assert_eq!(ro_a.advantages, ro_b.advantages);
            assert_eq!(ro_a.targets, ro_b.targets);
            assert_eq!(tr_a.mean(), tr_b.mean());
            let m_a = ppo_a.update(&ro_a);
            let m_b = ppo_b.update(&ro_b);
            assert_eq!(m_a, m_b);
            assert_eq!(ppo_a.actor.params, ppo_b.actor.params);
            assert_eq!(ppo_a.critic.params, ppo_b.critic.params);
        }
    }

    #[test]
    fn ppo_improves_on_empty_5x5_smoke() {
        // Short-budget smoke: after ~40k steps on Empty-5x5 (dense-enough
        // task) mean return should clearly beat the random-policy baseline.
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(1));
        let mut ppo = Ppo::new(
            PpoConfig { num_envs: 8, rollout_len: 64, lr: 1e-3, ..Default::default() },
            crate::agents::OBS_DIM,
            7,
            1,
        );
        let log = ppo.train(&mut env, 40_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.5,
            "PPO failed to learn Empty-5x5: final mean return {final_ret} over {} episodes",
            log.episodes
        );
    }
}
