//! Discrete Soft Actor-Critic (Haarnoja et al., 2018; discrete variant à la
//! Christodoulou, 2019) — paper §4.3 baseline.
//!
//! Twin Q-networks with Polyak-averaged targets, a categorical actor, and
//! automatic temperature tuning towards a target entropy expressed as a
//! ratio of the uniform-policy entropy (the Table-9 "target entropy ratio").
//! Uses the same 128-steps/128-updates cadence as DQN.
//!
//! Since PR 4 acting is one `[B, obs_dim]` actor forward per env step
//! (sampling draws stay in env order, so trajectories are bit-identical to
//! the per-sample path) and the update runs its six network passes as
//! batched forwards/backwards over reusable workspaces — the outputs each
//! later stage needs (`next_logits`, `q1s`, `q2s`) are copied out of the
//! shared cache between passes.

use crate::agents::{ensure, preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::agents::replay::Replay;
use crate::batch::BatchedEnv;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::mlp::BatchCache;
use crate::nn::{log_softmax, softmax, Activation, Mlp};
use crate::rng::Rng;

/// SAC hyperparameters (Table 9 "fitted" knobs).
#[derive(Clone, Debug)]
pub struct SacConfig {
    pub batch_size: usize,
    pub buffer_capacity: usize,
    pub learning_starts: usize,
    pub gamma: f32,
    pub lr: f32,
    /// Polyak coefficient for target critics.
    pub tau: f32,
    /// Target entropy = ratio × ln(num_actions).
    pub target_entropy_ratio: f32,
    pub parallel_steps: usize,
    pub activation: Activation,
}

impl Default for SacConfig {
    fn default() -> Self {
        SacConfig {
            batch_size: 128,
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            gamma: 0.99,
            lr: 3e-4,
            tau: 0.005,
            // Keep the temperature low: with sparse terminal rewards the
            // discounted entropy-bonus stream α·H/(1−γ) competes with the
            // +1 goal reward, and a high target entropy teaches the agent
            // to *avoid* terminating. 0.2·ln(7) ≈ 0.39 nats keeps
            // α·H/(1−γ) ≪ 1 at equilibrium.
            target_entropy_ratio: 0.2,
            parallel_steps: 128,
            activation: Activation::Relu,
        }
    }
}

/// Reusable batched-update/acting workspaces (grown on first use).
#[derive(Default)]
struct Workspace {
    /// `[B × obs_dim]` acting features.
    act_x: Vec<f32>,
    /// `[na]` softmax/log-softmax row scratch.
    p: Vec<f32>,
    lp: Vec<f32>,
    /// `[MB × na]` copies of batched outputs needed across passes.
    next_logits: Vec<f32>,
    nq1: Vec<f32>,
    q1s: Vec<f32>,
    q2s: Vec<f32>,
    /// `[MB]` TD targets and per-sample critic errors.
    y: Vec<f32>,
    e1: Vec<f32>,
    e2: Vec<f32>,
    /// `[MB × na]` output gradients.
    dq: Vec<f32>,
    dlogits: Vec<f32>,
    q1_grads: Vec<f32>,
    q2_grads: Vec<f32>,
    a_grads: Vec<f32>,
    cache: BatchCache,
}

/// Discrete SAC agent.
/// Everything [`Sac`] needs to resume training bit-identically: all five
/// networks, three optimizers, the learned temperature, the replay buffer
/// and the RNG/step counters.
#[derive(Clone)]
pub struct SacCheckpoint {
    pub actor: Mlp,
    pub q1: Mlp,
    pub q2: Mlp,
    pub q1_target: Mlp,
    pub q2_target: Mlp,
    pub actor_opt: Adam,
    pub q1_opt: Adam,
    pub q2_opt: Adam,
    pub log_alpha: f32,
    pub replay: Replay,
    pub rng: Rng,
    pub env_steps: u64,
}

pub struct Sac {
    pub cfg: SacConfig,
    pub actor: Mlp,
    pub q1: Mlp,
    pub q2: Mlp,
    q1_target: Mlp,
    q2_target: Mlp,
    actor_opt: Adam,
    q1_opt: Adam,
    q2_opt: Adam,
    pub log_alpha: f32,
    alpha_lr: f32,
    target_entropy: f32,
    replay: Replay,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
    env_steps: u64,
    ws: Workspace,
}

impl Sac {
    pub fn new(cfg: SacConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Sac {
        let mut rng = Rng::new(seed);
        let actor = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q1 = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q2 = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let (q1_target, q2_target) = (q1.clone(), q2.clone());
        let actor_opt = Adam::new(actor.params.len(), cfg.lr);
        let q1_opt = Adam::new(q1.params.len(), cfg.lr);
        let q2_opt = Adam::new(q2.params.len(), cfg.lr);
        let replay = Replay::new(cfg.buffer_capacity, obs_dim);
        let target_entropy = cfg.target_entropy_ratio * (n_actions as f32).ln();
        Sac {
            cfg,
            actor,
            q1,
            q2,
            q1_target,
            q2_target,
            actor_opt,
            q1_opt,
            q2_opt,
            // Start with a small temperature: MiniGrid rewards are sparse
            // ±1, so an α near 1 drowns the Q-signal in entropy bonus and
            // the policy never leaves uniform (the classic discrete-SAC
            // failure mode on gridworlds).
            log_alpha: 0.1_f32.ln(),
            alpha_lr: 1e-3,
            target_entropy,
            replay,
            obs_dim,
            n_actions,
            rng,
            env_steps: 0,
            ws: Workspace::default(),
        }
    }

    pub fn alpha(&self) -> f32 {
        self.log_alpha.exp()
    }

    /// Capture the agent's full training state. Pair with an engine
    /// [`crate::core::snapshot::EngineCheckpoint`] to checkpoint a run.
    pub fn save_state(&self) -> SacCheckpoint {
        SacCheckpoint {
            actor: self.actor.clone(),
            q1: self.q1.clone(),
            q2: self.q2.clone(),
            q1_target: self.q1_target.clone(),
            q2_target: self.q2_target.clone(),
            actor_opt: self.actor_opt.clone(),
            q1_opt: self.q1_opt.clone(),
            q2_opt: self.q2_opt.clone(),
            log_alpha: self.log_alpha,
            replay: self.replay.clone(),
            rng: self.rng.clone(),
            env_steps: self.env_steps,
        }
    }

    /// Restore a state captured by [`Sac::save_state`]; subsequent
    /// training replays bit-identically.
    pub fn restore_state(&mut self, ck: &SacCheckpoint) {
        self.actor = ck.actor.clone();
        self.q1 = ck.q1.clone();
        self.q2 = ck.q2.clone();
        self.q1_target = ck.q1_target.clone();
        self.q2_target = ck.q2_target.clone();
        self.actor_opt = ck.actor_opt.clone();
        self.q1_opt = ck.q1_opt.clone();
        self.q2_opt = ck.q2_opt.clone();
        self.log_alpha = ck.log_alpha;
        self.replay = ck.replay.clone();
        self.rng = ck.rng.clone();
        self.env_steps = ck.env_steps;
    }

    /// Sample actions for the whole batch from one batched actor forward.
    /// Sampling draws stay in env order — the per-sample path's exact RNG
    /// sequence.
    fn act_sample_batch(&mut self, prev_obs: &[Vec<i32>], actions: &mut [u8]) {
        let (b, d, na) = (prev_obs.len(), self.obs_dim, self.n_actions);
        ensure(&mut self.ws.act_x, b * d);
        ensure(&mut self.ws.p, na);
        {
            let ws = &mut self.ws;
            for (i, o) in prev_obs.iter().enumerate() {
                preprocess_obs(o, &mut ws.act_x[i * d..(i + 1) * d]);
            }
        }
        self.actor.forward_batch(&self.ws.act_x[..b * d], b, &mut self.ws.cache);
        let ws = &mut self.ws;
        let logits = ws.cache.out();
        for i in 0..b {
            softmax(&logits[i * na..(i + 1) * na], &mut ws.p[..na]);
            actions[i] = self.rng.categorical(&ws.p[..na]) as u8;
        }
    }

    /// One SAC update (both critics, actor, temperature), as six batched
    /// network passes over reusable workspaces — bit-identical to the
    /// original per-sample loop. Returns critic loss.
    pub fn update(&mut self) -> f32 {
        if self.replay.len() < self.cfg.batch_size.max(self.cfg.learning_starts) {
            return 0.0;
        }
        let batch = self.replay.sample(self.cfg.batch_size, &mut self.rng);
        let (na, mbs) = (self.n_actions, self.cfg.batch_size);
        let alpha = self.alpha();
        let scale = 1.0 / mbs as f32;
        let (q1len, q2len, alen) =
            (self.q1.params.len(), self.q2.params.len(), self.actor.params.len());
        {
            let ws = &mut self.ws;
            let row_bufs = [
                &mut ws.next_logits,
                &mut ws.nq1,
                &mut ws.q1s,
                &mut ws.q2s,
                &mut ws.dq,
                &mut ws.dlogits,
            ];
            for buf in row_bufs {
                ensure(buf, mbs * na);
            }
            for buf in [&mut ws.y, &mut ws.e1, &mut ws.e2] {
                ensure(buf, mbs);
            }
            ensure(&mut ws.p, na);
            ensure(&mut ws.lp, na);
            ensure(&mut ws.q1_grads, q1len);
            ensure(&mut ws.q2_grads, q2len);
            ensure(&mut ws.a_grads, alen);
            ws.q1_grads[..q1len].fill(0.0);
            ws.q2_grads[..q2len].fill(0.0);
            ws.a_grads[..alen].fill(0.0);
        }

        // --- critic target: expected (twin-min) value of s' under π.
        //
        // Deliberate deviation from the textbook soft backup: the
        // −α·logπ entropy term is kept in the ACTOR objective only.
        // With sparse terminal rewards, a soft value backup pays an
        // entropy annuity α·H/(1−γ) for *not terminating*, so any
        // non-vanishing temperature teaches the agent to avoid the
        // goal (we observed exactly this collapse). Dropping the term
        // from the backup bounds Q by the true return while the actor
        // stays entropy-regularised — the variant common in discrete-
        // SAC implementations on episodic tasks.
        self.actor.forward_batch(&batch.next_obs, mbs, &mut self.ws.cache);
        self.ws.next_logits[..mbs * na].copy_from_slice(&self.ws.cache.out()[..mbs * na]);
        self.q1_target.forward_batch(&batch.next_obs, mbs, &mut self.ws.cache);
        self.ws.nq1[..mbs * na].copy_from_slice(&self.ws.cache.out()[..mbs * na]);
        self.q2_target.forward_batch(&batch.next_obs, mbs, &mut self.ws.cache);
        {
            let ws = &mut self.ws;
            let nq2 = ws.cache.out();
            for k in 0..mbs {
                softmax(&ws.next_logits[k * na..(k + 1) * na], &mut ws.p[..na]);
                let mut v_next = 0.0f32;
                for j in 0..na {
                    v_next += ws.p[j] * ws.nq1[k * na + j].min(nq2[k * na + j]);
                }
                ws.y[k] = batch.rewards[k] + self.cfg.gamma * batch.nonterminal[k] * v_next;
            }
        }

        // --- critic updates (MSE on the taken action).
        self.q1.forward_batch(&batch.obs, mbs, &mut self.ws.cache);
        {
            let ws = &mut self.ws;
            ws.q1s[..mbs * na].copy_from_slice(&ws.cache.out()[..mbs * na]);
            ws.dq[..mbs * na].fill(0.0);
            for k in 0..mbs {
                let a = batch.actions[k] as usize;
                let e = ws.q1s[k * na + a] - ws.y[k];
                ws.e1[k] = e;
                ws.dq[k * na + a] = scale * e;
            }
        }
        self.q1.backward_batch(
            &mut self.ws.cache,
            &self.ws.dq[..mbs * na],
            &mut self.ws.q1_grads,
        );
        self.q2.forward_batch(&batch.obs, mbs, &mut self.ws.cache);
        {
            let ws = &mut self.ws;
            ws.q2s[..mbs * na].copy_from_slice(&ws.cache.out()[..mbs * na]);
            ws.dq[..mbs * na].fill(0.0);
            for k in 0..mbs {
                let a = batch.actions[k] as usize;
                let e = ws.q2s[k * na + a] - ws.y[k];
                ws.e2[k] = e;
                ws.dq[k * na + a] = scale * e;
            }
        }
        self.q2.backward_batch(
            &mut self.ws.cache,
            &self.ws.dq[..mbs * na],
            &mut self.ws.q2_grads,
        );
        // Per-sample, e1²+e2² paired like the serial loop (same sum order).
        let mut critic_loss = 0.0f32;
        for k in 0..mbs {
            let (e1, e2) = (self.ws.e1[k], self.ws.e2[k]);
            critic_loss += 0.5 * (e1 * e1 + e2 * e2);
        }

        // --- actor: minimise E_a[α log π − min Q] (Q detached).
        self.actor.forward_batch(&batch.obs, mbs, &mut self.ws.cache);
        let mut entropy_sum = 0.0f32;
        {
            let ws = &mut self.ws;
            let logits = ws.cache.out();
            for k in 0..mbs {
                let lrow = &logits[k * na..(k + 1) * na];
                softmax(lrow, &mut ws.p[..na]);
                log_softmax(lrow, &mut ws.lp[..na]);
                let mut expected = 0.0f32;
                for j in 0..na {
                    let inner = alpha * ws.lp[j] - ws.q1s[k * na + j].min(ws.q2s[k * na + j]);
                    expected += ws.p[j] * inner;
                }
                // dL/dlogit_j = p_j [ (inner_j + α) − Σ p (inner + α) ]
                //             = p_j [ inner_j − expected ]  (+α cancels)
                for j in 0..na {
                    let inner = alpha * ws.lp[j] - ws.q1s[k * na + j].min(ws.q2s[k * na + j]);
                    ws.dlogits[k * na + j] = scale * ws.p[j] * (inner - expected);
                }
                entropy_sum += -(0..na).map(|j| ws.p[j] * ws.lp[j]).sum::<f32>();
            }
        }
        self.actor.backward_batch(
            &mut self.ws.cache,
            &self.ws.dlogits[..mbs * na],
            &mut self.ws.a_grads,
        );

        clip_global_norm(&mut self.ws.q1_grads[..q1len], 10.0);
        clip_global_norm(&mut self.ws.q2_grads[..q2len], 10.0);
        clip_global_norm(&mut self.ws.a_grads[..alen], 10.0);
        self.q1_opt.step(&mut self.q1.params, &self.ws.q1_grads[..q1len]);
        self.q2_opt.step(&mut self.q2.params, &self.ws.q2_grads[..q2len]);
        self.actor_opt.step(&mut self.actor.params, &self.ws.a_grads[..alen]);

        // --- temperature: push entropy toward the target.
        let mean_entropy = entropy_sum * scale;
        self.log_alpha -= self.alpha_lr * (mean_entropy - self.target_entropy);
        // α ∈ [1e-4, 1]: an unbounded temperature lets the entropy stream
        // dominate sparse terminal rewards (see SacConfig docs).
        self.log_alpha = self.log_alpha.clamp(-9.2, 0.0);

        // --- Polyak target update.
        self.q1_target.soft_update_from(&self.q1, self.cfg.tau);
        self.q2_target.soft_update_from(&self.q2, self.cfg.tau);

        critic_loss * scale
    }

    /// Train for `total_steps` env steps.
    pub fn train(&mut self, env: &mut BatchedEnv, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        // One policy row per agent-row: multi-agent engines expose B·A
        // rows, and every row is an independent replay transition.
        let b = env.policy_rows();
        let mut actions = vec![0u8; b];
        // Policy rows are grid + mission: the replay buffer stores the full
        // goal-conditioned input, so off-policy updates see the goal too.
        let d = env.obs.stride(b) + crate::agents::MISSION_TOKENS;
        debug_assert_eq!(d, self.obs_dim, "agent obs_dim must be grid + mission");
        let mut next_row = vec![0i32; d];
        let mut prev_obs: Vec<Vec<i32>> = (0..b)
            .map(|i| {
                let mut row = vec![0i32; d];
                env.obs.copy_policy_row(b, i, &mut row);
                row
            })
            .collect();
        while self.env_steps < total_steps {
            let mut chunk_loss = 0.0;
            for _ in 0..self.cfg.parallel_steps {
                self.act_sample_batch(&prev_obs, &mut actions);
                env.step(&actions);
                for i in 0..b {
                    env.obs.copy_policy_row(b, i, &mut next_row);
                    if env.timestep.step_type[i] == crate::core::timestep::StepType::First {
                        prev_obs[i].copy_from_slice(&next_row);
                        continue;
                    }
                    let terminated = env.timestep.discount[i] == 0.0;
                    self.replay.push(
                        &prev_obs[i],
                        actions[i],
                        env.timestep.reward[i],
                        &next_row,
                        terminated,
                    );
                    if env.timestep.step_type[i].is_last() {
                        tracker.push(env.timestep.episodic_return[i]);
                    }
                    prev_obs[i].copy_from_slice(&next_row);
                }
                self.env_steps += b as u64;
            }
            for _ in 0..self.cfg.parallel_steps {
                chunk_loss += self.update();
            }
            log.curve.push(CurvePoint {
                env_steps: self.env_steps,
                mean_return: tracker.mean(),
                loss: chunk_loss / self.cfg.parallel_steps as f32,
            });
        }
        log.episodes = tracker.episodes;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn target_entropy_scales_with_actions() {
        let s = Sac::new(SacConfig { target_entropy_ratio: 0.5, ..Default::default() }, 4, 7, 0);
        assert!((s.target_entropy - 0.5 * (7.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn update_noop_before_learning_starts() {
        let mut s = Sac::new(SacConfig::default(), 4, 3, 0);
        assert_eq!(s.update(), 0.0);
    }

    #[test]
    fn sac_learns_empty_5x5_smoke() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(3));
        let cfg = SacConfig {
            learning_starts: 500,
            buffer_capacity: 20_000,
            lr: 1e-3,
            parallel_steps: 64,
            target_entropy_ratio: 0.1,
            ..Default::default()
        };
        let mut sac = Sac::new(cfg, crate::agents::OBS_DIM, 7, 3);
        let log = sac.train(&mut env, 60_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.3,
            "SAC failed to learn Empty-5x5: final return {final_ret} ({} eps)",
            log.episodes
        );
    }
}
