//! Discrete Soft Actor-Critic (Haarnoja et al., 2018; discrete variant à la
//! Christodoulou, 2019) — paper §4.3 baseline.
//!
//! Twin Q-networks with Polyak-averaged targets, a categorical actor, and
//! automatic temperature tuning towards a target entropy expressed as a
//! ratio of the uniform-policy entropy (the Table-9 "target entropy ratio").
//! Uses the same 128-steps/128-updates cadence as DQN.

use crate::agents::{preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::agents::replay::Replay;
use crate::batch::BatchedEnv;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::{log_softmax, sample_categorical, softmax, Activation, Mlp};
use crate::rng::Rng;

/// SAC hyperparameters (Table 9 "fitted" knobs).
#[derive(Clone, Debug)]
pub struct SacConfig {
    pub batch_size: usize,
    pub buffer_capacity: usize,
    pub learning_starts: usize,
    pub gamma: f32,
    pub lr: f32,
    /// Polyak coefficient for target critics.
    pub tau: f32,
    /// Target entropy = ratio × ln(num_actions).
    pub target_entropy_ratio: f32,
    pub parallel_steps: usize,
    pub activation: Activation,
}

impl Default for SacConfig {
    fn default() -> Self {
        SacConfig {
            batch_size: 128,
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            gamma: 0.99,
            lr: 3e-4,
            tau: 0.005,
            // Keep the temperature low: with sparse terminal rewards the
            // discounted entropy-bonus stream α·H/(1−γ) competes with the
            // +1 goal reward, and a high target entropy teaches the agent
            // to *avoid* terminating. 0.2·ln(7) ≈ 0.39 nats keeps
            // α·H/(1−γ) ≪ 1 at equilibrium.
            target_entropy_ratio: 0.2,
            parallel_steps: 128,
            activation: Activation::Relu,
        }
    }
}

/// Discrete SAC agent.
pub struct Sac {
    pub cfg: SacConfig,
    pub actor: Mlp,
    pub q1: Mlp,
    pub q2: Mlp,
    q1_target: Mlp,
    q2_target: Mlp,
    actor_opt: Adam,
    q1_opt: Adam,
    q2_opt: Adam,
    pub log_alpha: f32,
    alpha_lr: f32,
    target_entropy: f32,
    replay: Replay,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
    env_steps: u64,
}

impl Sac {
    pub fn new(cfg: SacConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Sac {
        let mut rng = Rng::new(seed);
        let actor = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q1 = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q2 = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let (q1_target, q2_target) = (q1.clone(), q2.clone());
        let actor_opt = Adam::new(actor.params.len(), cfg.lr);
        let q1_opt = Adam::new(q1.params.len(), cfg.lr);
        let q2_opt = Adam::new(q2.params.len(), cfg.lr);
        let replay = Replay::new(cfg.buffer_capacity, obs_dim);
        let target_entropy = cfg.target_entropy_ratio * (n_actions as f32).ln();
        Sac {
            cfg,
            actor,
            q1,
            q2,
            q1_target,
            q2_target,
            actor_opt,
            q1_opt,
            q2_opt,
            // Start with a small temperature: MiniGrid rewards are sparse
            // ±1, so an α near 1 drowns the Q-signal in entropy bonus and
            // the policy never leaves uniform (the classic discrete-SAC
            // failure mode on gridworlds).
            log_alpha: 0.1_f32.ln(),
            alpha_lr: 1e-3,
            target_entropy,
            replay,
            obs_dim,
            n_actions,
            rng,
            env_steps: 0,
        }
    }

    pub fn alpha(&self) -> f32 {
        self.log_alpha.exp()
    }

    fn act_sample(&mut self, obs: &[i32]) -> u8 {
        let mut x = vec![0.0f32; self.obs_dim];
        preprocess_obs(obs, &mut x);
        let logits = self.actor.infer(&x);
        sample_categorical(&logits, &mut self.rng) as u8
    }

    /// One SAC update (both critics, actor, temperature). Returns critic
    /// loss.
    pub fn update(&mut self) -> f32 {
        if self.replay.len() < self.cfg.batch_size.max(self.cfg.learning_starts) {
            return 0.0;
        }
        let batch = self.replay.sample(self.cfg.batch_size, &mut self.rng);
        let d = self.obs_dim;
        let na = self.n_actions;
        let alpha = self.alpha();
        let scale = 1.0 / self.cfg.batch_size as f32;

        let mut q1_grads = vec![0.0f32; self.q1.params.len()];
        let mut q2_grads = vec![0.0f32; self.q2.params.len()];
        let mut a_grads = vec![0.0f32; self.actor.params.len()];
        let mut cache = crate::nn::mlp::Cache::default();
        let mut critic_loss = 0.0f32;
        let mut entropy_sum = 0.0f32;

        for k in 0..self.cfg.batch_size {
            let x = &batch.obs[k * d..(k + 1) * d];
            let nx = &batch.next_obs[k * d..(k + 1) * d];
            let a = batch.actions[k] as usize;

            // --- critic target: expected (twin-min) value of s' under π.
            //
            // Deliberate deviation from the textbook soft backup: the
            // −α·logπ entropy term is kept in the ACTOR objective only.
            // With sparse terminal rewards, a soft value backup pays an
            // entropy annuity α·H/(1−γ) for *not terminating*, so any
            // non-vanishing temperature teaches the agent to avoid the
            // goal (we observed exactly this collapse). Dropping the term
            // from the backup bounds Q by the true return while the actor
            // stays entropy-regularised — the variant common in discrete-
            // SAC implementations on episodic tasks.
            let next_logits = self.actor.infer(nx);
            let mut np = vec![0.0; na];
            softmax(&next_logits, &mut np);
            let nq1 = self.q1_target.infer(nx);
            let nq2 = self.q2_target.infer(nx);
            let v_next: f32 = (0..na).map(|j| np[j] * nq1[j].min(nq2[j])).sum();
            let y = batch.rewards[k] + self.cfg.gamma * batch.nonterminal[k] * v_next;

            // --- critic updates (MSE on the taken action).
            let q1s = self.q1.forward(x, &mut cache);
            let e1 = q1s[a] - y;
            let mut dq = vec![0.0f32; na];
            dq[a] = scale * e1;
            self.q1.backward(&cache, &dq, &mut q1_grads);

            let q2s = self.q2.forward(x, &mut cache);
            let e2 = q2s[a] - y;
            dq.fill(0.0);
            dq[a] = scale * e2;
            self.q2.backward(&cache, &dq, &mut q2_grads);
            critic_loss += 0.5 * (e1 * e1 + e2 * e2);

            // --- actor: minimise E_a[α log π − min Q] (Q detached).
            let logits = self.actor.forward(x, &mut cache);
            let mut p = vec![0.0; na];
            let mut lp = vec![0.0; na];
            softmax(&logits, &mut p);
            log_softmax(&logits, &mut lp);
            let minq: Vec<f32> = (0..na).map(|j| q1s[j].min(q2s[j])).collect();
            let inner: Vec<f32> = (0..na).map(|j| alpha * lp[j] - minq[j]).collect();
            let expected: f32 = (0..na).map(|j| p[j] * inner[j]).sum();
            // dL/dlogit_j = p_j [ (inner_j + α) − Σ p (inner + α) ]
            //             = p_j [ inner_j − expected ]  (+α cancels)
            let mut dlogits = vec![0.0f32; na];
            for j in 0..na {
                dlogits[j] = scale * p[j] * (inner[j] - expected);
            }
            self.actor.backward(&cache, &dlogits, &mut a_grads);
            entropy_sum += -(0..na).map(|j| p[j] * lp[j]).sum::<f32>();
        }

        clip_global_norm(&mut q1_grads, 10.0);
        clip_global_norm(&mut q2_grads, 10.0);
        clip_global_norm(&mut a_grads, 10.0);
        self.q1_opt.step(&mut self.q1.params, &q1_grads);
        self.q2_opt.step(&mut self.q2.params, &q2_grads);
        self.actor_opt.step(&mut self.actor.params, &a_grads);

        // --- temperature: push entropy toward the target.
        let mean_entropy = entropy_sum * scale;
        self.log_alpha -= self.alpha_lr * (mean_entropy - self.target_entropy);
        // α ∈ [1e-4, 1]: an unbounded temperature lets the entropy stream
        // dominate sparse terminal rewards (see SacConfig docs).
        self.log_alpha = self.log_alpha.clamp(-9.2, 0.0);

        // --- Polyak target update.
        self.q1_target.soft_update_from(&self.q1, self.cfg.tau);
        self.q2_target.soft_update_from(&self.q2, self.cfg.tau);

        critic_loss * scale
    }

    /// Train for `total_steps` env steps.
    pub fn train(&mut self, env: &mut BatchedEnv, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let b = env.b;
        let mut actions = vec![0u8; b];
        let mut prev_obs: Vec<Vec<i32>> =
            (0..b).map(|i| env.obs.env_i32(b, i).to_vec()).collect();
        while self.env_steps < total_steps {
            let mut chunk_loss = 0.0;
            for _ in 0..self.cfg.parallel_steps {
                for i in 0..b {
                    actions[i] = self.act_sample(&prev_obs[i]);
                }
                env.step(&actions);
                for i in 0..b {
                    let next = env.obs.env_i32(b, i);
                    if env.timestep.step_type[i] == crate::core::timestep::StepType::First {
                        prev_obs[i].copy_from_slice(next);
                        continue;
                    }
                    let terminated = env.timestep.discount[i] == 0.0;
                    self.replay.push(
                        &prev_obs[i],
                        actions[i],
                        env.timestep.reward[i],
                        next,
                        terminated,
                    );
                    if env.timestep.step_type[i].is_last() {
                        tracker.push(env.timestep.episodic_return[i]);
                    }
                    prev_obs[i].copy_from_slice(next);
                }
                self.env_steps += b as u64;
            }
            for _ in 0..self.cfg.parallel_steps {
                chunk_loss += self.update();
            }
            log.curve.push(CurvePoint {
                env_steps: self.env_steps,
                mean_return: tracker.mean(),
                loss: chunk_loss / self.cfg.parallel_steps as f32,
            });
        }
        log.episodes = tracker.episodes;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn target_entropy_scales_with_actions() {
        let s = Sac::new(SacConfig { target_entropy_ratio: 0.5, ..Default::default() }, 4, 7, 0);
        assert!((s.target_entropy - 0.5 * (7.0f32).ln()).abs() < 1e-6);
    }

    #[test]
    fn update_noop_before_learning_starts() {
        let mut s = Sac::new(SacConfig::default(), 4, 3, 0);
        assert_eq!(s.update(), 0.0);
    }

    #[test]
    fn sac_learns_empty_5x5_smoke() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(3));
        let cfg = SacConfig {
            learning_starts: 500,
            buffer_capacity: 20_000,
            lr: 1e-3,
            parallel_steps: 64,
            target_entropy_ratio: 0.1,
            ..Default::default()
        };
        let mut sac = Sac::new(cfg, 147, 7, 3);
        let log = sac.train(&mut env, 60_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.3,
            "SAC failed to learn Empty-5x5: final return {final_ret} ({} eps)",
            log.episodes
        );
    }
}
