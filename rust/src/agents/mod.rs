//! RL algorithm baselines (paper §4.3): PPO, Double DQN and discrete SAC,
//! matching the Rejax implementations the paper benchmarks — networks with
//! two hidden layers of 64 units, tuned hyperparameters (Table 9), and the
//! "128 parallel env steps + 128 updates" cadence for the off-policy
//! algorithms.
//!
//! All agents consume the batched engine's symbolic first-person
//! observations; [`preprocess_obs`] is the shared featuriser.

pub mod dqn;
pub mod gae;
pub mod ppo;
pub mod replay;
pub mod sac;
pub mod tuning;

pub use dqn::{Dqn, DqnConfig};
pub use ppo::{Ppo, PpoConfig};
pub use sac::{Sac, SacConfig};

/// Flattened, normalised observation size for a symbolic first-person view.
pub const OBS_DIM: usize = 7 * 7 * 3;

/// Normalise a symbolic i32 observation into `[0, 1]`-ish floats
/// (tag ≤ 10, colour ≤ 5, state ≤ 3 → divide by 10). Elementwise, so it
/// works on a single `[obs_dim]` row or a whole `[B × obs_dim]` block.
pub fn preprocess_obs(obs: &[i32], out: &mut [f32]) {
    debug_assert_eq!(obs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(obs) {
        *o = x as f32 / 10.0;
    }
}

/// Featurise an entire observation batch into one contiguous
/// `[B × obs_dim]` f32 block in a single pass — the shared entry point of
/// every batched trainer (PPO/DQN/SAC and the XLA path). Panics on rgb
/// batches, like [`crate::batch::ObsBatch::as_i32`].
pub fn preprocess_obs_batch(obs: &crate::batch::ObsBatch, out: &mut [f32]) {
    preprocess_obs(obs.as_i32(), out)
}

/// Grow-only resize for the trainers' reusable workspace buffers — the
/// one shared helper, defined next to the [`crate::nn::mlp::BatchCache`]
/// buffers it manages.
pub(crate) use crate::nn::mlp::ensure;

/// Tracks completed-episode returns with a sliding window, the metric every
/// Fig.-7 curve reports.
#[derive(Clone, Debug)]
pub struct ReturnTracker {
    window: usize,
    recent: std::collections::VecDeque<f32>,
    pub episodes: u64,
}

impl ReturnTracker {
    pub fn new(window: usize) -> Self {
        ReturnTracker { window, recent: Default::default(), episodes: 0 }
    }

    pub fn push(&mut self, episodic_return: f32) {
        self.episodes += 1;
        if self.recent.len() == self.window {
            self.recent.pop_front();
        }
        self.recent.push_back(episodic_return);
    }

    /// Mean over the window (0.0 before any episode completes).
    pub fn mean(&self) -> f32 {
        if self.recent.is_empty() {
            return 0.0;
        }
        self.recent.iter().sum::<f32>() / self.recent.len() as f32
    }
}

/// One point on a training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub env_steps: u64,
    pub mean_return: f32,
    pub loss: f32,
}

/// Training log shared by all agents.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub curve: Vec<CurvePoint>,
    pub episodes: u64,
}

impl TrainLog {
    pub fn final_return(&self) -> f32 {
        self.curve.last().map(|p| p.mean_return).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preprocess_scales() {
        let obs = [10, 5, 0, 2];
        let mut out = [0.0; 4];
        preprocess_obs(&obs, &mut out);
        assert_eq!(out, [1.0, 0.5, 0.0, 0.2]);
    }

    #[test]
    fn return_tracker_windows() {
        let mut t = ReturnTracker::new(3);
        assert_eq!(t.mean(), 0.0);
        for r in [1.0, 2.0, 3.0, 4.0] {
            t.push(r);
        }
        assert_eq!(t.episodes, 4);
        assert!((t.mean() - 3.0).abs() < 1e-6); // window holds 2,3,4
    }
}
