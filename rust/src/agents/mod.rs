//! RL algorithm baselines (paper §4.3): PPO, Double DQN and discrete SAC,
//! matching the Rejax implementations the paper benchmarks — networks with
//! two hidden layers of 64 units, tuned hyperparameters (Table 9), and the
//! "128 parallel env steps + 128 updates" cadence for the off-policy
//! algorithms.
//!
//! All agents consume the batched engine's symbolic first-person
//! observations *concatenated with the mission feature block*, so
//! goal-conditioned families (GoToDoor, Fetch, GoToObj, PutNext, …) are
//! learnable: [`preprocess_obs_batch`] / [`preprocess_env_obs`] are the
//! shared featurisers.

pub mod dqn;
pub mod gae;
pub mod ppo;
pub mod replay;
pub mod sac;
pub mod tuning;

pub use dqn::{Dqn, DqnCheckpoint, DqnConfig};
pub use ppo::{Ppo, PpoCheckpoint, PpoConfig};
pub use sac::{Sac, SacCheckpoint, SacConfig};

/// Flattened grid-observation size for a symbolic first-person view.
pub const GRID_OBS_DIM: usize = 7 * 7 * 3;

/// Width of the goal-conditioning token block every observation batch
/// carries (see [`crate::core::mission`]).
pub const MISSION_TOKENS: usize = crate::core::mission::MISSION_TOKENS;

/// Policy input size: the flattened, normalised first-person grid features
/// (`GRID_OBS_DIM`) concatenated with the tokenised mission block
/// (`MISSION_TOKENS`). Every agent conditions on the goal — mission-free
/// families simply see an all-zero block. Derived, never hard-coded: the
/// AOT artifact pipeline and every trainer read this constant.
pub const OBS_DIM: usize = GRID_OBS_DIM + MISSION_TOKENS;

/// Normalise a symbolic i32 observation into `[0, 1]`-ish floats
/// (tag ≤ 10, colour ≤ 5, state ≤ 3 → divide by 10). Elementwise, so it
/// works on a single `[obs_dim]` row or a whole `[B × obs_dim]` block —
/// including rows that end in the small-integer mission token block
/// (which lands on the same 0.1 scale as the grid features).
pub fn preprocess_obs(obs: &[i32], out: &mut [f32]) {
    debug_assert_eq!(obs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(obs) {
        *o = x as f32 / 10.0;
    }
}

/// Featurise an entire observation batch into one contiguous
/// `[B × (grid + MISSION_TOKENS)]` f32 block — per env, the normalised grid
/// features followed by the mission features — the shared entry point of
/// every batched trainer (PPO/DQN/SAC). Bitwise identical to running
/// [`preprocess_env_obs`] row by row (the serial oracles pin this).
/// Panics on rgb batches, like [`crate::batch::ObsBatch::as_i32`].
pub fn preprocess_obs_batch(obs: &crate::batch::ObsBatch, out: &mut [f32]) {
    let b = obs.mission.len() / MISSION_TOKENS;
    let grid = obs.as_i32();
    let g = grid.len() / b;
    let d = g + MISSION_TOKENS;
    debug_assert_eq!(out.len(), b * d);
    for i in 0..b {
        let row = &mut out[i * d..(i + 1) * d];
        preprocess_obs(&grid[i * g..(i + 1) * g], &mut row[..g]);
        preprocess_obs(obs.mission_row(b, i), &mut row[g..]);
    }
}

/// Featurise one env's observation — grid then mission — into `out`
/// (`grid + MISSION_TOKENS` floats). The per-sample twin of
/// [`preprocess_obs_batch`], used by the serial parity oracles.
pub fn preprocess_env_obs(obs: &crate::batch::ObsBatch, b: usize, i: usize, out: &mut [f32]) {
    let grid = obs.env_i32(b, i);
    preprocess_obs(grid, &mut out[..grid.len()]);
    preprocess_obs(obs.mission_row(b, i), &mut out[grid.len()..]);
}

/// Grow-only resize for the trainers' reusable workspace buffers — the
/// one shared helper, defined next to the [`crate::nn::mlp::BatchCache`]
/// buffers it manages.
pub(crate) use crate::nn::mlp::ensure;

/// Tracks completed-episode returns with a sliding window, the metric every
/// Fig.-7 curve reports.
#[derive(Clone, Debug)]
pub struct ReturnTracker {
    window: usize,
    recent: std::collections::VecDeque<f32>,
    pub episodes: u64,
}

impl ReturnTracker {
    pub fn new(window: usize) -> Self {
        ReturnTracker { window, recent: Default::default(), episodes: 0 }
    }

    pub fn push(&mut self, episodic_return: f32) {
        self.episodes += 1;
        if self.recent.len() == self.window {
            self.recent.pop_front();
        }
        self.recent.push_back(episodic_return);
    }

    /// Mean over the window (0.0 before any episode completes).
    pub fn mean(&self) -> f32 {
        if self.recent.is_empty() {
            return 0.0;
        }
        self.recent.iter().sum::<f32>() / self.recent.len() as f32
    }
}

/// One point on a training curve.
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub env_steps: u64,
    pub mean_return: f32,
    pub loss: f32,
}

/// Training log shared by all agents.
#[derive(Clone, Debug, Default)]
pub struct TrainLog {
    pub curve: Vec<CurvePoint>,
    pub episodes: u64,
}

impl TrainLog {
    pub fn final_return(&self) -> f32 {
        self.curve.last().map(|p| p.mean_return).unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preprocess_scales() {
        let obs = [10, 5, 0, 2];
        let mut out = [0.0; 4];
        preprocess_obs(&obs, &mut out);
        assert_eq!(out, [1.0, 0.5, 0.0, 0.2]);
    }

    #[test]
    fn batch_featurise_concats_mission_and_matches_per_env_path() {
        use crate::batch::BatchedEnv;
        use crate::rng::Key;
        let cfg = crate::envs::registry::make("Navix-GoToDoor-5x5-v0")
            .expect("registry should know Navix-GoToDoor-5x5-v0");
        let b = 3;
        let env = BatchedEnv::new(cfg, b, Key::new(4));
        let g = env.obs.stride(b);
        let d = g + MISSION_TOKENS;
        assert_eq!(d, OBS_DIM, "first-person grid + mission = the policy input dim");
        let mut batch = vec![0.0f32; b * d];
        preprocess_obs_batch(&env.obs, &mut batch);
        let mut row = vec![0.0f32; d];
        for i in 0..b {
            preprocess_env_obs(&env.obs, b, i, &mut row);
            assert_eq!(&batch[i * d..(i + 1) * d], &row[..], "env {i}");
            assert!(
                row[g..].iter().any(|&x| x != 0.0),
                "env {i}: mission features must reach the policy"
            );
        }
    }

    #[test]
    fn return_tracker_windows() {
        let mut t = ReturnTracker::new(3);
        assert_eq!(t.mean(), 0.0);
        for r in [1.0, 2.0, 3.0, 4.0] {
            t.push(r);
        }
        assert_eq!(t.episodes, 4);
        assert!((t.mean() - 3.0).abs() < 1e-6); // window holds 2,3,4
    }
}
