//! Generalised Advantage Estimation (Schulman et al., 2016), with the
//! dm_env-style termination/truncation distinction the batched engine
//! produces: advantages stop accumulating at every episode boundary, and
//! bootstrapping uses `discount = 0` on termination only.

/// Compute GAE advantages and value targets in place.
///
/// Inputs are time-major flattened `[T × B]` slices:
/// * `rewards[t*b + i]` — r_{t+1}
/// * `values[t*b + i]` — V(s_t); `last_values[i]` — V(s_T) bootstrap
/// * `discounts` — 0.0 where the step *terminated*, 1.0 otherwise
/// * `boundaries` — true where the step ended an episode (terminated OR
///   truncated); the advantage chain is cut there
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    last_values: &[f32],
    discounts: &[f32],
    boundaries: &[bool],
    gamma: f32,
    lambda: f32,
    advantages: &mut [f32],
    targets: &mut [f32],
) {
    let b = last_values.len();
    let t_len = rewards.len() / b;
    debug_assert_eq!(rewards.len(), t_len * b);
    for i in 0..b {
        let mut adv = 0.0f32;
        let mut next_value = last_values[i];
        for t in (0..t_len).rev() {
            let idx = t * b + i;
            let nonterminal = discounts[idx]; // 0 when terminated
            let delta = rewards[idx] + gamma * nonterminal * next_value - values[idx];
            let carry = if boundaries[idx] { 0.0 } else { 1.0 };
            adv = delta + gamma * lambda * carry * adv;
            advantages[idx] = adv;
            targets[idx] = adv + values[idx];
            next_value = values[idx];
        }
    }
}

/// Normalise advantages to zero mean / unit variance (the standard PPO
/// trick; matches Rejax).
pub fn normalize(advantages: &mut [f32]) {
    let n = advantages.len() as f32;
    let mean = advantages.iter().sum::<f32>() / n;
    let var = advantages.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-8);
    for a in advantages.iter_mut() {
        *a = (*a - mean) / std;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_step_advantage_is_td_error() {
        let rewards = [1.0];
        let values = [0.5];
        let last = [0.25];
        let disc = [1.0];
        let bound = [false];
        let mut adv = [0.0];
        let mut tgt = [0.0];
        gae(&rewards, &values, &last, &disc, &bound, 0.9, 0.95, &mut adv, &mut tgt);
        let expect = 1.0 + 0.9 * 0.25 - 0.5;
        assert!((adv[0] - expect).abs() < 1e-6);
        assert!((tgt[0] - (expect + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn termination_stops_bootstrap_and_carry() {
        // two steps, terminal at t=0: the t=0 delta must ignore V(s_1).
        let rewards = [1.0, 0.0];
        let values = [0.5, 0.7];
        let last = [0.9];
        let disc = [0.0, 1.0]; // t=0 terminated
        let bound = [true, false];
        let mut adv = [0.0; 2];
        let mut tgt = [0.0; 2];
        gae(&rewards, &values, &last, &disc, &bound, 0.99, 0.95, &mut adv, &mut tgt);
        // t=0: delta = 1.0 - 0.5, no carry from t=1
        assert!((adv[0] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn truncation_cuts_carry_but_keeps_bootstrap() {
        let rewards = [0.0, 0.0];
        let values = [0.0, 0.0];
        let last = [1.0];
        let disc = [1.0, 1.0]; // truncated ≠ terminated: discount stays 1
        let bound = [true, false]; // but the chain is cut at t=0
        let mut adv = [0.0; 2];
        let mut tgt = [0.0; 2];
        gae(&rewards, &values, &last, &disc, &bound, 1.0, 1.0, &mut adv, &mut tgt);
        // t=1: delta = 0 + 1*1.0 - 0 = 1.0
        assert!((adv[1] - 1.0).abs() < 1e-6);
        // t=0 bootstraps V(s_1)=0 and does NOT add t=1's advantage
        assert!((adv[0] - 0.0).abs() < 1e-6);
    }

    #[test]
    fn batch_independence() {
        // two envs interleaved; env 1 all zeros must stay zeros.
        let rewards = [1.0, 0.0, 1.0, 0.0];
        let values = [0.0, 0.0, 0.0, 0.0];
        let last = [0.0, 0.0];
        let disc = [1.0, 1.0, 1.0, 1.0];
        let bound = [false, false, false, false];
        let mut adv = [0.0; 4];
        let mut tgt = [0.0; 4];
        gae(&rewards, &values, &last, &disc, &bound, 0.9, 0.9, &mut adv, &mut tgt);
        assert_eq!(adv[1], 0.0);
        assert_eq!(adv[3], 0.0);
        assert!(adv[0] > adv[2], "earlier reward accumulates future advantage");
    }

    #[test]
    fn normalize_zero_mean_unit_std() {
        let mut xs = [1.0, 2.0, 3.0, 4.0];
        normalize(&mut xs);
        let mean: f32 = xs.iter().sum::<f32>() / 4.0;
        let var: f32 = xs.iter().map(|x| x * x).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-5);
    }
}
