//! Double DQN (van Hasselt et al., 2016) — paper §4.3 baseline.
//!
//! Matches the paper's training recipe: instead of alternating one env step
//! and one update, the agent performs `parallel_steps` (=128) batched env
//! steps then `parallel_steps` updates, each on a fresh minibatch — the
//! cadence the paper reports as a pure-runtime win with unchanged final
//! performance.

use crate::agents::{preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::agents::replay::Replay;
use crate::batch::BatchedEnv;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::{argmax, Activation, Mlp};
use crate::rng::Rng;

/// DQN hyperparameters (Table 9 "fitted" knobs).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub batch_size: usize,
    pub buffer_capacity: usize,
    pub learning_starts: usize,
    pub target_update_freq: usize,
    pub gamma: f32,
    pub lr: f32,
    pub exploration_fraction: f32,
    pub final_eps: f32,
    pub max_grad_norm: f32,
    pub parallel_steps: usize,
    pub activation: Activation,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig {
            batch_size: 128,
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            target_update_freq: 1_000,
            gamma: 0.99,
            lr: 3e-4,
            exploration_fraction: 0.5,
            final_eps: 0.05,
            max_grad_norm: 10.0,
            parallel_steps: 128,
            activation: Activation::Relu,
        }
    }
}

/// Double-DQN agent with target network.
pub struct Dqn {
    pub cfg: DqnConfig,
    pub q: Mlp,
    pub q_target: Mlp,
    opt: Adam,
    replay: Replay,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
    env_steps: u64,
    updates: u64,
}

impl Dqn {
    pub fn new(cfg: DqnConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Dqn {
        let mut rng = Rng::new(seed);
        let q = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q_target = q.clone();
        let opt = Adam::new(q.params.len(), cfg.lr);
        let replay = Replay::new(cfg.buffer_capacity, obs_dim);
        Dqn { cfg, q, q_target, opt, replay, obs_dim, n_actions, rng, env_steps: 0, updates: 0 }
    }

    /// Linear ε schedule: 1.0 → final_eps over exploration_fraction of the
    /// budget.
    pub fn epsilon(&self, total_steps: u64) -> f32 {
        let frac = self.env_steps as f32
            / (self.cfg.exploration_fraction * total_steps as f32).max(1.0);
        (1.0 - frac).max(0.0) * (1.0 - self.cfg.final_eps) + self.cfg.final_eps
    }

    fn act_eps(&mut self, obs: &[i32], eps: f32) -> u8 {
        if self.rng.uniform_f32() < eps {
            return self.rng.below(self.n_actions as u32) as u8;
        }
        let mut x = vec![0.0f32; self.obs_dim];
        preprocess_obs(obs, &mut x);
        argmax(&self.q.infer(&x)) as u8
    }

    /// One gradient update on a sampled minibatch. Returns the TD loss.
    pub fn update(&mut self) -> f32 {
        if self.replay.len() < self.cfg.batch_size.max(self.cfg.learning_starts) {
            return 0.0;
        }
        let batch = self.replay.sample(self.cfg.batch_size, &mut self.rng);
        let d = self.obs_dim;
        let mut grads = vec![0.0f32; self.q.params.len()];
        let mut cache = crate::nn::mlp::Cache::default();
        let mut loss = 0.0f32;
        let scale = 1.0 / self.cfg.batch_size as f32;
        for k in 0..self.cfg.batch_size {
            let x = &batch.obs[k * d..(k + 1) * d];
            let nx = &batch.next_obs[k * d..(k + 1) * d];
            // Double-DQN target: online net picks, target net evaluates.
            let next_online = self.q.infer(nx);
            let a_star = argmax(&next_online);
            let next_target = self.q_target.infer(nx);
            let y = batch.rewards[k]
                + self.cfg.gamma * batch.nonterminal[k] * next_target[a_star];
            let qs = self.q.forward(x, &mut cache);
            let a = batch.actions[k] as usize;
            let err = qs[a] - y;
            loss += 0.5 * err * err;
            let mut dq = vec![0.0f32; self.n_actions];
            dq[a] = scale * err;
            self.q.backward(&cache, &dq, &mut grads);
        }
        clip_global_norm(&mut grads, self.cfg.max_grad_norm);
        self.opt.step(&mut self.q.params, &grads);
        self.updates += 1;
        if self.updates % self.cfg.target_update_freq as u64 == 0 {
            self.q_target = self.q.clone();
        }
        loss * scale
    }

    /// Train for `total_steps` env steps on `env` using the paper's
    /// 128-steps-then-128-updates cadence.
    pub fn train(&mut self, env: &mut BatchedEnv, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        let b = env.b;
        let mut actions = vec![0u8; b];
        let mut prev_obs: Vec<Vec<i32>> =
            (0..b).map(|i| env.obs.env_i32(b, i).to_vec()).collect();
        while self.env_steps < total_steps {
            let mut chunk_loss = 0.0;
            for _ in 0..self.cfg.parallel_steps {
                let eps = self.epsilon(total_steps);
                for i in 0..b {
                    actions[i] = self.act_eps(&prev_obs[i], eps);
                }
                env.step(&actions);
                for i in 0..b {
                    let next = env.obs.env_i32(b, i);
                    let terminated = env.timestep.discount[i] == 0.0;
                    if env.timestep.step_type[i] == crate::core::timestep::StepType::First {
                        // autoreset boundary: the transition that caused it
                        // was already recorded last step.
                        prev_obs[i].copy_from_slice(next);
                        continue;
                    }
                    self.replay.push(
                        &prev_obs[i],
                        actions[i],
                        env.timestep.reward[i],
                        next,
                        terminated,
                    );
                    if env.timestep.step_type[i].is_last() {
                        tracker.push(env.timestep.episodic_return[i]);
                    }
                    prev_obs[i].copy_from_slice(next);
                }
                self.env_steps += b as u64;
            }
            for _ in 0..self.cfg.parallel_steps {
                chunk_loss += self.update();
            }
            log.curve.push(CurvePoint {
                env_steps: self.env_steps,
                mean_return: tracker.mean(),
                loss: chunk_loss / self.cfg.parallel_steps as f32,
            });
        }
        log.episodes = tracker.episodes;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn epsilon_schedule_decays_to_final() {
        let mut dqn = Dqn::new(DqnConfig::default(), 147, 7, 0);
        assert!((dqn.epsilon(1000) - 1.0).abs() < 1e-6);
        dqn.env_steps = 500; // = exploration_fraction * total
        assert!((dqn.epsilon(1000) - dqn.cfg.final_eps).abs() < 1e-6);
        dqn.env_steps = 1000;
        assert!((dqn.epsilon(1000) - dqn.cfg.final_eps).abs() < 1e-6);
    }

    #[test]
    fn update_is_noop_until_learning_starts() {
        let mut dqn = Dqn::new(DqnConfig::default(), 4, 3, 0);
        assert_eq!(dqn.update(), 0.0);
    }

    #[test]
    fn dqn_learns_empty_5x5_smoke() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(2));
        let cfg = DqnConfig {
            learning_starts: 500,
            buffer_capacity: 20_000,
            lr: 1e-3,
            exploration_fraction: 0.4,
            parallel_steps: 64,
            ..Default::default()
        };
        let mut dqn = Dqn::new(cfg, 147, 7, 2);
        let log = dqn.train(&mut env, 60_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.4,
            "DQN failed to learn Empty-5x5: final return {final_ret} ({} eps)",
            log.episodes
        );
    }
}
