//! Double DQN (van Hasselt et al., 2016) — paper §4.3 baseline.
//!
//! Matches the paper's training recipe: instead of alternating one env step
//! and one update, the agent performs `parallel_steps` (=128) batched env
//! steps then `parallel_steps` updates, each on a fresh minibatch — the
//! cadence the paper reports as a pure-runtime win with unchanged final
//! performance.
//!
//! Since PR 4 both halves run on the batched NN core: acting performs one
//! `[B, obs_dim]` Q-forward per env step (the ε draws happen first, in env
//! order, so the RNG stream — and therefore every trajectory — is
//! bit-identical to the per-sample path), and the update runs three
//! batched forwards + one batched backward per minibatch through reusable
//! workspaces instead of `3·B` single-row passes.

use crate::agents::{ensure, preprocess_obs, CurvePoint, ReturnTracker, TrainLog};
use crate::agents::replay::Replay;
use crate::batch::BatchedEnv;
use crate::nn::adam::{clip_global_norm, Adam};
use crate::nn::mlp::BatchCache;
use crate::nn::{argmax, Activation, Mlp};
use crate::rng::Rng;

/// DQN hyperparameters (Table 9 "fitted" knobs).
#[derive(Clone, Debug)]
pub struct DqnConfig {
    pub batch_size: usize,
    pub buffer_capacity: usize,
    pub learning_starts: usize,
    pub target_update_freq: usize,
    pub gamma: f32,
    pub lr: f32,
    pub exploration_fraction: f32,
    pub final_eps: f32,
    pub max_grad_norm: f32,
    pub parallel_steps: usize,
    pub activation: Activation,
}

impl Default for DqnConfig {
    fn default() -> Self {
        DqnConfig {
            batch_size: 128,
            buffer_capacity: 50_000,
            learning_starts: 1_000,
            target_update_freq: 1_000,
            gamma: 0.99,
            lr: 3e-4,
            exploration_fraction: 0.5,
            final_eps: 0.05,
            max_grad_norm: 10.0,
            parallel_steps: 128,
            activation: Activation::Relu,
        }
    }
}

/// Reusable batched-update/acting workspaces (grown on first use).
#[derive(Default)]
struct Workspace {
    /// `[B × obs_dim]` acting features.
    act_x: Vec<f32>,
    /// `[B]` explore/exploit decisions of the current step.
    explore: Vec<bool>,
    /// `[MB × n_actions]` output gradient (one non-zero per row).
    dq: Vec<f32>,
    /// `[MB]` TD targets.
    y: Vec<f32>,
    /// `[MB]` argmax of the online net on s' (Double-DQN selection).
    a_star: Vec<usize>,
    grads: Vec<f32>,
    cache: BatchCache,
    icache: BatchCache,
}

/// Double-DQN agent with target network.
/// Everything [`Dqn`] needs to resume training bit-identically (the ε
/// schedule and target-sync cadence live in the counters).
#[derive(Clone)]
pub struct DqnCheckpoint {
    pub q: Mlp,
    pub q_target: Mlp,
    pub opt: Adam,
    pub replay: Replay,
    pub rng: Rng,
    pub env_steps: u64,
    pub updates: u64,
}

pub struct Dqn {
    pub cfg: DqnConfig,
    pub q: Mlp,
    pub q_target: Mlp,
    opt: Adam,
    replay: Replay,
    obs_dim: usize,
    n_actions: usize,
    rng: Rng,
    env_steps: u64,
    updates: u64,
    ws: Workspace,
}

impl Dqn {
    pub fn new(cfg: DqnConfig, obs_dim: usize, n_actions: usize, seed: u64) -> Dqn {
        let mut rng = Rng::new(seed);
        let q = Mlp::new(&[obs_dim, 64, 64, n_actions], cfg.activation, &mut rng);
        let q_target = q.clone();
        let opt = Adam::new(q.params.len(), cfg.lr);
        let replay = Replay::new(cfg.buffer_capacity, obs_dim);
        Dqn {
            cfg,
            q,
            q_target,
            opt,
            replay,
            obs_dim,
            n_actions,
            rng,
            env_steps: 0,
            updates: 0,
            ws: Workspace::default(),
        }
    }

    /// Capture the agent's full training state: online + target networks,
    /// Adam moments, the replay buffer contents, the RNG stream and the
    /// schedule counters. Pair with an engine
    /// [`crate::core::snapshot::EngineCheckpoint`] to checkpoint a run.
    pub fn save_state(&self) -> DqnCheckpoint {
        DqnCheckpoint {
            q: self.q.clone(),
            q_target: self.q_target.clone(),
            opt: self.opt.clone(),
            replay: self.replay.clone(),
            rng: self.rng.clone(),
            env_steps: self.env_steps,
            updates: self.updates,
        }
    }

    /// Restore a state captured by [`Dqn::save_state`]; subsequent
    /// training replays bit-identically.
    pub fn restore_state(&mut self, ck: &DqnCheckpoint) {
        self.q = ck.q.clone();
        self.q_target = ck.q_target.clone();
        self.opt = ck.opt.clone();
        self.replay = ck.replay.clone();
        self.rng = ck.rng.clone();
        self.env_steps = ck.env_steps;
        self.updates = ck.updates;
    }

    /// Linear ε schedule: 1.0 → final_eps over exploration_fraction of the
    /// budget.
    pub fn epsilon(&self, total_steps: u64) -> f32 {
        let frac = self.env_steps as f32
            / (self.cfg.exploration_fraction * total_steps as f32).max(1.0);
        (1.0 - frac).max(0.0) * (1.0 - self.cfg.final_eps) + self.cfg.final_eps
    }

    /// ε-greedy actions for the whole batch: the ε draws happen first in
    /// env order (the per-sample path's exact RNG sequence — one uniform,
    /// plus one `below` only when exploring), then a single batched greedy
    /// forward serves every exploiting env.
    fn act_eps_batch(&mut self, prev_obs: &[Vec<i32>], eps: f32, actions: &mut [u8]) {
        let (b, d, na) = (prev_obs.len(), self.obs_dim, self.n_actions);
        ensure(&mut self.ws.act_x, b * d);
        ensure(&mut self.ws.explore, b);
        let mut any_greedy = false;
        for i in 0..b {
            let explore = self.rng.uniform_f32() < eps;
            self.ws.explore[i] = explore;
            if explore {
                actions[i] = self.rng.below(na as u32) as u8;
            } else {
                any_greedy = true;
            }
        }
        // Early in training ε ≈ 1 and every env explores — skip the
        // forward entirely, like the per-sample path did.
        if !any_greedy {
            return;
        }
        {
            let ws = &mut self.ws;
            for (i, o) in prev_obs.iter().enumerate() {
                preprocess_obs(o, &mut ws.act_x[i * d..(i + 1) * d]);
            }
        }
        self.q.forward_batch(&self.ws.act_x[..b * d], b, &mut self.ws.icache);
        let qs = self.ws.icache.out();
        for i in 0..b {
            if !self.ws.explore[i] {
                actions[i] = argmax(&qs[i * na..(i + 1) * na]) as u8;
            }
        }
    }

    /// One gradient update on a sampled minibatch — three batched forwards
    /// (Double-DQN selection, target evaluation, online Q) and one batched
    /// backward through reusable workspaces. Bit-identical to the original
    /// per-sample loop. Returns the TD loss.
    pub fn update(&mut self) -> f32 {
        if self.replay.len() < self.cfg.batch_size.max(self.cfg.learning_starts) {
            return 0.0;
        }
        let batch = self.replay.sample(self.cfg.batch_size, &mut self.rng);
        let (na, mbs) = (self.n_actions, self.cfg.batch_size);
        let plen = self.q.params.len();
        let scale = 1.0 / mbs as f32;
        {
            let ws = &mut self.ws;
            ensure(&mut ws.dq, mbs * na);
            ensure(&mut ws.y, mbs);
            ensure(&mut ws.a_star, mbs);
            ensure(&mut ws.grads, plen);
            ws.grads[..plen].fill(0.0);
        }

        // Double-DQN target: online net picks…
        self.q.forward_batch(&batch.next_obs, mbs, &mut self.ws.icache);
        {
            let ws = &mut self.ws;
            let nq = ws.icache.out();
            for k in 0..mbs {
                ws.a_star[k] = argmax(&nq[k * na..(k + 1) * na]);
            }
        }
        // …target net evaluates.
        self.q_target.forward_batch(&batch.next_obs, mbs, &mut self.ws.icache);
        {
            let ws = &mut self.ws;
            let nt = ws.icache.out();
            for k in 0..mbs {
                ws.y[k] = batch.rewards[k]
                    + self.cfg.gamma * batch.nonterminal[k] * nt[k * na + ws.a_star[k]];
            }
        }

        // Online Q on s, TD error on the taken action, batched backward.
        self.q.forward_batch(&batch.obs, mbs, &mut self.ws.cache);
        let mut loss = 0.0f32;
        {
            let ws = &mut self.ws;
            let qs = ws.cache.out();
            ws.dq[..mbs * na].fill(0.0);
            for k in 0..mbs {
                let a = batch.actions[k] as usize;
                let err = qs[k * na + a] - ws.y[k];
                loss += 0.5 * err * err;
                ws.dq[k * na + a] = scale * err;
            }
        }
        self.q.backward_batch(&mut self.ws.cache, &self.ws.dq[..mbs * na], &mut self.ws.grads);
        clip_global_norm(&mut self.ws.grads[..plen], self.cfg.max_grad_norm);
        self.opt.step(&mut self.q.params, &self.ws.grads[..plen]);
        self.updates += 1;
        if self.updates % self.cfg.target_update_freq as u64 == 0 {
            self.q_target = self.q.clone();
        }
        loss * scale
    }

    /// Train for `total_steps` env steps on `env` using the paper's
    /// 128-steps-then-128-updates cadence.
    pub fn train(&mut self, env: &mut BatchedEnv, total_steps: u64) -> TrainLog {
        let mut log = TrainLog::default();
        let mut tracker = ReturnTracker::new(64);
        // One policy row per agent-row: multi-agent engines expose B·A
        // rows, and every row is an independent replay transition.
        let b = env.policy_rows();
        let mut actions = vec![0u8; b];
        // Policy rows are grid + mission: the replay buffer stores the full
        // goal-conditioned input, so off-policy updates see the goal too.
        let d = env.obs.stride(b) + crate::agents::MISSION_TOKENS;
        debug_assert_eq!(d, self.obs_dim, "agent obs_dim must be grid + mission");
        let mut next_row = vec![0i32; d];
        let mut prev_obs: Vec<Vec<i32>> = (0..b)
            .map(|i| {
                let mut row = vec![0i32; d];
                env.obs.copy_policy_row(b, i, &mut row);
                row
            })
            .collect();
        while self.env_steps < total_steps {
            let mut chunk_loss = 0.0;
            for _ in 0..self.cfg.parallel_steps {
                let eps = self.epsilon(total_steps);
                self.act_eps_batch(&prev_obs, eps, &mut actions);
                env.step(&actions);
                for i in 0..b {
                    env.obs.copy_policy_row(b, i, &mut next_row);
                    let terminated = env.timestep.discount[i] == 0.0;
                    if env.timestep.step_type[i] == crate::core::timestep::StepType::First {
                        // autoreset boundary: the transition that caused it
                        // was already recorded last step.
                        prev_obs[i].copy_from_slice(&next_row);
                        continue;
                    }
                    self.replay.push(
                        &prev_obs[i],
                        actions[i],
                        env.timestep.reward[i],
                        &next_row,
                        terminated,
                    );
                    if env.timestep.step_type[i].is_last() {
                        tracker.push(env.timestep.episodic_return[i]);
                    }
                    prev_obs[i].copy_from_slice(&next_row);
                }
                self.env_steps += b as u64;
            }
            for _ in 0..self.cfg.parallel_steps {
                chunk_loss += self.update();
            }
            log.curve.push(CurvePoint {
                env_steps: self.env_steps,
                mean_return: tracker.mean(),
                loss: chunk_loss / self.cfg.parallel_steps as f32,
            });
        }
        log.episodes = tracker.episodes;
        log
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::rng::Key;

    #[test]
    fn epsilon_schedule_decays_to_final() {
        let mut dqn = Dqn::new(DqnConfig::default(), crate::agents::OBS_DIM, 7, 0);
        assert!((dqn.epsilon(1000) - 1.0).abs() < 1e-6);
        dqn.env_steps = 500; // = exploration_fraction * total
        assert!((dqn.epsilon(1000) - dqn.cfg.final_eps).abs() < 1e-6);
        dqn.env_steps = 1000;
        assert!((dqn.epsilon(1000) - dqn.cfg.final_eps).abs() < 1e-6);
    }

    #[test]
    fn update_is_noop_until_learning_starts() {
        let mut dqn = Dqn::new(DqnConfig::default(), 4, 3, 0);
        assert_eq!(dqn.update(), 0.0);
    }

    #[test]
    fn dqn_learns_empty_5x5_smoke() {
        let mut env = BatchedEnv::new(make("Navix-Empty-5x5-v0").unwrap(), 8, Key::new(2));
        let cfg = DqnConfig {
            learning_starts: 500,
            buffer_capacity: 20_000,
            lr: 1e-3,
            exploration_fraction: 0.4,
            parallel_steps: 64,
            ..Default::default()
        };
        let mut dqn = Dqn::new(cfg, crate::agents::OBS_DIM, 7, 2);
        let log = dqn.train(&mut env, 60_000);
        let final_ret = log.final_return();
        assert!(
            final_ret > 0.4,
            "DQN failed to learn Empty-5x5: final return {final_ret} ({} eps)",
            log.episodes
        );
    }
}
