//! Random-search hyperparameter tuning (paper §4.3: "32 iterations of
//! random search… each configuration evaluated with 16 initial seeds; the
//! configuration with the highest average final return is selected").
//!
//! The search spaces cover the Table-9 "fitted" knobs for each algorithm.

use crate::rng::Rng;

/// A sampled hyperparameter assignment (name → value as f64; integer knobs
/// round).
#[derive(Clone, Debug, Default)]
pub struct Sample {
    pub values: Vec<(String, f64)>,
}

impl Sample {
    pub fn get(&self, name: &str) -> f64 {
        self.values
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
            .unwrap_or_else(|| panic!("hyperparameter {name} not sampled"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).round() as usize
    }

    pub fn get_f32(&self, name: &str) -> f32 {
        self.get(name) as f32
    }
}

/// One tunable dimension.
#[derive(Clone, Debug)]
pub enum Dim {
    /// Log-uniform continuous (e.g. learning rates).
    LogUniform { name: &'static str, lo: f64, hi: f64 },
    /// Uniform continuous.
    Uniform { name: &'static str, lo: f64, hi: f64 },
    /// Uniform over an explicit finite set.
    Choice { name: &'static str, options: &'static [f64] },
}

impl Dim {
    fn name(&self) -> &'static str {
        match self {
            Dim::LogUniform { name, .. } | Dim::Uniform { name, .. } | Dim::Choice { name, .. } => {
                name
            }
        }
    }

    fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            Dim::LogUniform { lo, hi, .. } => {
                (lo.ln() + rng.uniform() * (hi.ln() - lo.ln())).exp()
            }
            Dim::Uniform { lo, hi, .. } => lo + rng.uniform() * (hi - lo),
            Dim::Choice { options, .. } => options[rng.below(options.len() as u32) as usize],
        }
    }
}

/// Table-9 search space for PPO.
pub fn ppo_space() -> Vec<Dim> {
    vec![
        Dim::LogUniform { name: "lr", lo: 1e-4, hi: 1e-2 },
        Dim::Choice { name: "num_envs", options: &[8.0, 16.0, 32.0] },
        Dim::Choice { name: "rollout_len", options: &[64.0, 128.0, 256.0] },
        Dim::Choice { name: "epochs", options: &[2.0, 4.0, 8.0] },
        Dim::Choice { name: "minibatches", options: &[4.0, 8.0, 16.0] },
        Dim::Uniform { name: "gamma", lo: 0.95, hi: 0.999 },
        Dim::Uniform { name: "gae_lambda", lo: 0.9, hi: 1.0 },
        Dim::Choice { name: "max_grad_norm", options: &[0.5, 1.0, 10.0] },
        Dim::Choice { name: "activation", options: &[0.0, 1.0] }, // 0=relu 1=tanh
    ]
}

/// Table-9 search space for DQN.
pub fn dqn_space() -> Vec<Dim> {
    vec![
        Dim::LogUniform { name: "lr", lo: 1e-4, hi: 1e-2 },
        Dim::Choice { name: "batch_size", options: &[64.0, 128.0, 256.0] },
        Dim::Choice { name: "target_update_freq", options: &[250.0, 500.0, 1000.0] },
        Dim::Uniform { name: "gamma", lo: 0.95, hi: 0.999 },
        Dim::Uniform { name: "exploration_fraction", lo: 0.2, hi: 0.8 },
        Dim::Uniform { name: "final_eps", lo: 0.01, hi: 0.1 },
        Dim::Choice { name: "max_grad_norm", options: &[1.0, 10.0] },
        Dim::Choice { name: "activation", options: &[0.0, 1.0] },
    ]
}

/// Table-9 search space for SAC.
pub fn sac_space() -> Vec<Dim> {
    vec![
        Dim::LogUniform { name: "lr", lo: 1e-4, hi: 1e-2 },
        Dim::Choice { name: "batch_size", options: &[64.0, 128.0, 256.0] },
        Dim::Uniform { name: "gamma", lo: 0.95, hi: 0.999 },
        Dim::LogUniform { name: "tau", lo: 1e-3, hi: 5e-2 },
        Dim::Uniform { name: "target_entropy_ratio", lo: 0.05, hi: 0.5 },
        Dim::Choice { name: "activation", options: &[0.0, 1.0] },
    ]
}

/// Random search: `iterations` samples, each scored by `eval` (higher is
/// better — typically mean final return over seeds). Returns the best
/// (sample, score).
pub fn random_search<F: FnMut(&Sample) -> f64>(
    space: &[Dim],
    iterations: usize,
    seed: u64,
    mut eval: F,
) -> (Sample, f64) {
    let mut rng = Rng::new(seed);
    let mut best: Option<(Sample, f64)> = None;
    for _ in 0..iterations {
        let sample = Sample {
            values: space.iter().map(|d| (d.name().to_string(), d.sample(&mut rng))).collect(),
        };
        let score = eval(&sample);
        if best.as_ref().map(|(_, s)| score > *s).unwrap_or(true) {
            best = Some((sample, score));
        }
    }
    best.expect("iterations > 0")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_stay_in_bounds() {
        let space = ppo_space();
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            for d in &space {
                let v = d.sample(&mut rng);
                match d {
                    Dim::LogUniform { lo, hi, .. } | Dim::Uniform { lo, hi, .. } => {
                        assert!(v >= *lo && v <= *hi);
                    }
                    Dim::Choice { options, .. } => assert!(options.contains(&v)),
                }
            }
        }
    }

    #[test]
    fn search_finds_the_obvious_optimum() {
        // score = -(lr - 1e-3)^2 → best sample should be near 1e-3.
        let space = vec![Dim::LogUniform { name: "lr", lo: 1e-5, hi: 1e-1 }];
        let (best, score) =
            random_search(&space, 64, 7, |s| -((s.get("lr") - 1e-3).powi(2)));
        assert!(score <= 0.0);
        assert!(best.get("lr") > 1e-4 && best.get("lr") < 1e-2, "lr {}", best.get("lr"));
    }

    #[test]
    fn sample_accessors() {
        let s = Sample { values: vec![("epochs".into(), 4.0)] };
        assert_eq!(s.get_usize("epochs"), 4);
        assert_eq!(s.get_f32("epochs"), 4.0);
    }
}
