//! Uniform replay buffer for the off-policy baselines (DQN, SAC).
//!
//! Observations are stored in their compact symbolic i32-as-u8 form
//! (every channel value is ≤ 10), which keeps a 100k-transition buffer for
//! 7×7×3 views under 30 MB — the trick that lets the Fig.-7 baselines run
//! beside 2ⁿ-env throughput sweeps on one box.

use crate::rng::Rng;

/// One sampled minibatch (flattened, row-major).
pub struct Batch {
    pub obs: Vec<f32>,
    pub actions: Vec<u8>,
    pub rewards: Vec<f32>,
    pub next_obs: Vec<f32>,
    /// 0.0 where the transition terminated, 1.0 otherwise.
    pub nonterminal: Vec<f32>,
}

/// Fixed-capacity ring buffer of transitions.
#[derive(Clone)]
pub struct Replay {
    capacity: usize,
    obs_dim: usize,
    obs: Vec<u8>,
    next_obs: Vec<u8>,
    actions: Vec<u8>,
    rewards: Vec<f32>,
    nonterminal: Vec<f32>,
    len: usize,
    head: usize,
}

impl Replay {
    pub fn new(capacity: usize, obs_dim: usize) -> Replay {
        Replay {
            capacity,
            obs_dim,
            obs: vec![0; capacity * obs_dim],
            next_obs: vec![0; capacity * obs_dim],
            actions: vec![0; capacity],
            rewards: vec![0.0; capacity],
            nonterminal: vec![0.0; capacity],
            len: 0,
            head: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Push one transition (symbolic i32 observations are compacted to u8).
    pub fn push(&mut self, obs: &[i32], action: u8, reward: f32, next_obs: &[i32], terminated: bool) {
        debug_assert_eq!(obs.len(), self.obs_dim);
        let at = self.head;
        for (dst, &src) in
            self.obs[at * self.obs_dim..(at + 1) * self.obs_dim].iter_mut().zip(obs)
        {
            *dst = src.clamp(0, 255) as u8;
        }
        for (dst, &src) in
            self.next_obs[at * self.obs_dim..(at + 1) * self.obs_dim].iter_mut().zip(next_obs)
        {
            *dst = src.clamp(0, 255) as u8;
        }
        self.actions[at] = action;
        self.rewards[at] = reward;
        self.nonterminal[at] = if terminated { 0.0 } else { 1.0 };
        self.head = (self.head + 1) % self.capacity;
        self.len = (self.len + 1).min(self.capacity);
    }

    /// Sample a uniform minibatch (with replacement), normalising
    /// observations the same way the on-policy path does.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Batch {
        assert!(self.len > 0, "sampling from empty replay");
        let d = self.obs_dim;
        let mut batch = Batch {
            obs: vec![0.0; n * d],
            actions: vec![0; n],
            rewards: vec![0.0; n],
            next_obs: vec![0.0; n * d],
            nonterminal: vec![0.0; n],
        };
        for k in 0..n {
            let i = rng.below(self.len as u32) as usize;
            for j in 0..d {
                batch.obs[k * d + j] = self.obs[i * d + j] as f32 / 10.0;
                batch.next_obs[k * d + j] = self.next_obs[i * d + j] as f32 / 10.0;
            }
            batch.actions[k] = self.actions[i];
            batch.rewards[k] = self.rewards[i];
            batch.nonterminal[k] = self.nonterminal[i];
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_buffer_wraps() {
        let mut r = Replay::new(4, 2);
        for i in 0..6 {
            r.push(&[i, i], i as u8, i as f32, &[i + 1, i + 1], false);
        }
        assert_eq!(r.len(), 4);
        // oldest two (0,1) evicted; sampling only sees 2..=5
        let mut rng = Rng::new(0);
        let b = r.sample(64, &mut rng);
        assert!(b.actions.iter().all(|&a| a >= 2));
    }

    #[test]
    fn sample_round_trips_values() {
        let mut r = Replay::new(8, 3);
        r.push(&[10, 5, 0], 3, -1.0, &[1, 1, 1], true);
        let mut rng = Rng::new(0);
        let b = r.sample(4, &mut rng);
        for k in 0..4 {
            assert_eq!(b.actions[k], 3);
            assert_eq!(b.rewards[k], -1.0);
            assert_eq!(b.nonterminal[k], 0.0);
            assert_eq!(&b.obs[k * 3..k * 3 + 3], &[1.0, 0.5, 0.0]);
        }
    }
}
