//! Sequenced-mission families: the first layouts whose missions are
//! multi-clause [`MissionSpec`](crate::core::mission::MissionSpec)s rather
//! than a single packed goal. Both reward/terminate on `mission_complete`
//! — the latch the clause-advance machinery fires when the *final* clause
//! completes — so mid-sequence progress (`door_opened`) never ends an
//! episode.
//!
//! * `SeqUnlockPickup` — the Unlock geometry (two rooms, locked door, key
//!   on the agent's side, box in the far room) with the explicit two-step
//!   instruction "open the <c> door, then pick up the <c'> box". Unlike
//!   classic UnlockPickup, picking the box before the door clause has
//!   completed pays nothing.
//! * `OpenDoorsOrder` — one room, two closed doors of distinct colours in
//!   the outer wall; "open the <c1> door, then open the <c2> door". Order
//!   matters: opening the second door while the first clause is active
//!   advances nothing (the active clause's colour does not match).

use super::roomgrid::RoomGrid;
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::Tag;
use crate::core::grid::Pos;
use crate::core::mission::{MissionClause, MissionSpec};
use crate::core::state::{PlacementError, SlotMut};

/// MiniGrid `room_size` for SeqUnlockPickup (same footprint as Unlock).
pub const ROOM_SIZE: usize = 6;

/// SeqUnlockPickup grid dims (one row of two `ROOM_SIZE` rooms): 6×11.
pub fn seq_unlock_pickup_dims() -> (usize, usize) {
    RoomGrid::new(ROOM_SIZE, 1, 2).dims()
}

/// SeqUnlockPickup: Unlock geometry + a 2-clause mission
/// `Open(door colour) then PickUp(box colour)`.
pub fn seq_unlock_pickup(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    let rg = RoomGrid::new(ROOM_SIZE, 1, 2);
    rg.carve(s);

    let (door_ci, box_ci) = {
        let mut rng = s.rng();
        (rng.below(6) as u8, rng.below(6) as u8)
    };
    let door_color = Color::from_u8(door_ci);
    let box_color = Color::from_u8(box_ci);
    rg.add_door(s, 0, 0, Direction::East, door_color, DoorState::Locked);

    // Key in the left (agent) room, box in the far room.
    let key_p = rg.place_in_room(s, 0, 0, false)?;
    s.add_key(key_p, door_color);
    let box_p = rg.place_in_room(s, 0, 1, false)?;
    s.add_box(box_p, box_color);

    s.set_mission_spec(MissionSpec::then(
        MissionClause::Open { color: door_color },
        MissionClause::PickUp { kind: Tag::BOX, color: box_color },
    ));
    rg.place_agent(s, 0, 0)?;
    Ok(())
}

/// OpenDoorsOrder: `n`×`n` room, two doors, ordered 2-clause open mission.
pub fn open_doors_order(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);

    // Two distinct colours for the two doors.
    let mut colors = Color::ALL;
    {
        let mut rng = s.rng();
        for i in (1..colors.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            colors.swap(i, j);
        }
    }

    // One door in the top wall, one in the right wall (non-corner cells),
    // mirroring the GoToDoor outer-wall convention.
    let (o_top, o_right) = {
        let mut rng = s.rng();
        (rng.randint(1, w - 1), rng.randint(1, h - 1))
    };
    s.add_door(Pos::new(0, o_top), colors[0], DoorState::Closed);
    s.add_door(Pos::new(o_right, w - 1), colors[1], DoorState::Closed);

    // Random agent pose; the mission orders the two doors randomly.
    s.place_player(Pos::new(1, 1), Direction::East);
    let p = s.sample_free_cell(false)?;
    let (dir, first) = {
        let mut rng = s.rng();
        (rng.randint(0, 4), rng.below(2) as usize)
    };
    s.place_player(p, Direction::from_i32(dir));
    s.set_mission_spec(MissionSpec::then(
        MissionClause::Open { color: colors[first] },
        MissionClause::Open { color: colors[1 - first] },
    ));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::core::components::Pocket;
    use crate::core::mission::{Mission, MissionVerb};
    use crate::core::state::AgentView;
    use crate::envs::registry::make;
    use crate::envs::testutil::{reachable, reset_once};
    use crate::systems::intervention::intervene;

    #[test]
    fn seq_unlock_pickup_layout_and_two_clause_mission() {
        let cfg = make("Navix-SeqUnlockPickup-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let spec = s.mission_spec();
            assert_eq!(spec.len(), 2, "seed {seed}: two clauses");
            assert!(
                matches!(spec.clause(0), Some(MissionClause::Open { .. })),
                "seed {seed}: clause 1 opens the door"
            );
            assert!(
                matches!(spec.clause(1), Some(MissionClause::PickUp { kind: Tag::BOX, .. })),
                "seed {seed}: clause 2 picks the box"
            );
            // The packed column mirrors the *active* (first) clause.
            assert_eq!(
                s.mission_value().verb(),
                Some(MissionVerb::Open),
                "seed {seed}: packed mission must be the active clause"
            );
            assert_eq!(s.key_color[0], s.door_color[0], "seed {seed}: key opens the door");
            let door = Pos::decode(s.door_pos[0], s.w);
            let bx = Pos::decode(s.box_pos[0], s.w);
            assert!(bx.c > door.c, "seed {seed}: box in the far room");
            assert!(!reachable(&st, 0, bx, false), "seed {seed}: box gated by the door");
            assert!(reachable(&st, 0, bx, true), "seed {seed}: box reachable through doors");
        }
    }

    #[test]
    fn seq_unlock_pickup_completes_clause_by_clause() {
        let cfg = make("Navix-SeqUnlockPickup-v0").unwrap();
        let mut st = reset_once(&cfg, 5);
        let mut s = st.slot_mut(0);
        let door = Pos::decode(s.door_pos[0], s.w);
        let door_color = Color::from_u8(s.door_color[0]);
        let box_color = Color::from_u8(s.box_color[0]);
        // Premature box pickup pays nothing: the active clause is Open.
        let bx = Pos::decode(s.box_pos[0], s.w);
        s.place_player(Pos::new(bx.r, bx.c - 1), Direction::East);
        intervene(&mut s, Action::Pickup);
        assert!(!s.events[0].object_picked, "pickup under an Open clause is not the target");
        assert!(!s.events[0].mission_complete);
        // Put the box back and run the intended order.
        intervene(&mut s, Action::Drop);
        s.remove_key(0);
        s.pocket[0] = Pocket::holding(Tag::KEY, door_color).0;
        s.place_player(Pos::new(door.r, door.c - 1), Direction::East);
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_unlocked && s.events[0].door_opened);
        assert!(!s.events[0].mission_complete, "clause 1 alone must not complete");
        assert_eq!(
            s.mission_value(),
            Mission::pick_up(Tag::BOX, box_color),
            "packed mission must advance to clause 2"
        );
        drop(s);
        assert!(!cfg.termination.eval(&st.slot(0)), "mid-sequence progress never terminates");
        let mut s = st.slot_mut(0);
        s.pocket[0] = Pocket::EMPTY.0;
        let bx = Pos::decode(s.box_pos[0], s.w);
        s.place_player(Pos::new(bx.r, bx.c - 1), Direction::East);
        intervene(&mut s, Action::Pickup);
        assert!(s.events[0].object_picked && s.events[0].mission_complete);
        drop(s);
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Pickup, cfg.max_steps), 1.0);
    }

    #[test]
    fn open_doors_order_layout_orders_two_distinct_doors() {
        let cfg = make("Navix-OpenDoorsOrder-6x6-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            assert_ne!(s.door_color[0], s.door_color[1], "seed {seed}: distinct colours");
            let spec = s.mission_spec();
            assert_eq!(spec.len(), 2, "seed {seed}");
            let clause_colors: Vec<u8> = (0..2)
                .map(|c| match spec.clause(c) {
                    Some(MissionClause::Open { color }) => color as u8,
                    other => panic!("seed {seed}: clause {c} must be Open, got {other:?}"),
                })
                .collect();
            let mut door_colors = vec![s.door_color[0], s.door_color[1]];
            door_colors.sort_unstable();
            let mut sorted = clause_colors.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, door_colors, "seed {seed}: clauses name the two doors");
        }
    }

    /// Face the slot-`d` door from inside the room.
    fn face_door(s: &mut SlotMut<'_>, d: usize) {
        let p = Pos::decode(s.door_pos[d], s.w);
        let (h, w) = (s.h as i32, s.w as i32);
        let (stand, dir) = if p.r == 0 {
            (Pos::new(1, p.c), Direction::North)
        } else if p.r == h - 1 {
            (Pos::new(h - 2, p.c), Direction::South)
        } else if p.c == 0 {
            (Pos::new(p.r, 1), Direction::West)
        } else {
            (Pos::new(p.r, w - 2), Direction::East)
        };
        s.place_player(stand, dir);
    }

    #[test]
    fn open_doors_order_enforces_the_order() {
        let cfg = make("Navix-OpenDoorsOrder-6x6-v0").unwrap();
        let mut st = reset_once(&cfg, 7);
        let mut s = st.slot_mut(0);
        let first_color = s.mission_value().color() as u8;
        let first = (0..2).find(|&d| s.door_color[d] == first_color).unwrap();
        let second = 1 - first;
        // Wrong order: the clause-2 door opens but nothing advances.
        face_door(&mut s, second);
        intervene(&mut s, Action::Toggle);
        assert!(!s.events[0].door_opened, "wrong-colour open must not latch");
        assert_eq!(s.mission_value().color() as u8, first_color, "clause must not advance");
        // Close it again (toggle an open door) and run the right order.
        intervene(&mut s, Action::Toggle);
        face_door(&mut s, first);
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_opened);
        assert!(!s.events[0].mission_complete);
        assert_eq!(
            s.mission_value().color() as u8,
            s.door_color[second],
            "clause 2 becomes active"
        );
        face_door(&mut s, second);
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].mission_complete, "ordered opens complete the mission");
        drop(s);
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Toggle, cfg.max_steps), 1.0);
    }
}
