//! GoToDoor-NxN: four doors of distinct random colours, one per wall; the
//! mission is to reach the door of the mission colour and perform `done`
//! in front of it (paper Tables 5/6: `on_door_done`).

use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::Tag;
use crate::core::grid::Pos;
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

pub fn generate(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);

    // Four distinct colours.
    let mut colors = Color::ALL;
    {
        let mut rng = s.rng();
        for i in (1..colors.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            colors.swap(i, j);
        }
    }

    // One door per wall at a random offset (doors sit in the outer wall).
    let (o_top, o_bottom, o_left, o_right) = {
        let mut rng = s.rng();
        (rng.randint(1, w - 1), rng.randint(1, w - 1), rng.randint(1, h - 1), rng.randint(1, h - 1))
    };
    s.add_door(Pos::new(0, o_top), colors[0], DoorState::Closed);
    s.add_door(Pos::new(h - 1, o_bottom), colors[1], DoorState::Closed);
    s.add_door(Pos::new(o_left, 0), colors[2], DoorState::Closed);
    s.add_door(Pos::new(o_right, w - 1), colors[3], DoorState::Closed);

    // Random agent pose; mission = one of the four door colours.
    s.place_player(Pos::new(1, 1), Direction::East);
    let p = s.sample_free_cell(false)?;
    let (dir, target) = {
        let mut rng = s.rng();
        (rng.randint(0, 4), rng.below(4) as usize)
    };
    s.place_player(p, Direction::from_i32(dir));
    s.set_mission(Mission::go_to(Tag::DOOR, colors[target]));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::envs::registry::make;
    use crate::envs::testutil::reset_once;
    use crate::systems::intervention::intervene;

    #[test]
    fn four_distinct_door_colors_on_four_walls() {
        let cfg = make("Navix-GoToDoor-8x8-v0")
            .expect("registry should know Navix-GoToDoor-8x8-v0");
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let placed: Vec<usize> =
                (0..4).filter(|&d| s.door_pos[d] >= 0).collect();
            assert_eq!(placed.len(), 4, "seed {seed}");
            let mut cols: Vec<u8> = (0..4).map(|d| s.door_color[d]).collect();
            cols.sort_unstable();
            cols.dedup();
            assert_eq!(cols.len(), 4, "seed {seed}: colours must be distinct");
            // each door on the border
            for d in 0..4 {
                let p = Pos::decode(s.door_pos[d], s.w);
                let border = p.r == 0
                    || p.c == 0
                    || p.r == s.h as i32 - 1
                    || p.c == s.w as i32 - 1;
                assert!(border, "seed {seed}: door {d} not on a wall");
            }
        }
    }

    #[test]
    fn mission_matches_an_existing_door() {
        let cfg = make("Navix-GoToDoor-5x5-v0")
            .expect("registry should know Navix-GoToDoor-5x5-v0");
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let m = s.mission_value();
            let mission_color = m.color() as u8;
            assert_eq!(m.kind_tag(), Tag::DOOR);
            assert!(
                (0..4).any(|d| s.door_color[d] == mission_color),
                "seed {seed}: mission colour has no door"
            );
        }
    }

    #[test]
    fn done_before_mission_door_succeeds() {
        let cfg = make("Navix-GoToDoor-6x6-v0")
            .expect("registry should know Navix-GoToDoor-6x6-v0");
        let mut st = reset_once(&cfg, 3);
        // Teleport the agent in front of the mission door for the check.
        let (door_p, _mission) = {
            let s = st.slot(0);
            let mc = s.mission_value().color() as u8;
            let d = (0..4).find(|&d| s.door_color[d] == mc).unwrap();
            (Pos::decode(s.door_pos[d], s.w), s.mission)
        };
        let mut s = st.slot_mut(0);
        // stand on the interior cell adjacent to the door, facing it
        let (h, w) = (s.h as i32, s.w as i32);
        let (stand, dir) = if door_p.r == 0 {
            (Pos::new(1, door_p.c), Direction::North)
        } else if door_p.r == h - 1 {
            (Pos::new(h - 2, door_p.c), Direction::South)
        } else if door_p.c == 0 {
            (Pos::new(door_p.r, 1), Direction::West)
        } else {
            (Pos::new(door_p.r, w - 2), Direction::East)
        };
        s.place_player(stand, dir);
        intervene(&mut s, Action::Done);
        assert!(s.events[0].door_done);
        // wrong door: no event
        let other = (0..4)
            .find(|&d| {
                s.door_color[d] != s.mission_value().color() as u8 && s.door_pos[d] >= 0
            })
            .unwrap();
        let p = Pos::decode(s.door_pos[other], s.w);
        let (stand, dir) = if p.r == 0 {
            (Pos::new(1, p.c), Direction::North)
        } else if p.r == h - 1 {
            (Pos::new(h - 2, p.c), Direction::South)
        } else if p.c == 0 {
            (Pos::new(p.r, 1), Direction::West)
        } else {
            (Pos::new(p.r, w - 2), Direction::East)
        };
        s.place_player(stand, dir);
        intervene(&mut s, Action::Done);
        assert!(!s.events[0].door_done);
    }
}
