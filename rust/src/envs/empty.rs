//! Empty-NxN (+Random variants): an empty room with a goal in the
//! bottom-right corner. The canonical MiniGrid sanity-check environment and
//! the flagship of every throughput experiment in the paper (Figs. 4–6).

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Build the layout. `random_start`: sample the agent pose (the `-Random-`
/// ids); otherwise the MiniGrid default pose (top-left, facing east).
pub fn generate(s: &mut SlotMut<'_>, random_start: bool) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    s.set_cell(Pos::new(h - 2, w - 2), CellType::Goal, Color::Green);
    if random_start {
        s.place_player(Pos::new(1, 1), Direction::East); // so sample avoids nothing
        // the goal cell is not floor, so the sample can never land on it
        let p = s.sample_free_cell(false)?;
        let dir = Direction::from_i32({
            let mut rng = s.rng();
            rng.randint(0, 4)
        });
        s.place_player(p, dir);
    } else {
        s.place_player(Pos::new(1, 1), Direction::East);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn fixed_start_layout() {
        let cfg = make("Navix-Empty-8x8-v0").unwrap();
        let st = reset_once(&cfg, 0);
        let s = st.slot(0);
        assert_eq!(s.player(), Pos::new(1, 1));
        assert_eq!(s.dir(), Direction::East);
        assert_eq!(goal_pos(&st, 0), Some(Pos::new(6, 6)));
        assert!(reachable(&st, 0, Pos::new(6, 6), false));
    }

    #[test]
    fn random_start_varies_and_avoids_goal() {
        let cfg = make("Navix-Empty-Random-6x6").unwrap();
        let mut poses = std::collections::HashSet::new();
        for seed in 0..40 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let p = s.player();
            assert_ne!(Some(p), goal_pos(&st, 0));
            assert_eq!(s.cell(p), CellType::Floor);
            poses.insert((p.r, p.c, s.player_dir[0]));
        }
        assert!(poses.len() > 5, "random starts should vary: got {}", poses.len());
    }

    #[test]
    fn all_sizes_goal_reachable() {
        for id in
            ["Navix-Empty-5x5-v0", "Navix-Empty-6x6-v0", "Navix-Empty-8x8-v0", "Navix-Empty-16x16-v0"]
        {
            let cfg = make(id).unwrap();
            let st = reset_once(&cfg, 3);
            let goal = goal_pos(&st, 0).expect("Empty always has a goal");
            assert!(reachable(&st, 0, goal, false), "{id} unsolvable");
        }
    }
}
