//! LockedRoom: a 19×19 grid with a central vertical corridor and three
//! rooms on each side. One room is locked and holds the goal; the key to it
//! lies in one of the other rooms; each of the six doors has a distinct
//! colour (MiniGrid's `LockedRoomEnv`). Success is reaching the goal.

use super::roomgrid::set_door;
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Canonical grid edge.
pub const SIZE: usize = 19;

/// Interior rectangle `(r0, c0, r1, c1)` of room `k` (0..6): rooms 0/2/4 on
/// the left of the corridor, 1/3/5 on the right, top to bottom.
fn room_rect(k: usize, h: i32, lw: i32, rw: i32, w: i32) -> (i32, i32, i32, i32) {
    let band = (k / 2) as i32;
    let j = band * (h / 3);
    let (r0, r1) = (j + 1, j + h / 3);
    if k % 2 == 0 {
        (r0, 1, r1, lw)
    } else {
        (r0, rw + 1, r1, w - 1)
    }
}

/// Door cell of room `k` (on its corridor-side wall).
fn door_cell(k: usize, h: i32, lw: i32, rw: i32) -> Pos {
    let band = (k / 2) as i32;
    let j = band * (h / 3);
    Pos::new(j + 3, if k % 2 == 0 { lw } else { rw })
}

pub fn generate(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    let (h, w) = (s.h as i32, s.w as i32);
    let lw = w / 2 - 2;
    let rw = w / 2 + 2;

    s.fill_room();
    // Corridor walls (full height) and the three room-splitting wall bands.
    for r in 1..h - 1 {
        s.set_cell(Pos::new(r, lw), CellType::Wall, Color::Grey);
        s.set_cell(Pos::new(r, rw), CellType::Wall, Color::Grey);
    }
    for band in 1..3 {
        let j = band * (h / 3);
        for c in 1..lw {
            s.set_cell(Pos::new(j, c), CellType::Wall, Color::Grey);
        }
        for c in rw + 1..w - 1 {
            s.set_cell(Pos::new(j, c), CellType::Wall, Color::Grey);
        }
    }

    // Locked room, shuffled door colours, key room ≠ locked room.
    let mut colors = Color::ALL;
    let (locked, key_room) = {
        let mut rng = s.rng();
        for i in (1..colors.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            colors.swap(i, j);
        }
        let locked = rng.below(6) as usize;
        let key_room = (locked + 1 + rng.below(5) as usize) % 6;
        (locked, key_room)
    };

    for k in 0..6 {
        let state = if k == locked { DoorState::Locked } else { DoorState::Closed };
        set_door(s, door_cell(k, h, lw, rw), colors[k], state);
    }

    // Goal inside the locked room, key (of the locked door's colour) inside
    // the key room.
    let (r0, c0, r1, c1) = room_rect(locked, h, lw, rw, w);
    let goal = s.sample_free_in(r0, c0, r1, c1, false)?;
    s.set_cell(goal, CellType::Goal, Color::Green);
    let (r0, c0, r1, c1) = room_rect(key_room, h, lw, rw, w);
    let key_p = s.sample_free_in(r0, c0, r1, c1, false)?;
    s.add_key(key_p, colors[locked]);

    // Agent somewhere in the corridor, random facing.
    let agent = s.sample_free_in(1, lw + 1, h - 1, rw, false)?;
    let dir = {
        let mut rng = s.rng();
        rng.randint(0, 4)
    };
    s.place_player(agent, Direction::from_i32(dir));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn six_distinct_doors_one_locked_with_matching_key() {
        let cfg = make("Navix-LockedRoom-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let placed: Vec<usize> = (0..6).filter(|&d| s.door_pos[d] >= 0).collect();
            assert_eq!(placed.len(), 6, "seed {seed}");
            let mut cols: Vec<u8> = (0..6).map(|d| s.door_color[d]).collect();
            cols.sort_unstable();
            cols.dedup();
            assert_eq!(cols.len(), 6, "seed {seed}: door colours must be distinct");
            let locked: Vec<usize> = (0..6)
                .filter(|&d| DoorState::from_u8(s.door_state[d]) == DoorState::Locked)
                .collect();
            assert_eq!(locked.len(), 1, "seed {seed}: exactly one locked door");
            assert_eq!(
                s.key_color[0], s.door_color[locked[0]],
                "seed {seed}: key opens the locked door"
            );
        }
    }

    #[test]
    fn goal_is_behind_the_locked_door_key_is_not() {
        let cfg = make("Navix-LockedRoom-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let goal = goal_pos(&st, 0).expect("LockedRoom has a goal");
            let key = Pos::decode(s.key_pos[0], s.w);
            assert!(reachable(&st, 0, goal, true), "seed {seed}: goal unreachable topologically");
            assert!(reachable(&st, 0, key, true), "seed {seed}: key unreachable topologically");
            // The goal room is locked: not freely reachable from the corridor.
            assert!(!reachable(&st, 0, goal, false), "seed {seed}: locked room is open");
        }
    }

    #[test]
    fn agent_starts_in_the_corridor() {
        let cfg = make("Navix-LockedRoom-v0").unwrap();
        let (lw, rw) = (SIZE as i32 / 2 - 2, SIZE as i32 / 2 + 2);
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let p = st.slot(0).player();
            assert!(p.c > lw && p.c < rw, "seed {seed}: agent at {p:?} not in the corridor");
        }
    }
}
