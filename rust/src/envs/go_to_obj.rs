//! GoToObj-{N}x{N}-N{k}: an empty room scattered with `k` objects of
//! *distinct* kind×colour (keys, balls, boxes); the mission is to reach the
//! target object and declare `done` facing it (BabyAI's GoToObj / MiniGrid's
//! GoToObject, expressed through the typed [`Mission`] go-to verb and the
//! `object_reached` event).

use crate::core::components::{Color, Direction};
use crate::core::entities::Tag;
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

const KINDS: [i32; 3] = [Tag::KEY, Tag::BALL, Tag::BOX];
const COMBOS: u32 = (KINDS.len() * 6) as u32;

/// Draw a `(kind tag, colour)` pair not yet in `placed`, from the env's own
/// RNG stream (pure function of the episode key → shard-invariant).
/// Rejection sampling first; a deterministic wrap-around sweep over the 18
/// combos (RNG-derived start, like `sample_free_in`'s crowded fallback)
/// guarantees termination without biasing toward (key, red).
pub(crate) fn sample_distinct_object(s: &mut SlotMut<'_>, placed: &[(i32, u8)]) -> (i32, u8) {
    debug_assert!(placed.len() < COMBOS as usize);
    for _ in 0..32 {
        let (k, ci) = {
            let mut rng = s.rng();
            (rng.below(KINDS.len() as u32) as usize, rng.below(6) as u8)
        };
        if !placed.contains(&(KINDS[k], ci)) {
            return (KINDS[k], ci);
        }
    }
    let start = {
        let mut rng = s.rng();
        rng.below(COMBOS)
    };
    for j in 0..COMBOS {
        let idx = ((start + j) % COMBOS) as usize;
        let cand = (KINDS[idx / 6], (idx % 6) as u8);
        if !placed.contains(&cand) {
            return cand;
        }
    }
    unreachable!("fewer than {COMBOS} objects placed")
}

/// Place `n_objs` distinct objects on free cells and return their
/// `(kind tag, colour)` list (shared with the PutNext generator).
pub(crate) fn place_distinct_objects(
    s: &mut SlotMut<'_>,
    n_objs: usize,
) -> Result<Vec<(i32, u8)>, PlacementError> {
    let mut placed: Vec<(i32, u8)> = Vec::with_capacity(n_objs);
    for _ in 0..n_objs {
        let (tag, ci) = sample_distinct_object(s, &placed);
        let p = s.sample_free_cell(false)?;
        match tag {
            Tag::KEY => {
                s.add_key(p, Color::from_u8(ci));
            }
            Tag::BALL => {
                s.add_ball(p, Color::from_u8(ci));
            }
            _ => {
                s.add_box(p, Color::from_u8(ci));
            }
        }
        placed.push((tag, ci));
    }
    Ok(placed)
}

pub fn generate(s: &mut SlotMut<'_>, n_objs: usize) -> Result<(), PlacementError> {
    s.fill_room();
    let placed = place_distinct_objects(s, n_objs)?;

    // Mission: go to one of the placed objects, chosen uniformly.
    // Distinctness makes the instruction unambiguous.
    let target = {
        let mut rng = s.rng();
        rng.below(n_objs as u32) as usize
    };
    let (tag, ci) = placed[target];
    s.set_mission(Mission::go_to(tag, Color::from_u8(ci)));

    let agent = s.sample_free_cell(false)?;
    let dir = {
        let mut rng = s.rng();
        rng.randint(0, 4)
    };
    s.place_player(agent, Direction::from_i32(dir));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::mission::MissionVerb;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, object_exists, reset_once};

    #[test]
    fn mission_is_a_go_to_of_a_placed_object() {
        for id in ["Navix-GoToObj-6x6-N2-v0", "Navix-GoToObj-8x8-N2-v0", "Navix-GoToObj-8x8-N3-v0"]
        {
            let cfg = make(id).unwrap();
            for seed in 0..15 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                assert!(goal_pos(&st, 0).is_none(), "{id}: GoToObj is goal-less");
                let m = s.mission_value();
                assert_eq!(m.verb(), Some(MissionVerb::GoTo), "{id} seed {seed}");
                assert!(
                    object_exists(&s, m.kind_tag(), m.color() as u8),
                    "{id} seed {seed}: mission targets a missing object"
                );
            }
        }
    }

    #[test]
    fn objects_are_distinct_kind_colour_pairs() {
        let cfg = make("Navix-GoToObj-8x8-N3-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let mut objs: Vec<(i32, u8)> = Vec::new();
            for k in 0..s.key_pos.len() {
                if s.key_pos[k] >= 0 {
                    objs.push((Tag::KEY, s.key_color[k]));
                }
            }
            for b in 0..s.ball_pos.len() {
                if s.ball_pos[b] >= 0 {
                    objs.push((Tag::BALL, s.ball_color[b]));
                }
            }
            for b in 0..s.box_pos.len() {
                if s.box_pos[b] >= 0 {
                    objs.push((Tag::BOX, s.box_color[b]));
                }
            }
            assert_eq!(objs.len(), 3, "seed {seed}");
            objs.sort_unstable();
            objs.dedup();
            assert_eq!(objs.len(), 3, "seed {seed}: kind×colour pairs must be distinct");
        }
    }

    #[test]
    fn done_facing_the_target_terminates_with_reward() {
        use crate::core::actions::Action;
        use crate::core::grid::Pos;
        use crate::systems::intervention::intervene;
        // Deterministic construction (no seed hunting): one ball, one key,
        // mission = go to the ball.
        let cfg = make("Navix-GoToObj-6x6-N2-v0").unwrap();
        let mut st = crate::core::state::BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.add_ball(Pos::new(2, 3), Color::Blue);
        s.add_key(Pos::new(4, 4), Color::Red);
        s.set_mission(Mission::go_to(Tag::BALL, Color::Blue));
        s.place_player(Pos::new(2, 2), Direction::East); // facing the ball
        intervene(&mut s, Action::Done);
        assert!(s.events[0].object_reached);
        drop(s);
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Done, cfg.max_steps), 1.0);
        // facing the non-target key instead: nothing fires
        let mut s = st.slot_mut(0);
        s.place_player(Pos::new(4, 3), Direction::East);
        intervene(&mut s, Action::Done);
        assert!(!s.events[0].object_reached);
        drop(s);
        assert!(!cfg.termination.eval(&st.slot(0)));
    }
}
