//! FourRooms: the classic Sutton et al. options domain — four rooms joined
//! by gaps, random agent and goal (paper Table 8: 17×17, R1).

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

pub fn generate(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    let mid_r = h / 2;
    let mid_c = w / 2;

    // Dividing walls.
    for r in 1..h - 1 {
        s.set_cell(Pos::new(r, mid_c), CellType::Wall, Color::Grey);
    }
    for c in 1..w - 1 {
        s.set_cell(Pos::new(mid_r, c), CellType::Wall, Color::Grey);
    }

    // One gap per wall segment (four total), at random positions.
    let (g1, g2, g3, g4) = {
        let mut rng = s.rng();
        (
            rng.randint(1, mid_r),         // left vertical segment: gap row in top part? no: horizontal wall, left segment: gap col
            rng.randint(mid_c + 1, w - 1), // horizontal wall, right segment: gap col
            rng.randint(1, mid_r),         // vertical wall, top segment: gap row
            rng.randint(mid_r + 1, h - 1), // vertical wall, bottom segment: gap row
        )
    };
    s.set_cell(Pos::new(mid_r, g1.min(mid_c - 1).max(1)), CellType::Floor, Color::Grey);
    s.set_cell(Pos::new(mid_r, g2.min(w - 2)), CellType::Floor, Color::Grey);
    s.set_cell(Pos::new(g3.min(mid_r - 1).max(1), mid_c), CellType::Floor, Color::Grey);
    s.set_cell(Pos::new(g4.min(h - 2), mid_c), CellType::Floor, Color::Grey);

    // Random goal, then random agent avoiding the goal.
    s.place_player(Pos::new(1, 1), Direction::East);
    let goal = s.sample_free_cell(false)?;
    s.set_cell(goal, CellType::Goal, Color::Green);
    // the goal cell is no longer floor, so the agent sample can never hit it
    let agent = s.sample_free_cell(false)?;
    let dir = Direction::from_i32({
        let mut rng = s.rng();
        rng.randint(0, 4)
    });
    s.place_player(agent, dir);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn rooms_are_connected_and_solvable() {
        let cfg = make("Navix-FourRooms-v0").unwrap();
        for seed in 0..25 {
            let st = reset_once(&cfg, seed);
            let goal = goal_pos(&st, 0).expect("FourRooms has a goal");
            assert!(reachable(&st, 0, goal, false), "seed {seed}: goal unreachable");
        }
    }

    #[test]
    fn dividing_walls_exist() {
        let cfg = make("Navix-FourRooms-v0").unwrap();
        let st = reset_once(&cfg, 1);
        let s = st.slot(0);
        let (h, w) = (s.h as i32, s.w as i32);
        let mut wall_cells = 0;
        for r in 1..h - 1 {
            if s.cell(Pos::new(r, w / 2)) == CellType::Wall {
                wall_cells += 1;
            }
        }
        for c in 1..w - 1 {
            if s.cell(Pos::new(h / 2, c)) == CellType::Wall {
                wall_cells += 1;
            }
        }
        // 17x17: two 15-cell walls minus ≤5 gaps (4 gaps + crossing overlap)
        assert!(wall_cells >= 24, "only {wall_cells} wall cells on the dividers");
    }

    #[test]
    fn goal_and_agent_positions_vary() {
        let cfg = make("Navix-FourRooms-v0").unwrap();
        let mut goals = std::collections::HashSet::new();
        for seed in 0..20 {
            let st = reset_once(&cfg, seed);
            let g = goal_pos(&st, 0).expect("FourRooms has a goal");
            goals.insert((g.r, g.c));
        }
        assert!(goals.len() > 5, "goals should vary: {}", goals.len());
    }
}
