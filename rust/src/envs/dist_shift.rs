//! DistShift{1,2}: agent top-left, goal top-right, a lava strip between
//! them whose row differs between the two versions — the "distribution
//! shift" used for transfer studies (paper Table 8: 6×6 / 8×8, R2).

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// `strip_row`: the row of the lava strip (2 for DistShift1, 3 for
/// DistShift2 in this scaled layout).
pub fn generate(s: &mut SlotMut<'_>, strip_row: usize) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    let row = (strip_row as i32).min(h - 3);
    // Strip spans the middle columns, leaving the first and last interior
    // columns free so the task stays solvable by detouring below.
    for c in 2..w - 2 {
        s.set_cell(Pos::new(row, c), CellType::Lava, Color::Red);
    }
    s.set_cell(Pos::new(1, w - 2), CellType::Goal, Color::Green);
    s.place_player(Pos::new(1, 1), Direction::East);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn versions_shift_the_strip() {
        let c1 = make("Navix-DistShift1-v0").unwrap();
        let c2 = make("Navix-DistShift2-v0").unwrap();
        let s1 = reset_once(&c1, 0);
        let s2 = reset_once(&c2, 0);
        let row_of = |st: &crate::core::state::BatchedState| -> i32 {
            let s = st.slot(0);
            for r in 1..s.h as i32 - 1 {
                for c in 1..s.w as i32 - 1 {
                    if s.cell(Pos::new(r, c)) == CellType::Lava {
                        return r;
                    }
                }
            }
            -1
        };
        let (r1, r2) = (row_of(&s1), row_of(&s2));
        assert!(r1 > 0 && r2 > 0);
        assert_ne!(r1, r2, "the lava strip must shift between versions");
    }

    #[test]
    fn both_versions_solvable_avoiding_lava() {
        for id in ["Navix-DistShift1-v0", "Navix-DistShift2-v0"] {
            let cfg = make(id).unwrap();
            let st = reset_once(&cfg, 0);
            let goal = goal_pos(&st, 0).expect("DistShift has a goal");
            assert!(reachable(&st, 0, goal, false), "{id}");
            assert_eq!(goal, Pos::new(1, cfg.w as i32 - 2));
        }
    }
}
