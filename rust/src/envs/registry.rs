//! The environment registry: `make("Navix-...-v0")` string ids for every
//! Table-8 row, mirroring the Python API (`nx.make(...)`) including the
//! Appendix-C overrides (`make_with`).

use super::{EnvConfig, Layout};
use crate::core::state::Caps;
use crate::systems::observations::{ObsKind, ObsSpec};
use crate::systems::rewards::RewardSpec;
use crate::systems::terminations::TermSpec;
use anyhow::{anyhow, Result};

fn base(
    id: &str,
    h: usize,
    w: usize,
    caps: Caps,
    max_steps: u32,
    reward: RewardSpec,
    termination: TermSpec,
    layout: Layout,
) -> EnvConfig {
    EnvConfig {
        id: id.to_string(),
        h,
        w,
        caps,
        max_steps,
        obs: ObsSpec::new(ObsKind::SymbolicFirstPerson),
        reward,
        termination,
        stochastic_balls: matches!(layout, Layout::DynamicObstacles { .. }),
        n_agents: 1,
        layout,
    }
}

/// Multi-agent FourRooms race: two agents, first to the goal ends the slot
/// (the engine ORs terminations across a slot's agents) and only the
/// reaching agent's row pays.
fn ma_four_rooms_race(id: &str) -> EnvConfig {
    four_rooms(id).with_agents(2)
}

/// Cooperative PutNext: either agent completing the placement pays every
/// agent-row of the slot (team reward).
fn ma_put_next_coop(id: &str, n: usize, n_objs: usize) -> EnvConfig {
    put_next(id, n, n_objs).with_agents(2).with_reward(RewardSpec::team_object_placed())
}

/// Pursuit–evasion tag on the Dynamic-Obstacles grid: +1 for tagging,
/// −1 for being tagged (or hit by an obstacle); any contact ends the slot.
fn ma_tag(id: &str, n: usize) -> EnvConfig {
    dynamic_obstacles(id, n)
        .with_agents(2)
        .with_reward(RewardSpec::pursuit())
        .with_termination(TermSpec::pursuit())
}

fn empty(id: &str, n: usize, random: bool) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps::default(),
        (4 * n * n) as u32,
        RewardSpec::r1(),
        TermSpec::goal(),
        Layout::Empty { random_start: random },
    )
}

fn doorkey(id: &str, n: usize, random: bool) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps { doors: 1, keys: 1, ..Caps::default() },
        (10 * n * n) as u32,
        RewardSpec::r1(),
        TermSpec::goal(),
        Layout::DoorKey { random },
    )
}

fn key_corridor(id: &str, size: usize, rows: usize) -> EnvConfig {
    let (h, w) = super::key_corridor::dims(size, rows);
    base(
        id,
        h,
        w,
        Caps { doors: 2 * rows, keys: 1, balls: 1, ..Caps::default() },
        (10 * h * w) as u32,
        RewardSpec::ball_pickup(),
        TermSpec::ball_picked(),
        Layout::KeyCorridor { size, rows },
    )
}

fn lava_gap(id: &str, n: usize) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps::default(),
        (4 * n * n) as u32,
        RewardSpec::r2(),
        TermSpec::goal_or_lava(),
        Layout::LavaGap,
    )
}

fn crossings(id: &str, s: usize, n: usize, lava: bool) -> EnvConfig {
    base(
        id,
        s,
        s,
        Caps::default(),
        (4 * s * s) as u32,
        RewardSpec::r2(),
        TermSpec::goal_or_lava(),
        Layout::Crossings { n, lava },
    )
}

fn dynamic_obstacles(id: &str, n: usize) -> EnvConfig {
    let k = super::dynamic_obstacles::n_obstacles(n);
    base(
        id,
        n,
        n,
        Caps { balls: k, ..Caps::default() },
        (4 * n * n) as u32,
        RewardSpec::r3(),
        TermSpec::goal_or_ball_hit(),
        Layout::DynamicObstacles { n: k },
    )
}

fn dist_shift(id: &str, n: usize, strip_row: usize) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps::default(),
        (4 * n * n) as u32,
        RewardSpec::r2(),
        TermSpec::goal_or_lava(),
        Layout::DistShift { strip_row },
    )
}

fn go_to_door(id: &str, n: usize) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps { doors: 4, ..Caps::default() },
        (4 * n * n) as u32,
        RewardSpec::door_done(),
        TermSpec::door_done(),
        Layout::GoToDoor,
    )
}

fn four_rooms(id: &str) -> EnvConfig {
    base(
        id,
        17,
        17,
        Caps::default(),
        100,
        RewardSpec::r1(),
        TermSpec::goal(),
        Layout::FourRooms,
    )
}

fn multiroom(id: &str, n: usize, max_size: usize) -> EnvConfig {
    // MiniGrid: every MultiRoom id uses a 25×25 grid, T = 20·maxNumRooms.
    base(
        id,
        25,
        25,
        Caps { doors: n - 1, ..Caps::default() },
        (20 * n) as u32,
        RewardSpec::r1(),
        TermSpec::goal(),
        Layout::MultiRoom { n, max_size },
    )
}

fn unlock(id: &str) -> EnvConfig {
    let (h, w) = super::unlock::dims();
    // MiniGrid: T = 8·room_size².
    base(
        id,
        h,
        w,
        Caps { doors: 1, keys: 1, ..Caps::default() },
        (8 * super::unlock::ROOM_SIZE * super::unlock::ROOM_SIZE) as u32,
        RewardSpec::unlock(),
        TermSpec::door_unlocked(),
        Layout::Unlock,
    )
}

fn unlock_pickup(id: &str, blocked: bool) -> EnvConfig {
    let (h, w) = super::unlock::dims();
    let rs2 = super::unlock::ROOM_SIZE * super::unlock::ROOM_SIZE;
    // MiniGrid: T = 8·room_size² (16· for the blocked variant).
    let (max_steps, layout) = if blocked {
        (16 * rs2, Layout::BlockedUnlockPickup)
    } else {
        (8 * rs2, Layout::UnlockPickup)
    };
    base(
        id,
        h,
        w,
        Caps { doors: 1, keys: 1, balls: if blocked { 1 } else { 0 }, boxes: 1 },
        max_steps as u32,
        RewardSpec::object_pickup(),
        TermSpec::object_picked(),
        layout,
    )
}

fn locked_room(id: &str) -> EnvConfig {
    let n = super::locked_room::SIZE;
    // MiniGrid: T = 10·size².
    base(
        id,
        n,
        n,
        Caps { doors: 6, keys: 1, ..Caps::default() },
        (10 * n * n) as u32,
        RewardSpec::r1(),
        TermSpec::goal(),
        Layout::LockedRoom,
    )
}

fn go_to_obj(id: &str, n: usize, n_objs: usize) -> EnvConfig {
    // BabyAI GoToObj / MiniGrid GoToObject: `done` facing the mission
    // object; distinct kind x colour pairs keep the instruction unambiguous.
    base(
        id,
        n,
        n,
        Caps { keys: n_objs, balls: n_objs, boxes: n_objs, ..Caps::default() },
        (5 * n * n) as u32,
        RewardSpec::object_reached(),
        TermSpec::object_reached(),
        Layout::GoToObj { n_objs },
    )
}

fn put_next(id: &str, n: usize, n_objs: usize) -> EnvConfig {
    // BabyAI PutNext / MiniGrid PutNear: drop the mission object 4-adjacent
    // to the mission's second object.
    base(
        id,
        n,
        n,
        Caps { keys: n_objs, balls: n_objs, boxes: n_objs, ..Caps::default() },
        (5 * n * n) as u32,
        RewardSpec::object_placed(),
        TermSpec::object_placed(),
        Layout::PutNext { n_objs },
    )
}

fn fetch(id: &str, n: usize, n_objs: usize) -> EnvConfig {
    // MiniGrid: T = 5·size²; any pickup terminates, only the target pays.
    base(
        id,
        n,
        n,
        Caps { keys: n_objs, balls: n_objs, ..Caps::default() },
        (5 * n * n) as u32,
        RewardSpec::object_pickup(),
        TermSpec::fetch(),
        Layout::Fetch { n_objs },
    )
}

/// SeqUnlockPickup: Unlock geometry with the explicit 2-clause mission
/// "open the door, then pick up the box". Pays only on `mission_complete`
/// (the final clause), like all sequenced families.
fn seq_unlock_pickup(id: &str) -> EnvConfig {
    let (h, w) = super::sequenced::seq_unlock_pickup_dims();
    let rs2 = super::sequenced::ROOM_SIZE * super::sequenced::ROOM_SIZE;
    // Same T budget as BlockedUnlockPickup: two sub-goals, 16·room_size².
    base(
        id,
        h,
        w,
        Caps { doors: 1, keys: 1, boxes: 1, ..Caps::default() },
        (16 * rs2) as u32,
        RewardSpec::mission_complete(),
        TermSpec::mission_complete(),
        Layout::SeqUnlockPickup,
    )
}

/// OpenDoorsOrder: one room, two outer-wall doors, "open <c1> then <c2>".
fn open_doors_order(id: &str, n: usize) -> EnvConfig {
    base(
        id,
        n,
        n,
        Caps { doors: 2, ..Caps::default() },
        (8 * n * n) as u32,
        RewardSpec::mission_complete(),
        TermSpec::mission_complete(),
        Layout::OpenDoorsOrder,
    )
}

/// The curriculum chain (see [`super::curriculum`]): `level = None` is the
/// per-slot difficulty schedule; the `-L{k}-` aliases pin one level.
fn curriculum_room_grid(id: &str, level: Option<u8>) -> EnvConfig {
    let (h, w) = super::curriculum::dims();
    base(
        id,
        h,
        w,
        Caps { doors: 2, keys: 2, balls: 2, boxes: 1 },
        (8 * h * w) as u32,
        RewardSpec::mission_complete(),
        TermSpec::mission_complete(),
        Layout::CurriculumRoomGrid { level },
    )
}

/// All canonical environment ids (Table 8), in Table-7 benchmark order
/// first (x-ticks 0–29 of paper Fig. 3), then the Table-8 extras.
pub fn list_envs() -> Vec<&'static str> {
    vec![
        // Table 7 / Fig. 3 order (x-ticks 0..=29)
        "Navix-Empty-5x5-v0",
        "Navix-Empty-6x6-v0",
        "Navix-Empty-8x8-v0",
        "Navix-Empty-16x16-v0",
        "Navix-Empty-Random-5x5",
        "Navix-Empty-Random-6x6",
        "Navix-DoorKey-5x5-v0",
        "Navix-DoorKey-6x6-v0",
        "Navix-DoorKey-8x8-v0",
        "Navix-DoorKey-16x16-v0",
        "Navix-FourRooms-v0",
        "Navix-KeyCorridorS3R1-v0",
        "Navix-KeyCorridorS3R2-v0",
        "Navix-KeyCorridorS3R3-v0",
        "Navix-KeyCorridorS4R3-v0",
        "Navix-KeyCorridorS5R3-v0",
        "Navix-KeyCorridorS6R3-v0",
        "Navix-LavaGapS5-v0",
        "Navix-LavaGapS6-v0",
        "Navix-LavaGapS7-v0",
        "Navix-SimpleCrossingS9N1-v0",
        "Navix-SimpleCrossingS9N2-v0",
        "Navix-SimpleCrossingS9N3-v0",
        "Navix-SimpleCrossingS11N5-v0",
        "Navix-Dynamic-Obstacles-5x5",
        "Navix-Dynamic-Obstacles-6x6",
        "Navix-Dynamic-Obstacles-8x8",
        "Navix-Dynamic-Obstacles-16x16",
        "Navix-DistShift1-v0",
        "Navix-DistShift2-v0",
        // Table-8 extras
        "Navix-Empty-Random-8x8",
        "Navix-Empty-Random-16x16",
        "Navix-DoorKey-Random-5x5",
        "Navix-DoorKey-Random-6x6",
        "Navix-DoorKey-Random-8x8",
        "Navix-DoorKey-Random-16x16",
        "Navix-LavaCrossingS9N1-v0",
        "Navix-GoToDoor-5x5-v0",
        "Navix-GoToDoor-6x6-v0",
        "Navix-GoToDoor-8x8-v0",
        // RoomGrid / procedural-layout families
        "Navix-MultiRoom-N2-S4-v0",
        "Navix-MultiRoom-N4-S5-v0",
        "Navix-MultiRoom-N6-v0",
        "Navix-Unlock-v0",
        "Navix-UnlockPickup-v0",
        "Navix-BlockedUnlockPickup-v0",
        "Navix-LockedRoom-v0",
        "Navix-Fetch-5x5-N2-v0",
        "Navix-Fetch-8x8-N3-v0",
        // BabyAI-style goal-conditioned families (typed Mission subsystem)
        "Navix-GoToObj-6x6-N2-v0",
        "Navix-GoToObj-8x8-N2-v0",
        "Navix-GoToObj-8x8-N3-v0",
        "Navix-PutNext-6x6-N2-v0",
        "Navix-PutNext-8x8-N3-v0",
        // Multi-agent families (N agents per slot, appended so the Fig.-3
        // first-30 x-tick order above stays stable)
        "Navix-MA-FourRooms-Race-v0",
        "Navix-MA-PutNext-Coop-6x6-N2-v0",
        "Navix-MA-Tag-8x8-v0",
        // Sequenced-mission + curriculum families (compositional grammar;
        // the fixed-level `-L{0..3}-` curriculum ids are make()-only
        // aliases, not separate registry rows)
        "Navix-SeqUnlockPickup-v0",
        "Navix-OpenDoorsOrder-6x6-v0",
        "Navix-Curriculum-RoomGrid-v0",
    ]
}

/// The 30 Table-7 ids, in x-tick order (paper Figs. 3 and 8).
pub fn fig3_envs() -> Vec<&'static str> {
    list_envs()[..30].to_vec()
}

/// Instantiate an environment config by id. Accepts the canonical ids from
/// [`list_envs`] plus the Table-8 `Navix-Crossings-*` / `Navix-LavaGap-S*`
/// spelling aliases and the equivalent `MiniGrid-*` ids.
pub fn make(id: &str) -> Result<EnvConfig> {
    // Normalise aliases.
    let canonical = id
        .replace("MiniGrid-", "Navix-")
        .replace("Navix-Crossings-S", "Navix-SimpleCrossingS")
        .replace("Navix-LavaGap-S", "Navix-LavaGapS");
    let c = canonical.as_str();
    let cfg = match c {
        "Navix-Empty-5x5-v0" => empty(c, 5, false),
        "Navix-Empty-6x6-v0" => empty(c, 6, false),
        "Navix-Empty-8x8-v0" => empty(c, 8, false),
        "Navix-Empty-16x16-v0" => empty(c, 16, false),
        "Navix-Empty-Random-5x5" | "Navix-Empty-Random-5x5-v0" => empty(c, 5, true),
        "Navix-Empty-Random-6x6" | "Navix-Empty-Random-6x6-v0" => empty(c, 6, true),
        "Navix-Empty-Random-8x8" | "Navix-Empty-Random-8x8-v0" => empty(c, 8, true),
        "Navix-Empty-Random-16x16" | "Navix-Empty-Random-16x16-v0" => empty(c, 16, true),
        "Navix-DoorKey-5x5-v0" => doorkey(c, 5, false),
        "Navix-DoorKey-6x6-v0" => doorkey(c, 6, false),
        "Navix-DoorKey-8x8-v0" => doorkey(c, 8, false),
        "Navix-DoorKey-16x16-v0" => doorkey(c, 16, false),
        "Navix-DoorKey-Random-5x5" => doorkey(c, 5, true),
        "Navix-DoorKey-Random-6x6" => doorkey(c, 6, true),
        "Navix-DoorKey-Random-8x8" => doorkey(c, 8, true),
        "Navix-DoorKey-Random-16x16" => doorkey(c, 16, true),
        "Navix-FourRooms-v0" => four_rooms(c),
        "Navix-KeyCorridorS3R1-v0" => key_corridor(c, 3, 1),
        "Navix-KeyCorridorS3R2-v0" => key_corridor(c, 3, 2),
        "Navix-KeyCorridorS3R3-v0" => key_corridor(c, 3, 3),
        "Navix-KeyCorridorS4R3-v0" => key_corridor(c, 4, 3),
        "Navix-KeyCorridorS5R3-v0" => key_corridor(c, 5, 3),
        "Navix-KeyCorridorS6R3-v0" => key_corridor(c, 6, 3),
        "Navix-LavaGapS5-v0" => lava_gap(c, 5),
        "Navix-LavaGapS6-v0" => lava_gap(c, 6),
        "Navix-LavaGapS7-v0" => lava_gap(c, 7),
        "Navix-SimpleCrossingS9N1-v0" => crossings(c, 9, 1, false),
        "Navix-SimpleCrossingS9N2-v0" => crossings(c, 9, 2, false),
        "Navix-SimpleCrossingS9N3-v0" => crossings(c, 9, 3, false),
        "Navix-SimpleCrossingS11N5-v0" => crossings(c, 11, 5, false),
        "Navix-LavaCrossingS9N1-v0" => crossings(c, 9, 1, true),
        "Navix-Dynamic-Obstacles-5x5" | "Navix-Dynamic-Obstacles-5x5-v0" => {
            dynamic_obstacles(c, 5)
        }
        "Navix-Dynamic-Obstacles-6x6" | "Navix-Dynamic-Obstacles-6x6-v0" => {
            dynamic_obstacles(c, 6)
        }
        "Navix-Dynamic-Obstacles-8x8" | "Navix-Dynamic-Obstacles-8x8-v0" => {
            dynamic_obstacles(c, 8)
        }
        "Navix-Dynamic-Obstacles-16x16" | "Navix-Dynamic-Obstacles-16x16-v0" => {
            dynamic_obstacles(c, 16)
        }
        "Navix-DistShift1-v0" => dist_shift(c, 6, 2),
        "Navix-DistShift2-v0" => dist_shift(c, 8, 3),
        "Navix-GoToDoor-5x5-v0" => go_to_door(c, 5),
        "Navix-GoToDoor-6x6-v0" => go_to_door(c, 6),
        "Navix-GoToDoor-8x8-v0" => go_to_door(c, 8),
        "Navix-MultiRoom-N2-S4-v0" => multiroom(c, 2, 4),
        "Navix-MultiRoom-N4-S5-v0" => multiroom(c, 4, 5),
        "Navix-MultiRoom-N6-v0" => multiroom(c, 6, 10),
        "Navix-Unlock-v0" => unlock(c),
        "Navix-UnlockPickup-v0" => unlock_pickup(c, false),
        "Navix-BlockedUnlockPickup-v0" => unlock_pickup(c, true),
        "Navix-LockedRoom-v0" => locked_room(c),
        "Navix-Fetch-5x5-N2-v0" => fetch(c, 5, 2),
        "Navix-Fetch-8x8-N3-v0" => fetch(c, 8, 3),
        "Navix-GoToObj-6x6-N2-v0" => go_to_obj(c, 6, 2),
        "Navix-GoToObj-8x8-N2-v0" => go_to_obj(c, 8, 2),
        "Navix-GoToObj-8x8-N3-v0" => go_to_obj(c, 8, 3),
        "Navix-PutNext-6x6-N2-v0" => put_next(c, 6, 2),
        "Navix-PutNext-8x8-N3-v0" => put_next(c, 8, 3),
        "Navix-MA-FourRooms-Race-v0" => ma_four_rooms_race(c),
        "Navix-MA-PutNext-Coop-6x6-N2-v0" => ma_put_next_coop(c, 6, 2),
        "Navix-MA-Tag-8x8-v0" => ma_tag(c, 8),
        "Navix-SeqUnlockPickup-v0" => seq_unlock_pickup(c),
        "Navix-OpenDoorsOrder-6x6-v0" => open_doors_order(c, 6),
        "Navix-Curriculum-RoomGrid-v0" => curriculum_room_grid(c, None),
        "Navix-Curriculum-RoomGrid-L0-v0" => curriculum_room_grid(c, Some(0)),
        "Navix-Curriculum-RoomGrid-L1-v0" => curriculum_room_grid(c, Some(1)),
        "Navix-Curriculum-RoomGrid-L2-v0" => curriculum_room_grid(c, Some(2)),
        "Navix-Curriculum-RoomGrid-L3-v0" => curriculum_room_grid(c, Some(3)),
        _ => return Err(anyhow!("unknown environment id: {id}")),
    };
    Ok(cfg)
}

/// `make` with observation override (paper Appendix C `nx.make(id,
/// observation_fn=...)`).
pub fn make_with(id: &str, obs: ObsKind) -> Result<EnvConfig> {
    Ok(make(id)?.with_observation(obs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::testutil::reset_once;

    #[test]
    fn every_listed_env_instantiates_and_resets() {
        for id in list_envs() {
            let cfg = make(id).unwrap_or_else(|e| panic!("{id}: {e}"));
            assert_eq!(cfg.id, id.replace("MiniGrid-", "Navix-"));
            let st = reset_once(&cfg, 0);
            let s = st.slot(0);
            assert!(s.player().in_bounds(cfg.h, cfg.w), "{id}: player not placed");
        }
    }

    #[test]
    fn table8_dims() {
        let checks = [
            ("Navix-Empty-8x8-v0", 8, 8),
            ("Navix-DoorKey-16x16-v0", 16, 16),
            ("Navix-FourRooms-v0", 17, 17),
            ("Navix-KeyCorridorS3R1-v0", 3, 7),
            ("Navix-KeyCorridorS3R3-v0", 7, 7),
            ("Navix-KeyCorridorS6R3-v0", 16, 16),
            ("Navix-LavaGapS7-v0", 7, 7),
            ("Navix-SimpleCrossingS11N5-v0", 11, 11),
            ("Navix-DistShift1-v0", 6, 6),
            ("Navix-DistShift2-v0", 8, 8),
            ("Navix-GoToDoor-8x8-v0", 8, 8),
            ("Navix-MultiRoom-N6-v0", 25, 25),
            ("Navix-Unlock-v0", 6, 11),
            ("Navix-UnlockPickup-v0", 6, 11),
            ("Navix-BlockedUnlockPickup-v0", 6, 11),
            ("Navix-LockedRoom-v0", 19, 19),
            ("Navix-Fetch-8x8-N3-v0", 8, 8),
            ("Navix-GoToObj-8x8-N3-v0", 8, 8),
            ("Navix-PutNext-6x6-N2-v0", 6, 6),
        ];
        for (id, h, w) in checks {
            let cfg = make(id).unwrap();
            assert_eq!((cfg.h, cfg.w), (h, w), "{id}");
        }
    }

    #[test]
    fn aliases_resolve() {
        assert!(make("MiniGrid-Empty-8x8-v0").is_ok());
        assert!(make("Navix-Crossings-S9N1-v0").is_ok());
        assert!(make("Navix-LavaGap-S5-v0").is_ok());
        assert!(make("No-Such-Env").is_err());
    }

    #[test]
    fn fig3_list_has_30_ids() {
        assert_eq!(fig3_envs().len(), 30);
        assert_eq!(fig3_envs()[0], "Navix-Empty-5x5-v0");
        assert_eq!(fig3_envs()[29], "Navix-DistShift2-v0");
    }

    #[test]
    fn make_with_overrides_observation() {
        let cfg = make_with("Navix-Empty-8x8-v0", ObsKind::Rgb).unwrap();
        assert_eq!(cfg.obs.kind, ObsKind::Rgb);
    }

    #[test]
    fn reward_classes_match_table8() {
        assert_eq!(make("Navix-Empty-8x8-v0").unwrap().reward, RewardSpec::r1());
        assert_eq!(make("Navix-LavaGapS5-v0").unwrap().reward, RewardSpec::r2());
        assert_eq!(
            make("Navix-Dynamic-Obstacles-8x8").unwrap().reward,
            RewardSpec::r3()
        );
    }

    #[test]
    fn roomgrid_families_wire_mission_rewards_and_timeouts() {
        use crate::systems::terminations::TermSpec;
        let cfg = make("Navix-Unlock-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::unlock());
        assert_eq!(cfg.termination, TermSpec::door_unlocked());
        assert_eq!(cfg.max_steps, 288);
        let cfg = make("Navix-UnlockPickup-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::object_pickup());
        assert_eq!(cfg.termination, TermSpec::object_picked());
        assert_eq!(cfg.max_steps, 288);
        let cfg = make("Navix-BlockedUnlockPickup-v0").unwrap();
        assert_eq!(cfg.max_steps, 576);
        let cfg = make("Navix-Fetch-8x8-N3-v0").unwrap();
        assert_eq!(cfg.termination, TermSpec::fetch());
        assert_eq!(cfg.max_steps, 320);
        let cfg = make("Navix-MultiRoom-N4-S5-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::r1());
        assert_eq!(cfg.max_steps, 80);
        let cfg = make("Navix-LockedRoom-v0").unwrap();
        assert_eq!(cfg.termination, TermSpec::goal());
        assert_eq!(cfg.max_steps, 3610);
    }

    #[test]
    fn minigrid_aliases_cover_new_families() {
        assert!(make("MiniGrid-MultiRoom-N6-v0").is_ok());
        assert!(make("MiniGrid-BlockedUnlockPickup-v0").is_ok());
        assert!(make("MiniGrid-Fetch-8x8-N3-v0").is_ok());
        assert!(make("MiniGrid-GoToObj-8x8-N2-v0").is_ok());
        assert!(make("MiniGrid-PutNext-6x6-N2-v0").is_ok());
    }

    #[test]
    fn registry_counts_60_ids() {
        assert_eq!(list_envs().len(), 60);
    }

    #[test]
    fn sequenced_and_curriculum_families_wire_mission_complete() {
        use crate::envs::Layout;
        let cfg = make("Navix-SeqUnlockPickup-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::mission_complete());
        assert_eq!(cfg.termination, TermSpec::mission_complete());
        assert_eq!((cfg.h, cfg.w), (6, 11));
        assert_eq!(cfg.max_steps, 576);
        assert_eq!(cfg.layout, Layout::SeqUnlockPickup);
        let cfg = make("Navix-OpenDoorsOrder-6x6-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::mission_complete());
        assert_eq!(cfg.termination, TermSpec::mission_complete());
        assert_eq!(cfg.caps.doors, 2);
        assert_eq!(cfg.max_steps, 288);
        let cfg = make("Navix-Curriculum-RoomGrid-v0").unwrap();
        assert_eq!((cfg.h, cfg.w), (5, 13));
        assert_eq!(cfg.max_steps, 520);
        assert_eq!(cfg.layout, Layout::CurriculumRoomGrid { level: None });
        // The fixed-level ids are aliases: constructible, pinned, and not
        // extra registry rows.
        for l in 0..4u8 {
            let id = format!("Navix-Curriculum-RoomGrid-L{l}-v0");
            let cfg = make(&id).unwrap();
            assert_eq!(cfg.layout, Layout::CurriculumRoomGrid { level: Some(l) }, "{id}");
            assert!(!list_envs().contains(&id.as_str()), "{id} must stay an alias");
        }
    }

    #[test]
    fn multi_agent_families_wire_agents_rewards_and_terminations() {
        let cfg = make("Navix-MA-FourRooms-Race-v0").unwrap();
        assert_eq!(cfg.n_agents, 2);
        assert_eq!(cfg.reward, RewardSpec::r1());
        assert_eq!(cfg.termination, TermSpec::goal());
        let cfg = make("Navix-MA-PutNext-Coop-6x6-N2-v0").unwrap();
        assert_eq!(cfg.n_agents, 2);
        assert_eq!(cfg.reward, RewardSpec::team_object_placed());
        assert_eq!(cfg.termination, TermSpec::object_placed());
        let cfg = make("Navix-MA-Tag-8x8-v0").unwrap();
        assert_eq!(cfg.n_agents, 2);
        assert_eq!(cfg.reward, RewardSpec::pursuit());
        assert_eq!(cfg.termination, TermSpec::pursuit());
        assert!(cfg.stochastic_balls, "tag keeps the drifting obstacles");
        // Single-agent families stay at A = 1.
        assert_eq!(make("Navix-Empty-8x8-v0").unwrap().n_agents, 1);
    }

    #[test]
    fn multi_agent_resets_place_every_agent_on_distinct_cells() {
        for id in ["Navix-MA-FourRooms-Race-v0", "Navix-MA-PutNext-Coop-6x6-N2-v0", "Navix-MA-Tag-8x8-v0"] {
            let cfg = make(id).unwrap();
            for seed in 0..5 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                assert_eq!(s.player_pos.len(), 2, "{id}: two agent rows");
                for j in 0..2 {
                    assert!(s.player_pos[j] >= 0, "{id} seed {seed}: agent {j} unplaced");
                }
                assert_ne!(
                    s.player_pos[0], s.player_pos[1],
                    "{id} seed {seed}: agents must not share a cell"
                );
            }
        }
    }

    #[test]
    fn goal_conditioned_families_wire_mission_specs_and_timeouts() {
        let cfg = make("Navix-GoToObj-8x8-N3-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::object_reached());
        assert_eq!(cfg.termination, TermSpec::object_reached());
        assert_eq!(cfg.max_steps, 320);
        assert_eq!(cfg.caps.keys, 3);
        let cfg = make("Navix-PutNext-8x8-N3-v0").unwrap();
        assert_eq!(cfg.reward, RewardSpec::object_placed());
        assert_eq!(cfg.termination, TermSpec::object_placed());
        assert_eq!(cfg.max_steps, 320);
    }

    #[test]
    fn every_mission_family_sets_a_mission_and_goal_families_do_not() {
        // The state-level half of the mission-visibility pin (the
        // observation/engine half lives in tests/test_mission.rs).
        let mission_ids = [
            "Navix-GoToDoor-5x5-v0",
            "Navix-KeyCorridorS3R1-v0",
            "Navix-Fetch-5x5-N2-v0",
            "Navix-Unlock-v0",
            "Navix-UnlockPickup-v0",
            "Navix-BlockedUnlockPickup-v0",
            "Navix-GoToObj-6x6-N2-v0",
            "Navix-PutNext-6x6-N2-v0",
        ];
        for id in mission_ids {
            let cfg = make(id).unwrap();
            for seed in 0..5 {
                let st = reset_once(&cfg, seed);
                assert!(
                    !st.slot(0).mission_value().is_none(),
                    "{id} seed {seed}: mission env must set a mission"
                );
            }
        }
        for id in ["Navix-Empty-8x8-v0", "Navix-FourRooms-v0", "Navix-LavaGapS5-v0"] {
            let cfg = make(id).unwrap();
            let st = reset_once(&cfg, 0);
            assert!(st.slot(0).mission_value().is_none(), "{id}: goal env has no mission");
        }
    }
}
