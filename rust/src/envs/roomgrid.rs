//! RoomGrid: the procedural multi-room layout subsystem (the analog of
//! MiniGrid's `RoomGrid` and `MultiRoom` builders).
//!
//! Two layers of composable primitives, both driven exclusively by the
//! per-env [`SlotRng`](crate::core::state::SlotRng) stream so every layout
//! is a pure function of the episode key — which is what keeps generation
//! bitwise shard-invariant under [`crate::batch::ShardedEnv`]:
//!
//! * **Free-form carving** ([`carve_room_rect`]) for irregular plans
//!   (MultiRoom's random-walk room chains, LockedRoom's corridor plan).
//! * **[`RoomGrid`]**: a regular `rows × cols` grid of `room_size`-sized
//!   rooms sharing walls, with helpers to cut doors into shared walls,
//!   remove walls entirely, and place entities/the agent inside rooms.
//!
//! All placement goes through the fallible
//! [`SlotMut::sample_free_in`](crate::core::state::SlotMut::sample_free_in),
//! so a crowded room surfaces a [`PlacementError`] instead of panicking.

use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Carve a rectangular room whose bounding box is `rh × rw` cells at `top`:
/// a wall ring around a floor interior. Rooms that share a wall line may be
/// carved in any order — both write Wall on the shared line.
pub fn carve_room_rect(s: &mut SlotMut<'_>, top: Pos, rh: i32, rw: i32) {
    for r in 0..rh {
        for c in 0..rw {
            let p = Pos::new(top.r + r, top.c + c);
            let border = r == 0 || c == 0 || r == rh - 1 || c == rw - 1;
            s.set_cell(p, if border { CellType::Wall } else { CellType::Floor }, Color::Grey);
        }
    }
}

/// Turn the wall cell at `p` into a door (the base cell becomes floor — a
/// door *replaces* its cell, MiniGrid semantics). Returns the door slot.
pub fn set_door(s: &mut SlotMut<'_>, p: Pos, color: Color, state: DoorState) -> usize {
    s.set_cell(p, CellType::Floor, Color::Grey);
    s.add_door(p, color, state)
}

/// A regular `rows × cols` grid of square rooms of edge `room_size`,
/// sharing walls (MiniGrid `RoomGrid` geometry): the full grid is
/// `rows·(room_size−1)+1 × cols·(room_size−1)+1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RoomGrid {
    pub room_size: usize,
    pub rows: usize,
    pub cols: usize,
}

impl RoomGrid {
    pub fn new(room_size: usize, rows: usize, cols: usize) -> Self {
        assert!(room_size >= 3 && rows >= 1 && cols >= 1, "degenerate RoomGrid");
        RoomGrid { room_size, rows, cols }
    }

    /// Wall-to-wall stride between adjacent room origins.
    #[inline]
    fn stride(&self) -> i32 {
        (self.room_size - 1) as i32
    }

    /// Full grid dimensions `(h, w)`.
    pub fn dims(&self) -> (usize, usize) {
        (self.rows * (self.room_size - 1) + 1, self.cols * (self.room_size - 1) + 1)
    }

    /// Top-left corner of room `(i, j)` (on the shared wall lattice).
    pub fn room_top(&self, i: usize, j: usize) -> Pos {
        debug_assert!(i < self.rows && j < self.cols);
        Pos::new(i as i32 * self.stride(), j as i32 * self.stride())
    }

    /// Carve the whole grid: outer wall ring, floor, and the internal
    /// shared-wall lattice.
    pub fn carve(&self, s: &mut SlotMut<'_>) {
        let (h, w) = self.dims();
        debug_assert_eq!((s.h, s.w), (h, w), "slot dims must match the RoomGrid");
        s.fill_room();
        let st = self.stride();
        for k in 1..self.cols as i32 {
            for r in 1..(h as i32) - 1 {
                s.set_cell(Pos::new(r, k * st), CellType::Wall, Color::Grey);
            }
        }
        for k in 1..self.rows as i32 {
            for c in 1..(w as i32) - 1 {
                s.set_cell(Pos::new(k * st, c), CellType::Wall, Color::Grey);
            }
        }
    }

    /// The candidate door cells (non-corner wall cells) on the wall between
    /// room `(i, j)` and its neighbour in `side` direction. `side` must be
    /// `East` (neighbour `(i, j+1)`) or `South` (neighbour `(i+1, j)`).
    pub fn wall_cells(&self, i: usize, j: usize, side: Direction) -> Vec<Pos> {
        let top = self.room_top(i, j);
        let st = self.stride();
        match side {
            Direction::East => {
                debug_assert!(j + 1 < self.cols, "no room east of ({i},{j})");
                (1..st).map(|k| Pos::new(top.r + k, top.c + st)).collect()
            }
            Direction::South => {
                debug_assert!(i + 1 < self.rows, "no room south of ({i},{j})");
                (1..st).map(|k| Pos::new(top.r + st, top.c + k)).collect()
            }
            _ => panic!("wall_cells takes East or South (use the neighbouring room otherwise)"),
        }
    }

    /// Cut a door into the wall between room `(i, j)` and its `side`
    /// neighbour at a random (slot-RNG) wall cell. Returns the door's cell.
    pub fn add_door(
        &self,
        s: &mut SlotMut<'_>,
        i: usize,
        j: usize,
        side: Direction,
        color: Color,
        state: DoorState,
    ) -> Pos {
        let cells = self.wall_cells(i, j, side);
        let k = {
            let mut rng = s.rng();
            rng.below(cells.len() as u32) as usize
        };
        set_door(s, cells[k], color, state);
        cells[k]
    }

    /// Remove the entire wall between room `(i, j)` and its `side`
    /// neighbour (MiniGrid `remove_wall`).
    pub fn remove_wall(&self, s: &mut SlotMut<'_>, i: usize, j: usize, side: Direction) {
        for p in self.wall_cells(i, j, side) {
            s.set_cell(p, CellType::Floor, Color::Grey);
        }
    }

    /// Sample a free floor cell strictly inside room `(i, j)`.
    pub fn place_in_room(
        &self,
        s: &mut SlotMut<'_>,
        i: usize,
        j: usize,
        avoid_player: bool,
    ) -> Result<Pos, PlacementError> {
        let top = self.room_top(i, j);
        let st = self.stride();
        s.sample_free_in(top.r + 1, top.c + 1, top.r + st, top.c + st, avoid_player)
    }

    /// Place the agent at a random free cell of room `(i, j)` with a random
    /// facing.
    pub fn place_agent(
        &self,
        s: &mut SlotMut<'_>,
        i: usize,
        j: usize,
    ) -> Result<Pos, PlacementError> {
        let p = self.place_in_room(s, i, j, false)?;
        let dir = {
            let mut rng = s.rng();
            rng.randint(0, 4)
        };
        s.place_player(p, Direction::from_i32(dir));
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::state::{BatchedState, Caps};

    fn state_for(rg: RoomGrid) -> BatchedState {
        let (h, w) = rg.dims();
        BatchedState::new(1, h, w, Caps { doors: 4, keys: 2, balls: 2, boxes: 2 })
    }

    #[test]
    fn dims_match_minigrid_roomgrid() {
        assert_eq!(RoomGrid::new(6, 1, 2).dims(), (6, 11)); // Unlock family
        assert_eq!(RoomGrid::new(3, 3, 3).dims(), (7, 7));
        assert_eq!(RoomGrid::new(6, 3, 3).dims(), (16, 16));
    }

    #[test]
    fn carve_builds_shared_wall_lattice() {
        let rg = RoomGrid::new(4, 2, 2);
        let mut st = state_for(rg);
        let mut s = st.slot_mut(0);
        s.fill_room(); // dirty the slot first: carve must fully overwrite
        rg.carve(&mut s);
        // internal walls at row 3 and col 3
        for k in 1..6 {
            assert_eq!(s.cell(Pos::new(3, k)), CellType::Wall);
            assert_eq!(s.cell(Pos::new(k, 3)), CellType::Wall);
        }
        // room interiors are floor
        assert_eq!(s.cell(Pos::new(1, 1)), CellType::Floor);
        assert_eq!(s.cell(Pos::new(5, 5)), CellType::Floor);
    }

    #[test]
    fn doors_connect_rooms_and_sit_on_shared_walls() {
        let rg = RoomGrid::new(5, 2, 2);
        let mut st = state_for(rg);
        let mut s = st.slot_mut(0);
        *s.rng = 77;
        rg.carve(&mut s);
        let east = rg.add_door(&mut s, 0, 0, Direction::East, Color::Red, DoorState::Closed);
        let south = rg.add_door(&mut s, 0, 1, Direction::South, Color::Blue, DoorState::Locked);
        assert_eq!(east.c, 4, "east door on the shared vertical wall");
        assert!(east.r >= 1 && east.r <= 3);
        assert_eq!(south.r, 4, "south door on the shared horizontal wall");
        assert!(south.c >= 5 && south.c <= 7);
        assert!(s.door_at(east).is_some());
        assert_eq!(s.cell(east), CellType::Floor, "doors replace their wall cell");
    }

    #[test]
    fn remove_wall_opens_the_full_span() {
        let rg = RoomGrid::new(4, 1, 2);
        let mut st = state_for(rg);
        let mut s = st.slot_mut(0);
        rg.carve(&mut s);
        rg.remove_wall(&mut s, 0, 0, Direction::East);
        for r in 1..3 {
            assert_eq!(s.cell(Pos::new(r, 3)), CellType::Floor);
        }
    }

    #[test]
    fn place_in_room_stays_inside_and_errors_when_full() {
        let rg = RoomGrid::new(4, 1, 2);
        let mut st = state_for(rg);
        let mut s = st.slot_mut(0);
        *s.rng = 5;
        rg.carve(&mut s);
        // room (0,1) interior is rows 1..3 × cols 4..6
        for _ in 0..30 {
            let p = rg.place_in_room(&mut s, 0, 1, false).unwrap();
            assert!(p.r >= 1 && p.r <= 2 && p.c >= 4 && p.c <= 5, "{p:?} outside room (0,1)");
        }
        // fill room (0,0) and confirm the error carries the rectangle
        s.add_key(Pos::new(1, 1), Color::Red);
        s.add_key(Pos::new(1, 2), Color::Red);
        s.add_ball(Pos::new(2, 1), Color::Red);
        s.add_ball(Pos::new(2, 2), Color::Red);
        assert!(rg.place_in_room(&mut s, 0, 0, false).is_err());
    }

    #[test]
    fn layouts_are_a_pure_function_of_the_slot_rng() {
        let rg = RoomGrid::new(6, 1, 2);
        let build = |seed: u64| {
            let mut st = state_for(rg);
            let mut s = st.slot_mut(0);
            *s.rng = seed;
            rg.carve(&mut s);
            rg.add_door(&mut s, 0, 0, Direction::East, Color::Yellow, DoorState::Locked);
            let k = rg.place_in_room(&mut s, 0, 0, false).unwrap();
            s.add_key(k, Color::Yellow);
            rg.place_agent(&mut s, 0, 0).unwrap();
            drop(s);
            (st.base.clone(), st.door_pos.clone(), st.key_pos.clone(), st.player_pos.clone())
        };
        assert_eq!(build(42), build(42), "same key, same layout");
        assert_ne!(build(1), build(2), "different keys should produce different layouts");
    }
}
