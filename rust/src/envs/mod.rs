//! The NAVIX environment suite (paper Tables 7–8): every MiniGrid family the
//! paper reproduces, expressed as an [`EnvConfig`] — grid dimensions, static
//! entity capacities, timeout, observation/reward/termination systems and a
//! [`Layout`] generator.
//!
//! `EnvConfig` is pure data: the batched engine ([`crate::batch`]) consumes
//! it to reset/step `B` environments in SoA form, and the baseline engine
//! ([`crate::baseline`]) consumes the same configs so speed comparisons are
//! apples-to-apples.

pub mod crossings;
pub mod dist_shift;
pub mod doorkey;
pub mod dynamic_obstacles;
pub mod empty;
pub mod fetch;
pub mod four_rooms;
pub mod go_to_door;
pub mod key_corridor;
pub mod lava_gap;
pub mod locked_room;
pub mod multiroom;
pub mod registry;
pub mod roomgrid;
pub mod solvability;
pub mod unlock;

use crate::core::state::{Caps, PlacementError, SlotMut};
use crate::rng::Key;
use crate::systems::observations::{ObsKind, ObsSpec};
use crate::systems::rewards::RewardSpec;
use crate::systems::terminations::TermSpec;

/// Which layout generator builds the starting state (paper Table 8 "Class").
#[derive(Clone, Debug, PartialEq)]
pub enum Layout {
    /// Empty room, goal bottom-right. `random_start`: agent pose sampled.
    Empty { random_start: bool },
    /// Room split by a locked door; key on the agent's side.
    /// `random`: wall/door/key/agent positions sampled per episode.
    DoorKey { random: bool },
    /// Four connected rooms, random agent and goal.
    FourRooms,
    /// 3×`rows` grid of `size`-sized rooms around a central corridor; pick
    /// up the ball behind the locked door.
    KeyCorridor { size: usize, rows: usize },
    /// Vertical lava curtain with a single gap.
    LavaGap,
    /// `n` wall "rivers" (SimpleCrossing) or lava rivers with one opening
    /// each.
    Crossings { n: usize, lava: bool },
    /// Empty room with `n` randomly drifting balls.
    DynamicObstacles { n: usize },
    /// Lava strip near the top; v1/v2 differ by the strip row (the
    /// "distribution shift").
    DistShift { strip_row: usize },
    /// Four coloured doors, one per wall; `done` before the mission door.
    GoToDoor,
    /// Chain of `n` randomly-placed rooms connected by coloured doors
    /// (MultiRoom); goal in the last room.
    MultiRoom { n: usize, max_size: usize },
    /// Two rooms, a locked door between them, key on the agent's side;
    /// succeed by opening the door (RoomGrid Unlock).
    Unlock,
    /// Unlock, then pick up the box in the far room.
    UnlockPickup,
    /// UnlockPickup with a ball blocking the door.
    BlockedUnlockPickup,
    /// Six rooms off a central corridor; one is locked and holds the goal.
    LockedRoom,
    /// `n` random key/ball objects; pick up the mission target (Fetch).
    Fetch { n_objs: usize },
}

/// A fully-specified NAVIX environment (one Table-8 row).
#[derive(Clone, Debug)]
pub struct EnvConfig {
    pub id: String,
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    /// Timeout T (steps before truncation).
    pub max_steps: u32,
    pub obs: ObsSpec,
    pub reward: RewardSpec,
    pub termination: TermSpec,
    /// Balls are stochastic dynamic obstacles (Dynamic-Obstacles family).
    pub stochastic_balls: bool,
    pub layout: Layout,
}

/// Layout generation could not place an entity. Carries the env id and grid
/// dimensions so batch-level retry/reporting is actionable — generation
/// failure is data, not a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayoutError {
    pub env_id: String,
    pub h: usize,
    pub w: usize,
    pub source: PlacementError,
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "layout generation failed for {} ({}×{}): {}",
            self.env_id, self.h, self.w, self.source
        )
    }
}

impl std::error::Error for LayoutError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

impl EnvConfig {
    /// Reset one environment slot: reseed its stream, clear entities and run
    /// the layout generator. Fails (instead of panicking) when the generator
    /// cannot place an entity — the batch layer retries with a successor
    /// episode key.
    pub fn reset_slot(&self, s: &mut SlotMut<'_>, key: Key) -> Result<(), LayoutError> {
        *s.rng = key.0;
        s.clear_entities();
        self.generate(s).map_err(|source| LayoutError {
            env_id: self.id.clone(),
            h: self.h,
            w: self.w,
            source,
        })?;
        debug_assert!(s.player().in_bounds(self.h, self.w), "layout must place the player");
        Ok(())
    }

    /// Dispatch to the family generator.
    fn generate(&self, s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
        match self.layout {
            Layout::Empty { random_start } => empty::generate(s, random_start),
            Layout::DoorKey { random } => doorkey::generate(s, random),
            Layout::FourRooms => four_rooms::generate(s),
            Layout::KeyCorridor { size, rows } => key_corridor::generate(s, size, rows),
            Layout::LavaGap => lava_gap::generate(s),
            Layout::Crossings { n, lava } => crossings::generate(s, n, lava),
            Layout::DynamicObstacles { n } => dynamic_obstacles::generate(s, n),
            Layout::DistShift { strip_row } => dist_shift::generate(s, strip_row),
            Layout::GoToDoor => go_to_door::generate(s),
            Layout::MultiRoom { n, max_size } => multiroom::generate(s, n, max_size),
            Layout::Unlock => unlock::generate(s, unlock::Kind::Unlock),
            Layout::UnlockPickup => unlock::generate(s, unlock::Kind::Pickup),
            Layout::BlockedUnlockPickup => unlock::generate(s, unlock::Kind::BlockedPickup),
            Layout::LockedRoom => locked_room::generate(s),
            Layout::Fetch { n_objs } => fetch::generate(s, n_objs),
        }
    }

    /// Builder-style override of the observation function (paper Appendix C).
    pub fn with_observation(mut self, kind: ObsKind) -> Self {
        self.obs = ObsSpec::new(kind);
        self
    }

    /// Builder-style override of the reward function (paper Appendix C).
    pub fn with_reward(mut self, reward: RewardSpec) -> Self {
        self.reward = reward;
        self
    }

    /// Builder-style override of the termination function (paper Appendix C).
    pub fn with_termination(mut self, termination: TermSpec) -> Self {
        self.termination = termination;
        self
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::core::state::BatchedState;

    pub use super::solvability::{goal_pos, reachable};

    /// Reset `cfg` into a fresh single-env state for layout tests.
    pub fn reset_once(cfg: &EnvConfig, seed: u64) -> BatchedState {
        let mut st = BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        let mut s = st.slot_mut(0);
        cfg.reset_slot(&mut s, Key::new(seed)).expect("layout generation");
        drop(s);
        st
    }
}
