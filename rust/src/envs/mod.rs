//! The NAVIX environment suite (paper Tables 7–8): every MiniGrid family the
//! paper reproduces, expressed as an [`EnvConfig`] — grid dimensions, static
//! entity capacities, timeout, observation/reward/termination systems and a
//! [`Layout`] generator.
//!
//! `EnvConfig` is pure data: the batched engine ([`crate::batch`]) consumes
//! it to reset/step `B` environments in SoA form, and the baseline engine
//! ([`crate::baseline`]) consumes the same configs so speed comparisons are
//! apples-to-apples.

pub mod crossings;
pub mod curriculum;
pub mod dist_shift;
pub mod doorkey;
pub mod dynamic_obstacles;
pub mod empty;
pub mod fetch;
pub mod four_rooms;
pub mod go_to_door;
pub mod go_to_obj;
pub mod key_corridor;
pub mod lava_gap;
pub mod locked_room;
pub mod multiroom;
pub mod put_next;
pub mod registry;
pub mod roomgrid;
pub mod sequenced;
pub mod solvability;
pub mod unlock;

use crate::core::state::{Caps, PlacementError, SlotMut};
use crate::rng::Key;
use crate::systems::observations::{ObsKind, ObsSpec};
use crate::systems::rewards::RewardSpec;
use crate::systems::terminations::TermSpec;

/// Which layout generator builds the starting state (paper Table 8 "Class").
#[derive(Clone, Debug, PartialEq)]
pub enum Layout {
    /// Empty room, goal bottom-right. `random_start`: agent pose sampled.
    Empty { random_start: bool },
    /// Room split by a locked door; key on the agent's side.
    /// `random`: wall/door/key/agent positions sampled per episode.
    DoorKey { random: bool },
    /// Four connected rooms, random agent and goal.
    FourRooms,
    /// 3×`rows` grid of `size`-sized rooms around a central corridor; pick
    /// up the ball behind the locked door.
    KeyCorridor { size: usize, rows: usize },
    /// Vertical lava curtain with a single gap.
    LavaGap,
    /// `n` wall "rivers" (SimpleCrossing) or lava rivers with one opening
    /// each.
    Crossings { n: usize, lava: bool },
    /// Empty room with `n` randomly drifting balls.
    DynamicObstacles { n: usize },
    /// Lava strip near the top; v1/v2 differ by the strip row (the
    /// "distribution shift").
    DistShift { strip_row: usize },
    /// Four coloured doors, one per wall; `done` before the mission door.
    GoToDoor,
    /// Chain of `n` randomly-placed rooms connected by coloured doors
    /// (MultiRoom); goal in the last room.
    MultiRoom { n: usize, max_size: usize },
    /// Two rooms, a locked door between them, key on the agent's side;
    /// succeed by opening the door (RoomGrid Unlock).
    Unlock,
    /// Unlock, then pick up the box in the far room.
    UnlockPickup,
    /// UnlockPickup with a ball blocking the door.
    BlockedUnlockPickup,
    /// Six rooms off a central corridor; one is locked and holds the goal.
    LockedRoom,
    /// `n` random key/ball objects; pick up the mission target (Fetch).
    Fetch { n_objs: usize },
    /// `n` distinct random objects; `done` facing the mission target
    /// (BabyAI-style GoToObj).
    GoToObj { n_objs: usize },
    /// `n` distinct random objects; put the mission object next to the
    /// mission's second object (BabyAI-style PutNext).
    PutNext { n_objs: usize },
    /// Unlock geometry with an explicit 2-clause mission: open the door,
    /// *then* pick up the far-room box (sequenced UnlockPickup).
    SeqUnlockPickup,
    /// One room, two outer-wall doors, ordered 2-clause open mission.
    OpenDoorsOrder,
    /// Difficulty-parameterised RoomGrid chain. `level` pins a curriculum
    /// level; `None` draws one per episode from the slot key (the
    /// deterministic per-slot schedule).
    CurriculumRoomGrid { level: Option<u8> },
}

/// A fully-specified NAVIX environment (one Table-8 row).
#[derive(Clone, Debug)]
pub struct EnvConfig {
    pub id: String,
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    /// Timeout T (steps before truncation).
    pub max_steps: u32,
    pub obs: ObsSpec,
    pub reward: RewardSpec,
    pub termination: TermSpec,
    /// Balls are stochastic dynamic obstacles (Dynamic-Obstacles family).
    pub stochastic_balls: bool,
    /// Agents per environment slot (A). 1 for the classic single-agent
    /// families; multi-agent families widen every engine's action/obs/
    /// reward surface to `B·A` agent-rows.
    pub n_agents: usize,
    pub layout: Layout,
}

/// Layout generation could not place an entity. Carries the env id and grid
/// dimensions so batch-level retry/reporting is actionable — generation
/// failure is data, not a panic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayoutError {
    pub env_id: String,
    pub h: usize,
    pub w: usize,
    pub source: PlacementError,
}

impl std::fmt::Display for LayoutError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "layout generation failed for {} ({}×{}): {}",
            self.env_id, self.h, self.w, self.source
        )
    }
}

impl std::error::Error for LayoutError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        Some(&self.source)
    }
}

impl EnvConfig {
    /// Reset one environment slot: reseed its stream, clear entities and run
    /// the layout generator. Fails (instead of panicking) when the generator
    /// cannot place an entity — the batch layer retries with a successor
    /// episode key.
    pub fn reset_slot(&self, s: &mut SlotMut<'_>, key: Key) -> Result<(), LayoutError> {
        *s.rng = key.0;
        s.clear_entities();
        self.generate(s).map_err(|source| self.layout_err(source))?;
        // Extra agents (multi-agent families): a uniformly random free pose
        // per agent after the family generator has placed entities and
        // agent 0. A = 1 runs this loop zero times and consumes no RNG, so
        // single-agent episode streams are bit-identical to before.
        for j in 1..s.player_pos.len() {
            let p = s.sample_free_cell(true).map_err(|source| self.layout_err(source))?;
            let dir = {
                let mut rng = s.rng();
                rng.randint(0, 4)
            };
            s.place_agent(j, p, crate::core::components::Direction::from_i32(dir));
        }
        debug_assert!(s.player().in_bounds(self.h, self.w), "layout must place the player");
        Ok(())
    }

    fn layout_err(&self, source: PlacementError) -> LayoutError {
        LayoutError { env_id: self.id.clone(), h: self.h, w: self.w, source }
    }

    /// Dispatch to the family generator.
    fn generate(&self, s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
        match self.layout {
            Layout::Empty { random_start } => empty::generate(s, random_start),
            Layout::DoorKey { random } => doorkey::generate(s, random),
            Layout::FourRooms => four_rooms::generate(s),
            Layout::KeyCorridor { size, rows } => key_corridor::generate(s, size, rows),
            Layout::LavaGap => lava_gap::generate(s),
            Layout::Crossings { n, lava } => crossings::generate(s, n, lava),
            Layout::DynamicObstacles { n } => dynamic_obstacles::generate(s, n),
            Layout::DistShift { strip_row } => dist_shift::generate(s, strip_row),
            Layout::GoToDoor => go_to_door::generate(s),
            Layout::MultiRoom { n, max_size } => multiroom::generate(s, n, max_size),
            Layout::Unlock => unlock::generate(s, unlock::Kind::Unlock),
            Layout::UnlockPickup => unlock::generate(s, unlock::Kind::Pickup),
            Layout::BlockedUnlockPickup => unlock::generate(s, unlock::Kind::BlockedPickup),
            Layout::LockedRoom => locked_room::generate(s),
            Layout::Fetch { n_objs } => fetch::generate(s, n_objs),
            Layout::GoToObj { n_objs } => go_to_obj::generate(s, n_objs),
            Layout::PutNext { n_objs } => put_next::generate(s, n_objs),
            Layout::SeqUnlockPickup => sequenced::seq_unlock_pickup(s),
            Layout::OpenDoorsOrder => sequenced::open_doors_order(s),
            Layout::CurriculumRoomGrid { level } => curriculum::generate(s, level),
        }
    }

    /// Builder-style override of the observation function (paper Appendix C).
    pub fn with_observation(mut self, kind: ObsKind) -> Self {
        self.obs = ObsSpec::new(kind);
        self
    }

    /// Builder-style override of the reward function (paper Appendix C).
    pub fn with_reward(mut self, reward: RewardSpec) -> Self {
        self.reward = reward;
        self
    }

    /// Builder-style override of the termination function (paper Appendix C).
    pub fn with_termination(mut self, termination: TermSpec) -> Self {
        self.termination = termination;
        self
    }

    /// Builder-style override of the agents-per-slot count (multi-agent
    /// families).
    pub fn with_agents(mut self, n_agents: usize) -> Self {
        self.n_agents = n_agents.max(1);
        self
    }
}

/// How many successor episode keys a reset may burn before the
/// configuration is declared unsatisfiable.
pub const MAX_RESET_TRIES: usize = 8;

/// The shared episode-key retry loop: run `attempt` with successive try
/// indices until one succeeds, and panic with the *full* context — the
/// layout error, the env id and the root key — after [`MAX_RESET_TRIES`]
/// failures. Both the batched engine's autoreset path and the baseline
/// engine's `reset` drive their (previously duplicated) loops through this,
/// so the exhaustion message can never drift between engines again.
/// Retrying is deterministic: failure is a pure function of the episode
/// key, so every engine covering an env skips exactly the same keys.
pub fn retry_episode_keys<T>(
    env_id: &str,
    root: Key,
    mut attempt: impl FnMut(usize) -> Result<T, LayoutError>,
) -> T {
    let mut last: Option<LayoutError> = None;
    for try_idx in 0..MAX_RESET_TRIES {
        match attempt(try_idx) {
            Ok(v) => return v,
            Err(e) => last = Some(e),
        }
    }
    // Only an unsatisfiable configuration (capacity/geometry bug) fails
    // MAX_RESET_TRIES independent keys in a row.
    let e = last.expect("MAX_RESET_TRIES is nonzero");
    panic!(
        "{e} — env `{env_id}` exhausted {MAX_RESET_TRIES} episode keys (root key {:#018x})",
        root.0
    );
}

#[cfg(test)]
mod retry_tests {
    use super::*;
    use crate::core::state::PlacementError;

    fn layout_err() -> LayoutError {
        LayoutError {
            env_id: "Navix-Test-v0".into(),
            h: 5,
            w: 5,
            source: PlacementError { h: 5, w: 5, r0: 1, c0: 1, r1: 4, c1: 4 },
        }
    }

    #[test]
    fn retry_returns_on_first_success_and_counts_tries() {
        let mut calls = 0;
        let got = retry_episode_keys("Navix-Test-v0", Key::new(1), |t| {
            calls += 1;
            if t < 2 {
                Err(layout_err())
            } else {
                Ok(t)
            }
        });
        assert_eq!(got, 2);
        assert_eq!(calls, 3);
    }

    #[test]
    fn exhaustion_panics_with_env_id_and_root_key() {
        let root = Key::new(9);
        let err = std::panic::catch_unwind(|| {
            retry_episode_keys::<()>("Navix-Test-v0", root, |_| Err(layout_err()))
        })
        .expect_err("exhaustion must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("Navix-Test-v0"), "env id missing: {msg}");
        assert!(msg.contains(&format!("{:#018x}", root.0)), "root key missing: {msg}");
        assert!(msg.contains("episode keys"), "retry count missing: {msg}");
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::core::entities::Tag;
    use crate::core::state::{BatchedState, EnvSlot};

    pub use super::solvability::{goal_pos, reachable};

    /// Is an on-grid entity of exactly `(tag, colour)` present in slot `s`?
    /// Shared by the goal-conditioned families' layout tests (Fetch,
    /// GoToObj, PutNext) so the entity-table liveness convention
    /// (`pos >= 0`) lives in one place.
    pub fn object_exists(s: &EnvSlot<'_>, tag: i32, color: u8) -> bool {
        match tag {
            Tag::KEY => {
                (0..s.key_pos.len()).any(|k| s.key_pos[k] >= 0 && s.key_color[k] == color)
            }
            Tag::BALL => {
                (0..s.ball_pos.len()).any(|b| s.ball_pos[b] >= 0 && s.ball_color[b] == color)
            }
            Tag::BOX => {
                (0..s.box_pos.len()).any(|b| s.box_pos[b] >= 0 && s.box_color[b] == color)
            }
            _ => false,
        }
    }

    /// Reset `cfg` into a fresh single-env state for layout tests. The
    /// first attempt uses exactly `Key::new(seed)` — pinned-layout tests
    /// rely on that — and rejecting generators (the curriculum's
    /// satisfiability gate) fall back to the shared successor-key retry.
    pub fn reset_once(cfg: &EnvConfig, seed: u64) -> BatchedState {
        let mut st =
            BatchedState::with_agents(1, cfg.h, cfg.w, cfg.caps, cfg.n_agents.max(1));
        let root = Key::new(seed);
        retry_episode_keys(&cfg.id, root, |t| {
            let key = if t == 0 { root } else { root.fold_in(t as u64) };
            cfg.reset_slot(&mut st.slot_mut(0), key)
        });
        st
    }
}
