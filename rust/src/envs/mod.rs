//! The NAVIX environment suite (paper Tables 7–8): every MiniGrid family the
//! paper reproduces, expressed as an [`EnvConfig`] — grid dimensions, static
//! entity capacities, timeout, observation/reward/termination systems and a
//! [`Layout`] generator.
//!
//! `EnvConfig` is pure data: the batched engine ([`crate::batch`]) consumes
//! it to reset/step `B` environments in SoA form, and the baseline engine
//! ([`crate::baseline`]) consumes the same configs so speed comparisons are
//! apples-to-apples.

pub mod crossings;
pub mod dist_shift;
pub mod doorkey;
pub mod dynamic_obstacles;
pub mod empty;
pub mod four_rooms;
pub mod go_to_door;
pub mod key_corridor;
pub mod lava_gap;
pub mod registry;

use crate::core::state::{Caps, SlotMut};
use crate::rng::Key;
use crate::systems::observations::{ObsKind, ObsSpec};
use crate::systems::rewards::RewardSpec;
use crate::systems::terminations::TermSpec;

/// Which layout generator builds the starting state (paper Table 8 "Class").
#[derive(Clone, Debug, PartialEq)]
pub enum Layout {
    /// Empty room, goal bottom-right. `random_start`: agent pose sampled.
    Empty { random_start: bool },
    /// Room split by a locked door; key on the agent's side.
    /// `random`: wall/door/key/agent positions sampled per episode.
    DoorKey { random: bool },
    /// Four connected rooms, random agent and goal.
    FourRooms,
    /// 3×`rows` grid of `size`-sized rooms around a central corridor; pick
    /// up the ball behind the locked door.
    KeyCorridor { size: usize, rows: usize },
    /// Vertical lava curtain with a single gap.
    LavaGap,
    /// `n` wall "rivers" (SimpleCrossing) or lava rivers with one opening
    /// each.
    Crossings { n: usize, lava: bool },
    /// Empty room with `n` randomly drifting balls.
    DynamicObstacles { n: usize },
    /// Lava strip near the top; v1/v2 differ by the strip row (the
    /// "distribution shift").
    DistShift { strip_row: usize },
    /// Four coloured doors, one per wall; `done` before the mission door.
    GoToDoor,
}

/// A fully-specified NAVIX environment (one Table-8 row).
#[derive(Clone, Debug)]
pub struct EnvConfig {
    pub id: String,
    pub h: usize,
    pub w: usize,
    pub caps: Caps,
    /// Timeout T (steps before truncation).
    pub max_steps: u32,
    pub obs: ObsSpec,
    pub reward: RewardSpec,
    pub termination: TermSpec,
    /// Balls are stochastic dynamic obstacles (Dynamic-Obstacles family).
    pub stochastic_balls: bool,
    pub layout: Layout,
}

impl EnvConfig {
    /// Reset one environment slot: reseed its stream, clear entities and run
    /// the layout generator.
    pub fn reset_slot(&self, s: &mut SlotMut<'_>, key: Key) {
        *s.rng = key.0;
        s.clear_entities();
        self.generate(s);
        debug_assert!(s.player().in_bounds(self.h, self.w), "layout must place the player");
    }

    /// Dispatch to the family generator.
    fn generate(&self, s: &mut SlotMut<'_>) {
        match self.layout {
            Layout::Empty { random_start } => empty::generate(s, random_start),
            Layout::DoorKey { random } => doorkey::generate(s, random),
            Layout::FourRooms => four_rooms::generate(s),
            Layout::KeyCorridor { size, rows } => key_corridor::generate(s, size, rows),
            Layout::LavaGap => lava_gap::generate(s),
            Layout::Crossings { n, lava } => crossings::generate(s, n, lava),
            Layout::DynamicObstacles { n } => dynamic_obstacles::generate(s, n),
            Layout::DistShift { strip_row } => dist_shift::generate(s, strip_row),
            Layout::GoToDoor => go_to_door::generate(s),
        }
    }

    /// Builder-style override of the observation function (paper Appendix C).
    pub fn with_observation(mut self, kind: ObsKind) -> Self {
        self.obs = ObsSpec::new(kind);
        self
    }

    /// Builder-style override of the reward function (paper Appendix C).
    pub fn with_reward(mut self, reward: RewardSpec) -> Self {
        self.reward = reward;
        self
    }

    /// Builder-style override of the termination function (paper Appendix C).
    pub fn with_termination(mut self, termination: TermSpec) -> Self {
        self.termination = termination;
        self
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::core::grid::Pos;
    use crate::core::state::BatchedState;

    /// Reset `cfg` into a fresh single-env state for layout tests.
    pub fn reset_once(cfg: &EnvConfig, seed: u64) -> BatchedState {
        let mut st = BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        let mut s = st.slot_mut(0);
        cfg.reset_slot(&mut s, Key::new(seed));
        drop(s);
        st
    }

    /// Breadth-first reachability over walkable cells from the player to
    /// `target`. With `through_doors`, closed/locked doors and pickable
    /// entities count as passable (asserts topological solvability).
    pub fn reachable(st: &BatchedState, target: Pos, through_doors: bool) -> bool {
        let s = st.slot(0);
        let start = s.player();
        let mut seen = vec![false; s.h * s.w];
        let mut queue = std::collections::VecDeque::new();
        seen[(start.r as usize) * s.w + start.c as usize] = true;
        queue.push_back(start);
        while let Some(p) = queue.pop_front() {
            if p == target {
                return true;
            }
            for d in crate::core::components::Direction::ALL {
                let q = p.step(d);
                if !q.in_bounds(s.h, s.w) {
                    continue;
                }
                let qi = (q.r as usize) * s.w + q.c as usize;
                if seen[qi] {
                    continue;
                }
                let passable = if through_doors {
                    s.cell(q).walkable()
                } else {
                    s.walkable(q) || q == target
                };
                if passable {
                    seen[qi] = true;
                    queue.push_back(q);
                }
            }
        }
        false
    }

    /// Locate the (first) goal cell.
    pub fn goal_pos(st: &BatchedState) -> Pos {
        use crate::core::entities::CellType;
        let s = st.slot(0);
        for r in 0..s.h as i32 {
            for c in 0..s.w as i32 {
                if s.cell(Pos::new(r, c)) == CellType::Goal {
                    return Pos::new(r, c);
                }
            }
        }
        panic!("no goal in layout");
    }
}
