//! DoorKey-NxN (+Random variants): the room is split by a wall with a locked
//! door; the agent must fetch the key, unlock the door and reach the goal.
//! The canonical sparse-reward exploration benchmark.

use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Build the layout. The non-`random` ids use the size-determined canonical
/// layout (wall at w/2, door and key centred) so the MDP is fixed across
/// resets; `-Random-` ids sample wall/door/key/agent per episode, which is
/// MiniGrid's behaviour.
pub fn generate(s: &mut SlotMut<'_>, random: bool) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    s.set_cell(Pos::new(h - 2, w - 2), CellType::Goal, Color::Green);

    // Splitting wall at column `split` (agent side: columns < split).
    let split = if random {
        let mut rng = s.rng();
        rng.randint(2, w - 2)
    } else {
        w / 2
    };
    for r in 1..h - 1 {
        s.set_cell(Pos::new(r, split), CellType::Wall, Color::Grey);
    }
    // Door somewhere in the wall.
    let door_r = if random {
        let mut rng = s.rng();
        rng.randint(1, h - 1)
    } else {
        h / 2
    };
    // The door replaces the wall cell (MiniGrid semantics): the base cell
    // under a door is floor; the door entity itself controls passability.
    s.set_cell(Pos::new(door_r, split), CellType::Floor, Color::Grey);
    s.add_door(Pos::new(door_r, split), Color::Yellow, DoorState::Locked);

    // Agent and key on the left side.
    if random {
        s.place_player(Pos::new(1, 1), Direction::East);
        // Key and agent sampled on the agent's side of the wall, like
        // MiniGrid's `place_obj(top=(0,0), size=(splitIdx, height))`.
        let key_p = s.sample_free_in(1, 1, h - 1, split, false)?;
        s.add_key(key_p, Color::Yellow);
        let agent_p = s.sample_free_in(1, 1, h - 1, split, false)?;
        let dir = Direction::from_i32({
            let mut rng = s.rng();
            rng.randint(0, 4)
        });
        s.place_player(agent_p, dir);
    } else {
        s.place_player(Pos::new(1, 1), Direction::East);
        // key below the agent, canonical slot
        let key_r = (h - 2).min(h / 2 + 1);
        let key_c = (split - 1).max(1);
        s.add_key(Pos::new(key_r, key_c), Color::Yellow);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};
    use crate::systems::intervention::intervene;

    #[test]
    fn canonical_layout_has_locked_door_and_key_left() {
        let cfg = make("Navix-DoorKey-8x8-v0").unwrap();
        let st = reset_once(&cfg, 0);
        let s = st.slot(0);
        assert_eq!(s.door_pos.iter().filter(|&&d| d >= 0).count(), 1);
        assert_eq!(DoorState::from_u8(s.door_state[0]), DoorState::Locked);
        let door = Pos::decode(s.door_pos[0], s.w);
        let key = Pos::decode(s.key_pos[0], s.w);
        assert!(key.c < door.c, "key must be on the agent side");
        assert!(s.player().c < door.c);
        let goal = goal_pos(&st, 0).expect("DoorKey has a goal");
        // goal unreachable without passing the door…
        assert!(!reachable(&st, 0, goal, false));
        // …but reachable through it.
        assert!(reachable(&st, 0, goal, true));
    }

    #[test]
    fn random_layout_always_solvable() {
        let cfg = make("Navix-DoorKey-Random-8x8").unwrap();
        for seed in 0..30 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let key = Pos::decode(s.key_pos[0], s.w);
            let door = Pos::decode(s.door_pos[0], s.w);
            assert!(key.c < door.c, "seed {seed}: key right of wall");
            assert!(s.player().c < door.c, "seed {seed}: agent right of wall");
            assert!(reachable(&st, 0, key, false), "seed {seed}: key unreachable");
            let goal = goal_pos(&st, 0).expect("DoorKey has a goal");
            assert!(reachable(&st, 0, goal, true), "seed {seed}: goal blocked");
        }
    }

    #[test]
    fn full_task_is_completable_by_script() {
        // Drive the canonical 5x5 instance through the whole task to pin the
        // door/key interaction end-to-end.
        let cfg = make("Navix-DoorKey-5x5-v0").unwrap();
        let mut st = reset_once(&cfg, 0);
        let mut s = st.slot_mut(0);
        // layout (5x5): wall at col 2, door at (2,2), key at (3,1),
        // agent (1,1) facing east.
        intervene(&mut s, Action::Right); // face south
        intervene(&mut s, Action::Forward); // (2,1)
        intervene(&mut s, Action::Pickup); // key at (3,1)
        assert!(!s.pocket_value().is_empty(), "picked the key");
        intervene(&mut s, Action::Left); // face east
        intervene(&mut s, Action::Toggle); // unlock door at (2,2)
        assert_eq!(DoorState::from_u8(s.door_state[0]), DoorState::Open);
        intervene(&mut s, Action::Forward); // through the door (2,2)
        intervene(&mut s, Action::Forward); // (2,3)
        intervene(&mut s, Action::Right); // face south
        intervene(&mut s, Action::Forward); // (3,3) = goal
        assert!(s.events[0].goal_reached, "goal event after unlocking the door");
    }
}
