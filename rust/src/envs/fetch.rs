//! Fetch-{N}x{N}-N{k}: an empty room scattered with `k` random objects
//! (keys and balls of random colours); the mission is to pick up the target
//! object's kind+colour. Picking up any object ends the episode, but only
//! the target pays (MiniGrid's `FetchEnv`).

use crate::core::components::{Color, Direction};
use crate::core::entities::Tag;
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

pub fn generate(s: &mut SlotMut<'_>, n_objs: usize) -> Result<(), PlacementError> {
    s.fill_room();

    let mut placed: Vec<(i32, u8)> = Vec::with_capacity(n_objs);
    for _ in 0..n_objs {
        let (is_key, ci) = {
            let mut rng = s.rng();
            (rng.below(2) == 0, rng.below(6) as u8)
        };
        let p = s.sample_free_cell(false)?;
        if is_key {
            s.add_key(p, Color::from_u8(ci));
            placed.push((Tag::KEY, ci));
        } else {
            s.add_ball(p, Color::from_u8(ci));
            placed.push((Tag::BALL, ci));
        }
    }

    // Mission: one of the placed objects, chosen uniformly (duplicates of
    // the target kind+colour all satisfy the mission, as upstream).
    let target = {
        let mut rng = s.rng();
        rng.below(n_objs as u32) as usize
    };
    let (tag, ci) = placed[target];
    s.set_mission(Mission::pick_up(tag, Color::from_u8(ci)));

    let agent = s.sample_free_cell(false)?;
    let dir = {
        let mut rng = s.rng();
        rng.randint(0, 4)
    };
    s.place_player(agent, Direction::from_i32(dir));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::core::grid::Pos;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, object_exists, reset_once};
    use crate::systems::intervention::intervene;

    #[test]
    fn mission_targets_a_placed_object_and_no_goal_exists() {
        for id in ["Navix-Fetch-5x5-N2-v0", "Navix-Fetch-8x8-N3-v0"] {
            let cfg = make(id).unwrap();
            for seed in 0..15 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                assert!(goal_pos(&st, 0).is_none(), "{id}: Fetch is goal-less");
                let m = s.mission_value();
                assert!(
                    object_exists(&s, m.kind_tag(), m.color() as u8),
                    "{id} seed {seed}: mission targets a missing object"
                );
            }
        }
    }

    #[test]
    fn object_counts_match_spec() {
        let cfg = make("Navix-Fetch-8x8-N3-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let n = s.key_pos.iter().filter(|&&k| k >= 0).count()
                + s.ball_pos.iter().filter(|&&b| b >= 0).count();
            assert_eq!(n, 3, "seed {seed}");
        }
    }

    #[test]
    fn picking_the_target_succeeds_and_wrong_object_terminates_unpaid() {
        // Deterministic construction — no seed hunting: build the
        // wrong-object layout by hand through the typed Mission API, so the
        // test can never flake on an unlucky seed range (nor panic with
        // "no seed produced a non-target object").
        let cfg = make("Navix-Fetch-8x8-N3-v0").unwrap();
        let mut st = crate::core::state::BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        {
            let mut s = st.slot_mut(0);
            s.fill_room();
            s.add_ball(Pos::new(2, 2), Color::Red); // the mission target
            s.add_key(Pos::new(4, 4), Color::Blue); // a non-target object
            s.set_mission(Mission::pick_up(Tag::BALL, Color::Red));
            // Wrong object first: terminate, unpaid.
            s.place_player(Pos::new(4, 3), Direction::East);
            intervene(&mut s, Action::Pickup);
            assert!(s.events[0].wrong_pickup);
            assert!(!s.events[0].object_picked);
        }
        assert!(cfg.termination.eval(&st.slot(0)), "wrong pickup must end the episode");
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Pickup, cfg.max_steps), 0.0);
        {
            // Fresh slot (entities + pocket cleared): the target pickup
            // pays and terminates.
            let mut s = st.slot_mut(0);
            s.clear_entities();
            s.fill_room();
            s.add_ball(Pos::new(2, 2), Color::Red);
            s.add_key(Pos::new(4, 4), Color::Blue);
            s.set_mission(Mission::pick_up(Tag::BALL, Color::Red));
            s.place_player(Pos::new(2, 1), Direction::East);
            intervene(&mut s, Action::Pickup);
            assert!(s.events[0].object_picked);
            assert!(s.events[0].ball_picked, "target ball pickup also latches ball_picked");
            assert!(!s.events[0].wrong_pickup);
        }
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Pickup, cfg.max_steps), 1.0);
    }
}
