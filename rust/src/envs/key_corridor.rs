//! KeyCorridorS{S}R{R}: a 3×R grid of S-sized rooms around a central
//! corridor. The target ball sits in a right-side room behind a *locked*
//! door; the matching key is hidden in a left-side room. Success = picking
//! up the ball (paper Tables 5/6: `on_ball_picked`).
//!
//! Geometry follows MiniGrid's RoomGrid: rooms share walls, so
//! `W = 3(S−1)+1` and `H = R(S−1)+1` — which reproduces the Table-8 sizes
//! (S3R1: 3×7, S3R3: 7×7, S6R3: 16×16, …).

use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::{CellType, Tag};
use crate::core::grid::Pos;
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

/// Grid height/width for a given (size, rows).
pub fn dims(size: usize, rows: usize) -> (usize, usize) {
    (rows * (size - 1) + 1, 3 * (size - 1) + 1)
}

pub fn generate(s: &mut SlotMut<'_>, size: usize, rows: usize) -> Result<(), PlacementError> {
    let sw = (size - 1) as i32; // room stride
    let (h, w) = (s.h as i32, s.w as i32);
    debug_assert_eq!(h, rows as i32 * sw + 1);
    debug_assert_eq!(w, 3 * sw + 1);

    s.fill_room();
    // Internal vertical walls (corridor boundaries).
    for r in 1..h - 1 {
        s.set_cell(Pos::new(r, sw), CellType::Wall, Color::Grey);
        s.set_cell(Pos::new(r, 2 * sw), CellType::Wall, Color::Grey);
    }
    // Internal horizontal walls between room rows.
    for k in 1..rows as i32 {
        for c in 1..w - 1 {
            s.set_cell(Pos::new(k * sw, c), CellType::Wall, Color::Grey);
        }
    }
    // Corridor: carve gaps through the horizontal walls in the middle column.
    let mid_c = sw + sw / 2 + (sw % 2); // centre column of the corridor
    for k in 1..rows as i32 {
        s.set_cell(Pos::new(k * sw, mid_c), CellType::Floor, Color::Grey);
    }

    // Choose the locked room (right side), the key room (left side) and
    // colours.
    let (locked_row, key_row, door_color_i, ball_color_i) = {
        let mut rng = s.rng();
        (
            rng.below(rows as u32) as i32,
            rng.below(rows as u32) as i32,
            rng.below(6) as u8,
            rng.below(6) as u8,
        )
    };
    let door_color = Color::from_u8(door_color_i);
    let ball_color = Color::from_u8(ball_color_i);

    // Side doors: one per room per side, centred on the shared wall. The
    // base cell under a door is floor (doors replace wall cells).
    for j in 0..rows as i32 {
        let door_r = j * sw + sw / 2 + (sw % 2);
        let left_state = DoorState::Closed;
        let right_state =
            if j == locked_row { DoorState::Locked } else { DoorState::Closed };
        let left_color = if j == key_row { door_color } else { Color::Grey };
        let right_color = if j == locked_row { door_color } else { Color::Grey };
        s.set_cell(Pos::new(door_r, sw), CellType::Floor, Color::Grey);
        s.set_cell(Pos::new(door_r, 2 * sw), CellType::Floor, Color::Grey);
        s.add_door(Pos::new(door_r, sw), left_color, left_state);
        s.add_door(Pos::new(door_r, 2 * sw), right_color, right_state);
    }

    // Target ball in the centre of the locked right room.
    let ball_p = Pos::new(locked_row * sw + sw / 2 + (sw % 2), 2 * sw + sw / 2 + (sw % 2));
    s.add_ball(ball_p, ball_color);
    s.set_mission(Mission::pick_up(Tag::BALL, ball_color));

    // Key in the centre of the chosen left room.
    let key_p = Pos::new(key_row * sw + sw / 2 + (sw % 2), (sw / 2).max(1));
    s.add_key(key_p, door_color);

    // Agent somewhere in the corridor, random direction.
    let corridor_cells: Vec<Pos> = (1..h - 1)
        .flat_map(|r| (sw + 1..2 * sw).map(move |c| Pos::new(r, c)))
        .filter(|&p| s.cell(p) == CellType::Floor && !s.occupied_by_entity(p))
        .collect();
    if corridor_cells.is_empty() {
        return Err(PlacementError {
            h: s.h,
            w: s.w,
            r0: 1,
            c0: sw + 1,
            r1: h - 1,
            c1: 2 * sw,
        });
    }
    let (pick, dir) = {
        let mut rng = s.rng();
        (rng.below(corridor_cells.len() as u32) as usize, rng.randint(0, 4))
    };
    s.place_player(corridor_cells[pick], Direction::from_i32(dir));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{reachable, reset_once};

    #[test]
    fn dims_match_table8() {
        assert_eq!(dims(3, 1), (3, 7));
        assert_eq!(dims(3, 2), (5, 7));
        assert_eq!(dims(3, 3), (7, 7));
        assert_eq!(dims(4, 3), (10, 10));
        assert_eq!(dims(5, 3), (13, 13));
        assert_eq!(dims(6, 3), (16, 16));
    }

    #[test]
    fn exactly_one_locked_door_with_matching_key() {
        for id in [
            "Navix-KeyCorridorS3R1-v0",
            "Navix-KeyCorridorS3R2-v0",
            "Navix-KeyCorridorS3R3-v0",
            "Navix-KeyCorridorS4R3-v0",
            "Navix-KeyCorridorS5R3-v0",
            "Navix-KeyCorridorS6R3-v0",
        ] {
            let cfg = make(id).unwrap();
            for seed in 0..10 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                let locked: Vec<usize> = (0..s.door_pos.len())
                    .filter(|&d| {
                        s.door_pos[d] >= 0
                            && DoorState::from_u8(s.door_state[d]) == DoorState::Locked
                    })
                    .collect();
                assert_eq!(locked.len(), 1, "{id} seed {seed}");
                assert_eq!(
                    s.key_color[0], s.door_color[locked[0]],
                    "{id} seed {seed}: key colour must open the locked door"
                );
            }
        }
    }

    #[test]
    fn ball_behind_locked_door_key_reachable() {
        let cfg = make("Navix-KeyCorridorS3R3-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let ball = Pos::decode(s.ball_pos[0], s.w);
            let key = Pos::decode(s.key_pos[0], s.w);
            // ball is not freely reachable (locked door in the way)…
            // (it may be reachable if the locked room's door is the only
            // door — assert the strong topological property instead)
            assert!(reachable(&st, 0, ball, true), "seed {seed}: ball not behind doors only");
            assert!(reachable(&st, 0, key, true), "seed {seed}: key unreachable");
            // mission targets the ball colour
            assert_eq!(s.mission_value().kind_tag(), Tag::BALL);
            assert_eq!(s.mission_value().color() as u8, s.ball_color[0]);
        }
    }

    #[test]
    fn agent_starts_in_corridor() {
        let cfg = make("Navix-KeyCorridorS4R3-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let p = s.player();
            let sw = 3; // size 4 → stride 3
            assert!(p.c > sw && p.c < 2 * sw, "seed {seed}: agent at {p:?} not in corridor");
        }
    }
}
