//! Difficulty-parameterised curriculum over a RoomGrid chain
//! (`Navix-Curriculum-RoomGrid-v0`).
//!
//! One fixed 1×3 RoomGrid geometry hosts [`LEVELS`] difficulty levels, each
//! a [`Difficulty`] knob setting: effective room count (the unused chain
//! wall is removed outright), distractor-ball count, lock depth (how many
//! chain doors are locked, counted from the far end, each with a matching
//! key in the start room) and mission clause depth (a plain "pick up the
//! box" vs "open the far door, then pick up the box" sequence). The level
//! is drawn from the slot's own RNG stream at the top of generation —
//! a pure function of the episode key, so the per-slot schedule is
//! deterministic and bitwise shard-invariant — or pinned via
//! [`Layout::CurriculumRoomGrid`](super::Layout)'s `level` for the
//! fixed-difficulty registry aliases (`...-L0-v0` … `...-L3-v0`).
//!
//! Generation *rejects* unsatisfiable draws instead of shipping them: after
//! placement, a slot-level BFS checks that every key and the target box are
//! physically reachable (doors passable, other entities blocking — a
//! distractor ball can plug a 1-wide doorway). A failed check surfaces as a
//! [`PlacementError`], and the engines' shared
//! [`retry_episode_keys`](super::retry_episode_keys) loop deterministically
//! burns the episode key and tries the successor — rejection is a pure
//! function of the key, never a panic and never shard-dependent.

use super::roomgrid::RoomGrid;
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::Tag;
use crate::core::grid::Pos;
use crate::core::mission::{Mission, MissionClause, MissionSpec};
use crate::core::state::{AgentView, PlacementError, SlotMut};
use std::collections::VecDeque;

/// MiniGrid `room_size` of every room in the chain.
pub const ROOM_SIZE: usize = 5;

/// Rooms in the chain (left → right; the agent starts in room 0, the target
/// box sits in the last room).
pub const ROOMS: usize = 3;

/// Number of difficulty levels in the curriculum.
pub const LEVELS: u8 = 4;

/// Grid dims of the (level-independent) geometry: 5×13.
pub fn dims() -> (usize, usize) {
    RoomGrid::new(ROOM_SIZE, 1, ROOMS).dims()
}

/// The four curriculum knobs one level fixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Difficulty {
    /// Effective rooms (2 merges rooms 0–1 into one start room).
    pub rooms: usize,
    /// Distractor balls scattered across random rooms.
    pub distractors: usize,
    /// Chain doors locked, counted from the far end (each key in room 0).
    pub lock_depth: usize,
    /// Mission clauses: 1 = pick up the box, 2 = open-then-pick-up.
    pub clause_depth: usize,
}

impl Difficulty {
    /// The monotone level → knobs schedule.
    pub fn from_level(level: u8) -> Difficulty {
        match level {
            0 => Difficulty { rooms: 2, distractors: 0, lock_depth: 0, clause_depth: 1 },
            1 => Difficulty { rooms: 2, distractors: 1, lock_depth: 1, clause_depth: 1 },
            2 => Difficulty { rooms: 3, distractors: 1, lock_depth: 1, clause_depth: 2 },
            _ => Difficulty { rooms: 3, distractors: 2, lock_depth: 2, clause_depth: 2 },
        }
    }
}

/// Slot-level BFS from the agent: doors count as passable (the curriculum
/// guarantees their keys), other entities block, and the target cell itself
/// is exempt. This is deliberately stricter than topological reachability —
/// a distractor ball sitting directly behind a doorway *does* fail the
/// check, which is exactly the draw the generator rejects.
fn entity_reachable(s: &SlotMut<'_>, target: Pos) -> bool {
    let start = s.player();
    let mut seen = vec![false; s.h * s.w];
    let mut queue = VecDeque::new();
    seen[(start.r as usize) * s.w + start.c as usize] = true;
    queue.push_back(start);
    while let Some(p) = queue.pop_front() {
        if p == target {
            return true;
        }
        for d in Direction::ALL {
            let q = p.step(d);
            if !q.in_bounds(s.h, s.w) {
                continue;
            }
            let qi = (q.r as usize) * s.w + q.c as usize;
            if seen[qi] {
                continue;
            }
            if q == target || s.door_at(q).is_some() || s.walkable(q) {
                seen[qi] = true;
                queue.push_back(q);
            }
        }
    }
    false
}

/// Build one curriculum episode. `level` pins the difficulty; `None` draws
/// it from the slot RNG (the per-slot schedule).
pub fn generate(s: &mut SlotMut<'_>, level: Option<u8>) -> Result<(), PlacementError> {
    let lvl = match level {
        Some(l) => l.min(LEVELS - 1),
        None => {
            let mut rng = s.rng();
            rng.below(LEVELS as u32) as u8
        }
    };
    let d = Difficulty::from_level(lvl);
    let rg = RoomGrid::new(ROOM_SIZE, 1, ROOMS);
    rg.carve(s);

    // Distinct colours for the two chain doors, the box and the
    // distractors, all from one shuffle so the instruction is unambiguous.
    let mut colors = Color::ALL;
    {
        let mut rng = s.rng();
        for i in (1..colors.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            colors.swap(i, j);
        }
    }
    let (far_color, near_color, box_color) = (colors[0], colors[1], colors[2]);

    // The chain: rooms 0 → 1 → 2. The far wall (1|2) always carries a door;
    // the near wall (0|1) carries one only at 3 effective rooms, and is
    // removed outright at 2 (one big start room).
    let far_state = if d.lock_depth >= 1 { DoorState::Locked } else { DoorState::Closed };
    rg.add_door(s, 0, 1, Direction::East, far_color, far_state);
    if d.rooms >= 3 {
        let near_state = if d.lock_depth >= 2 { DoorState::Locked } else { DoorState::Closed };
        rg.add_door(s, 0, 0, Direction::East, near_color, near_state);
    } else {
        rg.remove_wall(s, 0, 0, Direction::East);
    }

    // Matching keys, far lock first, all in the start room.
    let mut key_ps = Vec::new();
    if d.lock_depth >= 1 {
        let p = rg.place_in_room(s, 0, 0, false)?;
        s.add_key(p, far_color);
        key_ps.push(p);
    }
    if d.lock_depth >= 2 {
        let p = rg.place_in_room(s, 0, 0, false)?;
        s.add_key(p, near_color);
        key_ps.push(p);
    }

    // The target box in the last room, then the distractor balls anywhere.
    let box_p = rg.place_in_room(s, 0, ROOMS - 1, false)?;
    s.add_box(box_p, box_color);
    for k in 0..d.distractors {
        let room = {
            let mut rng = s.rng();
            rng.below(ROOMS as u32) as usize
        };
        let p = rg.place_in_room(s, 0, room, false)?;
        s.add_ball(p, colors[3 + k]);
    }

    rg.place_agent(s, 0, 0)?;
    if d.clause_depth >= 2 {
        s.set_mission_spec(MissionSpec::then(
            MissionClause::Open { color: far_color },
            MissionClause::PickUp { kind: Tag::BOX, color: box_color },
        ));
    } else {
        s.set_mission(Mission::pick_up(Tag::BOX, box_color));
    }

    // Satisfiability gate: every key and the box must be reachable.
    // Reject (→ deterministic episode-key retry) instead of shipping an
    // unwinnable draw.
    let (h, w) = (s.h, s.w);
    for &t in key_ps.iter().chain(std::iter::once(&box_p)) {
        if !entity_reachable(s, t) {
            return Err(PlacementError { h, w, r0: 0, c0: 0, r1: h as i32, c1: w as i32 });
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::state::{BatchedState, Caps};
    use crate::envs::registry::make;
    use crate::envs::testutil::{reachable, reset_once};
    use crate::rng::Key;

    fn raw_state() -> BatchedState {
        let (h, w) = dims();
        BatchedState::new(1, h, w, Caps { doors: 2, keys: 2, balls: 2, boxes: 1 })
    }

    #[test]
    fn difficulty_schedule_is_monotone() {
        for l in 1..LEVELS {
            let (lo, hi) = (Difficulty::from_level(l - 1), Difficulty::from_level(l));
            assert!(hi.rooms >= lo.rooms, "level {l}");
            assert!(hi.lock_depth >= lo.lock_depth, "level {l}");
            assert!(hi.clause_depth >= lo.clause_depth, "level {l}");
            assert!(
                hi.rooms + hi.distractors + hi.lock_depth + hi.clause_depth
                    > lo.rooms + lo.distractors + lo.lock_depth + lo.clause_depth,
                "level {l} must be strictly harder overall"
            );
        }
    }

    #[test]
    fn per_level_knobs_shape_the_layout() {
        for lvl in 0..LEVELS {
            let d = Difficulty::from_level(lvl);
            for seed in 0..10u64 {
                let mut st = raw_state();
                let mut s = st.slot_mut(0);
                *s.rng = seed;
                s.clear_entities();
                if generate(&mut s, Some(lvl)).is_err() {
                    continue; // rejected draw; the engines retry the key
                }
                let n_doors = s.door_pos.iter().filter(|&&p| p >= 0).count();
                let n_keys = s.key_pos.iter().filter(|&&p| p >= 0).count();
                let n_balls = s.ball_pos.iter().filter(|&&p| p >= 0).count();
                assert_eq!(n_doors, d.rooms - 1, "level {lvl} seed {seed}: chain doors");
                assert_eq!(n_keys, d.lock_depth, "level {lvl} seed {seed}: one key per lock");
                assert_eq!(n_balls, d.distractors, "level {lvl} seed {seed}: distractors");
                let locked = (0..s.door_pos.len())
                    .filter(|&x| {
                        s.door_pos[x] >= 0
                            && DoorState::from_u8(s.door_state[x]) == DoorState::Locked
                    })
                    .count();
                assert_eq!(locked, d.lock_depth, "level {lvl} seed {seed}: lock depth");
                let spec = s.mission_spec();
                assert_eq!(spec.len(), d.clause_depth, "level {lvl} seed {seed}: clause depth");
                match spec.clause(spec.len() - 1) {
                    Some(MissionClause::PickUp { kind: Tag::BOX, .. }) => {}
                    other => panic!("level {lvl} seed {seed}: final clause must pick the box, got {other:?}"),
                }
                if d.clause_depth == 2 {
                    // Clause 1 names the far (locked) chain door.
                    let far = match spec.clause(0) {
                        Some(MissionClause::Open { color }) => color as u8,
                        other => panic!("level {lvl} seed {seed}: clause 1 must be Open, got {other:?}"),
                    };
                    assert!(
                        (0..s.door_pos.len())
                            .any(|x| s.door_pos[x] >= 0 && s.door_color[x] == far),
                        "level {lvl} seed {seed}: clause-1 colour has no door"
                    );
                }
            }
        }
    }

    #[test]
    fn generation_and_rejection_are_pure_functions_of_the_key() {
        // Rejection must be deterministic: same key → same outcome and same
        // layout, which is what keeps key-retry shard-invariant.
        for lvl in [None, Some(0), Some(3)] {
            for seed in 0..20u64 {
                let build = |seed: u64| {
                    let mut st = raw_state();
                    let mut s = st.slot_mut(0);
                    *s.rng = seed;
                    s.clear_entities();
                    let ok = generate(&mut s, lvl).is_ok();
                    drop(s);
                    (ok, st.base.clone(), st.door_pos.clone(), st.key_pos.clone(),
                     st.ball_pos.clone(), st.box_pos.clone(), st.player_pos.clone(),
                     st.mission_tokens.clone())
                };
                assert_eq!(build(seed), build(seed), "level {lvl:?} seed {seed}");
            }
        }
    }

    #[test]
    fn registry_reset_always_lands_a_solvable_episode() {
        // The full reset path (rejection → key retry) must always deliver:
        // box topologically reachable, and for 2-clause draws the far door
        // open-able (its key reachable too — pinned by the in-generator
        // check, re-verified here through the public reset).
        let cfg = make("Navix-Curriculum-RoomGrid-v0").unwrap();
        for seed in 0..25u64 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let bx = Pos::decode(s.box_pos[0], s.w);
            assert!(reachable(&st, 0, bx, true), "seed {seed}: box unreachable through doors");
            for k in 0..s.key_pos.len() {
                if s.key_pos[k] >= 0 {
                    let kp = Pos::decode(s.key_pos[k], s.w);
                    assert!(reachable(&st, 0, kp, true), "seed {seed}: key {k} unreachable");
                }
            }
            assert!(!s.mission_value().is_none(), "seed {seed}: curriculum always sets a mission");
        }
    }

    #[test]
    fn fixed_level_aliases_pin_the_difficulty() {
        for (id, lvl) in [
            ("Navix-Curriculum-RoomGrid-L0-v0", 0u8),
            ("Navix-Curriculum-RoomGrid-L1-v0", 1),
            ("Navix-Curriculum-RoomGrid-L2-v0", 2),
            ("Navix-Curriculum-RoomGrid-L3-v0", 3),
        ] {
            let cfg = make(id).unwrap();
            let d = Difficulty::from_level(lvl);
            for seed in 0..5u64 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                assert_eq!(
                    s.key_pos.iter().filter(|&&p| p >= 0).count(),
                    d.lock_depth,
                    "{id} seed {seed}"
                );
                assert_eq!(s.mission_spec().len(), d.clause_depth, "{id} seed {seed}");
            }
        }
    }

    #[test]
    fn mixed_schedule_draws_every_level() {
        // The level draw comes first in the RNG stream, so the id without a
        // pinned level must visit all difficulties across episode keys.
        let cfg = make("Navix-Curriculum-RoomGrid-v0").unwrap();
        let mut seen = [false; LEVELS as usize];
        for seed in 0..40u64 {
            let st = reset_once(&cfg, seed);
            let spec = st.slot(0).mission_spec();
            let keys = st.slot(0).key_pos.iter().filter(|&&p| p >= 0).count();
            // (clause_depth, lock_depth) identifies the level uniquely
            // except L0/L1, which the key count separates.
            let lvl = match (spec.len(), keys) {
                (1, 0) => 0,
                (1, 1) => 1,
                (2, 1) => 2,
                (2, 2) => 3,
                other => panic!("seed {seed}: knobs {other:?} match no level"),
            };
            seen[lvl] = true;
        }
        assert!(seen.iter().all(|&x| x), "per-slot schedule must cover all levels: {seen:?}");
    }

    #[test]
    fn reset_slot_keeps_working_on_a_multi_env_batch() {
        // Mirrors the engine autoreset pattern: a fresh slot borrow per
        // attempt, successor keys on rejection.
        let cfg = make("Navix-Curriculum-RoomGrid-v0").unwrap();
        let mut st = BatchedState::new(3, cfg.h, cfg.w, cfg.caps);
        for i in 0..3 {
            let root = Key::new(0xC0FFEE).fold_in(i as u64);
            crate::envs::retry_episode_keys(&cfg.id, root, |t| {
                cfg.reset_slot(&mut st.slot_mut(i), root.fold_in(t as u64))
            });
            assert!(!st.slot(i).mission_value().is_none(), "slot {i} must carry a mission");
        }
    }
}
