//! MultiRoom-N{n}: a chain of `n` randomly-sized, randomly-placed rooms
//! connected by coloured doors; the agent starts in the first room and the
//! goal sits in the last (MiniGrid's `MultiRoomEnv`, 25×25 for every
//! registered size). Built on the free-form carving primitives of
//! [`super::roomgrid`].
//!
//! Placement is a bounded random walk over room rectangles: each candidate
//! room hangs off a door cell on the previous room's wall, rejected if it
//! leaves the grid or intersects any earlier room. MiniGrid retries this
//! loop unboundedly (and its `_gen_grid` can raise); here attempts are
//! bounded and the best (longest) chain found is used, so generation is
//! total — a crowded draw degrades to a shorter chain instead of panicking
//! or hanging. All randomness is drawn from the slot RNG stream, keeping
//! layouts a pure function of the episode key (shard-invariant).

use super::roomgrid::{carve_room_rect, set_door};
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Minimum room edge (MiniGrid's `minRoomSize`).
const MIN_SIZE: i32 = 4;
/// Full-restart attempts before settling for the longest chain found.
const CHAIN_ATTEMPTS: usize = 12;
/// Per-room placement attempts within one chain (MiniGrid uses 8).
const ROOM_TRIES: usize = 8;

/// One placed room: bounding box plus the door cell shared with the
/// previous room of the chain (`entry` is (−1,−1) for the first room).
#[derive(Clone, Copy, Debug)]
struct RoomRect {
    top: Pos,
    h: i32,
    w: i32,
    entry: Pos,
}

impl RoomRect {
    fn intersects(&self, o: &RoomRect) -> bool {
        self.top.r < o.top.r + o.h
            && o.top.r < self.top.r + self.h
            && self.top.c < o.top.c + o.w
            && o.top.c < self.top.c + self.w
    }
}

pub fn generate(s: &mut SlotMut<'_>, n: usize, max_size: usize) -> Result<(), PlacementError> {
    let (h, w) = (s.h as i32, s.w as i32);
    let max_size = (max_size as i32).min(h).min(w);
    debug_assert!(max_size >= MIN_SIZE, "MultiRoom needs room for a {MIN_SIZE}-cell room");

    // Outside the rooms the grid is solid wall (MiniGrid leaves it void;
    // wall is equivalent for an agent that can never reach it).
    for r in 0..h {
        for c in 0..w {
            s.set_cell(Pos::new(r, c), CellType::Wall, Color::Grey);
        }
    }

    let mut rooms: Vec<RoomRect> = Vec::new();
    for _ in 0..CHAIN_ATTEMPTS {
        let chain = try_chain(s, h, w, n, max_size);
        if chain.len() > rooms.len() {
            rooms = chain;
        }
        if rooms.len() >= n {
            break;
        }
    }

    for room in &rooms {
        carve_room_rect(s, room.top, room.h, room.w);
    }

    // Doors between consecutive rooms; consecutive door colours differ
    // (MiniGrid's door-colour rule).
    let mut prev_color: Option<u8> = None;
    for room in rooms.iter().skip(1) {
        let mut ci = {
            let mut rng = s.rng();
            rng.below(Color::ALL.len() as u32) as u8
        };
        if prev_color == Some(ci) {
            ci = (ci + 1) % Color::ALL.len() as u8;
        }
        prev_color = Some(ci);
        set_door(s, room.entry, Color::from_u8(ci), DoorState::Closed);
    }

    // Goal in the last room, agent in the first (goal first: its cell stops
    // being floor, so the agent sample can never land on it).
    let last = rooms[rooms.len() - 1];
    let goal = s.sample_free_in(
        last.top.r + 1,
        last.top.c + 1,
        last.top.r + last.h - 1,
        last.top.c + last.w - 1,
        false,
    )?;
    s.set_cell(goal, CellType::Goal, Color::Green);
    let first = rooms[0];
    let agent = s.sample_free_in(
        first.top.r + 1,
        first.top.c + 1,
        first.top.r + first.h - 1,
        first.top.c + first.w - 1,
        false,
    )?;
    let dir = {
        let mut rng = s.rng();
        rng.randint(0, 4)
    };
    s.place_player(agent, Direction::from_i32(dir));
    Ok(())
}

/// One bounded random-walk attempt at an `n`-room chain. Always returns at
/// least one room (the seed room always fits).
fn try_chain(s: &mut SlotMut<'_>, h: i32, w: i32, n: usize, max_size: i32) -> Vec<RoomRect> {
    let mut rooms: Vec<RoomRect> = Vec::new();
    {
        let mut rng = s.rng();
        let rh = rng.randint(MIN_SIZE, max_size + 1);
        let rw = rng.randint(MIN_SIZE, max_size + 1);
        let top = Pos::new(rng.randint(0, h - rh + 1), rng.randint(0, w - rw + 1));
        rooms.push(RoomRect { top, h: rh, w: rw, entry: Pos::new(-1, -1) });
    }

    while rooms.len() < n {
        let mut placed = false;
        for _ in 0..ROOM_TRIES {
            let prev = rooms[rooms.len() - 1];
            let (dir, door, nh, nw, off) = {
                let mut rng = s.rng();
                let dir = Direction::from_i32(rng.randint(0, 4));
                // Door on prev's wall in that direction, never a corner.
                let door = match dir {
                    Direction::East => {
                        Pos::new(prev.top.r + rng.randint(1, prev.h - 1), prev.top.c + prev.w - 1)
                    }
                    Direction::West => {
                        Pos::new(prev.top.r + rng.randint(1, prev.h - 1), prev.top.c)
                    }
                    Direction::South => {
                        Pos::new(prev.top.r + prev.h - 1, prev.top.c + rng.randint(1, prev.w - 1))
                    }
                    Direction::North => {
                        Pos::new(prev.top.r, prev.top.c + rng.randint(1, prev.w - 1))
                    }
                };
                let nh = rng.randint(MIN_SIZE, max_size + 1);
                let nw = rng.randint(MIN_SIZE, max_size + 1);
                let along = if matches!(dir, Direction::East | Direction::West) { nh } else { nw };
                // Where the door falls along the new room's entry wall.
                let off = rng.randint(1, along - 1);
                (dir, door, nh, nw, off)
            };
            // Position the new room so its entry wall contains `door`: the
            // new rect starts on (shares) prev's wall line.
            let top = match dir {
                Direction::East => Pos::new(door.r - off, door.c),
                Direction::West => Pos::new(door.r - off, door.c - nw + 1),
                Direction::South => Pos::new(door.r, door.c - off),
                Direction::North => Pos::new(door.r - nh + 1, door.c - off),
            };
            let cand = RoomRect { top, h: nh, w: nw, entry: door };
            if top.r < 0 || top.c < 0 || top.r + nh > h || top.c + nw > w {
                continue;
            }
            // Strict separation from every room except the immediate
            // predecessor (which legitimately shares the entry wall line).
            if rooms[..rooms.len() - 1].iter().any(|r| cand.intersects(r)) {
                continue;
            }
            rooms.push(cand);
            placed = true;
            break;
        }
        if !placed {
            break;
        }
    }
    rooms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::components::DoorState;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn chains_place_agent_and_goal_in_connected_rooms() {
        for id in
            ["Navix-MultiRoom-N2-S4-v0", "Navix-MultiRoom-N4-S5-v0", "Navix-MultiRoom-N6-v0"]
        {
            let cfg = make(id).unwrap();
            for seed in 0..15 {
                let st = reset_once(&cfg, seed);
                let goal = goal_pos(&st, 0).expect("MultiRoom always has a goal");
                assert!(
                    reachable(&st, 0, goal, true),
                    "{id} seed {seed}: goal not reachable through doors"
                );
                let s = st.slot(0);
                // every placed door is closed (not locked) per MiniGrid
                for d in 0..s.door_pos.len() {
                    if s.door_pos[d] >= 0 {
                        assert_eq!(
                            DoorState::from_u8(s.door_state[d]),
                            DoorState::Closed,
                            "{id} seed {seed}: MultiRoom doors are never locked"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn full_chains_are_the_common_case() {
        // The bounded walk must almost always reach the requested room
        // count; assert every N4 seed in a window yields the full chain
        // (4 rooms → 3 doors) and layouts vary across seeds.
        let cfg = make("Navix-MultiRoom-N4-S5-v0").unwrap();
        let mut full = 0;
        let mut layouts = std::collections::HashSet::new();
        for seed in 0..20 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let doors = s.door_pos.iter().filter(|&&d| d >= 0).count();
            assert!(doors >= 1, "seed {seed}: chain collapsed to a single room");
            if doors == 3 {
                full += 1;
            }
            layouts.insert(st.base.clone());
        }
        assert!(full >= 15, "only {full}/20 seeds produced a full 4-room chain");
        assert!(layouts.len() > 10, "room plans should vary: {}", layouts.len());
    }

    #[test]
    fn consecutive_door_colors_differ() {
        let cfg = make("Navix-MultiRoom-N6-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let colors: Vec<u8> = (0..s.door_pos.len())
                .filter(|&d| s.door_pos[d] >= 0)
                .map(|d| s.door_color[d])
                .collect();
            for pair in colors.windows(2) {
                assert_ne!(pair[0], pair[1], "seed {seed}: consecutive doors share a colour");
            }
        }
    }
}
