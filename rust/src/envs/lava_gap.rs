//! LavaGapS{N}: a vertical curtain of lava with a single gap; touching lava
//! terminates with −1 (paper Table 8: R2).

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

pub fn generate(s: &mut SlotMut<'_>) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    let col = w / 2;
    let gap_r = {
        let mut rng = s.rng();
        rng.randint(1, h - 1)
    };
    for r in 1..h - 1 {
        if r != gap_r {
            s.set_cell(Pos::new(r, col), CellType::Lava, Color::Red);
        }
    }
    s.set_cell(Pos::new(h - 2, w - 2), CellType::Goal, Color::Green);
    s.place_player(Pos::new(1, 1), Direction::East);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn curtain_has_exactly_one_gap() {
        for id in ["Navix-LavaGapS5-v0", "Navix-LavaGapS6-v0", "Navix-LavaGapS7-v0"] {
            let cfg = make(id).unwrap();
            for seed in 0..10 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                let col = s.w as i32 / 2;
                let lava: i32 = (1..s.h as i32 - 1)
                    .filter(|&r| s.cell(Pos::new(r, col)) == CellType::Lava)
                    .count() as i32;
                assert_eq!(lava, s.h as i32 - 3, "{id} seed {seed}: wrong lava count");
            }
        }
    }

    #[test]
    fn goal_reachable_through_gap() {
        let cfg = make("Navix-LavaGapS7-v0").unwrap();
        for seed in 0..10 {
            let st = reset_once(&cfg, seed);
            // lava is walkable (that's how you die) so plain reachability
            // holds; also assert a lava-avoiding path exists by checking the
            // gap cell is on floor.
            let goal = goal_pos(&st, 0).expect("LavaGap has a goal");
            assert!(reachable(&st, 0, goal, false), "seed {seed}");
        }
    }

    #[test]
    fn gap_position_varies_with_seed() {
        let cfg = make("Navix-LavaGapS7-v0").unwrap();
        let mut gaps = std::collections::HashSet::new();
        for seed in 0..20 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let col = s.w as i32 / 2;
            for r in 1..s.h as i32 - 1 {
                if s.cell(Pos::new(r, col)) == CellType::Floor {
                    gaps.insert(r);
                }
            }
        }
        assert!(gaps.len() > 1, "gap should move across seeds");
    }
}
