//! SimpleCrossingS{S}N{K} / Crossings (paper Table 8): `K` full-width
//! "rivers" (walls, or lava for the Lava variant) each crossed by a single
//! opening. Rivers sit on even rows/columns and openings on odd ones, so
//! openings never collide with a perpendicular river and the maze is always
//! solvable — the same construction MiniGrid uses.

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

pub fn generate(s: &mut SlotMut<'_>, n: usize, lava: bool) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    let river_cell = if lava { CellType::Lava } else { CellType::Wall };

    // Candidate river coordinates: even rows / even cols strictly inside.
    let mut v_cands: Vec<i32> = (2..w - 2).step_by(2).collect();
    let mut h_cands: Vec<i32> = (2..h - 2).step_by(2).collect();
    {
        let mut rng = s.rng();
        // shuffle both candidate lists with the slot stream
        for i in (1..v_cands.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            v_cands.swap(i, j);
        }
        for i in (1..h_cands.len()).rev() {
            let j = rng.below(i as u32 + 1) as usize;
            h_cands.swap(i, j);
        }
    }

    // Alternate vertical/horizontal rivers like MiniGrid, bounded by what
    // fits in the grid.
    let mut rivers: Vec<(bool, i32)> = Vec::new(); // (vertical?, coord)
    let (mut vi, mut hi) = (0usize, 0usize);
    for k in 0..n {
        if k % 2 == 0 && vi < v_cands.len() {
            rivers.push((true, v_cands[vi]));
            vi += 1;
        } else if hi < h_cands.len() {
            rivers.push((false, h_cands[hi]));
            hi += 1;
        } else if vi < v_cands.len() {
            rivers.push((true, v_cands[vi]));
            vi += 1;
        }
    }

    for &(vertical, coord) in &rivers {
        if vertical {
            for r in 1..h - 1 {
                s.set_cell(Pos::new(r, coord), river_cell, Color::Grey);
            }
        } else {
            for c in 1..w - 1 {
                s.set_cell(Pos::new(coord, c), river_cell, Color::Grey);
            }
        }
    }

    // One opening per river, placed so the openings form a monotone
    // staircase from the start corner to the goal corner — MiniGrid's
    // construction, which guarantees solvability even when rivers cross:
    // crossing river k requires the opening to lie past every previously
    // crossed perpendicular river.
    rivers.sort_by_key(|&(_, coord)| coord);
    let (mut row_lo, mut col_lo) = (1i32, 1i32); // staircase progress
    for (idx, &(vertical, coord)) in rivers.iter().enumerate() {
        // The gap must sit inside the current band: past every crossed
        // perpendicular river (≥ lo) but before the next uncrossed one.
        let next_perp = rivers[idx + 1..]
            .iter()
            .find(|&&(v, _)| v != vertical)
            .map(|&(_, c)| c);
        if vertical {
            let hi = next_perp.unwrap_or(h - 1) - 1;
            let lo = if row_lo % 2 == 0 { row_lo + 1 } else { row_lo };
            debug_assert!(lo <= hi, "no room for a gap in vertical river at {coord}");
            let n_odd = (hi - lo) / 2 + 1; // odd rows in [lo, hi]
            let gap = {
                let mut rng = s.rng();
                lo + 2 * rng.randint(0, n_odd)
            };
            s.set_cell(Pos::new(gap, coord), CellType::Floor, Color::Grey);
            col_lo = coord + 1;
        } else {
            let hi = next_perp.unwrap_or(w - 1) - 1;
            let lo = if col_lo % 2 == 0 { col_lo + 1 } else { col_lo };
            debug_assert!(lo <= hi, "no room for a gap in horizontal river at {coord}");
            let n_odd = (hi - lo) / 2 + 1;
            let gap = {
                let mut rng = s.rng();
                lo + 2 * rng.randint(0, n_odd)
            };
            s.set_cell(Pos::new(coord, gap), CellType::Floor, Color::Grey);
            row_lo = coord + 1;
        }
    }

    s.set_cell(Pos::new(h - 2, w - 2), CellType::Goal, Color::Green);
    s.place_player(Pos::new(1, 1), Direction::East);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};

    #[test]
    fn all_registered_crossings_are_solvable() {
        for id in [
            "Navix-SimpleCrossingS9N1-v0",
            "Navix-SimpleCrossingS9N2-v0",
            "Navix-SimpleCrossingS9N3-v0",
            "Navix-SimpleCrossingS11N5-v0",
        ] {
            let cfg = make(id).unwrap();
            for seed in 0..20 {
                let st = reset_once(&cfg, seed);
                let goal = goal_pos(&st, 0).expect("Crossings has a goal");
                assert!(reachable(&st, 0, goal, false), "{id} seed {seed} unsolvable");
            }
        }
    }

    #[test]
    fn river_count_matches_n() {
        let cfg = make("Navix-SimpleCrossingS9N2-v0").unwrap();
        let st = reset_once(&cfg, 4);
        let s = st.slot(0);
        // count full river lines: interior rows/cols that are ≥ (span-3) wall
        let (h, w) = (s.h as i32, s.w as i32);
        let mut lines = 0;
        for c in 1..w - 1 {
            let walls = (1..h - 1).filter(|&r| s.cell(Pos::new(r, c)) == CellType::Wall).count();
            if walls >= (h - 3) as usize {
                lines += 1;
            }
        }
        for r in 1..h - 1 {
            let walls = (1..w - 1).filter(|&c| s.cell(Pos::new(r, c)) == CellType::Wall).count();
            if walls >= (w - 3) as usize {
                lines += 1;
            }
        }
        assert_eq!(lines, 2);
    }

    #[test]
    fn lava_variant_uses_lava() {
        let cfg = make("Navix-LavaCrossingS9N1-v0").unwrap();
        let st = reset_once(&cfg, 0);
        let s = st.slot(0);
        let mut lava = 0;
        for r in 1..s.h as i32 - 1 {
            for c in 1..s.w as i32 - 1 {
                if s.cell(Pos::new(r, c)) == CellType::Lava {
                    lava += 1;
                }
            }
        }
        assert!(lava > 0, "lava crossing must contain lava");
        assert!(reachable(&st, 0, goal_pos(&st, 0).unwrap(), false));
    }
}
