//! Layout solvability analysis, shared by the per-family layout tests and
//! the registry-wide conformance sweep: BFS reachability over one env slot,
//! and per-slot goal lookup.
//!
//! Formerly a test-only helper that inspected slot 0 and panicked on
//! goal-less layouts; goal-less families (Unlock, Fetch, KeyCorridor, …)
//! made both assumptions wrong, so both functions are per-slot and
//! [`goal_pos`] returns an `Option`.

use crate::core::components::Direction;
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::BatchedState;
use std::collections::VecDeque;

/// Breadth-first reachability from env `i`'s player to `target`. With
/// `through_doors`, closed/locked doors and pickable entities count as
/// passable (topological solvability); without it, only currently-walkable
/// cells are traversed — the target itself is exempt, so a blocked target
/// cell still counts as reached from an adjacent cell.
pub fn reachable(st: &BatchedState, i: usize, target: Pos, through_doors: bool) -> bool {
    let s = st.slot(i);
    let start = s.player();
    let mut seen = vec![false; s.h * s.w];
    let mut queue = VecDeque::new();
    seen[(start.r as usize) * s.w + start.c as usize] = true;
    queue.push_back(start);
    while let Some(p) = queue.pop_front() {
        if p == target {
            return true;
        }
        for d in Direction::ALL {
            let q = p.step(d);
            if !q.in_bounds(s.h, s.w) {
                continue;
            }
            let qi = (q.r as usize) * s.w + q.c as usize;
            if seen[qi] {
                continue;
            }
            let passable = if through_doors {
                s.cell(q).walkable()
            } else {
                s.walkable(q) || q == target
            };
            if passable {
                seen[qi] = true;
                queue.push_back(q);
            }
        }
    }
    false
}

/// Position of env `i`'s (first) goal cell, if the layout has one.
/// Goal-less families return `None`.
pub fn goal_pos(st: &BatchedState, i: usize) -> Option<Pos> {
    let s = st.slot(i);
    for r in 0..s.h as i32 {
        for c in 0..s.w as i32 {
            if s.cell(Pos::new(r, c)) == CellType::Goal {
                return Some(Pos::new(r, c));
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::reset_once;

    #[test]
    fn goal_pos_is_per_slot_and_optional() {
        let cfg = make("Navix-Empty-5x5-v0").unwrap();
        let st = reset_once(&cfg, 0);
        assert_eq!(goal_pos(&st, 0), Some(Pos::new(3, 3)));
        let cfg = make("Navix-Unlock-v0").unwrap();
        let st = reset_once(&cfg, 0);
        assert_eq!(goal_pos(&st, 0), None, "goal-less layout must not panic");
    }

    #[test]
    fn goal_pos_inspects_the_requested_slot() {
        use crate::core::state::BatchedState;
        use crate::rng::Key;
        let cfg = make("Navix-FourRooms-v0").unwrap();
        let mut st = BatchedState::new(2, cfg.h, cfg.w, cfg.caps);
        {
            let mut s = st.slot_mut(0);
            cfg.reset_slot(&mut s, Key::new(100)).unwrap();
        }
        let g0 = goal_pos(&st, 0).unwrap();
        // FourRooms goals are random per slot; across a handful of seeds in
        // slot 1 at least one must differ from slot 0's — something a
        // slot-0-only lookup could never observe.
        let mut saw_distinct = false;
        for seed in 101..106 {
            let mut s = st.slot_mut(1);
            cfg.reset_slot(&mut s, Key::new(seed)).unwrap();
            drop(s);
            assert_eq!(goal_pos(&st, 0), Some(g0), "slot 0 must be untouched");
            saw_distinct |= goal_pos(&st, 1).unwrap() != g0;
        }
        assert!(saw_distinct, "per-slot lookup must see each slot's own goal");
    }
}
