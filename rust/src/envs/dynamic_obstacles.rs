//! Dynamic-Obstacles-NxN: an empty room with drifting balls; colliding with
//! one terminates with −1 (paper Table 8: R3). The obstacle count follows
//! MiniGrid's default `size / 2`.

use crate::core::components::{Color, Direction};
use crate::core::entities::CellType;
use crate::core::grid::Pos;
use crate::core::state::{PlacementError, SlotMut};

/// Obstacle count for an `n × n` grid (MiniGrid's DynamicObstaclesEnv
/// default `n_obstacles = size // 2`, capped to leave the room navigable).
pub fn n_obstacles(size: usize) -> usize {
    (size / 2).clamp(1, (size - 2) * (size - 2) / 4)
}

pub fn generate(s: &mut SlotMut<'_>, n: usize) -> Result<(), PlacementError> {
    s.fill_room();
    let (h, w) = (s.h as i32, s.w as i32);
    s.set_cell(Pos::new(h - 2, w - 2), CellType::Goal, Color::Green);
    s.place_player(Pos::new(1, 1), Direction::East);
    for _ in 0..n {
        // the goal cell is not floor, so the sample can never land on it
        let p = s.sample_free_cell(true)?;
        s.add_ball(p, Color::Blue);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reset_once};

    #[test]
    fn obstacle_counts_match_minigrid_rule() {
        assert_eq!(n_obstacles(5), 2);
        assert_eq!(n_obstacles(6), 3);
        assert_eq!(n_obstacles(8), 4);
        assert_eq!(n_obstacles(16), 8);
    }

    #[test]
    fn balls_are_placed_on_free_cells() {
        for (id, expect) in [
            ("Navix-Dynamic-Obstacles-5x5", 2),
            ("Navix-Dynamic-Obstacles-6x6", 3),
            ("Navix-Dynamic-Obstacles-8x8", 4),
            ("Navix-Dynamic-Obstacles-16x16", 8),
        ] {
            let cfg = make(id).unwrap();
            let st = reset_once(&cfg, 7);
            let s = st.slot(0);
            let placed = s.ball_pos.iter().filter(|&&b| b >= 0).count();
            assert_eq!(placed, expect, "{id}");
            for &b in s.ball_pos.iter().filter(|&&b| b >= 0) {
                let p = Pos::decode(b, s.w);
                assert_eq!(s.cell(p), CellType::Floor);
                assert_ne!(p, s.player());
                assert_ne!(Some(p), goal_pos(&st, 0));
            }
        }
    }

    #[test]
    fn config_marks_balls_stochastic() {
        let cfg = make("Navix-Dynamic-Obstacles-8x8").unwrap();
        assert!(cfg.stochastic_balls);
    }
}
