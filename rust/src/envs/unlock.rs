//! The RoomGrid Unlock family: two 6×6 rooms side by side with a locked
//! door between them and the matching key in the agent's (left) room.
//!
//! * `Unlock` — success is opening the door (`on_door_unlocked`).
//! * `UnlockPickup` — a box sits in the right room; success is picking it
//!   up (`on_object_picked`).
//! * `BlockedUnlockPickup` — same, plus a ball dropped directly in front of
//!   the door that must be moved out of the way first.

use super::roomgrid::RoomGrid;
use crate::core::components::{Color, Direction, DoorState};
use crate::core::entities::Tag;
use crate::core::grid::Pos;
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

/// Which member of the Unlock family to build.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Unlock,
    Pickup,
    BlockedPickup,
}

/// MiniGrid `room_size` for the family.
pub const ROOM_SIZE: usize = 6;

/// Grid dims (one row of two `ROOM_SIZE` rooms): 6×11.
pub fn dims() -> (usize, usize) {
    RoomGrid::new(ROOM_SIZE, 1, 2).dims()
}

pub fn generate(s: &mut SlotMut<'_>, kind: Kind) -> Result<(), PlacementError> {
    let rg = RoomGrid::new(ROOM_SIZE, 1, 2);
    rg.carve(s);

    let (door_ci, box_ci, ball_ci) = {
        let mut rng = s.rng();
        (rng.below(6) as u8, rng.below(6) as u8, rng.below(6) as u8)
    };
    let door_color = Color::from_u8(door_ci);
    let door_p = rg.add_door(s, 0, 0, Direction::East, door_color, DoorState::Locked);

    if kind == Kind::BlockedPickup {
        // The blocker sits directly in front of the door on the agent side.
        s.add_ball(Pos::new(door_p.r, door_p.c - 1), Color::from_u8(ball_ci));
    }

    // Key in the left room (sampled after the blocker so they never collide).
    let key_p = rg.place_in_room(s, 0, 0, false)?;
    s.add_key(key_p, door_color);

    match kind {
        Kind::Unlock => {
            s.set_mission(Mission::go_to(Tag::DOOR, door_color));
        }
        Kind::Pickup | Kind::BlockedPickup => {
            let box_p = rg.place_in_room(s, 0, 1, false)?;
            s.add_box(box_p, Color::from_u8(box_ci));
            s.set_mission(Mission::pick_up(Tag::BOX, Color::from_u8(box_ci)));
        }
    }

    rg.place_agent(s, 0, 0)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, reachable, reset_once};
    use crate::systems::intervention::intervene;

    #[test]
    fn unlock_layout_key_matches_door_and_no_goal() {
        let cfg = make("Navix-Unlock-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            assert!(goal_pos(&st, 0).is_none(), "Unlock is goal-less");
            assert_eq!(DoorState::from_u8(s.door_state[0]), DoorState::Locked);
            assert_eq!(s.key_color[0], s.door_color[0], "key must open the door");
            let door = Pos::decode(s.door_pos[0], s.w);
            let key = Pos::decode(s.key_pos[0], s.w);
            assert!(key.c < door.c, "seed {seed}: key on the agent side");
            assert!(s.player().c < door.c, "seed {seed}: agent on the left");
            assert!(reachable(&st, 0, key, false), "seed {seed}: key unreachable");
            assert_eq!(s.mission_value().kind_tag(), Tag::DOOR);
        }
    }

    #[test]
    fn unlock_pickup_box_behind_the_locked_door() {
        let cfg = make("Navix-UnlockPickup-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let door = Pos::decode(s.door_pos[0], s.w);
            let bx = Pos::decode(s.box_pos[0], s.w);
            assert!(bx.c > door.c, "seed {seed}: box must be in the far room");
            assert!(!reachable(&st, 0, bx, false), "seed {seed}: box reachable without the key");
            assert!(reachable(&st, 0, bx, true), "seed {seed}: box unreachable through doors");
            assert_eq!(
                s.mission_value(),
                Mission::pick_up(Tag::BOX, Color::from_u8(s.box_color[0]))
            );
        }
    }

    #[test]
    fn blocked_variant_puts_a_ball_before_the_door() {
        let cfg = make("Navix-BlockedUnlockPickup-v0").unwrap();
        for seed in 0..15 {
            let st = reset_once(&cfg, seed);
            let s = st.slot(0);
            let door = Pos::decode(s.door_pos[0], s.w);
            let ball = Pos::decode(s.ball_pos[0], s.w);
            assert_eq!(ball, Pos::new(door.r, door.c - 1), "seed {seed}: blocker misplaced");
        }
    }

    #[test]
    fn unlocking_the_door_ends_an_unlock_episode() {
        // Script: teleport in front of the door with the key and toggle.
        let cfg = make("Navix-Unlock-v0").unwrap();
        let mut st = reset_once(&cfg, 3);
        let mut s = st.slot_mut(0);
        let door = Pos::decode(s.door_pos[0], s.w);
        let key_color = Color::from_u8(s.key_color[0]);
        s.remove_key(0);
        s.pocket[0] = crate::core::components::Pocket::holding(Tag::KEY, key_color).0;
        s.place_player(Pos::new(door.r, door.c - 1), Direction::East);
        intervene(&mut s, Action::Toggle);
        assert!(s.events[0].door_unlocked);
        drop(s);
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Toggle, cfg.max_steps), 1.0);
    }
}
