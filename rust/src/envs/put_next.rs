//! PutNext-{N}x{N}-N{k}: an empty room scattered with `k` objects of
//! distinct kind×colour; the mission is to pick the target object up and
//! drop it on a cell 4-adjacent to the mission's *second* object (BabyAI's
//! PutNext / MiniGrid's PutNear, expressed through the typed [`Mission`]
//! put-next verb and the `object_placed` event).

use crate::core::components::{Color, Direction};
use crate::core::mission::Mission;
use crate::core::state::{PlacementError, SlotMut};

use super::go_to_obj::place_distinct_objects;

pub fn generate(s: &mut SlotMut<'_>, n_objs: usize) -> Result<(), PlacementError> {
    debug_assert!(n_objs >= 2, "PutNext needs a moved object and a target");
    s.fill_room();
    let placed = place_distinct_objects(s, n_objs)?;

    // Mission: move object `mv` next to object `nr` (uniform over ordered
    // distinct pairs).
    let (mv, nr) = {
        let mut rng = s.rng();
        let mv = rng.below(n_objs as u32) as usize;
        let mut nr = rng.below(n_objs as u32 - 1) as usize;
        if nr >= mv {
            nr += 1;
        }
        (mv, nr)
    };
    s.set_mission(Mission::put_next(
        placed[mv].0,
        Color::from_u8(placed[mv].1),
        placed[nr].0,
        Color::from_u8(placed[nr].1),
    ));

    let agent = s.sample_free_cell(false)?;
    let dir = {
        let mut rng = s.rng();
        rng.randint(0, 4)
    };
    s.place_player(agent, Direction::from_i32(dir));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::actions::Action;
    use crate::core::entities::Tag;
    use crate::core::grid::Pos;
    use crate::core::mission::MissionVerb;
    use crate::envs::registry::make;
    use crate::envs::testutil::{goal_pos, object_exists, reset_once};
    use crate::systems::intervention::intervene;

    #[test]
    fn mission_names_two_distinct_placed_objects() {
        for id in ["Navix-PutNext-6x6-N2-v0", "Navix-PutNext-8x8-N3-v0"] {
            let cfg = make(id).unwrap();
            for seed in 0..15 {
                let st = reset_once(&cfg, seed);
                let s = st.slot(0);
                assert!(goal_pos(&st, 0).is_none(), "{id}: PutNext is goal-less");
                let m = s.mission_value();
                assert_eq!(m.verb(), Some(MissionVerb::PutNext), "{id} seed {seed}");
                assert_ne!(
                    (m.kind_tag(), m.color()),
                    (m.near_kind_tag(), m.near_color()),
                    "{id} seed {seed}: moved and target object must differ"
                );
                assert!(
                    object_exists(&s, m.kind_tag(), m.color() as u8),
                    "{id} seed {seed}: moved object"
                );
                assert!(
                    object_exists(&s, m.near_kind_tag(), m.near_color() as u8),
                    "{id} seed {seed}: near target"
                );
            }
        }
    }

    #[test]
    fn carrying_the_object_to_the_target_terminates_with_reward() {
        // Deterministic construction: ball to move, box as the target.
        let cfg = make("Navix-PutNext-6x6-N2-v0").unwrap();
        let mut st = crate::core::state::BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.add_ball(Pos::new(1, 1), Color::Purple);
        s.add_box(Pos::new(2, 4), Color::Green);
        s.set_mission(Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green));
        s.place_player(Pos::new(1, 2), Direction::West); // facing the ball
        intervene(&mut s, Action::Pickup);
        assert!(!s.events[0].object_picked, "put-next pickups fire no pickup-mission events");
        assert!(!s.events[0].wrong_pickup);
        // walk to (3,3), face east, drop at (3,4) — adjacent to the box.
        s.place_player(Pos::new(3, 3), Direction::East);
        intervene(&mut s, Action::Drop);
        assert!(s.events[0].object_placed);
        drop(s);
        assert!(cfg.termination.eval(&st.slot(0)));
        assert_eq!(cfg.reward.eval(&st.slot(0), Action::Drop, cfg.max_steps), 1.0);
    }

    #[test]
    fn dropping_far_from_the_target_does_not_terminate() {
        let cfg = make("Navix-PutNext-6x6-N2-v0").unwrap();
        let mut st = crate::core::state::BatchedState::new(1, cfg.h, cfg.w, cfg.caps);
        let mut s = st.slot_mut(0);
        s.fill_room();
        s.add_ball(Pos::new(1, 1), Color::Purple);
        s.add_box(Pos::new(4, 4), Color::Green);
        s.set_mission(Mission::put_next(Tag::BALL, Color::Purple, Tag::BOX, Color::Green));
        s.place_player(Pos::new(1, 2), Direction::West);
        intervene(&mut s, Action::Pickup);
        s.place_player(Pos::new(1, 2), Direction::West); // drop back at (1,1)
        intervene(&mut s, Action::Drop);
        assert!(!s.events[0].object_placed);
        drop(s);
        assert!(!cfg.termination.eval(&st.slot(0)));
    }
}
