//! # navix-rs — NAVIX (MiniGrid-in-JAX) reproduced as a Rust + JAX + Pallas stack
//!
//! This crate is the Layer-3 coordinator and simulator substrate of a
//! three-layer reproduction of *"NAVIX: Scaling MiniGrid Environments with
//! JAX"* (NeurIPS 2025):
//!
//! * [`core`], [`systems`], [`envs`] — the full MiniGrid/NAVIX environment
//!   suite as an Entity-Component-System engine with struct-of-arrays batched
//!   state (the paper's contribution, rebuilt natively).
//! * [`batch`] — the batched stepper (the `jax.vmap` analog) with autoreset,
//!   the sharded multi-core stepper (the `jax.pmap` analog) that splits
//!   the batch across a fixed worker pool with bit-identical results, and
//!   the double-buffered rollout pipeline that overlaps env stepping with
//!   learner compute (again bit-identical).
//! * [`baseline`] — a faithful scalar, object-oriented MiniGrid engine plus
//!   gymnasium-style vector wrappers (the system the paper benchmarks
//!   against).
//! * [`nn`], [`agents`] — PPO / Double-DQN / SAC baselines (paper §4.3) on a
//!   manual-backprop NN substrate.
//! * [`runtime`] — PJRT client that loads the AOT artifacts produced by the
//!   build-time Python layers (JAX model + Pallas kernels) and executes them
//!   from the Rust hot path.
//! * [`coordinator`] — training orchestration: XLA-fused PPO, multi-agent
//!   parallel training (paper Fig. 6), throughput harnesses (Figs. 4/5).
//! * [`bench_harness`] — timing/statistics used by every `benches/fig*.rs`.
//!
//! Python never runs on the request path: `make artifacts` lowers the JAX
//! model (with its Pallas kernels) to HLO text once; the Rust binary is
//! self-contained afterwards.

pub mod bench_harness;
pub mod cli;
pub mod config;
pub mod rng;
pub mod simd;

pub mod core;
pub mod systems;
pub mod envs;
pub mod batch;
pub mod baseline;

pub mod nn;
pub mod agents;

pub mod runtime;
pub mod coordinator;

pub use crate::batch::{
    BatchStepper, BatchedEnv, EngineFault, FaultPolicy, FaultStats, PipelinedEnv, ShardedEnv,
};
pub use crate::bench_harness::chaos::{ChaosInjector, ChaosKind, ChaosSpec};
pub use crate::core::snapshot::{EngineCheckpoint, SlotCheckpoint, SlotSnapshot};
pub use crate::core::actions::Action;
pub use crate::core::timestep::{StepType, Timestep};
pub use crate::envs::registry::{list_envs, make, make_with};
pub use crate::simd::KernelPath;
