//! Grid geometry: coordinates, direction algebra, cell indexing.
//!
//! Positions are encoded as a single `i32` cell index `r * W + c` (−1 means
//! "absent"/"picked up"), which keeps the batched component arrays flat and
//! branch-light — the same trick the JAX implementation uses to keep shapes
//! static.

use super::components::Direction;

/// A (row, col) coordinate pair. Row 0 is the top of the grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pos {
    pub r: i32,
    pub c: i32,
}

impl Pos {
    #[inline]
    pub const fn new(r: i32, c: i32) -> Self {
        Pos { r, c }
    }

    /// Encode to a flat cell index for a grid of width `w`; −1 if absent.
    #[inline]
    pub fn encode(self, w: usize) -> i32 {
        if self.r < 0 || self.c < 0 {
            -1
        } else {
            self.r * w as i32 + self.c
        }
    }

    /// Decode from a flat cell index.
    #[inline]
    pub fn decode(idx: i32, w: usize) -> Self {
        if idx < 0 {
            Pos { r: -1, c: -1 }
        } else {
            Pos { r: idx / w as i32, c: idx % w as i32 }
        }
    }

    /// Translate one step along `dir`.
    #[inline]
    pub fn step(self, dir: Direction) -> Pos {
        let (dr, dc) = dir.vec();
        Pos { r: self.r + dr, c: self.c + dc }
    }

    /// Translate `n` steps along `dir`.
    #[inline]
    pub fn step_n(self, dir: Direction, n: i32) -> Pos {
        let (dr, dc) = dir.vec();
        Pos { r: self.r + dr * n, c: self.c + dc * n }
    }

    #[inline]
    pub fn in_bounds(self, h: usize, w: usize) -> bool {
        self.r >= 0 && self.c >= 0 && (self.r as usize) < h && (self.c as usize) < w
    }

    /// Manhattan distance.
    #[inline]
    pub fn l1(self, other: Pos) -> i32 {
        (self.r - other.r).abs() + (self.c - other.c).abs()
    }
}

/// Immutable grid dimensions helper shared by systems.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GridDims {
    pub h: usize,
    pub w: usize,
}

impl GridDims {
    #[inline]
    pub fn new(h: usize, w: usize) -> Self {
        GridDims { h, w }
    }

    #[inline]
    pub fn cells(self) -> usize {
        self.h * self.w
    }

    #[inline]
    pub fn idx(self, r: usize, c: usize) -> usize {
        debug_assert!(r < self.h && c < self.w);
        r * self.w + c
    }

    #[inline]
    pub fn contains(self, p: Pos) -> bool {
        p.in_bounds(self.h, self.w)
    }

    /// Iterator over interior cells (excluding the outer wall ring).
    pub fn interior(self) -> impl Iterator<Item = Pos> {
        let (h, w) = (self.h as i32, self.w as i32);
        (1..h - 1).flat_map(move |r| (1..w - 1).map(move |c| Pos::new(r, c)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_decode_roundtrip() {
        let w = 8;
        for r in 0..8 {
            for c in 0..8 {
                let p = Pos::new(r, c);
                assert_eq!(Pos::decode(p.encode(w), w), p);
            }
        }
        assert_eq!(Pos::new(-1, -1).encode(w), -1);
        assert_eq!(Pos::decode(-1, w), Pos::new(-1, -1));
    }

    #[test]
    fn step_follows_direction_vectors() {
        let p = Pos::new(3, 3);
        assert_eq!(p.step(Direction::East), Pos::new(3, 4));
        assert_eq!(p.step(Direction::South), Pos::new(4, 3));
        assert_eq!(p.step(Direction::West), Pos::new(3, 2));
        assert_eq!(p.step(Direction::North), Pos::new(2, 3));
        assert_eq!(p.step_n(Direction::East, 3), Pos::new(3, 6));
    }

    #[test]
    fn bounds() {
        assert!(Pos::new(0, 0).in_bounds(5, 5));
        assert!(Pos::new(4, 4).in_bounds(5, 5));
        assert!(!Pos::new(5, 0).in_bounds(5, 5));
        assert!(!Pos::new(0, -1).in_bounds(5, 5));
    }

    #[test]
    fn interior_excludes_border() {
        let d = GridDims::new(5, 5);
        let cells: Vec<Pos> = d.interior().collect();
        assert_eq!(cells.len(), 9);
        assert!(cells.iter().all(|p| p.r >= 1 && p.r <= 3 && p.c >= 1 && p.c <= 3));
    }

    #[test]
    fn l1_distance() {
        assert_eq!(Pos::new(0, 0).l1(Pos::new(3, 4)), 7);
    }
}
